//! Lowered-module dispatch throughput on the PJRT backend.
//!
//! Emits a fresh artifact set (`segmul lower`'s library entry point) for
//! every registry design at n = 16, then measures `eval_design` through
//! the lowered modules — the exact path a `--designs all` sweep runs on
//! the accelerator backend. Bit-exactness against the CPU batched backend
//! is asserted before anything is timed. The summary publishes one
//! `pjrt_<family>_pairs_per_s` metric per design family plus the
//! dispatch-coverage count for the CI bench-regression gate
//! (`BENCH_pjrt.json`).

use segmul::bench::{bench, section, throughput, Summary};
use segmul::coordinator::{CpuBackend, EvalBackend, PjrtBackend};
use segmul::multiplier::{DispatchClass, MultiplierSpec};
use segmul::runtime::emit_artifacts;
use segmul::util::rng::Xoshiro256;

const N: u32 = 16;
const BATCH: usize = 8192;

fn main() {
    let dir = std::env::temp_dir().join(format!("segmul_bench_pjrt_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let specs = MultiplierSpec::registry_examples(N);
    emit_artifacts(&dir, &specs, BATCH).expect("emit lowered artifacts");
    let mut pjrt = PjrtBackend::load(&dir).expect("load lowered artifacts");
    let mut cpu = CpuBackend::new();

    let mut rng = Xoshiro256::seed_from_u64(0xBE7C);
    let a: Vec<u64> = (0..BATCH).map(|_| rng.next_bits(N)).collect();
    let b: Vec<u64> = (0..BATCH).map(|_| rng.next_bits(N)).collect();

    section(&format!("pjrt lowered-module dispatch (n={N}, batch {BATCH})"));
    let mut summary = Summary::new("pjrt");
    for spec in &specs {
        assert!(pjrt.supports_design(spec), "{}", spec.name());
        // Bit-exact against the CPU batched backend before timing.
        let sp = pjrt.eval_design(spec, &a, &b).expect("pjrt eval");
        let sc = cpu.eval_design(spec, &a, &b).expect("cpu eval");
        assert_eq!(sp, sc, "pjrt diverged from cpu for {}", spec.name());

        let r = bench(&format!("pjrt {}", spec.name()), Some(BATCH as f64), |iters| {
            let mut acc = 0u64;
            for _ in 0..iters {
                acc ^= pjrt.eval_design(spec, &a, &b).unwrap().err_count;
            }
            acc
        });
        summary.metric(
            &format!("pjrt_{}_pairs_per_s", spec.family()),
            throughput(&r).unwrap_or(0.0),
        );
    }

    // Dispatch-coverage audit: every registry design must have run
    // through a lowered module (the `--require-pjrt` contract).
    let log = pjrt.kernel_dispatch();
    let lowered = log.iter().filter(|(_, c)| *c == DispatchClass::Pjrt).count();
    assert_eq!(lowered, specs.len(), "designs missing from the lowered dispatch log: {log:?}");
    println!();
    println!("dispatch coverage: {lowered}/{} registry designs via lowered modules", specs.len());
    summary.metric("pjrt_design_coverage", lowered as f64);
    summary.write().expect("write bench summary");

    let _ = std::fs::remove_dir_all(&dir);
}
