//! Bench: the analytic answer-source fast path.
//!
//! Measures how fast the `error::analytic` registry answers the full
//! paper sweep grid (every design of `DesignSet::All` over the configured
//! bit-widths) in closed form — the workload `segmul sweep --analytic
//! require` serves with zero pool dispatches. The simulated equivalent
//! costs ~2^{2n} kernel evaluations per grid point; the analytic path
//! answers each point in microseconds.
//!
//! Writes `BENCH_analytic.json` with the two gated metrics:
//!   - `analytic_grid_answers_per_s` — full-grid answer throughput
//!   - `analytic_design_coverage`    — registry families with a model (8)

use segmul::api::{analytic_stats, DesignSet, MultiplierSpec};
use segmul::bench::{bench, section, Summary};

fn paper_grid() -> Vec<MultiplierSpec> {
    // The configured default sweep grid: DesignSet::All over the paper's
    // bit-widths (Config::default().sweep_bitwidths).
    let mut specs = Vec::new();
    for n in [4u32, 8, 16, 32] {
        specs.extend(DesignSet::All.specs(n));
    }
    specs
}

fn main() {
    let grid = paper_grid();
    let modeled = grid.iter().filter(|s| analytic_stats(s).is_some()).count();
    assert_eq!(
        modeled,
        grid.len(),
        "every grid design must have an analytic model (--analytic require contract)"
    );

    // Registry-family coverage: one representative per spec variant, all
    // eight families must be modeled.
    let coverage = MultiplierSpec::registry_examples(8)
        .iter()
        .filter(|s| analytic_stats(s).is_some())
        .count();

    section(&format!(
        "analytic answer source — {} grid points, {} registry families",
        grid.len(),
        coverage
    ));
    let full = bench("full paper grid, closed form", Some(grid.len() as f64), |iters| {
        let mut acc = 0.0f64;
        for _ in 0..iters {
            for spec in &grid {
                let s = analytic_stats(spec).unwrap();
                acc += s.er + s.med_abs;
            }
        }
        acc
    });
    // Per-family single-answer latency (informational).
    for spec in [
        MultiplierSpec::Segmented { n: 32, t: 16, fix: true },
        MultiplierSpec::Truncated { n: 32, k: 16 },
        MultiplierSpec::BrokenArray { n: 32, hbl: 8, vbl: 16 },
        MultiplierSpec::Mitchell { n: 32 },
        MultiplierSpec::Kulkarni { n: 32 },
    ] {
        bench(&format!("single answer {}", spec.name()), Some(1.0), |iters| {
            let mut acc = 0.0f64;
            for _ in 0..iters {
                acc += analytic_stats(&spec).unwrap().med_abs;
            }
            acc
        });
    }

    let answers_per_s = grid.len() as f64 / (full.ns_per_iter * 1e-9);
    let mut summary = Summary::new("analytic");
    summary
        .metric("analytic_grid_answers_per_s", answers_per_s)
        .metric("analytic_design_coverage", coverage as f64)
        .metric("analytic_grid_points", grid.len() as f64);
    match summary.write() {
        Ok(path) => println!("\nwrote {path:?}"),
        Err(e) => println!("\nsummary not written: {e}"),
    }
}
