//! Cost of the fault-injection seams on the zero-fault fast path.
//!
//! Every store/journal/worker/backend seam consults the session's
//! [`FaultInjector`] on the hot path. This bench prices that in two
//! configurations against the same cold-evaluation workload:
//!
//! * **disabled** — the default everyone runs: `fire()` is a single
//!   armed-flag load and returns immediately;
//! * **armed, quiescent** — a `p=0` plan over every hot seam, the
//!   worst case that still never fires: each `fire()` pays the full
//!   per-site counter bump and deterministic RNG draw.
//!
//! Bit-identity between the two configurations is asserted before
//! anything is timed; the summary writes `BENCH_fault.json` with
//! `fault_overhead_ratio` (armed-vs-disabled throughput ratio, ~1.0
//! when the seams are free) for the CI bench-regression gate.

use std::sync::Arc;

use segmul::api::{BackendChoice, EvalJob, Session};
use segmul::bench::{bench, section, speedup, Summary};
use segmul::fault::FaultInjector;
use segmul::util::threadpool::default_workers;

/// Every hot seam armed, none of them ever firing.
const QUIESCENT: &str = "store.read:p=0,store.write:p=0,journal.append:p=0,worker.panic:p=0,backend.fail:p=0";

fn session(faults: Arc<FaultInjector>, workers: usize) -> Session {
    Session::builder()
        .workers(workers)
        .backend(BackendChoice::Cpu)
        .cache(false) // measure the evaluation path, not the in-memory cache
        .faults(faults)
        .build()
        .expect("session startup")
}

fn main() {
    let workers = default_workers().expect("invalid SEGMUL_WORKERS").max(2);
    let job = EvalJob::mc(8, 3, true, 1 << 14, 42);

    let mut disabled = session(Arc::new(FaultInjector::disabled()), workers);
    let armed_plan = Arc::new(FaultInjector::parse(QUIESCENT, 0x5EED).expect("valid quiescent plan"));
    let mut armed = session(armed_plan.clone(), workers);

    // A quiescent plan must be invisible in the answers before it is
    // allowed to be invisible in the timings.
    let base = disabled.run(&job).expect("disabled run");
    let under_seams = armed.run(&job).expect("armed run");
    assert_eq!(base.stats, under_seams.stats, "a p=0 plan changed the answer");
    assert_eq!(base.stats.sum_red.to_bits(), under_seams.stats.sum_red.to_bits(), "sum_red bits diverged");

    section(&format!("fault-seam overhead ({workers} workers, cache disabled)"));
    let s_disabled = bench("cold eval, injector disabled", Some(1.0), |iters| {
        let mut acc = 0u64;
        for _ in 0..iters {
            acc ^= disabled.run(&job).unwrap().stats.err_count;
        }
        acc
    });
    let s_armed = bench("cold eval, armed p=0 plan", Some(1.0), |iters| {
        let mut acc = 0u64;
        for _ in 0..iters {
            acc ^= armed.run(&job).unwrap().stats.err_count;
        }
        acc
    });
    assert_eq!(armed_plan.total_injected(), 0, "a p=0 plan must never fire");

    // > 1 would mean the armed seams are somehow faster; ~1.0 is the
    // target, and the gate floor catches the fast path growing a cost.
    let ratio = speedup(&s_armed, &s_disabled);
    let overhead_pct = (1.0 / ratio - 1.0) * 100.0;
    println!();
    println!("armed-vs-disabled throughput ratio      : {ratio:>9.3}x");
    println!("zero-fault fast-path overhead           : {overhead_pct:>8.2} %");

    let mut summary = Summary::new("fault");
    summary.metric("fault_overhead_ratio", ratio);
    summary.write().expect("write bench summary");
}
