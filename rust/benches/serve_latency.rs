//! Serving-path latency and coalescing: loopback HTTP clients against an
//! in-process `segmul serve` server.
//!
//! Each round posts one heavy "primer" eval (which occupies the engine
//! thread) and then a burst of identical small evals; the burst queues
//! behind the primer and the coalescer answers it with a single pool
//! dispatch. The summary writes `BENCH_serve.json` for the CI
//! bench-regression gate:
//!
//! - `serve_reqs_per_s`        end-to-end request throughput (gated floor)
//! - `serve_coalesce_ratio`    requests per pool dispatch (gated floor)
//! - `serve_p99_ms`            client-observed p99 latency (informational;
//!   lower is better, so it is never gated by the higher-is-better rule)
//!
//! `SEGMUL_BENCH_FAST=1` shrinks rounds and sample counts for smoke runs.

use std::time::Instant;

use segmul::api::BackendChoice;
use segmul::bench::{section, Summary};
use segmul::report::percentile;
use segmul::serve::{client, metrics::metric_value, ServeConfig, Server};
use segmul::util::json::Json;
use segmul::util::threadpool::default_workers;

fn eval_body(t: u32, samples: u64, seed: u64) -> Json {
    let text = format!(
        r#"{{"design":{{"family":"segmented","n":16,"t":{t},"fix":true}},
            "workload":{{"kind":"mc","samples":{samples},"seed":{seed}}}}}"#
    );
    Json::parse(&text).expect("static request body")
}

fn main() {
    let fast = std::env::var_os("SEGMUL_BENCH_FAST").is_some();
    let workers = default_workers().expect("invalid SEGMUL_WORKERS").max(2);
    let rounds: u64 = if fast { 3 } else { 8 };
    let burst: u64 = 8;
    let primer_samples: u64 = if fast { 1 << 15 } else { 1 << 17 };
    let burst_samples: u64 = if fast { 1 << 12 } else { 1 << 14 };

    let server = Server::start(ServeConfig {
        backend: BackendChoice::Cpu,
        workers: Some(workers),
        ..ServeConfig::default()
    })
    .expect("server startup");
    let addr = server.addr();

    // Warm the engine (first-request costs: thread spawn, pool build).
    let warm = client::post_json(addr, "/v1/eval", &eval_body(1, 1 << 10, 1)).expect("warm-up");
    assert_eq!(warm.status, 200, "warm-up failed: {}", warm.text());

    section(&format!(
        "serve latency ({workers} workers, {rounds} rounds x {burst}-client coalesced bursts)"
    ));
    let mut latencies_ms: Vec<f64> = Vec::new();
    let t0 = Instant::now();
    for round in 0..rounds {
        // The primer keeps the engine busy so the burst piles up in the
        // admission queue and is answered by one coalesced dispatch.
        let primer = std::thread::spawn(move || {
            client::post_json(addr, "/v1/eval", &eval_body(7, primer_samples, 100 + round))
        });
        std::thread::sleep(std::time::Duration::from_millis(30));
        let clients: Vec<_> = (0..burst)
            .map(|_| {
                std::thread::spawn(move || {
                    let started = Instant::now();
                    let resp = client::post_json(
                        addr,
                        "/v1/eval",
                        &eval_body(3, burst_samples, 1000 + round),
                    )?;
                    Ok::<_, segmul::api::SegmulError>((
                        resp.status,
                        started.elapsed().as_secs_f64() * 1e3,
                    ))
                })
            })
            .collect();
        for handle in clients {
            let (status, lat) = handle.join().expect("client thread").expect("burst request");
            assert_eq!(status, 200, "burst request failed");
            latencies_ms.push(lat);
        }
        let primed = primer.join().expect("primer thread").expect("primer request");
        assert_eq!(primed.status, 200, "primer request failed");
    }
    let wall = t0.elapsed().as_secs_f64();
    let requests = rounds * (burst + 1);
    let reqs_per_s = requests as f64 / wall;

    let scrape = client::get(addr, "/metrics").expect("/metrics scrape");
    let doc = scrape.text();
    let coalesce_ratio: f64 = metric_value(&doc, "serve_coalesce_ratio")
        .and_then(|v| v.parse().ok())
        .expect("serve_coalesce_ratio in /metrics");

    latencies_ms.sort_by(|a, b| a.partial_cmp(b).expect("finite latency"));
    let p50 = percentile(&latencies_ms, 0.50);
    let p99 = percentile(&latencies_ms, 0.99);
    println!("request throughput            : {reqs_per_s:>9.1} reqs/s ({requests} requests)");
    println!("coalesce ratio                : {coalesce_ratio:>9.2} requests/dispatch");
    println!("burst latency p50 / p99       : {p50:>6.1} ms / {p99:.1} ms");

    let down = client::post_json(addr, "/v1/shutdown", &Json::Obj(Default::default()))
        .expect("shutdown");
    assert_eq!(down.status, 200, "shutdown failed");
    let summary = server.join();
    assert!(
        summary.telemetry.jobs_completed >= 1,
        "server answered no jobs"
    );

    let mut out = Summary::new("serve");
    out.metric("serve_reqs_per_s", reqs_per_s)
        .metric("serve_coalesce_ratio", coalesce_ratio)
        .metric("serve_p99_ms", p99);
    out.write().expect("write bench summary");
}
