//! Bench the gate-level substrate: 64-way bit-parallel simulation
//! throughput (the Fig. 3 power-estimation workhorse) and netlist
//! generation cost.

use segmul::bench::{bench, section};
use segmul::multiplier::U512;
use segmul::netlist::generators::seq_mult::{run_batch, seq_mult};
use segmul::netlist::SeqSim;
use segmul::util::rng::Xoshiro256;

fn main() {
    section("netlist generation");
    for n in [32u32, 128, 256] {
        bench(&format!("seq_mult(n={n}, t=n/2, fix) build"), None, |iters| {
            let mut acc = 0usize;
            for _ in 0..iters {
                acc ^= seq_mult(n, n / 2, true).nl.gate_count();
            }
            acc
        });
    }

    section("64-way cycle-accurate simulation (64 multiplies/batch)");
    for n in [32u32, 128, 256] {
        let c = seq_mult(n, n / 2, true);
        let gates = c.nl.gate_count() as f64;
        let mut sim = SeqSim::new(&c.nl);
        let mut rng = Xoshiro256::seed_from_u64(9);
        let a: Vec<U512> = (0..64).map(|_| U512::from_u64(rng.next_bits(n.min(63)))).collect();
        let b: Vec<U512> = (0..64).map(|_| U512::from_u64(rng.next_bits(n.min(63)))).collect();
        // gate-evals per run_batch = gates * (n + 2) cycles
        let evals = gates * (n as f64 + 2.0);
        bench(&format!("sim n={n} ({} gates)", gates as u64), Some(evals), |iters| {
            let mut acc = 0u64;
            for _ in 0..iters {
                acc ^= run_batch(&c, &mut sim, &a, &b, true)[0].limb(0);
            }
            acc
        });
    }
}
