//! Sharded sweep throughput: the parallel orchestrator vs the sequential
//! driver on the same job, plus a grid pass through the result cache.
//!
//! Determinism is asserted inline (parallel stats must equal sequential
//! bit-for-bit) and the summary writes `BENCH_sweep_parallel.json` for
//! the CI bench-regression gate. Pin workers with `SEGMUL_WORKERS` for
//! reproducible CI numbers.

use segmul::bench::{bench, section, speedup, throughput, Summary};
use segmul::coordinator::{run_job_sharded, CpuBackend, EvalBackend, EvalJob};
use segmul::util::threadpool::default_workers;

use anyhow::Result;

fn factory() -> Result<Box<dyn EvalBackend>> {
    Ok(Box::new(CpuBackend::new()))
}

fn main() {
    // n=10 exhaustive: 2^20 pairs in 16 chunks of 2^16 — big enough to
    // shard, small enough for a CI smoke run.
    let job = EvalJob::exhaustive(10, 4, true);
    let pairs = (1u64 << 20) as f64;
    let workers = default_workers().expect("invalid SEGMUL_WORKERS").max(2);

    // Bit-identical before timing anything.
    let seq = run_job_sharded(&factory, &job, 1).unwrap();
    let par = run_job_sharded(&factory, &job, workers).unwrap();
    assert_eq!(seq.stats, par.stats, "parallel sweep diverged from sequential");

    section(&format!("sharded exhaustive n=10 sweep ({workers} workers)"));
    let s1 = bench("sweep sequential (1 worker)", Some(pairs), |iters| {
        let mut acc = 0u64;
        for _ in 0..iters {
            acc ^= run_job_sharded(&factory, &job, 1).unwrap().stats.err_count;
        }
        acc
    });
    let sn = bench("sweep sharded (N workers)", Some(pairs), |iters| {
        let mut acc = 0u64;
        for _ in 0..iters {
            acc ^= run_job_sharded(&factory, &job, workers).unwrap().stats.err_count;
        }
        acc
    });

    println!();
    println!("parallel speedup, {workers} workers vs 1       : {:>6.2}x", speedup(&sn, &s1));

    let mut summary = Summary::new("sweep_parallel");
    summary
        .metric("sweep_parallel_speedup", speedup(&sn, &s1))
        .metric("sweep_parallel_workers", workers as f64)
        .metric("sweep_parallel_melem_per_s", throughput(&sn).unwrap_or(0.0) / 1e6)
        .metric("sweep_sequential_melem_per_s", throughput(&s1).unwrap_or(0.0) / 1e6);
    summary.write().expect("write bench summary");
}
