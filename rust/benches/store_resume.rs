//! Result-store answer latency: warm store hits vs fresh evaluation.
//!
//! A warm [`ResultStore`] turns a sweep point into a blob load + seal
//! check instead of a Monte-Carlo evaluation. This bench measures the
//! store-hit answer rate with the in-memory cache disabled (so every
//! `run` goes to disk) and the wall-clock ratio of a cold evaluation to
//! a warm store hit. Bit-identity between the evaluated and store-served
//! results is asserted before anything is timed; the summary writes
//! `BENCH_store.json` for the CI bench-regression gate.

use std::path::{Path, PathBuf};

use segmul::api::{BackendChoice, EvalJob, Session};
use segmul::bench::{bench, section, speedup, throughput, Summary};
use segmul::util::threadpool::default_workers;

fn session(store: Option<&Path>, workers: usize) -> Session {
    let mut builder = Session::builder()
        .workers(workers)
        .backend(BackendChoice::Cpu)
        .cache(false); // measure the store path, not the in-memory cache
    if let Some(dir) = store {
        builder = builder.store(dir);
    }
    builder.build().expect("session startup")
}

fn main() {
    let workers = default_workers().expect("invalid SEGMUL_WORKERS").max(2);
    let job = EvalJob::mc(8, 3, true, 1 << 14, 42);
    let dir: PathBuf = std::env::temp_dir().join(format!("segmul-bench-store-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    // Populate the store once, then prove a fresh session answers the
    // same job from disk bit-identically before timing anything.
    let mut writer = session(Some(&dir), workers);
    let evaluated = writer.run(&job).unwrap();
    assert_eq!(writer.jobs_evaluated(), 1, "first run must evaluate");
    drop(writer);
    let mut warm = session(Some(&dir), workers);
    let served = warm.run(&job).unwrap();
    assert_eq!(warm.store_hits(), 1, "second session must answer from the store");
    assert_eq!(evaluated.stats, served.stats, "store hit diverged from evaluation");

    section(&format!("result store ({workers} workers, cache disabled)"));
    let s_hit = bench("warm store hit (blob load + unseal)", Some(1.0), |iters| {
        let mut acc = 0u64;
        for _ in 0..iters {
            acc ^= warm.run(&job).unwrap().stats.err_count;
        }
        acc
    });
    let mut cold = session(None, workers);
    let s_eval = bench("cold evaluation (no store)", Some(1.0), |iters| {
        let mut acc = 0u64;
        for _ in 0..iters {
            acc ^= cold.run(&job).unwrap().stats.err_count;
        }
        acc
    });

    let hits_per_s = throughput(&s_hit).unwrap_or(0.0);
    let cold_vs_warm = speedup(&s_hit, &s_eval);
    println!();
    println!("store-hit answer rate                   : {hits_per_s:>10.0} answers/s");
    println!("cold-vs-warm wall-clock ratio           : {cold_vs_warm:>9.2}x");
    assert_eq!(warm.jobs_evaluated(), 0, "warm session must never re-evaluate");

    let mut summary = Summary::new("store");
    summary
        .metric("store_hit_answers_per_s", hits_per_s)
        .metric("store_cold_vs_warm_ratio", cold_vs_warm);
    summary.write().expect("write bench summary");

    let _ = std::fs::remove_dir_all(&dir);
}
