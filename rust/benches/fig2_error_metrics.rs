//! Bench E2 / Fig. 2: error-metric evaluation throughput — the cost of
//! regenerating the accuracy figure (exhaustive for small n, MC above).

use segmul::bench::{bench, section};
use segmul::error::exhaustive::{exhaustive_stats, exhaustive_stats_mul};
use segmul::error::montecarlo::{mc_stats, mc_stats_mul, McConfig};
use segmul::multiplier::baselines::{MitchellLog, TruncatedMul};

fn main() {
    section("Fig. 2 — exhaustive evaluation (ours)");
    for n in [8u32, 10, 12] {
        let pairs = (1u64 << (2 * n)) as f64;
        bench(&format!("exhaustive n={n} t={} fix", n / 2), Some(pairs), |iters| {
            let mut acc = 0u64;
            for _ in 0..iters {
                acc ^= exhaustive_stats(n, n / 2, true).err_count;
            }
            acc
        });
    }

    section("Fig. 2 — Monte-Carlo evaluation (ours, n beyond exhaustive)");
    for n in [16u32, 32] {
        let samples = 1u64 << 16;
        let cfg = McConfig::uniform(samples, 42);
        bench(&format!("mc n={n} t={} fix 2^16", n / 2), Some(samples as f64), |iters| {
            let mut acc = 0u64;
            for _ in 0..iters {
                acc ^= mc_stats(n, n / 2, true, &cfg).err_count;
            }
            acc
        });
    }

    section("Fig. 2 — baseline multipliers (exhaustive n=8 / MC n=16)");
    bench("trunc(n=8,k=4) exhaustive", Some((1u64 << 16) as f64), |iters| {
        let m = TruncatedMul { n: 8, k: 4 };
        let mut acc = 0u64;
        for _ in 0..iters {
            acc ^= exhaustive_stats_mul(&m, 1).err_count;
        }
        acc
    });
    bench("mitchell(n=16) mc 2^16", Some((1u64 << 16) as f64), |iters| {
        let m = MitchellLog { n: 16 };
        let cfg = McConfig::uniform(1 << 16, 7);
        let mut acc = 0u64;
        for _ in 0..iters {
            acc ^= mc_stats_mul(&m, &cfg).err_count;
        }
        acc
    });
}
