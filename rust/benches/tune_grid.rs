//! Bench: the `segmul tune` autotuner over the full paper grid.
//!
//! Measures one complete tune call — grid enumeration, closed-form error
//! answers for every point (`AnalyticMode::Require`: zero pool
//! dispatches), the FPGA technology join over the generated netlists,
//! the Pareto frontier, and winner selection. This is the interactive
//! cost a `segmul tune --budget 'mred<=1e-3'` user pays.
//!
//! Writes `BENCH_tune.json`:
//!   - `tune_grid_ms`        — wall ms per full-grid tune (informational,
//!                             lower is better)
//!   - `tune_grid_points`    — candidate count (exact gate: 120)
//!   - `tune_frontier_points`— non-dominated count (floor gate: >= 1)
//!   - `tune_points_per_s`   — candidate throughput (absolute floor)

use segmul::api::{AnalyticMode, Session};
use segmul::bench::{bench, section, Summary};
use segmul::tune::{tune, Budget, TuneQuery};

fn main() {
    let query = TuneQuery::new(Budget::parse("mred<=1e-3").unwrap()).hw_vectors(128);
    let mut session = Session::builder()
        .workers(1)
        .analytic(AnalyticMode::Require)
        .build()
        .unwrap();
    // Correctness preconditions for the numbers below: the whole grid
    // answers in closed form and produces a winner + frontier.
    let first = tune(&mut session, &query).unwrap();
    assert_eq!(first.jobs_evaluated, 0, "require mode must not dispatch the pool");
    assert!(first.winner().is_some(), "the accurate point is always feasible");
    assert!(!first.frontier().is_empty());
    let grid_points = first.points.len();
    let frontier_points = first.frontier().len();

    section(&format!(
        "tune autotuner — {grid_points} grid points, target {}",
        first.target.name()
    ));
    let r = bench("full paper grid tune (closed form)", Some(grid_points as f64), |iters| {
        let mut acc = 0usize;
        for _ in 0..iters {
            acc += tune(&mut session, &query).unwrap().frontier().len();
        }
        acc
    });

    let mut summary = Summary::new("tune");
    summary
        .metric("tune_grid_ms", r.ns_per_iter / 1e6)
        .metric("tune_grid_points", grid_points as f64)
        .metric("tune_frontier_points", frontier_points as f64)
        .metric("tune_points_per_s", grid_points as f64 / (r.ns_per_iter * 1e-9));
    match summary.write() {
        Ok(path) => println!("\nwrote {path:?}"),
        Err(e) => println!("\nsummary not written: {e}"),
    }
}
