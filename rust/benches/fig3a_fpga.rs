//! Bench E4 / Fig. 3a: the FPGA-model evaluation pipeline per bit-width
//! (netlist generation + vector-based activity simulation + LUT packing +
//! timing + power).

use segmul::bench::{bench, section};
use segmul::netlist::generators::seq_mult::seq_mult;
use segmul::tech::{measure_activity, FpgaModel};

fn main() {
    section("Fig. 3a — FPGA evaluation pipeline (accurate + approx)");
    for n in [16u32, 64, 256] {
        let vectors = 256u64;
        bench(&format!("fpga pair n={n} ({vectors} vectors)"), Some(2.0 * vectors as f64), |iters| {
            let mut acc = 0usize;
            for _ in 0..iters {
                let a = seq_mult(n, 0, false);
                let x = seq_mult(n, n / 2, true);
                let aa = measure_activity(&a, vectors, 1, false);
                let xa = measure_activity(&x, vectors, 1, true);
                let m = FpgaModel::default();
                let ra = m.evaluate(&a.nl, &aa, n + 1, None);
                let rx = m.evaluate(&x.nl, &xa, n + 1, Some(ra.figures.period_ns));
                acc ^= ra.luts + rx.luts;
            }
            acc
        });
    }
}
