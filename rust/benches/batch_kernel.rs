//! Batched vs scalar evaluation throughput (the acceptance benchmark for
//! the batched engine): the n=8 exhaustive sweep, measured three ways —
//!
//! 1. `scalar/dyn`   — per-pair virtual `Multiplier::mul` + per-pair
//!                     `ErrorStats::record` (the pre-batching hot path);
//! 2. `scalar/static`— per-pair statically-dispatched `approx_seq_mul`
//!                     (what the old specialized exhaustive loop did);
//! 3. `batched`      — the monomorphized 4-wide batch kernel streaming
//!                     through `BatchAccumulator` (the new engine).
//!
//! Pairs/sec lines are comparable across the three, and the summary prints
//! the batched-over-scalar speedups and writes `BENCH_batch_kernel.json`
//! for the CI bench-regression gate (`bench-gate` vs
//! `ci/bench_baseline.json`).
//! Target: batched ≥ 3x over scalar/dyn on the n=8 exhaustive sweep.
//!
//! The second half sweeps **every registry design** (n = 16): the per-pair
//! scalar reference (`MultiplierSpec::build_scalar_reference`) against the
//! branch-free batch kernel (`MultiplierSpec::build_batch`), printing a
//! per-design speedup summary and writing `BENCH_kernels.json` with
//! `<design>_pairs_per_s` / `<design>_speedup_vs_scalar` metrics — the
//! cross-design throughput trajectory the CI gate tracks.
//! Target: baseline-family batched ≥ 5x over the scalar adapters.

use segmul::bench::{bench, section, speedup, throughput, Summary};
use segmul::error::metrics::ErrorStats;
use segmul::error::stream::BatchAccumulator;
use segmul::multiplier::batch::approx_seq_mul_batch;
use segmul::multiplier::wordlevel::approx_seq_mul;
use segmul::multiplier::{BatchMultiplier, Multiplier, MultiplierSpec, SegmentedSeqMul};
use segmul::util::rng::Xoshiro256;

fn main() {
    let (n, t, fix) = (8u32, 4u32, true);
    let space = 1u64 << (2 * n);
    let pairs = space as f64;
    let mask = (1u64 << n) - 1;
    // Materialized operand arrays for the kernel-only comparison.
    let av: Vec<u64> = (0..space).map(|i| i & mask).collect();
    let bv: Vec<u64> = (0..space).map(|i| i >> n).collect();
    let mut out = vec![0u64; av.len()];
    let m = SegmentedSeqMul::new(n, t, fix);
    let dynm: &dyn Multiplier = &m;

    section("multiply kernel only (n=8 exhaustive operand set)");
    let k_dyn = bench("mul scalar/dyn (per-pair virtual call)", Some(pairs), |iters| {
        let mut acc = 0u64;
        for _ in 0..iters {
            for (&a, &b) in av.iter().zip(&bv) {
                acc ^= dynm.mul(a, b);
            }
        }
        acc
    });
    let k_static = bench("mul scalar/static (inlined fast path)", Some(pairs), |iters| {
        let mut acc = 0u64;
        for _ in 0..iters {
            for (&a, &b) in av.iter().zip(&bv) {
                acc ^= approx_seq_mul(a, b, n, t, fix);
            }
        }
        acc
    });
    let k_batch = bench("mul batched (monomorphized, 4-wide)", Some(pairs), |iters| {
        // XOR-fold the whole output (like the scalar loops) so no store
        // can be eliminated as dead under LTO.
        let mut acc = 0u64;
        for _ in 0..iters {
            approx_seq_mul_batch(&av, &bv, &mut out, n, t, fix);
            for &o in &out {
                acc ^= o;
            }
        }
        acc
    });

    section("full exhaustive sweep (multiply + streaming ErrorStats)");
    let s_dyn = bench("sweep scalar/dyn + per-pair record", Some(pairs), |iters| {
        let mut acc = 0u64;
        for _ in 0..iters {
            let mut stats = ErrorStats::new(n);
            for idx in 0..space {
                let (a, b) = (idx & mask, idx >> n);
                stats.record(a * b, dynm.mul(a, b));
            }
            acc ^= stats.err_count;
        }
        acc
    });
    let s_static = bench("sweep scalar/static + per-pair record", Some(pairs), |iters| {
        let mut acc = 0u64;
        for _ in 0..iters {
            let mut stats = ErrorStats::new(n);
            for idx in 0..space {
                let (a, b) = (idx & mask, idx >> n);
                stats.record(a * b, approx_seq_mul(a, b, n, t, fix));
            }
            acc ^= stats.err_count;
        }
        acc
    });
    let s_batch = bench("sweep batched engine (BatchAccumulator)", Some(pairs), |iters| {
        let mut acc = 0u64;
        for _ in 0..iters {
            let mut ba = BatchAccumulator::new(&m);
            ba.eval_index_range(0, space);
            acc ^= ba.finish().err_count;
        }
        acc
    });

    println!();
    println!("kernel speedup, batched vs scalar/dyn    : {:>6.2}x", speedup(&k_batch, &k_dyn));
    println!("kernel speedup, batched vs scalar/static : {:>6.2}x", speedup(&k_batch, &k_static));
    println!("sweep  speedup, batched vs scalar/dyn    : {:>6.2}x  (target >= 3x)", speedup(&s_batch, &s_dyn));
    println!("sweep  speedup, batched vs scalar/static : {:>6.2}x", speedup(&s_batch, &s_static));

    let mut summary = Summary::new("batch_kernel");
    summary
        .metric("kernel_speedup_batched_vs_dyn", speedup(&k_batch, &k_dyn))
        .metric("kernel_speedup_batched_vs_static", speedup(&k_batch, &k_static))
        .metric("sweep_speedup_batched_vs_dyn", speedup(&s_batch, &s_dyn))
        .metric("sweep_speedup_batched_vs_static", speedup(&s_batch, &s_static))
        .metric("batched_sweep_melem_per_s", throughput(&s_batch).unwrap_or(0.0) / 1e6);
    summary.write().expect("write bench summary");

    // ---- per-design kernels: every registry family, scalar reference vs
    // batch kernel. The bit-level oracle's per-pair transcription is
    // orders of magnitude slower than the word-level models, so it runs
    // on a smaller operand set (the rates stay comparable: both sides
    // report pairs/s).
    section("per-design kernels: scalar adapter vs batch kernel (n=16)");
    let n16 = 16u32;
    let designs: [(&str, MultiplierSpec, usize); 6] = [
        ("segmented", MultiplierSpec::Segmented { n: n16, t: 8, fix: true }, 1 << 16),
        ("trunc", MultiplierSpec::Truncated { n: n16, k: 4 }, 1 << 16),
        ("bam", MultiplierSpec::BrokenArray { n: n16, hbl: 4, vbl: 8 }, 1 << 16),
        ("mitchell", MultiplierSpec::Mitchell { n: n16 }, 1 << 16),
        ("kulkarni", MultiplierSpec::Kulkarni { n: n16 }, 1 << 16),
        ("bitlevel", MultiplierSpec::BitLevel { n: n16, t: 8, fix: true }, 1 << 12),
    ];
    let mut kernels = Summary::new("kernels");
    let mut rows: Vec<(&str, f64, f64)> = Vec::new();
    for (key, spec, len) in &designs {
        let mut rng = Xoshiro256::seed_from_u64(0xD5 ^ *len as u64);
        let a: Vec<u64> = (0..*len).map(|_| rng.next_bits(n16)).collect();
        let b: Vec<u64> = (0..*len).map(|_| rng.next_bits(n16)).collect();
        let mut buf = vec![0u64; a.len()];
        let batch_m = spec.build_batch().expect("build batch kernel");
        let scalar_m = spec.build_scalar_reference().expect("build scalar reference");
        let pairs = *len as f64;
        let r_scalar = bench(&format!("{key:>9} scalar/per-pair reference"), Some(pairs), |iters| {
            let mut acc = 0u64;
            for _ in 0..iters {
                scalar_m.mul_batch(&a, &b, &mut buf);
                for &o in &buf {
                    acc ^= o;
                }
            }
            acc
        });
        let r_batch = bench(&format!("{key:>9} batched kernel"), Some(pairs), |iters| {
            let mut acc = 0u64;
            for _ in 0..iters {
                batch_m.mul_batch(&a, &b, &mut buf);
                for &o in &buf {
                    acc ^= o;
                }
            }
            acc
        });
        let sp = speedup(&r_batch, &r_scalar);
        let pps = throughput(&r_batch).unwrap_or(0.0);
        kernels
            .metric(&format!("{key}_pairs_per_s"), pps)
            .metric(&format!("{key}_speedup_vs_scalar"), sp);
        rows.push((*key, sp, pps));
    }
    // Baseline family = everything except the segmented design (which had
    // its kernel since PR 1).
    let family: Vec<&(&str, f64, f64)> =
        rows.iter().filter(|(k, _, _)| *k != "segmented").collect();
    let geomean =
        (family.iter().map(|(_, sp, _)| sp.ln()).sum::<f64>() / family.len() as f64).exp();

    println!();
    println!("per-design batched-over-scalar speedups (baseline-family target >= 5x):");
    for (key, sp, pps) in &rows {
        println!("  {key:>9}: {sp:>7.2}x   ({:>8.1} Mpairs/s batched)", pps / 1e6);
    }
    println!("  baseline-family geomean: {geomean:.2}x");
    kernels.metric("baseline_family_speedup_geomean", geomean);
    kernels.write().expect("write kernels summary");
}
