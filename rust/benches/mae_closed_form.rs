//! Bench E3 / Eq. 11: closed-form MAE vs the exhaustive measurement that
//! validates it (the cost of the E3 table).

use segmul::bench::{bench, section};
use segmul::error::closed_form::{mae_eq11, mae_measured_nofix};
use segmul::error::exhaustive::exhaustive_stats;

fn main() {
    section("Eq. 11 — closed form (O(1)) vs exhaustive validation");
    bench("closed-form sweep n<=12 all t", None, |iters| {
        let mut acc = 0u64;
        for _ in 0..iters {
            for n in 4..=12u32 {
                for t in 1..=n / 2 {
                    acc ^= mae_eq11(n, t) ^ mae_measured_nofix(n, t);
                }
            }
        }
        acc
    });
    for n in [8u32, 10, 12] {
        let pairs = (1u64 << (2 * n)) as f64 * (n / 2) as f64;
        bench(&format!("exhaustive MAE validation n={n} (all t)"), Some(pairs), |iters| {
            let mut acc = 0u64;
            for _ in 0..iters {
                for t in 1..=n / 2 {
                    acc ^= exhaustive_stats(n, t, false).max_abs_ed;
                }
            }
            acc
        });
    }
}
