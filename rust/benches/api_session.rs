//! Persistent-pool job-submission latency through the `api` facade.
//!
//! Small jobs make the fixed per-job cost visible: broadcast to the
//! pool, chunk steal, ordered merge, result plumbing. The session path
//! (long-lived workers, backend built once per worker) is compared
//! against the per-job scoped runner (`run_job_sharded`, which re-spawns
//! threads and re-builds backends for every job). Determinism is
//! asserted inline; the summary writes `BENCH_api_session.json` for the
//! CI bench-regression gate.

use segmul::api::{BackendChoice, EvalJob, Session};
use segmul::bench::{bench, section, speedup, throughput, Summary};
use segmul::coordinator::{run_job_sharded, CpuBackend, EvalBackend};
use segmul::util::threadpool::default_workers;

use anyhow::Result;

fn factory() -> Result<Box<dyn EvalBackend>> {
    Ok(Box::new(CpuBackend::new()))
}

fn main() {
    let workers = default_workers().expect("invalid SEGMUL_WORKERS").max(2);
    // One backend-batch worth of samples: the job body is cheap, so the
    // measurement is dominated by submission + merge overhead.
    let job = EvalJob::mc(8, 3, true, 1 << 12, 42);

    let mut session = Session::builder()
        .workers(workers)
        .backend(BackendChoice::Cpu)
        .cache(false) // measure evaluation, not cache lookups
        .build()
        .expect("session startup");

    // Bit-identical before timing anything.
    let via_session = session.run(&job).unwrap();
    let via_respawn = run_job_sharded(&factory, &job, workers).unwrap();
    assert_eq!(
        via_session.stats, via_respawn.stats,
        "session diverged from the scoped sharded runner"
    );

    section(&format!("api session job submission ({workers} workers)"));
    let s_pool = bench("session persistent pool", Some(1.0), |iters| {
        let mut acc = 0u64;
        for _ in 0..iters {
            acc ^= session.run(&job).unwrap().stats.err_count;
        }
        acc
    });
    let s_spawn = bench("per-job worker respawn", Some(1.0), |iters| {
        let mut acc = 0u64;
        for _ in 0..iters {
            acc ^= run_job_sharded(&factory, &job, workers).unwrap().stats.err_count;
        }
        acc
    });

    let jobs_per_s = throughput(&s_pool).unwrap_or(0.0);
    println!();
    println!("persistent-pool submission rate         : {jobs_per_s:>10.0} jobs/s");
    println!(
        "speedup vs per-job respawn              : {:>9.2}x",
        speedup(&s_pool, &s_spawn)
    );
    println!(
        "sanity: session built {} backends for {} workers across the whole run",
        session.backend_builds(),
        session.workers()
    );

    let mut summary = Summary::new("api_session");
    summary
        .metric("api_session_jobs_per_s", jobs_per_s)
        .metric("api_session_speedup_vs_respawn", speedup(&s_pool, &s_spawn))
        .metric("api_session_workers", workers as f64);
    summary.write().expect("write bench summary");
}
