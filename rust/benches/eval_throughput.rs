//! Bench the L3 hot path: single-multiply latency, batch evaluation on the
//! CPU backend, and (when artifacts exist) the PJRT stats module —
//! dispatch amortization included.

use std::path::PathBuf;

use segmul::bench::{bench, section};
use segmul::coordinator::{CpuBackend, EvalBackend, PjrtBackend};
use segmul::multiplier::wordlevel::approx_seq_mul;
use segmul::util::rng::Xoshiro256;

fn main() {
    section("word-level multiplier (the innermost loop)");
    for (n, t) in [(8u32, 4u32), (16, 8), (32, 16)] {
        bench(&format!("approx_seq_mul n={n} t={t}"), Some(1.0), |iters| {
            let mut acc = 0u64;
            let mut x = 0x12345u64;
            for _ in 0..iters {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
                let a = x >> (64 - n);
                let b = (x << 7) >> (64 - n);
                acc ^= approx_seq_mul(a, b, n, t, true);
            }
            acc
        });
    }

    section("CPU backend batches");
    let mut cpu = CpuBackend::new();
    let mut rng = Xoshiro256::seed_from_u64(5);
    for n in [8u32, 16, 32] {
        let len = 1usize << 16;
        let a: Vec<u64> = (0..len).map(|_| rng.next_bits(n)).collect();
        let b: Vec<u64> = (0..len).map(|_| rng.next_bits(n)).collect();
        bench(&format!("cpu stats batch n={n} (2^16 pairs)"), Some(len as f64), |iters| {
            let mut acc = 0u64;
            for _ in 0..iters {
                acc ^= cpu.eval_batch(n, n / 2, true, &a, &b).unwrap().err_count;
            }
            acc
        });
    }

    let dir = PathBuf::from("artifacts");
    if dir.join("manifest.json").exists() {
        section("PJRT backend batches (AOT-compiled stats module)");
        let mut pjrt = PjrtBackend::load(&dir).expect("artifacts");
        for n in [8u32, 16, 32] {
            let len = pjrt.max_batch();
            let a: Vec<u64> = (0..len).map(|_| rng.next_bits(n)).collect();
            let b: Vec<u64> = (0..len).map(|_| rng.next_bits(n)).collect();
            bench(&format!("pjrt stats batch n={n} (2^16 pairs)"), Some(len as f64), |iters| {
                let mut acc = 0u64;
                for _ in 0..iters {
                    acc ^= pjrt.eval_batch(n, n / 2, true, &a, &b).unwrap().err_count;
                }
                acc
            });
        }
    } else {
        eprintln!("(skipping PJRT benches — run `make artifacts`)");
    }
}
