//! Bench E5 / Fig. 3b: the ASIC-model evaluation pipeline per bit-width
//! (netlist + activity + CLA-substituted timing + area + power).

use segmul::bench::{bench, section};
use segmul::netlist::generators::seq_mult::seq_mult;
use segmul::tech::{measure_activity, AsicModel};

fn main() {
    section("Fig. 3b — ASIC evaluation pipeline (accurate + approx)");
    for n in [16u32, 64, 256] {
        let vectors = 256u64;
        bench(&format!("asic pair n={n} ({vectors} vectors)"), Some(2.0 * vectors as f64), |iters| {
            let mut acc = 0u64;
            for _ in 0..iters {
                let a = seq_mult(n, 0, false);
                let x = seq_mult(n, n / 2, true);
                let aa = measure_activity(&a, vectors, 1, false);
                let xa = measure_activity(&x, vectors, 1, true);
                let m = AsicModel::default();
                let ra = m.evaluate(&a.nl, &aa, n + 1, None);
                let rx = m.evaluate(&x.nl, &xa, n + 1, Some(ra.figures.period_ns));
                acc ^= (ra.figures.resource + rx.figures.resource) as u64;
            }
            acc
        });
    }
}
