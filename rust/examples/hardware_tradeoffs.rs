//! Hardware trade-off sweep (Fig. 3a / Fig. 3b / headline claims) on the
//! FPGA and ASIC technology models.
//!
//! Run: `cargo run --release --example hardware_tradeoffs`
//! (reduced vector count; `segmul figures fig3a --hw-vectors 65536` for
//! the paper-scale run)

use segmul::config::Config;
use segmul::report;

fn main() -> anyhow::Result<()> {
    let mut cfg = Config::default();
    cfg.hw_bitwidths = vec![4, 8, 16, 32, 64, 128];
    cfg.hw_vectors = 1 << 10;
    cfg.results_dir = "results".into();

    println!("== Fig. 3a: FPGA (LUT6 + carry-chain model) ==");
    let t = report::fig3a(&cfg)?;
    println!("{}", t.to_text());

    println!("== Fig. 3b: ASIC (45nm-class cell model) ==");
    let t = report::fig3b(&cfg)?;
    println!("{}", t.to_text());

    println!("== Sec. V-D headline claims vs paper ==");
    let t = report::headline(&cfg)?;
    println!("{}", t.to_text());

    println!("== Sec. III: sequential vs combinational crossover ==");
    let t = report::seqcomb(&cfg)?;
    println!("{}", t.to_text());

    println!("CSVs in ./results/");
    Ok(())
}
