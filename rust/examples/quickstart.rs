//! Quickstart: the segmented-carry sequential multiplier in five minutes.
//!
//! Run: `cargo run --release --example quickstart`

use segmul::error::closed_form;
use segmul::error::exhaustive::exhaustive_stats;
use segmul::error::probprop;
use segmul::multiplier::{Multiplier, SegmentedSeqMul};

fn main() {
    // --- 1. A single approximate multiply -------------------------------
    // The paper's Table IIb example: 11 x 6 at n = 4 with the carry chain
    // split at t = 2. The LSP carry of cycle 2 is deferred one cycle by
    // the D flip-flop and lands one position high: 82 instead of 66.
    let m = SegmentedSeqMul::new(4, 2, false);
    println!(
        "{}: 11 x 6 = {} (exact 66, ED = {})",
        m.name(),
        m.mul(11, 6),
        66i64 - m.mul(11, 6) as i64
    );

    // --- 2. Accuracy is configurable via t ------------------------------
    println!("\nexhaustive metrics at n = 8 (all 65 536 input pairs):");
    println!("{:>3} {:>10} {:>12} {:>8} {:>12}", "t", "ER", "MED|ED|", "MAE", "MRED");
    for t in 0..=4u32 {
        let s = exhaustive_stats(8, t, t >= 1).metrics().expect("nonempty");
        println!("{:>3} {:>10.6} {:>12.4} {:>8} {:>12.3e}", t, s.er, s.med_abs, s.mae, s.mred);
    }
    println!("(t = 0 is the fully accurate sequential multiplier)");

    // --- 3. Closed forms & estimates ------------------------------------
    let (n, t) = (8u32, 4u32);
    println!("\nclosed forms at n={n}, t={t}:");
    println!("  Eq. 11 MAE             = {}", closed_form::mae_eq11(n, t));
    println!(
        "  measured closed form   = {} (= 2^(n+t-1))",
        closed_form::mae_measured_nofix(n, t)
    );
    println!("  exhaustive MAE (nofix) = {}", exhaustive_stats(n, t, false).max_abs_ed);
    let lat = probprop::propagate(n, t);
    println!("  ER estimate (Sec V-B)  = {:.4}", lat.er_estimate());
    println!(
        "  ER exhaustive          = {:.4}",
        exhaustive_stats(n, t, false).metrics().expect("nonempty").er
    );

    // --- 4. Why bother: the hardware win --------------------------------
    println!("\ncarry-chain length (the critical path driver):");
    for n in [8u32, 16, 32, 64] {
        println!(
            "  n={n:>3}: accurate {} bits -> segmented (t=n/2) {} bits",
            closed_form::accurate_chain_bits(n),
            closed_form::segmented_chain_bits(n, n / 2)
        );
    }
    println!("\nsee `cargo run --release --example hardware_tradeoffs` for the full Fig. 3 sweep");
}
