//! Error analysis walkthrough (E2/E3/E6): exhaustive metrics, closed-form
//! MAE comparison, and the Sec. V-B probability-propagation estimator.
//!
//! Run: `cargo run --release --example error_analysis`

use segmul::error::closed_form;
use segmul::error::exhaustive::exhaustive_stats;
use segmul::error::montecarlo::{mc_stats, McConfig};
use segmul::error::probprop;

fn main() {
    // --- exhaustive sweep over t at n = 10 ------------------------------
    let n = 10u32;
    println!("exhaustive error metrics, n = {n} (2^20 input pairs per row):");
    println!(
        "{:>3} {:>5} {:>10} {:>12} {:>9} {:>11} {:>11}",
        "t", "fix", "ER", "MED|ED|", "MAE", "NMED", "MRED"
    );
    for t in 1..=n / 2 {
        for fix in [false, true] {
            let m = exhaustive_stats(n, t, fix).metrics().expect("nonempty");
            println!(
                "{:>3} {:>5} {:>10.6} {:>12.3} {:>9} {:>11.3e} {:>11.3e}",
                t, fix, m.er, m.med_abs, m.mae, m.nmed, m.mred
            );
        }
    }

    // --- Eq. 11 vs measurement (the E3 finding) -------------------------
    println!("\nEq. 11 closed-form MAE vs exhaustive measurement (fix off):");
    println!("{:>3} {:>3} {:>10} {:>12} {:>12}", "n", "t", "Eq.11", "measured", "2^(n+t-1)");
    for n in [6u32, 8, 10] {
        for t in [n / 4, n / 2] {
            let meas = exhaustive_stats(n, t, false).max_abs_ed;
            println!(
                "{:>3} {:>3} {:>10} {:>12} {:>12}",
                n,
                t,
                closed_form::mae_eq11(n, t),
                meas,
                closed_form::mae_measured_nofix(n, t)
            );
        }
    }
    println!("-> the dropped final LSP carry alone reaches 2^(n+t-1); Eq. 11's");
    println!("   -2^(t+1) rebate does not apply to that event (EXPERIMENTS.md E3).");

    // --- estimator vs ground truth (E6) ----------------------------------
    println!("\nSec. V-B probability propagation vs exhaustive ER:");
    println!("{:>3} {:>3} {:>12} {:>12} {:>9}", "n", "t", "ER exact", "ER est", "rel err");
    for n in [6u32, 8, 10] {
        for t in 1..=n / 2 {
            let exact = exhaustive_stats(n, t, false).metrics().expect("nonempty").er;
            let est = probprop::propagate(n, t).er_estimate();
            println!(
                "{:>3} {:>3} {:>12.6} {:>12.6} {:>8.1}%",
                n,
                t,
                exact,
                est,
                100.0 * (est - exact).abs() / exact
            );
        }
    }

    // --- MC vs exhaustive sanity -----------------------------------------
    let (n, t) = (12u32, 6u32);
    let exact = exhaustive_stats(n, t, true).metrics().expect("nonempty");
    let mc = mc_stats(n, t, true, &McConfig::uniform(1 << 20, 0xF00D)).metrics().expect("nonempty");
    println!("\nMC (2^20 samples) vs exhaustive at n={n}, t={t}, fix:");
    println!("  ER  : {:.6} vs {:.6}", mc.er, exact.er);
    println!("  MED : {:.2} vs {:.2}", mc.med_abs, exact.med_abs);
    println!("  MRED: {:.4e} vs {:.4e}", mc.mred, exact.mred);
}
