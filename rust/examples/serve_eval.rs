//! Serving demo: batched evaluation requests through the coordinator with
//! the PJRT backend (the request path never touches python), reporting
//! per-job latency percentiles and end-to-end throughput.
//!
//! Run: `cargo run --release --example serve_eval`

use std::path::PathBuf;
use std::time::Instant;

use segmul::coordinator::{CpuBackend, EvalBackend, EvalJob, EvalService, PjrtBackend};

fn main() -> anyhow::Result<()> {
    let artifacts = PathBuf::from("artifacts");
    let use_pjrt = artifacts.join("manifest.json").exists();
    let svc = EvalService::start(move || {
        if use_pjrt {
            Ok(Box::new(PjrtBackend::load(&artifacts)?) as Box<dyn EvalBackend>)
        } else {
            eprintln!("no artifacts/ — falling back to the CPU backend");
            Ok(Box::new(CpuBackend::new()) as Box<dyn EvalBackend>)
        }
    })?;

    let jobs = 24u64;
    let samples = 1u64 << 17;
    let n = 16u32;
    println!(
        "submitting {jobs} evaluation jobs (n={n}, {samples} samples each) to the {} backend",
        if use_pjrt { "pjrt" } else { "cpu" }
    );

    let t0 = Instant::now();
    let submitted: Vec<_> = (0..jobs)
        .map(|i| {
            let t = 1 + (i as u32 % (n / 2));
            (Instant::now(), svc.submit(EvalJob::mc(n, t, i % 2 == 0, samples, 1000 + i)))
        })
        .collect();

    let mut latencies_ms: Vec<f64> = Vec::new();
    for (i, (t_submit, ticket)) in submitted.into_iter().enumerate() {
        let r = ticket.wait()?;
        let lat = t_submit.elapsed().as_secs_f64() * 1e3;
        latencies_ms.push(lat);
        let m = r.metrics()?;
        if i < 4 || i as u64 == jobs - 1 {
            println!(
                "  job {i:>2}: {} ER={:.5} NMED={:.3e} [{:.0} ms]",
                r.job.design.name(),
                m.er,
                m.nmed,
                lat
            );
        } else if i == 4 {
            println!("  ...");
        }
    }
    let wall = t0.elapsed();
    latencies_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| latencies_ms[((latencies_ms.len() - 1) as f64 * p) as usize];
    let tele = svc.telemetry();
    println!("\nresults:");
    println!("  jobs      : {} completed, {} failed", tele.jobs_completed, tele.jobs_failed);
    println!("  pairs     : {} ({} batches)", tele.pairs_evaluated, tele.batches_executed);
    println!("  wall      : {:.2} s", wall.as_secs_f64());
    println!(
        "  throughput: {:.2} Mpairs/s end-to-end",
        tele.pairs_evaluated as f64 / wall.as_secs_f64() / 1e6
    );
    println!(
        "  latency   : p50 {:.0} ms / p90 {:.0} ms / p99 {:.0} ms (queue + execute)",
        pct(0.50),
        pct(0.90),
        pct(0.99)
    );
    svc.shutdown();
    Ok(())
}
