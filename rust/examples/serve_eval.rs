//! Serving demo: concurrent loopback clients against an in-process
//! `segmul serve` server — the same HTTP front end, coalescer, and
//! admission control the CLI runs, exercised end-to-end with latency
//! percentiles and a `/metrics` scrape.
//!
//! The backend identity is printed machine-readably (`backend: <name>`)
//! and checkable: set `SEGMUL_EXPECT_BACKEND=pjrt` (or `cpu`) to make
//! the demo exit non-zero when the server silently fell back to a
//! different backend — the old demo only mentioned the fallback on
//! stderr and still exited 0.
//!
//! Run: `cargo run --release --example serve_eval`

use std::path::PathBuf;
use std::time::Instant;

use segmul::api::BackendChoice;
use segmul::report::percentile;
use segmul::serve::{client, metrics::metric_value, ServeConfig, Server};
use segmul::util::json::Json;

fn main() -> anyhow::Result<()> {
    let artifacts = PathBuf::from("artifacts");
    let server = Server::start(ServeConfig {
        backend: BackendChoice::Auto(artifacts),
        ..ServeConfig::default()
    })
    .map_err(|e| anyhow::anyhow!("{e}"))?;
    let addr = server.addr();
    let backend = server.backend_name().to_string();
    println!("server on http://{addr}");
    println!("backend: {backend}");
    if let Ok(expected) = std::env::var("SEGMUL_EXPECT_BACKEND") {
        if backend != expected {
            eprintln!("error: expected the {expected} backend, got {backend}");
            std::process::exit(1);
        }
    }

    let jobs = 24u32;
    let samples = 1u64 << 17;
    let n = 16u32;
    println!("submitting {jobs} concurrent eval requests (n={n}, {samples} samples each)");

    let t0 = Instant::now();
    let handles: Vec<_> = (0..jobs)
        .map(|i| {
            std::thread::spawn(move || {
                let t = 1 + (i % (n / 2));
                // Three clients per (t, fix) point ask the exact same
                // question — the coalescer answers them with one pool
                // evaluation each.
                let body = format!(
                    r#"{{"design":{{"family":"segmented","n":{n},"t":{t},"fix":{}}},
                        "workload":{{"kind":"mc","samples":{samples},"seed":{}}}}}"#,
                    i % 2 == 0,
                    1000 + u64::from(i % 8),
                );
                let t_submit = Instant::now();
                let resp = client::post_json(addr, "/v1/eval", &Json::parse(&body).unwrap())?;
                Ok::<_, segmul::api::SegmulError>((
                    resp,
                    t_submit.elapsed().as_secs_f64() * 1e3,
                ))
            })
        })
        .collect();

    let mut latencies_ms: Vec<f64> = Vec::new();
    for (i, handle) in handles.into_iter().enumerate() {
        let (resp, lat) = handle.join().expect("client thread panicked")?;
        anyhow::ensure!(resp.status == 200, "request {i}: http {}: {}", resp.status, resp.text());
        let row = resp.json().map_err(|e| anyhow::anyhow!("{e}"))?;
        latencies_ms.push(lat);
        if i < 4 || i as u32 == jobs - 1 {
            let m = row.get("metrics").expect("metrics field");
            println!(
                "  req {i:>2}: {} ER={:.5} NMED={:.3e} {} [{:.0} ms]",
                row.get("name").and_then(Json::as_str).unwrap_or("?"),
                m.get("er").and_then(Json::as_f64).unwrap_or(f64::NAN),
                m.get("nmed").and_then(Json::as_f64).unwrap_or(f64::NAN),
                if row.get("cached").and_then(Json::as_bool) == Some(true) {
                    "(cached)"
                } else {
                    ""
                },
                lat
            );
        } else if i == 4 {
            println!("  ...");
        }
    }
    let wall = t0.elapsed();
    latencies_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());

    let scrape = client::get(addr, "/metrics").map_err(|e| anyhow::anyhow!("{e}"))?;
    let doc = scrape.text();
    let metric = |k: &str| metric_value(&doc, k).unwrap_or_else(|| "?".into());
    println!("\nresults:");
    println!("  requests  : {} ({} ok)", metric("serve_requests_total"), metric("serve_responses_2xx"));
    println!(
        "  coalescing: {} requests -> {} pool dispatches (ratio {})",
        metric("serve_coalesce_requests"),
        metric("serve_coalesce_dispatched"),
        metric("serve_coalesce_ratio")
    );
    println!("  pairs     : {}", metric("session_pairs_evaluated"));
    println!("  wall      : {:.2} s", wall.as_secs_f64());
    println!(
        "  latency   : p50 {:.0} ms / p90 {:.0} ms / p99 {:.0} ms (client-observed)",
        percentile(&latencies_ms, 0.50),
        percentile(&latencies_ms, 0.90),
        percentile(&latencies_ms, 0.99)
    );
    println!("  server p99: {} ms (from /metrics)", metric("serve_latency_p99_ms"));

    let down = client::post_json(addr, "/v1/shutdown", &Json::Obj(Default::default()))
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    anyhow::ensure!(down.status == 200, "shutdown failed: http {}", down.status);
    let summary = server.join();
    println!(
        "drained: {} jobs completed, {} evaluated on the {} backend",
        summary.telemetry.jobs_completed, summary.telemetry.jobs_evaluated, summary.backend
    );
    Ok(())
}
