//! End-to-end driver (E2e in DESIGN.md): approximate multiplication in a
//! real multimedia workload — the paper's motivating domain.
//!
//! A synthetic 256x256 8-bit image is smoothed with a 3x3 Gaussian kernel
//! whose pixel-x-weight products run through the approximate sequential
//! multiplier (n = 8), for every splitting point t and fix-to-1 setting.
//! Quality is reported as PSNR vs. the exact filter. When `make artifacts`
//! has been run, every multiply ALSO executes on the AOT-compiled PJRT
//! product module and the results are cross-checked bit-for-bit — proving
//! the three layers (Pallas kernel -> HLO -> rust PJRT hot path) compose.
//!
//! Run: `cargo run --release --example image_filter`

use std::path::PathBuf;
use std::time::Instant;

use segmul::multiplier::wordlevel::approx_seq_mul;
use segmul::runtime::Runtime;

const W: usize = 256;
const H: usize = 256;
// 5x5 binomial Gaussian ({1,4,6,4,1} outer product, /256). The multi-bit
// weights (6 = 110b) and the 8-bit pixel multiplicand generate real carry
// traffic across the splitting point — power-of-two weights would make
// the approximate multiplier exact (only one partial product).
const K1D: [u64; 5] = [1, 4, 6, 4, 1];

/// Synthetic test image: gradient + circles + checkerboard detail.
fn synthesize() -> Vec<u8> {
    let mut img = vec![0u8; W * H];
    for y in 0..H {
        for x in 0..W {
            let grad = (x + y) / 2;
            let dx = x as i64 - 96;
            let dy = y as i64 - 128;
            let circle = if dx * dx + dy * dy < 60 * 60 { 80 } else { 0 };
            let checker = if (x / 8 + y / 8) % 2 == 0 { 24 } else { 0 };
            img[y * W + x] = ((grad + circle + checker) % 256) as u8;
        }
    }
    img
}

/// Convolve with the multiplier `mul(pixel, weight)` (5x5 separable
/// weights applied as a full 2-D kernel; divide by 256 at the end).
fn convolve<F: FnMut(u64, u64) -> u64>(img: &[u8], mut mul: F) -> Vec<u8> {
    let mut out = vec![0u8; W * H];
    for y in 0..H {
        for x in 0..W {
            let mut acc = 0u64;
            for (ky, &wy) in K1D.iter().enumerate() {
                for (kx, &wx) in K1D.iter().enumerate() {
                    let sy = (y + ky).saturating_sub(2).min(H - 1);
                    let sx = (x + kx).saturating_sub(2).min(W - 1);
                    acc += mul(img[sy * W + sx] as u64, wy * wx);
                }
            }
            out[y * W + x] = (acc >> 8).min(255) as u8;
        }
    }
    out
}

fn psnr(a: &[u8], b: &[u8]) -> f64 {
    let mse: f64 = a
        .iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = x as f64 - y as f64;
            d * d
        })
        .sum::<f64>()
        / a.len() as f64;
    if mse == 0.0 {
        f64::INFINITY
    } else {
        10.0 * (255.0f64 * 255.0 / mse).log10()
    }
}

fn main() {
    let img = synthesize();
    let exact = convolve(&img, |p, w| p * w);
    let n = 8u32;
    let muls_per_image = (W * H * 25) as u64;

    // Optional PJRT cross-check path.
    let artifacts = PathBuf::from("artifacts");
    let mut runtime = if artifacts.join("manifest.json").exists() {
        match Runtime::load(&artifacts) {
            // This example drives the value-returning prod module; a
            // lowered-only manifest (`segmul lower`) has none.
            Ok(rt) if rt.has(n, segmul::runtime::ModuleKind::Prod) => {
                println!("PJRT runtime loaded — cross-checking every multiply on the compiled kernel");
                Some(rt)
            }
            Ok(_) => {
                println!("artifacts carry no prod module for n={n} — CPU word-level only");
                None
            }
            Err(e) => {
                println!("PJRT unavailable ({e}); CPU word-level only");
                None
            }
        }
    } else {
        println!("no artifacts/ — CPU word-level only (run `make artifacts` for the PJRT path)");
        None
    };

    println!("\n5x5 Gaussian blur, {W}x{H} image, {muls_per_image} multiplies per image");
    println!(
        "{:>3} {:>5} {:>10} {:>12} {:>14}",
        "t", "fix", "PSNR dB", "Mmul/s", "pjrt-checked"
    );
    for t in 0..=n / 2 {
        for fix in [false, true] {
            if t == 0 && fix {
                continue;
            }
            let started = Instant::now();
            let filtered = convolve(&img, |p, w| approx_seq_mul(w, p, n, t, fix));
            let dt = started.elapsed();
            // PJRT cross-check: run all pixel-weight products through the
            // compiled module in batches and compare.
            let checked = if let Some(rt) = runtime.as_mut() {
                let batch = rt.batch();
                let mut pairs: Vec<(u64, u64)> = Vec::with_capacity(batch);
                'outer: for y in (0..H).step_by(5) {
                    for x in 0..W {
                        for (ky, &wy) in K1D.iter().enumerate() {
                            for (kx, &wx) in K1D.iter().enumerate() {
                                let sy = (y + ky).saturating_sub(2).min(H - 1);
                                let sx = (x + kx).saturating_sub(2).min(W - 1);
                                pairs.push((wy * wx, img[sy * W + sx] as u64));
                                if pairs.len() == batch {
                                    break 'outer;
                                }
                            }
                        }
                    }
                }
                pairs.truncate(batch);
                while pairs.len() < batch {
                    pairs.push((0, 0));
                }
                let a: Vec<u64> = pairs.iter().map(|p| p.0).collect();
                let b: Vec<u64> = pairs.iter().map(|p| p.1).collect();
                let got = rt.exec_prod(n, &a, &b, t as u64, fix).expect("pjrt exec");
                for (i, ((&x, &w), &g)) in a.iter().zip(&b).zip(&got).enumerate() {
                    assert_eq!(g, approx_seq_mul(x, w, n, t, fix), "mismatch at {i}");
                }
                "yes"
            } else {
                "-"
            };
            println!(
                "{:>3} {:>5} {:>10.2} {:>12.2} {:>14}",
                t,
                fix,
                psnr(&exact, &filtered),
                muls_per_image as f64 / dt.as_secs_f64() / 1e6,
                checked
            );
        }
    }
    println!("\nPSNR degrades gracefully with t — the accuracy-configurability the paper claims.");
}
