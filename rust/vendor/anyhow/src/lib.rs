//! Minimal, API-compatible subset of the `anyhow` crate, vendored because
//! the build image has no crates.io access. Covers exactly the surface the
//! `segmul` crate uses:
//!
//! * [`Error`] / [`Result`] (with the `E = Error` default parameter),
//! * the [`anyhow!`], [`bail!`] and [`ensure!`] macros (bare-condition and
//!   formatted forms),
//! * the [`Context`] extension trait (`context` / `with_context`),
//! * `From<E>` for every `std::error::Error`, so `?` converts foreign
//!   errors.
//!
//! Differences from the real crate: the error keeps a flattened message
//! string instead of a source chain (context is prepended eagerly), and
//! backtraces are not captured. Swap back to crates.io `anyhow` by
//! replacing the `path` dependency — no call sites change.

use std::fmt;

/// A flattened error message. Like `anyhow::Error`, this deliberately does
/// NOT implement `std::error::Error`, which is what makes the blanket
/// `From<E: std::error::Error>` impl coherent.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from a printable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(msg: M) -> Self {
        Error { msg: msg.to_string() }
    }

    /// Prepend a context layer (most recent first, `{outer}: {inner}`).
    pub fn context<C: fmt::Display>(self, ctx: C) -> Self {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{:#}` on the real crate prints the full cause chain; our message
        // is already flattened, so both forms print the same string.
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Error { msg: e.to_string() }
    }
}

/// `anyhow::Result<T>` — plain `std::result::Result` with a defaulted
/// error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding context to fallible values.
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Wrap the error with a lazily-evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "nope")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(inner().unwrap_err().to_string(), "nope");
    }

    #[test]
    fn context_layers_prepend() {
        let e: Result<()> = Err(io_err());
        let e = e.with_context(|| format!("reading {}", "x")).unwrap_err();
        assert_eq!(e.to_string(), "reading x: nope");
        assert_eq!(format!("{e:#}"), "reading x: nope");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert!(v.context("missing").is_err());
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }

    #[test]
    fn macros_compile_in_all_forms() {
        fn f(flag: bool) -> Result<u32> {
            ensure!(1 + 1 == 2);
            ensure!(!flag, "flag was {flag}");
            if flag {
                bail!("unreachable");
            }
            Err(anyhow!("value {} bad", 7))
        }
        let e = f(false).unwrap_err();
        assert_eq!(e.to_string(), "value 7 bad");
        assert_eq!(f(true).unwrap_err().to_string(), "flag was true");
    }

    #[test]
    fn bare_ensure_names_the_condition() {
        fn f() -> Result<()> {
            ensure!(1 > 2);
            Ok(())
        }
        assert!(f().unwrap_err().to_string().contains("1 > 2"));
    }
}
