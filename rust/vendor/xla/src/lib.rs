//! Stub of the `xla` (PJRT) bindings used by `segmul::runtime`.
//!
//! The real bindings wrap `xla_extension`'s C++ PJRT client, which is not
//! present in this build image. This stub mirrors the small API surface
//! `runtime/client.rs` consumes so the crate always compiles; at runtime
//! [`PjRtClient::cpu`] returns an error, which the runtime surfaces as
//! "PJRT unavailable". Every caller (CLI backend selection, the
//! coordinator integration tests, the PJRT benches) already falls back to
//! the pure-Rust CPU backend when the AOT artifacts cannot be loaded, so
//! the stub degrades the system gracefully instead of breaking the build.
//!
//! To enable real PJRT execution, point the `xla` path dependency in
//! `rust/Cargo.toml` at the actual bindings; no call sites change.

use std::fmt;
use std::path::Path;

/// Error type for every stubbed operation.
pub struct XlaError(String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

fn unavailable() -> XlaError {
    XlaError("xla/PJRT bindings unavailable in this build (vendor/xla stub)".to_string())
}

/// Stubbed result alias.
pub type Result<T> = std::result::Result<T, XlaError>;

/// PJRT client handle. [`PjRtClient::cpu`] always fails in the stub, so no
/// instance (nor any downstream executable/buffer) can ever exist.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

/// Parsed HLO module (text interchange format).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Compiled executable (never constructible through the stub).
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// Device buffer handle.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Host-side literal value.
pub struct Literal(());

impl Literal {
    pub fn vec1<T>(_values: &[T]) -> Literal {
        Literal(())
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}

impl From<u64> for Literal {
    fn from(_v: u64) -> Literal {
        Literal(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().err().expect("stub must fail");
        assert!(e.to_string().contains("unavailable"));
    }

    #[test]
    fn literal_constructors_exist() {
        let _ = Literal::vec1(&[1u64, 2, 3]);
        let _ = Literal::from(7u64);
        let _ = XlaComputation::from_proto(&HloModuleProto(()));
        assert!(HloModuleProto::from_text_file("/nonexistent").is_err());
    }
}
