//! Artifact-manifest validation contract: every malformed, missing,
//! wrong-bit-width, or wrong-batch manifest produces a **typed**
//! [`SegmulError::Artifact`] (kind `"artifact"`) — never a panic and never
//! a stringly `anyhow` blob — and a `segmul lower` emission round-trips
//! through the validating loader for every registry [`MultiplierSpec`].

use std::path::{Path, PathBuf};

use segmul::api::{MultiplierSpec, SegmulError};
use segmul::runtime::{emit_artifacts, Manifest};

/// A fresh scratch dir per test (parallel test threads must not collide).
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("segmul_manifest_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn write_manifest(dir: &Path, text: &str) {
    std::fs::write(dir.join("manifest.json"), text).unwrap();
}

/// Load must fail with an `artifact`-class error whose message mentions
/// every given needle.
fn assert_artifact_error(dir: &Path, needles: &[&str]) {
    let e = Manifest::load(dir).unwrap_err();
    assert_eq!(e.kind(), "artifact", "{e}");
    assert!(matches!(e, SegmulError::Artifact { .. }));
    let msg = e.to_string();
    for needle in needles {
        assert!(msg.contains(needle), "missing {needle:?} in {msg:?}");
    }
}

#[test]
fn missing_manifest_is_typed_with_a_hint() {
    let dir = scratch("absent");
    assert_artifact_error(&dir, &["manifest.json", "segmul lower"]);
}

#[test]
fn malformed_json_is_typed() {
    let dir = scratch("malformed");
    write_manifest(&dir, "{not json");
    assert_artifact_error(&dir, &["malformed JSON"]);
}

#[test]
fn missing_batch_and_empty_manifests_are_typed() {
    let dir = scratch("nobatch");
    write_manifest(&dir, r#"{"schema_version": 2, "lowered": []}"#);
    assert_artifact_error(&dir, &["batch"]);
    write_manifest(&dir, r#"{"schema_version": 2, "batch": 16, "lowered": [], "modules": []}"#);
    assert_artifact_error(&dir, &["no modules"]);
    write_manifest(&dir, r#"{"schema_version": 2, "batch": 0, "lowered": []}"#);
    assert_artifact_error(&dir, &["batch must be positive"]);
}

#[test]
fn unsupported_schema_and_v1_lowered_are_typed() {
    let dir = scratch("schema");
    write_manifest(&dir, r#"{"schema_version": 3, "batch": 16, "lowered": []}"#);
    assert_artifact_error(&dir, &["schema_version 3"]);
    // `lowered` entries need schema >= 2.
    write_manifest(&dir, r#"{"batch": 16, "lowered": []}"#);
    assert_artifact_error(&dir, &["schema_version >= 2"]);
}

/// A valid single-entry v2 manifest body, with substitution points for
/// the tamper tests.
fn lowered_manifest(n: u32, module_batch: u32, design: &str) -> String {
    format!(
        r#"{{"schema_version": 2, "batch": 16, "lowered": [
            {{"name": "m", "design": {design}, "n": {n}, "batch": {module_batch},
              "file": "m.segir"}}
        ]}}"#
    )
}

const SEG_DESIGN: &str = r#"{"family": "segmented", "n": 8, "t": 3, "fix": true}"#;

fn write_module(dir: &Path) {
    // Content is only probed for existence by the manifest loader.
    std::fs::write(dir.join("m.segir"), "segir 1\nn 8\ninput %0 a\ninput %1 b\nret %0\n").unwrap();
}

#[test]
fn wrong_bit_width_is_typed() {
    let dir = scratch("wrongn");
    write_module(&dir);
    // Entry n=16 contradicts the design tag's n=8.
    write_manifest(&dir, &lowered_manifest(16, 16, SEG_DESIGN));
    assert_artifact_error(&dir, &["n=16", "segmul(n=8,t=3,fix)"]);
}

#[test]
fn wrong_batch_is_typed() {
    let dir = scratch("wrongbatch");
    write_module(&dir);
    // Module batch 4 contradicts the manifest batch 16.
    write_manifest(&dir, &lowered_manifest(8, 4, SEG_DESIGN));
    assert_artifact_error(&dir, &["batch 4", "manifest batch 16"]);
}

#[test]
fn bad_design_tags_are_typed() {
    let dir = scratch("badtag");
    write_module(&dir);
    write_manifest(&dir, &lowered_manifest(8, 16, r#"{"family": "warp", "n": 8}"#));
    assert_artifact_error(&dir, &["warp"]);
    // Structurally valid but semantically invalid design parameters.
    write_manifest(&dir, &lowered_manifest(12, 16, r#"{"family": "kulkarni", "n": 12}"#));
    assert_artifact_error(&dir, &["invalid design"]);
    // Missing the design tag entirely.
    write_manifest(
        &dir,
        r#"{"schema_version": 2, "batch": 16, "lowered": [
            {"name": "m", "n": 8, "batch": 16, "file": "m.segir"}
        ]}"#,
    );
    assert_artifact_error(&dir, &["design tag"]);
}

#[test]
fn missing_module_file_and_duplicates_are_typed() {
    let dir = scratch("misc");
    // File referenced but absent.
    write_manifest(&dir, &lowered_manifest(8, 16, SEG_DESIGN));
    assert_artifact_error(&dir, &["m.segir", "not found"]);
    // Duplicate design entries.
    write_module(&dir);
    write_manifest(
        &dir,
        &format!(
            r#"{{"schema_version": 2, "batch": 16, "lowered": [
                {{"name": "m", "design": {SEG_DESIGN}, "n": 8, "batch": 16, "file": "m.segir"}},
                {{"name": "m2", "design": {SEG_DESIGN}, "n": 8, "batch": 16, "file": "m.segir"}}
            ]}}"#
        ),
    );
    assert_artifact_error(&dir, &["duplicate", "segmul(n=8,t=3,fix)"]);
}

#[test]
fn valid_lowered_manifest_loads_and_covers() {
    let dir = scratch("valid");
    write_module(&dir);
    write_manifest(&dir, &lowered_manifest(8, 16, SEG_DESIGN));
    let m = Manifest::load(&dir).unwrap();
    assert_eq!(m.schema, 2);
    assert_eq!(m.batch, 16);
    assert_eq!(m.lowered.len(), 1);
    let spec = MultiplierSpec::Segmented { n: 8, t: 3, fix: true };
    assert_eq!(m.find_lowered(&spec).unwrap().design, spec);
    assert!(m.covers_design(&spec));
    assert!(!m.covers_design(&MultiplierSpec::Segmented { n: 8, t: 3, fix: false }));
}

/// The emitter round-trip over **every** registry `MultiplierSpec`: emit →
/// validating load → per-entry design/bit-width/batch/file agreement.
#[test]
fn emitted_manifest_round_trips_every_registry_spec() {
    let dir = scratch("roundtrip");
    let mut specs = Vec::new();
    for n in [4u32, 8, 16] {
        specs.extend(MultiplierSpec::registry_examples(n));
    }
    let emitted = emit_artifacts(&dir, &specs, 64).unwrap();
    let reloaded = Manifest::load(&dir).unwrap();
    assert_eq!(reloaded.schema, 2);
    assert_eq!(reloaded.batch, 64);
    assert_eq!(reloaded.lowered.len(), specs.len());
    assert_eq!(emitted.lowered.len(), reloaded.lowered.len());
    for spec in &specs {
        let entry = reloaded.find_lowered(spec).unwrap();
        assert_eq!(entry.design, *spec, "{}", spec.name());
        assert_eq!(entry.n, spec.n());
        assert_eq!(entry.batch, 64);
        assert!(reloaded.dir.join(&entry.file).exists(), "{}", spec.name());
        assert!(reloaded.covers_design(spec), "{}", spec.name());
    }
    let _ = std::fs::remove_dir_all(&dir);
}
