//! PJRT lowered-module differential contract: for **every** registry
//! design, the PJRT backend's lowered-module path, the CPU batched
//! backend, and the per-pair scalar reference produce **bit-identical**
//! [`ErrorStats`] (f64 fields and flags included) over identical operand
//! slices — exhaustively at n ∈ {4, 8} and Monte-Carlo at n = 16 — and a
//! cross-design sweep through the `api::Session` dispatches every design
//! via a lowered module with zero scalar/CPU fallbacks (the
//! `--require-pjrt` CI contract).

use std::path::PathBuf;

use segmul::api::{BackendChoice, DesignSet, DispatchClass, MultiplierSpec, Session, SweepGrid};
use segmul::coordinator::{CpuBackend, EvalBackend, PjrtBackend};
use segmul::error::metrics::ErrorStats;
use segmul::multiplier::{exact_mul_batch, BatchMultiplier};
use segmul::runtime::emit_artifacts;
use segmul::util::rng::Xoshiro256;

const BATCH: usize = 4096;

/// Emit lowered artifacts for every design the tests touch, once per
/// scratch dir.
fn emit(tag: &str, bitwidths: &[u32]) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("segmul_pjrt_diff_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut specs = Vec::new();
    for &n in bitwidths {
        specs.extend(DesignSet::All.specs(n));
        specs.extend(MultiplierSpec::registry_examples(n));
    }
    emit_artifacts(&dir, &specs, BATCH).unwrap();
    dir
}

/// Per-chunk scalar reference: the per-pair adapter's products folded in
/// the exact accumulation order the backends use.
fn scalar_chunk(spec: &MultiplierSpec, a: &[u64], b: &[u64]) -> ErrorStats {
    let reference = spec.build_scalar_reference().unwrap();
    let mut phat = vec![0u64; a.len()];
    reference.mul_batch(a, b, &mut phat);
    let mut prod = vec![0u64; a.len()];
    exact_mul_batch(a, b, &mut prod);
    let mut stats = ErrorStats::new(spec.n());
    stats.record_batch(&prod, &phat);
    stats
}

/// Drive `spec` over the operand stream in BATCH-sized chunks through all
/// three evaluators, asserting bit-exact equality chunk-by-chunk and on
/// the in-order merged totals.
fn assert_three_way(
    pjrt: &mut PjrtBackend,
    cpu: &mut CpuBackend,
    spec: &MultiplierSpec,
    a: &[u64],
    b: &[u64],
) {
    let mut pjrt_total = ErrorStats::new(spec.n());
    let mut cpu_total = ErrorStats::new(spec.n());
    for (ca, cb) in a.chunks(BATCH).zip(b.chunks(BATCH)) {
        let sp = pjrt.eval_design(spec, ca, cb).unwrap();
        let sc = cpu.eval_design(spec, ca, cb).unwrap();
        let sr = scalar_chunk(spec, ca, cb);
        assert_eq!(sp, sc, "pjrt != cpu for {}", spec.name());
        assert_eq!(sc, sr, "cpu != scalar reference for {}", spec.name());
        pjrt_total.merge(&sp);
        cpu_total.merge(&sc);
    }
    assert_eq!(pjrt_total, cpu_total, "{}", spec.name());
    assert_eq!(pjrt_total.count, a.len() as u64, "{}", spec.name());
}

#[test]
fn exhaustive_bit_exactness_n4_n8_every_registry_design() {
    let dir = emit("exh", &[4, 8]);
    let mut pjrt = PjrtBackend::load(&dir).unwrap();
    let mut cpu = CpuBackend::new();
    for n in [4u32, 8] {
        // The full 2^(2n) input space, in the canonical index order.
        let mask = (1u64 << n) - 1;
        let space = 1u64 << (2 * n);
        let a: Vec<u64> = (0..space).map(|i| i & mask).collect();
        let b: Vec<u64> = (0..space).map(|i| i >> n).collect();
        for spec in MultiplierSpec::registry_examples(n) {
            assert!(pjrt.supports_design(&spec), "{}", spec.name());
            assert_three_way(&mut pjrt, &mut cpu, &spec, &a, &b);
        }
        // The paper grid's own axes, beyond the registry examples.
        for t in 0..n {
            for fix in [false, true] {
                let spec = MultiplierSpec::Segmented { n, t, fix };
                assert_three_way(&mut pjrt, &mut cpu, &spec, &a, &b);
            }
        }
    }
    // Every design dispatched through the lowered pjrt path.
    for (name, class) in pjrt.kernel_dispatch() {
        assert_eq!(class, DispatchClass::Pjrt, "{name}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn monte_carlo_bit_exactness_n16_every_registry_design() {
    let dir = emit("mc", &[16]);
    let mut pjrt = PjrtBackend::load(&dir).unwrap();
    let mut cpu = CpuBackend::new();
    let mut rng = Xoshiro256::seed_from_u64(0x9_16_16);
    let len = 3 * BATCH + 517; // ragged tail exercises the padded path
    let a: Vec<u64> = (0..len).map(|_| rng.next_bits(16)).collect();
    let b: Vec<u64> = (0..len).map(|_| rng.next_bits(16)).collect();
    for spec in MultiplierSpec::registry_examples(16) {
        assert!(pjrt.supports_design(&spec), "{}", spec.name());
        assert_three_way(&mut pjrt, &mut cpu, &spec, &a, &b);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// The `--require-pjrt` sweep contract, end-to-end through the facade: a
/// cross-design `--designs all` grid on the PJRT backend evaluates every
/// design via a lowered module (zero scalar/CPU fallbacks) and matches
/// the CPU sweep bit-for-bit.
#[test]
fn cross_design_sweep_runs_fully_lowered_and_matches_cpu() {
    let dir = emit("sweep", &[4]);
    let grid = SweepGrid {
        bitwidths: vec![4],
        designs: DesignSet::All,
        exhaustive_max_n: 8,
        force_mc: false,
        mc_samples: 10_000,
        seed: 7,
    };
    let mut pjrt_session = Session::builder()
        .workers(2)
        .backend(BackendChoice::Pjrt(dir.clone()))
        .seed(7)
        .build()
        .unwrap();
    let mut cpu_session = Session::builder()
        .workers(2)
        .backend(BackendChoice::Cpu)
        .seed(7)
        .build()
        .unwrap();
    let pjrt_out = pjrt_session.run_grid(&grid, |_, _, _| {}).unwrap();
    let cpu_out = cpu_session.run_grid(&grid, |_, _, _| {}).unwrap();
    assert_eq!(pjrt_out.len(), cpu_out.len());
    for (p, c) in pjrt_out.iter().zip(&cpu_out) {
        assert_eq!(p.job.design, c.job.design);
        // n=4 exhaustive fits one backend chunk on both backends, so the
        // accumulation order is identical: full bitwise equality.
        assert_eq!(
            p.result().unwrap().stats,
            c.result().unwrap().stats,
            "{}",
            p.job.design.name()
        );
        if !p.cached {
            assert_eq!(p.result().unwrap().backend, "pjrt", "{}", p.job.design.name());
        }
    }
    let telemetry = pjrt_session.telemetry();
    assert_eq!(pjrt_session.backend_name(), "pjrt");
    assert!(telemetry.scalar_fallbacks().is_empty(), "{:?}", telemetry.kernel_dispatch);
    assert!(
        telemetry.non_pjrt_dispatches().is_empty(),
        "designs fell back from the lowered path: {:?}",
        telemetry.kernel_dispatch
    );
    assert!(!telemetry.pjrt_dispatches().is_empty());
    // The t=0 ≡ accurate dedup still collapses across designs on PJRT.
    assert!(telemetry.cache_hits > 0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Capability preflight: a design without a lowered module is rejected by
/// the pool with a typed backend error, before any chunk runs.
#[test]
fn uncovered_designs_fail_preflight_with_typed_error() {
    let dir = emit("uncov", &[4]);
    let mut session = Session::builder()
        .workers(1)
        .backend(BackendChoice::Pjrt(dir.clone()))
        .build()
        .unwrap();
    // n=8 was never lowered into this artifact set.
    let job = session
        .job(MultiplierSpec::Mitchell { n: 8 })
        .monte_carlo(1000)
        .build()
        .unwrap();
    let e = session.run(&job).unwrap_err();
    assert_eq!(e.kind(), "backend");
    assert!(e.to_string().contains("n=8"), "{e}");
    // A covered bit-width but an unlowered design point.
    let job = session
        .job(MultiplierSpec::Truncated { n: 4, k: 3 })
        .monte_carlo(1000)
        .build()
        .unwrap();
    let e = session.run(&job).unwrap_err();
    assert_eq!(e.kind(), "backend");
    assert!(e.to_string().contains("trunc(n=4,k=3)"), "{e}");
    // The session stays usable for covered designs.
    let ok = session
        .job(MultiplierSpec::Mitchell { n: 4 })
        .monte_carlo(1000)
        .build()
        .unwrap();
    assert_eq!(session.run(&ok).unwrap().stats.count, 1000);
    let _ = std::fs::remove_dir_all(&dir);
}
