//! Supervision and graceful degradation of `segmul serve` under
//! deterministic fault injection.
//!
//! Two contracts:
//! * an engine panic strands its in-flight requests with **typed 500s**
//!   (never a hang, never a dead server) and the supervisor restarts the
//!   session, after which the server answers normally;
//! * a worker-panic storm flips the server into degraded mode, where
//!   analytic-eligible requests keep answering in closed form with the
//!   `degraded: true` wire flag while non-eligible work gets typed 503s,
//!   and a successful pool probe returns the server to healthy — all of
//!   it proven end-to-end through a clean drain.

use std::sync::Arc;
use std::time::Duration;

use segmul::api::BackendChoice;
use segmul::fault::FaultInjector;
use segmul::serve::metrics::metric_value;
use segmul::serve::{client, ServeConfig, Server};
use segmul::util::json::Json;

fn boot_with(faults: &str) -> Server {
    Server::start(ServeConfig {
        workers: Some(2),
        backend: BackendChoice::Cpu,
        default_deadline: Duration::from_secs(120),
        faults: Some(Arc::new(FaultInjector::parse(faults, 0xFA11).expect("valid fault plan"))),
        ..ServeConfig::default()
    })
    .expect("server startup")
}

fn segmented_eval() -> Json {
    Json::parse(
        r#"{"design":{"family":"segmented","n":8,"t":3,"fix":true},
            "workload":{"kind":"mc","samples":20000,"seed":1}}"#,
    )
    .expect("valid request")
}

fn accurate_eval() -> Json {
    Json::parse(
        r#"{"design":{"family":"accurate","n":8},
            "workload":{"kind":"mc","samples":20000,"seed":1}}"#,
    )
    .expect("valid request")
}

/// An injected engine panic strands the first request with a typed 500;
/// the supervisor restarts the engine (counted in `/metrics`) and the
/// very next request is answered by the rebuilt session.
#[test]
fn engine_panic_is_a_typed_500_and_the_supervisor_restarts() {
    let server = boot_with("engine.panic:after=1");
    let addr = server.addr();

    let first = client::post_json(addr, "/v1/eval", &segmented_eval()).unwrap();
    assert_eq!(first.status, 500, "{}", first.text());
    let err = first.json().unwrap();
    let err = err.get("error").expect("typed error body");
    assert_eq!(err.get("kind").and_then(Json::as_str), Some("serve"));
    assert!(
        err.get("detail").and_then(Json::as_str).unwrap().contains("engine exited"),
        "unhelpful detail: {err:?}"
    );

    // The one-shot trigger is spent; the rebuilt engine answers (the
    // post-panic degraded flag clears on this first successful probe).
    let second = client::post_json(addr, "/v1/eval", &segmented_eval()).unwrap();
    assert_eq!(second.status, 200, "{}", second.text());
    let body = second.json().unwrap();
    assert_eq!(body.get("degraded").and_then(Json::as_bool), Some(false));

    let doc = client::get(addr, "/metrics").unwrap().text();
    let restarts: u64 = metric_value(&doc, "serve_engine_restarts").unwrap().parse().unwrap();
    assert!(restarts >= 1, "the supervisor restart must be counted:\n{doc}");

    let _ = client::post_json(addr, "/v1/shutdown", &Json::Obj(Default::default())).unwrap();
    let summary = server.join();
    assert!(summary.requests_total >= 2);
}

/// The acceptance storm: with every pool evaluation panicking, the
/// server degrades after two consecutive pool failures, keeps answering
/// analytic-eligible evals and sweeps in closed form (flagged
/// `degraded: true`), 503s non-eligible work, recovers through a pool
/// probe once the storm passes, and drains cleanly.
#[test]
fn worker_panic_storm_degrades_to_closed_form_and_recovers() {
    // `first=8` arms exactly two full retry budgets (4 attempts each):
    // evals 1 and 2 exhaust theirs and fail; the recovery probe (attempt
    // 9) succeeds.
    let server = boot_with("worker.panic:first=8");
    let addr = server.addr();

    // Two consecutive pool failures: typed eval errors, and the second
    // one flips the server into degraded mode.
    for i in 0..2 {
        let resp = client::post_json(addr, "/v1/eval", &segmented_eval()).unwrap();
        assert_eq!(resp.status, 500, "storm eval {i}: {}", resp.text());
        let err = resp.json().unwrap();
        assert_eq!(
            err.get("error").unwrap().get("kind").and_then(Json::as_str),
            Some("eval"),
            "storm failures are typed pool errors"
        );
    }
    let health = client::get(addr, "/healthz").unwrap();
    assert_eq!(health.status, 200, "degraded is not draining: the server still serves");
    assert_eq!(health.json().unwrap().get("status").and_then(Json::as_str), Some("degraded"));
    assert_eq!(health.json().unwrap().get("degraded").and_then(Json::as_bool), Some(true));

    // Analytic-eligible evals keep answering, in closed form, flagged.
    let resp = client::post_json(addr, "/v1/eval", &accurate_eval()).unwrap();
    assert_eq!(resp.status, 200, "{}", resp.text());
    let body = resp.json().unwrap();
    assert_eq!(body.get("degraded").and_then(Json::as_bool), Some(true));
    assert_eq!(body.get("source").and_then(Json::as_str), Some("analytic"));

    // A whole analytic-eligible sweep streams to completion, every row
    // flagged, without ever touching the dead pool.
    let sweep = client::post_json(
        addr,
        "/v1/sweep",
        &Json::parse(r#"{"designs":"accurate","bitwidths":[8],"mc":true,"samples":20000}"#).unwrap(),
    )
    .unwrap();
    assert_eq!(sweep.status, 200);
    let lines = sweep.json_lines().unwrap();
    let trailer = lines.last().expect("stream trailer");
    assert_eq!(trailer.get("status").and_then(Json::as_str), Some("complete"), "{trailer:?}");
    let rows: Vec<&Json> = lines.iter().filter_map(|l| l.get("row")).collect();
    assert!(!rows.is_empty(), "the degraded sweep must still produce rows");
    for row in rows {
        assert_eq!(row.get("degraded").and_then(Json::as_bool), Some(true), "{row:?}");
    }

    // The storm has passed (the `first=8` budget is spent): the next
    // non-analytic eval doubles as the recovery probe and succeeds.
    let probe = client::post_json(addr, "/v1/eval", &segmented_eval()).unwrap();
    assert_eq!(probe.status, 200, "{}", probe.text());
    assert_eq!(probe.json().unwrap().get("degraded").and_then(Json::as_bool), Some(false));
    let health = client::get(addr, "/healthz").unwrap();
    assert_eq!(health.json().unwrap().get("status").and_then(Json::as_str), Some("ok"));

    let doc = client::get(addr, "/metrics").unwrap().text();
    let degraded_answers: u64 = metric_value(&doc, "serve_degraded_answers").unwrap().parse().unwrap();
    assert!(degraded_answers >= 2, "closed-form answers must be counted:\n{doc}");
    assert_eq!(metric_value(&doc, "serve_degraded").as_deref(), Some("0"), "recovered");

    // The acceptance drain: the server never hung and never died.
    let _ = client::post_json(addr, "/v1/shutdown", &Json::Obj(Default::default())).unwrap();
    let summary = server.join();
    assert!(summary.requests_total >= 6);
    assert!(summary.metrics_doc.contains("serve_draining 1"));
}
