//! Netlist ↔ tech-model integration: the generated circuits drive the
//! FPGA/ASIC models and reproduce the paper's structural claims.

use segmul::netlist::generators::array_mult::array_mult;
use segmul::netlist::generators::seq_mult::seq_mult;
use segmul::netlist::timing::{analyze, logic_depth, UnitDelay};
use segmul::tech::{measure_activity, AsicModel, FpgaModel};

#[test]
fn asic_latency_gap_peaks_at_small_n() {
    // Paper (Fig. 3b): the ASIC latency reduction is LARGEST at n = 8
    // (34.14%) and shrinks for wider designs — the synthesizer replaces
    // long ripple chains with log-depth prefix adders, so halving the
    // chain helps less once CLA substitution kicks in. Our ASIC model
    // reproduces that trend via its min(ripple, CLA) timing pass.
    let m = AsicModel::default();
    let mut reductions = Vec::new();
    for n in [8u32, 16, 32, 64, 128] {
        let acc = seq_mult(n, 0, false);
        let apx = seq_mult(n, n / 2, true);
        let a_act = measure_activity(&acc, 64, 1, false);
        let x_act = measure_activity(&apx, 64, 1, true);
        let ar = m.evaluate(&acc.nl, &a_act, n + 1, None);
        let xr = m.evaluate(&apx.nl, &x_act, n + 1, None);
        let red = 1.0 - xr.figures.period_ns / ar.figures.period_ns;
        assert!(red > 0.0, "latency must always reduce (n={n}), got {red}");
        reductions.push((n, red));
    }
    let max = reductions.iter().cloned().fold((0, 0.0f64), |a, b| if b.1 > a.1 { b } else { a });
    assert!(max.0 <= 16, "max reduction should occur at small n, got n={}", max.0);
    // and the reduction at n=128 must be below the n=8 peak
    assert!(reductions.last().unwrap().1 < reductions[0].1);
}

#[test]
fn fpga_lut_overhead_small_and_power_overhead_small() {
    let m = FpgaModel::default();
    for n in [16u32, 32] {
        let acc = seq_mult(n, 0, false);
        let apx = seq_mult(n, n / 2, true);
        let a_act = measure_activity(&acc, 256, 2, false);
        let x_act = measure_activity(&apx, 256, 2, true);
        let ar = m.evaluate(&acc.nl, &a_act, n + 1, None);
        let xr = m.evaluate(&apx.nl, &x_act, n + 1, Some(ar.figures.period_ns));
        let lut_ovh = xr.luts as f64 / ar.luts as f64 - 1.0;
        let pow_ovh = xr.figures.dyn_power_mw / ar.figures.dyn_power_mw - 1.0;
        assert!(lut_ovh > 0.0 && lut_ovh < 0.5, "n={n} lut overhead {lut_ovh}");
        assert!(pow_ovh > -0.2 && pow_ovh < 0.5, "n={n} power overhead {pow_ovh}");
    }
}

#[test]
fn array_multiplier_depth_exceeds_sequential_adder_depth() {
    // The combinational multiplier's depth grows ~2n; the sequential
    // design's per-cycle depth grows ~n. (Total sequential latency is n
    // cycles, which the latency figures account for.)
    let arr = array_mult(16);
    let seqm = seq_mult(16, 0, false);
    let arr_depth = *logic_depth(&arr).iter().max().unwrap();
    let seq_depth = *logic_depth(&seqm.nl).iter().max().unwrap();
    assert!(arr_depth > seq_depth);
}

#[test]
fn unit_delay_critical_paths_ordered() {
    // accurate n-bit chain > segmented max(t, n-t) chain at every n.
    for n in [8u32, 12, 16, 24] {
        let acc = analyze(&seq_mult(n, 0, false).nl, &UnitDelay).critical_path_ps;
        let seg = analyze(&seq_mult(n, n / 2, true).nl, &UnitDelay).critical_path_ps;
        assert!(seg < acc, "n={n}: {seg} !< {acc}");
    }
}

#[test]
fn decrement_controller_cost_is_logarithmic() {
    // Controller gates grow ~log n; datapath grows ~n — the counter must
    // not dominate.
    let g16 = seq_mult(16, 0, false).nl.gate_count() as f64;
    let g64 = seq_mult(64, 0, false).nl.gate_count() as f64;
    let ratio = g64 / g16;
    assert!(ratio > 3.0 && ratio < 4.6, "gate growth should be ~linear, got {ratio}");
}
