//! Corruption properties of the persistent result store.
//!
//! The invariant under test: **no corrupted store entry is ever served**.
//! Truncations, bit flips, and schema mismatches surface as the typed
//! `SegmulError::Store` (kind `"store"`) at the store layer, and the
//! sweep runner degrades every such error to a counted miss — the job
//! re-evaluates and the answer is bit-identical to a fresh-store run.
//! Never a panic, never a silently wrong answer.

use std::path::PathBuf;

use anyhow::Result;

use segmul::coordinator::{CpuBackend, EvalBackend, EvalJob, SweepRunner};
use segmul::store::{ResultStore, StoreKey};

fn cpu_factory() -> impl Fn() -> Result<Box<dyn EvalBackend>> + Send + Sync + 'static {
    || Ok(Box::new(CpuBackend::new()) as Box<dyn EvalBackend>)
}

fn tmp_store(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("segmul-store-corrupt-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn job() -> EvalJob {
    EvalJob::mc(8, 3, true, 200_000, 42)
}

/// Evaluate `job()` through a store-backed runner, committing its blob,
/// and return the store dir, the key, and the runner's result stats.
fn committed_store(tag: &str) -> (PathBuf, StoreKey, segmul::error::metrics::ErrorStats) {
    let dir = tmp_store(tag);
    let mut runner = SweepRunner::new(cpu_factory(), 2).unwrap();
    runner.set_store(ResultStore::open(&dir).unwrap());
    let out = runner.run_jobs(&[job()], |_, _, _| {}).unwrap();
    let stats = out[0].result().unwrap().stats.clone();
    let skey = StoreKey::new(&job(), "cpu", runner.pool().batch());
    assert!(runner.store().unwrap().load(&skey).unwrap().is_some(), "blob must be committed");
    (dir, skey, stats)
}

/// After corrupting the blob with `mutate`, the store must report a
/// typed `"store"` error (or a clean miss), and a fresh runner must
/// re-evaluate to the bit-identical answer and heal the entry.
fn assert_recovers(tag: &str, mutate: impl FnOnce(&[u8]) -> Vec<u8>) {
    let (dir, skey, want) = committed_store(tag);
    let store = ResultStore::open(&dir).unwrap();
    let blob_path = store.blob_path(&skey);
    let original = std::fs::read(&blob_path).unwrap();
    std::fs::write(&blob_path, mutate(&original)).unwrap();

    // Layer 1: the raw load is a typed error, never a panic and never a
    // decoded result.
    match store.load(&skey) {
        Err(e) => assert_eq!(e.kind(), "store", "{tag}: {e}"),
        Ok(None) => {} // an empty/removed file degrades to a plain miss
        Ok(Some(_)) => panic!("{tag}: corrupted blob was served"),
    }

    // Layer 2: the runner degrades the error to a re-evaluation that is
    // bit-identical to the fresh-store run.
    let mut runner = SweepRunner::new(cpu_factory(), 2).unwrap();
    runner.set_store(store);
    let out = runner.run_jobs(&[job()], |_, _, _| {}).unwrap();
    let got = &out[0].result().unwrap().stats;
    assert_eq!(got, &want, "{tag}: re-evaluation diverged");
    assert_eq!(got.sum_red.to_bits(), want.sum_red.to_bits(), "{tag}: sum_red bits");
    assert_eq!(runner.store_hits, 0, "{tag}: a corrupt entry must not count as a hit");
    assert_eq!(runner.jobs_evaluated, 1, "{tag}");

    // Layer 3: the re-evaluation healed the entry — the blob now loads.
    let healed = runner.store().unwrap().load(&skey).unwrap().expect("healed blob");
    assert_eq!(healed.stats, want, "{tag}: healed blob diverged");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn truncated_blob_recovers_at_every_cut_depth() {
    // Property: for a spread of truncation lengths (including zero, one
    // byte, and one-byte-short-of-valid), the entry is never served.
    for frac in [0usize, 1, 4] {
        assert_recovers(&format!("trunc-num-{frac}"), move |orig| {
            orig[..orig.len() * frac / 8].to_vec()
        });
    }
    assert_recovers("trunc-tail", |orig| orig[..orig.len() - 1].to_vec());
    assert_recovers("trunc-one", |orig| orig[..1.min(orig.len())].to_vec());
}

#[test]
fn bit_flipped_blob_recovers_at_every_probed_position() {
    // Property: flip one bit at a spread of positions across the blob —
    // the seal (a content hash over the serialized record) must reject
    // every variant; none may decode to a wrong answer.
    let (dir, skey, _) = committed_store("flip-probe");
    let store = ResultStore::open(&dir).unwrap();
    let original = std::fs::read(store.blob_path(&skey)).unwrap();
    let _ = std::fs::remove_dir_all(&dir);
    let positions: Vec<usize> = (0..original.len()).step_by(7.max(original.len() / 40)).collect();
    for pos in positions {
        assert_recovers(&format!("flip-{pos}"), move |orig| {
            let mut bytes = orig.to_vec();
            bytes[pos] ^= 1u8 << (pos % 8);
            bytes
        });
    }
}

#[test]
fn garbage_and_wrong_record_blobs_recover() {
    assert_recovers("garbage", |_| b"not json at all".to_vec());
    assert_recovers("empty-obj", |_| b"{}".to_vec());
    // A structurally valid record whose seal does not match its content.
    assert_recovers("forged-check", |orig| {
        let text = String::from_utf8(orig.to_vec()).unwrap();
        text.replacen("\"check\":\"", "\"check\":\"0", 1).into_bytes()
    });
}

#[test]
fn schema_mismatched_store_is_a_typed_error_not_a_wrong_answer() {
    let (dir, _skey, _want) = committed_store("schema");
    // A future (or past) process with a different on-disk schema must
    // refuse the whole store with a typed error at open — entries are
    // never reinterpreted across schema versions.
    std::fs::write(dir.join("STORE_SCHEMA"), "999").unwrap();
    let err = ResultStore::open(&dir).unwrap_err();
    assert_eq!(err.kind(), "store");
    assert!(err.to_string().contains("schema"), "unhelpful error: {err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupted_journal_resumes_from_the_longest_valid_prefix() {
    // A torn or bit-flipped journal must cut at the damage point and
    // resume bit-identically from the surviving prefix.
    let reference = {
        let mut runner = SweepRunner::new(cpu_factory(), 2).unwrap();
        runner.run_jobs(&[job()], |_, _, _| {}).unwrap()[0].result().unwrap().stats.clone()
    };
    for (tag, damage) in [
        ("tear", 0usize),   // torn tail: drop the last half-line
        ("midflip", 1),     // bit flip in a middle record
        ("headflip", 2),    // bit flip in the first record
    ] {
        let dir = tmp_store(&format!("journal-{tag}"));
        let store = ResultStore::open(&dir).unwrap();
        // Capture the job's per-chunk stats and write them as a full
        // journal, as a checkpointed run would have before dying.
        let capture = SweepRunner::new(cpu_factory(), 2).unwrap();
        let mut chunks = Vec::new();
        let mut sink = |id: u64, s: &segmul::error::metrics::ErrorStats| chunks.push((id, s.clone()));
        capture.pool().run_job_checkpointed(&job(), &[], &mut |_| {}, Some(&mut sink)).unwrap();
        let skey = StoreKey::new(&job(), "cpu", capture.pool().batch());
        let mut writer = store.journal_writer(&skey, 0).unwrap();
        for (id, stats) in &chunks {
            writer.append(*id, stats);
        }
        drop(writer);
        let jpath = dir.join("journal").join(format!("{}.jsonl", skey.address()));
        let mut bytes = std::fs::read(&jpath).unwrap();
        match damage {
            0 => bytes.truncate(bytes.len() - bytes.len() / (2 * chunks.len())),
            1 => {
                let mid = bytes.len() / 2;
                bytes[mid] ^= 0x10;
            }
            _ => bytes[8] ^= 0x10,
        }
        std::fs::write(&jpath, &bytes).unwrap();

        let mut resumed = SweepRunner::new(cpu_factory(), 2).unwrap();
        resumed.set_store(store);
        let got = resumed.run_jobs(&[job()], |_, _, _| {}).unwrap()[0]
            .result()
            .unwrap()
            .stats
            .clone();
        assert_eq!(got, reference, "journal-{tag}: resumed stats diverged");
        assert_eq!(got.sum_red.to_bits(), reference.sum_red.to_bits(), "journal-{tag}");
        assert!(resumed.store_recoveries >= 1, "journal-{tag}: damage must be counted");
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// Write the reference journal for `job()` into a fresh store and return
/// `(dir, store, key, per-chunk stats, journal bytes)`.
fn reference_journal(
    tag: &str,
) -> (PathBuf, ResultStore, StoreKey, Vec<segmul::error::metrics::ErrorStats>, Vec<u8>) {
    let dir = tmp_store(tag);
    let store = ResultStore::open(&dir).unwrap();
    let capture = SweepRunner::new(cpu_factory(), 2).unwrap();
    let mut chunks = Vec::new();
    let mut sink = |_id: u64, s: &segmul::error::metrics::ErrorStats| chunks.push(s.clone());
    capture.pool().run_job_checkpointed(&job(), &[], &mut |_| {}, Some(&mut sink)).unwrap();
    let skey = StoreKey::new(&job(), "cpu", capture.pool().batch());
    let mut writer = store.journal_writer(&skey, 0).unwrap();
    for (id, stats) in chunks.iter().enumerate() {
        writer.append(id as u64, stats);
    }
    drop(writer);
    let bytes = std::fs::read(dir.join("journal").join(format!("{}.jsonl", skey.address()))).unwrap();
    (dir, store, skey, chunks, bytes)
}

/// Exhaustive journal-damage property: for **every** byte-length
/// truncation and **every** single-bit flip of a live journal, recovery
/// returns exactly the longest valid prefix of whole, sealed lines —
/// bit-exact per chunk — and folding that prefix with the re-evaluated
/// remainder reproduces the uninterrupted answer bit-identically. The
/// seal must reject every flipped line: a single wrong bit may cost the
/// tail of the journal, but can never decode into a wrong answer.
#[test]
fn journal_recovers_exact_prefix_under_every_truncation_and_bit_flip() {
    let (dir, store, skey, chunks, original) = reference_journal("journal-exhaustive");
    assert!(chunks.len() >= 2, "property needs a multi-chunk journal");
    let mut reference = chunks[0].clone();
    for s in &chunks[1..] {
        reference.merge(s);
    }
    // End offset (exclusive) of each whole line.
    let line_ends: Vec<usize> =
        original.iter().enumerate().filter(|(_, b)| **b == b'\n').map(|(i, _)| i + 1).collect();
    assert_eq!(line_ends.len(), chunks.len(), "one journal line per chunk");
    let jpath = dir.join("journal").join(format!("{}.jsonl", skey.address()));

    // The recovered prefix must hold exactly the first `want` chunks,
    // bit-exact, and merging the surviving prefix with the re-evaluated
    // remainder must reproduce the reference bitwise.
    let check = |tag: &str, rec: &segmul::store::RecoveredJournal, want: usize| {
        assert_eq!(rec.chunks.len(), want, "{tag}: wrong prefix length");
        let mut merged: Option<segmul::error::metrics::ErrorStats> = None;
        for (i, got) in rec.chunks.iter().enumerate() {
            assert_eq!(got, &chunks[i], "{tag}: chunk {i} not bit-exact");
            assert_eq!(got.sum_red.to_bits(), chunks[i].sum_red.to_bits(), "{tag}: chunk {i} sum_red");
            match &mut merged {
                None => merged = Some(got.clone()),
                Some(m) => m.merge(got),
            }
        }
        for re_evaluated in &chunks[want..] {
            match &mut merged {
                None => merged = Some(re_evaluated.clone()),
                Some(m) => m.merge(re_evaluated),
            }
        }
        let merged = merged.expect("at least one chunk");
        assert_eq!(merged, reference, "{tag}: resumed merge diverged");
        assert_eq!(merged.sum_red.to_bits(), reference.sum_red.to_bits(), "{tag}: merge sum_red");
    };

    // Every byte-length truncation: a cut keeps exactly the whole lines
    // that fit (a trailing partial line is a torn tail, discarded).
    for len in 0..=original.len() {
        std::fs::write(&jpath, &original[..len]).unwrap();
        let want = line_ends.iter().filter(|&&e| e <= len).count();
        check(&format!("trunc-{len}"), &store.recover_journal(&skey), want);
    }

    // Every single-bit flip: the seal (or the line framing) must reject
    // the damaged line, cutting the prefix exactly there — never before
    // (earlier lines are untouched) and never decoding the damage.
    for pos in 0..original.len() {
        let line = line_ends.iter().filter(|&&e| e <= pos).count();
        for bit in 0..8u8 {
            let mut bytes = original.clone();
            bytes[pos] ^= 1u8 << bit;
            std::fs::write(&jpath, &bytes).unwrap();
            check(&format!("flip-{pos}-{bit}"), &store.recover_journal(&skey), line);
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Full-stack spot checks of the same property: at every line boundary,
/// mid-line, and under first/last-byte flips, a store-backed runner
/// resumes from the damaged journal and lands bit-identically on the
/// fresh-run answer (the exhaustive sweep above proves the prefix
/// recovery; this proves the runner actually re-evaluates the rest).
#[test]
fn damaged_journal_full_stack_resume_is_bit_identical() {
    let (refdir, _store, skey, chunks, original) = reference_journal("journal-fullstack-ref");
    let _ = std::fs::remove_dir_all(&refdir);
    let reference = {
        let mut runner = SweepRunner::new(cpu_factory(), 2).unwrap();
        runner.run_jobs(&[job()], |_, _, _| {}).unwrap()[0].result().unwrap().stats.clone()
    };
    let line_ends: Vec<usize> =
        original.iter().enumerate().filter(|(_, b)| **b == b'\n').map(|(i, _)| i + 1).collect();
    let mut cases: Vec<(String, Vec<u8>)> = Vec::new();
    for &end in &line_ends {
        cases.push((format!("cut-at-{end}"), original[..end].to_vec()));
        cases.push((format!("cut-mid-{end}"), original[..end - end / (2 * chunks.len())].to_vec()));
    }
    for pos in [0usize, original.len() / 2, original.len() - 1] {
        let mut bytes = original.clone();
        bytes[pos] ^= 0x04;
        cases.push((format!("flip-at-{pos}"), bytes));
    }
    for (tag, bytes) in cases {
        let dir = tmp_store(&format!("journal-fs-{tag}"));
        let store = ResultStore::open(&dir).unwrap();
        let jpath = dir.join("journal").join(format!("{}.jsonl", skey.address()));
        std::fs::write(&jpath, &bytes).unwrap();
        let mut resumed = SweepRunner::new(cpu_factory(), 2).unwrap();
        resumed.set_store(store);
        let got = resumed.run_jobs(&[job()], |_, _, _| {}).unwrap()[0].result().unwrap().stats.clone();
        assert_eq!(got, reference, "{tag}: resumed stats diverged");
        assert_eq!(got.sum_red.to_bits(), reference.sum_red.to_bits(), "{tag}: sum_red bits");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
