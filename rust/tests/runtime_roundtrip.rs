//! Integration: the AOT-compiled PJRT artifacts must agree bit-for-bit with
//! the Rust word-level model (which itself is proven equal to the paper's
//! Boolean recurrences). This closes the loop python(L1/L2) == rust(L3).
//!
//! Skipped (with a message) when `make artifacts` has not been run.

use std::path::PathBuf;

use segmul::multiplier::wordlevel::{approx_seq_mul, error_distance, exact_mul};
use segmul::runtime::{artifact, ModuleKind, Runtime};
use segmul::util::rng::Xoshiro256;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts/ — run `make artifacts` first");
        None
    }
}

fn random_operands(n: u32, len: usize, seed: u64) -> (Vec<u64>, Vec<u64>) {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let a: Vec<u64> = (0..len).map(|_| rng.next_bits(n)).collect();
    let b: Vec<u64> = (0..len).map(|_| rng.next_bits(n)).collect();
    (a, b)
}

#[test]
fn manifest_covers_expected_bitwidths() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = artifact::Manifest::load(&dir).unwrap();
    for n in [4u32, 8, 16, 32] {
        assert!(manifest.find(n, ModuleKind::Stats).is_some(), "missing stats n={n}");
        assert!(manifest.find(n, ModuleKind::Prod).is_some(), "missing prod n={n}");
    }
}

#[test]
fn prod_module_matches_wordlevel() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::load(&dir).unwrap();
    let batch = rt.batch();
    for (n, t, fix) in [(4u32, 2u64, false), (8, 3, true), (16, 8, true), (32, 13, false)] {
        let (a, b) = random_operands(n, batch, 42 + n as u64);
        let got = rt.exec_prod(n, &a, &b, t, fix).unwrap();
        for i in (0..batch).step_by(97) {
            let want = approx_seq_mul(a[i], b[i], n, t as u32, fix);
            assert_eq!(got[i], want, "n={n} t={t} fix={fix} i={i} a={} b={}", a[i], b[i]);
        }
        // full equality too (cheap)
        let want_all: Vec<u64> = a
            .iter()
            .zip(&b)
            .map(|(&x, &y)| approx_seq_mul(x, y, n, t as u32, fix))
            .collect();
        assert_eq!(got, want_all, "n={n}");
    }
}

#[test]
fn stats_module_matches_wordlevel_aggregation() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::load(&dir).unwrap();
    let batch = rt.batch();
    for (n, t, fix) in [(8u32, 4u64, true), (16, 6, false)] {
        let (a, b) = random_operands(n, batch, 7 + n as u64);
        let got = rt.exec_stats(n, &a, &b, t, fix).unwrap();
        assert_eq!(got.len(), 6 + 2 * n as usize);

        let mut err_count = 0f64;
        let mut sum_ed = 0f64;
        let mut sum_abs = 0f64;
        let mut max_abs = 0f64;
        let mut sum_red = 0f64;
        let mut flips = vec![0f64; 2 * n as usize];
        for i in 0..batch {
            let p = exact_mul(a[i], b[i], n);
            let phat = approx_seq_mul(a[i], b[i], n, t as u32, fix);
            let ed = error_distance(p, phat);
            if ed != 0 {
                err_count += 1.0;
            }
            sum_ed += ed as f64;
            sum_abs += ed.unsigned_abs() as f64;
            max_abs = max_abs.max(ed.unsigned_abs() as f64);
            sum_red += ed.unsigned_abs() as f64 / (p.max(1)) as f64;
            let x = p ^ phat;
            for (bit, f) in flips.iter_mut().enumerate() {
                *f += ((x >> bit) & 1) as f64;
            }
        }
        assert_eq!(got[0], batch as f64);
        assert_eq!(got[1], err_count, "err_count n={n}");
        assert!((got[2] - sum_ed).abs() <= sum_abs.abs() * 1e-9, "sum_ed {} vs {}", got[2], sum_ed);
        assert!((got[3] - sum_abs).abs() <= sum_abs * 1e-9);
        assert_eq!(got[4], max_abs, "max_abs n={n}");
        assert!((got[5] - sum_red).abs() <= sum_red.max(1.0) * 1e-9);
        for (bit, f) in flips.iter().enumerate() {
            assert_eq!(got[6 + bit], *f, "bitflip[{bit}] n={n}");
        }
    }
}

#[test]
fn stats_accurate_config_is_error_free() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::load(&dir).unwrap();
    let batch = rt.batch();
    let (a, b) = random_operands(16, batch, 99);
    let got = rt.exec_stats(16, &a, &b, 0, false).unwrap();
    assert_eq!(got[0], batch as f64);
    for v in &got[1..] {
        assert_eq!(*v, 0.0);
    }
}

#[test]
fn rejects_bad_inputs() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::load(&dir).unwrap();
    let short = vec![0u64; 3];
    assert!(rt.exec_stats(8, &short, &short, 1, false).is_err());
    let (a, b) = random_operands(8, rt.batch(), 1);
    assert!(rt.exec_stats(8, &a, &b, 8, false).is_err(), "t >= n must be rejected");
    assert!(rt.exec_stats(7, &a, &b, 1, false).is_err(), "unknown n must be rejected");
}
