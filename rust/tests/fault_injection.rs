//! End-to-end deterministic fault injection through the session facade.
//!
//! The contract under test: an armed [`FaultInjector`] makes the stack
//! *slower but never wrong*. Injected store/journal/lease/worker faults
//! are recovered by the typed retry layer (or degrade a durability
//! feature with a warning), the final answers stay bit-identical to a
//! fault-free run, and every injection and retry is counted in the
//! session telemetry. Unrecoverable storms surface as typed errors —
//! never a panic, never a hang, never a silently wrong answer.

use std::path::PathBuf;
use std::sync::Arc;

use segmul::api::{BackendChoice, EvalJob, Session};
use segmul::fault::{FaultInjector, FaultSite};

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("segmul-faultinj-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn session(store: Option<&PathBuf>, faults: Option<Arc<FaultInjector>>) -> Session {
    let mut b = Session::builder().workers(2).backend(BackendChoice::Cpu).seed(42);
    if let Some(dir) = store {
        b = b.store(dir.clone());
    }
    if let Some(f) = faults {
        b = b.faults(f);
    }
    b.build().expect("session startup")
}

fn jobs() -> Vec<EvalJob> {
    vec![
        EvalJob::mc(8, 3, true, 150_000, 9),
        EvalJob::mc(8, 5, false, 150_000, 9),
        EvalJob::mc(10, 4, true, 150_000, 9),
    ]
}

/// A chaos-rate plan over every store/worker seam leaves a store-backed
/// sweep bit-identical to a clean run, with the injections and the
/// recovering retries both counted.
#[test]
fn chaotic_store_backed_sweep_is_bit_identical_to_a_clean_run() {
    let clean = session(None, None).run_jobs(&jobs(), |_, _, _| {}).expect("clean run");
    let dir = tmp_dir("chaos");
    let spec = "store.read:p=0.4,store.write:p=0.4,store.corrupt:p=0.4,\
                journal.append:p=0.5,worker.panic:p=0.1,lease.claim:p=0.4";
    let faults = Arc::new(FaultInjector::parse(spec, 0xC0FFEE).expect("valid plan"));
    let mut chaotic = session(Some(&dir), Some(faults.clone()));
    let got = chaotic.run_jobs(&jobs(), |_, _, _| {}).expect("chaotic run must still complete");
    assert_eq!(got.len(), clean.len());
    for (g, c) in got.iter().zip(&clean) {
        let (gs, cs) = (&g.result().expect("simulated").stats, &c.result().expect("simulated").stats);
        assert_eq!(gs, cs, "{}: chaos changed the answer", g.job.design.name());
        assert_eq!(gs.sum_red.to_bits(), cs.sum_red.to_bits(), "{}: sum_red bits", g.job.design.name());
    }
    assert!(faults.total_injected() > 0, "the chaos plan never fired");
    let t = chaotic.telemetry();
    assert_eq!(t.faults_injected, faults.total_injected(), "telemetry must mirror the injector");
    let _ = std::fs::remove_dir_all(&dir);
}

/// `after=n` one-shot triggers fire exactly once; the single injected
/// commit failure is invisible in the answers, and a later fault-free
/// session converges on the bit-identical result through the same store.
#[test]
fn one_shot_store_write_fault_fires_once_and_recovers() {
    let dir = tmp_dir("oneshot");
    let job = EvalJob::mc(8, 3, true, 120_000, 11);
    let faults = Arc::new(FaultInjector::parse("store.write:after=1", 7).expect("valid plan"));
    let r1 = session(Some(&dir), Some(faults.clone())).run(&job).expect("run under one-shot fault");
    assert_eq!(faults.injected(FaultSite::StoreWrite), 1, "one-shot must fire exactly once");
    assert_eq!(faults.counters(), vec![("store.write", 1)]);
    let r2 = session(Some(&dir), None).run(&job).expect("clean follow-up run");
    assert_eq!(r1.stats, r2.stats);
    assert_eq!(r1.stats.sum_red.to_bits(), r2.stats.sum_red.to_bits());
    let _ = std::fs::remove_dir_all(&dir);
}

/// A worker-panic storm past the retry budget is a typed eval error with
/// the exhausted retries counted — the process neither hangs nor dies.
#[test]
fn unrecoverable_panic_storm_is_a_typed_error_with_gave_up_counted() {
    let faults = Arc::new(FaultInjector::parse("worker.panic:p=1", 3).expect("valid plan"));
    let mut s = session(None, Some(faults.clone()));
    let err = s.run(&EvalJob::mc(8, 3, true, 50_000, 5)).expect_err("p=1 must exhaust the budget");
    assert_eq!(err.kind(), "eval", "panic storms surface as typed eval errors: {err}");
    assert!(s.gave_up() > 0, "the exhausted retry episode must be counted");
    assert!(faults.total_injected() >= 2, "every panicked attempt counts as an injection");
}
