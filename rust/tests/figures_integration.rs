//! Figure-generation integration: every paper artifact generator runs on a
//! reduced configuration and produces structurally-correct output.

use segmul::config::Config;
use segmul::coordinator::CpuBackend;
use segmul::report;

fn test_cfg(tag: &str) -> Config {
    let mut c = Config::default();
    c.results_dir = std::env::temp_dir().join(format!("segmul_figint_{tag}"));
    c.error_bitwidths = vec![4, 8];
    c.hw_bitwidths = vec![4, 8, 16];
    c.hw_vectors = 64;
    c.mc_samples = 1 << 10;
    c.exhaustive_max_n = 8;
    c
}

#[test]
fn fig2_rows_cover_designs_and_baselines() {
    let cfg = test_cfg("fig2");
    let mut be = CpuBackend::new();
    let t = report::fig2(&cfg, &mut be).unwrap();
    let designs: Vec<&str> = t.rows.iter().map(|r| r[0].as_str()).collect();
    assert!(designs.iter().any(|d| *d == "segmul"));
    assert!(designs.iter().any(|d| *d == "segmul+fix"));
    assert!(designs.iter().any(|d| d.starts_with("trunc")));
    assert!(designs.iter().any(|d| d.starts_with("mitchell")));
    assert!(designs.iter().any(|d| d.starts_with("kulkarni")));
    // ER column must be a probability
    for row in &t.rows {
        let er: f64 = row[4].parse().unwrap();
        assert!((0.0..=1.0).contains(&er));
    }
}

#[test]
fn headline_reports_both_targets() {
    let mut cfg = test_cfg("headline");
    // small n (4) is noise-dominated on the FPGA model (constant LUT
    // entry/exit swamps the 2-bit chain); the claim is about the sweep.
    cfg.hw_bitwidths = vec![8, 16, 32];
    let t = report::headline(&cfg).unwrap();
    assert_eq!(t.rows.len(), 2);
    for row in &t.rows {
        let avg_red: f64 = row[1].parse().unwrap();
        assert!(avg_red > 0.0, "latency must reduce on average: {row:?}");
    }
}

#[test]
fn probprop_table_bounded_error() {
    let mut cfg = test_cfg("probprop");
    cfg.exhaustive_max_n = 8;
    let t = report::probprop_accuracy(&cfg).unwrap();
    assert!(!t.rows.is_empty());
    for row in &t.rows {
        let rel: f64 = row[4].parse().unwrap();
        assert!(rel < 0.5, "estimator ER rel err {rel} too large: {row:?}");
    }
}

#[test]
fn all_csvs_written() {
    let cfg = test_cfg("csv");
    let mut be = CpuBackend::new();
    report::fig2(&cfg, &mut be).unwrap();
    report::mae_table(&cfg).unwrap();
    report::fig3a(&cfg).unwrap();
    report::fig3b(&cfg).unwrap();
    report::seqcomb(&cfg).unwrap();
    for f in [
        "fig2_error_metrics.csv",
        "mae_closed_form.csv",
        "fig3a_fpga.csv",
        "fig3b_asic.csv",
        "seqcomb_crossover.csv",
    ] {
        assert!(cfg.results_dir.join(f).exists(), "{f} missing");
    }
}
