//! Parallel-vs-sequential determinism of the sweep orchestrator.
//!
//! The contract under test: for every config in a grid, MC and
//! exhaustive sweeps produce **bit-identical** `ErrorStats` — every
//! integer field and the order-sensitive f64 `sum_red` — for workers
//! ∈ {1, 2, 7}, and the `(design, seed, samples)` result cache serves
//! repeats without re-evaluating. Since PR 3 the runner executes on the
//! persistent worker pool (backends built once per worker, not per job);
//! the determinism expectations are unchanged from PR 2.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use anyhow::Result;

use segmul::coordinator::{
    run_job, run_job_sharded, CpuBackend, EvalBackend, EvalJob, SweepGrid, SweepRunner,
};
use segmul::multiplier::DesignSet;

const WORKER_COUNTS: [usize; 3] = [1, 2, 7];

fn cpu_factory() -> impl Fn() -> Result<Box<dyn EvalBackend>> + Send + Sync + 'static {
    || Ok(Box::new(CpuBackend::new()) as Box<dyn EvalBackend>)
}

fn exhaustive_grid() -> SweepGrid {
    SweepGrid {
        bitwidths: vec![4, 8],
        designs: DesignSet::Paper,
        exhaustive_max_n: 12,
        force_mc: false,
        mc_samples: 1 << 16,
        seed: 0x5EED,
    }
}

fn mc_grid() -> SweepGrid {
    SweepGrid {
        bitwidths: vec![8, 12],
        designs: DesignSet::Paper,
        exhaustive_max_n: 12,
        force_mc: true,
        // > one chunk (2^16) per config so sharding actually interleaves.
        mc_samples: 300_000,
        seed: 0x5EED,
    }
}

/// Every config of `grid`, evaluated at each worker count, must be
/// bit-identical to the sequential driver.
fn assert_grid_deterministic(grid: &SweepGrid) {
    let jobs = grid.jobs();
    assert!(!jobs.is_empty());
    let reference: Vec<_> = jobs
        .iter()
        .map(|job| {
            let mut be = CpuBackend::new();
            run_job(&mut be, job).unwrap().stats
        })
        .collect();
    for workers in WORKER_COUNTS {
        let mut runner = SweepRunner::new(cpu_factory(), workers).unwrap();
        let outcomes = runner.run_grid(grid, |_, _, _| {}).unwrap();
        for (outcome, want) in outcomes.iter().zip(&reference) {
            // Full equality: count, err_count, sums, bitflips AND the
            // accumulation-order-sensitive sum_red.
            assert_eq!(
                &outcome.result().unwrap().stats,
                want,
                "workers={workers} design={}",
                outcome.job.design.name()
            );
        }
    }
}

#[test]
fn exhaustive_grid_bit_identical_across_worker_counts() {
    assert_grid_deterministic(&exhaustive_grid());
}

#[test]
fn mc_grid_bit_identical_across_worker_counts() {
    assert_grid_deterministic(&mc_grid());
}

#[test]
fn cross_design_grid_bit_identical_across_worker_counts() {
    // The comparative sweep (paper × accurate × baselines × oracle ×
    // netlist spots) must obey the same determinism contract.
    assert_grid_deterministic(&SweepGrid {
        bitwidths: vec![4],
        designs: DesignSet::All,
        exhaustive_max_n: 12,
        force_mc: false,
        mc_samples: 1 << 16,
        seed: 0x5EED,
    });
}

#[test]
fn sharded_job_equals_sequential_for_large_config() {
    // One big config sliced many ways (more chunks than workers so the
    // stealing cursor actually interleaves).
    let job = EvalJob::mc(16, 7, true, 500_000, 42);
    let mut be = CpuBackend::new();
    let want = run_job(&mut be, &job).unwrap();
    for workers in WORKER_COUNTS {
        let got = run_job_sharded(&cpu_factory(), &job, workers).unwrap();
        assert_eq!(got.stats, want.stats, "workers={workers}");
        assert_eq!(got.batches, want.batches, "workers={workers}");
    }
}

#[test]
fn cache_serves_repeats_without_reevaluating() {
    // Counting backend: every eval_batch call is recorded.
    let calls = Arc::new(AtomicUsize::new(0));
    struct Counting {
        inner: CpuBackend,
        calls: Arc<AtomicUsize>,
    }
    impl EvalBackend for Counting {
        fn name(&self) -> &'static str {
            "counting"
        }
        fn max_batch(&self) -> usize {
            self.inner.max_batch()
        }
        fn supports(&self, n: u32) -> bool {
            self.inner.supports(n)
        }
        fn eval_batch(
            &mut self,
            n: u32,
            t: u32,
            fix: bool,
            a: &[u64],
            b: &[u64],
        ) -> Result<segmul::error::metrics::ErrorStats> {
            self.calls.fetch_add(1, Ordering::Relaxed);
            self.inner.eval_batch(n, t, fix, a, b)
        }
    }
    let counter = calls.clone();
    let factory = move || {
        Ok(Box::new(Counting { inner: CpuBackend::new(), calls: counter.clone() })
            as Box<dyn EvalBackend>)
    };
    let grid = exhaustive_grid();
    let mut runner = SweepRunner::new(factory, 2).unwrap();
    let first = runner.run_grid(&grid, |_, _, _| {}).unwrap();
    let evals_after_first_pass = calls.load(Ordering::Relaxed);
    // t=0 fix=true is served from the t=0 fix=false entry per bit-width.
    assert_eq!(runner.cache_hits, grid.bitwidths.len() as u64);
    // Second pass over the same grid: all cache hits, zero backend work.
    let second = runner.run_grid(&grid, |_, _, _| {}).unwrap();
    assert!(second.iter().all(|o| o.cached));
    assert_eq!(
        calls.load(Ordering::Relaxed),
        evals_after_first_pass,
        "cache hits must not re-evaluate"
    );
    for (a, b) in first.iter().zip(&second) {
        assert_eq!(a.result().unwrap().stats, b.result().unwrap().stats);
    }
    // The persistent pool built exactly one backend per worker for the
    // whole two-pass run.
    assert_eq!(runner.pool().backend_builds(), 2);
}

#[test]
fn segmul_workers_env_contract() {
    // The env override is parsed through this pure helper (process-global
    // env mutation is racy under the parallel test harness).
    use segmul::util::threadpool::workers_override;
    assert_eq!(workers_override(Some("4")).unwrap(), Some(4));
    // Since PR 3 an explicit 0 is a typed configuration error instead of
    // a silent clamp, and so is junk.
    assert_eq!(workers_override(Some("0")).unwrap_err().kind(), "config");
    assert_eq!(workers_override(Some("junk")).unwrap_err().kind(), "config");
}
