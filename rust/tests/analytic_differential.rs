//! Three-way differential validation of the analytic error-model
//! registry (`error::analytic`).
//!
//! Tier 1 — exact ground truth: for every modeled design family at
//! n ∈ {4, 8, 10} the analytic statistics must agree with exhaustive
//! evaluation of all `2^{2n}` input pairs — bit-for-bit for the
//! closed-form combinational families (truncation, broken-array,
//! Mitchell, Kulkarni), within the documented calibration bounds for the
//! segmented lattice estimates (both fix modes).
//!
//! Tier 2 — statistical: at n ∈ {16, 32} exhaustive evaluation is
//! infeasible, so the models are checked against Monte-Carlo sampling
//! within confidence-interval-scale tolerances.
//!
//! Tier 3 — sweep-level: `--analytic require` over a full cross-design
//! grid must answer every row in closed form (zero pool dispatches) and
//! produce rows consistent with a fully simulated run of the same grid.

use segmul::api::{
    analytic_stats, AnalyticMode, BackendChoice, DesignSet, MultiplierSpec, Session, SweepGrid,
};
use segmul::error::exhaustive::{exhaustive_stats, exhaustive_stats_batch};
use segmul::error::montecarlo::{mc_stats, mc_stats_batch, McConfig};

/// The combinational baseline families with fully closed-form models at
/// one bit-width (Kulkarni requires a power-of-two width).
fn combinational_designs(n: u32) -> Vec<MultiplierSpec> {
    let mut out = vec![
        MultiplierSpec::Truncated { n, k: n / 4 },
        MultiplierSpec::Truncated { n, k: n / 2 },
        MultiplierSpec::BrokenArray { n, hbl: n / 4, vbl: n / 2 },
        MultiplierSpec::Mitchell { n },
    ];
    if n.is_power_of_two() {
        out.push(MultiplierSpec::Kulkarni { n });
    }
    out
}

fn rel_err(a: f64, b: f64) -> f64 {
    if b == 0.0 {
        a.abs()
    } else {
        (a - b).abs() / b.abs()
    }
}

#[test]
fn combinational_models_match_exhaustive_exactly() {
    for n in [4u32, 8, 10] {
        for spec in combinational_designs(n) {
            let a = analytic_stats(&spec).expect("modeled design");
            assert!(a.exact, "{} must be exact at n={n}", spec.name());
            let bl = spec.build_batch().unwrap();
            let m = exhaustive_stats_batch(bl.as_ref(), 2).metrics().unwrap();
            assert_eq!(m.samples, 1u64 << (2 * n), "{}", spec.name());
            assert!(
                (a.er - m.er).abs() < 1e-12,
                "{} ER analytic {} vs exhaustive {}",
                spec.name(),
                a.er,
                m.er
            );
            assert!(
                (a.med_abs - m.med_abs).abs() < 1e-6 * (1.0 + m.med_abs),
                "{} MED analytic {} vs exhaustive {}",
                spec.name(),
                a.med_abs,
                m.med_abs
            );
            assert!(
                (a.med_signed - m.med_signed).abs() < 1e-6 * (1.0 + m.med_signed.abs()),
                "{} signed MED analytic {} vs exhaustive {}",
                spec.name(),
                a.med_signed,
                m.med_signed
            );
            assert_eq!(a.wce, m.mae, "{} WCE", spec.name());
            assert!(
                rel_err(a.mred, m.mred) < 1e-5,
                "{} MRED analytic {} vs exhaustive {}",
                spec.name(),
                a.mred,
                m.mred
            );
        }
    }
}

#[test]
fn segmented_model_tracks_exhaustive_within_calibration_bounds() {
    use segmul::error::closed_form::{mae_fix_envelope, mae_measured_nofix};
    for n in [4u32, 8, 10] {
        for t in 1..=n / 2 {
            for fix in [false, true] {
                let spec = MultiplierSpec::Segmented { n, t, fix };
                let a = analytic_stats(&spec).expect("segmented is modeled");
                assert!(!a.exact, "segmented estimates must not claim exactness");
                let m = exhaustive_stats(n, t, fix).metrics().unwrap();
                let scale = (1u64 << (n + t - 1)) as f64;
                assert!(
                    rel_err(a.er, m.er) <= 0.6,
                    "n={n} t={t} fix={fix}: ER est {} vs exact {}",
                    a.er,
                    m.er
                );
                let signed_tol = if fix { 0.06 } else { 0.01 };
                assert!(
                    (a.med_signed - m.med_signed).abs() <= signed_tol * scale,
                    "n={n} t={t} fix={fix}: signed MED est {} vs exact {} (scale {scale})",
                    a.med_signed,
                    m.med_signed
                );
                let abs_tol = if fix { 0.15 } else { 0.35 };
                assert!(
                    rel_err(a.med_abs, m.med_abs) <= abs_tol,
                    "n={n} t={t} fix={fix}: MED est {} vs exact {}",
                    a.med_abs,
                    m.med_abs
                );
                assert!(
                    a.mred >= m.mred / 4.0 && a.mred <= m.mred * 4.0,
                    "n={n} t={t} fix={fix}: MRED est {} vs exact {}",
                    a.mred,
                    m.mred
                );
                if fix {
                    // The fix WCE is a tight envelope: it dominates the
                    // measurement but by less than a factor of two.
                    assert_eq!(a.wce, mae_fix_envelope(n, t));
                    assert!(m.mae <= a.wce, "n={n} t={t}: envelope violated");
                    assert!(m.mae > a.wce / 2, "n={n} t={t}: envelope loose");
                } else {
                    assert_eq!(a.wce, mae_measured_nofix(n, t));
                    assert_eq!(a.wce, m.mae, "n={n} t={t}: no-fix WCE is exact");
                }
            }
        }
    }
}

#[test]
fn large_n_models_agree_with_monte_carlo() {
    const SAMPLES: u64 = 1 << 18;
    for n in [16u32, 32] {
        // Combinational families: the closed-form (n = 16) and hybrid
        // (n = 32) tiers against MC with CI-scale tolerances.
        for spec in combinational_designs(n) {
            let a = analytic_stats(&spec).expect("modeled design");
            let bl = spec.build_batch().unwrap();
            let mc = McConfig::uniform(SAMPLES, 0xD1FF ^ n as u64);
            let m = mc_stats_batch(bl.as_ref(), &mc).metrics().unwrap();
            assert!(
                (a.er - m.er).abs() < 0.01,
                "{} ER analytic {} vs MC {}",
                spec.name(),
                a.er,
                m.er
            );
            assert!(
                rel_err(a.med_abs, m.med_abs) < 0.05,
                "{} MED analytic {} vs MC {}",
                spec.name(),
                a.med_abs,
                m.med_abs
            );
        }
        // Segmented estimates at the paper's t = n/2 point.
        let t = n / 2;
        for fix in [false, true] {
            let a = analytic_stats(&MultiplierSpec::Segmented { n, t, fix }).unwrap();
            let m = mc_stats(n, t, fix, &McConfig::uniform(SAMPLES, 0x5E6)).metrics().unwrap();
            assert!(
                rel_err(a.er, m.er) <= 0.4,
                "n={n} t={t} fix={fix}: ER est {} vs MC {}",
                a.er,
                m.er
            );
        }
    }
}

#[test]
fn analytic_require_sweep_is_dispatch_free_and_consistent_with_simulation() {
    let grid = SweepGrid {
        bitwidths: vec![4, 8],
        designs: DesignSet::All,
        exhaustive_max_n: 8,
        force_mc: false,
        mc_samples: 1 << 14,
        seed: 9,
    };
    let mut simulated = Session::builder()
        .workers(2)
        .backend(BackendChoice::Cpu)
        .seed(9)
        .build()
        .unwrap();
    let sim = simulated.run_grid(&grid, |_, _, _| {}).unwrap();

    let mut fast = Session::builder()
        .workers(2)
        .backend(BackendChoice::Cpu)
        .seed(9)
        .analytic(AnalyticMode::Require)
        .build()
        .unwrap();
    let ana = fast.run_grid(&grid, |_, _, _| {}).unwrap();

    // Zero pool dispatches: nothing evaluated, nothing cached, every row
    // answered analytically.
    assert_eq!(fast.jobs_evaluated(), 0);
    assert_eq!(fast.cache_hits(), 0);
    assert_eq!(fast.analytic_answers(), ana.len() as u64);
    assert_eq!(fast.telemetry().analytic_answers, ana.len() as u64);

    // Row identity: same grid, same order; per-row metrics consistent
    // with simulation — bit-consistent where the model is exact, inside
    // the documented calibration bounds where it is an estimate.
    assert_eq!(sim.len(), ana.len());
    for (s, a) in sim.iter().zip(&ana) {
        assert_eq!(s.job.design, a.job.design);
        assert_eq!(s.source(), "simulated");
        assert_eq!(a.source(), "analytic");
        let stats = a.analytic().expect("analytic answer carries its stats");
        let sm = s.metrics().unwrap();
        let am = a.metrics().unwrap();
        assert_eq!(sm.samples, am.samples, "{}", s.job.design.name());
        if stats.exact {
            assert!(
                (sm.er - am.er).abs() < 1e-12 && (sm.med_abs - am.med_abs).abs() < 1e-6,
                "{}: exact row diverged (ER {} vs {}, MED {} vs {})",
                s.job.design.name(),
                sm.er,
                am.er,
                sm.med_abs,
                am.med_abs
            );
            assert_eq!(sm.mae, am.mae, "{}", s.job.design.name());
        } else {
            assert!(
                rel_err(am.er, sm.er) <= 0.6,
                "{}: ER est {} vs simulated {}",
                s.job.design.name(),
                am.er,
                sm.er
            );
            assert!(
                rel_err(am.med_abs, sm.med_abs) <= 0.35,
                "{}: MED est {} vs simulated {}",
                s.job.design.name(),
                am.med_abs,
                sm.med_abs
            );
            assert!(sm.mae <= am.mae, "{}: WCE must dominate", s.job.design.name());
        }
    }
}
