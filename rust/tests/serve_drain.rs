//! Graceful drain and deadline enforcement: in-flight work completes
//! after a shutdown request while new work gets typed 503s, and expired
//! deadlines surface as typed 504s (headline or in-band) without ever
//! hanging a client or the server.

use std::time::Duration;

use segmul::api::BackendChoice;
use segmul::serve::metrics::metric_value;
use segmul::serve::{client, ServeConfig, Server};
use segmul::util::json::Json;

fn boot() -> Server {
    Server::start(ServeConfig {
        workers: Some(2),
        backend: BackendChoice::Cpu,
        default_deadline: Duration::from_secs(120),
        ..ServeConfig::default()
    })
    .expect("server startup")
}

/// A drain requested mid-sweep lets the sweep finish (it was admitted
/// before the drain) while late arrivals get typed 503s.
#[test]
fn shutdown_completes_inflight_sweep_and_rejects_new_work() {
    let server = boot();
    let addr = server.addr();

    // A sweep heavy enough to span many engine cycles (one grid point
    // per cycle) and to still be in flight while the drain checks below
    // run: the client thread blocks until the stream completes.
    let sweeper = std::thread::spawn(move || {
        client::post_json(
            addr,
            "/v1/sweep",
            &Json::parse(r#"{"designs":"paper","bitwidths":[8],"mc":true,"samples":5000000,"seed":5}"#)
                .unwrap(),
        )
    });
    std::thread::sleep(Duration::from_millis(50));

    let down = client::post_json(addr, "/v1/shutdown", &Json::Obj(Default::default())).unwrap();
    assert_eq!(down.status, 200);
    assert_eq!(down.json().unwrap().get("status").and_then(Json::as_str), Some("draining"));
    assert!(server.draining());

    // Health flips to draining; new work is refused with a typed 503.
    let health = client::get(addr, "/healthz").unwrap();
    assert_eq!(health.status, 503);
    assert_eq!(health.json().unwrap().get("status").and_then(Json::as_str), Some("draining"));
    let late = client::post_json(
        addr,
        "/v1/eval",
        &Json::parse(
            r#"{"design":{"family":"accurate","n":8},
                "workload":{"kind":"mc","samples":1000,"seed":1}}"#,
        )
        .unwrap(),
    )
    .unwrap();
    assert_eq!(late.status, 503, "{}", late.text());
    let err = late.json().unwrap();
    assert_eq!(err.get("error").unwrap().get("kind").and_then(Json::as_str), Some("serve"));

    // The in-flight sweep still streams to completion.
    let sweep = sweeper.join().unwrap().unwrap();
    assert_eq!(sweep.status, 200);
    let lines = sweep.json_lines().unwrap();
    let trailer = lines.last().expect("stream trailer");
    assert_eq!(
        trailer.get("status").and_then(Json::as_str),
        Some("complete"),
        "drain must not abort admitted work: {trailer:?}"
    );
    let total = trailer.get("total").unwrap().as_u64().unwrap();
    assert_eq!(trailer.get("done").unwrap().as_u64(), Some(total));
    assert!(total >= 2, "paper grid at n=8 has multiple points");

    let summary = server.join();
    assert_eq!(
        summary.telemetry.jobs_completed, total,
        "every admitted grid point ran; the rejected eval never reached the engine"
    );
    assert!(summary.metrics_doc.contains("serve_draining 1"));
}

#[test]
fn begin_drain_via_handle_stops_the_server() {
    let server = boot();
    let addr = server.addr();
    assert!(!server.draining());
    server.begin_drain();
    assert!(server.draining());
    // While the drain is settling, a late client gets a typed 503; once
    // the idle engine and acceptor have exited (which can be immediate —
    // the queue is empty), the connection is refused instead.
    if let Ok(health) = client::get(addr, "/healthz") {
        assert_eq!(health.status, 503);
    }
    let summary = server.join();
    assert_eq!(summary.telemetry.jobs_completed, 0);
}

/// An eval whose deadline expires before the engine answers gets a
/// typed 504 and is cancelled, never evaluated on the client's behalf.
#[test]
fn eval_deadline_expires_as_typed_504() {
    let server = boot();
    let addr = server.addr();

    let resp = client::post_json(
        addr,
        "/v1/eval",
        &Json::parse(
            r#"{"design":{"family":"segmented","n":16,"t":5,"fix":true},
                "workload":{"kind":"mc","samples":2000000,"seed":2},
                "deadline_ms":1}"#,
        )
        .unwrap(),
    )
    .unwrap();
    assert_eq!(resp.status, 504, "{}", resp.text());
    let err = resp.json().unwrap();
    let err = err.get("error").unwrap();
    assert_eq!(err.get("kind").and_then(Json::as_str), Some("serve"));
    assert_eq!(err.get("status").and_then(Json::as_u64), Some(504));
    assert!(err.get("detail").and_then(Json::as_str).unwrap().contains("deadline"));

    let doc = client::get(addr, "/metrics").unwrap().text();
    let timeouts: u64 = metric_value(&doc, "serve_deadline_timeouts").unwrap().parse().unwrap();
    assert!(timeouts >= 1);

    let _ = client::post_json(addr, "/v1/shutdown", &Json::Obj(Default::default())).unwrap();
    let summary = server.join();
    assert!(summary.requests_total >= 3);
}

/// A sweep deadline fires after the 200 head is committed, so it is
/// delivered in-band: a typed 504 error row terminates the stream.
#[test]
fn sweep_deadline_is_delivered_in_band() {
    let server = boot();
    let addr = server.addr();

    let resp = client::post_json(
        addr,
        "/v1/sweep",
        &Json::parse(
            r#"{"designs":"paper","bitwidths":[16],"mc":true,"samples":2000000,"deadline_ms":1}"#,
        )
        .unwrap(),
    )
    .unwrap();
    assert_eq!(resp.status, 200, "the head is already committed when the deadline fires");
    let lines = resp.json_lines().unwrap();
    let last = lines.last().expect("in-band error row");
    let err = last.get("error").expect("typed error row");
    assert_eq!(err.get("kind").and_then(Json::as_str), Some("serve"));
    assert_eq!(err.get("status").and_then(Json::as_u64), Some(504));
    assert!(err.get("detail").and_then(Json::as_str).unwrap().contains("grid points"));

    let _ = client::post_json(addr, "/v1/shutdown", &Json::Obj(Default::default())).unwrap();
    server.join();
}
