//! Resume and sharding guarantees of the persistent result store.
//!
//! The contracts under test, end-to-end through `SweepRunner`:
//!
//! * a sweep that stopped between jobs (committed blobs) or mid-job
//!   (a checkpointed chunk journal) resumes **bit-identically** — every
//!   integer field and the order-sensitive f64 `sum_red` — for workers
//!   ∈ {1, 2, 7}, with and without the analytic answer source;
//! * N processes claiming disjoint [`Shard`]s of one grid into a shared
//!   store perform zero duplicate evaluations, and a merge pass over the
//!   full grid reproduces the single-process results from store hits
//!   alone.

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use anyhow::Result;

use segmul::coordinator::{
    AnalyticMode, CpuBackend, EvalBackend, EvalJob, Shard, SweepGrid, SweepOutcome, SweepRunner,
};
use segmul::error::metrics::ErrorStats;
use segmul::multiplier::DesignSet;
use segmul::store::{ResultStore, StoreKey};

const WORKER_COUNTS: [usize; 3] = [1, 2, 7];

fn cpu_factory() -> impl Fn() -> Result<Box<dyn EvalBackend>> + Send + Sync + 'static {
    || Ok(Box::new(CpuBackend::new()) as Box<dyn EvalBackend>)
}

fn tmp_store(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("segmul-store-it-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn mc_grid() -> SweepGrid {
    SweepGrid {
        bitwidths: vec![8, 12],
        designs: DesignSet::Paper,
        exhaustive_max_n: 12,
        force_mc: true,
        // > one chunk per config so mid-job checkpoints are non-trivial.
        mc_samples: 300_000,
        seed: 0x5EED,
    }
}

fn assert_outcomes_bit_identical(got: &[SweepOutcome], want: &[SweepOutcome], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: outcome count");
    for (g, w) in got.iter().zip(want) {
        assert_eq!(g.job.design.name(), w.job.design.name(), "{ctx}: job order");
        match (g.result(), w.result()) {
            (Some(gr), Some(wr)) => {
                // Full equality including the accumulation-order-
                // sensitive sum_red, plus the exact f64 bit pattern.
                assert_eq!(gr.stats, wr.stats, "{ctx}: {}", g.job.design.name());
                assert_eq!(
                    gr.stats.sum_red.to_bits(),
                    wr.stats.sum_red.to_bits(),
                    "{ctx}: sum_red bits for {}",
                    g.job.design.name()
                );
                assert_eq!(gr.batches, wr.batches, "{ctx}: {}", g.job.design.name());
            }
            (None, None) => {} // both analytic
            _ => panic!("{ctx}: answer source diverged for {}", g.job.design.name()),
        }
    }
}

/// A sweep preempted between jobs: the committed prefix answers from the
/// store and the remainder evaluates fresh, for every worker count, with
/// bytes identical to an uninterrupted no-store run.
#[test]
fn resume_between_jobs_bit_identical_across_worker_counts() {
    let grid = mc_grid();
    let jobs = grid.jobs();
    assert!(jobs.len() >= 4, "grid too small to interrupt meaningfully");
    let mut reference = SweepRunner::new(cpu_factory(), 2).unwrap();
    let want = reference.run_grid(&grid, |_, _, _| {}).unwrap();

    for workers in WORKER_COUNTS {
        let dir = tmp_store(&format!("between-{workers}"));
        // The victim evaluates only a prefix of the grid, then "dies".
        let cut = jobs.len() / 2;
        let mut victim = SweepRunner::new(cpu_factory(), workers).unwrap();
        victim.set_store(ResultStore::open(&dir).unwrap());
        victim.run_jobs(&jobs[..cut], |_, _, _| {}).unwrap();
        let committed = victim.jobs_evaluated;
        assert!(committed > 0);
        drop(victim);

        // A fresh process resumes the full grid against the same store.
        let mut resumed = SweepRunner::new(cpu_factory(), workers).unwrap();
        resumed.set_store(ResultStore::open(&dir).unwrap());
        let got = resumed.run_jobs(&jobs, |_, _, _| {}).unwrap();
        assert_eq!(resumed.store_hits, committed, "workers={workers}");
        assert_eq!(
            resumed.jobs_evaluated + resumed.store_hits + resumed.cache_hits,
            jobs.len() as u64,
            "workers={workers}"
        );
        assert_outcomes_bit_identical(&got, &want, &format!("workers={workers}"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// A sweep preempted mid-job: the journal holds a strict prefix of the
/// job's chunks, and the resumed run folds that prefix through the same
/// ordered merge, so the result is bit-identical from any cut point at
/// any worker count.
#[test]
fn resume_mid_job_from_journal_prefix_bit_identical() {
    let job = EvalJob::mc(8, 3, true, 500_000, 42);
    // Capture the job's per-chunk stats in merge order.
    let capture = SweepRunner::new(cpu_factory(), 2).unwrap();
    let mut chunks: Vec<(u64, ErrorStats)> = Vec::new();
    let mut sink = |id: u64, s: &ErrorStats| chunks.push((id, s.clone()));
    let want = capture
        .pool()
        .run_job_checkpointed(&job, &[], &mut |_| {}, Some(&mut sink))
        .unwrap();
    let batch = capture.pool().batch();
    assert!(chunks.len() >= 4, "need several chunks to cut between");
    assert!(chunks.iter().enumerate().all(|(i, (id, _))| *id == i as u64));

    for workers in WORKER_COUNTS {
        for cut in [1, chunks.len() / 2, chunks.len() - 1] {
            let dir = tmp_store(&format!("midjob-{workers}-{cut}"));
            let store = ResultStore::open(&dir).unwrap();
            let skey = StoreKey::new(&job, "cpu", batch);
            let mut writer = store.journal_writer(&skey, 0).unwrap();
            for (id, stats) in &chunks[..cut] {
                writer.append(*id, stats);
            }
            drop(writer);

            let mut resumed = SweepRunner::new(cpu_factory(), workers).unwrap();
            resumed.set_store(store);
            let got = resumed.run_jobs(std::slice::from_ref(&job), |_, _, _| {}).unwrap();
            assert_eq!(resumed.store_recoveries, 1, "workers={workers} cut={cut}");
            assert_eq!(resumed.jobs_evaluated, 1, "workers={workers} cut={cut}");
            let result = got[0].result().unwrap();
            assert_eq!(result.stats, want.stats, "workers={workers} cut={cut}");
            assert_eq!(
                result.stats.sum_red.to_bits(),
                want.stats.sum_red.to_bits(),
                "workers={workers} cut={cut}"
            );
            assert_eq!(result.batches, want.batches, "workers={workers} cut={cut}");
            // The resumed run committed the blob; the journal is gone.
            let reread = resumed.store().unwrap().load(&skey).unwrap().expect("committed blob");
            assert_eq!(reread.stats, want.stats);
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
}

/// The analytic answer source composes with the store: closed-form
/// answers never touch the disk, simulated ones round-trip through it,
/// and a resumed `--analytic auto` sweep is bit-identical.
#[test]
fn resume_with_analytic_auto_bit_identical() {
    let grid = SweepGrid {
        bitwidths: vec![8],
        designs: DesignSet::All,
        exhaustive_max_n: 12,
        force_mc: true,
        mc_samples: 200_000,
        seed: 0x5EED,
    };
    let mut reference = SweepRunner::new(cpu_factory(), 2).unwrap();
    reference.set_analytic_mode(AnalyticMode::Auto);
    let want = reference.run_grid(&grid, |_, _, _| {}).unwrap();
    assert!(reference.analytic_answers > 0, "grid must exercise the analytic source");
    assert!(reference.jobs_evaluated > 0, "grid must exercise the pool");

    let dir = tmp_store("analytic");
    let mut first = SweepRunner::new(cpu_factory(), 2).unwrap();
    first.set_analytic_mode(AnalyticMode::Auto);
    first.set_store(ResultStore::open(&dir).unwrap());
    first.run_grid(&grid, |_, _, _| {}).unwrap();
    let committed = first.jobs_evaluated;
    drop(first);

    for workers in WORKER_COUNTS {
        let mut resumed = SweepRunner::new(cpu_factory(), workers).unwrap();
        resumed.set_analytic_mode(AnalyticMode::Auto);
        resumed.set_store(ResultStore::open(&dir).unwrap());
        let got = resumed.run_grid(&grid, |_, _, _| {}).unwrap();
        assert_eq!(resumed.jobs_evaluated, 0, "workers={workers}: store must answer");
        assert_eq!(resumed.store_hits, committed, "workers={workers}");
        assert_eq!(resumed.analytic_answers, reference.analytic_answers, "workers={workers}");
        assert_outcomes_bit_identical(&got, &want, &format!("analytic workers={workers}"));
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Two shard "processes" sharing one store evaluate disjoint halves of
/// the grid with zero duplicate backend work, and a merge pass over the
/// full grid answers entirely from the store, bit-identical to a
/// single-process run.
#[test]
fn sharded_runs_merge_to_single_process_results_with_zero_duplicates() {
    // Counting backend: every eval_batch call is recorded.
    struct Counting {
        inner: CpuBackend,
        calls: Arc<AtomicUsize>,
    }
    impl EvalBackend for Counting {
        fn name(&self) -> &'static str {
            "cpu" // present as cpu so store keys match across runners
        }
        fn max_batch(&self) -> usize {
            self.inner.max_batch()
        }
        fn supports(&self, n: u32) -> bool {
            self.inner.supports(n)
        }
        fn eval_batch(&mut self, n: u32, t: u32, fix: bool, a: &[u64], b: &[u64]) -> Result<ErrorStats> {
            self.calls.fetch_add(1, Ordering::Relaxed);
            self.inner.eval_batch(n, t, fix, a, b)
        }
    }
    let counting_factory = |calls: &Arc<AtomicUsize>| {
        let calls = calls.clone();
        move || {
            Ok(Box::new(Counting { inner: CpuBackend::new(), calls: calls.clone() })
                as Box<dyn EvalBackend>)
        }
    };

    let grid = mc_grid();
    let jobs = grid.jobs();
    let single_calls = Arc::new(AtomicUsize::new(0));
    let mut single = SweepRunner::new(counting_factory(&single_calls), 2).unwrap();
    let want = single.run_jobs(&jobs, |_, _, _| {}).unwrap();
    let single_evals = single.jobs_evaluated;

    let dir = tmp_store("shards");
    let sharded_calls = Arc::new(AtomicUsize::new(0));
    let mut evals_by_shard = Vec::new();
    for index in 0..2 {
        let shard = Shard { index, count: 2 };
        let mine = shard.select(&jobs);
        assert!(!mine.is_empty(), "shard {index} owns no jobs");
        let mut runner = SweepRunner::new(counting_factory(&sharded_calls), 2).unwrap();
        runner.set_store(ResultStore::open(&dir).unwrap());
        runner.run_jobs(&mine, |_, _, _| {}).unwrap();
        assert_eq!(runner.store_hits, 0, "shards are disjoint: no cross-shard hits expected");
        evals_by_shard.push(runner.jobs_evaluated);
    }
    assert_eq!(
        evals_by_shard.iter().sum::<u64>(),
        single_evals,
        "shards must evaluate exactly the single-process set, no duplicates"
    );
    assert_eq!(
        sharded_calls.load(Ordering::Relaxed),
        single_calls.load(Ordering::Relaxed),
        "duplicate backend batches across shards"
    );

    // Merge pass: the full grid from the shared store, zero evaluations.
    let merge_calls = Arc::new(AtomicUsize::new(0));
    let mut merge = SweepRunner::new(counting_factory(&merge_calls), 2).unwrap();
    merge.set_store(ResultStore::open(&dir).unwrap());
    let got = merge.run_jobs(&jobs, |_, _, _| {}).unwrap();
    assert_eq!(merge.jobs_evaluated, 0, "merge must be pure store hits");
    assert_eq!(merge.store_hits, single_evals);
    assert_eq!(merge_calls.load(Ordering::Relaxed), 0);
    assert_outcomes_bit_identical(&got, &want, "sharded merge");
    let _ = std::fs::remove_dir_all(&dir);
}
