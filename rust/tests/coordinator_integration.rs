//! Coordinator integration: PJRT backend ≡ CPU backend on identical jobs,
//! service end-to-end over the compiled artifacts, telemetry sanity.
//! PJRT parts skip gracefully when `make artifacts` has not run.

use std::path::PathBuf;

use segmul::coordinator::{run_job, CpuBackend, EvalBackend, EvalJob, EvalService, PjrtBackend, WorkSpec};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts/");
        None
    }
}

#[test]
fn pjrt_and_cpu_backends_agree_exactly() {
    let Some(dir) = artifacts_dir() else { return };
    let mut pjrt = PjrtBackend::load(&dir).unwrap();
    let mut cpu = CpuBackend::new();
    // Same MC spec on both: identical chunk decomposition requires equal
    // max_batch, so drive both through explicit batches instead.
    use segmul::util::rng::Xoshiro256;
    let mut rng = Xoshiro256::seed_from_u64(99);
    for (n, t, fix) in [(8u32, 3u32, true), (16, 8, false), (32, 16, true)] {
        let len = pjrt.max_batch();
        let a: Vec<u64> = (0..len).map(|_| rng.next_bits(n)).collect();
        let b: Vec<u64> = (0..len).map(|_| rng.next_bits(n)).collect();
        let sp = pjrt.eval_batch(n, t, fix, &a, &b).unwrap();
        let sc = cpu.eval_batch(n, t, fix, &a, &b).unwrap();
        assert_eq!(sp.count, sc.count);
        assert_eq!(sp.err_count, sc.err_count, "n={n} t={t}");
        // PJRT sums are f64 on-device (approx_sums): exact below 2^53,
        // else within f64 rounding of the exact integer sums.
        assert!(sp.approx_sums && !sc.approx_sums);
        let rel = |a: f64, b: f64| (a - b).abs() / b.abs().max(1.0);
        assert!(rel(sp.sum_ed as f64, sc.sum_ed as f64) < 1e-12, "n={n} sum_ed");
        assert!(rel(sp.sum_abs_ed as f64, sc.sum_abs_ed as f64) < 1e-12, "n={n} sum_abs");
        assert_eq!(sp.max_abs_ed, sc.max_abs_ed);
        assert_eq!(sp.bitflips, sc.bitflips);
        assert!((sp.sum_red - sc.sum_red).abs() <= 1e-6 * sc.sum_red.max(1.0));
    }
}

#[test]
fn pjrt_padding_correction() {
    let Some(dir) = artifacts_dir() else { return };
    let mut pjrt = PjrtBackend::load(&dir).unwrap();
    let a = vec![3u64; 100];
    let b = vec![7u64; 100];
    let s = pjrt.eval_batch(8, 4, true, &a, &b).unwrap();
    assert_eq!(s.count, 100, "pad pairs must not inflate the sample count");
}

#[test]
fn service_with_pjrt_backend() {
    let Some(dir) = artifacts_dir() else { return };
    let svc = EvalService::start(move || {
        Ok(Box::new(PjrtBackend::load(&dir)?) as Box<dyn EvalBackend>)
    })
    .unwrap();
    let r = svc
        .eval(EvalJob::mc(16, 8, true, 1 << 17, 7))
        .unwrap();
    assert_eq!(r.backend, "pjrt");
    assert_eq!(r.stats.count, 1 << 17);
    assert!(r.metrics().unwrap().er > 0.0);
    let t = svc.telemetry();
    assert_eq!(t.jobs_completed, 1);
    assert_eq!(t.pairs_evaluated, 1 << 17);
    svc.shutdown();
}

#[test]
fn adaptive_job_on_pjrt() {
    let Some(dir) = artifacts_dir() else { return };
    let mut pjrt = PjrtBackend::load(&dir).unwrap();
    let job = EvalJob {
        design: segmul::multiplier::MultiplierSpec::Segmented { n: 16, t: 4, fix: false },
        spec: WorkSpec::Adaptive { max_samples: 1 << 22, seed: 3, target_rel_stderr: 0.02 },
    };
    let r = run_job(&mut pjrt, &job).unwrap();
    assert!(r.stats.count <= 1 << 22);
    assert!(r.stats.count >= 1 << 12);
}

#[test]
fn cpu_service_handles_job_burst() {
    let svc = EvalService::start(|| Ok(Box::new(CpuBackend::new()) as Box<dyn EvalBackend>)).unwrap();
    let tickets: Vec<_> = (0..8)
        .map(|i| svc.submit(EvalJob::mc(12, 1 + (i % 6), i % 2 == 0, 20_000, i as u64)))
        .collect();
    for t in tickets {
        assert!(t.wait().is_ok());
    }
    assert_eq!(svc.telemetry().jobs_completed, 8);
}
