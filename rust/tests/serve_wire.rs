//! Malformed-request battery: every hostile payload gets a typed 4xx
//! JSON error — the server never panics, never hangs, and stays healthy
//! for the next well-formed request.

use std::time::Duration;

use segmul::api::BackendChoice;
use segmul::serve::{client, ServeConfig, Server};
use segmul::util::json::Json;

fn boot() -> Server {
    Server::start(ServeConfig {
        workers: Some(2),
        backend: BackendChoice::Cpu,
        default_deadline: Duration::from_secs(60),
        ..ServeConfig::default()
    })
    .expect("server startup")
}

/// Assert a typed error response: expected status, JSON body with an
/// `error` object whose `status` echoes the HTTP status.
fn assert_typed_error(resp: &client::Response, status: u16, kind: &str) {
    assert_eq!(resp.status, status, "body: {}", resp.text());
    let err = resp
        .json()
        .unwrap_or_else(|_| panic!("error body is not JSON: {:?}", resp.text()));
    let err = err.get("error").expect("error object");
    assert_eq!(err.get("kind").and_then(Json::as_str), Some(kind));
    assert_eq!(err.get("status").and_then(Json::as_u64), Some(status as u64));
    assert!(err.get("detail").and_then(Json::as_str).is_some());
}

#[test]
fn malformed_requests_get_typed_4xx_and_the_server_survives() {
    let server = boot();
    let addr = server.addr();

    // --- wire-level garbage ------------------------------------------------
    let raw = |bytes: &[u8]| client::send_bytes(addr, bytes).unwrap();
    assert_typed_error(&raw(b""), 400, "serve");
    assert_typed_error(&raw(b"GET /healthz HT"), 400, "serve");
    assert_typed_error(&raw(b"NONSENSE\r\n\r\n"), 400, "serve");
    assert_typed_error(&raw(b"GET /healthz HTTP/3.0\r\n\r\n"), 400, "serve");
    assert_typed_error(&raw(b"GET healthz HTTP/1.1\r\n\r\n"), 400, "serve");
    assert_typed_error(
        &raw(b"POST /v1/eval HTTP/1.1\r\nContent-Length: nope\r\n\r\n"),
        400,
        "serve",
    );
    // Declared body larger than sent: truncated, typed 400.
    assert_typed_error(
        &raw(b"POST /v1/eval HTTP/1.1\r\nContent-Length: 50\r\n\r\n{}"),
        400,
        "serve",
    );
    // Oversized payload refused from the declared length alone (413).
    assert_typed_error(
        &raw(b"POST /v1/eval HTTP/1.1\r\nContent-Length: 99999999\r\n\r\n"),
        413,
        "serve",
    );
    // Chunked request bodies are not supported.
    assert_typed_error(
        &raw(b"POST /v1/eval HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n0\r\n\r\n"),
        400,
        "serve",
    );
    // Header bomb past max_head: typed 431.
    let mut bomb = b"GET /healthz HTTP/1.1\r\n".to_vec();
    bomb.extend(vec![b'a'; 9001]);
    assert_typed_error(&raw(&bomb), 431, "serve");
    // Pipelined garbage after a complete request is never interpreted
    // (Connection: close); the first request still answers.
    let pipelined =
        raw(b"GET /healthz HTTP/1.1\r\n\r\nGARBAGE MORE GARBAGE\r\nContent-Length: -1\r\n\r\n");
    assert_eq!(pipelined.status, 200, "{}", pipelined.text());

    // --- routing -----------------------------------------------------------
    assert_typed_error(&client::get(addr, "/nope").unwrap(), 404, "serve");
    assert_typed_error(&client::get(addr, "/v1/evaluate").unwrap(), 404, "serve");
    assert_typed_error(&client::get(addr, "/v1/eval").unwrap(), 405, "serve");
    assert_typed_error(&client::get(addr, "/v1/sweep").unwrap(), 405, "serve");
    assert_typed_error(
        &client::post_bytes(addr, "/healthz", b"{}").unwrap(),
        405,
        "serve",
    );
    assert_typed_error(
        &client::request(addr, "DELETE", "/metrics", None).unwrap(),
        405,
        "serve",
    );

    // --- body-level garbage on /v1/eval -------------------------------------
    let post = |body: &[u8]| client::post_bytes(addr, "/v1/eval", body).unwrap();
    assert_typed_error(&post(b"not json"), 400, "serve");
    assert_typed_error(&post(b"\xff\xfe\x00"), 400, "serve");
    assert_typed_error(&post(b"[1,2,3]"), 400, "serve");
    assert_typed_error(&post(b"{}"), 400, "serve");
    assert_typed_error(&post(br#"{"design": "segmented", "workload": {"kind":"exhaustive"}}"#), 400, "serve");
    assert_typed_error(
        &post(br#"{"design": {"family":"warp","n":8}, "workload": {"kind":"exhaustive"}}"#),
        400,
        "serve",
    );
    assert_typed_error(
        &post(br#"{"design": {"family":"accurate","n":8}, "workload": {"kind":"turbo"}}"#),
        400,
        "serve",
    );
    assert_typed_error(
        &post(br#"{"design": {"family":"accurate","n":8}, "workload": {"kind":"mc"}}"#),
        400,
        "serve",
    );
    assert_typed_error(
        &post(br#"{"design": {"family":"accurate","n":8}, "workload": {"kind":"mc","samples":-4}}"#),
        400,
        "serve",
    );
    // Domain validation keeps its own typed kinds (still 400).
    assert_typed_error(
        &post(br#"{"design": {"family":"segmented","n":8,"t":9,"fix":false}, "workload": {"kind":"exhaustive"}}"#),
        400,
        "spec",
    );
    assert_typed_error(
        &post(br#"{"design": {"family":"accurate","n":8}, "workload": {"kind":"mc","samples":0}}"#),
        400,
        "workload",
    );

    // --- body-level garbage on /v1/sweep ------------------------------------
    let sweep = |body: &[u8]| client::post_bytes(addr, "/v1/sweep", body).unwrap();
    assert_typed_error(&sweep(b"not json"), 400, "serve");
    assert_typed_error(&sweep(br#"{"bitwidths":[]}"#), 400, "serve");
    assert_typed_error(&sweep(br#"{"bitwidths":"wide"}"#), 400, "serve");
    assert_typed_error(&sweep(br#"{"mc":"yes"}"#), 400, "serve");
    assert_typed_error(&sweep(br#"{"designs":["paper"]}"#), 400, "serve");
    assert_typed_error(&sweep(br#"{"deadline_ms":"soon"}"#), 400, "serve");

    // --- the server is still healthy after the battery ----------------------
    let health = client::get(addr, "/healthz").unwrap();
    assert_eq!(health.status, 200);
    let eval = client::post_json(
        addr,
        "/v1/eval",
        &Json::parse(
            r#"{"design":{"family":"segmented","n":8,"t":2,"fix":true},
                "workload":{"kind":"mc","samples":20000,"seed":3}}"#,
        )
        .unwrap(),
    )
    .unwrap();
    assert_eq!(eval.status, 200, "{}", eval.text());

    let _ = client::post_json(addr, "/v1/shutdown", &Json::Obj(Default::default())).unwrap();
    let summary = server.join();
    assert_eq!(summary.telemetry.jobs_completed, 1, "garbage must never reach the engine");
    assert!(summary.requests_total >= 30);
}
