//! Differential + property validation of the `tune` autotuner.
//!
//! Property tier — `pareto_frontier` is cross-checked against an
//! independent brute-force implementation of the domination definition
//! on randomized small objective sets (ties, duplicates, and NaN
//! coordinates included): the returned mask must be exactly the set of
//! non-dominated NaN-free points, which makes it both mutually
//! non-dominated and complete.
//!
//! Differential tier — on the exact-model families (the related-work
//! baselines and the accurate reference at exhaustive bit-widths) a
//! tune answered entirely in closed form (`--analytic require`, zero
//! pool dispatches) must agree with the same tune answered by
//! store-backed simulation: same grid, same winner, same frontier
//! membership, per-point metrics bit-consistent. A second store-backed
//! run must answer every point from disk without re-evaluating.

use segmul::api::{AnalyticMode, DesignSet, Session};
use segmul::tune::{pareto_frontier, tune, Budget, TuneQuery, TuneResult};
use segmul::util::prop::Cases;

// ---------------------------------------------------------------------
// Property tier: pareto_frontier vs brute force
// ---------------------------------------------------------------------

/// The mathematical definition, written independently of the library
/// code: `a` dominates `b` iff `a` is NaN-free, `a ≤ b` in every
/// objective, and `a < b` in at least one.
fn brute_dominates(a: &[f64], b: &[f64]) -> bool {
    if a.iter().any(|v| v.is_nan()) {
        return false;
    }
    let mut strictly = false;
    for (x, y) in a.iter().zip(b) {
        if !(x <= y) && !y.is_nan() {
            return false;
        }
        if *x < *y {
            strictly = true;
        }
    }
    strictly
}

/// Brute-force frontier: every NaN-free point no other point dominates.
fn brute_frontier(objectives: &[Vec<f64>]) -> Vec<bool> {
    (0..objectives.len())
        .map(|i| {
            !objectives[i].iter().any(|v| v.is_nan())
                && !objectives
                    .iter()
                    .enumerate()
                    .any(|(j, b)| j != i && brute_dominates(b, &objectives[i]))
        })
        .collect()
}

#[test]
fn frontier_matches_brute_force_on_random_sets() {
    Cases::new(0x7A_0E70, 400).run(|rng, _| {
        let n_points = rng.next_below(13) as usize;
        let dims = 1 + rng.next_below(4) as usize;
        // Coordinates from a small discrete set force ties and exact
        // duplicates; a sprinkling of NaN exercises the disqualification
        // rule on both sides of the comparison.
        let objectives: Vec<Vec<f64>> = (0..n_points)
            .map(|_| {
                (0..dims)
                    .map(|_| {
                        if rng.next_below(8) == 0 {
                            f64::NAN
                        } else {
                            rng.next_below(4) as f64
                        }
                    })
                    .collect()
            })
            .collect();

        let mask = pareto_frontier(&objectives);
        assert_eq!(mask, brute_frontier(&objectives), "objectives: {objectives:?}");

        // Mutual non-domination within the returned frontier.
        for (i, a) in objectives.iter().enumerate() {
            for (j, b) in objectives.iter().enumerate() {
                if i != j && mask[i] && mask[j] {
                    assert!(
                        !brute_dominates(a, b),
                        "frontier point {a:?} dominates frontier point {b:?}"
                    );
                }
            }
        }
        // Completeness: every non-dominated NaN-free input is kept.
        for (i, a) in objectives.iter().enumerate() {
            let nan_free = !a.iter().any(|v| v.is_nan());
            let undominated = !objectives
                .iter()
                .enumerate()
                .any(|(j, b)| j != i && brute_dominates(b, a));
            if nan_free && undominated {
                assert!(mask[i], "non-dominated point {a:?} dropped from the frontier");
            }
        }
    });
}

#[test]
fn frontier_edge_cases() {
    // Empty input, exact duplicates (both kept), and an all-NaN point.
    assert!(pareto_frontier(&[]).is_empty());
    let twins = vec![vec![1.0, 2.0], vec![1.0, 2.0], vec![2.0, 3.0]];
    assert_eq!(pareto_frontier(&twins), vec![true, true, false]);
    assert_eq!(pareto_frontier(&[vec![f64::NAN]]), vec![false]);
    // A NaN point must not eliminate a finite one it "beats" elsewhere.
    let mixed = vec![vec![0.0, f64::NAN], vec![5.0, 5.0]];
    assert_eq!(pareto_frontier(&mixed), vec![false, true]);
}

// ---------------------------------------------------------------------
// Differential tier: analytic require vs store-backed simulation
// ---------------------------------------------------------------------

fn rel_err(a: f64, b: f64) -> f64 {
    if b == 0.0 {
        a.abs()
    } else {
        (a - b).abs() / b.abs()
    }
}

/// The two answer paths must describe the same grid identically: the
/// exact models make per-point metrics bit-consistent, so feasibility,
/// frontier membership, and the winning spec all coincide.
fn assert_tunes_agree(ana: &TuneResult, sim: &TuneResult) {
    assert_eq!(ana.points.len(), sim.points.len());
    for (a, s) in ana.points.iter().zip(&sim.points) {
        let name = a.spec.name();
        assert_eq!(a.spec, s.spec, "grid order diverged");
        assert!(
            (a.metrics.er - s.metrics.er).abs() < 1e-12,
            "{name}: ER {} vs {}",
            a.metrics.er,
            s.metrics.er
        );
        assert!(
            (a.metrics.med_abs - s.metrics.med_abs).abs() < 1e-6 * (1.0 + s.metrics.med_abs),
            "{name}: MED {} vs {}",
            a.metrics.med_abs,
            s.metrics.med_abs
        );
        assert_eq!(a.metrics.mae, s.metrics.mae, "{name}: WCE");
        assert!(
            rel_err(a.metrics.mred, s.metrics.mred) < 1e-5,
            "{name}: MRED {} vs {}",
            a.metrics.mred,
            s.metrics.mred
        );
        assert_eq!(a.feasible, s.feasible, "{name}: feasibility flipped");
        assert_eq!(a.frontier, s.frontier, "{name}: frontier membership flipped");
        assert_eq!(a.hw.is_some(), s.hw.is_some(), "{name}: technology join diverged");
    }
    assert_eq!(
        ana.winner().map(|p| p.spec),
        sim.winner().map(|p| p.spec),
        "the two answer paths crowned different winners"
    );
}

#[test]
fn analytic_tune_matches_store_backed_simulation_on_exact_families() {
    let dir = std::env::temp_dir()
        .join(format!("segmul-tune-diff-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    for designs in [DesignSet::Baselines, DesignSet::Accurate] {
        // A budget wide enough that the exact families stay feasible on
        // both paths with margin (no threshold within 1e-6 of a value).
        let query = TuneQuery::new(Budget::mred(0.5))
            .bitwidths(vec![4, 8])
            .designs(designs)
            .hw_vectors(64);

        let mut fast = Session::builder()
            .workers(1)
            .analytic(AnalyticMode::Require)
            .build()
            .unwrap();
        let ana = tune(&mut fast, &query).unwrap();
        assert_eq!(ana.jobs_evaluated, 0, "require mode must not dispatch the pool");
        assert_eq!(ana.analytic_answers, ana.points.len() as u64);
        assert!(ana.winner().is_some(), "{}: wide budget must admit a winner", designs.name());

        let mut stored = Session::builder()
            .workers(2)
            .store(&dir)
            .build()
            .unwrap();
        let sim = tune(&mut stored, &query).unwrap();
        assert_eq!(sim.analytic_answers, 0);
        assert_eq!(sim.jobs_evaluated, sim.points.len() as u64, "cold store evaluates everything");

        assert_tunes_agree(&ana, &sim);

        // Warm pass in a fresh process-independent session: every answer
        // comes off disk, nothing is re-evaluated, and the result is
        // unchanged — the ladder's "slower, never wrong" contract.
        let mut warm = Session::builder()
            .workers(2)
            .store(&dir)
            .build()
            .unwrap();
        let replay = tune(&mut warm, &query).unwrap();
        assert_eq!(replay.jobs_evaluated, 0, "warm store must answer without the pool");
        assert_eq!(replay.store_hits, replay.points.len() as u64);
        assert_tunes_agree(&sim, &replay);
    }

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn tight_budget_agrees_across_answer_paths() {
    // Near the threshold the two paths must still agree on which points
    // pass: the exact models differ by < 1e-12, far inside the margin
    // between any baseline's MRED and this cutoff.
    let query = TuneQuery::new(Budget::parse("mred<=1e-2").unwrap())
        .bitwidths(vec![8])
        .designs(DesignSet::Baselines)
        .hw_vectors(64);
    let mut fast = Session::builder()
        .workers(1)
        .analytic(AnalyticMode::Require)
        .build()
        .unwrap();
    let mut slow = Session::builder().workers(2).build().unwrap();
    let ana = tune(&mut fast, &query).unwrap();
    let sim = tune(&mut slow, &query).unwrap();
    assert_eq!(ana.feasible_count(), sim.feasible_count());
    assert_tunes_agree(&ana, &sim);
}
