//! Differential test harness for the batched evaluation engine.
//!
//! Four independent implementations of the segmented-carry sequential
//! multiplier must agree bit-for-bit wherever they overlap:
//!
//! * the batched word-level kernel (`approx_seq_mul_batch`, the hot path),
//! * the scalar word-level fast path (`approx_seq_mul`),
//! * the bit-level `Ŝ/Ĉ` Boolean recurrences (`approx_seq_mul_bitlevel`,
//!   the paper-equation oracle),
//! * the gate-level netlist simulated cycle-accurately (`seq_mult` +
//!   `run_batch`).
//!
//! Sweeps are randomized over `(n, t, fix, a, b)` for n ∈ {4, 8, 16, 32}
//! with seeded `Xoshiro256` streams (`util::prop::Cases`), so every
//! failure replays from its printed seed. The second half of the file
//! pins the merge semantics of the batched engine: partial `ErrorStats`
//! from arbitrary chunkings (1, 3, 7, 64 workers / pieces) fold bit-exactly
//! to the sequential result.

use segmul::coordinator::{CpuBackend, EvalBackend};
use segmul::error::exhaustive::{exhaustive_stats, exhaustive_stats_batch, exhaustive_stats_workers};
use segmul::error::metrics::ErrorStats;
use segmul::error::stream::{BatchAccumulator, BLOCK};
use segmul::multiplier::batch::approx_seq_mul_batch;
use segmul::multiplier::wordlevel::{approx_seq_mul, approx_seq_mul_generic};
use segmul::multiplier::{
    approx_seq_mul_bitlevel, BatchMultiplier, DispatchClass, MultiplierSpec, SegmentedSeqMul, U512,
};
use segmul::netlist::generators::seq_mult::{run_batch, seq_mult};
use segmul::netlist::SeqSim;
use segmul::util::prop::Cases;
use segmul::util::rng::Xoshiro256;

const WIDTHS: [u32; 4] = [4, 8, 16, 32];

/// Batched kernel ≡ scalar fast path ≡ scalar generic loop ≡ bit-level
/// oracle, randomized over the full configuration space.
#[test]
fn batched_equals_scalar_and_bitlevel_oracle() {
    for &n in &WIDTHS {
        Cases::new(0xD1FF ^ n as u64, 40).run(|rng, _| {
            let t = rng.next_below(n as u64) as u32;
            let fix = rng.next_bits(1) == 1;
            let len = 1 + rng.next_below(96) as usize;
            let a: Vec<u64> = (0..len).map(|_| rng.next_bits(n)).collect();
            let b: Vec<u64> = (0..len).map(|_| rng.next_bits(n)).collect();
            let mut batched = vec![0u64; len];
            approx_seq_mul_batch(&a, &b, &mut batched, n, t, fix);
            for i in 0..len {
                let scalar = approx_seq_mul(a[i], b[i], n, t, fix);
                let generic = approx_seq_mul_generic(a[i], b[i], n, t, fix);
                let oracle = approx_seq_mul_bitlevel(a[i], b[i], n, t, fix);
                assert_eq!(batched[i], scalar, "batch!=scalar n={n} t={t} fix={fix} a={} b={}", a[i], b[i]);
                assert_eq!(batched[i], generic, "batch!=generic n={n} t={t} fix={fix} a={} b={}", a[i], b[i]);
                assert_eq!(batched[i], oracle, "batch!=bitlevel n={n} t={t} fix={fix} a={} b={}", a[i], b[i]);
            }
        });
    }
}

/// Batched kernel ≡ gate-level netlist simulation, over randomized
/// operands for each width (the netlist is cycle-accurate, so one circuit
/// per configuration and 64-lane batches keep this fast even at n = 32).
#[test]
fn batched_equals_netlist_simulation() {
    for &(n, t) in &[(4u32, 2u32), (8, 3), (8, 4), (16, 8), (32, 13)] {
        let circuit = seq_mult(n, t, t >= 1);
        let mut sim = SeqSim::new(&circuit.nl);
        for fix in [false, true] {
            let run_fix = fix && t >= 1;
            let mut rng = Xoshiro256::stream(0x9E71, (n as u64) << 8 | t as u64);
            let a: Vec<u64> = (0..64).map(|_| rng.next_bits(n)).collect();
            let b: Vec<u64> = (0..64).map(|_| rng.next_bits(n)).collect();
            let av: Vec<U512> = a.iter().map(|&x| U512::from_u64(x)).collect();
            let bv: Vec<U512> = b.iter().map(|&x| U512::from_u64(x)).collect();
            let gate = run_batch(&circuit, &mut sim, &av, &bv, run_fix);
            let mut batched = vec![0u64; a.len()];
            approx_seq_mul_batch(&a, &b, &mut batched, n, t, run_fix);
            for i in 0..a.len() {
                assert_eq!(
                    gate[i].limb(0),
                    batched[i],
                    "gate!=batch n={n} t={t} fix={run_fix} a={} b={}",
                    a[i],
                    b[i]
                );
            }
        }
    }
}

/// The batched exhaustive evaluator ≡ a naive per-pair double loop over
/// the full space (small n, every t and fix).
#[test]
fn batched_exhaustive_equals_naive_double_loop() {
    for n in [4u32, 5, 6] {
        for t in 0..n {
            for fix in [false, true] {
                let mut naive = ErrorStats::new(n);
                for a in 0..(1u64 << n) {
                    for b in 0..(1u64 << n) {
                        naive.record(a * b, approx_seq_mul(a, b, n, t, fix));
                    }
                }
                let batched = exhaustive_stats(n, t, fix);
                assert!(batched.approx_eq(&naive), "n={n} t={t} fix={fix}");
            }
        }
    }
}

/// Chunking invariance of the batched exhaustive path: 1, 3, 7 and 64
/// workers must fold to the same statistics (integer fields bit-exact).
#[test]
fn exhaustive_chunking_invariant_1_3_7_64() {
    let (n, t, fix) = (8u32, 4u32, true);
    let w1 = exhaustive_stats_workers(n, t, fix, 1);
    for workers in [3usize, 7, 64] {
        let w = exhaustive_stats_workers(n, t, fix, workers);
        assert!(w1.approx_eq(&w), "workers={workers}");
        // approx_eq already pins every integer field; make the intent
        // explicit for the batched path:
        assert_eq!(w1.count, w.count);
        assert_eq!(w1.err_count, w.err_count);
        assert_eq!(w1.sum_ed, w.sum_ed);
        assert_eq!(w1.sum_abs_ed, w.sum_abs_ed);
        assert_eq!(w1.max_abs_ed, w.max_abs_ed);
        assert_eq!(w1.bitflips, w.bitflips);
    }
}

/// Folding partial `ErrorStats` from arbitrary stream chunkings (1, 3, 7,
/// 64 pieces, ragged sizes) is bit-exact versus the sequential fold —
/// identical order per piece means even the f64 `sum_red` matches exactly.
#[test]
fn record_batch_partials_merge_exactly_any_chunking() {
    let n = 8u32;
    let mut rng = Xoshiro256::seed_from_u64(0xC47);
    let len = 10_000usize;
    let exact: Vec<u64> = (0..len).map(|_| rng.next_bits(16)).collect();
    let approx: Vec<u64> = exact
        .iter()
        .map(|&p| if rng.next_bits(2) == 0 { p } else { rng.next_bits(16) })
        .collect();

    let mut sequential = ErrorStats::new(n);
    sequential.record_batch(&exact, &approx);

    for pieces in [1usize, 3, 7, 64] {
        let piece_len = len.div_ceil(pieces);
        let mut folded: Option<ErrorStats> = None;
        for (ce, ca) in exact.chunks(piece_len).zip(approx.chunks(piece_len)) {
            let mut part = ErrorStats::new(n);
            part.record_batch(ce, ca);
            folded = Some(match folded {
                None => part,
                Some(mut acc) => {
                    acc.merge(&part);
                    acc
                }
            });
        }
        let folded = folded.unwrap();
        // Integer fields are bit-exact under any chunking; sum_red is f64
        // and merging re-associates its additions, so it is compared up to
        // accumulation-order noise (approx_eq).
        assert_eq!(folded.count, sequential.count, "pieces={pieces}");
        assert_eq!(folded.err_count, sequential.err_count, "pieces={pieces}");
        assert_eq!(folded.sum_ed, sequential.sum_ed, "pieces={pieces}");
        assert_eq!(folded.sum_abs_ed, sequential.sum_abs_ed, "pieces={pieces}");
        assert_eq!(folded.max_abs_ed, sequential.max_abs_ed, "pieces={pieces}");
        assert_eq!(folded.bitflips, sequential.bitflips, "pieces={pieces}");
        assert!(folded.approx_eq(&sequential), "pieces={pieces}");
    }
}

/// The BatchAccumulator over split index ranges ≡ one accumulator over
/// the whole range, for ragged splits around the internal BLOCK size.
#[test]
fn accumulator_split_ranges_fold_exactly() {
    let (n, t, fix) = (7u32, 3u32, true);
    let m = SegmentedSeqMul::new(n, t, fix);
    let total = 1u64 << (2 * n);
    let mut whole = BatchAccumulator::new(&m);
    whole.eval_index_range(0, total);
    let whole = whole.finish();

    let cuts = [0u64, 1, BLOCK as u64 - 1, BLOCK as u64 + 7, total / 2, total];
    let mut folded = ErrorStats::new(n);
    for w in cuts.windows(2) {
        let mut part = BatchAccumulator::new(&m);
        part.eval_index_range(w[0], w[1]);
        folded.merge(&part.finish());
    }
    // Integer fields bit-exact; sum_red up to merge re-association noise.
    assert_eq!(folded.count, whole.count);
    assert_eq!(folded.err_count, whole.err_count);
    assert_eq!(folded.sum_ed, whole.sum_ed);
    assert_eq!(folded.sum_abs_ed, whole.sum_abs_ed);
    assert_eq!(folded.max_abs_ed, whole.max_abs_ed);
    assert_eq!(folded.bitflips, whole.bitflips);
    assert!(folded.approx_eq(&whole));
}

/// The coordinator's CPU backend is a thin wrapper over the same batched
/// kernels: identical statistics to the direct engine, floats included.
#[test]
fn cpu_backend_is_thin_wrapper_over_batch_kernels() {
    let (n, t, fix) = (8u32, 3u32, true);
    let mut be = CpuBackend::new();
    let mut rng = Xoshiro256::seed_from_u64(0xBE);
    let a: Vec<u64> = (0..2000).map(|_| rng.next_bits(n)).collect();
    let b: Vec<u64> = (0..2000).map(|_| rng.next_bits(n)).collect();
    let got = be.eval_batch(n, t, fix, &a, &b).unwrap();
    let m = SegmentedSeqMul::new(n, t, fix);
    let mut want = BatchAccumulator::new(&m);
    want.eval_pairs(&a, &b);
    assert_eq!(got, want.finish());
}

/// exhaustive_stats_batch with the paper's multiplier as a BatchMultiplier
/// trait object agrees with the monomorphized entry point across widths
/// that are exhaustively tractable.
#[test]
fn trait_object_batch_path_matches_specialized() {
    for (n, t, fix) in [(4u32, 2u32, false), (8, 4, true)] {
        let m = SegmentedSeqMul::new(n, t, fix);
        let via_obj = exhaustive_stats_batch(&m, 2);
        let direct = exhaustive_stats(n, t, fix);
        assert!(via_obj.approx_eq(&direct), "n={n} t={t} fix={fix}");
    }
}

/// The design points the cross-registry differential tests sweep: every
/// registry family, plus extra parameter points so each baseline kernel's
/// configuration axes (truncation column, both break-line orders, fix
/// modes) are exercised — not just the registry examples.
fn differential_specs(n: u32) -> Vec<MultiplierSpec> {
    let mut specs = MultiplierSpec::registry_examples(n);
    specs.push(MultiplierSpec::Segmented { n, t: 1, fix: false });
    specs.push(MultiplierSpec::Truncated { n, k: n / 2 });
    specs.push(MultiplierSpec::Truncated { n, k: n });
    specs.push(MultiplierSpec::BrokenArray { n, hbl: n / 2, vbl: n / 4 });
    specs.push(MultiplierSpec::BitLevel { n, t: 1, fix: false });
    specs.push(MultiplierSpec::Netlist { n, t: n - 1, fix: false });
    specs
}

/// Every registry design's batch kernel ≡ its per-pair scalar reference,
/// exhaustively over the full 2^(2n) input space at n ∈ {4, 8}. This is
/// the contract that lets `OwnedScalarBatch` survive only as the
/// differential-test reference: the production evaluators are proven
/// bit-exact against it here.
#[test]
fn every_registry_design_batched_equals_scalar_exhaustive_small() {
    for n in [4u32, 8] {
        let space = 1u64 << (2 * n);
        let mask = (1u64 << n) - 1;
        let a: Vec<u64> = (0..space).map(|i| i & mask).collect();
        let b: Vec<u64> = (0..space).map(|i| i >> n).collect();
        for spec in differential_specs(n) {
            let batch = spec.build_batch().unwrap();
            let reference = spec.build_scalar_reference().unwrap();
            assert_eq!(batch.dispatch_class(), DispatchClass::Batched, "{}", spec.name());
            assert_eq!(reference.dispatch_class(), DispatchClass::Scalar, "{}", spec.name());
            let mut got = vec![0u64; a.len()];
            let mut want = vec![0u64; a.len()];
            batch.mul_batch(&a, &b, &mut got);
            reference.mul_batch(&a, &b, &mut want);
            for i in 0..a.len() {
                assert_eq!(got[i], want[i], "{} n={n} a={} b={}", spec.name(), a[i], b[i]);
            }
        }
    }
}

/// Monte-Carlo differential at n = 16 (exhaustive is 2^32 pairs): every
/// registry design, seeded random operands, batched ≡ scalar reference.
#[test]
fn every_registry_design_batched_equals_scalar_mc_n16() {
    let n = 16u32;
    let mut rng = Xoshiro256::seed_from_u64(0xD1FF16);
    let len = 4096usize;
    let a: Vec<u64> = (0..len).map(|_| rng.next_bits(n)).collect();
    let b: Vec<u64> = (0..len).map(|_| rng.next_bits(n)).collect();
    for spec in differential_specs(n) {
        let batch = spec.build_batch().unwrap();
        let reference = spec.build_scalar_reference().unwrap();
        let mut got = vec![0u64; len];
        let mut want = vec![0u64; len];
        batch.mul_batch(&a, &b, &mut got);
        reference.mul_batch(&a, &b, &mut want);
        for i in 0..len {
            assert_eq!(got[i], want[i], "{} a={} b={}", spec.name(), a[i], b[i]);
        }
    }
}

/// Chunked-merge bit-exactness through `error::stream` for the baseline
/// kernels: partial `ErrorStats` folded from ragged chunkings equal the
/// sequential accumulation on every integer field, for each design family
/// that gained a batch kernel in this layer. Also pins that the streaming
/// engine over the batch kernel produces *identical* stats — floats
/// included — to the same engine over the scalar reference (same products
/// in the same order).
#[test]
fn baseline_kernels_chunked_merge_bit_exact_through_stream() {
    let n = 8u32;
    let mut rng = Xoshiro256::seed_from_u64(0xBA5E);
    let len = 10_000usize;
    let a: Vec<u64> = (0..len).map(|_| rng.next_bits(n)).collect();
    let b: Vec<u64> = (0..len).map(|_| rng.next_bits(n)).collect();
    for spec in [
        MultiplierSpec::Truncated { n, k: 3 },
        MultiplierSpec::BrokenArray { n, hbl: 2, vbl: 4 },
        MultiplierSpec::Mitchell { n },
        MultiplierSpec::Kulkarni { n },
        MultiplierSpec::BitLevel { n, t: 4, fix: true },
    ] {
        let m = spec.build_batch().unwrap();
        let mut whole = BatchAccumulator::new(m.as_ref());
        whole.eval_pairs(&a, &b);
        let whole = whole.finish();

        // Streaming over the scalar reference: same order, same stats,
        // f64 fields included.
        let reference = spec.build_scalar_reference().unwrap();
        let mut via_scalar = BatchAccumulator::new(reference.as_ref());
        via_scalar.eval_pairs(&a, &b);
        assert_eq!(via_scalar.finish(), whole, "{}", spec.name());

        // Ragged chunkings fold bit-exactly on the integer fields.
        for pieces in [3usize, 7, 64] {
            let piece_len = len.div_ceil(pieces);
            let mut folded = ErrorStats::new(n);
            for (ca, cb) in a.chunks(piece_len).zip(b.chunks(piece_len)) {
                let mut part = BatchAccumulator::new(m.as_ref());
                part.eval_pairs(ca, cb);
                folded.merge(&part.finish());
            }
            assert_eq!(folded.count, whole.count, "{} pieces={pieces}", spec.name());
            assert_eq!(folded.err_count, whole.err_count, "{} pieces={pieces}", spec.name());
            assert_eq!(folded.sum_ed, whole.sum_ed, "{} pieces={pieces}", spec.name());
            assert_eq!(folded.sum_abs_ed, whole.sum_abs_ed, "{} pieces={pieces}", spec.name());
            assert_eq!(folded.max_abs_ed, whole.max_abs_ed, "{} pieces={pieces}", spec.name());
            assert_eq!(folded.bitflips, whole.bitflips, "{} pieces={pieces}", spec.name());
            assert!(folded.approx_eq(&whole), "{} pieces={pieces}", spec.name());
        }
    }
}

/// The CPU backend evaluates every design of a cross-design grid on a
/// true batch kernel and says so: zero scalar-fallback dispatches outside
/// the differential-test references.
#[test]
fn cpu_backend_cross_design_dispatch_is_fully_batched() {
    use segmul::multiplier::DesignSet;
    let mut be = CpuBackend::new();
    let mut rng = Xoshiro256::seed_from_u64(0xA11);
    let a: Vec<u64> = (0..500).map(|_| rng.next_bits(4)).collect();
    let b: Vec<u64> = (0..500).map(|_| rng.next_bits(4)).collect();
    for spec in DesignSet::All.specs(4) {
        be.eval_design(&spec, &a, &b).unwrap();
    }
    let log = be.kernel_dispatch();
    assert!(!log.is_empty());
    for (name, class) in &log {
        assert_eq!(*class, DispatchClass::Batched, "{name} regressed to per-pair dispatch");
    }
}
