//! Loopback smoke for `segmul serve`: every endpoint answers, coalesced
//! bursts share pool dispatches, and a served eval is bit-identical to
//! the same job run directly through an [`api::Session`].

use std::time::Duration;

use segmul::api::{BackendChoice, EvalJob, Session};
use segmul::serve::metrics::metric_value;
use segmul::serve::{client, ServeConfig, Server};
use segmul::util::json::Json;

fn boot() -> Server {
    Server::start(ServeConfig {
        workers: Some(2),
        backend: BackendChoice::Cpu,
        default_deadline: Duration::from_secs(60),
        ..ServeConfig::default()
    })
    .expect("server startup")
}

fn eval_body(samples: u64, seed: u64) -> Json {
    Json::parse(&format!(
        r#"{{"design":{{"family":"segmented","n":8,"t":3,"fix":true}},
            "workload":{{"kind":"mc","samples":{samples},"seed":{seed}}}}}"#
    ))
    .unwrap()
}

#[test]
fn every_endpoint_answers() {
    let server = boot();
    let addr = server.addr();

    let health = client::get(addr, "/healthz").unwrap();
    assert_eq!(health.status, 200);
    let body = health.json().unwrap();
    assert_eq!(body.get("status").and_then(Json::as_str), Some("ok"));
    assert_eq!(body.get("backend").and_then(Json::as_str), Some("cpu"));

    let designs = client::get(addr, "/v1/designs").unwrap();
    assert_eq!(designs.status, 200);
    let rows = match designs.json().unwrap().get("designs") {
        Some(Json::Arr(rows)) => rows.clone(),
        other => panic!("expected designs array, got {other:?}"),
    };
    assert!(!rows.is_empty(), "registry must expose example designs");
    for row in &rows {
        assert!(row.get("design").is_some() && row.get("name").is_some());
    }
    assert!(
        rows.iter().any(|r| r.get("family").and_then(Json::as_str) == Some("segmented")),
        "paper family missing from /v1/designs"
    );

    let eval = client::post_json(addr, "/v1/eval", &eval_body(40_000, 11)).unwrap();
    assert_eq!(eval.status, 200, "{}", eval.text());
    let row = eval.json().unwrap();
    assert_eq!(row.get("backend").and_then(Json::as_str), Some("cpu"));
    assert_eq!(row.get("source").and_then(Json::as_str), Some("simulated"));
    assert!(row.get("metrics").unwrap().get("er").unwrap().as_f64().unwrap() > 0.0);

    // n=4 is under the exhaustive threshold: a small deterministic grid.
    let sweep = client::post_json(
        addr,
        "/v1/sweep",
        &Json::parse(r#"{"designs":"paper","bitwidths":[4]}"#).unwrap(),
    )
    .unwrap();
    assert_eq!(sweep.status, 200);
    assert_eq!(
        sweep.header("transfer-encoding").map(str::to_ascii_lowercase).as_deref(),
        Some("chunked")
    );
    let lines = sweep.json_lines().unwrap();
    assert!(lines.len() >= 2, "stream must carry rows plus a trailer");
    let trailer = lines.last().unwrap();
    assert_eq!(trailer.get("status").and_then(Json::as_str), Some("complete"));
    let total = trailer.get("total").unwrap().as_u64().unwrap();
    assert_eq!(trailer.get("done").unwrap().as_u64(), Some(total));
    assert_eq!(lines.len() as u64, total + 1);
    for line in &lines[..lines.len() - 1] {
        let row = line.get("row").expect("stream row");
        assert_eq!(row.get("backend").and_then(Json::as_str), Some("cpu"));
        assert!(row.get("metrics").unwrap().get("mae").unwrap().as_f64().is_some());
    }

    let scrape = client::get(addr, "/metrics").unwrap();
    assert_eq!(scrape.status, 200);
    let doc = scrape.text();
    assert_eq!(metric_value(&doc, "serve_backend").as_deref(), Some("cpu"));
    assert_eq!(metric_value(&doc, "serve_draining").as_deref(), Some("0"));
    let total: u64 = metric_value(&doc, "serve_requests_total").unwrap().parse().unwrap();
    assert!(total >= 4);
    assert!(metric_value(&doc, "session_jobs_completed").is_some());

    let down = client::post_json(addr, "/v1/shutdown", &Json::Obj(Default::default())).unwrap();
    assert_eq!(down.status, 200);
    let summary = server.join();
    assert_eq!(summary.backend, "cpu");
    assert!(summary.requests_total >= 5);
    assert!(summary.metrics_doc.contains("serve_backend cpu"));
}

/// Identical concurrent requests must not each cost a pool dispatch:
/// the coalescer (or, across engine cycles, the session cache) answers
/// them from one evaluation, and every client sees the same bits.
#[test]
fn identical_burst_coalesces_and_answers_identically() {
    let server = boot();
    let addr = server.addr();

    let burst = 8;
    let handles: Vec<_> = (0..burst)
        .map(|_| {
            std::thread::spawn(move || client::post_json(addr, "/v1/eval", &eval_body(60_000, 99)))
        })
        .collect();
    let mut bodies: Vec<Json> = Vec::new();
    for handle in handles {
        let resp = handle.join().unwrap().unwrap();
        assert_eq!(resp.status, 200, "{}", resp.text());
        bodies.push(resp.json().unwrap());
    }
    // All clients got byte-for-byte the same metrics (only `cached` and
    // `wall_ms` legitimately differ between a dispatch and a cache hit).
    let reference = bodies[0].get("metrics").unwrap().to_string_compact();
    for body in &bodies {
        assert_eq!(body.get("metrics").unwrap().to_string_compact(), reference);
    }

    let doc = client::get(addr, "/metrics").unwrap().text();
    let requests: u64 = metric_value(&doc, "serve_coalesce_requests").unwrap().parse().unwrap();
    let dispatched: u64 =
        metric_value(&doc, "serve_coalesce_dispatched").unwrap().parse().unwrap();
    assert_eq!(requests, burst);
    // Whether the burst landed in one engine cycle (one coalesced group)
    // or spread across cycles (cache hits after the first), exactly one
    // pool dispatch happened.
    assert_eq!(dispatched, 1, "identical burst must evaluate once, not {dispatched} times");
    let ratio: f64 = metric_value(&doc, "serve_coalesce_ratio").unwrap().parse().unwrap();
    assert!(ratio >= burst as f64 - 1e-9);

    // Bit-identity with the offline path: the same job through a direct
    // session produces exactly the served numbers.
    let mut session = Session::builder()
        .workers(2)
        .backend(BackendChoice::Cpu)
        .build()
        .unwrap();
    let direct = session
        .run_outcome(&EvalJob::mc(8, 3, true, 60_000, 99))
        .unwrap();
    let m = direct.metrics().unwrap();
    let served = bodies[0].get("metrics").unwrap();
    let exact = |field: &str| served.get(field).unwrap().as_f64().unwrap();
    assert_eq!(exact("er"), m.er, "served ER diverged from direct evaluation");
    assert_eq!(exact("mae"), m.mae as f64);
    assert_eq!(exact("med_abs"), m.med_abs);
    assert_eq!(exact("med_signed"), m.med_signed);
    assert_eq!(exact("nmed"), m.nmed);
    assert_eq!(exact("mred"), m.mred);
    assert_eq!(served.get("samples").unwrap().as_u64(), Some(m.samples));

    let _ = client::post_json(addr, "/v1/shutdown", &Json::Obj(Default::default())).unwrap();
    let summary = server.join();
    assert_eq!(
        summary.telemetry.jobs_evaluated, 1,
        "the engine must have evaluated the burst exactly once"
    );
}
