//! Kill-and-heal properties of the `segmul fleet` supervisor, against
//! the real binary.
//!
//! * A shard SIGKILLed mid-sweep is restarted from its store
//!   checkpoints, the fleet drains, and the merged report is
//!   byte-identical to an uninterrupted no-store reference run.
//! * A shard that crashes past `--max-restarts` makes the fleet kill
//!   the survivors and exit nonzero with a typed "giving up" error —
//!   it never hangs and never burns restarts forever.

use std::io::{BufRead, BufReader};
use std::path::PathBuf;
use std::process::{Command, Stdio};

const BIN: &str = env!("CARGO_BIN_EXE_segmul");

fn tmp(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("segmul-fleet-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("tmp dir");
    dir
}

/// The grid both runs share; small enough for CI, big enough that a
/// freshly spawned shard is still working when the kill lands.
const GRID: &[&str] =
    &["--designs", "paper", "--n", "8", "--mc", "--samples", "1500000", "--seed", "42", "--workers", "2"];

#[test]
fn fleet_heals_a_killed_shard_and_merges_to_reference_bytes() {
    let work = tmp("heal");

    // Uninterrupted no-store reference.
    let ref_dir = work.join("ref");
    let status = Command::new(BIN)
        .arg("sweep")
        .args(GRID)
        .args(["--deterministic-report", "--results"])
        .arg(&ref_dir)
        .stdout(Stdio::null())
        .status()
        .expect("reference sweep");
    assert!(status.success(), "reference sweep failed");

    // The fleet: two supervised shards over one store. Shard 0 is
    // SIGKILLed the moment its pid line appears — mid-startup or
    // mid-sweep, either way the supervisor must restart and heal it.
    let fleet_dir = work.join("fleet");
    let mut fleet = Command::new(BIN)
        .args(["fleet", "--shards", "2"])
        .args(GRID)
        .args(["--max-restarts", "3", "--wedge-secs", "300", "--store"])
        .arg(work.join("store"))
        .arg("--results")
        .arg(&fleet_dir)
        .stdout(Stdio::piped())
        .spawn()
        .expect("fleet spawn");
    let reader = BufReader::new(fleet.stdout.take().expect("piped stdout"));
    let mut killed = false;
    let mut saw_restart = false;
    let mut log = Vec::new();
    for line in reader.lines() {
        let line = line.expect("fleet stdout");
        if !killed {
            if let Some(pid) = line
                .strip_prefix("fleet: shard 0/2 pid ")
                .and_then(|rest| rest.split_whitespace().next())
                .and_then(|p| p.parse::<u32>().ok())
            {
                let _ = Command::new("sh").arg("-c").arg(format!("kill -9 {pid}")).status();
                killed = true;
            }
        }
        if line.contains("(restart #1)") {
            saw_restart = true;
        }
        log.push(line);
    }
    let status = fleet.wait().expect("fleet exit");
    let log = log.join("\n");
    assert!(status.success(), "fleet failed:\n{log}");
    assert!(killed, "shard 0's pid line never appeared:\n{log}");
    assert!(saw_restart, "the killed shard was never restarted:\n{log}");
    assert!(log.contains("merge complete"), "missing merge pass:\n{log}");

    // The healed, merged report is byte-identical to the reference.
    for report in ["sweep.csv", "BENCH_sweep.json"] {
        let want = std::fs::read(ref_dir.join(report)).expect("reference report");
        let got = std::fs::read(fleet_dir.join(report)).expect("fleet report");
        assert_eq!(got, want, "{report}: fleet merge diverged from the reference bytes");
    }
    let _ = std::fs::remove_dir_all(&work);
}

#[test]
fn fleet_gives_up_after_max_restarts_with_a_typed_error() {
    let work = tmp("fatal");
    // Every child inherits a worker-panic storm that exhausts its retry
    // budget, so each shard attempt exits nonzero almost immediately.
    let out = Command::new(BIN)
        .args(["fleet", "--shards", "1"])
        .args(["--designs", "paper", "--n", "8", "--mc", "--samples", "100000", "--seed", "1"])
        .args(["--workers", "2", "--max-restarts", "1", "--store"])
        .arg(work.join("store"))
        .arg("--results")
        .arg(work.join("results"))
        .env("SEGMUL_FAULTS", "worker.panic:p=1")
        .output()
        .expect("fleet run");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(!out.status.success(), "an unhealable fleet must exit nonzero\n{stderr}");
    assert!(stderr.contains("giving up"), "missing typed give-up error:\n{stderr}");
    let _ = std::fs::remove_dir_all(&work);
}
