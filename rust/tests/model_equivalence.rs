//! Cross-model equivalence: the four implementations of the approximate
//! multiplier (bit-level paper equations, word-level u64/u128/U512, and
//! the gate-level netlist) must agree bit-for-bit everywhere they overlap.

use segmul::multiplier::wordlevel::{approx_seq_mul, approx_seq_mul_u128, approx_seq_mul_wide};
use segmul::multiplier::{approx_seq_mul_bitlevel, U512};
use segmul::netlist::generators::seq_mult::{run_batch, seq_mult};
use segmul::netlist::SeqSim;
use segmul::util::prop::Cases;

#[test]
fn exhaustive_all_models_n_le_5() {
    for n in 2..=5u32 {
        for t in 0..n {
            for fix in [false, true] {
                let run_fix = fix && t >= 1;
                let circuit = seq_mult(n, t, t >= 1);
                let mut sim = SeqSim::new(&circuit.nl);
                let all: Vec<(u64, u64)> = (0..(1u64 << n))
                    .flat_map(|a| (0..(1u64 << n)).map(move |b| (a, b)))
                    .collect();
                for chunk in all.chunks(64) {
                    let av: Vec<U512> = chunk.iter().map(|&(a, _)| U512::from_u64(a)).collect();
                    let bv: Vec<U512> = chunk.iter().map(|&(_, b)| U512::from_u64(b)).collect();
                    let gate = run_batch(&circuit, &mut sim, &av, &bv, run_fix);
                    for (&(a, b), g) in chunk.iter().zip(&gate) {
                        let word = approx_seq_mul(a, b, n, t, run_fix);
                        let bit = approx_seq_mul_bitlevel(a, b, n, t, run_fix);
                        let w128 = approx_seq_mul_u128(a as u128, b as u128, n, t, run_fix) as u64;
                        assert_eq!(word, bit, "word!=bit n={n} t={t} fix={run_fix} {a}x{b}");
                        assert_eq!(word, w128, "word!=u128 n={n} t={t} {a}x{b}");
                        assert_eq!(g.limb(0), word, "gate!=word n={n} t={t} fix={run_fix} {a}x{b}");
                    }
                }
            }
        }
    }
}

#[test]
fn prop_wide_and_word_agree_random_n_up_to_60() {
    Cases::new(0xE951, 40).run(|rng, _| {
        let n = 33 + rng.next_below(28) as u32; // 33..=60
        let t = rng.next_below(n as u64) as u32;
        let fix = rng.next_bits(1) == 1;
        let a = rng.next_bits(n.min(60)) as u128;
        let b = rng.next_bits(n.min(60)) as u128;
        let via128 = approx_seq_mul_u128(a, b, n, t, fix);
        let wide = approx_seq_mul_wide(&U512::from_u128(a), &U512::from_u128(b), n, t, fix);
        assert_eq!(wide, U512::from_u128(via128), "n={n} t={t} fix={fix}");
    });
}

#[test]
fn paper_worked_examples() {
    // Table Ia/Ib: 1011 x 0110 = 1000010 (66), accurate.
    assert_eq!(approx_seq_mul(0b1011, 0b0110, 4, 0, false), 66);
    // Table IIb: t = 2 segmentation defers the cycle-2 LSP carry.
    assert_eq!(approx_seq_mul(0b1011, 0b0110, 4, 2, false), 82);
    // MAE structure (E3): dropped final carry achieves 2^{n+t-1} exactly.
    let (n, t) = (6u32, 3u32);
    let mut worst = 0i64;
    for a in 0..(1u64 << n) {
        for b in 0..(1u64 << n) {
            let ed = (a * b) as i64 - approx_seq_mul(a, b, n, t, false) as i64;
            worst = worst.max(ed.abs());
        }
    }
    assert_eq!(worst as u64, 1u64 << (n + t - 1));
}
