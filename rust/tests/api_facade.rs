//! Facade contract tests: the persistent worker pool, bit-identical
//! sharded results, the design-agnostic spec registry, and the typed
//! error surface.
//!
//! The load-bearing assertions:
//! * **Persistent pool** — a session reused across ≥ 3 jobs constructs
//!   its backends exactly once per worker (counting factory), never per
//!   job (the old `run_job_sharded` behavior this facade replaces).
//! * **Determinism** — session results are bit-identical to PR 2's
//!   `sweep_determinism` expectations (the sequential driver reference)
//!   for any worker count.
//! * **Registry** — every `MultiplierSpec` variant round-trips through
//!   `JobKey`, and the cross-design sweep runs ≥ 2 non-paper designs
//!   through the shared cache/shard path.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use segmul::api::{
    BackendChoice, DesignSet, EvalJob, JobBuilder, MultiplierSpec, ProgressEvent, SegmulError,
    Session, SweepGrid, WorkSpec,
};
use segmul::coordinator::{run_job, CpuBackend, EvalBackend};

/// A factory that counts backend constructions and batch evaluations.
fn counting_factory(
    builds: Arc<AtomicUsize>,
    evals: Arc<AtomicUsize>,
) -> impl Fn() -> Result<Box<dyn EvalBackend>> + Send + Sync + 'static {
    struct Counting {
        inner: CpuBackend,
        evals: Arc<AtomicUsize>,
    }
    impl EvalBackend for Counting {
        fn name(&self) -> &'static str {
            "counting"
        }
        fn max_batch(&self) -> usize {
            self.inner.max_batch()
        }
        fn supports(&self, n: u32) -> bool {
            self.inner.supports(n)
        }
        fn eval_batch(
            &mut self,
            n: u32,
            t: u32,
            fix: bool,
            a: &[u64],
            b: &[u64],
        ) -> Result<segmul::error::metrics::ErrorStats> {
            self.evals.fetch_add(1, Ordering::Relaxed);
            self.inner.eval_batch(n, t, fix, a, b)
        }
        fn supports_design(&self, design: &MultiplierSpec) -> bool {
            self.inner.supports_design(design)
        }
        fn eval_design(
            &mut self,
            design: &MultiplierSpec,
            a: &[u64],
            b: &[u64],
        ) -> Result<segmul::error::metrics::ErrorStats> {
            self.evals.fetch_add(1, Ordering::Relaxed);
            self.inner.eval_design(design, a, b)
        }
    }
    move || {
        builds.fetch_add(1, Ordering::SeqCst);
        Ok(Box::new(Counting { inner: CpuBackend::new(), evals: evals.clone() })
            as Box<dyn EvalBackend>)
    }
}

#[test]
fn session_reuse_builds_backends_once_per_worker() {
    let builds = Arc::new(AtomicUsize::new(0));
    let evals = Arc::new(AtomicUsize::new(0));
    let mut session = Session::builder()
        .workers(3)
        .backend_factory(counting_factory(builds.clone(), evals.clone()))
        .build()
        .unwrap();
    assert_eq!(builds.load(Ordering::SeqCst), 3, "one construction per worker at startup");

    // ≥ 3 distinct jobs through the same session: the persistent pool
    // must evaluate them all without a single re-construction.
    let jobs = [
        EvalJob::mc(8, 2, false, 150_000, 1),
        EvalJob::mc(8, 4, true, 150_000, 2),
        EvalJob::exhaustive(8, 3, true),
        EvalJob::mc(10, 5, false, 150_000, 3),
    ];
    for job in &jobs {
        let r = session.run(job).unwrap();
        assert!(r.stats.count > 0);
    }
    assert!(evals.load(Ordering::Relaxed) > 0);
    assert_eq!(
        builds.load(Ordering::SeqCst),
        3,
        "backends are constructed once per worker per session, not per job"
    );
    assert_eq!(session.backend_builds(), 3);

    // A cache hit does not touch the backends either.
    let before = evals.load(Ordering::Relaxed);
    let _ = session.run(&jobs[0]).unwrap();
    assert_eq!(evals.load(Ordering::Relaxed), before);
    assert_eq!(session.cache_hits(), 1);
}

#[test]
fn session_results_bit_identical_to_sequential_driver() {
    // The PR 2 sweep_determinism expectation, now through the facade:
    // for every config, stats equal the sequential driver bit-for-bit —
    // integer fields AND the order-sensitive f64 sum_red.
    let jobs = [
        EvalJob::exhaustive(10, 4, true),
        EvalJob::mc(12, 5, false, 300_000, 0x5EED),
        EvalJob::new(
            MultiplierSpec::Mitchell { n: 12 },
            WorkSpec::MonteCarlo { samples: 200_000, seed: 0x5EED },
        ),
        EvalJob::new(
            MultiplierSpec::Truncated { n: 10, k: 3 },
            WorkSpec::Exhaustive,
        ),
    ];
    let reference: Vec<_> = jobs
        .iter()
        .map(|job| {
            let mut be = CpuBackend::new();
            run_job(&mut be, job).unwrap()
        })
        .collect();
    for workers in [1usize, 2, 7] {
        let mut session = Session::builder()
            .workers(workers)
            .backend(BackendChoice::Cpu)
            .build()
            .unwrap();
        for (job, want) in jobs.iter().zip(&reference) {
            let got = session.run(job).unwrap();
            assert_eq!(
                got.stats,
                want.stats,
                "workers={workers} design={}",
                job.design.name()
            );
            assert_eq!(got.batches, want.batches, "workers={workers}");
        }
    }
}

#[test]
fn every_spec_variant_round_trips_through_job_key() {
    let specs = MultiplierSpec::registry_examples(8);
    assert_eq!(specs.len(), 8, "registry must cover every design family");
    let mut keys = Vec::new();
    for spec in &specs {
        let j1 = JobBuilder::new(*spec).monte_carlo(1000).seed(3).build().unwrap();
        let j2 = JobBuilder::new(*spec).monte_carlo(1000).seed(3).build().unwrap();
        assert_eq!(j1.key(), j2.key(), "{} key must be stable", spec.name());
        assert_eq!(j1.key().design, spec.canonical());
        keys.push(j1.key());
    }
    // The registry examples are pairwise distinct product functions, so
    // their keys must be pairwise distinct.
    for i in 0..keys.len() {
        for j in (i + 1)..keys.len() {
            assert_ne!(keys[i], keys[j], "{} vs {}", specs[i].name(), specs[j].name());
        }
    }
}

#[test]
fn cross_design_sweep_runs_non_paper_designs_through_shared_path() {
    // `segmul sweep --designs all` reduced to a test-sized grid: ≥ 2
    // non-paper designs must be *evaluated* (not cache-served) through
    // the same session cache/shard path as the paper grid.
    let builds = Arc::new(AtomicUsize::new(0));
    let evals = Arc::new(AtomicUsize::new(0));
    let mut session = Session::builder()
        .workers(2)
        .backend_factory(counting_factory(builds.clone(), evals.clone()))
        .build()
        .unwrap();
    let grid = SweepGrid {
        bitwidths: vec![4],
        designs: DesignSet::All,
        exhaustive_max_n: 8,
        force_mc: false,
        mc_samples: 10_000,
        seed: 1,
    };
    let outcomes = session.run_grid(&grid, |_, _, _| {}).unwrap();
    let non_paper_evaluated = outcomes
        .iter()
        .filter(|o| !o.cached && !matches!(o.job.design, MultiplierSpec::Segmented { .. }))
        .count();
    assert!(
        non_paper_evaluated >= 2,
        "expected >= 2 non-paper designs evaluated, got {non_paper_evaluated}"
    );
    // Canonical dedup across designs: the accurate baseline is served
    // from the paper grid's t=0 entry (evaluated earlier in grid order).
    let accurate = outcomes
        .iter()
        .find(|o| matches!(o.job.design, MultiplierSpec::Accurate { .. }))
        .expect("grid contains the accurate design");
    assert!(accurate.cached, "accurate must dedup against the t=0 paper points");
    let t0 = outcomes
        .iter()
        .find(|o| o.job.design == MultiplierSpec::Segmented { n: 4, t: 0, fix: false })
        .unwrap();
    assert_eq!(accurate.result().unwrap().stats, t0.result().unwrap().stats);
    // Everything ran on the persistent pool: 2 builds, ever.
    assert_eq!(builds.load(Ordering::SeqCst), 2);
}

#[test]
fn progress_callback_streams_chunk_completion() {
    let events: Arc<Mutex<Vec<ProgressEvent>>> = Arc::new(Mutex::new(Vec::new()));
    let sink = events.clone();
    let mut session = Session::builder()
        .workers(2)
        .on_progress(move |e| sink.lock().unwrap().push(e))
        .build()
        .unwrap();
    // 300k samples over 2^16-pair chunks => 5 chunk merges.
    let job = EvalJob::mc(8, 3, true, 300_000, 7);
    let r = session.run(&job).unwrap();
    let log = events.lock().unwrap();
    let merges: Vec<(u64, u64)> = log
        .iter()
        .filter_map(|e| match e {
            ProgressEvent::ChunkMerged { merged, samples, .. } => Some((*merged, *samples)),
            _ => None,
        })
        .collect();
    assert_eq!(merges.len() as u64, r.batches, "one event per in-order merge");
    for (i, (merged, _)) in merges.iter().enumerate() {
        assert_eq!(*merged, i as u64 + 1, "merges arrive in prefix order");
    }
    assert_eq!(merges.last().unwrap().1, 300_000, "final event covers the full budget");
}

#[test]
fn typed_errors_on_the_facade_surface() {
    // Config: zero workers.
    let e = Session::builder().workers(0).build().unwrap_err();
    assert!(matches!(e, SegmulError::Config(_)), "{e}");
    // Spec: invalid design parameters.
    let e = JobBuilder::new(MultiplierSpec::Kulkarni { n: 12 })
        .monte_carlo(10)
        .build()
        .unwrap_err();
    assert!(matches!(e, SegmulError::Spec { .. }), "{e}");
    // Workload: zero samples.
    let e = JobBuilder::new(MultiplierSpec::Accurate { n: 8 })
        .monte_carlo(0)
        .build()
        .unwrap_err();
    assert!(matches!(e, SegmulError::Workload(_)), "{e}");
    // Backend: factory failure at session build.
    let e = Session::builder()
        .workers(2)
        .backend_factory(|| anyhow::bail!("no such accelerator"))
        .build()
        .unwrap_err();
    assert!(matches!(e, SegmulError::Backend(_)), "{e}");
    assert!(e.to_string().contains("no such accelerator"), "{e}");

    // Backend: capability preflight — a backend on the trait defaults
    // (like PJRT) cannot run non-segmented designs, and the facade must
    // report that as a typed Backend error before any chunk work.
    struct SegOnly;
    impl EvalBackend for SegOnly {
        fn name(&self) -> &'static str {
            "segonly"
        }
        fn max_batch(&self) -> usize {
            256
        }
        fn supports(&self, n: u32) -> bool {
            (1..=32).contains(&n)
        }
        fn eval_batch(
            &mut self,
            n: u32,
            t: u32,
            fix: bool,
            a: &[u64],
            b: &[u64],
        ) -> Result<segmul::error::metrics::ErrorStats> {
            CpuBackend::new().eval_batch(n, t, fix, a, b)
        }
    }
    let mut s = Session::builder()
        .workers(1)
        .backend_factory(|| Ok(Box::new(SegOnly) as Box<dyn EvalBackend>))
        .build()
        .unwrap();
    let job = JobBuilder::new(MultiplierSpec::Mitchell { n: 8 })
        .monte_carlo(100)
        .build()
        .unwrap();
    let e = s.run(&job).unwrap_err();
    assert!(matches!(e, SegmulError::Backend(_)), "{e}");
    assert!(e.to_string().contains("mitchell"), "{e}");
    // The segmented family still runs on the same session.
    let ok = s
        .run(&JobBuilder::new(MultiplierSpec::Accurate { n: 8 }).monte_carlo(100).build().unwrap())
        .unwrap();
    assert_eq!(ok.stats.count, 100);
}

#[test]
fn session_seed_policy_flows_into_jobs() {
    let session = Session::builder().workers(1).seed(0xABCD).build().unwrap();
    let job = session
        .job(MultiplierSpec::Segmented { n: 8, t: 2, fix: false })
        .monte_carlo(100)
        .build()
        .unwrap();
    match job.spec {
        WorkSpec::MonteCarlo { seed, .. } => assert_eq!(seed, 0xABCD),
        _ => panic!("expected MC workload"),
    }
    // Explicit seed overrides the session policy.
    let job = session
        .job(MultiplierSpec::Segmented { n: 8, t: 2, fix: false })
        .monte_carlo(100)
        .seed(5)
        .build()
        .unwrap();
    match job.spec {
        WorkSpec::MonteCarlo { seed, .. } => assert_eq!(seed, 5),
        _ => panic!("expected MC workload"),
    }
}
