//! Blob encoding: one committed job result per store key, self-checking.
//!
//! A blob is a JSON object carrying the schema version, the full
//! canonical key string, the exact [`ErrorStats`], the accounting fields
//! (`batches`, wall time in nanoseconds), and an FNV-1a integrity hash
//! over the canonical compact serialization of everything else. Exactness
//! rules: the JSON codec's only number type is f64, so every u64/i128/
//! u128 field is encoded as a decimal string and `sum_red` is persisted
//! as the hex of its IEEE-754 bit pattern — a loaded blob reproduces the
//! original statistics *bit for bit*, which is what makes store-served
//! sweep rows byte-identical to evaluated ones.
//!
//! Decoding is strict: parse failure (truncation), integrity mismatch
//! (bit flips), schema mismatch, and key mismatch (an address collision
//! or a tampered file) are all errors — the caller falls back to
//! re-evaluation, never to a silently wrong answer.

use std::time::Duration;

use crate::coordinator::JobResult;
use crate::error::metrics::ErrorStats;
use crate::util::json::{obj, Json};

use super::{fnv1a64, StoreKey, STORE_SCHEMA};

/// A blob's payload: everything needed to reconstruct a
/// [`JobResult`] (the backend tag is implied by the key, which pins the
/// backend name; the job itself is supplied by the requester).
#[derive(Clone, Debug, PartialEq)]
pub struct StoredResult {
    /// The committed error statistics.
    pub stats: ErrorStats,
    /// Backend batch executions performed by the original run.
    pub batches: u64,
    /// Wall time of the original run (exact nanoseconds).
    pub wall: Duration,
}

/// Exact JSON image of an [`ErrorStats`] (shared by blobs and journal
/// lines).
pub(crate) fn stats_to_json(s: &ErrorStats) -> Json {
    obj(vec![
        ("approx_sums", Json::from(s.approx_sums)),
        ("bitflips", Json::Arr(s.bitflips.iter().map(|f| Json::Str(f.to_string())).collect())),
        ("count", Json::Str(s.count.to_string())),
        ("err_count", Json::Str(s.err_count.to_string())),
        ("max_abs_ed", Json::Str(s.max_abs_ed.to_string())),
        ("n", Json::from(s.n as u64)),
        ("sum_abs_ed", Json::Str(s.sum_abs_ed.to_string())),
        ("sum_ed", Json::Str(s.sum_ed.to_string())),
        ("sum_red_bits", Json::Str(format!("{:016x}", s.sum_red.to_bits()))),
    ])
}

/// Strict inverse of [`stats_to_json`]. The error is a plain reason
/// string; callers wrap it into [`crate::error::SegmulError::Store`] with
/// the offending path.
pub(crate) fn stats_from_json(j: &Json) -> Result<ErrorStats, String> {
    let n = j
        .get("n")
        .and_then(Json::as_u64)
        .and_then(|v| u32::try_from(v).ok())
        .ok_or("stats missing numeric 'n'")?;
    if !(1..=32).contains(&n) {
        return Err(format!("stats n={n} out of range"));
    }
    let text = |key: &str| -> Result<&str, String> {
        j.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| format!("stats missing string '{key}'"))
    };
    let flips = j.get("bitflips").and_then(Json::as_arr).ok_or("stats missing 'bitflips'")?;
    if flips.len() != 2 * n as usize {
        return Err(format!("stats bitflips length {} != {}", flips.len(), 2 * n));
    }
    let mut bitflips = Vec::with_capacity(flips.len());
    for f in flips {
        let v = f
            .as_str()
            .ok_or("bitflip entry is not a string")?
            .parse::<u64>()
            .map_err(|e| format!("bad bitflip count: {e}"))?;
        bitflips.push(v);
    }
    let sum_red_bits = u64::from_str_radix(text("sum_red_bits")?, 16)
        .map_err(|e| format!("bad sum_red_bits: {e}"))?;
    Ok(ErrorStats {
        n,
        count: text("count")?.parse().map_err(|e| format!("bad count: {e}"))?,
        err_count: text("err_count")?.parse().map_err(|e| format!("bad err_count: {e}"))?,
        sum_ed: text("sum_ed")?.parse().map_err(|e| format!("bad sum_ed: {e}"))?,
        sum_abs_ed: text("sum_abs_ed")?.parse().map_err(|e| format!("bad sum_abs_ed: {e}"))?,
        max_abs_ed: text("max_abs_ed")?.parse().map_err(|e| format!("bad max_abs_ed: {e}"))?,
        sum_red: f64::from_bits(sum_red_bits),
        bitflips,
        approx_sums: j
            .get("approx_sums")
            .and_then(Json::as_bool)
            .ok_or("stats missing boolean 'approx_sums'")?,
    })
}

/// Attach the integrity hash: FNV-1a over the canonical compact
/// serialization of the object *without* its `check` field (object keys
/// are BTreeMap-sorted, so the serialization is deterministic whatever
/// formatting the file on disk uses).
pub(crate) fn seal(mut payload: Json) -> Json {
    let check = fnv1a64(payload.to_string_compact().as_bytes());
    if let Json::Obj(m) = &mut payload {
        m.insert("check".to_string(), Json::Str(format!("{check:016x}")));
    }
    payload
}

/// Verify and strip the integrity hash attached by [`seal`], returning
/// the checked body.
pub(crate) fn unseal(parsed: Json) -> Result<Json, String> {
    let mut m = match parsed {
        Json::Obj(m) => m,
        _ => return Err("not a JSON object".to_string()),
    };
    let found = match m.remove("check") {
        Some(Json::Str(s)) => s,
        _ => return Err("missing integrity check".to_string()),
    };
    let body = Json::Obj(m);
    let want = format!("{:016x}", fnv1a64(body.to_string_compact().as_bytes()));
    if found != want {
        return Err(format!("integrity check mismatch (found {found}, computed {want})"));
    }
    Ok(body)
}

/// Serialize one committed result as a blob file.
pub(crate) fn encode(key: &StoreKey, result: &JobResult) -> String {
    let payload = obj(vec![
        ("batches", Json::Str(result.batches.to_string())),
        ("key", Json::from(key.canonical())),
        ("schema", Json::from(STORE_SCHEMA as u64)),
        ("stats", stats_to_json(&result.stats)),
        ("wall_ns", Json::Str(result.wall.as_nanos().to_string())),
    ]);
    let mut text = seal(payload).to_string_pretty();
    text.push('\n');
    text
}

/// Strictly decode a blob file for `key`.
pub(crate) fn decode(text: &str, key: &StoreKey) -> Result<StoredResult, String> {
    let parsed = Json::parse(text).map_err(|e| format!("unreadable blob: {e}"))?;
    let body = unseal(parsed)?;
    let schema = body.get("schema").and_then(Json::as_u64).ok_or("blob missing 'schema'")?;
    if schema != STORE_SCHEMA as u64 {
        return Err(format!("blob schema {schema} != supported {STORE_SCHEMA}"));
    }
    let stored_key = body.get("key").and_then(Json::as_str).ok_or("blob missing 'key'")?;
    if stored_key != key.canonical() {
        return Err(
            "blob key does not match the requested job (address collision or foreign file)"
                .to_string(),
        );
    }
    let stats = stats_from_json(body.get("stats").ok_or("blob missing 'stats'")?)?;
    let batches = body
        .get("batches")
        .and_then(Json::as_str)
        .ok_or("blob missing string 'batches'")?
        .parse::<u64>()
        .map_err(|e| format!("bad batches: {e}"))?;
    let wall_ns = body
        .get("wall_ns")
        .and_then(Json::as_str)
        .ok_or("blob missing string 'wall_ns'")?
        .parse::<u128>()
        .map_err(|e| format!("bad wall_ns: {e}"))?;
    let wall = Duration::new(
        (wall_ns / 1_000_000_000) as u64,
        (wall_ns % 1_000_000_000) as u32,
    );
    Ok(StoredResult { stats, batches, wall })
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::coordinator::EvalJob;

    fn sample_stats() -> ErrorStats {
        let mut s = ErrorStats::new(6);
        // Force interesting field values, including a negative signed sum
        // and a sum_red with a long mantissa.
        s.record(63 * 63, 0);
        s.record(5, 9);
        s.record(100, 100);
        s.sum_red += 0.1234567890123456789;
        s
    }

    fn sample_blob() -> (StoreKey, JobResult, String) {
        let job = EvalJob::mc(6, 2, true, 1000, 0xDEAD_BEEF_CAFE_F00D);
        let key = StoreKey::new(&job, "cpu", 512);
        let result = JobResult {
            job: job.clone(),
            stats: sample_stats(),
            backend: "cpu",
            wall: Duration::new(3, 141_592_653),
            batches: 2,
        };
        let text = encode(&key, &result);
        (key, result, text)
    }

    #[test]
    fn roundtrip_is_bit_exact() {
        let (key, result, text) = sample_blob();
        let hit = decode(&text, &key).unwrap();
        assert_eq!(hit.stats, result.stats);
        assert_eq!(hit.stats.sum_red.to_bits(), result.stats.sum_red.to_bits());
        assert_eq!(hit.batches, result.batches);
        assert_eq!(hit.wall, result.wall);
    }

    #[test]
    fn truncation_is_detected() {
        let (key, _, text) = sample_blob();
        for cut in [0, 1, text.len() / 2, text.len() - 2] {
            assert!(decode(&text[..cut], &key).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn every_content_bit_flip_is_detected_or_harmless() {
        // The corruption property at the codec level: flipping any single
        // bit of the blob either fails decoding (typed at the store
        // layer) or — when the flip lands in formatting whitespace —
        // leaves the decoded content exactly equal to the original.
        // There is no third outcome.
        let (key, result, text) = sample_blob();
        let bytes = text.as_bytes();
        for pos in 0..bytes.len() {
            let mut corrupt = bytes.to_vec();
            corrupt[pos] ^= 1 << (pos % 8);
            let corrupt = match String::from_utf8(corrupt) {
                Ok(s) => s,
                Err(_) => continue, // fs::read_to_string would refuse it
            };
            if let Ok(hit) = decode(&corrupt, &key) {
                assert_eq!(hit.stats, result.stats, "silent corruption at byte {pos}");
                assert_eq!(hit.batches, result.batches, "silent corruption at byte {pos}");
                assert_eq!(hit.wall, result.wall, "silent corruption at byte {pos}");
            }
        }
    }

    #[test]
    fn schema_and_key_mismatches_are_detected() {
        let (key, _, text) = sample_blob();
        // Schema bump: re-seal so only the schema check can object.
        let body = unseal(Json::parse(&text).unwrap()).unwrap();
        let mut m = match body {
            Json::Obj(m) => m,
            _ => unreachable!(),
        };
        m.insert("schema".to_string(), Json::from(9999u64));
        let resealed = seal(Json::Obj(m)).to_string_pretty();
        let err = decode(&resealed, &key).unwrap_err();
        assert!(err.contains("schema"), "{err}");
        // Foreign key: a valid blob for a different job must be refused.
        let other = EvalJob::mc(6, 2, true, 1000, 1);
        let other_key = StoreKey::new(&other, "cpu", 512);
        let err = decode(&text, &other_key).unwrap_err();
        assert!(err.contains("key"), "{err}");
    }
}
