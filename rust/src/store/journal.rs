//! The append-only chunk journal: the store's checkpointed chunk cursor.
//!
//! One file per store key, one line per chunk, written strictly in
//! chunk-id order by the pool's ordered merge at the moment the chunk
//! folds into the in-order prefix. Each line is a compact JSON record
//! `{chunk, stats, check}` sealed with the same FNV-1a scheme as blobs.
//!
//! Recovery is lenient by construction: it returns the **longest valid
//! prefix** of records with chunk ids `0, 1, 2, …`. A process killed
//! mid-append leaves at most one torn tail line; a corrupt interior
//! record (or any out-of-order id) cuts the prefix right there. Either
//! way the discarded chunks are simply re-evaluated — recovery can lose
//! work but can never fabricate or reorder it, which is what keeps a
//! resumed run bit-identical to an uninterrupted one.
//!
//! Durability model: appends go straight to the file descriptor (no
//! user-space buffering), so a SIGKILL loses nothing already appended.
//! There is deliberately no per-chunk fsync — an OS crash may drop the
//! cache tail, which recovery handles like any other torn tail.

use std::fs;
use std::io::{Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::error::metrics::ErrorStats;
use crate::error::SegmulError;
use crate::fault::{FaultInjector, FaultSite};
use crate::util::json::{obj, Json};

use super::blob::{seal, stats_from_json, stats_to_json, unseal};

/// The recovered checkpoint for one store key.
#[derive(Debug)]
pub struct RecoveredJournal {
    /// Per-chunk stats of the longest valid in-order prefix: entry `i`
    /// is chunk `i`, exactly as the original run merged it.
    pub chunks: Vec<ErrorStats>,
    /// Byte length of that valid prefix — where [`JournalWriter`]
    /// resumes appending (anything beyond is truncated away).
    pub valid_len: u64,
    /// Bytes discarded beyond the valid prefix (torn tail, corruption).
    pub discarded_bytes: u64,
}

fn encode_line(chunk_id: u64, stats: &ErrorStats) -> String {
    let payload = obj(vec![
        ("chunk", Json::Str(chunk_id.to_string())),
        ("stats", stats_to_json(stats)),
    ]);
    let mut line = seal(payload).to_string_compact();
    line.push('\n');
    line
}

fn decode_line(body: &str, expect_id: u64) -> Result<ErrorStats, String> {
    let parsed = Json::parse(body).map_err(|e| format!("unreadable journal line: {e}"))?;
    let checked = unseal(parsed)?;
    let id = checked
        .get("chunk")
        .and_then(Json::as_str)
        .ok_or("journal line missing 'chunk'")?
        .parse::<u64>()
        .map_err(|e| format!("bad chunk id: {e}"))?;
    if id != expect_id {
        return Err(format!("journal line holds chunk {id}, expected {expect_id}"));
    }
    stats_from_json(checked.get("stats").ok_or("journal line missing 'stats'")?)
}

/// Recover the longest valid in-order prefix of the journal at `path`.
/// A missing or empty file is an empty (zero-chunk) checkpoint.
pub(crate) fn recover(path: &Path) -> RecoveredJournal {
    let text = match fs::read_to_string(path) {
        Ok(t) => t,
        Err(_) => {
            return RecoveredJournal { chunks: Vec::new(), valid_len: 0, discarded_bytes: 0 }
        }
    };
    let mut chunks = Vec::new();
    let mut valid_len = 0usize;
    for line in text.split_inclusive('\n') {
        if !line.ends_with('\n') {
            break; // torn tail: the normal SIGKILL artifact
        }
        match decode_line(line.trim_end_matches(['\n', '\r']), chunks.len() as u64) {
            Ok(stats) => {
                valid_len += line.len();
                chunks.push(stats);
            }
            Err(_) => break, // corruption cuts the prefix, soundly
        }
    }
    RecoveredJournal {
        chunks,
        valid_len: valid_len as u64,
        discarded_bytes: (text.len() - valid_len) as u64,
    }
}

/// Appends checkpoint lines as chunks merge. A write failure (disk full,
/// revoked mount) disables the writer with one warning — resumability
/// degrades, the run itself continues and stays correct.
pub struct JournalWriter {
    file: fs::File,
    path: PathBuf,
    failed: bool,
    faults: Arc<FaultInjector>,
}

impl JournalWriter {
    pub(crate) fn open(
        path: PathBuf,
        valid_len: u64,
        faults: Arc<FaultInjector>,
    ) -> Result<JournalWriter, SegmulError> {
        let wrap = |e: std::io::Error| SegmulError::store(path.display().to_string(), e.to_string());
        let mut file = fs::OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(false)
            .open(&path)
            .map_err(wrap)?;
        // Cut away any invalid tail behind the recovered prefix before
        // appending, so one torn line can never corrupt the next run's
        // records.
        file.set_len(valid_len).map_err(wrap)?;
        file.seek(SeekFrom::End(0)).map_err(wrap)?;
        Ok(JournalWriter { file, path, failed: false, faults })
    }

    /// Append the checkpoint line for `chunk_id` (callers append in
    /// chunk-id order; recovery enforces it).
    pub fn append(&mut self, chunk_id: u64, stats: &ErrorStats) {
        if self.failed {
            return;
        }
        let line = encode_line(chunk_id, stats);
        if self.faults.fire(FaultSite::JournalAppend) {
            // Torn append: half the line reaches the disk, then the
            // writer disables like any real write failure. Recovery
            // discards the torn tail; resumability degrades to the
            // prefix already on disk, correctness is unaffected.
            let _ = self.file.write_all(&line.as_bytes()[..line.len() / 2]);
            eprintln!(
                "warning: chunk journal {} disabled: injected torn append",
                self.path.display()
            );
            self.failed = true;
            return;
        }
        if let Err(e) = self.file.write_all(line.as_bytes()) {
            eprintln!("warning: chunk journal {} disabled: {e}", self.path.display());
            self.failed = true;
        }
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;

    fn stats(i: u64) -> ErrorStats {
        let mut s = ErrorStats::new(4);
        s.record(10 + i, 3);
        s.sum_red += i as f64 * 0.3333333333333333;
        s
    }

    #[test]
    fn line_roundtrip_is_exact() {
        for i in [0u64, 1, 77] {
            let s = stats(i);
            let line = encode_line(i, &s);
            let back = decode_line(line.trim_end(), i).unwrap();
            assert_eq!(back, s);
            assert_eq!(back.sum_red.to_bits(), s.sum_red.to_bits());
        }
    }

    #[test]
    fn out_of_order_and_flipped_lines_are_rejected() {
        let line = encode_line(3, &stats(3));
        assert!(decode_line(line.trim_end(), 4).is_err());
        let flipped = line.replacen("\"count\":\"1\"", "\"count\":\"2\"", 1);
        assert_ne!(flipped, line, "test premise: a count field exists to flip");
        assert!(decode_line(flipped.trim_end(), 3).is_err());
    }

    #[test]
    fn recover_missing_file_is_empty() {
        let rec = recover(Path::new("/nonexistent/segmul/journal.jsonl"));
        assert!(rec.chunks.is_empty());
        assert_eq!(rec.valid_len, 0);
        assert_eq!(rec.discarded_bytes, 0);
    }
}
