//! Atomic lease files: multi-process mutual exclusion per store key.
//!
//! The claim primitive is `O_CREAT | O_EXCL` (`create_new`), which is
//! atomic on local filesystems and on NFSv3+ — exactly one of N
//! processes racing for a key wins and writes its pid into the lease.
//! Losers report [`Claim::Busy`] and poll for the winner's blob commit
//! instead of duplicating the evaluation.
//!
//! Stale-lease eviction is **single-winner**: an evictor must first
//! create an `O_EXCL` eviction marker (`<key>.evict`) next to the lease,
//! then *re-verify* the holder is still dead before removing the lease,
//! then remove the marker. The marker serializes racing evictors, and
//! the re-verify closes the stale-observation race: without it, a second
//! evictor acting on an old "holder is dead" observation could evict a
//! lease freshly re-created by a live claimant (claimants create leases
//! with `create_new`, which cannot overwrite — the path can only change
//! inside the marker's critical section, so the re-verified remove is
//! sound). A marker left by a crashed evictor is itself liveness-checked
//! and cleaned up, so a key can never wedge. An unreadable lease (a
//! claimant between `create_new` and its pid write, or a non-Linux host
//! where liveness cannot be probed) is conservatively treated as live;
//! the caller's wait timeout bounds the damage to one duplicated
//! evaluation, which the keyed blob commit then dedups — correctness
//! never depends on the lease.

use std::fs;
use std::io::{self, Write};
use std::path::{Path, PathBuf};

use crate::error::SegmulError;

/// Outcome of a claim attempt.
pub enum Claim {
    /// This process now holds the lease (released when the guard drops).
    Acquired(LeaseGuard),
    /// Another live process holds it: poll for its committed blob.
    Busy,
}

/// Holds a claimed lease; dropping it removes the lease file.
pub struct LeaseGuard {
    path: PathBuf,
}

impl LeaseGuard {
    /// Explicit release (identical to drop; named for call-site clarity).
    pub fn release(self) {}
}

impl Drop for LeaseGuard {
    fn drop(&mut self) {
        let _ = fs::remove_file(&self.path);
    }
}

/// Provable process death: only a missing `/proc/<pid>` on Linux says
/// yes; anywhere liveness cannot be probed is conservatively "alive".
fn pid_is_dead(pid: u32) -> bool {
    #[cfg(target_os = "linux")]
    {
        !Path::new(&format!("/proc/{pid}")).exists()
    }
    #[cfg(not(target_os = "linux"))]
    {
        let _ = pid;
        false
    }
}

/// Is the recorded holder provably dead? Only a parseable pid with no
/// live process says yes; everything else is conservatively "alive".
pub(crate) fn holder_is_dead(lease: &Path) -> bool {
    let pid = match fs::read_to_string(lease) {
        Ok(text) => match text.trim().parse::<u32>() {
            Ok(pid) => pid,
            Err(_) => return false,
        },
        Err(_) => return false,
    };
    if pid == std::process::id() {
        // Our own pid in a lease we failed to create: a previous claim of
        // this process (or a pid-reused corpse); treat as stale.
        return true;
    }
    pid_is_dead(pid)
}

/// Was this eviction marker abandoned by a crashed evictor? Unlike
/// [`holder_is_dead`], our own pid means a *live* evictor thread of this
/// very process mid-protocol — never abandoned.
fn marker_is_abandoned(marker: &Path) -> bool {
    match fs::read_to_string(marker) {
        Ok(text) => match text.trim().parse::<u32>() {
            Ok(pid) => pid != std::process::id() && pid_is_dead(pid),
            Err(_) => false,
        },
        Err(_) => false,
    }
}

pub(crate) fn claim(path: &Path) -> Result<Claim, SegmulError> {
    // Bounded retry: each loop either claims, reports Busy, or evicts a
    // provably dead holder; pathological churn (leases dying faster than
    // we can claim) gives up as Busy rather than spinning forever.
    for _ in 0..64 {
        match fs::OpenOptions::new().write(true).create_new(true).open(path) {
            Ok(mut f) => {
                let _ = writeln!(f, "{}", std::process::id());
                return Ok(Claim::Acquired(LeaseGuard { path: path.to_path_buf() }));
            }
            Err(e) if e.kind() == io::ErrorKind::AlreadyExists => {
                if !holder_is_dead(path) {
                    return Ok(Claim::Busy);
                }
                // Evict under the single-winner marker protocol, then
                // retry the atomic create whether or not we were the
                // winning evictor.
                let _ = evict(path);
            }
            Err(e) => {
                return Err(SegmulError::store(path.display().to_string(), e.to_string()))
            }
        }
    }
    Ok(Claim::Busy)
}

/// Single-winner eviction of a dead holder's lease. Returns `true` iff
/// *this* caller removed the lease.
///
/// Protocol: atomically create the `O_EXCL` eviction marker (losers back
/// off), **re-verify** the holder is still dead — the observation that
/// motivated this call may predate a win-and-reclaim by someone else —
/// and only then remove the lease. Claimants create leases with
/// `create_new`, which cannot replace an existing file, so between the
/// re-verify and the remove the lease path cannot change hands: the
/// remove provably deletes the corpse that was re-verified.
pub(crate) fn evict(path: &Path) -> bool {
    let marker = path.with_extension("evict");
    match fs::OpenOptions::new().write(true).create_new(true).open(&marker) {
        Ok(mut f) => {
            let _ = writeln!(f, "{}", std::process::id());
            let evicted = holder_is_dead(path) && fs::remove_file(path).is_ok();
            let _ = fs::remove_file(&marker);
            evicted
        }
        Err(e) if e.kind() == io::ErrorKind::AlreadyExists => {
            // Another evictor holds the marker: let it finish. A marker
            // whose recorded evictor is itself dead (an evictor crashed
            // mid-protocol) is cleaned up so the key cannot wedge.
            if marker_is_abandoned(&marker) {
                let _ = fs::remove_file(&marker);
            }
            false
        }
        Err(_) => false,
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;

    fn tmplease(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("segmul-lease-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir.join("key.lease")
    }

    #[test]
    fn claim_release_reclaim() {
        let path = tmplease("basic");
        let g = match claim(&path).unwrap() {
            Claim::Acquired(g) => g,
            Claim::Busy => panic!("fresh path must claim"),
        };
        assert!(path.exists());
        drop(g);
        assert!(!path.exists(), "drop must remove the lease");
        match claim(&path).unwrap() {
            Claim::Acquired(g) => g.release(),
            Claim::Busy => panic!("released path must re-claim"),
        }
    }

    #[test]
    fn own_pid_lease_is_reclaimed() {
        // A lease recorded under our own pid (a crashed previous claim of
        // this very process id) must not deadlock us.
        let path = tmplease("own");
        fs::write(&path, format!("{}\n", std::process::id())).unwrap();
        match claim(&path).unwrap() {
            Claim::Acquired(g) => g.release(),
            Claim::Busy => panic!("own-pid lease must be evicted"),
        }
    }

    #[test]
    fn garbage_lease_is_conservatively_busy() {
        let path = tmplease("garbage");
        fs::write(&path, "not-a-pid\n").unwrap();
        assert!(matches!(claim(&path).unwrap(), Claim::Busy));
    }

    /// The race this protocol exists for: many evictors observing the
    /// same dead holder race to evict — exactly one may win.
    #[test]
    fn concurrent_evictors_have_a_single_winner() {
        for round in 0..20 {
            let path = tmplease(&format!("race{round}"));
            fs::write(&path, "4294967295\n").unwrap();
            let wins: usize = std::thread::scope(|s| {
                let handles: Vec<_> =
                    (0..8).map(|_| s.spawn(|| usize::from(evict(&path)))).collect();
                handles.into_iter().map(|h| h.join().unwrap()).sum()
            });
            assert_eq!(wins, 1, "round {round}: exactly one evictor removes the corpse");
            assert!(!path.exists());
        }
    }

    /// A stale "holder is dead" observation must never evict a lease
    /// freshly re-created by a live claimant: the re-verify inside the
    /// marker section refuses.
    #[test]
    fn eviction_reverifies_and_spares_a_recreated_live_lease() {
        let path = tmplease("fresh");
        fs::write(&path, "4294967295\n").unwrap();
        assert!(evict(&path), "first evictor removes the corpse");
        // A live claimant from another process re-creates the lease (pid
        // 1 is the namespace init — always alive, never ours).
        fs::write(&path, "1\n").unwrap();
        // A second evictor still acting on the stale observation must
        // leave the live holder alone.
        assert!(!evict(&path));
        assert!(path.exists(), "the live lease survives the stale evictor");
    }

    /// A marker abandoned by a crashed evictor is cleaned up instead of
    /// wedging the key forever.
    #[test]
    fn abandoned_eviction_marker_is_cleaned_up() {
        let path = tmplease("wedge");
        fs::write(&path, "4294967295\n").unwrap();
        let marker = path.with_extension("evict");
        fs::write(&marker, "4294967295\n").unwrap();
        // First attempt observes the foreign marker: backs off, but
        // clears the dead evictor's marker.
        assert!(!evict(&path));
        assert!(!marker.exists(), "dead evictor's marker must be cleared");
        // The retry (as the claim loop would) now wins normally.
        assert!(evict(&path));
        match claim(&path).unwrap() {
            Claim::Acquired(g) => g.release(),
            Claim::Busy => panic!("evicted key must be claimable"),
        }
    }
}
