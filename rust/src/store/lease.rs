//! Atomic lease files: multi-process mutual exclusion per store key.
//!
//! The claim primitive is `O_CREAT | O_EXCL` (`create_new`), which is
//! atomic on local filesystems and on NFSv3+ — exactly one of N
//! processes racing for a key wins and writes its pid into the lease.
//! Losers report [`Claim::Busy`] and poll for the winner's blob commit
//! instead of duplicating the evaluation.
//!
//! Stale-lease eviction: a lease whose recorded pid is provably dead
//! (no `/proc/<pid>` on Linux) is *renamed away* to a unique tombstone —
//! renames of one source path succeed for exactly one evictor — deleted,
//! and the claim retried. An unreadable lease (a claimant between
//! `create_new` and its pid write, or a non-Linux host where liveness
//! cannot be probed) is conservatively treated as live; the caller's
//! wait timeout bounds the damage to one duplicated evaluation, which
//! the keyed blob commit then dedups — correctness never depends on the
//! lease.

use std::fs;
use std::io::{self, Write};
use std::path::{Path, PathBuf};

use crate::error::SegmulError;

/// Outcome of a claim attempt.
pub enum Claim {
    /// This process now holds the lease (released when the guard drops).
    Acquired(LeaseGuard),
    /// Another live process holds it: poll for its committed blob.
    Busy,
}

/// Holds a claimed lease; dropping it removes the lease file.
pub struct LeaseGuard {
    path: PathBuf,
}

impl LeaseGuard {
    /// Explicit release (identical to drop; named for call-site clarity).
    pub fn release(self) {}
}

impl Drop for LeaseGuard {
    fn drop(&mut self) {
        let _ = fs::remove_file(&self.path);
    }
}

/// Is the recorded holder provably dead? Only a parseable pid with no
/// live process says yes; everything else is conservatively "alive".
fn holder_is_dead(lease: &Path) -> bool {
    let pid = match fs::read_to_string(lease) {
        Ok(text) => match text.trim().parse::<u32>() {
            Ok(pid) => pid,
            Err(_) => return false,
        },
        Err(_) => return false,
    };
    if pid == std::process::id() {
        // Our own pid in a lease we failed to create: a previous claim of
        // this process (or a pid-reused corpse); treat as stale.
        return true;
    }
    #[cfg(target_os = "linux")]
    {
        !Path::new(&format!("/proc/{pid}")).exists()
    }
    #[cfg(not(target_os = "linux"))]
    {
        false
    }
}

pub(crate) fn claim(path: &Path) -> Result<Claim, SegmulError> {
    // Bounded retry: each loop either claims, reports Busy, or evicts a
    // provably dead holder; pathological churn (leases dying faster than
    // we can claim) gives up as Busy rather than spinning forever.
    for _ in 0..64 {
        match fs::OpenOptions::new().write(true).create_new(true).open(path) {
            Ok(mut f) => {
                let _ = writeln!(f, "{}", std::process::id());
                return Ok(Claim::Acquired(LeaseGuard { path: path.to_path_buf() }));
            }
            Err(e) if e.kind() == io::ErrorKind::AlreadyExists => {
                if !holder_is_dead(path) {
                    return Ok(Claim::Busy);
                }
                // Evict: rename the corpse to a unique tombstone. Exactly
                // one racing evictor's rename succeeds; everyone retries
                // the atomic create either way.
                let tomb =
                    path.with_extension(format!("stale.{}", std::process::id()));
                if fs::rename(path, &tomb).is_ok() {
                    let _ = fs::remove_file(&tomb);
                }
            }
            Err(e) => {
                return Err(SegmulError::store(path.display().to_string(), e.to_string()))
            }
        }
    }
    Ok(Claim::Busy)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmplease(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("segmul-lease-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir.join("key.lease")
    }

    #[test]
    fn claim_release_reclaim() {
        let path = tmplease("basic");
        let g = match claim(&path).unwrap() {
            Claim::Acquired(g) => g,
            Claim::Busy => panic!("fresh path must claim"),
        };
        assert!(path.exists());
        drop(g);
        assert!(!path.exists(), "drop must remove the lease");
        match claim(&path).unwrap() {
            Claim::Acquired(g) => g.release(),
            Claim::Busy => panic!("released path must re-claim"),
        }
    }

    #[test]
    fn own_pid_lease_is_reclaimed() {
        // A lease recorded under our own pid (a crashed previous claim of
        // this very process id) must not deadlock us.
        let path = tmplease("own");
        fs::write(&path, format!("{}\n", std::process::id())).unwrap();
        match claim(&path).unwrap() {
            Claim::Acquired(g) => g.release(),
            Claim::Busy => panic!("own-pid lease must be evicted"),
        }
    }

    #[test]
    fn garbage_lease_is_conservatively_busy() {
        let path = tmplease("garbage");
        fs::write(&path, "not-a-pid\n").unwrap();
        assert!(matches!(claim(&path).unwrap(), Claim::Busy));
    }
}
