//! The persistent, content-addressed result store.
//!
//! A sweep used to live and die with one process and its in-memory
//! [`crate::coordinator::JobKey`] cache. [`ResultStore`] moves the cache
//! onto disk so results survive preemption and can be produced by many
//! cooperating processes over a shared filesystem:
//!
//! * **Blobs** (`blobs/<addr>.json`, [`blob`]): one committed
//!   [`crate::coordinator::JobResult`] per canonical store key, written
//!   atomically (temp file + rename) with an FNV-1a integrity hash and
//!   the full key string embedded for collision/tamper detection. Loading
//!   is *lazy* (one file open per query, no directory scans) and
//!   *strict*: a truncated, bit-flipped, or schema-mismatched blob is a
//!   typed [`SegmulError::Store`], never a silently wrong answer.
//! * **Chunk journals** (`journal/<addr>.jsonl`, [`journal`]): the
//!   checkpointed chunk cursor. The pool's ordered merge appends one
//!   self-checking line per chunk, *in chunk-id order*, the moment the
//!   chunk folds into the in-order prefix. A killed process therefore
//!   leaves exactly a valid prefix (plus at most one torn tail line,
//!   discarded on recovery), and a resumed run re-folds that prefix
//!   through the same [`crate::error::stream::OrderedMerger`] — so the
//!   resumed result is **bit-identical** (f64 `sum_red` included) to an
//!   uninterrupted run.
//! * **Leases** (`leases/<addr>.lease`, [`lease`]): multi-process
//!   mutual exclusion via atomic `create_new`, so N processes sharding
//!   one grid never evaluate the same key twice; stale leases from dead
//!   processes are evicted by an atomic rename.
//!
//! The store key ([`StoreKey`]) extends the in-memory `JobKey` with the
//! backend name and batch size: `JobKey`'s own docs warn that the MC
//! operand multiset depends on the backend's chunk-to-stream layout, so
//! a *persistent* key must pin both — two runners only share blobs when
//! their chunk plans are identical.
//!
//! **Fault seams**: every store I/O class (blob read, blob commit,
//! journal append, lease claim) consults the process-wide
//! [`FaultInjector`] before touching the filesystem, so chaos runs can
//! deterministically exercise the exact recovery paths above — torn
//! commits, corrupted-then-sealed blobs, disabled journals, unavailable
//! leases — and prove answers stay bit-identical (see `fault/`).

#![warn(clippy::unwrap_used, clippy::expect_used)]

mod blob;
mod journal;
mod lease;

use std::fs;
use std::io;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::coordinator::{EvalJob, JobResult, SpecKey};
use crate::error::SegmulError;
use crate::fault::{FaultInjector, FaultSite};
use crate::util::json::{obj, Json};

pub use blob::StoredResult;
pub use journal::{JournalWriter, RecoveredJournal};
pub use lease::{Claim, LeaseGuard};

/// On-disk layout version. Bump on any incompatible change to the blob /
/// journal encoding; [`ResultStore::open`] refuses directories written by
/// a different schema, and CI keys its `actions/cache` entry on this.
pub const STORE_SCHEMA: u32 = 1;

/// FNV-1a 64-bit — the store's self-contained content/integrity hash (no
/// external crypto in this offline build; collision resistance is not a
/// goal, which is why blobs also embed and verify the full key string).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The persistent identity of one evaluation: the canonical
/// [`crate::coordinator::JobKey`] (canonical design + workload + seed /
/// sample budget) plus the backend name and batch size that fix the
/// chunk layout. Serialized as deterministic compact JSON; the FNV-1a
/// hash of that string is the blob address.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StoreKey {
    canonical: String,
    hash: u64,
}

impl StoreKey {
    /// The canonical key for `job` under `backend` with batch size `batch`.
    pub fn new(job: &EvalJob, backend: &str, batch: usize) -> StoreKey {
        let key = job.key();
        // u64 fields (seeds especially) are serialized as decimal strings:
        // the JSON codec's numbers are f64 and would round above 2^53.
        let workload = match &key.spec {
            SpecKey::Exhaustive => obj(vec![("kind", Json::from("exhaustive"))]),
            SpecKey::MonteCarlo { samples, seed } => obj(vec![
                ("kind", Json::from("mc")),
                ("samples", Json::Str(samples.to_string())),
                ("seed", Json::Str(seed.to_string())),
            ]),
            SpecKey::Adaptive { max_samples, seed, target_bits } => obj(vec![
                ("kind", Json::from("adaptive")),
                ("max_samples", Json::Str(max_samples.to_string())),
                ("seed", Json::Str(seed.to_string())),
                ("target_bits", Json::Str(format!("{target_bits:016x}"))),
            ]),
        };
        let id = obj(vec![
            ("backend", Json::from(backend)),
            ("batch", Json::from(batch as u64)),
            ("design", key.design.to_json()),
            ("schema", Json::from(STORE_SCHEMA as u64)),
            ("workload", workload),
        ]);
        let canonical = id.to_string_compact();
        let hash = fnv1a64(canonical.as_bytes());
        StoreKey { canonical, hash }
    }

    /// The full canonical identity string (embedded in blobs and verified
    /// on load, so an address collision can never serve a foreign result).
    pub fn canonical(&self) -> &str {
        &self.canonical
    }

    /// The content address: hex FNV-1a of [`Self::canonical`], used as
    /// the blob / journal / lease file stem.
    pub fn address(&self) -> String {
        format!("{:016x}", self.hash)
    }
}

/// The on-disk store. Cheap to open (four `mkdir -p` plus one schema
/// sentinel check); every query is lazy — one file open per key, no
/// directory scans, so a million-blob store costs nothing until read.
pub struct ResultStore {
    root: PathBuf,
    faults: Arc<FaultInjector>,
}

impl ResultStore {
    /// Open (creating if needed) the store rooted at `root`. Refuses a
    /// directory written by a different [`STORE_SCHEMA`]. Fault seams
    /// are armed from `SEGMUL_FAULTS` (disabled when unset).
    pub fn open(root: impl Into<PathBuf>) -> Result<ResultStore, SegmulError> {
        Self::open_with_faults(root, FaultInjector::from_env()?)
    }

    /// [`Self::open`] with an explicit fault plan (a session threads its
    /// own injector through so one plan accounts for the whole process).
    pub fn open_with_faults(
        root: impl Into<PathBuf>,
        faults: Arc<FaultInjector>,
    ) -> Result<ResultStore, SegmulError> {
        let root = root.into();
        for sub in ["blobs", "journal", "leases", "tmp"] {
            let dir = root.join(sub);
            fs::create_dir_all(&dir).map_err(|e| {
                SegmulError::store(dir.display().to_string(), format!("cannot create: {e}"))
            })?;
        }
        let sentinel = root.join("STORE_SCHEMA");
        match fs::read_to_string(&sentinel) {
            Ok(text) => {
                let found = text.trim().to_string();
                if found != STORE_SCHEMA.to_string() {
                    return Err(SegmulError::store(
                        sentinel.display().to_string(),
                        format!("store schema {found:?} != supported {STORE_SCHEMA}"),
                    ));
                }
            }
            Err(e) if e.kind() == io::ErrorKind::NotFound => {
                fs::write(&sentinel, format!("{STORE_SCHEMA}\n")).map_err(|e| {
                    SegmulError::store(sentinel.display().to_string(), e.to_string())
                })?;
            }
            Err(e) => {
                return Err(SegmulError::store(sentinel.display().to_string(), e.to_string()))
            }
        }
        Ok(ResultStore { root, faults })
    }

    /// The fault plan this store consults (for telemetry aggregation).
    pub fn faults(&self) -> &Arc<FaultInjector> {
        &self.faults
    }

    /// The store's root directory.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// The blob path for `key` (exposed so tests can corrupt it).
    pub fn blob_path(&self, key: &StoreKey) -> PathBuf {
        self.root.join("blobs").join(format!("{}.json", key.address()))
    }

    fn journal_path(&self, key: &StoreKey) -> PathBuf {
        self.root.join("journal").join(format!("{}.jsonl", key.address()))
    }

    /// The lease path for `key` (exposed for tests and diagnostics).
    pub fn lease_path(&self, key: &StoreKey) -> PathBuf {
        self.root.join("leases").join(format!("{}.lease", key.address()))
    }

    /// Load the committed result for `key`, if any. Strict: any
    /// corruption (torn write, bit flip, wrong schema, key mismatch
    /// behind a colliding address) is a typed [`SegmulError::Store`] —
    /// callers treat it as a miss and re-evaluate.
    pub fn load(&self, key: &StoreKey) -> Result<Option<StoredResult>, SegmulError> {
        let path = self.blob_path(key);
        if self.faults.fire(FaultSite::StoreRead) {
            return Err(SegmulError::store(
                path.display().to_string(),
                "injected read fault (EIO)",
            ));
        }
        let text = match fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(SegmulError::store(path.display().to_string(), e.to_string())),
        };
        blob::decode(&text, key)
            .map(Some)
            .map_err(|reason| SegmulError::store(path.display().to_string(), reason))
    }

    /// Commit a finished result: written to `tmp/`, then atomically
    /// renamed into `blobs/` — readers only ever see absent or complete
    /// blobs. The chunk journal is superseded and removed.
    pub fn commit(&self, key: &StoreKey, result: &JobResult) -> Result<(), SegmulError> {
        let text = blob::encode(key, result);
        let tmp = self
            .root
            .join("tmp")
            .join(format!("{}.{}.tmp", key.address(), std::process::id()));
        let path = self.blob_path(key);
        if self.faults.fire(FaultSite::StoreWrite) {
            // Torn short write: leave a truncated tmp file behind (never
            // renamed into blobs/, so readers cannot see it) and fail the
            // commit — the caller's answer in memory stays correct.
            let _ = fs::write(&tmp, &text.as_bytes()[..text.len() / 2]);
            return Err(SegmulError::store(
                path.display().to_string(),
                "commit failed: injected short write (EIO)",
            ));
        }
        let bytes = if self.faults.fire(FaultSite::StoreCorrupt) {
            // Silent media corruption: the commit "succeeds" but one
            // content byte is damaged — the blob's seal check must catch
            // it on the next load (counted recovery, job re-evaluated).
            let mut damaged = text.clone().into_bytes();
            let mid = damaged.len() / 2;
            damaged[mid] ^= 0x20;
            damaged
        } else {
            text.into_bytes()
        };
        fs::write(&tmp, &bytes)
            .and_then(|_| fs::rename(&tmp, &path))
            .map_err(|e| {
                SegmulError::store(path.display().to_string(), format!("commit failed: {e}"))
            })?;
        let _ = fs::remove_file(self.journal_path(key));
        Ok(())
    }

    /// Recover the checkpointed chunk prefix for `key`: the longest valid
    /// in-order journal prefix (a torn tail line — the normal SIGKILL
    /// artifact — and anything after a corrupt record is discarded and
    /// simply re-evaluated, so recovery is always sound).
    pub fn recover_journal(&self, key: &StoreKey) -> RecoveredJournal {
        journal::recover(&self.journal_path(key))
    }

    /// Open the chunk journal for appending at `valid_len` (from
    /// [`RecoveredJournal::valid_len`]; any invalid tail beyond it is
    /// truncated away first).
    pub fn journal_writer(
        &self,
        key: &StoreKey,
        valid_len: u64,
    ) -> Result<JournalWriter, SegmulError> {
        JournalWriter::open(self.journal_path(key), valid_len, self.faults.clone())
    }

    /// Try to claim the evaluation lease for `key` (multi-process mutual
    /// exclusion). See [`lease`] for the protocol.
    pub fn claim(&self, key: &StoreKey) -> Result<Claim, SegmulError> {
        let path = self.lease_path(key);
        if self.faults.fire(FaultSite::LeaseClaim) {
            return Err(SegmulError::store(
                path.display().to_string(),
                "injected lease I/O fault (EIO)",
            ));
        }
        lease::claim(&path)
    }

    /// Sweep the lease directory and evict every lease whose recorded
    /// holder is provably dead (single-winner per lease — safe to run
    /// concurrently with claimants and other reclaimers). Returns the
    /// number of leases this call evicted. The fleet supervisor runs
    /// this between shard restarts so a SIGKILLed shard's keys free up
    /// immediately instead of waiting for a claimant's probe.
    pub fn reclaim_dead_leases(&self) -> usize {
        let dir = self.root.join("leases");
        let entries = match fs::read_dir(&dir) {
            Ok(entries) => entries,
            Err(_) => return 0,
        };
        let mut evicted = 0;
        for entry in entries.flatten() {
            let path = entry.path();
            if path.extension().and_then(|e| e.to_str()) != Some("lease") {
                continue;
            }
            if lease::holder_is_dead(&path) && lease::evict(&path) {
                evicted += 1;
            }
        }
        evicted
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::coordinator::WorkSpec;
    use crate::multiplier::MultiplierSpec;
    use std::time::Duration;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("segmul-store-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn mc_job(seed: u64) -> EvalJob {
        EvalJob::mc(8, 3, true, 50_000, seed)
    }

    fn result_for(job: &EvalJob) -> JobResult {
        use crate::coordinator::{run_job, CpuBackend};
        let mut be = CpuBackend::new();
        run_job(&mut be, job).unwrap()
    }

    #[test]
    fn fnv_reference_vectors() {
        // Published FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x8594_4171_f739_67e8);
    }

    #[test]
    fn key_canonicalizes_like_the_cache_and_pins_the_runner() {
        let fix_t0 = EvalJob::exhaustive(8, 0, true);
        let nofix_t0 = EvalJob::exhaustive(8, 0, false);
        let accurate =
            EvalJob::new(MultiplierSpec::Accurate { n: 8 }, WorkSpec::Exhaustive);
        // Same canonicalization as JobKey: the t=0 twins and the accurate
        // design share one persistent identity.
        assert_eq!(StoreKey::new(&fix_t0, "cpu", 64), StoreKey::new(&nofix_t0, "cpu", 64));
        assert_eq!(StoreKey::new(&fix_t0, "cpu", 64), StoreKey::new(&accurate, "cpu", 64));
        // ...but the backend name and batch size are part of the key:
        // persisted results never cross runners with different chunk
        // layouts (the JobKey soundness caveat).
        assert_ne!(StoreKey::new(&fix_t0, "cpu", 64), StoreKey::new(&fix_t0, "pjrt", 64));
        assert_ne!(StoreKey::new(&fix_t0, "cpu", 64), StoreKey::new(&fix_t0, "cpu", 128));
        // Distinct workloads and seeds are distinct keys, even above 2^53.
        let huge_seed = EvalJob::mc(8, 3, true, 50_000, (1u64 << 60) + 1);
        let huge_seed2 = EvalJob::mc(8, 3, true, 50_000, (1u64 << 60) + 2);
        assert_ne!(
            StoreKey::new(&huge_seed, "cpu", 64).address(),
            StoreKey::new(&huge_seed2, "cpu", 64).address()
        );
    }

    #[test]
    fn blob_roundtrip_is_exact() {
        let dir = tmpdir("roundtrip");
        let store = ResultStore::open(&dir).unwrap();
        let job = mc_job(7);
        let key = StoreKey::new(&job, "cpu", 1 << 13);
        assert!(store.load(&key).unwrap().is_none());
        let result = result_for(&job);
        store.commit(&key, &result).unwrap();
        let hit = store.load(&key).unwrap().expect("committed blob must load");
        // Bit-exact round trip: every integer field, the f64 sum_red bit
        // pattern, and the accounting fields.
        assert_eq!(hit.stats, result.stats);
        assert_eq!(hit.stats.sum_red.to_bits(), result.stats.sum_red.to_bits());
        assert_eq!(hit.batches, result.batches);
        assert_eq!(hit.wall, result.wall);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn reopen_checks_schema_sentinel() {
        let dir = tmpdir("schema");
        ResultStore::open(&dir).unwrap();
        // Same schema: reopen fine.
        ResultStore::open(&dir).unwrap();
        fs::write(dir.join("STORE_SCHEMA"), "999\n").unwrap();
        let err = ResultStore::open(&dir).unwrap_err();
        assert_eq!(err.kind(), "store");
        assert!(err.to_string().contains("999"), "{err}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn journal_roundtrip_and_torn_tail_recovery() {
        let dir = tmpdir("journal");
        let store = ResultStore::open(&dir).unwrap();
        let job = mc_job(3);
        let key = StoreKey::new(&job, "cpu", 1 << 13);
        let empty = store.recover_journal(&key);
        assert!(empty.chunks.is_empty());
        assert_eq!(empty.valid_len, 0);

        // Append three chunks, in order.
        let mut chunks = Vec::new();
        for i in 0..3u64 {
            let mut s = crate::error::metrics::ErrorStats::new(8);
            s.record(100 + i, 90);
            chunks.push(s);
        }
        let mut w = store.journal_writer(&key, 0).unwrap();
        for (i, s) in chunks.iter().enumerate() {
            w.append(i as u64, s);
        }
        drop(w);
        let rec = store.recover_journal(&key);
        assert_eq!(rec.chunks, chunks);
        assert_eq!(rec.discarded_bytes, 0);

        // A torn tail line (the SIGKILL artifact) is discarded; the valid
        // prefix survives and the writer truncates the tear away.
        let path = dir.join("journal").join(format!("{}.jsonl", key.address()));
        let mut bytes = fs::read(&path).unwrap();
        let tear = bytes.len() as u64;
        bytes.extend_from_slice(b"{\"chunk\":\"3\",\"stats\":{\"n\":8,");
        fs::write(&path, &bytes).unwrap();
        let rec = store.recover_journal(&key);
        assert_eq!(rec.chunks, chunks);
        assert_eq!(rec.valid_len, tear);
        assert!(rec.discarded_bytes > 0);
        let mut w = store.journal_writer(&key, rec.valid_len).unwrap();
        let mut s3 = crate::error::metrics::ErrorStats::new(8);
        s3.record(7, 7);
        w.append(3, &s3);
        drop(w);
        let rec = store.recover_journal(&key);
        assert_eq!(rec.chunks.len(), 4);
        assert_eq!(rec.chunks[3], s3);

        // A corrupt *interior* record cuts the prefix there, soundly.
        let text = fs::read_to_string(&path).unwrap();
        let flipped = text.replacen("\"chunk\":\"1\"", "\"chunk\":\"9\"", 1);
        fs::write(&path, flipped).unwrap();
        let rec = store.recover_journal(&key);
        assert_eq!(rec.chunks.len(), 1, "prefix must stop at the bad record");
        assert_eq!(rec.chunks[0], chunks[0]);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn commit_supersedes_journal() {
        let dir = tmpdir("supersede");
        let store = ResultStore::open(&dir).unwrap();
        let job = mc_job(9);
        let key = StoreKey::new(&job, "cpu", 1 << 13);
        let mut w = store.journal_writer(&key, 0).unwrap();
        let mut s = crate::error::metrics::ErrorStats::new(8);
        s.record(3, 2);
        w.append(0, &s);
        drop(w);
        assert_eq!(store.recover_journal(&key).chunks.len(), 1);
        store.commit(&key, &result_for(&job)).unwrap();
        assert!(store.recover_journal(&key).chunks.is_empty(), "journal removed on commit");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn lease_excludes_live_holders_and_evicts_dead_ones() {
        let dir = tmpdir("lease");
        let store = ResultStore::open(&dir).unwrap();
        let job = mc_job(11);
        let key = StoreKey::new(&job, "cpu", 1 << 13);
        // First claim wins...
        let guard = match store.claim(&key).unwrap() {
            Claim::Acquired(g) => g,
            Claim::Busy => panic!("fresh lease must be acquirable"),
        };
        // ...and excludes a second claimant while this (live) process
        // holds it.
        assert!(matches!(store.claim(&key).unwrap(), Claim::Busy));
        drop(guard);
        // Released: claimable again.
        let guard = match store.claim(&key).unwrap() {
            Claim::Acquired(g) => g,
            Claim::Busy => panic!("released lease must be acquirable"),
        };
        guard.release();
        // A lease left behind by a dead process (a pid that cannot exist)
        // is evicted and re-claimed.
        fs::write(store.lease_path(&key), "4294967295\n").unwrap();
        match store.claim(&key).unwrap() {
            Claim::Acquired(g) => g.release(),
            Claim::Busy => panic!("stale lease must be evicted"),
        }
        // An unreadable lease (no pid yet: a claimant between create and
        // write) is conservatively treated as live.
        fs::write(store.lease_path(&key), "").unwrap();
        assert!(matches!(store.claim(&key).unwrap(), Claim::Busy));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn wall_roundtrips_exact_nanos() {
        let dir = tmpdir("wall");
        let store = ResultStore::open(&dir).unwrap();
        let job = mc_job(13);
        let key = StoreKey::new(&job, "cpu", 1 << 13);
        let mut result = result_for(&job);
        result.wall = Duration::new(1234, 567_891_234);
        store.commit(&key, &result).unwrap();
        let hit = store.load(&key).unwrap().unwrap();
        assert_eq!(hit.wall, result.wall);
        let _ = fs::remove_dir_all(&dir);
    }
}
