//! Atomic file writes: the tmp + rename idiom of the result store
//! (`crate::store`), shared so report writers never leave a torn file.
//!
//! Invariant: a reader at `path` sees either the previous complete
//! contents or the new complete contents — never a prefix. The bytes are
//! first written to a process-unique sibling under the same directory
//! (same filesystem, so the rename cannot degrade to a copy), then
//! [`std::fs::rename`]d into place, which POSIX guarantees is atomic.

use std::path::Path;

use crate::error::SegmulError;

/// Write `bytes` to `path` atomically (tmp sibling + rename), creating
/// parent directories as needed. Failures are typed [`SegmulError::Io`]
/// naming the destination; the destination is never left truncated —
/// at worst an orphaned `.tmp` sibling remains, which a retry overwrites.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> Result<(), SegmulError> {
    let parent = path.parent().filter(|p| !p.as_os_str().is_empty());
    if let Some(dir) = parent {
        std::fs::create_dir_all(dir).map_err(|e| {
            SegmulError::Io(format!("creating {}: {e}", dir.display()))
        })?;
    }
    let file_name = path
        .file_name()
        .ok_or_else(|| SegmulError::Io(format!("{}: not a file path", path.display())))?;
    let mut tmp_name = file_name.to_os_string();
    tmp_name.push(format!(".{}.tmp", std::process::id()));
    let tmp = path.with_file_name(tmp_name);
    std::fs::write(&tmp, bytes)
        .and_then(|_| std::fs::rename(&tmp, path))
        .map_err(|e| {
            // Never leave the torn tmp behind on failure.
            let _ = std::fs::remove_file(&tmp);
            SegmulError::Io(format!("writing {}: {e}", path.display()))
        })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("segmul-fsio-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn writes_and_overwrites_through_rename() {
        let dir = tmpdir("basic");
        let path = dir.join("nested").join("out.csv");
        write_atomic(&path, b"first").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"first");
        write_atomic(&path, b"second").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"second");
        // No tmp siblings survive a successful write.
        let leftovers: Vec<_> = std::fs::read_dir(path.parent().unwrap())
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().ends_with(".tmp"))
            .collect();
        assert!(leftovers.is_empty(), "{leftovers:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn failure_is_typed_io_and_leaves_no_tmp() {
        let dir = tmpdir("fail");
        std::fs::create_dir_all(&dir).unwrap();
        // Destination is a directory: the rename must fail.
        let path = dir.join("blocked");
        std::fs::create_dir_all(&path).unwrap();
        let e = write_atomic(&path, b"x").unwrap_err();
        assert_eq!(e.kind(), "io");
        assert!(e.to_string().contains("blocked"), "{e}");
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().ends_with(".tmp"))
            .collect();
        assert!(leftovers.is_empty(), "{leftovers:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
