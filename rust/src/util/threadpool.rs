//! Scoped parallel-map built on `std::thread::scope`.
//!
//! rayon is not available offline; this provides the one primitive the
//! evaluators need: split an index range across worker threads and fold the
//! partial results. On the 1-core CI box this degenerates gracefully to a
//! sequential loop (no thread spawn when `workers == 1`).

use crate::error::SegmulError;

/// Parse a `SEGMUL_WORKERS`-style override. Absent or blank values mean
/// "no override" (`Ok(None)`); `0` and unparsable values are rejected
/// with a typed [`SegmulError::Config`] instead of being silently
/// clamped — a pinned-but-impossible worker count is a configuration
/// bug the caller must see.
pub fn workers_override(value: Option<&str>) -> Result<Option<usize>, SegmulError> {
    let Some(v) = value else { return Ok(None) };
    let v = v.trim();
    if v.is_empty() {
        return Ok(None);
    }
    match v.parse::<usize>() {
        Ok(0) => Err(SegmulError::config(
            "SEGMUL_WORKERS=0: worker count must be >= 1",
        )),
        Ok(w) => Ok(Some(w)),
        Err(_) => Err(SegmulError::config(format!(
            "SEGMUL_WORKERS={v:?} is not a positive integer"
        ))),
    }
}

/// Number of worker threads to use by default: the `SEGMUL_WORKERS`
/// environment variable when set (so CI and benches can pin worker
/// counts deterministically), else the machine's available parallelism.
/// An invalid override (`0`, non-numeric) is a typed configuration
/// error, surfaced by the CLI and by [`crate::api::SessionBuilder`].
pub fn default_workers() -> Result<usize, SegmulError> {
    if let Ok(v) = std::env::var("SEGMUL_WORKERS") {
        if let Some(w) = workers_override(Some(&v))? {
            return Ok(w);
        }
    }
    Ok(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
}

/// Split `[0, len)` into `parts` near-equal contiguous chunks.
pub fn chunks(len: u64, parts: usize) -> Vec<(u64, u64)> {
    let parts = parts.max(1) as u64;
    let base = len / parts;
    let rem = len % parts;
    let mut out = Vec::with_capacity(parts as usize);
    let mut start = 0;
    for i in 0..parts {
        let sz = base + if i < rem { 1 } else { 0 };
        if sz == 0 {
            continue;
        }
        out.push((start, start + sz));
        start += sz;
    }
    out
}

/// Run `work(chunk_index, start, end)` over `[0, len)` split across
/// `workers` threads, then fold the partial results with `fold`.
pub fn parallel_fold<T, F, G>(len: u64, workers: usize, work: F, fold: G) -> Option<T>
where
    T: Send,
    F: Fn(usize, u64, u64) -> T + Sync,
    G: Fn(T, T) -> T,
{
    let parts = chunks(len, workers);
    if parts.is_empty() {
        return None;
    }
    if parts.len() == 1 {
        let (s, e) = parts[0];
        return Some(work(0, s, e));
    }
    let results: Vec<T> = std::thread::scope(|scope| {
        let handles: Vec<_> = parts
            .iter()
            .enumerate()
            .map(|(i, &(s, e))| {
                let work = &work;
                scope.spawn(move || work(i, s, e))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    });
    results.into_iter().reduce(fold)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_exactly_once() {
        for len in [0u64, 1, 7, 100, 101] {
            for parts in [1usize, 2, 3, 8, 200] {
                let cs = chunks(len, parts);
                let mut covered = 0u64;
                let mut prev_end = 0u64;
                for (s, e) in &cs {
                    assert_eq!(*s, prev_end, "gap/overlap at {s}");
                    assert!(e > s);
                    covered += e - s;
                    prev_end = *e;
                }
                assert_eq!(covered, len, "len={len} parts={parts}");
            }
        }
    }

    #[test]
    fn fold_sums_range() {
        let total = parallel_fold(
            1000,
            4,
            |_, s, e| (s..e).sum::<u64>(),
            |a, b| a + b,
        )
        .unwrap();
        assert_eq!(total, (0..1000u64).sum::<u64>());
    }

    #[test]
    fn single_worker_no_threads() {
        let total = parallel_fold(10, 1, |_, s, e| e - s, |a, b| a + b).unwrap();
        assert_eq!(total, 10);
    }

    #[test]
    fn empty_range() {
        assert!(parallel_fold(0, 4, |_, _, _| 0u64, |a, b| a + b).is_none());
    }

    #[test]
    fn workers_override_parsing() {
        assert_eq!(workers_override(None).unwrap(), None);
        assert_eq!(workers_override(Some("")).unwrap(), None);
        assert_eq!(workers_override(Some("4")).unwrap(), Some(4));
        assert_eq!(workers_override(Some(" 7 ")).unwrap(), Some(7));
    }

    #[test]
    fn workers_override_rejects_zero_with_typed_config_error() {
        // Regression: an explicit 0 used to clamp silently to 1; it must
        // now surface as a typed configuration error.
        let e = workers_override(Some("0")).unwrap_err();
        assert_eq!(e.kind(), "config");
        assert!(e.to_string().contains("SEGMUL_WORKERS=0"), "{e}");
        // Unparsable values are configuration errors too.
        assert_eq!(workers_override(Some("abc")).unwrap_err().kind(), "config");
        assert_eq!(workers_override(Some("-2")).unwrap_err().kind(), "config");
    }

    #[test]
    fn default_workers_is_positive() {
        // CI pins SEGMUL_WORKERS to a valid value; locally the env is
        // either unset or valid, so this must produce >= 1.
        assert!(default_workers().unwrap() >= 1);
    }
}
