//! Small self-contained substrates (no external crates available offline):
//! a JSON codec, a counter-based PRNG, a scoped thread pool, and a
//! lightweight property-testing helper.

pub mod cli;
pub mod fsio;
pub mod json;
pub mod prop;
pub mod rng;
pub mod threadpool;
