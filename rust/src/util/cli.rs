//! Minimal CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `segmul <subcommand> [--flag] [--key value] [positional...]`.
//! Flags and options may appear in any order after the subcommand.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// The first bare argument (`segmul <subcommand>`).
    pub subcommand: Option<String>,
    /// Bare arguments after the subcommand.
    pub positional: Vec<String>,
    /// `--name value` pairs (last occurrence wins).
    pub options: BTreeMap<String, String>,
    /// Bare `--name` switches.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args> {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare '--' not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = iter.next().unwrap();
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    /// Parse `std::env::args`.
    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    /// Whether bare switch `--name` was passed.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// The value of `--name value`, if present.
    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// `--name` parsed as `u64` (typed config error on garbage).
    pub fn opt_u64(&self, name: &str) -> Result<Option<u64>> {
        self.opt(name)
            .map(|v| {
                v.replace('_', "")
                    .parse::<u64>()
                    .map_err(|_| anyhow!("--{name} expects an integer, got {v:?}"))
            })
            .transpose()
    }

    /// `--name` parsed as `u32`.
    pub fn opt_u32(&self, name: &str) -> Result<Option<u32>> {
        Ok(self.opt_u64(name)?.map(|v| v as u32))
    }

    /// `--name` parsed as `f64`.
    pub fn opt_f64(&self, name: &str) -> Result<Option<f64>> {
        self.opt(name)
            .map(|v| v.parse::<f64>().map_err(|_| anyhow!("--{name} expects a float, got {v:?}")))
            .transpose()
    }

    /// Required option helper.
    pub fn req_u32(&self, name: &str) -> Result<u32> {
        self.opt_u32(name)?.ok_or_else(|| anyhow!("missing required --{name}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn subcommand_options_flags() {
        let a = parse("eval --n 8 --t 4 --fix --samples 1_000");
        assert_eq!(a.subcommand.as_deref(), Some("eval"));
        assert_eq!(a.req_u32("n").unwrap(), 8);
        assert_eq!(a.opt_u32("t").unwrap(), Some(4));
        assert!(a.flag("fix"));
        assert_eq!(a.opt_u64("samples").unwrap(), Some(1000));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("figures --out=results fig2");
        assert_eq!(a.opt("out"), Some("results"));
        assert_eq!(a.positional, vec!["fig2"]);
    }

    #[test]
    fn trailing_flag() {
        let a = parse("eval --fix");
        assert!(a.flag("fix"));
        assert_eq!(a.opt("fix"), None);
    }

    #[test]
    fn type_errors() {
        let a = parse("eval --n abc");
        assert!(a.opt_u32("n").is_err());
        assert!(a.req_u32("missing").is_err());
    }
}
