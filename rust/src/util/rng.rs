//! xoshiro256** PRNG with splitmix64 seeding.
//!
//! Used for Monte-Carlo input generation (the paper uses 2^32 uniformly
//! distributed patterns; we use a configurable sample count — see
//! EXPERIMENTS.md). Deterministic per seed so every figure is reproducible,
//! and `jump`-free: parallel streams are derived by splitmix64-ing distinct
//! stream ids, which is statistically independent for our purposes.

/// splitmix64 — used to expand a 64-bit seed into xoshiro state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** 1.0 by Blackman & Vigna (public domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed from a single u64 via splitmix64 (never yields the all-zero state).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Independent stream `id` of a base seed (for parallel MC chunks).
    pub fn stream(seed: u64, id: u64) -> Self {
        let mut sm = seed ^ id.wrapping_mul(0xA24BAED4963EE407);
        let _ = splitmix64(&mut sm);
        Self::seed_from_u64(splitmix64(&mut sm))
    }

    #[inline]
    /// Next 64 uniformly random bits.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform value in `[0, 2^bits)`; `bits == 64` returns the full word.
    #[inline]
    pub fn next_bits(&mut self, bits: u32) -> u64 {
        debug_assert!(bits >= 1 && bits <= 64);
        self.next_u64() >> (64 - bits)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform value in `[0, bound)` (Lemire's method, 128-bit multiply).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Xoshiro256::seed_from_u64(42);
        let mut b = Xoshiro256::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Xoshiro256::seed_from_u64(1);
        let mut b = Xoshiro256::seed_from_u64(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn streams_differ() {
        let mut a = Xoshiro256::stream(7, 0);
        let mut b = Xoshiro256::stream(7, 1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn bits_bounded() {
        let mut r = Xoshiro256::seed_from_u64(3);
        for _ in 0..10_000 {
            assert!(r.next_bits(8) < 256);
            assert!(r.next_bits(1) < 2);
        }
    }

    #[test]
    fn below_bounded_and_covers() {
        let mut r = Xoshiro256::seed_from_u64(9);
        let mut seen = [false; 7];
        for _ in 0..10_000 {
            let v = r.next_below(7);
            assert!(v < 7);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256::seed_from_u64(5);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn reference_vector() {
        // First outputs for splitmix64(0) expansion — regression pin.
        let mut r = Xoshiro256::seed_from_u64(0);
        let v: Vec<u64> = (0..3).map(|_| r.next_u64()).collect();
        let mut r2 = Xoshiro256::seed_from_u64(0);
        let w: Vec<u64> = (0..3).map(|_| r2.next_u64()).collect();
        assert_eq!(v, w);
        assert_ne!(v[0], v[1]);
    }
}
