//! Lightweight property-testing helper (proptest is unavailable offline).
//!
//! `Cases` drives a closure with seeded pseudo-random inputs and reports the
//! first failing case with its seed so it can be replayed; `forall_u64`
//! et al. are convenience drivers used by the invariant tests across the
//! crate (multiplier equivalences, coordinator chunking, metric merges).

use super::rng::Xoshiro256;

/// A deterministic case driver: `n_cases` random trials from `seed`.
pub struct Cases {
    /// Base seed; every case derives its own stream from it.
    pub seed: u64,
    /// Number of cases to run.
    pub n_cases: usize,
}

impl Default for Cases {
    fn default() -> Self {
        Self { seed: 0xC0FFEE, n_cases: 256 }
    }
}

impl Cases {
    /// A driver with explicit seed and case count.
    pub fn new(seed: u64, n_cases: usize) -> Self {
        Self { seed, n_cases }
    }

    /// Run `f(rng, case_index)`; panics with seed/case info on failure so the
    /// failure is reproducible.
    pub fn run<F>(&self, mut f: F)
    where
        F: FnMut(&mut Xoshiro256, usize),
    {
        for case in 0..self.n_cases {
            let mut rng = Xoshiro256::stream(self.seed, case as u64);
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                f(&mut rng, case)
            }));
            if let Err(e) = result {
                let msg = e
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "<non-string panic>".into());
                panic!(
                    "property failed at case {case} (replay: Cases::new({}, ..)): {msg}",
                    self.seed
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let mut count = 0;
        Cases::new(1, 50).run(|_, _| count += 1);
        assert_eq!(count, 50);
    }

    #[test]
    fn deterministic_inputs() {
        let mut first: Vec<u64> = Vec::new();
        Cases::new(2, 10).run(|rng, _| first.push(rng.next_u64()));
        let mut second: Vec<u64> = Vec::new();
        Cases::new(2, 10).run(|rng, _| second.push(rng.next_u64()));
        assert_eq!(first, second);
    }

    #[test]
    #[should_panic(expected = "property failed at case")]
    fn reports_failing_case() {
        Cases::new(3, 10).run(|_, case| assert!(case < 5));
    }
}
