//! Minimal JSON codec (parser + writer).
//!
//! serde/serde_json are not available in this offline environment, so the
//! artifact manifest, config files, and report outputs use this
//! self-contained implementation. Supports the full JSON grammar except
//! `\u` surrogate pairs beyond the BMP (sufficient for our ASCII manifests).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Object keys are sorted (BTreeMap) so serialization
/// is deterministic — handy for golden tests.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any number (f64 — integers above 2^53 may round).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (keys sorted).
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
/// Parse failure with its byte position.
pub struct JsonError {
    /// What went wrong.
    pub msg: String,
    /// Byte offset in the source.
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse one JSON document (trailing garbage is an error).
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The value as an exact `u64`, if this is a non-negative integer.
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|f| {
            if f >= 0.0 && f.fract() == 0.0 && f <= u64::MAX as f64 {
                Some(f as u64)
            } else {
                None
            }
        })
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The boolean value, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Object field `key`, if present.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (k, v) in a.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (k, (key, v)) in m.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, key);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}

/// Build a `Json::Obj` from key/value pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", word)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            m.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            if self.i + 5 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(j.get("c"), Some(&Json::Bool(false)));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"batch":65536,"modules":[{"n":4,"name":"seqmul_stats_n4"}]}"#;
        let j = Json::parse(src).unwrap();
        let again = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(j, again);
        let pretty = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, pretty);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn u64_accessor() {
        assert_eq!(Json::parse("65536").unwrap().as_u64(), Some(65536));
        assert_eq!(Json::parse("1.5").unwrap().as_u64(), None);
        assert_eq!(Json::parse("-3").unwrap().as_u64(), None);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(Json::parse("[]").unwrap().to_string_compact(), "[]");
    }

    #[test]
    fn real_manifest_shape() {
        let src = r#"{
          "batch": 8,
          "modules": [
            {"name": "m", "n": 4, "inputs": [{"name":"a","dtype":"u64","shape":[8]}],
             "output": {"dtype":"f64","shape":[14]}}
          ]}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("batch").unwrap().as_u64(), Some(8));
        let m = &j.get("modules").unwrap().as_arr().unwrap()[0];
        assert_eq!(m.get("output").unwrap().get("dtype").unwrap().as_str(), Some("f64"));
    }
}
