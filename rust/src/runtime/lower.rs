//! Design-agnostic artifact lowering: every [`MultiplierSpec`] registry
//! family → a lowered, branch-free module executable on the PJRT backend.
//!
//! The AOT pipeline (`make artifacts`) lowers only the segmented family;
//! this module closes the gap for the rest of the registry so a
//! `--designs all` sweep never falls back to the CPU backend. Each design
//! is lowered to a **straight-line program** over a tiny lane-wise tensor
//! IR ("segir"): two `u64[batch]` inputs, a sequence of SSA instructions
//! (wrapping arithmetic, bitwise ops, immediate and lane-variable shifts,
//! `lzcnt`, zero-tests), and one return register. Loops are fully
//! unrolled at lowering time — every configuration axis (`n`, `t`, `k`,
//! break lines, fix mode) is baked into the module, exactly like the HLO
//! artifacts bake theirs — so the program has uniform latency and no
//! data-dependent control flow, the same contract the batch kernels of
//! [`crate::multiplier::batch_baselines`] satisfy:
//!
//! * **Truncation / broken-array** — one wide multiply over the surviving
//!   high rows plus `k` masked adds.
//! * **Mitchell** — leading-one detect via `clz`, the two piecewise
//!   antilog cases as a mask select on the mantissa-sum carry.
//! * **Kulkarni** — the closed form `a*b − 2·f(a)·f(b)` with the SWAR
//!   digit marker `f(x) = x & (x>>1) & 0x5555…`.
//! * **Segmented / accurate** — the branch-free word-level recurrence of
//!   [`crate::multiplier::batch`], unrolled over `j ∈ 1..n`.
//! * **Bit-level / netlist** — lowered to the same word-level recurrence:
//!   all three compute the identical product function (the paper's §IV
//!   equivalence, pinned for every `(n, t, fix)` by
//!   `tests/kernel_differential.rs` and re-pinned PJRT-vs-CPU by
//!   `tests/pjrt_lowered_differential.rs`).
//!
//! Modules serialize to a versioned text format (`segir 1`) referenced by
//! the schema-v2 manifest ([`super::artifact`]); [`emit_artifacts`] is the
//! emitter behind `segmul lower`. [`LoweredExec`] is the software
//! executor the stub PJRT client dispatches through — it interprets the
//! program tile-by-tile over the operand batch (one pass per instruction,
//! lane-parallel within a tile), which keeps the register file L1-resident
//! while preserving the one-execution-per-batch accounting of the real
//! PJRT path.

use std::path::Path;

use crate::error::SegmulError;
use crate::multiplier::MultiplierSpec;
use crate::util::json::{obj, Json};

use super::artifact::{Manifest, SCHEMA_VERSION};

/// SSA register index: `%0` = operand `a`, `%1` = operand `b`,
/// instruction `i` writes `%(2+i)`.
pub type Reg = u32;

/// One lane-wise instruction. All arithmetic wraps; shift-by-register
/// amounts are masked to `& 63`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    /// Broadcast an immediate into every lane.
    Const(u64),
    /// Lane-wise wrapping multiply.
    Mul(Reg, Reg),
    /// Lane-wise wrapping add.
    Add(Reg, Reg),
    /// Lane-wise wrapping subtract.
    Sub(Reg, Reg),
    /// Lane-wise bitwise AND.
    And(Reg, Reg),
    /// Lane-wise bitwise OR.
    Or(Reg, Reg),
    /// Lane-wise bitwise XOR.
    Xor(Reg, Reg),
    /// Shift by a lowering-time immediate (`imm < 64`).
    Shl(Reg, u32),
    /// Shift right by a lowering-time immediate (`imm < 64`).
    Shr(Reg, u32),
    /// Shift by a lane-wise register amount (masked `& 63`).
    Shlv(Reg, Reg),
    /// Shift right by a lane-wise register amount (masked `& 63`).
    Shrv(Reg, Reg),
    /// Lane-wise bitwise NOT.
    Not(Reg),
    /// Two's-complement negation — turns a 0/1 lane into a 0/all-ones mask.
    Neg(Reg),
    /// 1 when the lane is nonzero, else 0.
    Nez(Reg),
    /// `leading_zeros` as a lane value (0..=64).
    Clz(Reg),
}

/// A lowered straight-line module: `ret = f(a, b)` lane-wise.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Program {
    /// Operand bit-width the module was lowered for (operands `< 2^n`).
    pub n: u32,
    /// Straight-line ops in execution order.
    pub ops: Vec<Op>,
    /// Register holding the per-lane result.
    pub ret: Reg,
}

// ---------------------------------------------------------------------------
// Lowering (emission)
// ---------------------------------------------------------------------------

/// SSA builder with constant memoization.
struct Lowerer {
    ops: Vec<Op>,
    consts: std::collections::BTreeMap<u64, Reg>,
}

const A: Reg = 0;
const B: Reg = 1;

impl Lowerer {
    fn new() -> Self {
        Lowerer { ops: Vec::new(), consts: std::collections::BTreeMap::new() }
    }

    fn push(&mut self, op: Op) -> Reg {
        if let Op::Shl(_, s) | Op::Shr(_, s) = op {
            debug_assert!(s < 64, "immediate shift out of range");
        }
        self.ops.push(op);
        1 + self.ops.len() as Reg
    }

    fn konst(&mut self, v: u64) -> Reg {
        if let Some(&r) = self.consts.get(&v) {
            return r;
        }
        let r = self.push(Op::Const(v));
        self.consts.insert(v, r);
        r
    }

    fn mul(&mut self, x: Reg, y: Reg) -> Reg {
        self.push(Op::Mul(x, y))
    }
    fn add(&mut self, x: Reg, y: Reg) -> Reg {
        self.push(Op::Add(x, y))
    }
    fn sub(&mut self, x: Reg, y: Reg) -> Reg {
        self.push(Op::Sub(x, y))
    }
    fn and(&mut self, x: Reg, y: Reg) -> Reg {
        self.push(Op::And(x, y))
    }
    fn or(&mut self, x: Reg, y: Reg) -> Reg {
        self.push(Op::Or(x, y))
    }
    fn shl(&mut self, x: Reg, s: u32) -> Reg {
        self.push(Op::Shl(x, s))
    }
    fn shr(&mut self, x: Reg, s: u32) -> Reg {
        self.push(Op::Shr(x, s))
    }
    fn shlv(&mut self, x: Reg, s: Reg) -> Reg {
        self.push(Op::Shlv(x, s))
    }
    fn shrv(&mut self, x: Reg, s: Reg) -> Reg {
        self.push(Op::Shrv(x, s))
    }
    fn not(&mut self, x: Reg) -> Reg {
        self.push(Op::Not(x))
    }
    fn neg(&mut self, x: Reg) -> Reg {
        self.push(Op::Neg(x))
    }
    fn nez(&mut self, x: Reg) -> Reg {
        self.push(Op::Nez(x))
    }
    fn clz(&mut self, x: Reg) -> Reg {
        self.push(Op::Clz(x))
    }

    /// All-ones mask of bit `j` of `x` (the AND-mask form of the scalar
    /// models' `((x >> j) & 1).wrapping_neg()`).
    fn bit_mask(&mut self, x: Reg, j: u32) -> Reg {
        let one = self.konst(1);
        let b = self.shr(x, j);
        let b1 = self.and(b, one);
        self.neg(b1)
    }

    fn finish(self, n: u32, ret: Reg) -> Program {
        Program { n, ops: self.ops, ret }
    }
}

/// The branch-free segmented-carry recurrence of
/// [`crate::multiplier::batch::approx_seq_mul_batch`], unrolled over
/// `j ∈ 1..n` (also the lowering of the accurate design at `t = 0`).
fn lower_segmented(l: &mut Lowerer, n: u32, t: u32, fix: bool) -> Reg {
    let one = l.konst(1);
    let mt = l.konst((1u64 << t) - 1);
    // s = a & -(b & 1)
    let b0 = l.and(B, one);
    let m0 = l.neg(b0);
    let mut s = l.and(A, m0);
    let mut cff = l.konst(0);
    let mut low = l.konst(0);
    for j in 1..n {
        let sbit = l.and(s, one);
        let sl = l.shl(sbit, j - 1);
        low = l.or(low, sl);
        let x = l.shr(s, 1);
        let ppm = l.bit_mask(B, j);
        let pp = l.and(A, ppm);
        let xm = l.and(x, mt);
        let ppl = l.and(pp, mt);
        let lsum = l.add(xm, ppl);
        let lst = l.shr(lsum, t);
        let clsp = l.and(lst, one);
        let xh = l.shr(x, t);
        let pph = l.shr(pp, t);
        let mh = l.add(xh, pph);
        let msum = l.add(mh, cff);
        let msh = l.shl(msum, t);
        let lsl = l.and(lsum, mt);
        s = l.or(msh, lsl);
        cff = clsp;
    }
    let sh = l.shl(s, n - 1);
    let mut phat = l.or(sh, low);
    if fix {
        // Lanes with the compensated carry raised force the n+t LSBs to 1.
        let fm = l.neg(cff);
        let bits = l.konst((1u64 << (n + t)) - 1);
        let fbits = l.and(fm, bits);
        phat = l.or(phat, fbits);
    }
    phat
}

/// Vertical truncation: one wide multiply over rows `j >= k` plus `k`
/// masked adds (mirrors `trunc_mul_one`).
fn lower_truncated(l: &mut Lowerer, k: u32) -> Reg {
    let bh = l.shr(B, k);
    let bh2 = l.shl(bh, k);
    let mut p = l.mul(A, bh2);
    for j in 0..k {
        let av = l.shr(A, k - j);
        let avs = l.shl(av, k);
        let m = l.bit_mask(B, j);
        let term = l.and(avs, m);
        p = l.add(p, term);
    }
    p
}

/// Broken-array: rows `< hbl` and columns `< vbl` dropped (mirrors
/// `bam_mul_one`).
fn lower_broken_array(l: &mut Lowerer, hbl: u32, vbl: u32) -> Reg {
    let cut = hbl.max(vbl);
    let bh = l.shr(B, cut);
    let bh2 = l.shl(bh, cut);
    let mut p = l.mul(A, bh2);
    for j in hbl..vbl {
        let av = l.shr(A, vbl - j);
        let avs = l.shl(av, vbl);
        let m = l.bit_mask(B, j);
        let term = l.and(avs, m);
        p = l.add(p, term);
    }
    p
}

/// Mitchell's logarithmic multiplier: `clz` leading-one detect, zero
/// operands as an AND mask, the piecewise antilog as a mask select
/// (mirrors `mitchell_mul_one`).
fn lower_mitchell(l: &mut Lowerer) -> Reg {
    let one = l.konst(1);
    let nza = l.nez(A);
    let nzb = l.nez(B);
    let both = l.and(nza, nzb);
    let nz = l.neg(both);
    let am = l.and(A, nz);
    let bm = l.and(B, nz);
    let c63 = l.konst(63);
    let a1 = l.or(am, one);
    let b1 = l.or(bm, one);
    let lza = l.clz(a1);
    let lzb = l.clz(b1);
    let k1 = l.sub(c63, lza);
    let k2 = l.sub(c63, lzb);
    let bit1 = l.shlv(one, k1);
    let nb1 = l.not(bit1);
    let x1 = l.and(am, nb1);
    let bit2 = l.shlv(one, k2);
    let nb2 = l.not(bit2);
    let x2 = l.and(bm, nb2);
    let k = l.add(k1, k2);
    let s1 = l.shlv(x1, k2);
    let s2 = l.shlv(x2, k1);
    let s = l.add(s1, s2);
    let sk = l.shrv(s, k);
    let skb = l.and(sk, one);
    let over = l.neg(skb);
    let pk = l.shlv(one, k);
    let base = l.add(pk, s);
    let nover = l.not(over);
    let r1 = l.and(base, nover);
    let s2x = l.shl(s, 1);
    let r2 = l.and(s2x, over);
    let r = l.or(r1, r2);
    l.and(r, nz)
}

/// Kulkarni's closed form `a*b − 2·f(a)·f(b)` (mirrors `kulkarni_mul_one`).
fn lower_kulkarni(l: &mut Lowerer, n: u32) -> Reg {
    let m3 = l.konst(0x5555_5555_5555_5555u64 & (((1u128 << n) - 1) as u64));
    let a1 = l.shr(A, 1);
    let fa0 = l.and(A, a1);
    let fa = l.and(fa0, m3);
    let b1 = l.shr(B, 1);
    let fb0 = l.and(B, b1);
    let fb = l.and(fb0, m3);
    let ab = l.mul(A, B);
    let ff = l.mul(fa, fb);
    let ff2 = l.shl(ff, 1);
    l.sub(ab, ff2)
}

/// Lower one registry design to its straight-line module. The spec is
/// validated first, so malformed designs surface as typed
/// [`SegmulError::Spec`] — never as a bad program.
pub fn lower_design(spec: &MultiplierSpec) -> Result<Program, SegmulError> {
    spec.validate()?;
    let n = spec.n();
    let mut l = Lowerer::new();
    let ret = match *spec {
        MultiplierSpec::Segmented { t, fix, .. } => lower_segmented(&mut l, n, t, fix),
        MultiplierSpec::Accurate { .. } => lower_segmented(&mut l, n, 0, false),
        MultiplierSpec::Truncated { k, .. } => lower_truncated(&mut l, k),
        MultiplierSpec::BrokenArray { hbl, vbl, .. } => lower_broken_array(&mut l, hbl, vbl),
        MultiplierSpec::Mitchell { .. } => lower_mitchell(&mut l),
        MultiplierSpec::Kulkarni { .. } => lower_kulkarni(&mut l, n),
        // Same product function as the word-level recurrence (§IV
        // equivalence, pinned by the differential tests).
        MultiplierSpec::BitLevel { t, fix, .. } | MultiplierSpec::Netlist { t, fix, .. } => {
            lower_segmented(&mut l, n, t, fix)
        }
    };
    Ok(l.finish(n, ret))
}

// ---------------------------------------------------------------------------
// Text serialization ("segir 1")
// ---------------------------------------------------------------------------

impl Program {
    /// Serialize to the versioned `segir 1` text form.
    pub fn to_text(&self) -> String {
        use std::fmt::Write;
        let mut text = String::new();
        text.push_str("segir 1\n");
        let _ = writeln!(text, "n {}", self.n);
        text.push_str("input %0 a\ninput %1 b\n");
        for (i, op) in self.ops.iter().enumerate() {
            let d = 2 + i;
            let line = match *op {
                Op::Const(v) => format!("%{d} = const {v}"),
                Op::Mul(x, y) => format!("%{d} = mul %{x} %{y}"),
                Op::Add(x, y) => format!("%{d} = add %{x} %{y}"),
                Op::Sub(x, y) => format!("%{d} = sub %{x} %{y}"),
                Op::And(x, y) => format!("%{d} = and %{x} %{y}"),
                Op::Or(x, y) => format!("%{d} = or %{x} %{y}"),
                Op::Xor(x, y) => format!("%{d} = xor %{x} %{y}"),
                Op::Shl(x, s) => format!("%{d} = shl %{x} {s}"),
                Op::Shr(x, s) => format!("%{d} = shr %{x} {s}"),
                Op::Shlv(x, y) => format!("%{d} = shlv %{x} %{y}"),
                Op::Shrv(x, y) => format!("%{d} = shrv %{x} %{y}"),
                Op::Not(x) => format!("%{d} = not %{x}"),
                Op::Neg(x) => format!("%{d} = neg %{x}"),
                Op::Nez(x) => format!("%{d} = nez %{x}"),
                Op::Clz(x) => format!("%{d} = clz %{x}"),
            };
            text.push_str(&line);
            text.push('\n');
        }
        let _ = writeln!(text, "ret %{}", self.ret);
        text
    }

    /// Parse the `segir 1` text form, validating SSA discipline (each
    /// instruction writes the next register, operands reference earlier
    /// registers only) and shift-immediate ranges. The error is a plain
    /// reason string; callers wrap it with the file path.
    pub fn parse(text: &str) -> Result<Program, String> {
        fn reg(tok: &str, limit: u32) -> Result<Reg, String> {
            let idx = tok
                .strip_prefix('%')
                .and_then(|v| v.parse::<u32>().ok())
                .ok_or_else(|| format!("expected register, got {tok:?}"))?;
            if idx >= limit {
                return Err(format!("register %{idx} references a not-yet-defined value"));
            }
            Ok(idx)
        }
        let mut lines = text
            .lines()
            .map(str::trim)
            .filter(|l| !l.is_empty() && !l.starts_with('#'));
        match lines.next() {
            Some("segir 1") => {}
            Some(other) => return Err(format!("unsupported module header {other:?} (expected \"segir 1\")")),
            None => return Err("empty module".to_string()),
        }
        let mut n: Option<u32> = None;
        let mut inputs = 0u32;
        let mut ops: Vec<Op> = Vec::new();
        let mut ret: Option<Reg> = None;
        for line in lines {
            if ret.is_some() {
                return Err(format!("instruction after 'ret': {line:?}"));
            }
            let toks: Vec<&str> = line.split_whitespace().collect();
            let defined = 2 + ops.len() as u32;
            match toks.as_slice() {
                ["n", v] => {
                    let bits = v.parse::<u32>().map_err(|_| format!("bad bit-width {v:?}"))?;
                    if !(1..=32).contains(&bits) {
                        return Err(format!("bit-width n={bits} out of range 1..=32"));
                    }
                    n = Some(bits);
                }
                ["input", r, name] => {
                    let idx = reg(r, 2)?;
                    let want = ["a", "b"];
                    if idx != inputs || inputs >= 2 || *name != want[inputs as usize] {
                        return Err(format!("unexpected input declaration {line:?}"));
                    }
                    inputs += 1;
                }
                ["ret", r] => ret = Some(reg(r, defined)?),
                [dst, "=", body @ ..] => {
                    if inputs != 2 {
                        return Err("instructions before both input declarations".to_string());
                    }
                    let d = dst
                        .strip_prefix('%')
                        .and_then(|v| v.parse::<u32>().ok())
                        .ok_or_else(|| format!("bad destination {dst:?}"))?;
                    if d != defined {
                        return Err(format!("instruction writes %{d}, expected %{defined}"));
                    }
                    let imm = |tok: &str| -> Result<u32, String> {
                        let s = tok
                            .parse::<u32>()
                            .map_err(|_| format!("expected shift immediate, got {tok:?}"))?;
                        if s >= 64 {
                            return Err(format!("shift immediate {s} out of range 0..64"));
                        }
                        Ok(s)
                    };
                    let op = match *body {
                        ["const", v] => {
                            Op::Const(v.parse::<u64>().map_err(|_| format!("bad constant {v:?}"))?)
                        }
                        ["mul", x, y] => Op::Mul(reg(x, defined)?, reg(y, defined)?),
                        ["add", x, y] => Op::Add(reg(x, defined)?, reg(y, defined)?),
                        ["sub", x, y] => Op::Sub(reg(x, defined)?, reg(y, defined)?),
                        ["and", x, y] => Op::And(reg(x, defined)?, reg(y, defined)?),
                        ["or", x, y] => Op::Or(reg(x, defined)?, reg(y, defined)?),
                        ["xor", x, y] => Op::Xor(reg(x, defined)?, reg(y, defined)?),
                        ["shl", x, s] => Op::Shl(reg(x, defined)?, imm(s)?),
                        ["shr", x, s] => Op::Shr(reg(x, defined)?, imm(s)?),
                        ["shlv", x, y] => Op::Shlv(reg(x, defined)?, reg(y, defined)?),
                        ["shrv", x, y] => Op::Shrv(reg(x, defined)?, reg(y, defined)?),
                        ["not", x] => Op::Not(reg(x, defined)?),
                        ["neg", x] => Op::Neg(reg(x, defined)?),
                        ["nez", x] => Op::Nez(reg(x, defined)?),
                        ["clz", x] => Op::Clz(reg(x, defined)?),
                        _ => return Err(format!("unparsable instruction {line:?}")),
                    };
                    ops.push(op);
                }
                _ => return Err(format!("unparsable line {line:?}")),
            }
        }
        let n = n.ok_or_else(|| "module missing 'n' declaration".to_string())?;
        if inputs != 2 {
            return Err("module missing input declarations".to_string());
        }
        let ret = ret.ok_or_else(|| "module missing 'ret'".to_string())?;
        Ok(Program { n, ops, ret })
    }
}

// ---------------------------------------------------------------------------
// Execution (the stub PJRT client's software executor)
// ---------------------------------------------------------------------------

/// Lanes evaluated per interpreter pass: the register file stays
/// L1/L2-resident (`(2 + ops) × TILE × 8` bytes) while each instruction
/// runs as one tight lane loop.
pub const TILE: usize = 1024;

/// A compiled-for-execution lowered module: the program plus a reusable
/// tile-register scratch file.
pub struct LoweredExec {
    prog: Program,
    regs: Vec<u64>,
}

impl LoweredExec {
    /// An executor with scratch registers sized for `prog`.
    pub fn new(prog: Program) -> Self {
        let slots = (2 + prog.ops.len()) * TILE;
        LoweredExec { prog, regs: vec![0; slots] }
    }

    /// The program this executor runs.
    pub fn program(&self) -> &Program {
        &self.prog
    }

    /// Execute the module: `out[i] = f(a[i], b[i])` for every lane. Any
    /// length; processed in [`TILE`]-lane passes.
    pub fn run(&mut self, a: &[u64], b: &[u64], out: &mut [u64]) {
        assert_eq!(a.len(), b.len(), "operand slices must have equal length");
        assert_eq!(a.len(), out.len(), "output slice must match operand length");
        for ((ca, cb), co) in a.chunks(TILE).zip(b.chunks(TILE)).zip(out.chunks_mut(TILE)) {
            run_tile(&self.prog, &mut self.regs, ca, cb, co);
        }
    }
}

fn bin(d: &mut [u64], x: &[u64], y: &[u64], f: impl Fn(u64, u64) -> u64) {
    for ((o, &a), &b) in d.iter_mut().zip(x).zip(y) {
        *o = f(a, b);
    }
}

fn un(d: &mut [u64], x: &[u64], f: impl Fn(u64) -> u64) {
    for (o, &a) in d.iter_mut().zip(x) {
        *o = f(a);
    }
}

fn run_tile(prog: &Program, regs: &mut [u64], a: &[u64], b: &[u64], out: &mut [u64]) {
    let w = a.len();
    regs[..w].copy_from_slice(a);
    regs[TILE..TILE + w].copy_from_slice(b);
    for (i, op) in prog.ops.iter().enumerate() {
        let dst = (2 + i) * TILE;
        // SSA: operands always reference earlier registers, so the
        // destination tile is disjoint from every source tile.
        let (src, rest) = regs.split_at_mut(dst);
        let d = &mut rest[..w];
        let r = |reg: Reg| &src[reg as usize * TILE..reg as usize * TILE + w];
        match *op {
            Op::Const(v) => d.fill(v),
            Op::Mul(x, y) => bin(d, r(x), r(y), |a, b| a.wrapping_mul(b)),
            Op::Add(x, y) => bin(d, r(x), r(y), |a, b| a.wrapping_add(b)),
            Op::Sub(x, y) => bin(d, r(x), r(y), |a, b| a.wrapping_sub(b)),
            Op::And(x, y) => bin(d, r(x), r(y), |a, b| a & b),
            Op::Or(x, y) => bin(d, r(x), r(y), |a, b| a | b),
            Op::Xor(x, y) => bin(d, r(x), r(y), |a, b| a ^ b),
            Op::Shl(x, s) => un(d, r(x), |a| a << s),
            Op::Shr(x, s) => un(d, r(x), |a| a >> s),
            Op::Shlv(x, y) => bin(d, r(x), r(y), |a, s| a << (s & 63)),
            Op::Shrv(x, y) => bin(d, r(x), r(y), |a, s| a >> (s & 63)),
            Op::Not(x) => un(d, r(x), |a| !a),
            Op::Neg(x) => un(d, r(x), |a| a.wrapping_neg()),
            Op::Nez(x) => un(d, r(x), |a| (a != 0) as u64),
            Op::Clz(x) => un(d, r(x), |a| a.leading_zeros() as u64),
        }
    }
    let ret = prog.ret as usize * TILE;
    out.copy_from_slice(&regs[ret..ret + w]);
}

// ---------------------------------------------------------------------------
// The artifact emitter (`segmul lower`)
// ---------------------------------------------------------------------------

/// Lower every spec (deduplicated, order-preserving) into `dir`: one
/// `<stem>.segir` module per design plus a schema-v2 `manifest.json`.
/// Returns the manifest **re-loaded through the validating parser**, so a
/// successful emit is also a proven round-trip.
pub fn emit_artifacts(
    dir: &Path,
    specs: &[MultiplierSpec],
    batch: usize,
) -> Result<Manifest, SegmulError> {
    if batch == 0 {
        return Err(SegmulError::config("lowered batch must be positive"));
    }
    if specs.is_empty() {
        return Err(SegmulError::config("no designs to lower"));
    }
    std::fs::create_dir_all(dir)?;
    let mut seen = std::collections::HashSet::new();
    let mut entries = Vec::new();
    for spec in specs {
        if !seen.insert(*spec) {
            continue;
        }
        let prog = lower_design(spec)?;
        let stem = spec.artifact_stem();
        let file = format!("{stem}.segir");
        std::fs::write(dir.join(&file), prog.to_text())?;
        entries.push(obj(vec![
            ("name", Json::from(stem.as_str())),
            ("design", spec.to_json()),
            ("n", Json::from(spec.n() as u64)),
            ("batch", Json::from(batch as u64)),
            ("file", Json::from(file.as_str())),
            ("ops", Json::from(prog.ops.len() as u64)),
        ]));
    }
    let manifest = obj(vec![
        ("schema_version", Json::from(SCHEMA_VERSION)),
        ("generator", Json::from("segmul lower")),
        ("batch", Json::from(batch as u64)),
        ("lowered", Json::Arr(entries)),
    ]);
    std::fs::write(dir.join("manifest.json"), manifest.to_string_pretty())?;
    Manifest::load(dir)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multiplier::wordlevel::approx_seq_mul;
    use crate::multiplier::BatchMultiplier;
    use crate::util::rng::Xoshiro256;

    fn operands(n: u32, len: usize, seed: u64) -> (Vec<u64>, Vec<u64>) {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        // Bias toward 0 and powers of two (Mitchell's special paths).
        let sample = |rng: &mut Xoshiro256| match rng.next_below(8) {
            0 => 0u64,
            1 => 1u64 << rng.next_below(n as u64),
            _ => rng.next_bits(n),
        };
        let a: Vec<u64> = (0..len).map(|_| sample(&mut rng)).collect();
        let b: Vec<u64> = (0..len).map(|_| sample(&mut rng)).collect();
        (a, b)
    }

    /// Every registry design's lowered module computes the exact product
    /// function of its production batch kernel, across a TILE boundary.
    #[test]
    fn lowered_modules_match_batch_kernels() {
        for n in [4u32, 8, 16] {
            let (a, b) = operands(n, TILE + 137, 0x10 + n as u64);
            for spec in MultiplierSpec::registry_examples(n) {
                let prog = lower_design(&spec).unwrap();
                assert_eq!(prog.n, n);
                let mut exec = LoweredExec::new(prog);
                let mut got = vec![0u64; a.len()];
                exec.run(&a, &b, &mut got);
                let kernel = spec.build_batch().unwrap();
                let mut want = vec![0u64; a.len()];
                kernel.mul_batch(&a, &b, &mut want);
                assert_eq!(got, want, "{}", spec.name());
            }
        }
    }

    #[test]
    fn segmented_lowering_matches_scalar_model_every_config() {
        for n in [1u32, 2, 5, 8] {
            for t in 0..n {
                for fix in [false, true] {
                    let spec = MultiplierSpec::Segmented { n, t, fix };
                    let mut exec = LoweredExec::new(lower_design(&spec).unwrap());
                    let (a, b) = operands(n, 300, (n as u64) << 8 | t as u64);
                    let mut got = vec![0u64; a.len()];
                    exec.run(&a, &b, &mut got);
                    for i in 0..a.len() {
                        assert_eq!(
                            got[i],
                            approx_seq_mul(a[i], b[i], n, t, fix),
                            "n={n} t={t} fix={fix} a={} b={}",
                            a[i],
                            b[i]
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn widest_configs_lower_and_execute() {
        // n = 32 stresses the shift-immediate extremes (n + t = 63, k = n).
        for spec in [
            MultiplierSpec::Segmented { n: 32, t: 31, fix: true },
            MultiplierSpec::Truncated { n: 32, k: 32 },
            MultiplierSpec::BrokenArray { n: 32, hbl: 32, vbl: 32 },
            MultiplierSpec::Kulkarni { n: 32 },
            MultiplierSpec::Mitchell { n: 32 },
        ] {
            let mut exec = LoweredExec::new(lower_design(&spec).unwrap());
            let (a, b) = operands(32, 200, 0xFF);
            let mut got = vec![0u64; a.len()];
            exec.run(&a, &b, &mut got);
            let kernel = spec.build_batch().unwrap();
            let mut want = vec![0u64; a.len()];
            kernel.mul_batch(&a, &b, &mut want);
            assert_eq!(got, want, "{}", spec.name());
        }
    }

    #[test]
    fn text_round_trip_preserves_program_and_semantics() {
        for spec in MultiplierSpec::registry_examples(8) {
            let prog = lower_design(&spec).unwrap();
            let text = prog.to_text();
            let back = Program::parse(&text).unwrap();
            assert_eq!(back, prog, "{}", spec.name());
            let (a, b) = operands(8, 100, 7);
            let (mut x, mut y) = (vec![0u64; 100], vec![0u64; 100]);
            LoweredExec::new(prog).run(&a, &b, &mut x);
            LoweredExec::new(back).run(&a, &b, &mut y);
            assert_eq!(x, y);
        }
    }

    #[test]
    fn parse_rejects_malformed_modules() {
        assert!(Program::parse("").unwrap_err().contains("empty"));
        assert!(Program::parse("hlo 7\n").unwrap_err().contains("header"));
        let head = "segir 1\nn 8\ninput %0 a\ninput %1 b\n";
        // Wrong destination index.
        assert!(Program::parse(&format!("{head}%5 = const 1\nret %5\n")).is_err());
        // Operand referencing a later register.
        assert!(Program::parse(&format!("{head}%2 = add %3 %0\nret %2\n")).is_err());
        // Shift immediate out of range.
        assert!(Program::parse(&format!("{head}%2 = shl %0 64\nret %2\n")).is_err());
        // Unknown mnemonic.
        assert!(Program::parse(&format!("{head}%2 = frob %0\nret %2\n")).is_err());
        // Missing ret.
        assert!(Program::parse(&format!("{head}%2 = const 1\n")).unwrap_err().contains("ret"));
        // Bad bit-width.
        assert!(Program::parse("segir 1\nn 40\ninput %0 a\ninput %1 b\nret %0\n").is_err());
        // Minimal valid module parses.
        let ok = Program::parse(&format!("{head}%2 = mul %0 %1\nret %2\n")).unwrap();
        assert_eq!(ok.ops.len(), 1);
        assert_eq!(ok.ret, 2);
    }

    #[test]
    fn emit_artifacts_round_trips_through_validating_loader() {
        let dir = std::env::temp_dir().join(format!("segmul_lower_emit_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut specs = MultiplierSpec::registry_examples(8);
        specs.push(specs[0]); // duplicates collapse
        let m = emit_artifacts(&dir, &specs, 256).unwrap();
        assert_eq!(m.schema, 2);
        assert_eq!(m.batch, 256);
        assert_eq!(m.lowered.len(), MultiplierSpec::registry_examples(8).len());
        for spec in MultiplierSpec::registry_examples(8) {
            assert!(m.covers_design(&spec), "{}", spec.name());
            let ls = m.find_lowered(&spec).unwrap();
            assert_eq!(ls.design, spec);
            assert_eq!(ls.n, spec.n());
            let text = std::fs::read_to_string(m.dir.join(&ls.file)).unwrap();
            assert_eq!(Program::parse(&text).unwrap().n, spec.n());
        }
        // Canonical fallback: the t=0 segmented point is served by the
        // accurate module.
        assert!(m.covers_design(&MultiplierSpec::Segmented { n: 8, t: 0, fix: true }));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn emit_rejects_degenerate_requests() {
        let dir = std::env::temp_dir().join("segmul_lower_reject");
        assert_eq!(
            emit_artifacts(&dir, &[MultiplierSpec::Accurate { n: 8 }], 0).unwrap_err().kind(),
            "config"
        );
        assert_eq!(emit_artifacts(&dir, &[], 16).unwrap_err().kind(), "config");
        // Invalid specs surface as typed spec errors.
        assert_eq!(
            emit_artifacts(&dir, &[MultiplierSpec::Kulkarni { n: 12 }], 16).unwrap_err().kind(),
            "spec"
        );
    }
}
