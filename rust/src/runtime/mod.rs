//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `make artifacts` and executes them on the CPU PJRT client.
//!
//! This is the only place the `xla` crate is touched. The interchange
//! format is HLO *text* (not serialized HloModuleProto) — jax ≥ 0.5 emits
//! protos with 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see /opt/xla-example/README.md).

pub mod artifact;
pub mod client;

pub use artifact::{Manifest, ModuleKind, ModuleSpec};
pub use client::Runtime;
