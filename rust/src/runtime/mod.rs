//! PJRT runtime: loads lowered artifacts and executes them on the PJRT
//! backend.
//!
//! Two artifact classes are served:
//!
//! * **Legacy HLO modules** (`make artifacts`, schema v1): AOT-compiled
//!   stats/prod modules of the segmented family, executed through the
//!   `xla` crate. This is the only place `xla` is touched; the
//!   interchange format is HLO *text* (not serialized HloModuleProto) —
//!   jax ≥ 0.5 emits protos with 64-bit instruction ids that
//!   xla_extension 0.5.1 rejects; the text parser reassigns ids (see
//!   /opt/xla-example/README.md).
//! * **Design-lowered modules** (`segmul lower`, schema v2): one
//!   branch-free [`lower::Program`] per registry design
//!   ([`crate::multiplier::MultiplierSpec`]), executed by the stub PJRT
//!   client's software executor ([`lower::LoweredExec`]) — so every
//!   registry design dispatches on the PJRT backend even where the real
//!   bindings are stubbed out.

pub mod artifact;
pub mod client;
pub mod lower;

pub use artifact::{LoweredSpec, Manifest, ModuleKind, ModuleSpec};
pub use client::Runtime;
pub use lower::{emit_artifacts, lower_design, LoweredExec, Program};
