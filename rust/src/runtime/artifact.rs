//! Artifact manifest: `artifacts/manifest.json`, describing every lowered
//! module the PJRT runtime can execute.
//!
//! Two module classes share one manifest:
//!
//! * **Legacy HLO modules** (`modules`, schema v1) — written by
//!   `python -m compile.aot` (`make artifacts`): per-bit-width stats /
//!   prod modules of the segmented family, lowered to HLO text and
//!   compiled through the real PJRT bindings.
//! * **Design-lowered modules** (`lowered`, schema v2) — written by
//!   `segmul lower` ([`crate::runtime::lower`]): one branch-free straight-
//!   line module per [`MultiplierSpec`] registry design, executable by the
//!   stub PJRT client, so `--designs all` sweeps run fully on the
//!   accelerator backend with zero CPU fallbacks.
//!
//! The schema is versioned (`schema_version`, absent = 1) and validation
//! failures are typed [`SegmulError::Artifact`] values — malformed JSON,
//! unsupported schema, missing files, wrong bit-width, wrong batch shape,
//! and duplicate designs all name the offending file and reason instead
//! of panicking or flattening into strings.

use std::collections::HashSet;
use std::path::{Path, PathBuf};

use crate::error::SegmulError;
use crate::multiplier::MultiplierSpec;
use crate::util::json::Json;

/// Highest manifest schema this build understands.
pub const SCHEMA_VERSION: u64 = 2;

/// What a legacy (HLO) lowered module computes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ModuleKind {
    /// f64[6+2n] statistics vector (the evaluation-service hot path).
    Stats,
    /// u64[batch] approximate products (value-returning path).
    Prod,
}

impl ModuleKind {
    /// Parse a manifest `kind` field.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "stats" => Ok(ModuleKind::Stats),
            "prod" => Ok(ModuleKind::Prod),
            other => Err(format!("unknown module kind {other:?}")),
        }
    }
}

/// One AOT-lowered HLO module (legacy, segmented family only).
#[derive(Clone, Debug)]
pub struct ModuleSpec {
    /// Module name (manifest key).
    pub name: String,
    /// Stats or prod variant.
    pub kind: ModuleKind,
    /// Operand bit-width the module was lowered for.
    pub n: u32,
    /// HLO text file, relative to the artifacts dir.
    pub file: PathBuf,
    /// Static batch size (length of the `a`/`b` operands).
    pub batch: usize,
    /// Output vector length (6+2n for stats, batch for prod).
    pub out_len: usize,
}

/// One design-lowered module (`segmul lower`): a branch-free straight-line
/// program computing `design`'s approximate products over a static batch.
#[derive(Clone, Debug)]
pub struct LoweredSpec {
    /// Module name (manifest key).
    pub name: String,
    /// The registry design this module computes.
    pub design: MultiplierSpec,
    /// Operand bit-width (must equal `design.n()`).
    pub n: u32,
    /// Static batch size (must equal the manifest batch).
    pub batch: usize,
    /// Module text file (`.segir`), relative to the artifacts dir.
    pub file: PathBuf,
}

/// Parsed `manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// The artifacts directory the manifest was loaded from.
    pub dir: PathBuf,
    /// Manifest schema version (1 = legacy HLO-only, 2 adds `lowered`).
    pub schema: u64,
    /// Static batch size shared by every module.
    pub batch: usize,
    /// Legacy HLO modules (may be empty in a `segmul lower` manifest).
    pub modules: Vec<ModuleSpec>,
    /// Design-lowered modules (empty in a legacy v1 manifest).
    pub lowered: Vec<LoweredSpec>,
}

/// Shorthand: a typed artifact error naming `path`.
fn err(path: &Path, reason: impl Into<String>) -> SegmulError {
    SegmulError::artifact(path.display().to_string(), reason)
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`. Every failure is a typed
    /// [`SegmulError::Artifact`] naming the offending file.
    pub fn load(dir: &Path) -> Result<Manifest, SegmulError> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            err(&path, format!("reading manifest: {e} — run `segmul lower` or `make artifacts`"))
        })?;
        let json = Json::parse(&text).map_err(|e| err(&path, format!("malformed JSON: {e}")))?;
        let schema = match json.get("schema_version") {
            None => 1,
            Some(v) => v
                .as_u64()
                .ok_or_else(|| err(&path, "'schema_version' must be a non-negative integer"))?,
        };
        if schema == 0 || schema > SCHEMA_VERSION {
            return Err(err(
                &path,
                format!("unsupported schema_version {schema} (this build understands 1..={SCHEMA_VERSION})"),
            ));
        }
        let batch = json
            .get("batch")
            .and_then(Json::as_u64)
            .ok_or_else(|| err(&path, "manifest missing numeric 'batch'"))? as usize;
        if batch == 0 {
            return Err(err(&path, "manifest batch must be positive"));
        }

        let mut modules = Vec::new();
        if let Some(arr) = json.get("modules") {
            let arr = arr.as_arr().ok_or_else(|| err(&path, "'modules' must be an array"))?;
            for m in arr {
                modules.push(Self::parse_module(dir, &path, m, batch)?);
            }
        }

        let mut lowered = Vec::new();
        if let Some(arr) = json.get("lowered") {
            if schema < 2 {
                return Err(err(&path, "'lowered' modules require schema_version >= 2"));
            }
            let arr = arr.as_arr().ok_or_else(|| err(&path, "'lowered' must be an array"))?;
            let mut seen: HashSet<MultiplierSpec> = HashSet::new();
            for m in arr {
                let spec = Self::parse_lowered(dir, &path, m, batch)?;
                if !seen.insert(spec.design) {
                    return Err(err(
                        &path,
                        format!("duplicate lowered module for design {}", spec.design.name()),
                    ));
                }
                lowered.push(spec);
            }
        }

        if modules.is_empty() && lowered.is_empty() {
            return Err(err(&path, "manifest has no modules"));
        }
        Ok(Manifest { dir: dir.to_path_buf(), schema, batch, modules, lowered })
    }

    fn parse_module(dir: &Path, path: &Path, m: &Json, batch: usize) -> Result<ModuleSpec, SegmulError> {
        let name = m
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| err(path, "module missing 'name'"))?
            .to_string();
        let kind = m
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| err(path, format!("module {name}: missing kind")))
            .and_then(|s| ModuleKind::parse(s).map_err(|e| err(path, format!("module {name}: {e}"))))?;
        let n = m
            .get("n")
            .and_then(Json::as_u64)
            .ok_or_else(|| err(path, format!("module {name}: missing n")))? as u32;
        let file = PathBuf::from(
            m.get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| err(path, format!("module {name}: missing file")))?,
        );
        let out_len = m
            .get("output")
            .and_then(|o| o.get("shape"))
            .and_then(Json::as_arr)
            .and_then(|s| s.first())
            .and_then(Json::as_u64)
            .ok_or_else(|| err(path, format!("module {name}: missing output shape")))? as usize;
        if !dir.join(&file).exists() {
            return Err(err(path, format!("module {name}: artifact file {file:?} not found in {dir:?}")));
        }
        Ok(ModuleSpec { name, kind, n, file, batch, out_len })
    }

    fn parse_lowered(dir: &Path, path: &Path, m: &Json, batch: usize) -> Result<LoweredSpec, SegmulError> {
        let name = m
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| err(path, "lowered module missing 'name'"))?
            .to_string();
        let design_json = m
            .get("design")
            .ok_or_else(|| err(path, format!("lowered module {name}: missing design tag")))?;
        let design = MultiplierSpec::from_json(design_json)
            .map_err(|e| err(path, format!("lowered module {name}: {e}")))?;
        design
            .validate()
            .map_err(|e| err(path, format!("lowered module {name}: invalid design: {e}")))?;
        let n = m
            .get("n")
            .and_then(Json::as_u64)
            .ok_or_else(|| err(path, format!("lowered module {name}: missing n")))? as u32;
        if n != design.n() {
            return Err(err(
                path,
                format!(
                    "lowered module {name}: bit-width n={n} contradicts design {} (n={})",
                    design.name(),
                    design.n()
                ),
            ));
        }
        let module_batch = m
            .get("batch")
            .and_then(Json::as_u64)
            .ok_or_else(|| err(path, format!("lowered module {name}: missing batch")))? as usize;
        if module_batch != batch {
            return Err(err(
                path,
                format!("lowered module {name}: batch {module_batch} != manifest batch {batch}"),
            ));
        }
        let file = PathBuf::from(
            m.get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| err(path, format!("lowered module {name}: missing file")))?,
        );
        if !dir.join(&file).exists() {
            return Err(err(path, format!("lowered module {name}: artifact file {file:?} not found in {dir:?}")));
        }
        Ok(LoweredSpec { name, design, n, batch, file })
    }

    /// Find a legacy module by bit-width and kind.
    pub fn find(&self, n: u32, kind: ModuleKind) -> Option<&ModuleSpec> {
        self.modules.iter().find(|m| m.n == n && m.kind == kind)
    }

    /// Find a design-lowered module: exact spec first, then the canonical
    /// representative (`t = 0` segmented → accurate, ...).
    pub fn find_lowered(&self, design: &MultiplierSpec) -> Option<&LoweredSpec> {
        self.lowered
            .iter()
            .find(|m| m.design == *design)
            .or_else(|| self.lowered.iter().find(|m| m.design == design.canonical()))
    }

    /// Whether the PJRT backend can dispatch `design` from this manifest:
    /// a lowered module exists for it (exactly or canonically), or it is
    /// in the segmented family and a legacy stats module covers its
    /// bit-width.
    pub fn covers_design(&self, design: &MultiplierSpec) -> bool {
        self.find_lowered(design).is_some()
            || (design.has_segmented_lowering() && self.find(design.n(), ModuleKind::Stats).is_some())
    }

    /// Bit-widths with a stats module available.
    pub fn stats_bitwidths(&self) -> Vec<u32> {
        let mut v: Vec<u32> = self
            .modules
            .iter()
            .filter(|m| m.kind == ModuleKind::Stats)
            .map(|m| m.n)
            .collect();
        v.sort_unstable();
        v
    }
}

/// Default artifacts directory: `$SEGMUL_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var_os("SEGMUL_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fake(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("m.hlo.txt"), "HloModule fake").unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"batch": 8, "modules": [
                {"name":"seqmul_stats_n4","kind":"stats","n":4,"file":"m.hlo.txt",
                 "inputs":[],"output":{"dtype":"f64","shape":[14]}}
            ]}"#,
        )
        .unwrap();
    }

    #[test]
    fn loads_and_finds() {
        let dir = std::env::temp_dir().join("segmul_manifest_test");
        write_fake(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.batch, 8);
        assert_eq!(m.schema, 1);
        assert!(m.lowered.is_empty());
        let spec = m.find(4, ModuleKind::Stats).unwrap();
        assert_eq!(spec.out_len, 14);
        assert!(m.find(4, ModuleKind::Prod).is_none());
        assert_eq!(m.stats_bitwidths(), vec![4]);
        // A v1 stats module covers exactly the segmented family at its n.
        assert!(m.covers_design(&MultiplierSpec::Segmented { n: 4, t: 2, fix: true }));
        assert!(m.covers_design(&MultiplierSpec::Accurate { n: 4 }));
        assert!(!m.covers_design(&MultiplierSpec::Mitchell { n: 4 }));
        assert!(!m.covers_design(&MultiplierSpec::Segmented { n: 8, t: 2, fix: true }));
    }

    #[test]
    fn missing_file_is_typed_artifact_error() {
        let dir = std::env::temp_dir().join("segmul_manifest_missing");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"batch": 8, "modules": [
                {"name":"x","kind":"stats","n":4,"file":"nope.hlo.txt",
                 "output":{"dtype":"f64","shape":[14]}}
            ]}"#,
        )
        .unwrap();
        let e = Manifest::load(&dir).unwrap_err();
        assert_eq!(e.kind(), "artifact");
        assert!(e.to_string().contains("nope.hlo.txt"), "{e}");
    }

    #[test]
    fn unsupported_schema_rejected() {
        let dir = std::env::temp_dir().join("segmul_manifest_schema");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), r#"{"schema_version": 99, "batch": 8, "modules": []}"#)
            .unwrap();
        let e = Manifest::load(&dir).unwrap_err();
        assert_eq!(e.kind(), "artifact");
        assert!(e.to_string().contains("schema_version 99"), "{e}");
    }

    #[test]
    fn real_artifacts_manifest_if_present() {
        // When `make artifacts` has run, validate the real manifest parses.
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            if m.schema == 1 {
                assert!(m.find(8, ModuleKind::Stats).is_some());
                assert_eq!(m.find(8, ModuleKind::Stats).unwrap().out_len, 6 + 16);
            }
        }
    }
}
