//! Artifact manifest: `artifacts/manifest.json` written by `python -m
//! compile.aot`, describing each lowered HLO module (shapes, dtypes, batch).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// What a lowered module computes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ModuleKind {
    /// f64[6+2n] statistics vector (the evaluation-service hot path).
    Stats,
    /// u64[batch] approximate products (value-returning path).
    Prod,
}

impl ModuleKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "stats" => Ok(ModuleKind::Stats),
            "prod" => Ok(ModuleKind::Prod),
            other => bail!("unknown module kind {other:?}"),
        }
    }
}

/// One AOT-lowered HLO module.
#[derive(Clone, Debug)]
pub struct ModuleSpec {
    pub name: String,
    pub kind: ModuleKind,
    /// Operand bit-width the module was lowered for.
    pub n: u32,
    /// HLO text file, relative to the artifacts dir.
    pub file: PathBuf,
    /// Static batch size (length of the `a`/`b` operands).
    pub batch: usize,
    /// Output vector length (6+2n for stats, batch for prod).
    pub out_len: usize,
}

/// Parsed `manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub batch: usize,
    pub modules: Vec<ModuleSpec>,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — did you run `make artifacts`?"))?;
        let json = Json::parse(&text).with_context(|| format!("parsing {path:?}"))?;
        let batch = json
            .get("batch")
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow!("manifest missing numeric 'batch'"))? as usize;
        let mut modules = Vec::new();
        for m in json
            .get("modules")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing 'modules' array"))?
        {
            let name = m
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("module missing 'name'"))?
                .to_string();
            let kind = ModuleKind::parse(
                m.get("kind").and_then(Json::as_str).ok_or_else(|| anyhow!("module {name}: missing kind"))?,
            )?;
            let n = m
                .get("n")
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("module {name}: missing n"))? as u32;
            let file = PathBuf::from(
                m.get("file").and_then(Json::as_str).ok_or_else(|| anyhow!("module {name}: missing file"))?,
            );
            let out_len = m
                .get("output")
                .and_then(|o| o.get("shape"))
                .and_then(Json::as_arr)
                .and_then(|s| s.first())
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("module {name}: missing output shape"))? as usize;
            if !dir.join(&file).exists() {
                bail!("module {name}: artifact file {:?} not found in {dir:?}", file);
            }
            modules.push(ModuleSpec { name, kind, n, file, batch, out_len });
        }
        if modules.is_empty() {
            bail!("manifest has no modules");
        }
        Ok(Manifest { dir: dir.to_path_buf(), batch, modules })
    }

    /// Find a module by bit-width and kind.
    pub fn find(&self, n: u32, kind: ModuleKind) -> Option<&ModuleSpec> {
        self.modules.iter().find(|m| m.n == n && m.kind == kind)
    }

    /// Bit-widths with a stats module available.
    pub fn stats_bitwidths(&self) -> Vec<u32> {
        let mut v: Vec<u32> = self
            .modules
            .iter()
            .filter(|m| m.kind == ModuleKind::Stats)
            .map(|m| m.n)
            .collect();
        v.sort_unstable();
        v
    }
}

/// Default artifacts directory: `$SEGMUL_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var_os("SEGMUL_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fake(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("m.hlo.txt"), "HloModule fake").unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"batch": 8, "modules": [
                {"name":"seqmul_stats_n4","kind":"stats","n":4,"file":"m.hlo.txt",
                 "inputs":[],"output":{"dtype":"f64","shape":[14]}}
            ]}"#,
        )
        .unwrap();
    }

    #[test]
    fn loads_and_finds() {
        let dir = std::env::temp_dir().join("segmul_manifest_test");
        write_fake(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.batch, 8);
        let spec = m.find(4, ModuleKind::Stats).unwrap();
        assert_eq!(spec.out_len, 14);
        assert!(m.find(4, ModuleKind::Prod).is_none());
        assert_eq!(m.stats_bitwidths(), vec![4]);
    }

    #[test]
    fn missing_file_is_error() {
        let dir = std::env::temp_dir().join("segmul_manifest_missing");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"batch": 8, "modules": [
                {"name":"x","kind":"stats","n":4,"file":"nope.hlo.txt",
                 "output":{"dtype":"f64","shape":[14]}}
            ]}"#,
        )
        .unwrap();
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn real_artifacts_manifest_if_present() {
        // When `make artifacts` has run, validate the real manifest parses.
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.find(8, ModuleKind::Stats).is_some());
            assert_eq!(m.find(8, ModuleKind::Stats).unwrap().out_len, 6 + 16);
        }
    }
}
