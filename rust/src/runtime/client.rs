//! The PJRT client wrapper: compile-once, execute-many.
//!
//! Adapted from `/opt/xla-example/src/bin/load_hlo.rs`. One
//! `PjRtLoadedExecutable` per manifest module; executions are synchronous
//! on the calling thread (the coordinator owns a dedicated executor thread
//! and feeds it through channels — the FFI types are kept off other
//! threads).

use std::collections::HashMap;
use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::artifact::{Manifest, ModuleKind, ModuleSpec};

/// Execution telemetry for one runtime instance.
#[derive(Clone, Debug, Default)]
pub struct RuntimeStats {
    pub executions: u64,
    pub pairs_evaluated: u64,
    pub exec_time: Duration,
    pub compile_time: Duration,
}

/// Loaded-and-compiled artifact set.
pub struct Runtime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    modules: HashMap<(u32, ModuleKind), LoadedModule>,
    batch: usize,
    stats: RuntimeStats,
}

struct LoadedModule {
    spec: ModuleSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Runtime {
    /// Create a CPU PJRT client and compile every module in the manifest.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        Self::from_manifest(&manifest)
    }

    /// Compile every module of an already-parsed manifest.
    pub fn from_manifest(manifest: &Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PjRtClient::cpu: {e}"))?;
        let mut modules = HashMap::new();
        let mut compile_time = Duration::ZERO;
        for spec in &manifest.modules {
            let path = manifest.dir.join(&spec.file);
            let started = Instant::now();
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parsing HLO text {path:?}: {e}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e}", spec.name))?;
            compile_time += started.elapsed();
            modules.insert((spec.n, spec.kind), LoadedModule { spec: spec.clone(), exe });
        }
        Ok(Self {
            client,
            modules,
            batch: manifest.batch,
            stats: RuntimeStats { compile_time, ..Default::default() },
        })
    }

    /// The static batch size every module was lowered with.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Bit-widths with a stats module compiled.
    pub fn stats_bitwidths(&self) -> Vec<u32> {
        let mut v: Vec<u32> = self
            .modules
            .keys()
            .filter(|(_, k)| *k == ModuleKind::Stats)
            .map(|(n, _)| *n)
            .collect();
        v.sort_unstable();
        v
    }

    pub fn has(&self, n: u32, kind: ModuleKind) -> bool {
        self.modules.contains_key(&(n, kind))
    }

    /// Telemetry snapshot.
    pub fn stats(&self) -> RuntimeStats {
        self.stats.clone()
    }

    fn execute(&mut self, n: u32, kind: ModuleKind, a: &[u64], b: &[u64], t: u64, fix: bool) -> Result<(xla::Literal, usize)> {
        let module = self
            .modules
            .get(&(n, kind))
            .ok_or_else(|| anyhow!("no {kind:?} module for n={n} (run `make artifacts`)"))?;
        if a.len() != module.spec.batch || b.len() != module.spec.batch {
            bail!(
                "operand length {} != lowered batch {} (module {})",
                a.len(),
                module.spec.batch,
                module.spec.name
            );
        }
        if t >= n as u64 {
            bail!("splitting point t={t} out of range for n={n}");
        }
        let started = Instant::now();
        let lit_a = xla::Literal::vec1(a);
        let lit_b = xla::Literal::vec1(b);
        let lit_t = xla::Literal::from(t);
        let lit_fix = xla::Literal::from(fix as u64);
        let result = module
            .exe
            .execute::<xla::Literal>(&[lit_a, lit_b, lit_t, lit_fix])
            .map_err(|e| anyhow!("executing {}: {e}", module.spec.name))?;
        let literal = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {}: {e}", module.spec.name))?;
        // Lowered with return_tuple=True → unwrap the 1-tuple.
        let out = literal
            .to_tuple1()
            .map_err(|e| anyhow!("untupling result of {}: {e}", module.spec.name))?;
        self.stats.executions += 1;
        self.stats.pairs_evaluated += a.len() as u64;
        self.stats.exec_time += started.elapsed();
        Ok((out, module.spec.out_len))
    }

    /// Run the stats module: returns the raw f64 statistics vector
    /// (layout documented in `python/compile/model.py`).
    pub fn exec_stats(&mut self, n: u32, a: &[u64], b: &[u64], t: u64, fix: bool) -> Result<Vec<f64>> {
        let (out, out_len) = self.execute(n, ModuleKind::Stats, a, b, t, fix)?;
        let v = out
            .to_vec::<f64>()
            .map_err(|e| anyhow!("reading stats vector: {e}"))?;
        if v.len() != out_len {
            bail!("stats length {} != manifest {}", v.len(), out_len);
        }
        Ok(v)
    }

    /// Run the prod module: returns the approximate products.
    pub fn exec_prod(&mut self, n: u32, a: &[u64], b: &[u64], t: u64, fix: bool) -> Result<Vec<u64>> {
        let (out, out_len) = self.execute(n, ModuleKind::Prod, a, b, t, fix)?;
        let v = out
            .to_vec::<u64>()
            .map_err(|e| anyhow!("reading product vector: {e}"))?;
        if v.len() != out_len {
            bail!("product length {} != manifest {}", v.len(), out_len);
        }
        Ok(v)
    }
}
