//! The PJRT client wrapper: compile-once, execute-many.
//!
//! Adapted from `/opt/xla-example/src/bin/load_hlo.rs`. One
//! `PjRtLoadedExecutable` per legacy manifest module; executions are
//! synchronous on the calling thread (the coordinator owns dedicated
//! executor threads and feeds them through channels — the FFI types are
//! kept off other threads).
//!
//! Design-lowered modules (`segmul lower`) compile to the in-process
//! software executor instead ([`super::lower::LoweredExec`]) — the stub
//! PJRT client. The real `xla` client is only constructed when the
//! manifest actually contains legacy HLO modules, so a lowered-only
//! artifact set loads and executes even where the bindings are stubbed.

use std::collections::HashMap;
use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::multiplier::MultiplierSpec;

use super::artifact::{Manifest, ModuleKind, ModuleSpec};
use super::lower::{LoweredExec, Program};

/// Execution telemetry for one runtime instance.
#[derive(Clone, Debug, Default)]
pub struct RuntimeStats {
    /// Module executions performed.
    pub executions: u64,
    /// Operand pairs evaluated.
    pub pairs_evaluated: u64,
    /// Cumulative execution wall time.
    pub exec_time: Duration,
    /// Cumulative compile/load wall time.
    pub compile_time: Duration,
}

/// Loaded-and-compiled artifact set.
pub struct Runtime {
    /// Constructed only when legacy HLO modules are present.
    #[allow(dead_code)]
    client: Option<xla::PjRtClient>,
    modules: HashMap<(u32, ModuleKind), LoadedModule>,
    /// Design-lowered modules, keyed by their exact design spec.
    lowered: HashMap<MultiplierSpec, LoweredModule>,
    batch: usize,
    stats: RuntimeStats,
}

struct LoadedModule {
    spec: ModuleSpec,
    exe: xla::PjRtLoadedExecutable,
}

struct LoweredModule {
    name: String,
    exec: LoweredExec,
}

impl Runtime {
    /// Create the runtime from `<dir>/manifest.json`, compiling every
    /// module (legacy modules through the PJRT client, lowered modules
    /// through the software executor).
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        Self::from_manifest(&manifest)
    }

    /// Compile every module of an already-parsed manifest.
    pub fn from_manifest(manifest: &Manifest) -> Result<Self> {
        let mut compile_time = Duration::ZERO;
        let mut modules = HashMap::new();
        let client = if manifest.modules.is_empty() {
            None
        } else {
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PjRtClient::cpu: {e}"))?;
            for spec in &manifest.modules {
                let path = manifest.dir.join(&spec.file);
                let started = Instant::now();
                let proto = xla::HloModuleProto::from_text_file(&path)
                    .map_err(|e| anyhow!("parsing HLO text {path:?}: {e}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .map_err(|e| anyhow!("compiling {}: {e}", spec.name))?;
                compile_time += started.elapsed();
                modules.insert((spec.n, spec.kind), LoadedModule { spec: spec.clone(), exe });
            }
            Some(client)
        };
        let mut lowered = HashMap::new();
        for ls in &manifest.lowered {
            let path = manifest.dir.join(&ls.file);
            let started = Instant::now();
            let text = std::fs::read_to_string(&path)
                .map_err(|e| anyhow!("reading lowered module {path:?}: {e}"))?;
            let prog = Program::parse(&text)
                .map_err(|e| anyhow!("parsing lowered module {path:?}: {e}"))?;
            if prog.n != ls.n {
                bail!(
                    "lowered module {path:?}: program bit-width n={} contradicts manifest n={}",
                    prog.n,
                    ls.n
                );
            }
            compile_time += started.elapsed();
            lowered.insert(ls.design, LoweredModule { name: ls.name.clone(), exec: LoweredExec::new(prog) });
        }
        Ok(Self {
            client,
            modules,
            lowered,
            batch: manifest.batch,
            stats: RuntimeStats { compile_time, ..Default::default() },
        })
    }

    /// The static batch size every module was lowered with.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Bit-widths with a stats module compiled.
    pub fn stats_bitwidths(&self) -> Vec<u32> {
        let mut v: Vec<u32> = self
            .modules
            .keys()
            .filter(|(_, k)| *k == ModuleKind::Stats)
            .map(|(n, _)| *n)
            .collect();
        v.sort_unstable();
        v
    }

    /// Whether a legacy module for `(n, kind)` is loaded.
    pub fn has(&self, n: u32, kind: ModuleKind) -> bool {
        self.modules.contains_key(&(n, kind))
    }

    /// Whether a design-lowered module can serve `design` (exact spec, or
    /// its canonical representative — `t = 0` segmented ≡ accurate, ...).
    pub fn has_lowered(&self, design: &MultiplierSpec) -> bool {
        self.lowered.contains_key(design) || self.lowered.contains_key(&design.canonical())
    }

    /// Whether any module (legacy or lowered) serves bit-width `n`.
    pub fn supports_bitwidth(&self, n: u32) -> bool {
        self.has(n, ModuleKind::Stats) || self.lowered.keys().any(|d| d.n() == n)
    }

    /// Designs with a lowered module, in deterministic (name) order.
    pub fn lowered_designs(&self) -> Vec<MultiplierSpec> {
        let mut v: Vec<MultiplierSpec> = self.lowered.keys().copied().collect();
        v.sort_by_key(|d| d.name());
        v
    }

    /// Number of design-lowered modules compiled.
    pub fn lowered_len(&self) -> usize {
        self.lowered.len()
    }

    /// Telemetry snapshot.
    pub fn stats(&self) -> RuntimeStats {
        self.stats.clone()
    }

    /// Execute the lowered module for `design` (exact spec first, then
    /// canonical): returns the approximate products. Operand length must
    /// equal the lowered batch — callers pad (see the PJRT backend).
    pub fn exec_lowered(&mut self, design: &MultiplierSpec, a: &[u64], b: &[u64]) -> Result<Vec<u64>> {
        let key = if self.lowered.contains_key(design) { *design } else { design.canonical() };
        let module = self
            .lowered
            .get_mut(&key)
            .ok_or_else(|| anyhow!("no lowered module for design {} (run `segmul lower`)", design.name()))?;
        if a.len() != b.len() || a.len() != self.batch {
            bail!(
                "operand length {} != lowered batch {} (module {})",
                a.len(),
                self.batch,
                module.name
            );
        }
        let started = Instant::now();
        let mut out = vec![0u64; a.len()];
        module.exec.run(a, b, &mut out);
        self.stats.executions += 1;
        self.stats.pairs_evaluated += a.len() as u64;
        self.stats.exec_time += started.elapsed();
        Ok(out)
    }

    fn execute(&mut self, n: u32, kind: ModuleKind, a: &[u64], b: &[u64], t: u64, fix: bool) -> Result<(xla::Literal, usize)> {
        let module = self
            .modules
            .get(&(n, kind))
            .ok_or_else(|| anyhow!("no {kind:?} module for n={n} (run `make artifacts`)"))?;
        if a.len() != module.spec.batch || b.len() != module.spec.batch {
            bail!(
                "operand length {} != lowered batch {} (module {})",
                a.len(),
                module.spec.batch,
                module.spec.name
            );
        }
        if t >= n as u64 {
            bail!("splitting point t={t} out of range for n={n}");
        }
        let started = Instant::now();
        let lit_a = xla::Literal::vec1(a);
        let lit_b = xla::Literal::vec1(b);
        let lit_t = xla::Literal::from(t);
        let lit_fix = xla::Literal::from(fix as u64);
        let result = module
            .exe
            .execute::<xla::Literal>(&[lit_a, lit_b, lit_t, lit_fix])
            .map_err(|e| anyhow!("executing {}: {e}", module.spec.name))?;
        let literal = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {}: {e}", module.spec.name))?;
        // Lowered with return_tuple=True → unwrap the 1-tuple.
        let out = literal
            .to_tuple1()
            .map_err(|e| anyhow!("untupling result of {}: {e}", module.spec.name))?;
        self.stats.executions += 1;
        self.stats.pairs_evaluated += a.len() as u64;
        self.stats.exec_time += started.elapsed();
        Ok((out, module.spec.out_len))
    }

    /// Run the stats module: returns the raw f64 statistics vector
    /// (layout documented in `python/compile/model.py`).
    pub fn exec_stats(&mut self, n: u32, a: &[u64], b: &[u64], t: u64, fix: bool) -> Result<Vec<f64>> {
        let (out, out_len) = self.execute(n, ModuleKind::Stats, a, b, t, fix)?;
        let v = out
            .to_vec::<f64>()
            .map_err(|e| anyhow!("reading stats vector: {e}"))?;
        if v.len() != out_len {
            bail!("stats length {} != manifest {}", v.len(), out_len);
        }
        Ok(v)
    }

    /// Run the prod module: returns the approximate products.
    pub fn exec_prod(&mut self, n: u32, a: &[u64], b: &[u64], t: u64, fix: bool) -> Result<Vec<u64>> {
        let (out, out_len) = self.execute(n, ModuleKind::Prod, a, b, t, fix)?;
        let v = out
            .to_vec::<u64>()
            .map_err(|e| anyhow!("reading product vector: {e}"))?;
        if v.len() != out_len {
            bail!("product length {} != manifest {}", v.len(), out_len);
        }
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multiplier::wordlevel::approx_seq_mul;
    use crate::runtime::lower::emit_artifacts;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn lowered_only_runtime_loads_and_executes_without_xla() {
        // The vendored xla stub cannot construct a client; a lowered-only
        // manifest must not need one.
        let dir = std::env::temp_dir().join(format!("segmul_runtime_lowered_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let specs = MultiplierSpec::registry_examples(8);
        emit_artifacts(&dir, &specs, 128).unwrap();
        let mut rt = Runtime::load(&dir).unwrap();
        assert_eq!(rt.batch(), 128);
        assert_eq!(rt.lowered_len(), specs.len());
        assert!(rt.supports_bitwidth(8));
        assert!(!rt.supports_bitwidth(16));
        assert!(rt.stats_bitwidths().is_empty(), "no legacy stats modules");

        let mut rng = Xoshiro256::seed_from_u64(3);
        let a: Vec<u64> = (0..128).map(|_| rng.next_bits(8)).collect();
        let b: Vec<u64> = (0..128).map(|_| rng.next_bits(8)).collect();
        let got = rt.exec_lowered(&MultiplierSpec::Segmented { n: 8, t: 4, fix: true }, &a, &b).unwrap();
        for i in 0..a.len() {
            assert_eq!(got[i], approx_seq_mul(a[i], b[i], 8, 4, true), "i={i}");
        }
        // Canonical fallback: t=0 segmented served by the accurate module.
        assert!(rt.has_lowered(&MultiplierSpec::Segmented { n: 8, t: 0, fix: true }));
        let t0 = rt.exec_lowered(&MultiplierSpec::Segmented { n: 8, t: 0, fix: false }, &a, &b).unwrap();
        for i in 0..a.len() {
            assert_eq!(t0[i], a[i] * b[i], "i={i}");
        }
        // Telemetry counted the lowered executions.
        let stats = rt.stats();
        assert_eq!(stats.executions, 2);
        assert_eq!(stats.pairs_evaluated, 256);

        // Wrong batch is rejected; unknown designs name `segmul lower`.
        assert!(rt.exec_lowered(&MultiplierSpec::Mitchell { n: 8 }, &a[..10], &b[..10]).is_err());
        let e = rt
            .exec_lowered(&MultiplierSpec::Truncated { n: 16, k: 2 }, &a, &b)
            .unwrap_err()
            .to_string();
        assert!(e.contains("segmul lower"), "{e}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn legacy_manifest_still_requires_the_xla_client() {
        // A v1 manifest with HLO modules must keep failing against the
        // stub bindings (graceful CPU fallback at the call sites).
        let dir = std::env::temp_dir().join(format!("segmul_runtime_legacy_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("m.hlo.txt"), "HloModule fake").unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"batch": 8, "modules": [
                {"name":"seqmul_stats_n4","kind":"stats","n":4,"file":"m.hlo.txt",
                 "output":{"dtype":"f64","shape":[14]}}
            ]}"#,
        )
        .unwrap();
        let e = Runtime::load(&dir).unwrap_err().to_string();
        assert!(e.contains("unavailable"), "{e}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
