//! The typed error taxonomy of the public API surface.
//!
//! Defined here in the core layer (validation lives in `multiplier::spec`,
//! `coordinator::job`, and `util::threadpool`, all below the facade) and
//! re-exported through [`crate::api`]. Internal machinery keeps using
//! `anyhow` where enumerating failure shapes gains nothing, but
//! everything exported through the facade — spec validation, builders,
//! session startup, job execution — reports a [`SegmulError`] so callers
//! can branch on the failure class instead of parsing strings.
//! `SegmulError` implements [`std::error::Error`], so `?` converts it
//! into `anyhow::Error` at the machinery boundary, and
//! [`From<anyhow::Error>`] converts the other way at the facade boundary.

use std::fmt;

/// Public-surface error classes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SegmulError {
    /// Invalid configuration: environment variables (`SEGMUL_WORKERS`),
    /// config-file values, or builder settings.
    Config(String),
    /// An invalid [`crate::multiplier::spec::MultiplierSpec`].
    Spec {
        /// Display name of the offending design.
        design: String,
        reason: String,
    },
    /// An invalid workload (sample budget, exhaustive range, CI target).
    Workload(String),
    /// An invalid or inconsistent AOT-artifact manifest / lowered module
    /// (`artifacts/manifest.json`, written by `segmul lower` or
    /// `make artifacts`): malformed JSON, unsupported schema version,
    /// missing module files, or per-module metadata that contradicts the
    /// manifest (wrong bit-width, wrong batch shape, duplicate designs).
    Artifact {
        /// The offending file (manifest or module), display form.
        path: String,
        reason: String,
    },
    /// Backend construction or capability failure.
    Backend(String),
    /// Evaluation failed at run time.
    Eval(String),
    /// Metric derivation from an unusable statistics accumulator (e.g.
    /// deriving `ErrorMetrics` from zero accumulated samples, which would
    /// otherwise silently poison merged sweep rows with NaN/∞).
    Stats(String),
    /// A persistent-result-store failure (`crate::store`): an unreadable
    /// store directory, or a blob that is truncated, bit-flipped, schema-
    /// mismatched, or keyed to a different job. Consumers treat a `Store`
    /// error on load as a miss and re-evaluate — corruption must never
    /// become a silent wrong answer.
    Store {
        /// The offending store path (directory or blob), display form.
        path: String,
        reason: String,
    },
    /// Report / persistence I/O failure.
    Io(String),
    /// A serving-layer failure (`crate::serve`): an admission rejection
    /// (429 over-budget, 503 draining), a deadline expiry (504), or a
    /// malformed wire request (400/404/405/413/431). Carries the HTTP
    /// status the wire layer maps it to, so the rejection class survives
    /// a trip through `anyhow` and back.
    Serve {
        /// HTTP status code of the wire mapping.
        status: u16,
        reason: String,
    },
}

impl SegmulError {
    /// A [`SegmulError::Config`].
    pub fn config(msg: impl Into<String>) -> Self {
        SegmulError::Config(msg.into())
    }

    /// A [`SegmulError::Spec`] for `design`.
    pub fn spec(design: impl Into<String>, reason: impl Into<String>) -> Self {
        SegmulError::Spec { design: design.into(), reason: reason.into() }
    }

    /// A [`SegmulError::Workload`].
    pub fn workload(msg: impl Into<String>) -> Self {
        SegmulError::Workload(msg.into())
    }

    /// A [`SegmulError::Backend`].
    pub fn backend(msg: impl Into<String>) -> Self {
        SegmulError::Backend(msg.into())
    }

    /// A [`SegmulError::Artifact`] at `path`.
    pub fn artifact(path: impl Into<String>, reason: impl Into<String>) -> Self {
        SegmulError::Artifact { path: path.into(), reason: reason.into() }
    }

    /// A [`SegmulError::Stats`].
    pub fn stats(msg: impl Into<String>) -> Self {
        SegmulError::Stats(msg.into())
    }

    /// A [`SegmulError::Store`] at `path`.
    pub fn store(path: impl Into<String>, reason: impl Into<String>) -> Self {
        SegmulError::Store { path: path.into(), reason: reason.into() }
    }

    /// A [`SegmulError::Serve`] carrying its HTTP status.
    pub fn serve(status: u16, reason: impl Into<String>) -> Self {
        SegmulError::Serve { status, reason: reason.into() }
    }

    /// Short class tag (stable across message rewording).
    pub fn kind(&self) -> &'static str {
        match self {
            SegmulError::Config(_) => "config",
            SegmulError::Spec { .. } => "spec",
            SegmulError::Workload(_) => "workload",
            SegmulError::Artifact { .. } => "artifact",
            SegmulError::Backend(_) => "backend",
            SegmulError::Eval(_) => "eval",
            SegmulError::Stats(_) => "stats",
            SegmulError::Store { .. } => "store",
            SegmulError::Io(_) => "io",
            SegmulError::Serve { .. } => "serve",
        }
    }
}

impl fmt::Display for SegmulError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SegmulError::Config(m) => write!(f, "configuration error: {m}"),
            SegmulError::Spec { design, reason } => {
                write!(f, "invalid design {design}: {reason}")
            }
            SegmulError::Workload(m) => write!(f, "invalid workload: {m}"),
            SegmulError::Artifact { path, reason } => {
                write!(f, "invalid artifact {path}: {reason}")
            }
            SegmulError::Backend(m) => write!(f, "backend error: {m}"),
            SegmulError::Eval(m) => write!(f, "evaluation error: {m}"),
            SegmulError::Stats(m) => write!(f, "statistics error: {m}"),
            SegmulError::Store { path, reason } => {
                write!(f, "result store error at {path}: {reason}")
            }
            SegmulError::Io(m) => write!(f, "io error: {m}"),
            SegmulError::Serve { status, reason } => {
                write!(f, "serve error (http {status}): {reason}")
            }
        }
    }
}

impl std::error::Error for SegmulError {}

/// Machinery errors crossing the facade boundary default to the `Eval`
/// class. The vendored `anyhow` shim flattens errors to strings (no
/// downcast), so facade entry points validate **before** handing work to
/// anyhow-typed machinery — this conversion only ever sees genuine
/// run-time evaluation failures.
impl From<anyhow::Error> for SegmulError {
    fn from(e: anyhow::Error) -> Self {
        SegmulError::Eval(e.to_string())
    }
}

impl From<std::io::Error> for SegmulError {
    fn from(e: std::io::Error) -> Self {
        SegmulError::Io(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_carries_class_and_message() {
        let e = SegmulError::config("SEGMUL_WORKERS=0");
        assert!(e.to_string().contains("configuration"));
        assert!(e.to_string().contains("SEGMUL_WORKERS=0"));
        assert_eq!(e.kind(), "config");
        let e = SegmulError::spec("segmul(n=8,t=9)", "t out of range");
        assert!(e.to_string().contains("segmul(n=8,t=9)"));
        assert_eq!(e.kind(), "spec");
        let e = SegmulError::artifact("artifacts/manifest.json", "module batch 4 != manifest batch 8");
        assert!(e.to_string().contains("manifest.json"));
        assert!(e.to_string().contains("batch"));
        assert_eq!(e.kind(), "artifact");
        let e = SegmulError::stats("no samples accumulated");
        assert!(e.to_string().contains("statistics"));
        assert!(e.to_string().contains("no samples"));
        assert_eq!(e.kind(), "stats");
        let e = SegmulError::store("store/blobs/ab.json", "integrity check mismatch");
        assert!(e.to_string().contains("store/blobs/ab.json"));
        assert!(e.to_string().contains("integrity"));
        assert_eq!(e.kind(), "store");
        let e = SegmulError::serve(429, "in-flight budget exhausted");
        assert!(e.to_string().contains("429"));
        assert!(e.to_string().contains("budget"));
        assert_eq!(e.kind(), "serve");
        assert_eq!(e, SegmulError::Serve { status: 429, reason: "in-flight budget exhausted".into() });
    }

    #[test]
    fn converts_both_ways_across_the_anyhow_boundary() {
        // typed -> anyhow (machinery `?`)
        fn machinery() -> anyhow::Result<()> {
            Err(SegmulError::workload("samples must be positive"))?;
            Ok(())
        }
        let msg = machinery().unwrap_err().to_string();
        assert!(msg.contains("samples must be positive"), "{msg}");
        // anyhow -> typed (facade boundary)
        let typed = SegmulError::from(anyhow::anyhow!("backend exploded"));
        assert_eq!(typed.kind(), "eval");
        assert!(typed.to_string().contains("backend exploded"));
    }
}
