//! Monte-Carlo error evaluation.
//!
//! For n > 16 the paper switches to MC simulation with 2^32 uniform input
//! patterns; here the sample count is configurable (EXPERIMENTS.md records
//! the counts used). Sampling is chunked with independent xoshiro streams
//! whose layout is derived from the sample count and `chunk` size only —
//! never from the worker count — so every integer statistic is bit-exact
//! per seed for any `workers`. Only the f64 `sum_red` can wobble in its
//! last bits here, because `parallel_fold` groups chunk merges by worker;
//! the coordinator's sharded runner (`coordinator::sharded`) instead
//! folds chunks in id order and is bit-identical across worker counts,
//! `sum_red` included.
//!
//! Within each chunk, operands are sampled into blocks and evaluated
//! through the batched engine ([`super::stream::BatchAccumulator`]), so
//! the multiply inner loop is the same monomorphized kernel the
//! exhaustive path uses. The sampling order (a, b interleaved per pair,
//! sequential within a chunk) is part of the reproducibility contract and
//! is unchanged by the blocking.

use crate::multiplier::batch::BatchMultiplier;
use crate::multiplier::{Multiplier, ScalarBatch, SegmentedSeqMul};
use crate::util::rng::Xoshiro256;
use crate::util::threadpool::{default_workers, parallel_fold};

use super::metrics::ErrorStats;
use super::stream::{BatchAccumulator, BLOCK};

/// Operand distribution for MC sampling.
#[derive(Clone, Debug)]
pub enum InputDist {
    /// Uniform over `[0, 2^n)` (the paper's Fig. 2 setting).
    Uniform,
    /// Weighted distribution over `[0, 2^n)` via a probability table
    /// (the paper's `Pr(a)·Pr(b)` measured-PDF MED variant); sampled with
    /// Walker's alias method. Practical for n ≤ 16.
    Weighted(AliasTable),
}

/// Walker alias table for O(1) weighted sampling.
#[derive(Clone, Debug)]
pub struct AliasTable {
    prob: Vec<f64>,
    alias: Vec<u32>,
}

impl AliasTable {
    /// Build from non-negative weights (need not be normalized).
    pub fn new(weights: &[f64]) -> Self {
        assert!(!weights.is_empty() && weights.len() <= (1 << 16));
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weights must not all be zero");
        let k = weights.len();
        let mut prob: Vec<f64> = weights.iter().map(|w| w * k as f64 / total).collect();
        let mut alias = vec![0u32; k];
        let mut small: Vec<u32> = (0..k as u32).filter(|&i| prob[i as usize] < 1.0).collect();
        let mut large: Vec<u32> = (0..k as u32).filter(|&i| prob[i as usize] >= 1.0).collect();
        while let (Some(s), Some(l)) = (small.pop(), large.pop()) {
            alias[s as usize] = l;
            prob[l as usize] = (prob[l as usize] + prob[s as usize]) - 1.0;
            if prob[l as usize] < 1.0 {
                small.push(l);
            } else {
                large.push(l);
            }
        }
        // Numerical leftovers become certain columns.
        for i in small.into_iter().chain(large) {
            prob[i as usize] = 1.0;
        }
        Self { prob, alias }
    }

    #[inline]
    /// Draw one operand (alias method, O(1)).
    pub fn sample(&self, rng: &mut Xoshiro256) -> u64 {
        let k = self.prob.len() as u64;
        let col = rng.next_below(k) as usize;
        if rng.next_f64() < self.prob[col] {
            col as u64
        } else {
            self.alias[col] as u64
        }
    }
}

/// MC evaluation configuration.
#[derive(Clone, Debug)]
pub struct McConfig {
    /// Total samples.
    pub samples: u64,
    /// Base RNG seed.
    pub seed: u64,
    /// Samples per independent RNG stream (chunk) — fixes the reproducible
    /// decomposition of the sample space.
    pub chunk: u64,
    /// Operand-`a` distribution.
    pub dist_a: InputDist,
    /// Operand-`b` distribution.
    pub dist_b: InputDist,
    /// Worker threads for the chunked parallel path.
    pub workers: usize,
}

impl McConfig {
    /// Uniform operands: `samples` draws seeded with `seed`.
    pub fn uniform(samples: u64, seed: u64) -> Self {
        Self {
            samples,
            seed,
            chunk: 1 << 16,
            dist_a: InputDist::Uniform,
            dist_b: InputDist::Uniform,
            // Infallible convenience: an invalid SEGMUL_WORKERS is
            // surfaced as a typed error by the api facade / CLI; here it
            // degrades to a single worker.
            workers: default_workers().unwrap_or(1),
        }
    }
}

#[inline]
fn sample_operand(dist: &InputDist, n: u32, rng: &mut Xoshiro256) -> u64 {
    match dist {
        InputDist::Uniform => rng.next_bits(n),
        InputDist::Weighted(table) => table.sample(rng),
    }
}

/// MC stats for the paper's segmented sequential multiplier (the batched
/// monomorphized kernel).
pub fn mc_stats(n: u32, t: u32, fix: bool, cfg: &McConfig) -> ErrorStats {
    assert!(n >= 1 && n <= 32);
    assert!(t < n);
    mc_stats_batch(&SegmentedSeqMul::new(n, t, fix), cfg)
}

/// MC stats for any scalar [`Multiplier`] (via the [`ScalarBatch`]
/// adapter — per-pair dispatch, but the same sampling decomposition).
pub fn mc_stats_mul(m: &dyn Multiplier, cfg: &McConfig) -> ErrorStats {
    mc_stats_batch(&ScalarBatch(m), cfg)
}

/// MC stats for any [`BatchMultiplier`]. Chunks are assigned to workers;
/// each chunk owns an independent xoshiro stream and is evaluated in
/// [`BLOCK`]-sized operand blocks through the batched engine.
pub fn mc_stats_batch(m: &dyn BatchMultiplier, cfg: &McConfig) -> ErrorStats {
    assert!(cfg.samples > 0 && cfg.chunk > 0);
    let n = m.n();
    let n_chunks = cfg.samples.div_ceil(cfg.chunk);
    parallel_fold(
        n_chunks,
        cfg.workers,
        |_, first_chunk, last_chunk| {
            let mut acc = BatchAccumulator::new(m);
            let mut a = vec![0u64; BLOCK];
            let mut b = vec![0u64; BLOCK];
            for chunk_id in first_chunk..last_chunk {
                let mut rng = Xoshiro256::stream(cfg.seed, chunk_id);
                let mut remaining = cfg.chunk.min(cfg.samples - chunk_id * cfg.chunk);
                while remaining > 0 {
                    let len = (remaining as usize).min(BLOCK);
                    for (ai, bi) in a[..len].iter_mut().zip(&mut b[..len]) {
                        *ai = sample_operand(&cfg.dist_a, n, &mut rng);
                        *bi = sample_operand(&cfg.dist_b, n, &mut rng);
                    }
                    acc.eval_pairs(&a[..len], &b[..len]);
                    remaining -= len as u64;
                }
            }
            acc.finish()
        },
        |mut acc, part| {
            acc.merge(&part);
            acc
        },
    )
    .expect("samples > 0")
}

/// Standard error of the MED estimate (for CI-based stopping): the sample
/// standard deviation of |ED| is not tracked exactly, so we use the
/// conservative bound `MAE / (2·sqrt(samples))` when only `ErrorStats` is
/// available.
pub fn med_stderr_bound(stats: &ErrorStats) -> f64 {
    if stats.count == 0 {
        return f64::INFINITY;
    }
    stats.max_abs_ed as f64 / (2.0 * (stats.count as f64).sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::exhaustive::exhaustive_stats;

    #[test]
    fn deterministic_per_seed() {
        let cfg = McConfig::uniform(10_000, 7);
        let a = mc_stats(8, 4, true, &cfg);
        let b = mc_stats(8, 4, true, &cfg);
        assert_eq!(a, b);
    }

    #[test]
    fn worker_count_does_not_change_result() {
        let mut cfg = McConfig::uniform(20_000, 3);
        cfg.workers = 1;
        let w1 = mc_stats(8, 3, false, &cfg);
        cfg.workers = 5;
        let w5 = mc_stats(8, 3, false, &cfg);
        assert!(w1.approx_eq(&w5));
    }

    #[test]
    fn sample_count_exact_with_ragged_tail() {
        let mut cfg = McConfig::uniform(100_001, 1);
        cfg.chunk = 1000;
        let s = mc_stats(8, 2, false, &cfg);
        assert_eq!(s.count, 100_001);
    }

    #[test]
    fn batched_and_scalar_adapter_agree() {
        // The monomorphized batch kernel and the per-pair scalar adapter
        // must see identical operands and produce identical statistics.
        let cfg = McConfig::uniform(30_000, 21);
        let m = crate::multiplier::SegmentedSeqMul::new(10, 4, true);
        let fast = mc_stats(10, 4, true, &cfg);
        let via_adapter = mc_stats_mul(&m, &cfg);
        assert!(fast.approx_eq(&via_adapter));
    }

    #[test]
    fn mc_converges_to_exhaustive() {
        // ER from 2^20 samples must be within ~3 sigma of the exhaustive ER.
        let (n, t) = (8u32, 4u32);
        let exact = exhaustive_stats(n, t, true).metrics().unwrap();
        let mc = mc_stats(n, t, true, &McConfig::uniform(1 << 20, 11)).metrics().unwrap();
        let sigma = (exact.er * (1.0 - exact.er) / (1u64 << 20) as f64).sqrt();
        assert!(
            (mc.er - exact.er).abs() < 4.0 * sigma + 1e-9,
            "MC ER {} vs exhaustive {} (sigma {sigma})",
            mc.er,
            exact.er
        );
        // MED (abs) within 2%
        assert!(
            (mc.med_abs - exact.med_abs).abs() / exact.med_abs < 0.02,
            "MC med {} vs exhaustive {}",
            mc.med_abs,
            exact.med_abs
        );
    }

    #[test]
    fn alias_table_matches_weights() {
        let weights = [0.1, 0.0, 0.4, 0.5];
        let table = AliasTable::new(&weights);
        let mut rng = Xoshiro256::seed_from_u64(2);
        let mut counts = [0u64; 4];
        let trials = 200_000;
        for _ in 0..trials {
            counts[table.sample(&mut rng) as usize] += 1;
        }
        assert_eq!(counts[1], 0);
        for (i, &w) in weights.iter().enumerate() {
            let freq = counts[i] as f64 / trials as f64;
            assert!((freq - w).abs() < 0.01, "bin {i}: {freq} vs {w}");
        }
    }

    #[test]
    fn weighted_dist_drives_eval() {
        // Distribution concentrated on single values => deterministic inputs.
        let mut wa = vec![0.0; 256];
        wa[11] = 1.0;
        let mut wb = vec![0.0; 256];
        wb[6] = 1.0;
        let cfg = McConfig {
            samples: 100,
            seed: 1,
            chunk: 10,
            dist_a: InputDist::Weighted(AliasTable::new(&wa)),
            dist_b: InputDist::Weighted(AliasTable::new(&wb)),
            workers: 2,
        };
        let s = mc_stats(8, 2, false, &cfg);
        assert_eq!(s.count, 100);
        // 11 * 6 never generates an LSP carry situation? just check determinism
        let s2 = mc_stats(8, 2, false, &cfg);
        assert_eq!(s, s2);
    }

    #[test]
    fn stderr_bound_shrinks() {
        let small = mc_stats(8, 4, false, &McConfig::uniform(1_000, 5));
        let large = mc_stats(8, 4, false, &McConfig::uniform(100_000, 5));
        assert!(med_stderr_bound(&large) < med_stderr_bound(&small));
    }
}
