//! §V-B probability-propagation estimator.
//!
//! Theorems 1–2 make exact ER/MED/MRED computation #P-complete; the paper's
//! remedy is to propagate approximate signal probabilities `ρ̂(Ŝ_i^j)`,
//! `ρ̂(Ĉ_i^j)` through the recurrences, "disregarding correlations between
//! Ŝ and Ĉ" and keeping only the strongest local structure. Our
//! implementation keeps the two dominant exact structures:
//!
//! * the per-cycle mixture over `b_j ∈ {0, 1}` — every partial-product bit
//!   of cycle j shares `b_j`, so each cycle is propagated twice (generate
//!   probability 0 when `b_j = 0`) and mixed 50/50;
//! * the in-cycle carry chain decomposition `cout = g + p·cin` with
//!   generate/propagate disjointness (`g = x∧pp`, `p = x⊕pp` cannot both
//!   hold).
//!
//! Everything else is independence — exactly the spirit of the paper's
//! cofactor scheme. The estimator also evaluates Eq. (9) per accumulation
//! and an independence-composed Eq. (10) for the product ER, plus a MED
//! estimate from the delayed-carry overshoot/drop weights. E6 in
//! EXPERIMENTS.md quantifies estimator-vs-exhaustive accuracy.

/// Probability lattice for an (n, t) configuration under uniform inputs.
#[derive(Clone, Debug)]
pub struct ProbLattice {
    /// Operand bit-width.
    pub n: u32,
    /// Splitting point.
    pub t: u32,
    /// `ps[j][i] = ρ̂(Ŝ_i^j)`, i ∈ [0, n] (index n is the carry-out bit).
    pub ps: Vec<Vec<f64>>,
    /// `pc_ff[j] = ρ̂(Ĉ_{t-1}^j)` — the D-FF input after cycle j.
    pub pc_ff: Vec<f64>,
}

#[inline]
fn xor3(a: f64, b: f64, c: f64) -> f64 {
    // P(a ⊕ b ⊕ c) for independent Bernoulli a, b, c.
    let ab = a * (1.0 - b) + b * (1.0 - a);
    ab * (1.0 - c) + c * (1.0 - ab)
}

/// Clamp a propagated probability into [0, 1]. The `xor3`/carry-chain
/// compositions are long f64 product chains; rounding drift can push a
/// mathematically-valid probability epsilon outside the unit interval,
/// which then breaks downstream `sqrt`/log users. Every per-cycle store
/// goes through this.
#[inline]
fn clamp01(p: f64) -> f64 {
    p.clamp(0.0, 1.0)
}

/// Propagate signal probabilities for the approximate multiplier.
///
/// `t = 0` propagates the accurate design (no D-FF events, `pc_ff = 0`).
pub fn propagate(n: u32, t: u32) -> ProbLattice {
    assert!(n >= 1 && n <= 64);
    assert!(t < n);
    let nn = n as usize;
    let mut ps: Vec<Vec<f64>> = Vec::with_capacity(nn);
    let mut pc_ff = vec![0.0f64; nn];

    // Cycle 0: S_i^0 = a_i ∧ b_0 → 1/4; carry-out bit S_n^0 = 0.
    let mut row = vec![0.25f64; nn + 1];
    row[nn] = 0.0;
    ps.push(row);

    for j in 1..nn {
        let prev = &ps[j - 1];
        let ff = if t >= 1 { pc_ff[j - 1] } else { 0.0 };
        // Two branches over b_j; each yields (sum probs, C_{t-1} prob).
        let mut mixed = vec![0.0f64; nn + 1];
        let mut mixed_ff = 0.0f64;
        for &bj in &[0.0f64, 1.0] {
            let mut cin = 0.0f64; // carry into bit 0 is absent
            let mut branch = vec![0.0f64; nn + 1];
            let mut branch_ff = 0.0;
            let mut cout = 0.0;
            for i in 0..nn {
                let x = prev[i + 1]; // S_{i+1}^{j-1}
                let ppp = 0.5 * bj; // P(a_i ∧ b_j | b_j)
                let cin_here = if t >= 1 && i == t as usize { ff } else { cin };
                branch[i] = clamp01(xor3(x, cin_here, ppp));
                // g = x ∧ pp, prop = x ⊕ pp — disjoint, so cout = g + p·cin.
                let g = x * ppp;
                let p = x * (1.0 - ppp) + ppp * (1.0 - x);
                cout = clamp01(g + p * cin_here);
                if t >= 1 && i == t as usize - 1 {
                    branch_ff = cout;
                }
                cin = cout;
            }
            branch[nn] = cout; // S_n^j = C_{n-1}^j
            for (m, b) in mixed.iter_mut().zip(&branch) {
                *m += 0.5 * b;
            }
            mixed_ff += 0.5 * branch_ff;
        }
        pc_ff[j] = clamp01(mixed_ff);
        ps.push(mixed.into_iter().map(clamp01).collect());
    }
    ProbLattice { n, t, ps, pc_ff }
}

impl ProbLattice {
    /// Eq. (9): per-accumulation error probability — a carry generated in
    /// the LSP reaching (or generated at) its MSB during cycle `j`.
    /// Requires `j >= 1` (cycle 0 introduces no error) and `t >= 1`.
    pub fn er_accumulation(&self, j: u32) -> f64 {
        assert!(j >= 1 && (j as usize) < self.ps.len());
        if self.t == 0 {
            return 0.0;
        }
        let t = self.t as usize;
        let prev = &self.ps[j as usize - 1];
        // All events require b_j = 1 (probability 1/2); under b_j = 1 the
        // partial-product bit is a_i (probability 1/2) and the propagate
        // probability at bit l is P(Ŝ_{l+1} ⊕ a_l) = 1/2 exactly.
        let mut p = 0.5 * prev[t] * 0.5; // generate directly at the MSB (i = t-1)
        for i in 0..t.saturating_sub(1) {
            let gen = prev[i + 1] * 0.5;
            let prop = 0.5f64.powi((t - 1 - i) as i32);
            p += 0.5 * gen * prop;
        }
        p
    }

    /// Eq. (10) under event independence: the product-level ER composed
    /// from every cycle's delayed-carry event (each delayed or dropped
    /// carry perturbs at least one surviving product bit).
    pub fn er_estimate(&self) -> f64 {
        if self.t == 0 {
            return 0.0;
        }
        let mut no_error = 1.0f64;
        for j in 1..self.n {
            no_error *= 1.0 - self.er_accumulation(j);
        }
        1.0 - no_error
    }

    /// MED estimate (signed, fix-to-1 disabled) from the delayed-carry
    /// weights: a carry deferred from cycle j to j+1 overshoots by
    /// `-2^{t+j}`; the final cycle's carry is dropped, `+2^{n+t-1}`.
    pub fn med_estimate(&self) -> f64 {
        if self.t == 0 {
            return 0.0;
        }
        let (n, t) = (self.n, self.t);
        let mut med = 0.0f64;
        for j in 1..n {
            let p_carry = self.pc_ff[j as usize];
            if j < n - 1 {
                med -= p_carry * (1u128 << (t + j)) as f64;
            } else {
                med += p_carry * (1u128 << (n + t - 1)) as f64;
            }
        }
        med
    }

    /// Estimated probability that fix-to-1 triggers: `ρ̂(Ĉ_{t-1}^{n-1})`.
    pub fn fix_probability(&self) -> f64 {
        if self.t == 0 {
            0.0
        } else {
            self.pc_ff[self.n as usize - 1]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::exhaustive::exhaustive_stats;
    use crate::multiplier::wordlevel::approx_seq_mul;

    #[test]
    fn probabilities_are_probabilities() {
        for (n, t) in [(8u32, 4u32), (12, 3), (16, 8), (32, 16)] {
            let lat = propagate(n, t);
            for row in &lat.ps {
                for &p in row {
                    assert!((0.0..=1.0).contains(&p), "p={p}");
                }
            }
            for &p in &lat.pc_ff {
                assert!((0.0..=1.0).contains(&p));
            }
        }
    }

    #[test]
    fn prop_all_probabilities_in_unit_interval_full_grid() {
        // Property over the FULL (n, t) grid up to n = 32: every stored
        // ρ̂ — lattice rows, FF carries, and the derived estimates — is a
        // probability. Guards the clamp against f64 drift in the long
        // xor3/carry product chains.
        for n in 1..=32u32 {
            for t in 0..n {
                let lat = propagate(n, t);
                for (j, row) in lat.ps.iter().enumerate() {
                    for (i, &p) in row.iter().enumerate() {
                        assert!((0.0..=1.0).contains(&p), "n={n} t={t} ps[{j}][{i}]={p}");
                    }
                }
                for (j, &p) in lat.pc_ff.iter().enumerate() {
                    assert!((0.0..=1.0).contains(&p), "n={n} t={t} pc_ff[{j}]={p}");
                }
                let er = lat.er_estimate();
                assert!((0.0..=1.0).contains(&er), "n={n} t={t} er={er}");
                let pf = lat.fix_probability();
                assert!((0.0..=1.0).contains(&pf), "n={n} t={t} fix_p={pf}");
            }
        }
    }

    #[test]
    fn accurate_lattice_has_no_error_events() {
        let lat = propagate(8, 0);
        assert_eq!(lat.er_estimate(), 0.0);
        assert_eq!(lat.med_estimate(), 0.0);
        assert_eq!(lat.fix_probability(), 0.0);
    }

    fn exact_ff_carry_prob(n: u32, t: u32, j: u32) -> f64 {
        // Measure ρ(Ĉ_{t-1}^j) by exhaustive simulation of the word-level
        // model, extracting the FF value after cycle j.
        let mut count = 0u64;
        let total = 1u64 << (2 * n);
        for idx in 0..total {
            let a = idx & ((1 << n) - 1);
            let b = idx >> n;
            // replicate the loop up to cycle j
            let mt = (1u64 << t) - 1;
            let mut s = if b & 1 == 1 { a } else { 0 };
            let mut cff = 0u64;
            for jj in 1..=j {
                let x = s >> 1;
                let pp = if (b >> jj) & 1 == 1 { a } else { 0 };
                let lsum = (x & mt) + (pp & mt);
                let clsp = (lsum >> t) & 1;
                let msum = (x >> t) + (pp >> t) + cff;
                s = (msum << t) | (lsum & mt);
                cff = clsp;
            }
            count += cff;
        }
        count as f64 / total as f64
    }

    #[test]
    fn ff_carry_estimate_close_to_exact() {
        // The estimator's ρ̂(Ĉ_{t-1}^j) should track the exhaustive value
        // within a few percentage points (it is an approximation).
        for (n, t) in [(6u32, 2u32), (6, 3), (8, 4)] {
            let lat = propagate(n, t);
            for j in [1, n / 2, n - 1] {
                let exact = exact_ff_carry_prob(n, t, j);
                let est = lat.pc_ff[j as usize];
                assert!(
                    (exact - est).abs() < 0.06,
                    "n={n} t={t} j={j}: exact {exact} est {est}"
                );
            }
        }
    }

    #[test]
    fn er_estimate_tracks_exhaustive() {
        for (n, t) in [(6u32, 2u32), (8, 3), (8, 4)] {
            let exact = exhaustive_stats(n, t, false).metrics().unwrap().er;
            let est = propagate(n, t).er_estimate();
            let rel = (est - exact).abs() / exact;
            assert!(rel < 0.35, "n={n} t={t}: exact {exact} est {est} rel {rel}");
        }
    }

    #[test]
    fn med_estimate_sign_and_magnitude() {
        // Without fix-to-1 the signed MED is dominated by the dropped
        // final carry (positive) minus the overshoot terms.
        for (n, t) in [(6u32, 3u32), (8, 4)] {
            let exact = exhaustive_stats(n, t, false).metrics().unwrap().med_signed;
            let est = propagate(n, t).med_estimate();
            let scale = (1u64 << (n + t - 1)) as f64;
            assert!(
                (exact - est).abs() / scale < 0.10,
                "n={n} t={t}: exact {exact} est {est}"
            );
        }
    }

    #[test]
    fn fix_probability_matches_fix_trigger_rate() {
        let (n, t) = (8u32, 4u32);
        let total = 1u64 << (2 * n);
        let mut triggers = 0u64;
        for idx in 0..total {
            let a = idx & ((1 << n) - 1);
            let b = idx >> n;
            if approx_seq_mul(a, b, n, t, true) != approx_seq_mul(a, b, n, t, false) {
                triggers += 1;
            }
        }
        let exact = triggers as f64 / total as f64;
        let est = propagate(n, t).fix_probability();
        assert!((exact - est).abs() < 0.05, "exact {exact} est {est}");
    }
}
