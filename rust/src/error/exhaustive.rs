//! Exhaustive error evaluation over all `2^(2n)` input pairs.
//!
//! The paper evaluates exhaustively for n ≤ 16 (4.3·10^9 pairs on their
//! testbed); on this 1-core box the practical limit is n ≈ 12–13 (1.7·10^7
//! – 6.7·10^7 pairs), above which [`super::montecarlo`] takes over. The
//! iteration space is chunked across the scoped thread pool and each chunk
//! runs the batched streaming engine ([`super::stream::BatchAccumulator`]):
//! blocks of operand pairs go through the monomorphized word-level batch
//! kernel — no per-pair virtual dispatch anywhere on the hot path — and
//! the partial [`ErrorStats`] fold exactly regardless of the chunking.

use crate::multiplier::batch::BatchMultiplier;
use crate::multiplier::{Multiplier, ScalarBatch, SegmentedSeqMul};
use crate::util::threadpool::{default_workers, parallel_fold};

use super::metrics::ErrorStats;
use super::stream::BatchAccumulator;

/// Exhaustive stats for the paper's segmented sequential multiplier.
/// Specialized on the batched word-level kernel (no dyn dispatch in the
/// inner loop).
pub fn exhaustive_stats(n: u32, t: u32, fix: bool) -> ErrorStats {
    // Infallible convenience: an invalid SEGMUL_WORKERS is surfaced as a
    // typed error by the api facade / CLI; here it degrades to 1 worker.
    exhaustive_stats_workers(n, t, fix, default_workers().unwrap_or(1))
}

/// As [`exhaustive_stats`] with an explicit worker count.
pub fn exhaustive_stats_workers(n: u32, t: u32, fix: bool, workers: usize) -> ErrorStats {
    assert!(n >= 1 && n <= 16, "exhaustive evaluation is limited to n <= 16");
    assert!(t < n);
    exhaustive_stats_batch(&SegmentedSeqMul::new(n, t, fix), workers)
}

/// Exhaustive stats for any [`BatchMultiplier`]. The whole `2^(2n)` index
/// space is split across `workers` threads; each worker streams its range
/// through a [`BatchAccumulator`] and the partials are merged.
pub fn exhaustive_stats_batch(m: &dyn BatchMultiplier, workers: usize) -> ErrorStats {
    let n = m.n();
    assert!(n >= 1 && n <= 16, "exhaustive evaluation is limited to n <= 16");
    let total: u64 = 1u64 << (2 * n);
    parallel_fold(
        total,
        workers,
        |_, start, end| {
            let mut acc = BatchAccumulator::new(m);
            acc.eval_index_range(start, end);
            acc.finish()
        },
        |mut acc, part| {
            acc.merge(&part);
            acc
        },
    )
    .expect("nonempty input space")
}

/// Exhaustive stats for any scalar [`Multiplier`] (used for the Fig. 2
/// baselines, which have no batched kernels): the scalar model runs under
/// the batched engine through the [`ScalarBatch`] adapter.
pub fn exhaustive_stats_mul(m: &dyn Multiplier, workers: usize) -> ErrorStats {
    exhaustive_stats_batch(&ScalarBatch(m), workers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multiplier::baselines::TruncatedMul;
    use crate::multiplier::wordlevel::approx_seq_mul;
    use crate::multiplier::SegmentedSeqMul;

    #[test]
    fn accurate_config_has_zero_error() {
        let s = exhaustive_stats(6, 0, false);
        assert_eq!(s.count, 1 << 12);
        assert_eq!(s.err_count, 0);
        assert_eq!(s.max_abs_ed, 0);
    }

    #[test]
    fn t0_fix_is_irrelevant() {
        // The zero-bit LSP adder never raises a carry, so fix-to-1 cannot
        // trigger at t=0 — the premise behind `EvalJob::key`'s fix
        // canonicalization for the sweep result cache.
        assert_eq!(exhaustive_stats(6, 0, false), exhaustive_stats(6, 0, true));
    }

    #[test]
    fn chunking_invariant_worker_count() {
        // The fold must be exact regardless of how the space is chunked.
        let w1 = exhaustive_stats_workers(6, 3, true, 1);
        let w4 = exhaustive_stats_workers(6, 3, true, 4);
        let w13 = exhaustive_stats_workers(6, 3, true, 13);
        assert!(w1.approx_eq(&w4));
        assert!(w1.approx_eq(&w13));
    }

    #[test]
    fn matches_naive_double_loop() {
        let n = 5;
        let t = 2;
        let mut naive = ErrorStats::new(n);
        for a in 0..(1u64 << n) {
            for b in 0..(1u64 << n) {
                naive.record(a * b, approx_seq_mul(a, b, n, t, true));
            }
        }
        assert!(exhaustive_stats(n, t, true).approx_eq(&naive));
    }

    #[test]
    fn dyn_multiplier_agrees_with_specialized() {
        let m = SegmentedSeqMul::new(6, 3, false);
        let via_dyn = exhaustive_stats_mul(&m, 2);
        let via_fast = exhaustive_stats(6, 3, false);
        assert!(via_dyn.approx_eq(&via_fast));
    }

    #[test]
    fn batch_multiplier_entry_point_agrees() {
        let m = SegmentedSeqMul::new(6, 2, true);
        let via_batch = exhaustive_stats_batch(&m, 3);
        let via_fast = exhaustive_stats(6, 2, true);
        assert!(via_batch.approx_eq(&via_fast));
    }

    #[test]
    fn trunc_k0_zero_error_exhaustive() {
        let s = exhaustive_stats_mul(&TruncatedMul { n: 6, k: 0 }, 2);
        assert_eq!(s.err_count, 0);
    }

    #[test]
    fn paper_mae_shape_no_fix() {
        // Measured exhaustive MAE without fix-to-1 is exactly 2^{n+t-1}
        // (the dropped final LSP carry) — the paper's Eq. 11 claims
        // 2^{n+t-1} - 2^{t+1}; see EXPERIMENTS.md E3 for the comparison.
        for (n, t) in [(6u32, 2u32), (6, 3), (8, 4)] {
            let s = exhaustive_stats(n, t, false);
            assert_eq!(s.max_abs_ed, 1u64 << (n + t - 1), "n={n} t={t}");
        }
    }
}
