//! The paper's error metrics (§III-B) and the four evaluation strategies:
//!
//! * [`metrics`]     — streaming accumulator + derived metric set
//!   (BER per bit, ER, ED, MAE, MED, NMED, MRED), mergeable across chunks
//!   and loadable from the PJRT stats vector.
//! * [`stream`]      — the batched streaming engine: a
//!   [`stream::BatchAccumulator`] drives a batched multiplier kernel over
//!   L1-sized operand blocks and folds exact-vs-approximate products into
//!   a mergeable [`ErrorStats`].
//! * [`exhaustive`]  — exact evaluation over all 2^(2n) input pairs
//!   (chunked across workers, batched within each chunk).
//! * [`montecarlo`]  — sampled evaluation (the paper uses 2^32 patterns;
//!   sample count is configurable here) with uniform or weighted operand
//!   distributions, batched per chunk.
//! * [`closed_form`] — Eq. (11) MAE closed form reconciled with the
//!   measured form (exact overshoot WCE vs two-sided MAE), the fix-to-1
//!   residue identity and its tight envelope, and latency/adder-count
//!   formulas from §III/§IV.
//! * [`probprop`]    — the §V-B polynomial-time probability-propagation
//!   estimator for ER (the remedy to Theorem 1/2's #P-completeness).
//! * [`analytic`]    — the per-family analytic model registry
//!   ([`AnalyticStats`]): simulation-free ER/MED/NMED/MRED/WCE for every
//!   registry design, serving the sweep's `--analytic` fast path.
//! * [`fault`]       — the typed [`SegmulError`] taxonomy the public
//!   [`crate::api`] facade reports (defined here so the layers below the
//!   facade can construct it without depending upward).

pub mod analytic;
pub mod closed_form;
pub mod exhaustive;
pub mod fault;
pub mod metrics;
pub mod montecarlo;
pub mod probprop;
pub mod stream;

pub use analytic::{analytic_stats, AnalyticStats};
pub use exhaustive::exhaustive_stats;
pub use fault::SegmulError;
pub use metrics::{ErrorMetrics, ErrorStats};
pub use montecarlo::{mc_stats, InputDist, McConfig};
pub use stream::BatchAccumulator;
