//! Closed-form error expressions (§IV-B, Eq. 11) and resource-count
//! formulas (§III).
//!
//! The paper derives `MAE = 2^{n+t-1} - 2^{t+1}` (Eq. 11). Our exhaustive
//! evaluation of the paper's own Boolean recurrences (see
//! `exhaustive::tests::paper_mae_shape_no_fix` and EXPERIMENTS.md E3)
//! measures `MAE = 2^{n+t-1}` exactly when fix-to-1 is disabled — the
//! dropped final LSP carry-out (weight `2^t` in the final accumulation
//! `S^{n-1}`, i.e. product weight `2^{t+n-1}`) is achievable on its own,
//! without the `-2^{t+1}` LSB rebate the paper subtracts. Both forms are
//! provided; the benches compare them against measurement.

/// Eq. (11) as printed in the paper: `2^{n+t-1} - 2^{t+1}`.
pub fn mae_eq11(n: u32, t: u32) -> u64 {
    assert!(t >= 1 && t < n && n + t - 1 < 64);
    (1u64 << (n + t - 1)) - (1u64 << (t + 1))
}

/// Measured closed form without fix-to-1: the dropped final carry
/// dominates, `MAE = 2^{n+t-1}` (exhaustively verified for n ≤ 12).
pub fn mae_measured_nofix(n: u32, t: u32) -> u64 {
    assert!(t >= 1 && t < n && n + t - 1 < 64);
    1u64 << (n + t - 1)
}

/// Upper bound on MAE with fix-to-1 enabled: the fix writes `2^{n+t}-1`
/// into the low bits, so `|ED| < 2^{n+t}`.
pub fn mae_fix_upper_bound(n: u32, t: u32) -> u64 {
    assert!(t >= 1 && t < n && n + t < 64);
    (1u64 << (n + t)) - 1
}

/// §III: adders required by the combinatorial tree multiplier — `n - 1`,
/// scaling linearly with the bit-width (the motivation for sequential).
pub fn combinational_adder_count(n: u32) -> u32 {
    assert!(n.is_power_of_two());
    n - 1
}

/// §III: the sequential multiplier needs a single n-bit adder and performs
/// `n` accumulation cycles.
pub fn sequential_cycles(n: u32) -> u32 {
    n
}

/// Carry-chain length of the accurate sequential multiplier's adder.
pub fn accurate_chain_bits(n: u32) -> u32 {
    n
}

/// Carry-chain length after segmentation: `max(t, n-t)` — the paper's
/// `max{lat(MSP), lat(LSP)}` latency argument (§IV-A).
pub fn segmented_chain_bits(n: u32, t: u32) -> u32 {
    assert!(t < n);
    t.max(n - t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::exhaustive::exhaustive_stats;

    #[test]
    fn eq11_reference_values() {
        assert_eq!(mae_eq11(4, 2), 24);
        assert_eq!(mae_eq11(8, 4), 2016);
        assert_eq!(mae_eq11(16, 8), (1 << 23) - (1 << 9));
    }

    #[test]
    fn measured_form_matches_exhaustive_nofix() {
        for n in 4..=10u32 {
            for t in 1..=n / 2 {
                let measured = exhaustive_stats(n, t, false).max_abs_ed;
                assert_eq!(measured, mae_measured_nofix(n, t), "n={n} t={t}");
            }
        }
    }

    #[test]
    fn eq11_understates_measurement_by_lsb_rebate() {
        for n in 4..=10u32 {
            for t in 1..=n / 2 {
                assert_eq!(
                    mae_measured_nofix(n, t) - mae_eq11(n, t),
                    1u64 << (t + 1),
                    "n={n} t={t}"
                );
            }
        }
    }

    #[test]
    fn fix_bound_holds_exhaustively() {
        for n in 4..=9u32 {
            for t in 1..=n / 2 {
                let measured = exhaustive_stats(n, t, true).max_abs_ed;
                assert!(measured <= mae_fix_upper_bound(n, t), "n={n} t={t}");
            }
        }
    }

    #[test]
    fn chain_shortening() {
        assert_eq!(segmented_chain_bits(8, 4), 4);
        assert_eq!(segmented_chain_bits(8, 2), 6);
        assert_eq!(accurate_chain_bits(8), 8);
        // t = n/2 halves the carry chain — the paper's latency lever.
        for n in [8u32, 16, 32, 64] {
            assert_eq!(segmented_chain_bits(n, n / 2), n / 2);
        }
    }

    #[test]
    fn adder_count_formula() {
        // Σ_{i=1}^{log2 n} n/2^i = n - 1 (§III)
        for n in [4u32, 8, 16, 32, 64, 128, 256] {
            let sum: u32 = (1..=n.ilog2()).map(|i| n >> i).sum();
            assert_eq!(sum, n - 1);
            assert_eq!(combinational_adder_count(n), n - 1);
        }
    }
}
