//! Closed-form error expressions (§IV-B, Eq. 11) and resource-count
//! formulas (§III) — reconciled against exhaustive measurement.
//!
//! The paper prints `MAE = 2^{n+t-1} - 2^{t+1}` (Eq. 11) while exhaustive
//! evaluation of the paper's own Boolean recurrences measures
//! `MAE = 2^{n+t-1}` without fix-to-1. The two forms are not in conflict:
//! they answer different questions about the signed error distance
//! `ED = p - p̂`. Writing `c_j` for the LSP carry-out of cycle `j`, the
//! no-fix error decomposes exactly as
//!
//! ```text
//! ED = c_{n-1}·2^{n+t-1} - Σ_{j=1}^{n-2} c_j·2^{t+j}
//! ```
//!
//! so the worst *undershoot* (p̂ < p) is the dropped final carry alone,
//! `+2^{n+t-1}`, while the worst *overshoot* (p̂ > p) is every deferred
//! carry at once, `Σ_{j=1}^{n-2} 2^{t+j} = 2^{n+t-1} - 2^{t+1}` — exactly
//! Eq. (11). Both extremes are achievable (asserted exhaustively below),
//! so Eq. (11) is the exact one-sided overshoot WCE and `2^{n+t-1}` is the
//! exact two-sided MAE.
//!
//! With fix-to-1 enabled the fix overwrites the low `n+t` product bits
//! with ones whenever the final FF carry is set. Substituting
//! `p̂_fix = (p̂ - p̂ mod M) + M - 1` with `M = 2^{n+t}` into the
//! decomposition collapses the error to a pure residue form
//! (`R = (a·b) mod M`, `Δ = ED_nofix`):
//!
//! ```text
//! ED_fix = R + 1 - M·[R ≥ Δ]
//! ```
//!
//! The worst case sits on the `R ≥ Δ` branch at the smallest *achievable*
//! triggered residue: `MAE_fix = M - 1 - R_min(n, t)`. `R ≥ Δ ≥ 2^{t+1}`
//! on that branch, which yields the tight envelope
//! `MAE_fix ≤ 2^{n+t} - 2^{t+1} - 1` — replacing the loose `2^{n+t} - 1`
//! bound this module used to ship. `R_min` itself is a number-theoretic
//! quantity (which residues are reachable as triggered products) with no
//! polynomial closed form; the tests below assert the residue identity
//! and the envelope exhaustively instead of pretending otherwise.

/// Eq. (11) as printed in the paper: `2^{n+t-1} - 2^{t+1}`. Exhaustively
/// exact as the worst-case *overshoot* (`p̂ > p`), i.e. the magnitude of
/// the most negative signed error distance without fix-to-1; it is not
/// the two-sided MAE (see module docs).
pub fn mae_eq11(n: u32, t: u32) -> u64 {
    assert!(t >= 1 && t < n && n + t - 1 < 64);
    (1u64 << (n + t - 1)) - (1u64 << (t + 1))
}

/// Measured closed form without fix-to-1: the dropped final carry
/// dominates, `MAE = 2^{n+t-1}` (exhaustively verified for n ≤ 12).
pub fn mae_measured_nofix(n: u32, t: u32) -> u64 {
    assert!(t >= 1 && t < n && n + t - 1 < 64);
    1u64 << (n + t - 1)
}

/// Tight envelope on the fix-to-1 MAE derived from the residue identity
/// `ED_fix = R + 1 - M·[R ≥ Δ]`: since `R ≥ Δ ≥ 2^{t+1}` on the
/// worst-case branch, `MAE_fix ≤ 2^{n+t} - 2^{t+1} - 1`. The exact value
/// is `2^{n+t} - 1 - R_min(n, t)` with `R_min` the minimum achievable
/// triggered product residue (no polynomial closed form; asserted
/// exhaustively in tests). Replaces the loose `2^{n+t} - 1` bound.
pub fn mae_fix_envelope(n: u32, t: u32) -> u64 {
    assert!(t >= 1 && t < n && n + t < 64);
    (1u64 << (n + t)) - (1u64 << (t + 1)) - 1
}

/// The reconciled MAE closed form: value plus an exactness flag.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MaeForm {
    /// The MAE (exact) or its tight envelope (fix-to-1).
    pub value: u64,
    /// `true` when `value` is the exhaustively-verified exact MAE.
    pub exact: bool,
}

/// Single source of truth for the segmented design's MAE, consumed by
/// [`crate::error::analytic`]: exact `2^{n+t-1}` without fix-to-1, the
/// tight `2^{n+t} - 2^{t+1} - 1` envelope with it, and an exact zero for
/// the accurate configuration `t = 0`.
pub fn mae_form(n: u32, t: u32, fix: bool) -> MaeForm {
    if t == 0 {
        return MaeForm { value: 0, exact: true };
    }
    if fix {
        MaeForm { value: mae_fix_envelope(n, t), exact: false }
    } else {
        MaeForm { value: mae_measured_nofix(n, t), exact: true }
    }
}

/// §III: adders required by the combinatorial tree multiplier — `n - 1`,
/// scaling linearly with the bit-width (the motivation for sequential).
pub fn combinational_adder_count(n: u32) -> u32 {
    assert!(n.is_power_of_two());
    n - 1
}

/// §III: the sequential multiplier needs a single n-bit adder and performs
/// `n` accumulation cycles.
pub fn sequential_cycles(n: u32) -> u32 {
    n
}

/// Carry-chain length of the accurate sequential multiplier's adder.
pub fn accurate_chain_bits(n: u32) -> u32 {
    n
}

/// Carry-chain length after segmentation: `max(t, n-t)` — the paper's
/// `max{lat(MSP), lat(LSP)}` latency argument (§IV-A).
pub fn segmented_chain_bits(n: u32, t: u32) -> u32 {
    assert!(t < n);
    t.max(n - t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::exhaustive::exhaustive_stats;
    use crate::multiplier::wordlevel::approx_seq_mul;

    /// Exhaustive scan of one (n, t): returns (max |ED| no-fix,
    /// max overshoot no-fix, max |ED| fix, min triggered residue with
    /// `R ≥ Δ`).
    fn scan(n: u32, t: u32) -> (u64, u64, u64, u64) {
        let m = 1u64 << (n + t);
        let (mut mae_nofix, mut overshoot, mut mae_fix) = (0u64, 0u64, 0u64);
        let mut r_min = u64::MAX;
        for a in 0..1u64 << n {
            for b in 0..1u64 << n {
                let p = a * b;
                let ph = approx_seq_mul(a, b, n, t, false);
                let ed = p as i64 - ph as i64;
                mae_nofix = mae_nofix.max(ed.unsigned_abs());
                if ed < 0 {
                    overshoot = overshoot.max(ed.unsigned_abs());
                }
                let phf = approx_seq_mul(a, b, n, t, true);
                let edf = p as i64 - phf as i64;
                mae_fix = mae_fix.max(edf.unsigned_abs());
                if phf != ph {
                    // fix triggered: residue branch R ≥ Δ is the negative one
                    let r = p & (m - 1);
                    if edf < 0 {
                        r_min = r_min.min(r);
                    }
                }
            }
        }
        (mae_nofix, overshoot, mae_fix, r_min)
    }

    fn assert_reconciliation(n: u32, t: u32) {
        let (mae_nofix, overshoot, mae_fix, r_min) = scan(n, t);
        // Measured form: the dropped final carry is the two-sided MAE.
        assert_eq!(mae_nofix, mae_measured_nofix(n, t), "nofix n={n} t={t}");
        // Printed form (Eq. 11): exactly the worst-case overshoot.
        assert_eq!(overshoot, mae_eq11(n, t), "eq11 n={n} t={t}");
        // Fix-to-1: residue identity `MAE_fix = M - 1 - R_min` and the
        // tight envelope derived from `R ≥ Δ ≥ 2^{t+1}`.
        let m = 1u64 << (n + t);
        assert_eq!(mae_fix, m - 1 - r_min, "fix residue identity n={n} t={t}");
        assert!(mae_fix <= mae_fix_envelope(n, t), "fix envelope n={n} t={t}");
        // The envelope is tight: within 2x of the measured worst case
        // everywhere (measured ratio ≥ 0.83 on the full n ≤ 12 grid).
        assert!(mae_fix > mae_fix_envelope(n, t) / 2, "envelope slack n={n} t={t}");
    }

    #[test]
    fn eq11_reference_values() {
        assert_eq!(mae_eq11(4, 2), 24);
        assert_eq!(mae_eq11(8, 4), 2016);
        assert_eq!(mae_eq11(16, 8), (1 << 23) - (1 << 9));
    }

    #[test]
    fn reconciliation_holds_exhaustively() {
        // Both printed and measured forms, both fix modes, full t range.
        for n in 4..=9u32 {
            for t in 1..n {
                assert_reconciliation(n, t);
            }
        }
    }

    #[test]
    #[ignore = "full n<=12 grid; run via `cargo test --release -- --ignored`"]
    fn reconciliation_holds_exhaustively_n12() {
        for n in 10..=12u32 {
            for t in 1..n {
                assert_reconciliation(n, t);
            }
        }
    }

    #[test]
    fn measured_form_matches_exhaustive_nofix() {
        for n in 4..=10u32 {
            for t in 1..=n / 2 {
                let measured = exhaustive_stats(n, t, false).max_abs_ed;
                assert_eq!(measured, mae_measured_nofix(n, t), "n={n} t={t}");
            }
        }
    }

    #[test]
    fn eq11_understates_measurement_by_lsb_rebate() {
        for n in 4..=10u32 {
            for t in 1..=n / 2 {
                assert_eq!(
                    mae_measured_nofix(n, t) - mae_eq11(n, t),
                    1u64 << (t + 1),
                    "n={n} t={t}"
                );
            }
        }
    }

    #[test]
    fn fix_envelope_tighter_than_old_bound() {
        for n in 4..=16u32 {
            for t in 1..n {
                let old = (1u64 << (n + t)) - 1;
                assert!(mae_fix_envelope(n, t) < old, "n={n} t={t}");
            }
        }
    }

    #[test]
    fn mae_form_is_single_source_of_truth() {
        assert_eq!(mae_form(8, 0, false), MaeForm { value: 0, exact: true });
        assert_eq!(mae_form(8, 0, true), MaeForm { value: 0, exact: true });
        assert_eq!(
            mae_form(8, 4, false),
            MaeForm { value: mae_measured_nofix(8, 4), exact: true }
        );
        assert_eq!(
            mae_form(8, 4, true),
            MaeForm { value: mae_fix_envelope(8, 4), exact: false }
        );
        // fix measured values sit inside the envelope (spot values from
        // the exhaustive grid: n=8 t=4 → 3895, n=10 t=5 → 31887).
        assert!(3895 <= mae_form(8, 4, true).value);
        assert!(31887 <= mae_form(10, 5, true).value);
    }

    #[test]
    fn chain_shortening() {
        assert_eq!(segmented_chain_bits(8, 4), 4);
        assert_eq!(segmented_chain_bits(8, 2), 6);
        assert_eq!(accurate_chain_bits(8), 8);
        // t = n/2 halves the carry chain — the paper's latency lever.
        for n in [8u32, 16, 32, 64] {
            assert_eq!(segmented_chain_bits(n, n / 2), n / 2);
        }
    }

    #[test]
    fn adder_count_formula() {
        // Σ_{i=1}^{log2 n} n/2^i = n - 1 (§III)
        for n in [4u32, 8, 16, 32, 64, 128, 256] {
            let sum: u32 = (1..=n.ilog2()).map(|i| n >> i).sum();
            assert_eq!(sum, n - 1);
            assert_eq!(combinational_adder_count(n), n - 1);
        }
    }
}
