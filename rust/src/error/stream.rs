//! Streaming batched evaluation: multiplier kernels → [`ErrorStats`].
//!
//! [`BatchAccumulator`] is the single evaluation engine behind the
//! exhaustive and Monte-Carlo paths: it drives a [`BatchMultiplier`] over
//! operand blocks of [`BLOCK`] pairs (sized so the four scratch buffers
//! stay L1/L2-resident), computes the exact products alongside, and folds
//! both into a streaming [`ErrorStats`]. Partial accumulators from
//! different chunks of the input space merge exactly (integer fields are
//! bit-exact under any chunking; see `tests/kernel_differential.rs`), so
//! the same engine runs sequentially, across `util::threadpool` workers,
//! and inside the coordinator's backend batches.

use std::collections::BTreeMap;

use crate::error::metrics::ErrorStats;
use crate::multiplier::batch::{exact_mul_batch, BatchMultiplier};

/// Operand block size for the streaming engine. Four u64 buffers of this
/// length are 128 KiB total — L2-resident on every target we run on,
/// while long enough to amortize per-block dispatch to noise.
pub const BLOCK: usize = 4096;

/// Streaming batched evaluator for one multiplier configuration.
pub struct BatchAccumulator<'m> {
    m: &'m dyn BatchMultiplier,
    /// Scratch operand blocks (used by the index-range driver).
    a: Vec<u64>,
    b: Vec<u64>,
    /// Scratch product blocks.
    prod: Vec<u64>,
    phat: Vec<u64>,
    stats: ErrorStats,
}

/// Evaluate one block: batched approximate + exact products, then a
/// batched statistics record. Free function so callers can pass disjoint
/// borrows of an accumulator's fields.
fn eval_block(
    m: &dyn BatchMultiplier,
    a: &[u64],
    b: &[u64],
    prod: &mut [u64],
    phat: &mut [u64],
    stats: &mut ErrorStats,
) {
    m.mul_batch(a, b, phat);
    exact_mul_batch(a, b, prod);
    stats.record_batch(prod, phat);
}

impl<'m> BatchAccumulator<'m> {
    /// An accumulator driving `m` over L1-sized blocks.
    pub fn new(m: &'m dyn BatchMultiplier) -> Self {
        let n = m.n();
        Self {
            m,
            a: vec![0; BLOCK],
            b: vec![0; BLOCK],
            prod: vec![0; BLOCK],
            phat: vec![0; BLOCK],
            stats: ErrorStats::new(n),
        }
    }

    /// Evaluate explicit operand pairs (any length; blocked internally).
    pub fn eval_pairs(&mut self, a: &[u64], b: &[u64]) {
        assert_eq!(a.len(), b.len(), "operand slices must have equal length");
        for (ca, cb) in a.chunks(BLOCK).zip(b.chunks(BLOCK)) {
            let len = ca.len();
            eval_block(self.m, ca, cb, &mut self.prod[..len], &mut self.phat[..len], &mut self.stats);
        }
    }

    /// Evaluate the exhaustive index range `[start, end)` of the `2^(2n)`
    /// input space, where index `i` encodes `a = i & (2^n - 1)`,
    /// `b = i >> n` (the same decomposition `error::exhaustive` and the
    /// coordinator driver use).
    pub fn eval_index_range(&mut self, start: u64, end: u64) {
        let n = self.stats.n;
        let mask = (1u64 << n) - 1;
        let mut idx = start;
        while idx < end {
            let len = ((end - idx) as usize).min(BLOCK);
            for (k, (ai, bi)) in self.a[..len].iter_mut().zip(&mut self.b[..len]).enumerate() {
                let i = idx + k as u64;
                *ai = i & mask;
                *bi = i >> n;
            }
            eval_block(
                self.m,
                &self.a[..len],
                &self.b[..len],
                &mut self.prod[..len],
                &mut self.phat[..len],
                &mut self.stats,
            );
            idx += len as u64;
        }
    }

    /// The statistics accumulated so far.
    pub fn stats(&self) -> &ErrorStats {
        &self.stats
    }

    /// Consume the accumulator, yielding its statistics.
    pub fn finish(self) -> ErrorStats {
        self.stats
    }
}

/// Order-restoring reducer for chunked parallel evaluation.
///
/// `ErrorStats::merge` is exact on the integer fields under any merge
/// order, but `sum_red` is an f64 whose accumulation order matters at the
/// last bit. A sequential chunk loop merges partials in chunk-id order;
/// parallel workers complete chunks in a nondeterministic order. This
/// reducer buffers out-of-order partials and applies every merge in
/// chunk-id order, so the folded result is **bit-identical** — `sum_red`
/// included — to the sequential loop, for any worker count and any
/// completion schedule. Buffering grows with the schedule's
/// out-of-orderness: typically ~workers partials when chunks complete at
/// similar rates, but a stalled low-id chunk lets it reach O(pending
/// chunks) in the worst case — callers sizing giant chunk spaces should
/// account for that.
#[derive(Debug)]
pub struct OrderedMerger {
    total: ErrorStats,
    /// Next chunk id the in-order prefix is waiting for.
    next: u64,
    /// Out-of-order partials, keyed by chunk id.
    pending: BTreeMap<u64, ErrorStats>,
}

impl OrderedMerger {
    /// A merger for `n`-bit stats starting at chunk 0.
    pub fn new(n: u32) -> Self {
        Self { total: ErrorStats::new(n), next: 0, pending: BTreeMap::new() }
    }

    /// Offer the partial for `chunk_id`. Merges it (and any unblocked
    /// pending successors) as soon as the in-order prefix reaches it.
    /// Each chunk id must be offered exactly once.
    pub fn push(&mut self, chunk_id: u64, stats: ErrorStats) {
        self.offer(chunk_id, stats);
        while self.step() {}
    }

    /// Buffer the partial for `chunk_id` without merging. Callers that
    /// must observe the prefix after every single merge (e.g. adaptive
    /// convergence checks, which may stop mid-drain) pair this with
    /// [`Self::step`]; everyone else uses [`Self::push`].
    pub fn offer(&mut self, chunk_id: u64, stats: ErrorStats) {
        assert!(
            chunk_id >= self.next && !self.pending.contains_key(&chunk_id),
            "chunk {chunk_id} offered twice"
        );
        self.pending.insert(chunk_id, stats);
    }

    /// Merge at most one pending chunk into the in-order prefix. Returns
    /// `true` if a chunk was merged (inspect [`Self::prefix`] after).
    pub fn step(&mut self) -> bool {
        match self.pending.remove(&self.next) {
            Some(s) => {
                self.total.merge(&s);
                self.next += 1;
                true
            }
            None => false,
        }
    }

    /// Number of chunks merged into the in-order prefix so far.
    pub fn merged(&self) -> u64 {
        self.next
    }

    /// The stats of the contiguous in-order prefix merged so far (what a
    /// sequential loop would hold after `merged()` chunks).
    pub fn prefix(&self) -> &ErrorStats {
        &self.total
    }

    /// Finish, returning the folded stats. Panics if gaps remain — every
    /// chunk id in `0..merged()` must have been pushed.
    pub fn finish(self) -> ErrorStats {
        assert!(self.pending.is_empty(), "ordered merge finished with gaps");
        self.total
    }

    /// Consume, returning the in-order prefix and discarding any pending
    /// out-of-order partials (an adaptive job that converged mid-stream
    /// legitimately abandons chunks beyond its stopping point).
    pub fn into_prefix(self) -> ErrorStats {
        self.total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multiplier::wordlevel::approx_seq_mul;
    use crate::multiplier::SegmentedSeqMul;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn index_range_matches_per_pair_record() {
        let (n, t, fix) = (6u32, 3u32, true);
        let m = SegmentedSeqMul::new(n, t, fix);
        let mut acc = BatchAccumulator::new(&m);
        acc.eval_index_range(0, 1 << (2 * n));
        let mut want = ErrorStats::new(n);
        for idx in 0..(1u64 << (2 * n)) {
            let (a, b) = (idx & ((1 << n) - 1), idx >> n);
            want.record(a * b, approx_seq_mul(a, b, n, t, fix));
        }
        // Same evaluation order => identical accumulation, floats included.
        assert_eq!(acc.finish(), want);
    }

    #[test]
    fn pairs_blocking_is_invisible() {
        // One call over > BLOCK pairs == many calls over ragged pieces.
        let (n, t, fix) = (8u32, 4u32, false);
        let m = SegmentedSeqMul::new(n, t, fix);
        let mut rng = Xoshiro256::seed_from_u64(3);
        let len = BLOCK + 1234;
        let a: Vec<u64> = (0..len).map(|_| rng.next_bits(n)).collect();
        let b: Vec<u64> = (0..len).map(|_| rng.next_bits(n)).collect();
        let mut one = BatchAccumulator::new(&m);
        one.eval_pairs(&a, &b);
        let mut pieces = BatchAccumulator::new(&m);
        let cut1 = 7;
        let cut2 = BLOCK + 13;
        pieces.eval_pairs(&a[..cut1], &b[..cut1]);
        pieces.eval_pairs(&a[cut1..cut2], &b[cut1..cut2]);
        pieces.eval_pairs(&a[cut2..], &b[cut2..]);
        assert_eq!(one.finish(), pieces.finish());
    }

    #[test]
    fn split_index_ranges_merge_exactly() {
        let (n, t) = (5u32, 2u32);
        let m = SegmentedSeqMul::new(n, t, true);
        let total = 1u64 << (2 * n);
        let mut whole = BatchAccumulator::new(&m);
        whole.eval_index_range(0, total);
        let mut left = BatchAccumulator::new(&m);
        left.eval_index_range(0, total / 3);
        let mut right = BatchAccumulator::new(&m);
        right.eval_index_range(total / 3, total);
        let mut merged = left.finish();
        merged.merge(&right.finish());
        assert!(merged.approx_eq(whole.stats()));
    }

    /// Per-chunk stats over distinct slices of a random workload.
    fn chunk_stats(n_chunks: usize) -> Vec<ErrorStats> {
        let m = SegmentedSeqMul::new(8, 4, true);
        let mut rng = Xoshiro256::seed_from_u64(0xC0);
        (0..n_chunks)
            .map(|_| {
                let a: Vec<u64> = (0..300).map(|_| rng.next_bits(8)).collect();
                let b: Vec<u64> = (0..300).map(|_| rng.next_bits(8)).collect();
                let mut acc = BatchAccumulator::new(&m);
                acc.eval_pairs(&a, &b);
                acc.finish()
            })
            .collect()
    }

    #[test]
    fn ordered_merger_bit_identical_under_any_arrival_order() {
        let parts = chunk_stats(7);
        // Sequential reference: merge in chunk order.
        let mut want = ErrorStats::new(8);
        for p in &parts {
            want.merge(p);
        }
        for arrival in [
            vec![0u64, 1, 2, 3, 4, 5, 6],
            vec![6, 5, 4, 3, 2, 1, 0],
            vec![3, 0, 6, 1, 5, 2, 4],
        ] {
            let mut om = OrderedMerger::new(8);
            for &id in &arrival {
                om.push(id, parts[id as usize].clone());
            }
            assert_eq!(om.merged(), 7);
            // Full bitwise equality: the f64 sum_red must match exactly.
            assert_eq!(om.finish(), want);
        }
    }

    #[test]
    fn ordered_merger_prefix_tracks_in_order_merges() {
        let parts = chunk_stats(3);
        let mut om = OrderedMerger::new(8);
        om.push(2, parts[2].clone());
        assert_eq!(om.merged(), 0); // chunk 0 still missing
        assert_eq!(om.prefix().count, 0);
        om.push(0, parts[0].clone());
        assert_eq!(om.merged(), 1); // 0 merged; 2 still blocked on 1
        om.push(1, parts[1].clone());
        assert_eq!(om.merged(), 3);
    }

    #[test]
    #[should_panic(expected = "gaps")]
    fn ordered_merger_rejects_gaps() {
        let parts = chunk_stats(2);
        let mut om = OrderedMerger::new(8);
        om.push(1, parts[1].clone());
        let _ = om.finish();
    }

    #[test]
    #[should_panic(expected = "twice")]
    fn ordered_merger_rejects_duplicates() {
        let parts = chunk_stats(1);
        let mut om = OrderedMerger::new(8);
        om.push(0, parts[0].clone());
        om.push(0, parts[0].clone());
    }
}
