//! Streaming error-statistics accumulator and the derived metric set.
//!
//! `ErrorStats` is the single aggregation currency of the whole system:
//! the Rust word-level evaluators fill it exactly (integer sums), the PJRT
//! stats modules fill it from the on-device f64 vector, chunked/parallel
//! evaluation merges partials (merge is associative and commutative —
//! property-tested), and `ErrorMetrics` derives the paper's §III-B metrics.

use crate::error::fault::SegmulError;
use crate::multiplier::wordlevel::error_distance;

/// Raw accumulated statistics for one (design, workload) evaluation.
#[derive(Clone, Debug, PartialEq)]
pub struct ErrorStats {
    /// Operand bit-width (determines the 2n bit-flip counters).
    pub n: u32,
    /// Evaluated input pairs.
    pub count: u64,
    /// Pairs with `p̂ != p` (numerator of ER, Eq. 3).
    pub err_count: u64,
    /// Σ ED, signed and exact (for MED, Eq. 6).
    pub sum_ed: i128,
    /// Σ |ED| (for the absolute-ED MED variant used by NMED, cf. [3]).
    pub sum_abs_ed: u128,
    /// max |ED| (MAE, Eq. 5).
    pub max_abs_ed: u64,
    /// Σ |ED| / max(1, p) (MRED, Eq. 8).
    pub sum_red: f64,
    /// Per-output-bit flip counts (BER numerators, Eq. 2); length 2n.
    pub bitflips: Vec<u64>,
    /// True when filled from f64 sums (PJRT): sums beyond 2^53 may round.
    pub approx_sums: bool,
}

impl ErrorStats {
    /// Empty accumulator for `n`-bit operands.
    pub fn new(n: u32) -> Self {
        assert!(n >= 1 && n <= 32);
        Self {
            n,
            count: 0,
            err_count: 0,
            sum_ed: 0,
            sum_abs_ed: 0,
            max_abs_ed: 0,
            sum_red: 0.0,
            bitflips: vec![0; 2 * n as usize],
            approx_sums: false,
        }
    }

    /// Record one (exact, approximate) product pair.
    #[inline]
    pub fn record(&mut self, p: u64, phat: u64) {
        self.count += 1;
        if p != phat {
            self.record_mismatch(p, phat);
        }
    }

    /// Record a batch of (exact, approximate) product pairs — the batched
    /// engine's entry point. Equivalent to calling [`Self::record`] per
    /// pair (bit-exact, same accumulation order), with the per-pair count
    /// bump hoisted out of the loop.
    pub fn record_batch(&mut self, exact: &[u64], approx: &[u64]) {
        assert_eq!(exact.len(), approx.len(), "product slices must have equal length");
        self.count += exact.len() as u64;
        for (&p, &phat) in exact.iter().zip(approx) {
            if p != phat {
                self.record_mismatch(p, phat);
            }
        }
    }

    /// The error branch of [`Self::record`] (`p != phat` established).
    #[inline]
    fn record_mismatch(&mut self, p: u64, phat: u64) {
        self.err_count += 1;
        let ed = error_distance(p, phat);
        self.sum_ed += ed as i128;
        let abs = ed.unsigned_abs();
        self.sum_abs_ed += abs as u128;
        if abs > self.max_abs_ed {
            self.max_abs_ed = abs;
        }
        self.sum_red += abs as f64 / p.max(1) as f64;
        let mut flips = p ^ phat;
        while flips != 0 {
            let bit = flips.trailing_zeros() as usize;
            self.bitflips[bit] += 1;
            flips &= flips - 1;
        }
    }

    /// Merge another partial accumulation (associative, commutative).
    pub fn merge(&mut self, other: &ErrorStats) {
        assert_eq!(self.n, other.n, "cannot merge stats of different bit-widths");
        self.count += other.count;
        self.err_count += other.err_count;
        self.sum_ed += other.sum_ed;
        self.sum_abs_ed += other.sum_abs_ed;
        self.max_abs_ed = self.max_abs_ed.max(other.max_abs_ed);
        self.sum_red += other.sum_red;
        for (s, o) in self.bitflips.iter_mut().zip(&other.bitflips) {
            *s += o;
        }
        self.approx_sums |= other.approx_sums;
    }

    /// Build from the PJRT stats vector (layout in python/compile/model.py:
    /// `[count, err, sum_ed, sum_abs, max_abs, sum_red, flips...]`).
    pub fn from_f64_vec(n: u32, v: &[f64]) -> anyhow::Result<Self> {
        let expect = 6 + 2 * n as usize;
        anyhow::ensure!(v.len() == expect, "stats vector len {} != {expect}", v.len());
        let mut s = Self::new(n);
        s.count = v[0] as u64;
        s.err_count = v[1] as u64;
        s.sum_ed = v[2] as i128;
        s.sum_abs_ed = v[3] as u128;
        s.max_abs_ed = v[4] as u64;
        s.sum_red = v[5];
        for (i, f) in s.bitflips.iter_mut().enumerate() {
            *f = v[6 + i] as u64;
        }
        s.approx_sums = true;
        Ok(s)
    }

    /// Equality up to f64 accumulation-order noise in `sum_red`: all
    /// integer fields must match exactly. Chunked/parallel evaluation can
    /// legally reorder the `sum_red` float additions, so tests comparing
    /// different decompositions of the same input space use this.
    pub fn approx_eq(&self, other: &ErrorStats) -> bool {
        self.n == other.n
            && self.count == other.count
            && self.err_count == other.err_count
            && self.sum_ed == other.sum_ed
            && self.sum_abs_ed == other.sum_abs_ed
            && self.max_abs_ed == other.max_abs_ed
            && self.bitflips == other.bitflips
            && (self.sum_red - other.sum_red).abs()
                <= 1e-9 * self.sum_red.abs().max(other.sum_red.abs()).max(1.0)
    }

    /// Derive the paper's metrics.
    ///
    /// An empty accumulator has no defined metrics — every mean divides
    /// by `count` — so rather than silently poisoning merged sweep rows
    /// with NaN/∞, deriving from zero samples reports a typed
    /// [`SegmulError::Stats`].
    pub fn metrics(&self) -> Result<ErrorMetrics, SegmulError> {
        if self.count == 0 {
            return Err(SegmulError::stats(format!(
                "cannot derive metrics from an empty accumulator (n={})",
                self.n
            )));
        }
        let cnt = self.count as f64;
        let max_p = {
            let m = (1u64 << self.n) - 1;
            (m as f64) * (m as f64)
        };
        Ok(ErrorMetrics {
            n: self.n,
            samples: self.count,
            er: self.err_count as f64 / cnt,
            med_signed: self.sum_ed as f64 / cnt,
            med_abs: self.sum_abs_ed as f64 / cnt,
            mae: self.max_abs_ed,
            nmed: (self.sum_abs_ed as f64 / cnt) / max_p,
            mred: self.sum_red / cnt,
            ber: self.bitflips.iter().map(|&f| f as f64 / cnt).collect(),
        })
    }
}

/// The derived metric set of §III-B.
#[derive(Clone, Debug)]
pub struct ErrorMetrics {
    /// Operand bit-width.
    pub n: u32,
    /// Input pairs the metrics were computed over.
    pub samples: u64,
    /// Arithmetic error rate (Eq. 3).
    pub er: f64,
    /// Mean error distance, signed (Eq. 6).
    pub med_signed: f64,
    /// Mean |ED| (the variant used for NMED comparisons, cf. [3]).
    pub med_abs: f64,
    /// Maximum absolute error (Eq. 5).
    pub mae: u64,
    /// Normalized MED (Eq. 7): mean |ED| / (2^n - 1)^2.
    pub nmed: f64,
    /// Mean relative error distance (Eq. 8).
    pub mred: f64,
    /// Bit error rate per output bit (Eq. 2); length 2n.
    pub ber: Vec<f64>,
}

impl ErrorMetrics {
    /// Mean BER across all 2n output bits. Analytic metric sets carry no
    /// per-bit flip model (`ber` is empty); that yields `NaN` rather than
    /// a silent division panic — report layers render it as `-`.
    pub fn mean_ber(&self) -> f64 {
        if self.ber.is_empty() {
            return f64::NAN;
        }
        self.ber.iter().sum::<f64>() / self.ber.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Cases;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn record_exact_pair_only_counts() {
        let mut s = ErrorStats::new(8);
        s.record(100, 100);
        assert_eq!(s.count, 1);
        assert_eq!(s.err_count, 0);
        assert_eq!(s.metrics().unwrap().er, 0.0);
        assert_eq!(s.metrics().unwrap().mae, 0);
    }

    #[test]
    fn empty_accumulator_reports_typed_stats_error() {
        let s = ErrorStats::new(8);
        let err = s.metrics().unwrap_err();
        assert_eq!(err.kind(), "stats");
        assert!(err.to_string().contains("empty accumulator"), "{err}");
    }

    #[test]
    fn single_record_metrics_are_finite_and_exact() {
        let mut s = ErrorStats::new(4);
        s.record(200, 190); // ED = +10
        let m = s.metrics().unwrap();
        assert_eq!(m.samples, 1);
        assert_eq!(m.er, 1.0);
        assert_eq!(m.med_signed, 10.0);
        assert_eq!(m.med_abs, 10.0);
        assert_eq!(m.mae, 10);
        assert!((m.nmed - 10.0 / 225.0).abs() < 1e-12);
        assert!((m.mred - 0.05).abs() < 1e-12);
        assert!(m.mean_ber().is_finite());
        // And a single exact record: all-zero metrics, no NaN anywhere.
        let mut z = ErrorStats::new(4);
        z.record(9, 9);
        let m = z.metrics().unwrap();
        assert_eq!((m.er, m.med_abs, m.mae, m.mred), (0.0, 0.0, 0, 0.0));
        assert_eq!(m.mean_ber(), 0.0);
    }

    #[test]
    fn mean_ber_nan_on_empty_bit_model() {
        let mut s = ErrorStats::new(4);
        s.record(1, 2);
        let mut m = s.metrics().unwrap();
        m.ber.clear(); // analytic metric sets carry no per-bit model
        assert!(m.mean_ber().is_nan());
    }

    #[test]
    fn record_signed_directions() {
        let mut s = ErrorStats::new(8);
        s.record(100, 90); // ED = +10
        s.record(50, 60); // ED = -10
        assert_eq!(s.sum_ed, 0);
        assert_eq!(s.sum_abs_ed, 20);
        assert_eq!(s.max_abs_ed, 10);
        let m = s.metrics().unwrap();
        assert_eq!(m.med_signed, 0.0);
        assert_eq!(m.med_abs, 10.0);
        assert_eq!(m.er, 1.0);
    }

    #[test]
    fn bitflips_positions() {
        let mut s = ErrorStats::new(4);
        s.record(0b1010, 0b0110); // bits 2 and 3 flipped
        assert_eq!(s.bitflips[2], 1);
        assert_eq!(s.bitflips[3], 1);
        assert_eq!(s.bitflips.iter().sum::<u64>(), 2);
    }

    #[test]
    fn mred_uses_exact_denominator() {
        let mut s = ErrorStats::new(8);
        s.record(200, 100);
        assert!((s.metrics().unwrap().mred - 0.5).abs() < 1e-12);
        // p = 0 clamps denominator to 1
        let mut z = ErrorStats::new(8);
        z.record(0, 3);
        assert!((z.metrics().unwrap().mred - 3.0).abs() < 1e-12);
    }

    #[test]
    fn record_batch_equals_per_pair() {
        let mut rng = Xoshiro256::seed_from_u64(0xBB);
        let exact: Vec<u64> = (0..777).map(|_| rng.next_bits(16)).collect();
        let approx: Vec<u64> =
            exact.iter().map(|&p| if p % 3 == 0 { p } else { p ^ 5 }).collect();
        let mut batched = ErrorStats::new(8);
        batched.record_batch(&exact, &approx);
        let mut scalar = ErrorStats::new(8);
        for (&p, &ph) in exact.iter().zip(&approx) {
            scalar.record(p, ph);
        }
        // Same accumulation order => bit-identical, floats included.
        assert_eq!(batched, scalar);
    }

    #[test]
    #[should_panic(expected = "equal length")]
    fn record_batch_rejects_mismatched_lengths() {
        let mut s = ErrorStats::new(8);
        s.record_batch(&[1, 2], &[1]);
    }

    #[test]
    fn prop_merge_equals_sequential() {
        Cases::new(0xE5, 100).run(|rng, _| {
            let n = 8;
            let mut all = ErrorStats::new(n);
            let mut left = ErrorStats::new(n);
            let mut right = ErrorStats::new(n);
            for k in 0..200 {
                let p = rng.next_bits(16);
                let phat = if rng.next_bits(2) == 0 { p } else { rng.next_bits(16) };
                all.record(p, phat);
                if k % 2 == 0 {
                    left.record(p, phat)
                } else {
                    right.record(p, phat)
                }
            }
            let mut merged = left.clone();
            merged.merge(&right);
            assert!(merged.approx_eq(&all));
            // commutativity (bitwise: same addition order per side)
            let mut swapped = right.clone();
            swapped.merge(&left);
            assert!(swapped.approx_eq(&all));
        });
    }

    #[test]
    fn prop_merge_associative() {
        let mk = |seed: u64| {
            let mut rng = Xoshiro256::seed_from_u64(seed);
            let mut s = ErrorStats::new(8);
            for _ in 0..100 {
                s.record(rng.next_bits(16), rng.next_bits(16));
            }
            s
        };
        let (a, b, c) = (mk(1), mk(2), mk(3));
        let mut ab_c = a.clone();
        ab_c.merge(&b);
        ab_c.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut a_bc = a.clone();
        a_bc.merge(&bc);
        assert_eq!(ab_c, a_bc);
    }

    #[test]
    fn f64_roundtrip_matches_native() {
        let mut s = ErrorStats::new(4);
        let mut rng = Xoshiro256::seed_from_u64(5);
        for _ in 0..500 {
            s.record(rng.next_bits(8), rng.next_bits(8));
        }
        // Simulate the PJRT vector
        let mut v = vec![
            s.count as f64,
            s.err_count as f64,
            s.sum_ed as f64,
            s.sum_abs_ed as f64,
            s.max_abs_ed as f64,
            s.sum_red,
        ];
        v.extend(s.bitflips.iter().map(|&f| f as f64));
        let back = ErrorStats::from_f64_vec(4, &v).unwrap();
        assert_eq!(back.count, s.count);
        assert_eq!(back.err_count, s.err_count);
        assert_eq!(back.sum_ed, s.sum_ed);
        assert_eq!(back.max_abs_ed, s.max_abs_ed);
        assert_eq!(back.bitflips, s.bitflips);
        assert!(back.approx_sums);
    }

    #[test]
    fn from_f64_rejects_wrong_len() {
        assert!(ErrorStats::from_f64_vec(4, &[0.0; 10]).is_err());
    }

    #[test]
    #[should_panic(expected = "different bit-widths")]
    fn merge_rejects_mixed_n() {
        let mut a = ErrorStats::new(4);
        a.merge(&ErrorStats::new(8));
    }

    #[test]
    fn nmed_normalization() {
        let mut s = ErrorStats::new(4);
        s.record(225, 0); // max |ED| at n=4: (2^4-1)^2
        let m = s.metrics().unwrap();
        assert!((m.nmed - 1.0).abs() < 1e-12);
    }
}
