//! Analytic (simulation-free) error models per design family — the
//! registry behind the sweep's `--analytic` answer-source fast path.
//!
//! Every [`MultiplierSpec`] family maps to a model that computes the
//! paper's metric set (ER / MED / NMED / MRED / WCE) from closed forms or
//! polynomial-time propagation instead of evaluating `2^{2n}` (or
//! sampled) operand pairs:
//!
//! * **accurate** (and every spec that canonicalizes to it — segmented
//!   `t = 0`, truncation `k = 0`): exact zeros.
//! * **segmented / bitlevel / netlist** (`t ≥ 1`): the §V-B
//!   probability-propagation lattice ([`crate::error::probprop`]) yields
//!   ER and the per-cycle deferred-carry probabilities `ρ̂(Ĉ_ff)`; the
//!   signed/absolute MED follow from the exact error decomposition
//!   `ED = c_{n-1}·2^{n+t-1} - Σ_j c_j·2^{t+j}`, with the fix-to-1 branch
//!   mapped through the residue identity of
//!   [`crate::error::closed_form`]. WCE comes from the reconciled
//!   [`closed_form::mae_form`] (exact without fix, tight envelope with).
//!   These are *estimates* (`exact: false`): the lattice assumes event
//!   independence (the paper's remedy to Theorem 1/2's #P-completeness).
//! * **truncated / broken_array**: the closed forms of "Error Analysis of
//!   Approximate Array Multipliers" (arXiv:1908.01343), generalized to
//!   the row/column break-line grid: with `d_j` low columns dropped from
//!   partial-product row `j`, `ER = 1 - [2^{-n} + Σ_v 2^{-(v+1)-D(v)}]`
//!   (conditioning on the lowest set bit `v` of the multiplicand,
//!   `D(v) = #{j : d_j > v}`), `MED = Σ_dropped 2^{i+j}/4`,
//!   `WCE = Σ_dropped 2^{i+j}`, and
//!   `MRED = 4^{-n} Σ_dropped 2^{i+j} H_i H_j` where
//!   `H_i = Σ_{a≥1, bit i of a set} 1/a`. Exact for `n ≤ 16` (`H_i` by
//!   direct summation, verified ≤ 1e-9 against brute force); for larger
//!   `n` the `H_i` switch to a blocked harmonic approximation
//!   (≈ 4e-6 relative), flagged `exact: false`.
//! * **mitchell**: the log-error expressions of the Comparative Study
//!   (arXiv:1803.06587): `ER = (1 - (n+1)/2^n)^2` and
//!   `WCE = 2^{2n-4}` exactly for every `n`; MED / MRED by an
//!   `O(n·2^n)` per-mantissa-class prefix-sum reduction of the piecewise
//!   error `ED = x1·x2` (no log overflow) / `(2^{k1}-x1)(2^{k2}-x2)`
//!   (overflow), exact for `n ≤ 16`; beyond that the continuous limits
//!   `MED = ((4^n-1)/3)^2 / (12·4^n)` and `MRED → 0.038488` (both match
//!   the exact `n = 16` values to ≤ 1e-4 relative).
//! * **kulkarni**: the 2×2-block underdesign errs by `ED = 2·f(a)·f(b)`
//!   with `f(x) = Σ_i [base-4 digit i of x = 3]·4^i`, giving
//!   `ER = (1 - (3/4)^{n/2})^2`, `MED = 2(F/4)^2`, `WCE = 2F^2` with
//!   `F = (2^n-1)/3` — exact for every `n` — and `MRED = 2G^2` with
//!   `G = 2^{-n} Σ_{a≥1} f(a)/a` (exact sum `n ≤ 16`, blocked harmonic
//!   approximation above).
//!
//! The `exact` flag is the registry contract consumed by the sweep
//! layer: `--analytic auto` serves only `exact: true` answers, `require`
//! serves every modeled design (documenting that estimates replace
//! measurement). All models run in microseconds-to-milliseconds — the
//! point of the fast path is answering million-config design-space
//! queries without a single pool dispatch.

use crate::error::closed_form::mae_form;
use crate::error::metrics::ErrorMetrics;
use crate::error::probprop::propagate;
use crate::multiplier::spec::MultiplierSpec;

/// Analytic metric set for one design point.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AnalyticStats {
    /// Operand bit-width.
    pub n: u32,
    /// Arithmetic error rate (Eq. 3).
    pub er: f64,
    /// Mean signed error distance (Eq. 6).
    pub med_signed: f64,
    /// Mean |ED|.
    pub med_abs: f64,
    /// Normalized MED: mean |ED| / (2^n - 1)^2 (Eq. 7).
    pub nmed: f64,
    /// Mean relative error distance (Eq. 8).
    pub mred: f64,
    /// Worst-case (maximum absolute) error. For the segmented family
    /// with fix-to-1 this is the tight envelope of
    /// [`crate::error::closed_form::mae_fix_envelope`].
    pub wce: u64,
    /// `true` when every field is an exhaustively-verified closed form;
    /// `false` when any field is an estimate (segmented lattice,
    /// harmonic / continuous tiers above n = 16).
    pub exact: bool,
}

impl AnalyticStats {
    /// Bridge into the simulated-metric type so report layers render
    /// analytic and simulated rows identically. `samples` is the
    /// exhaustive population `2^{2n}` the model characterizes
    /// (saturating at `u64::MAX` for `n = 32`); `ber` is empty — the
    /// models carry no per-bit flip decomposition, and
    /// [`ErrorMetrics::mean_ber`] renders that as `-`.
    pub fn to_metrics(&self) -> ErrorMetrics {
        let samples = if self.n >= 32 {
            u64::MAX
        } else {
            1u64 << (2 * self.n)
        };
        ErrorMetrics {
            n: self.n,
            samples,
            er: self.er,
            med_signed: self.med_signed,
            med_abs: self.med_abs,
            mae: self.wce,
            nmed: self.nmed,
            mred: self.mred,
            ber: Vec::new(),
        }
    }
}

/// The model registry: analytic statistics for any valid registry spec,
/// dispatched on the [`MultiplierSpec::canonical`] representative (so
/// degenerate configurations inherit the exact-zero accurate model).
/// Returns `None` only for invalid specs.
pub fn analytic_stats(spec: &MultiplierSpec) -> Option<AnalyticStats> {
    spec.validate().ok()?;
    Some(match spec.canonical() {
        MultiplierSpec::Accurate { n } => exact_zero(n),
        MultiplierSpec::Segmented { n, t, fix } => segmented(n, t, fix),
        // Same product function as the word-level segmented model (the
        // oracle / netlist differential tests assert exactly that); at
        // t = 0 both compute the accurate product.
        MultiplierSpec::BitLevel { n, t, fix } | MultiplierSpec::Netlist { n, t, fix } => {
            if t == 0 {
                exact_zero(n)
            } else {
                segmented(n, t, fix)
            }
        }
        MultiplierSpec::Truncated { n, k } => array_truncation(n, 0, k),
        MultiplierSpec::BrokenArray { n, hbl, vbl } => array_truncation(n, hbl, vbl),
        MultiplierSpec::Mitchell { n } => mitchell(n),
        MultiplierSpec::Kulkarni { n } => kulkarni(n),
    })
}

/// `(2^n - 1)^2` as f64 — the NMED normalizer (matches
/// [`crate::error::metrics::ErrorStats::metrics`]).
fn max_product(n: u32) -> f64 {
    let m = ((1u64 << n) - 1) as f64;
    m * m
}

fn pow2f(e: u32) -> f64 {
    debug_assert!(e < 64);
    (1u64 << e) as f64
}

fn exact_zero(n: u32) -> AnalyticStats {
    AnalyticStats {
        n,
        er: 0.0,
        med_signed: 0.0,
        med_abs: 0.0,
        nmed: 0.0,
        mred: 0.0,
        wce: 0,
        exact: true,
    }
}

/// Segmented-family estimates (`t ≥ 1`) from the probability lattice.
///
/// Writing `ρ_j = ρ̂(Ĉ_ff)` after cycle `j`, the deferred-carry
/// expectation is `E[S] = Σ_{j=1}^{n-2} ρ_j·2^{t+j}` and the final-carry
/// (drop / fix-trigger) probability is `ρ_{n-1}`. Without fix-to-1 the
/// decomposition gives `MED_signed ≈ ρ_{n-1}·2^{n+t-1} - E[S]`; with it,
/// the residue identity spreads the triggered error uniformly over
/// `[Δ̄ - M, Δ̄]` (`M = 2^{n+t}`, `Δ̄ = 2^{n+t-1} - E[S]`). Calibrated
/// against exhaustive evaluation on the full `n ≤ 10` grid: ER relative
/// error ≤ 0.5 (tightest ≈ 0.22 at `t = n/2`), signed MED within
/// `0.04·2^{n+t-1}`, absolute MED within 35% (no fix) / 15% (fix). MRED
/// uses the order-of-magnitude reduction `MED_abs / 4^{n-1}`.
fn segmented(n: u32, t: u32, fix: bool) -> AnalyticStats {
    debug_assert!(t >= 1 && t < n);
    let lat = propagate(n, t);
    let er = lat.er_estimate();
    let scale = pow2f(n + t - 1);
    let es: f64 = (1..n.saturating_sub(1))
        .map(|j| lat.pc_ff[j as usize] * pow2f(t + j))
        .sum();
    let p_last = lat.fix_probability();
    let (med_signed, med_abs) = if fix {
        let m = pow2f(n + t);
        let dbar = scale - es;
        (
            p_last * (dbar - m / 2.0) - (1.0 - p_last) * es,
            p_last * (dbar * dbar + (m - dbar) * (m - dbar)) / (2.0 * m) + (1.0 - p_last) * es,
        )
    } else {
        (
            p_last * scale - es,
            p_last * (scale - es) + (1.0 - p_last) * es,
        )
    };
    let wce = mae_form(n, t, fix).value;
    AnalyticStats {
        n,
        er,
        med_signed,
        med_abs,
        nmed: med_abs / max_product(n),
        mred: med_abs / pow2f(2 * (n - 1)),
        wce,
        exact: false,
    }
}

/// Shared truncation / broken-array model (truncation is `hbl = 0`).
/// `d_j` = low columns dropped from row `j` — mirrors the kernels in
/// [`crate::multiplier::baselines`] exactly.
fn array_truncation(n: u32, hbl: u32, vbl: u32) -> AnalyticStats {
    let d: Vec<u32> = (0..n)
        .map(|j| if j < hbl { n } else { vbl.saturating_sub(j).min(n) })
        .collect();
    // ER: condition on the lowest set bit v of the multiplicand; the
    // product survives iff every row dropping a column ≤ v has a zero
    // multiplier bit.
    let mut p_ok = 0.5f64.powi(n as i32);
    for v in 0..n {
        let dcount = d.iter().filter(|&&dj| dj > v).count() as i32;
        p_ok += 0.5f64.powi(v as i32 + 1) * 0.5f64.powi(dcount);
    }
    let er = 1.0 - p_ok;
    // Every dropped cell (i, j) carries weight 2^{i+j} and is set with
    // probability 1/4; ED ≥ 0 always, so MED_signed = MED_abs.
    let mut med = 0.0f64;
    let mut wce = 0u64;
    for j in 0..n {
        for i in 0..d[j as usize] {
            med += pow2f(i + j) / 4.0;
            wce += 1u64 << (i + j);
        }
    }
    let h = harmonic_bit_weights(n);
    let mut mred = 0.0f64;
    for j in 0..n {
        for i in 0..d[j as usize] {
            mred += pow2f(i + j) * h[i as usize] * h[j as usize];
        }
    }
    mred /= pow2f(n) * pow2f(n);
    AnalyticStats {
        n,
        er,
        med_signed: med,
        med_abs: med,
        nmed: med / max_product(n),
        mred,
        wce,
        exact: n <= 16,
    }
}

/// `H_i = Σ_{a ∈ [1, 2^n), bit i of a set} 1/a`: exact for `n ≤ 16`,
/// blocked harmonic approximation (≈ 4e-6 relative) above.
fn harmonic_bit_weights(n: u32) -> Vec<f64> {
    if n <= 16 {
        let mut h = vec![0.0f64; n as usize];
        for a in 1..1u64 << n {
            let inv = 1.0 / a as f64;
            let mut x = a;
            let mut i = 0usize;
            while x != 0 {
                if x & 1 == 1 {
                    h[i] += inv;
                }
                x >>= 1;
                i += 1;
            }
        }
        h
    } else {
        (0..n)
            .map(|i| masked_harmonic(1u64 << (i + 1), 1u64 << i, (1u64 << (i + 1)) - 1, 1u64 << n))
            .collect()
    }
}

/// `Σ_{a=lo}^{hi} 1/a` (`lo ≥ 1`): exact short sums, midpoint-log form
/// for long intervals.
fn harmonic_interval(lo: u64, hi: u64) -> f64 {
    if hi < lo {
        return 0.0;
    }
    if hi - lo < 64 {
        (lo..=hi).map(|a| 1.0 / a as f64).sum()
    } else {
        ((hi as f64 + 0.5) / (lo as f64 - 0.5)).ln()
    }
}

/// `Σ 1/a` over `a ∈ [1, limit)` with `a mod period ∈ [lo, hi]`: the
/// first 4096 period-blocks exactly, the tail by density × harmonic.
fn masked_harmonic(period: u64, lo: u64, hi: u64, limit: u64) -> f64 {
    let nblocks = limit.div_ceil(period);
    const CAP: u64 = 4096;
    let mut total = 0.0f64;
    for m in 0..nblocks.min(CAP) {
        let blo = (m * period + lo).max(1);
        let bhi = (m * period + hi).min(limit - 1);
        if blo <= bhi {
            total += harmonic_interval(blo, bhi);
        }
    }
    if nblocks > CAP {
        let density = (hi - lo + 1) as f64 / period as f64;
        total += density * harmonic_interval((CAP * period).max(1), limit - 1);
    }
    total
}

/// Mitchell's logarithmic multiplier. Splitting `a = 2^{k1}(1 + f1)`,
/// `b = 2^{k2}(1 + f2)`: `ED = x1·x2` when `f1 + f2 < 1` and
/// `(2^{k1} - x1)(2^{k2} - x2)` otherwise (`x = f·2^k`), both
/// non-negative, so `MED_signed = MED_abs`; the WCE sits at the overflow
/// boundary `x1 = x2 = 0`, `k1 = k2 = n - 1`: `2^{2n-4}`.
fn mitchell(n: u32) -> AnalyticStats {
    if n == 1 {
        // 1-bit products are 0 or 1; the log approximation is exact.
        return exact_zero(1);
    }
    let q = (n as f64 + 1.0) / pow2f(n);
    let er = (1.0 - q) * (1.0 - q);
    let wce = 1u64 << (2 * n - 4);
    let (med, mred, exact) = if n <= 16 {
        let (med, mred) = mitchell_sums_exact(n);
        (med, mred, true)
    } else {
        // Continuous limits (match exact n = 16 to ≤ 1e-4 relative).
        let pn = pow2f(n);
        let fourn = pn * pn;
        let f = (fourn - 1.0) / 3.0;
        (f * f / (12.0 * fourn), 0.038488, false)
    };
    AnalyticStats {
        n,
        er,
        med_signed: med,
        med_abs: med,
        nmed: med / max_product(n),
        mred,
        wce,
        exact,
    }
}

/// Exact Mitchell MED / MRED by an `O(n·2^n)` prefix-sum reduction over
/// mantissa classes `(k1, x1)`: for each `k2`, precompute prefix sums of
/// `x2/(2^{k2}+x2)` (no-overflow branch) and `(2^{k2}-x2)/(2^{k2}+x2)`
/// (overflow branch); the branch threshold is
/// `x2 < ⌈(2^{k1}-x1)·2^{k2}/2^{k1}⌉`. Verified bit-identical to the
/// `O(4^n)` brute force at n = 8.
fn mitchell_sums_exact(n: u32) -> (f64, f64) {
    let mut sum_ed: u128 = 0;
    let mut sum_red = 0.0f64;
    for k2 in 0..n {
        let big_k2 = 1u64 << k2;
        let mut p = vec![0.0f64; big_k2 as usize + 1];
        let mut q = vec![0.0f64; big_k2 as usize + 1];
        for x2 in 0..big_k2 {
            let denom = (big_k2 + x2) as f64;
            p[x2 as usize + 1] = p[x2 as usize] + x2 as f64 / denom;
            q[x2 as usize + 1] = q[x2 as usize] + (big_k2 - x2) as f64 / denom;
        }
        for k1 in 0..n {
            let big_k1 = 1u64 << k1;
            for x1 in 0..big_k1 {
                let lim = (((big_k1 - x1) * big_k2 + big_k1 - 1) >> k1).min(big_k2);
                let a = (big_k1 + x1) as f64;
                // no-overflow branch: x2 ∈ [0, lim), ED = x1·x2
                sum_ed += (x1 as u128) * ((lim * lim.saturating_sub(1)) / 2) as u128;
                sum_red += (x1 as f64 / a) * p[lim as usize];
                // overflow branch: x2 ∈ [lim, 2^{k2}), ED = y1·y2
                let y1 = big_k1 - x1;
                let span = big_k2 - lim;
                sum_ed += (y1 as u128) * ((span * (span + 1)) / 2) as u128;
                sum_red += (y1 as f64 / a) * (q[big_k2 as usize] - q[lim as usize]);
            }
        }
    }
    let cnt = pow2f(n) * pow2f(n);
    (sum_ed as f64 / cnt, sum_red / cnt)
}

/// Kulkarni's 2×2-block underdesign: the only erring base case is
/// `3 × 3 → 7` (ED 2), and the recursion makes the product error exactly
/// `ED = 2·f(a)·f(b)` with `f(x) = Σ_i [digit_i(x) = 3]·4^i` (base-4
/// digits) — so ER / MED / WCE are exact closed forms for every `n`.
fn kulkarni(n: u32) -> AnalyticStats {
    let m = n / 2;
    let miss = 1.0 - 0.75f64.powi(m as i32);
    let er = miss * miss;
    // E[f] = F/4 with F = Σ_i 4^i = (2^n - 1)/3; f(a), f(b) independent.
    let f_top = ((1u64 << n) - 1) / 3;
    let med = 2.0 * (f_top as f64 / 4.0) * (f_top as f64 / 4.0);
    let wce = 2 * f_top * f_top;
    let g = if n <= 16 {
        let mut g = 0.0f64;
        for a in 1..1u64 << n {
            let mut fa = 0u64;
            let mut x = a;
            let mut i = 0;
            while x != 0 {
                if x & 3 == 3 {
                    fa += 1u64 << (2 * i);
                }
                x >>= 2;
                i += 1;
            }
            g += fa as f64 / a as f64;
        }
        g / pow2f(n)
    } else {
        // f(a) has digit i equal to 3 iff a mod 4^{i+1} ∈ [3·4^i, 4^{i+1}).
        (0..m)
            .map(|i| {
                pow2f(2 * i)
                    * masked_harmonic(
                        1u64 << (2 * i + 2),
                        3u64 << (2 * i),
                        (1u64 << (2 * i + 2)) - 1,
                        1u64 << n,
                    )
            })
            .sum::<f64>()
            / pow2f(n)
    };
    let mred = 2.0 * g * g;
    AnalyticStats {
        n,
        er,
        med_signed: med,
        med_abs: med,
        nmed: med / max_product(n),
        mred,
        wce,
        exact: n <= 16,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64, rel: f64) -> bool {
        (a - b).abs() <= rel * a.abs().max(b.abs()).max(1e-300)
    }

    fn stats(spec: MultiplierSpec) -> AnalyticStats {
        analytic_stats(&spec).unwrap_or_else(|| panic!("no model for {}", spec.name()))
    }

    #[test]
    fn every_registry_family_has_a_model() {
        for spec in MultiplierSpec::registry_examples(8) {
            let s = stats(spec);
            assert_eq!(s.n, 8, "{}", spec.name());
            assert!((0.0..=1.0).contains(&s.er), "{}", spec.name());
            assert!(s.med_abs >= 0.0 && s.med_abs.is_finite(), "{}", spec.name());
            assert!(s.mred.is_finite() && s.nmed.is_finite(), "{}", spec.name());
        }
    }

    #[test]
    fn invalid_specs_have_no_model() {
        assert!(analytic_stats(&MultiplierSpec::Segmented { n: 8, t: 8, fix: false }).is_none());
        assert!(analytic_stats(&MultiplierSpec::Kulkarni { n: 12 }).is_none());
    }

    #[test]
    fn degenerate_configs_inherit_the_exact_zero_model() {
        for spec in [
            MultiplierSpec::Accurate { n: 8 },
            MultiplierSpec::Segmented { n: 8, t: 0, fix: true },
            MultiplierSpec::Segmented { n: 8, t: 0, fix: false },
            MultiplierSpec::Truncated { n: 8, k: 0 },
            MultiplierSpec::BrokenArray { n: 8, hbl: 0, vbl: 0 },
            MultiplierSpec::BitLevel { n: 8, t: 0, fix: true },
            MultiplierSpec::Netlist { n: 8, t: 0, fix: false },
        ] {
            assert_eq!(stats(spec), exact_zero(8), "{}", spec.name());
        }
    }

    #[test]
    fn truncation_closed_forms_match_brute_force_constants() {
        // Spot values computed by O(4^n) brute force over the actual
        // TruncatedMul / BrokenArrayMul kernels.
        let s = stats(MultiplierSpec::Truncated { n: 8, k: 4 });
        assert!(s.exact);
        assert_eq!(s.er, 0.8125);
        assert_eq!(s.med_abs, 12.25);
        assert_eq!(s.med_signed, 12.25);
        assert_eq!(s.wce, 49);
        assert!(close(s.mred, 0.005596923497286267, 1e-9), "{}", s.mred);
        let s = stats(MultiplierSpec::Truncated { n: 8, k: 2 });
        assert_eq!((s.er, s.med_abs, s.wce), (0.5, 1.25, 5));
        assert!(close(s.mred, 0.0007684763422423708, 1e-9));
    }

    #[test]
    fn broken_array_closed_forms_match_brute_force_constants() {
        let s = stats(MultiplierSpec::BrokenArray { n: 8, hbl: 2, vbl: 4 });
        assert!(s.exact);
        assert_eq!(s.er, 0.8720703125);
        assert_eq!(s.med_abs, 196.25);
        assert_eq!(s.wce, 785);
        assert!(close(s.mred, 0.03754954972142397, 1e-9), "{}", s.mred);
        assert!(close(s.nmed, 196.25 / (255.0 * 255.0), 1e-12));
    }

    #[test]
    fn mitchell_closed_forms_match_brute_force_constants() {
        let s = stats(MultiplierSpec::Mitchell { n: 8 });
        assert!(s.exact);
        assert_eq!(s.er, 0.9309234619140625);
        assert_eq!(s.wce, 4096); // 2^{2n-4}
        assert!(close(s.med_abs, 606.3981475830078, 1e-12), "{}", s.med_abs);
        assert!(close(s.mred, 0.037582937684927105, 1e-12), "{}", s.mred);
    }

    #[test]
    fn mitchell_continuous_tier_tracks_exact_boundary() {
        // n = 16 is the last exact bit-width; the continuous limits must
        // agree with it closely (measured ≤ 1e-4 relative), so the n>16
        // tier is a smooth extension rather than a jump.
        let exact16 = mitchell_sums_exact(16);
        let f = (pow2f(16) * pow2f(16) - 1.0) / 3.0;
        let cont_med = f * f / (12.0 * pow2f(16) * pow2f(16));
        assert!(close(exact16.0, cont_med, 1e-6), "{} vs {cont_med}", exact16.0);
        assert!(close(exact16.1, 0.038488, 1e-3), "{}", exact16.1);
        let s = stats(MultiplierSpec::Mitchell { n: 32 });
        assert!(!s.exact);
        assert_eq!(s.wce, 1u64 << 60);
        assert!(s.med_abs > 0.0 && s.mred > 0.0);
    }

    #[test]
    fn kulkarni_closed_forms_match_brute_force_constants() {
        let s = stats(MultiplierSpec::Kulkarni { n: 8 });
        assert!(s.exact);
        assert_eq!(s.er, 0.4673004150390625);
        assert_eq!(s.med_abs, 903.125);
        assert_eq!(s.wce, 14450);
        assert!(close(s.mred, 0.03254912141206344, 1e-9), "{}", s.mred);
        let s = stats(MultiplierSpec::Kulkarni { n: 4 });
        assert_eq!(s.er, 0.19140625);
        assert_eq!(s.med_abs, 3.125);
        assert_eq!(s.wce, 50);
        assert!(close(s.mred, 0.026082504221552665, 1e-9));
    }

    #[test]
    fn kulkarni_hybrid_tier_is_finite_and_bounded() {
        let s = stats(MultiplierSpec::Kulkarni { n: 32 });
        assert!(!s.exact);
        // G < E[f]/1 trivially; measured hybrid value ≈ 0.0332.
        assert!(close(s.mred, 0.03322925295753541, 1e-6), "{}", s.mred);
        let f_top = ((1u64 << 32) - 1) / 3;
        assert_eq!(s.wce, 2 * f_top * f_top);
    }

    #[test]
    fn segmented_estimates_are_bounded_and_anchor_wce_to_closed_form() {
        use crate::error::closed_form::{mae_fix_envelope, mae_measured_nofix};
        for n in [4u32, 8, 16, 32] {
            for t in 1..n {
                for fix in [false, true] {
                    let s = stats(MultiplierSpec::Segmented { n, t, fix });
                    assert!(!s.exact);
                    assert!((0.0..=1.0).contains(&s.er), "er n={n} t={t}");
                    assert!(s.med_abs >= 0.0 && s.med_abs.is_finite(), "n={n} t={t}");
                    assert!(s.med_signed.abs() <= s.med_abs + 1e-9, "n={n} t={t}");
                    let want = if fix {
                        mae_fix_envelope(n, t)
                    } else {
                        mae_measured_nofix(n, t)
                    };
                    assert_eq!(s.wce, want, "wce n={n} t={t} fix={fix}");
                }
            }
        }
    }

    #[test]
    fn oracle_and_netlist_share_the_segmented_model() {
        let seg = stats(MultiplierSpec::Segmented { n: 8, t: 4, fix: true });
        assert_eq!(stats(MultiplierSpec::BitLevel { n: 8, t: 4, fix: true }), seg);
        assert_eq!(stats(MultiplierSpec::Netlist { n: 8, t: 4, fix: true }), seg);
    }

    #[test]
    fn to_metrics_bridges_into_the_simulated_type() {
        let s = stats(MultiplierSpec::Truncated { n: 8, k: 4 });
        let m = s.to_metrics();
        assert_eq!(m.n, 8);
        assert_eq!(m.samples, 1 << 16);
        assert_eq!(m.er, s.er);
        assert_eq!(m.mae, s.wce);
        assert_eq!(m.med_abs, s.med_abs);
        assert_eq!(m.nmed, s.nmed);
        assert!(m.ber.is_empty());
        assert!(m.mean_ber().is_nan());
        // n = 32: the exhaustive population 2^64 saturates.
        let m = stats(MultiplierSpec::Mitchell { n: 32 }).to_metrics();
        assert_eq!(m.samples, u64::MAX);
    }

    #[test]
    fn harmonic_helpers_agree_with_direct_summation() {
        let direct: f64 = (1u64..=1000).map(|a| 1.0 / a as f64).sum();
        assert!(close(harmonic_interval(1, 1000), direct, 1e-4));
        assert_eq!(harmonic_interval(10, 9), 0.0);
        // Masked sum over odd a in [1, 4096): exact (single-block cap
        // never reached at this size).
        let odd: f64 = (1u64..4096).step_by(2).map(|a| 1.0 / a as f64).sum();
        assert!(close(masked_harmonic(2, 1, 1, 4096), odd, 1e-4));
        // H_i hybrid vs exact at n = 16 (measured worst ≈ 4.3e-6).
        let exact = harmonic_bit_weights(16);
        for (i, &hi) in exact.iter().enumerate() {
            let hyb = masked_harmonic(
                1u64 << (i + 1),
                1u64 << i,
                (1u64 << (i + 1)) - 1,
                1u64 << 16,
            );
            assert!(close(hyb, hi, 1e-4), "i={i}: {hyb} vs {hi}");
        }
    }
}
