//! Deterministic fault injection: the chaos seams behind every recovery
//! path in the stack.
//!
//! A [`FaultInjector`] is parsed from a compact spec (usually the
//! `SEGMUL_FAULTS` environment variable) and threaded by `Arc` into the
//! store (blob/journal/lease I/O), the worker pool (panics, hangs,
//! delayed chunks, transient backend failures), and the serve engine
//! thread. Each instrumented site calls [`FaultInjector::fire`] at the
//! moment the real operation would run; a `true` answer makes the seam
//! simulate the failure (short write, EIO, panic, …) instead.
//!
//! Two properties make injected chaos usable in CI:
//!
//! * **Determinism.** Every decision is a pure function of
//!   `(seed, site, per-site attempt index)` via [`Xoshiro256::stream`] —
//!   no wall clock, no global RNG. The same spec + seed over the same
//!   work replays the same fault schedule.
//! * **Accounting.** Every injected fault increments a per-site counter
//!   ([`FaultInjector::injected`]), surfaced through session telemetry
//!   and `/metrics`, so tests can assert both that faults actually fired
//!   *and* that the final statistics stayed bit-identical.
//!
//! Spec grammar (comma-separated `site:trigger` entries):
//!
//! ```text
//! SEGMUL_FAULTS="store.write:p=0.05,worker.panic:after=3,backend.fail:every=7"
//! ```
//!
//! Triggers: `p=<f64>` fires each attempt with probability *p*;
//! `after=<n>` fires exactly once, on the *n*-th attempt (one-shot, so a
//! self-healing system can be observed recovering); `every=<n>` fires on
//! every *n*-th attempt; `first=<n>` fires on each of the first *n*
//! attempts (a bounded storm that ends deterministically).
//!
//! The zero-fault fast path is one branch on a plain `bool` — benches
//! gate it at <2% overhead (`fault_overhead_ratio`).

#![warn(clippy::unwrap_used, clippy::expect_used)]

pub mod retry;

pub use retry::{RetryCounters, RetryPolicy};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::error::SegmulError;
use crate::util::rng::Xoshiro256;

/// The instrumented failure sites, one per recovery path under test.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultSite {
    /// Blob load: the read fails with a simulated EIO (typed `Store`
    /// error → counted miss, the job re-evaluates).
    StoreRead,
    /// Blob commit: the tmp write is torn short and errors (the commit
    /// fails with a warning; the answer in memory stays correct).
    StoreWrite,
    /// Blob commit: the tmp file is written whole but one byte is
    /// damaged before the rename — the seal check catches it on the next
    /// load (recovery counted, job re-evaluated).
    StoreCorrupt,
    /// Journal checkpoint append: the line is torn mid-write and the
    /// writer disables, exactly like a disk-full — resumability degrades
    /// to an earlier prefix, correctness is unaffected.
    JournalAppend,
    /// Lease claim I/O error: the claimant retries, then proceeds
    /// without exclusion (duplicate work, never a wrong answer).
    LeaseClaim,
    /// Worker thread panics mid-chunk (caught, retried in-worker).
    WorkerPanic,
    /// Worker stalls for a bounded interval before evaluating.
    WorkerHang,
    /// Worker delays a chunk briefly (reordering pressure on the merge).
    WorkerDelay,
    /// Transient `EvalBackend` failure (retried under [`RetryPolicy`]).
    BackendFail,
    /// Serve engine thread panics mid-cycle (caught by the supervisor,
    /// which answers stranded clients with typed 500s and restarts).
    EnginePanic,
}

const N_SITES: usize = 10;

/// All sites, in stable order, paired with their spec names.
pub const SITES: [(FaultSite, &str); N_SITES] = [
    (FaultSite::StoreRead, "store.read"),
    (FaultSite::StoreWrite, "store.write"),
    (FaultSite::StoreCorrupt, "store.corrupt"),
    (FaultSite::JournalAppend, "journal.append"),
    (FaultSite::LeaseClaim, "lease.claim"),
    (FaultSite::WorkerPanic, "worker.panic"),
    (FaultSite::WorkerHang, "worker.hang"),
    (FaultSite::WorkerDelay, "worker.delay"),
    (FaultSite::BackendFail, "backend.fail"),
    (FaultSite::EnginePanic, "engine.panic"),
];

impl FaultSite {
    /// The spec / telemetry name of this site.
    pub fn name(self) -> &'static str {
        SITES[self as usize].1
    }

    fn from_name(name: &str) -> Option<FaultSite> {
        SITES.iter().find(|(_, n)| *n == name).map(|(s, _)| *s)
    }
}

/// When an armed site fires, as a function of its 1-based attempt index.
#[derive(Clone, Copy, Debug, PartialEq)]
enum Trigger {
    /// Independent probability per attempt (deterministic draw).
    Prob(f64),
    /// Exactly once, on the n-th attempt.
    After(u64),
    /// On every n-th attempt.
    Every(u64),
    /// On each of the first n attempts.
    First(u64),
}

impl Trigger {
    fn parse(text: &str) -> Result<Trigger, String> {
        let (key, value) = text
            .split_once('=')
            .ok_or_else(|| format!("trigger {text:?} is not key=value"))?;
        match key {
            "p" => {
                let p: f64 =
                    value.parse().map_err(|e| format!("bad probability {value:?}: {e}"))?;
                if !(0.0..=1.0).contains(&p) {
                    return Err(format!("probability {p} outside [0, 1]"));
                }
                Ok(Trigger::Prob(p))
            }
            "after" | "every" | "first" => {
                let n: u64 = value.parse().map_err(|e| format!("bad count {value:?}: {e}"))?;
                if n == 0 {
                    return Err(format!("{key} requires a count >= 1"));
                }
                Ok(match key {
                    "after" => Trigger::After(n),
                    "every" => Trigger::Every(n),
                    _ => Trigger::First(n),
                })
            }
            _ => Err(format!("unknown trigger {key:?} (want p/after/every/first)")),
        }
    }
}

/// The armed fault plan plus per-site attempt / injection accounting.
///
/// Cheap to consult when disarmed (one bool branch), deterministic when
/// armed. Shared by `Arc` across the session, store, pool, and serve
/// engine so one plan accounts for the whole process.
#[derive(Debug)]
pub struct FaultInjector {
    armed: bool,
    seed: u64,
    plan: [Option<Trigger>; N_SITES],
    attempts: [AtomicU64; N_SITES],
    injected: [AtomicU64; N_SITES],
}

impl Default for FaultInjector {
    fn default() -> Self {
        Self::disabled()
    }
}

impl FaultInjector {
    /// An injector with no armed sites — the production fast path.
    pub fn disabled() -> FaultInjector {
        FaultInjector {
            armed: false,
            seed: 0,
            plan: [None; N_SITES],
            attempts: std::array::from_fn(|_| AtomicU64::new(0)),
            injected: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Parse a `site:trigger,site:trigger` spec (see module docs).
    pub fn parse(spec: &str, seed: u64) -> Result<FaultInjector, SegmulError> {
        let mut plan: [Option<Trigger>; N_SITES] = [None; N_SITES];
        let mut any = false;
        for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
            let (site_name, trigger_text) = entry.split_once(':').ok_or_else(|| {
                SegmulError::config(format!("fault entry {entry:?} is not site:trigger"))
            })?;
            let site = FaultSite::from_name(site_name.trim()).ok_or_else(|| {
                let known: Vec<&str> = SITES.iter().map(|(_, n)| *n).collect();
                SegmulError::config(format!(
                    "unknown fault site {site_name:?} (known: {})",
                    known.join(", ")
                ))
            })?;
            let trigger = Trigger::parse(trigger_text.trim()).map_err(|e| {
                SegmulError::config(format!("fault entry {entry:?}: {e}"))
            })?;
            if plan[site as usize].is_some() {
                return Err(SegmulError::config(format!(
                    "fault site {site_name:?} specified twice"
                )));
            }
            plan[site as usize] = Some(trigger);
            any = true;
        }
        Ok(FaultInjector {
            armed: any,
            seed,
            plan,
            attempts: std::array::from_fn(|_| AtomicU64::new(0)),
            injected: std::array::from_fn(|_| AtomicU64::new(0)),
        })
    }

    /// Build from `SEGMUL_FAULTS` / `SEGMUL_FAULT_SEED` (unset or empty
    /// spec → disabled; a malformed spec is a typed `Config` error, never
    /// silently ignored).
    pub fn from_env() -> Result<Arc<FaultInjector>, SegmulError> {
        let spec = std::env::var("SEGMUL_FAULTS").unwrap_or_default();
        if spec.trim().is_empty() {
            return Ok(Arc::new(FaultInjector::disabled()));
        }
        let seed = match std::env::var("SEGMUL_FAULT_SEED") {
            Ok(s) => s.trim().parse().map_err(|e| {
                SegmulError::config(format!("bad SEGMUL_FAULT_SEED {s:?}: {e}"))
            })?,
            Err(_) => 0x5EED,
        };
        Ok(Arc::new(FaultInjector::parse(&spec, seed)?))
    }

    /// Whether any site is armed (the bench-gated fast-path branch).
    pub fn armed(&self) -> bool {
        self.armed
    }

    /// Whether this specific site is armed (seams that need setup work
    /// before simulating a failure check this first).
    pub fn site_armed(&self, site: FaultSite) -> bool {
        self.armed && self.plan[site as usize].is_some()
    }

    /// Consult the plan at an instrumented site: counts the attempt and
    /// answers whether the seam must simulate a failure now. Decisions
    /// are deterministic in `(seed, site, attempt index)`.
    pub fn fire(&self, site: FaultSite) -> bool {
        if !self.armed {
            return false;
        }
        let i = site as usize;
        let Some(trigger) = self.plan[i] else { return false };
        let attempt = self.attempts[i].fetch_add(1, Ordering::Relaxed) + 1;
        let hit = match trigger {
            Trigger::Prob(p) => {
                // One deterministic draw per (seed, site, attempt).
                let salt = (i as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15);
                Xoshiro256::stream(self.seed ^ salt, attempt).next_f64() < p
            }
            Trigger::After(n) => attempt == n,
            Trigger::Every(n) => attempt % n == 0,
            Trigger::First(n) => attempt <= n,
        };
        if hit {
            self.injected[i].fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// Injected-fault count for one site.
    pub fn injected(&self, site: FaultSite) -> u64 {
        self.injected[site as usize].load(Ordering::Relaxed)
    }

    /// Attempts observed at one site (fired or not) — lets tests prove a
    /// seam was actually consulted.
    pub fn attempts(&self, site: FaultSite) -> u64 {
        self.attempts[site as usize].load(Ordering::Relaxed)
    }

    /// Total injected faults across all sites.
    pub fn total_injected(&self) -> u64 {
        self.injected.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    /// `(site name, injected count)` for every site that fired at least
    /// once — the telemetry / chaos-report view.
    pub fn counters(&self) -> Vec<(&'static str, u64)> {
        SITES
            .iter()
            .filter_map(|&(site, name)| {
                let n = self.injected(site);
                (n > 0).then_some((name, n))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;

    #[test]
    fn disabled_never_fires_and_counts_nothing() {
        let f = FaultInjector::disabled();
        assert!(!f.armed());
        for &(site, _) in &SITES {
            for _ in 0..100 {
                assert!(!f.fire(site));
            }
            assert_eq!(f.injected(site), 0);
        }
        assert_eq!(f.total_injected(), 0);
        assert!(f.counters().is_empty());
    }

    #[test]
    fn spec_round_trips_every_trigger_kind() {
        let f = FaultInjector::parse(
            "store.write:p=0.5, worker.panic:after=3, backend.fail:every=2, engine.panic:first=4",
            7,
        )
        .unwrap();
        assert!(f.armed());
        assert!(f.site_armed(FaultSite::StoreWrite));
        assert!(!f.site_armed(FaultSite::StoreRead));
        // after=3: exactly one firing, on the third attempt.
        let fires: Vec<bool> = (0..6).map(|_| f.fire(FaultSite::WorkerPanic)).collect();
        assert_eq!(fires, [false, false, true, false, false, false]);
        assert_eq!(f.injected(FaultSite::WorkerPanic), 1);
        // every=2: attempts 2, 4, 6.
        let fires: Vec<bool> = (0..6).map(|_| f.fire(FaultSite::BackendFail)).collect();
        assert_eq!(fires, [false, true, false, true, false, true]);
        // first=4: attempts 1..=4 all fire, then the storm ends.
        let fires: Vec<bool> = (0..6).map(|_| f.fire(FaultSite::EnginePanic)).collect();
        assert_eq!(fires, [true, true, true, true, false, false]);
        assert_eq!(f.total_injected(), 1 + 3 + 4 + f.injected(FaultSite::StoreWrite));
    }

    #[test]
    fn probability_is_deterministic_in_seed_and_attempt() {
        let run = |seed| {
            let f = FaultInjector::parse("store.read:p=0.3", seed).unwrap();
            (0..1000).map(|_| f.fire(FaultSite::StoreRead)).collect::<Vec<bool>>()
        };
        let a = run(42);
        assert_eq!(a, run(42), "same seed replays the same schedule");
        assert_ne!(a, run(43), "different seeds differ");
        let hits = a.iter().filter(|&&h| h).count();
        assert!((150..450).contains(&hits), "p=0.3 over 1000 attempts fired {hits} times");
    }

    #[test]
    fn p_zero_is_consulted_but_never_fires() {
        let f = FaultInjector::parse("backend.fail:p=0", 1).unwrap();
        assert!(f.armed(), "armed plan exercises the seam even at p=0");
        for _ in 0..50 {
            assert!(!f.fire(FaultSite::BackendFail));
        }
        assert_eq!(f.attempts(FaultSite::BackendFail), 50);
        assert_eq!(f.injected(FaultSite::BackendFail), 0);
    }

    #[test]
    fn malformed_specs_are_typed_config_errors() {
        for bad in [
            "store.write",              // no trigger
            "nope.site:p=0.1",          // unknown site
            "store.write:p=1.5",        // probability out of range
            "store.write:after=0",      // zero count
            "store.write:when=3",       // unknown trigger key
            "store.write:p=0.1,store.write:p=0.2", // duplicate site
        ] {
            let err = FaultInjector::parse(bad, 0).unwrap_err();
            assert_eq!(err.kind(), "config", "{bad:?} -> {err}");
        }
    }

    #[test]
    fn counters_report_only_fired_sites() {
        let f = FaultInjector::parse("worker.hang:first=2,lease.claim:after=99", 0).unwrap();
        f.fire(FaultSite::WorkerHang);
        f.fire(FaultSite::WorkerHang);
        f.fire(FaultSite::LeaseClaim);
        assert_eq!(f.counters(), vec![("worker.hang", 2)]);
        assert_eq!(f.total_injected(), 2);
    }
}
