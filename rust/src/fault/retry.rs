//! Typed retry: bounded exponential backoff with deterministic jitter.
//!
//! One policy type replaces the ad-hoc sleep loops that grew around
//! transient failures (the store's 25 ms lease poll, in-worker chunk
//! retries after a caught panic or an injected backend failure). A
//! [`RetryPolicy`] is a pure value — attempts bounded, per-attempt delay
//! exponential from `base` and capped at `cap`, the whole episode capped
//! by a wall-clock `budget` — and its jitter is drawn from
//! [`Xoshiro256::stream`] of `(seed, attempt)`, so a replayed chaos run
//! waits the same schedule it waited the first time.
//!
//! Accounting flows through [`RetryCounters`]: `retries` counts every
//! backoff actually taken, `gave_up` counts episodes that exhausted their
//! attempt or time budget. Both surface in `SessionTelemetry` and
//! `/metrics`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use crate::util::rng::Xoshiro256;

/// Shared retry accounting (one per pool / runner, aggregated into
/// session telemetry).
#[derive(Debug, Default)]
pub struct RetryCounters {
    /// Backoffs taken (each is one re-attempt of a failed operation).
    pub retries: AtomicU64,
    /// Episodes that exhausted the policy and surfaced their error.
    pub gave_up: AtomicU64,
}

impl RetryCounters {
    /// Fresh zeroed counters.
    pub fn new() -> RetryCounters {
        RetryCounters::default()
    }

    /// Backoffs taken so far.
    pub fn retries(&self) -> u64 {
        self.retries.load(Ordering::Relaxed)
    }

    /// Episodes that exhausted the policy.
    pub fn gave_up(&self) -> u64 {
        self.gave_up.load(Ordering::Relaxed)
    }
}

/// Bounded exponential backoff with deterministic jitter.
#[derive(Clone, Debug)]
pub struct RetryPolicy {
    /// Maximum attempts per episode (>= 1; the first attempt counts).
    pub max_attempts: u32,
    /// Delay before the second attempt; doubles per attempt thereafter.
    pub base: Duration,
    /// Per-attempt delay ceiling.
    pub cap: Duration,
    /// Wall-clock budget for the whole episode; an attempt whose backoff
    /// would overrun it gives up instead.
    pub budget: Duration,
    /// Jitter stream seed (deterministic; never the wall clock).
    pub seed: u64,
}

impl RetryPolicy {
    /// In-worker chunk retry: a few fast attempts, so a transient
    /// backend failure or caught panic never costs more than a blink,
    /// while a persistent failure still surfaces promptly.
    pub fn chunk() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 4,
            base: Duration::from_millis(1),
            cap: Duration::from_millis(20),
            budget: Duration::from_secs(2),
            seed: 0xC4C4,
        }
    }

    /// Lease poll-for-commit: patient, capped waits replacing the old
    /// fixed 25 ms spin; `budget` is the session's `store_wait`.
    pub fn lease(budget: Duration) -> RetryPolicy {
        RetryPolicy {
            max_attempts: u32::MAX,
            base: Duration::from_millis(5),
            cap: Duration::from_millis(250),
            budget,
            seed: 0x1EA5E,
        }
    }

    /// The delay taken after failed attempt `attempt` (1-based):
    /// `base * 2^(attempt-1)` capped at `cap`, scaled by a deterministic
    /// jitter factor in `[0.5, 1.0)` drawn from `(seed, attempt)`.
    pub fn backoff(&self, attempt: u32) -> Duration {
        let exp = self
            .base
            .saturating_mul(1u32 << (attempt - 1).min(20))
            .min(self.cap);
        let jitter = 0.5 + 0.5 * Xoshiro256::stream(self.seed, attempt as u64).next_f64();
        exp.mul_f64(jitter)
    }

    /// Run `op` under this policy. `op` receives the 1-based attempt
    /// index; the first failure whose next backoff would exceed the
    /// attempt or time budget is returned as-is (typed, never wrapped).
    pub fn run<T, E>(
        &self,
        counters: &RetryCounters,
        mut op: impl FnMut(u32) -> Result<T, E>,
    ) -> Result<T, E> {
        let start = Instant::now();
        let mut attempt = 1u32;
        loop {
            match op(attempt) {
                Ok(v) => return Ok(v),
                Err(e) => {
                    let delay = self.backoff(attempt);
                    if attempt >= self.max_attempts || start.elapsed() + delay > self.budget {
                        counters.gave_up.fetch_add(1, Ordering::Relaxed);
                        return Err(e);
                    }
                    counters.retries.fetch_add(1, Ordering::Relaxed);
                    std::thread::sleep(delay);
                    attempt += 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;

    #[test]
    fn backoff_is_deterministic_capped_and_jittered() {
        let p = RetryPolicy {
            max_attempts: 10,
            base: Duration::from_millis(4),
            cap: Duration::from_millis(32),
            budget: Duration::from_secs(60),
            seed: 9,
        };
        for attempt in 1..=8 {
            let d = p.backoff(attempt);
            assert_eq!(d, p.backoff(attempt), "deterministic per (seed, attempt)");
            let exp = Duration::from_millis(4).saturating_mul(1 << (attempt - 1)).min(p.cap);
            assert!(d >= exp.mul_f64(0.5) && d < exp, "attempt {attempt}: {d:?} vs exp {exp:?}");
        }
        // Deep attempts never overflow the shift.
        assert!(p.backoff(200) <= p.cap);
    }

    #[test]
    fn succeeds_after_transient_failures_and_counts_retries() {
        let p = RetryPolicy {
            base: Duration::from_micros(10),
            cap: Duration::from_micros(50),
            ..RetryPolicy::chunk()
        };
        let c = RetryCounters::new();
        let out: Result<u32, &str> =
            p.run(&c, |attempt| if attempt < 3 { Err("transient") } else { Ok(attempt) });
        assert_eq!(out, Ok(3));
        assert_eq!(c.retries(), 2);
        assert_eq!(c.gave_up(), 0);
    }

    #[test]
    fn exhausting_attempts_surfaces_the_error_and_counts_give_up() {
        let p = RetryPolicy {
            max_attempts: 3,
            base: Duration::from_micros(10),
            cap: Duration::from_micros(50),
            budget: Duration::from_secs(5),
            seed: 1,
        };
        let c = RetryCounters::new();
        let mut calls = 0u32;
        let out: Result<(), &str> = p.run(&c, |_| {
            calls += 1;
            Err("persistent")
        });
        assert_eq!(out, Err("persistent"));
        assert_eq!(calls, 3);
        assert_eq!(c.retries(), 2);
        assert_eq!(c.gave_up(), 1);
    }

    #[test]
    fn time_budget_caps_the_episode() {
        let p = RetryPolicy {
            max_attempts: u32::MAX,
            base: Duration::from_millis(30),
            cap: Duration::from_millis(30),
            budget: Duration::from_millis(1),
            seed: 2,
        };
        let c = RetryCounters::new();
        let start = Instant::now();
        let out: Result<(), &str> = p.run(&c, |_| Err("slow"));
        assert_eq!(out, Err("slow"));
        assert!(start.elapsed() < Duration::from_millis(500), "gave up without the long sleep");
        assert_eq!(c.gave_up(), 1);
    }
}
