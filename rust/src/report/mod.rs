//! Figure regeneration: one entry point per paper table/figure.
//!
//! Every function returns a [`csv::Table`] (also written to
//! `results/<name>.csv`) and the experiment index in DESIGN.md §4 maps
//! each to its paper artifact. EXPERIMENTS.md records paper-vs-measured.

pub mod csv;
pub mod figures;
pub mod percentile;
pub mod sweep;

pub use figures::*;
pub use percentile::percentile;
