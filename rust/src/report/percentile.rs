//! Shared nearest-rank percentile — the one latency-percentile
//! definition used by the serving layer (`/metrics`, `BENCH_serve.json`)
//! and the loopback example.
//!
//! The previous per-example helper used a floor-biased index
//! (`(len - 1) * p as usize`), which under-reports upper percentiles on
//! small sample sets: for 10 samples it returned the 9th value as "p99"
//! instead of the maximum. Nearest-rank is the standard fix: the p-th
//! percentile of N sorted samples is the value at rank `ceil(p * N)`
//! (1-based), so p99 of 10 samples is the 10th — the tail is never
//! rounded away.

/// Nearest-rank percentile of an **ascending-sorted** sample slice.
///
/// `p` is a fraction in `(0, 1]` (`0.99` for p99); values outside the
/// range are clamped. Returns `NaN` for an empty slice — the report
/// layers render that as `-` rather than panicking.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let p = p.clamp(f64::MIN_POSITIVE, 1.0);
    let rank = (p * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nearest_rank_definition() {
        let v: Vec<f64> = (1..=10).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.50), 5.0);
        assert_eq!(percentile(&v, 0.90), 9.0);
        // The old floor-biased index returned 9.0 here; nearest-rank
        // keeps the tail: p99 of 10 samples is the maximum.
        assert_eq!(percentile(&v, 0.99), 10.0);
        assert_eq!(percentile(&v, 1.0), 10.0);
    }

    #[test]
    fn small_and_degenerate_inputs() {
        assert!(percentile(&[], 0.5).is_nan());
        assert_eq!(percentile(&[7.0], 0.01), 7.0);
        assert_eq!(percentile(&[7.0], 0.99), 7.0);
        let two = [1.0, 2.0];
        assert_eq!(percentile(&two, 0.50), 1.0);
        assert_eq!(percentile(&two, 0.51), 2.0);
        // Out-of-range p clamps instead of indexing out of bounds.
        assert_eq!(percentile(&two, -1.0), 1.0);
        assert_eq!(percentile(&two, 2.0), 2.0);
    }

    #[test]
    fn p50_of_even_count_is_lower_median() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.5), 2.0);
    }
}
