//! Tiny CSV table: build in memory, render to string, write to disk.

use std::path::Path;

use anyhow::Result;

use crate::util::fsio::write_atomic;

/// An in-memory table with a header row.
#[derive(Clone, Debug, Default)]
pub struct Table {
    /// Column names.
    pub header: Vec<String>,
    /// Data rows (stringified cells).
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// An empty table with `header` columns.
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append one row.
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render as CSV text (header + rows).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.header.join(","));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.iter().map(|c| escape(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Render as an aligned text table (for terminal output).
    pub fn to_text(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (w, c) in widths.iter_mut().zip(r) {
                *w = (*w).max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = fmt_row(&self.header);
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }

    /// Write the CSV rendering atomically (tmp + rename, so a crash or a
    /// concurrent reader never sees a torn file). I/O failures surface as
    /// typed [`crate::error::SegmulError::Io`] through the anyhow result.
    pub fn write(&self, path: &Path) -> Result<()> {
        write_atomic(path, self.to_csv().as_bytes())?;
        Ok(())
    }
}

fn escape(cell: &str) -> String {
    if cell.contains(',') || cell.contains('"') || cell.contains('\n') {
        format!("\"{}\"", cell.replace('"', "\"\""))
    } else {
        cell.to_string()
    }
}

/// Format a float compactly for tables.
pub fn f(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 1000.0 || v.abs() < 0.001 {
        format!("{v:.4e}")
    } else {
        format!("{v:.5}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_rendering() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into(), "x,y".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,\"x,y\"\n");
    }

    #[test]
    fn text_alignment() {
        let mut t = Table::new(&["name", "v"]);
        t.row(vec!["long-name".into(), "1".into()]);
        let text = t.to_text();
        assert!(text.contains("long-name"));
        assert!(text.lines().count() == 3);
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn width_checked() {
        let mut t = Table::new(&["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn float_format() {
        assert_eq!(f(0.0), "0");
        assert!(f(1234567.0).contains('e'));
        assert_eq!(f(0.25), "0.25000");
    }
}
