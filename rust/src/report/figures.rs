//! One generator per paper artifact (experiment index: DESIGN.md §4).

use std::path::Path;

use anyhow::Result;

use crate::config::Config;
use crate::coordinator::{run_job, EvalBackend, EvalJob};
use crate::error::closed_form;
use crate::error::exhaustive::{exhaustive_stats, exhaustive_stats_batch};
use crate::error::metrics::ErrorMetrics;
use crate::error::montecarlo::{mc_stats_batch, McConfig};
use crate::error::probprop;
use crate::multiplier::DesignSet;
use crate::netlist::generators::seq_mult::seq_mult;
use crate::tech::{measure_activity, AsicModel, FpgaModel, HwFigures};

use super::csv::{f, Table};

fn metrics_row(design: &str, n: u32, t: Option<u32>, m: &ErrorMetrics) -> Vec<String> {
    vec![
        design.to_string(),
        n.to_string(),
        t.map(|t| t.to_string()).unwrap_or_default(),
        m.samples.to_string(),
        f(m.er),
        f(m.med_abs),
        f(m.med_signed),
        m.mae.to_string(),
        f(m.nmed),
        f(m.mred),
        f(m.mean_ber()),
    ]
}

/// E2 / Fig. 2: error metrics of our design (t ∈ {2..n/2}, fix on/off) and
/// the re-implemented related-work baselines, per bit-width.
pub fn fig2(cfg: &Config, backend: &mut dyn EvalBackend) -> Result<Table> {
    let mut table = Table::new(&[
        "design", "n", "t", "samples", "er", "med_abs", "med_signed", "mae", "nmed", "mred",
        "mean_ber",
    ]);
    for &n in &cfg.error_bitwidths {
        let exhaustive = n <= cfg.exhaustive_max_n;
        // our design
        for t in 2..=n / 2 {
            for fix in [false, true] {
                let job = if exhaustive {
                    EvalJob::exhaustive(n, t, fix)
                } else {
                    EvalJob::mc(n, t, fix, cfg.mc_samples, cfg.seed ^ (n as u64) << 8 ^ t as u64)
                };
                let m = run_job(backend, &job)?.metrics()?;
                let name = if fix { "segmul+fix" } else { "segmul" };
                table.row(metrics_row(name, n, Some(t), &m));
            }
        }
        // baselines (n <= 32; Kulkarni needs power-of-two) — evaluated
        // through the same branch-free batch kernels the sweeps run, not
        // the per-pair scalar adapters.
        for spec in DesignSet::Baselines.specs(n) {
            let bl = spec.build_batch()?;
            let m = if exhaustive {
                exhaustive_stats_batch(bl.as_ref(), cfg.workers).metrics()?
            } else {
                let mc = McConfig::uniform(cfg.mc_samples, cfg.seed ^ 0xB15E);
                mc_stats_batch(bl.as_ref(), &mc).metrics()?
            };
            table.row(metrics_row(&spec.name(), n, None, &m));
        }
    }
    table.write(&cfg.results_dir.join("fig2_error_metrics.csv"))?;
    Ok(table)
}

/// E3 / Eq. 11: closed-form MAE vs exhaustively measured MAE.
pub fn mae_table(cfg: &Config) -> Result<Table> {
    let mut table = Table::new(&[
        "n", "t", "mae_eq11", "mae_measured_nofix", "mae_closed_nofix", "mae_measured_fix",
        "fix_envelope", "eq11_matches", "closed_matches", "envelope_holds",
    ]);
    for n in 4..=cfg.exhaustive_max_n.min(12) {
        for t in 1..=n / 2 {
            let nofix = exhaustive_stats(n, t, false).max_abs_ed;
            let fix = exhaustive_stats(n, t, true).max_abs_ed;
            let eq11 = closed_form::mae_eq11(n, t);
            let closed = closed_form::mae_measured_nofix(n, t);
            let envelope = closed_form::mae_fix_envelope(n, t);
            table.row(vec![
                n.to_string(),
                t.to_string(),
                eq11.to_string(),
                nofix.to_string(),
                closed.to_string(),
                fix.to_string(),
                envelope.to_string(),
                (eq11 == nofix).to_string(),
                (closed == nofix).to_string(),
                (fix <= envelope).to_string(),
            ]);
        }
    }
    table.write(&cfg.results_dir.join("mae_closed_form.csv"))?;
    Ok(table)
}

/// Hardware sweep row shared by Fig. 3a/3b.
fn hw_row(n: u32, variant: &str, resource_name: &str, h: &HwFigures) -> Vec<String> {
    let _ = resource_name;
    vec![
        n.to_string(),
        variant.to_string(),
        f(h.resource),
        h.ffs.to_string(),
        f(h.period_ns),
        f(h.latency_ns),
        f(h.dyn_power_mw),
        f(h.total_power_mw()),
    ]
}

/// Result pair for one bit-width of the hardware sweep.
pub struct HwPair {
    /// Operand bit-width.
    pub n: u32,
    /// The accurate reference's figures.
    pub accurate: HwFigures,
    /// The approximate design's figures.
    pub approx: HwFigures,
}

/// Run the Fig. 3 sweep (t = n/2, fix enabled, per the paper) on either
/// technology. Power fairness: both designs are clocked at the *accurate*
/// design's minimum period (the paper pins a common clock per n).
pub fn hw_sweep(cfg: &Config, fpga: bool) -> Vec<HwPair> {
    let mut out = Vec::new();
    for &n in &cfg.hw_bitwidths {
        let acc = seq_mult(n, 0, false);
        let apx = seq_mult(n, n / 2, true);
        let acc_act = measure_activity(&acc, cfg.hw_vectors, cfg.seed ^ n as u64, false);
        let apx_act = measure_activity(&apx, cfg.hw_vectors, cfg.seed ^ n as u64, true);
        let cycles = n + 1;
        let (a_fig, x_fig) = if fpga {
            let m = FpgaModel::default();
            let a = m.evaluate(&acc.nl, &acc_act, cycles, None);
            // pin approx power clock to the accurate period; latency keeps
            // its own achievable period (reported via period_ns).
            let x = m.evaluate(&apx.nl, &apx_act, cycles, Some(a.figures.period_ns));
            let mut xf = x.figures.clone();
            xf.latency_ns = cycles as f64 * xf.period_ns; // achievable latency
            (a.figures, xf)
        } else {
            let m = AsicModel::default();
            let a = m.evaluate(&acc.nl, &acc_act, cycles, None);
            let x = m.evaluate(&apx.nl, &apx_act, cycles, Some(a.figures.period_ns));
            let mut xf = x.figures.clone();
            xf.latency_ns = cycles as f64 * xf.period_ns;
            (a.figures, xf)
        };
        out.push(HwPair { n, accurate: a_fig, approx: x_fig });
    }
    out
}

/// E4 / Fig. 3a: FPGA LUTs, latency, power.
pub fn fig3a(cfg: &Config) -> Result<Table> {
    let mut table = Table::new(&[
        "n", "variant", "luts", "ffs", "period_ns", "latency_ns", "dyn_power_mw", "total_power_mw",
    ]);
    for pair in hw_sweep(cfg, true) {
        table.row(hw_row(pair.n, "accurate", "luts", &pair.accurate));
        table.row(hw_row(pair.n, "approx_t_n2", "luts", &pair.approx));
    }
    table.write(&cfg.results_dir.join("fig3a_fpga.csv"))?;
    Ok(table)
}

/// E5 / Fig. 3b: ASIC area, latency, power.
pub fn fig3b(cfg: &Config) -> Result<Table> {
    let mut table = Table::new(&[
        "n", "variant", "area_um2", "ffs", "period_ns", "latency_ns", "dyn_power_mw",
        "total_power_mw",
    ]);
    for pair in hw_sweep(cfg, false) {
        table.row(hw_row(pair.n, "accurate", "area", &pair.accurate));
        table.row(hw_row(pair.n, "approx_t_n2", "area", &pair.approx));
    }
    table.write(&cfg.results_dir.join("fig3b_asic.csv"))?;
    Ok(table)
}

/// E7 / §V-D headline claims, derived from a hardware sweep.
pub fn headline(cfg: &Config) -> Result<Table> {
    let mut table = Table::new(&[
        "target", "latency_reduction_avg_pct", "latency_reduction_max_pct", "max_at_n",
        "power_overhead_avg_pct", "resource_overhead_avg_pct", "paper_latency_avg_pct",
        "paper_latency_max_pct",
    ]);
    for (name, fpga, paper_avg, paper_max) in
        [("fpga", true, 19.15, 29.0), ("asic", false, 16.1, 34.14)]
    {
        let pairs = hw_sweep(cfg, fpga);
        let mut lat_reds = Vec::new();
        let mut pow_ovh = Vec::new();
        let mut res_ovh = Vec::new();
        let mut max_red = (0.0f64, 0u32);
        for p in &pairs {
            let red = 100.0 * (1.0 - p.approx.latency_ns / p.accurate.latency_ns);
            lat_reds.push(red);
            if red > max_red.0 {
                max_red = (red, p.n);
            }
            pow_ovh
                .push(100.0 * (p.approx.total_power_mw() / p.accurate.total_power_mw() - 1.0));
            res_ovh.push(100.0 * (p.approx.resource / p.accurate.resource - 1.0));
        }
        let avg = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        table.row(vec![
            name.to_string(),
            f(avg(&lat_reds)),
            f(max_red.0),
            max_red.1.to_string(),
            f(avg(&pow_ovh)),
            f(avg(&res_ovh)),
            f(paper_avg),
            f(paper_max),
        ]);
    }
    table.write(&cfg.results_dir.join("headline_claims.csv"))?;
    Ok(table)
}

/// E6 / §V-B: probability-propagation estimator vs exhaustive ground truth.
pub fn probprop_accuracy(cfg: &Config) -> Result<Table> {
    let mut table = Table::new(&[
        "n", "t", "er_exact", "er_estimate", "er_rel_err", "med_exact", "med_estimate",
        "fix_prob_exact_ish", "fix_prob_estimate",
    ]);
    for n in 4..=cfg.exhaustive_max_n.min(10) {
        for t in 1..=n / 2 {
            let exact = exhaustive_stats(n, t, false).metrics()?;
            let lat = probprop::propagate(n, t);
            let er_est = lat.er_estimate();
            let med_est = lat.med_estimate();
            let rel = if exact.er > 0.0 { (er_est - exact.er).abs() / exact.er } else { 0.0 };
            // "exact-ish" fix trigger rate: fraction of inputs where fix
            // changes the output (cheap exhaustive count).
            let fixdiff = {
                let total = 1u64 << (2 * n);
                let mut c = 0u64;
                for idx in 0..total {
                    let a = idx & ((1 << n) - 1);
                    let b = idx >> n;
                    if crate::multiplier::approx_seq_mul(a, b, n, t, true)
                        != crate::multiplier::approx_seq_mul(a, b, n, t, false)
                    {
                        c += 1;
                    }
                }
                c as f64 / total as f64
            };
            table.row(vec![
                n.to_string(),
                t.to_string(),
                f(exact.er),
                f(er_est),
                f(rel),
                f(exact.med_signed),
                f(med_est),
                f(fixdiff),
                f(lat.fix_probability()),
            ]);
        }
    }
    table.write(&cfg.results_dir.join("probprop_accuracy.csv"))?;
    Ok(table)
}

/// E8 / §III: sequential vs combinational resource crossover.
pub fn seqcomb(cfg: &Config) -> Result<Table> {
    use crate::netlist::generators::array_mult::array_mult;
    let mut table = Table::new(&[
        "n", "seq_gates", "seq_ffs", "array_gates", "seq_luts", "array_luts", "seq_smaller",
    ]);
    for &n in &[4u32, 8, 16, 32, 64] {
        let seq = seq_mult(n, 0, false);
        let arr = array_mult(n);
        let seq_luts = crate::tech::fpga::pack_luts(&seq.nl).luts;
        let arr_luts = crate::tech::fpga::pack_luts(&arr).luts;
        table.row(vec![
            n.to_string(),
            seq.nl.gate_count().to_string(),
            seq.nl.ff_count().to_string(),
            arr.gate_count().to_string(),
            seq_luts.to_string(),
            arr_luts.to_string(),
            (seq_luts < arr_luts).to_string(),
        ]);
    }
    table.write(&cfg.results_dir.join("seqcomb_crossover.csv"))?;
    Ok(table)
}

/// E10 / tune: the accuracy × latency trade-off scatter behind `segmul
/// tune` — every paper-grid point at the hardware bit-widths, answered
/// in closed form (zero simulation), with the non-dominated set flagged
/// in the `frontier` column. The budget columns use the headline
/// MRED ≤ 1e-3 target; the frontier itself is budget-independent.
pub fn pareto_fig(cfg: &Config) -> Result<Table> {
    use crate::api::Session;
    use crate::coordinator::AnalyticMode;
    use crate::tune::{tune, Budget, TuneQuery};
    let query = TuneQuery::new(Budget::mred(1e-3))
        .bitwidths(cfg.hw_bitwidths.clone())
        .workload(cfg.exhaustive_max_n, cfg.mc_samples)
        .hw_vectors(cfg.hw_vectors)
        .hw_seed(cfg.seed);
    let mut session = Session::builder().workers(1).analytic(AnalyticMode::Require).build()?;
    let result = tune(&mut session, &query)?;
    let table = result.points_table();
    table.write(&cfg.results_dir.join("pareto_tradeoff.csv"))?;
    Ok(table)
}

/// Write a markdown snippet summarizing a table (used by EXPERIMENTS.md
/// regeneration).
pub fn write_markdown(path: &Path, title: &str, table: &Table) -> Result<()> {
    let mut md = format!("## {title}\n\n```\n{}\n```\n", table.to_text());
    md.push('\n');
    crate::util::fsio::write_atomic(path, md.as_bytes())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CpuBackend;

    fn tiny_cfg() -> Config {
        let mut c = Config::default();
        c.results_dir = std::env::temp_dir().join("segmul_fig_test");
        c.error_bitwidths = vec![6];
        c.hw_bitwidths = vec![4, 8];
        c.hw_vectors = 64;
        c.mc_samples = 1 << 12;
        c.exhaustive_max_n = 8;
        c
    }

    #[test]
    fn fig2_produces_rows_and_csv() {
        let cfg = tiny_cfg();
        let mut be = CpuBackend::new();
        let t = fig2(&cfg, &mut be).unwrap();
        // 2 segmul variants x t in {2,3} + 4 baselines (6 not pow2 -> no kulkarni)
        assert!(t.rows.len() >= 8, "{}", t.rows.len());
        assert!(cfg.results_dir.join("fig2_error_metrics.csv").exists());
    }

    #[test]
    fn mae_table_confirms_correction() {
        let cfg = tiny_cfg();
        let t = mae_table(&cfg).unwrap();
        // every row: closed_matches == true, eq11_matches == false, and the
        // tight fix envelope dominates the measured fix MAE.
        for row in &t.rows {
            assert_eq!(row[8], "true", "closed form must match measurement");
            assert_eq!(row[7], "false", "Eq.11 understates (paper discrepancy)");
            assert_eq!(row[9], "true", "fix envelope must dominate measurement");
        }
    }

    #[test]
    fn hw_sweep_latency_reduction() {
        let cfg = tiny_cfg();
        for pair in hw_sweep(&cfg, true) {
            assert!(pair.approx.latency_ns < pair.accurate.latency_ns, "n={}", pair.n);
        }
        for pair in hw_sweep(&cfg, false) {
            assert!(pair.approx.latency_ns < pair.accurate.latency_ns, "n={}", pair.n);
        }
    }

    #[test]
    fn pareto_fig_scatter_flags_a_frontier() {
        let cfg = tiny_cfg();
        let t = pareto_fig(&cfg).unwrap();
        assert_eq!(t.rows.len(), 24, "paper grid at n=4,8: 2n points each");
        let fcol = t.header.iter().position(|h| h == "frontier").unwrap();
        assert!(t.rows.iter().any(|r| r[fcol] == "true"));
        assert!(cfg.results_dir.join("pareto_tradeoff.csv").exists());
    }

    #[test]
    fn seqcomb_crossover_shape() {
        let cfg = tiny_cfg();
        let t = seqcomb(&cfg).unwrap();
        // paper: combinational smaller below n=8, sequential wins large n.
        let last = t.rows.last().unwrap();
        assert_eq!(last[6], "true", "sequential must be smaller at n=64");
    }
}
