//! Sweep reporting: metric table, CSV, and the `BENCH_sweep.json`
//! machine-readable summary consumed by the CI bench-regression gate and
//! by downstream plotting.

use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::Result;

use crate::coordinator::sweep::SweepOutcome;
use crate::coordinator::WorkSpec;
use crate::util::json::{obj, Json};

use super::csv::{f, Table};

fn workload_name(spec: &WorkSpec) -> &'static str {
    match spec {
        WorkSpec::Exhaustive => "exhaustive",
        WorkSpec::MonteCarlo { .. } => "mc",
        WorkSpec::Adaptive { .. } => "adaptive",
    }
}

/// Render the per-config metric table (also the CSV layout). The `t` and
/// `fix` columns are the segmented-family configuration axes; designs
/// without them (baselines, accurate) carry `-`. The `source` column
/// distinguishes `simulated` rows from O(1) `analytic` answers (which
/// carry no throughput or per-bit BER — rendered `-`). With
/// `deterministic` set every timing-derived cell renders `-`, so two
/// runs producing the same statistics produce byte-identical CSVs (the
/// resume gauntlet's compare surface). Errs (typed `Stats`, surfaced
/// through anyhow) only on an empty accumulator, which the drivers never
/// produce.
pub fn sweep_table(outcomes: &[SweepOutcome], deterministic: bool) -> Result<Table> {
    let mut table = Table::new(&[
        "design",
        "n",
        "t",
        "fix",
        "workload",
        "samples",
        "er",
        "med_abs",
        "mae",
        "nmed",
        "mred",
        "mean_ber",
        "mpairs_per_s",
        "cached",
        "source",
    ]);
    for o in outcomes {
        let m = o.metrics()?;
        let mean_ber = m.mean_ber();
        table.row(vec![
            o.job.design.name(),
            o.job.n().to_string(),
            o.job.design.split_point().map(|t| t.to_string()).unwrap_or_else(|| "-".into()),
            o.job.design.fix_mode().map(|f| f.to_string()).unwrap_or_else(|| "-".into()),
            workload_name(&o.job.spec).to_string(),
            m.samples.to_string(),
            f(m.er),
            f(m.med_abs),
            m.mae.to_string(),
            f(m.nmed),
            f(m.mred),
            if mean_ber.is_nan() { "-".into() } else { f(mean_ber) },
            match o.result() {
                Some(r) if !deterministic => f(r.throughput() / 1e6),
                _ => "-".into(),
            },
            o.cached.to_string(),
            o.source().to_string(),
        ]);
    }
    Ok(table)
}

/// Aggregate run facts for the JSON summary.
pub struct SweepRunInfo {
    /// Worker threads used.
    pub workers: usize,
    /// Grid points served from the in-memory cache.
    pub cache_hits: u64,
    /// Grid points actually evaluated.
    pub jobs_evaluated: u64,
    /// Grid points served by closed-form analytic models instead of
    /// simulation (counted separately from `cache_hits`).
    pub analytic_answers: u64,
    /// Grid points answered from the persistent result store's committed
    /// blobs (counted separately from `cache_hits`).
    pub store_hits: u64,
    /// Total sweep wall time.
    pub wall: Duration,
    /// Backend name.
    pub backend: String,
    /// Kernel-dispatch audit: `(design name, dispatch class name)` per
    /// evaluated design (`batched` / `pjrt` / `scalar`), so the shipped
    /// `BENCH_sweep.json` itself proves which tier every design ran on.
    pub kernel_dispatch: Vec<(String, String)>,
    /// Deterministic-report mode (`--deterministic-report`): drop every
    /// field that depends on timing or on *where* answers came from
    /// (wall clocks, throughput, evaluated/hit counters, dispatch audit,
    /// worker count), keeping only the statistics surface — so an
    /// uninterrupted run, a kill-and-resume run, and an N-process
    /// sharded merge over the same grid emit **byte-identical** reports.
    pub deterministic: bool,
}

/// Build the `BENCH_sweep.json` document: run totals (what the CI gate
/// reads) plus the full per-config result array.
pub fn sweep_json(outcomes: &[SweepOutcome], info: &SweepRunInfo) -> Result<Json> {
    // Cached and analytic configs cost no evaluation time: throughput
    // totals count fresh simulated runs only.
    let pairs: u64 =
        outcomes.iter().filter(|o| !o.cached).filter_map(|o| o.result()).map(|r| r.stats.count).sum();
    let busy: f64 = outcomes
        .iter()
        .filter(|o| !o.cached)
        .filter_map(|o| o.result())
        .map(|r| r.wall.as_secs_f64())
        .sum();
    let wall = info.wall.as_secs_f64();
    let mut results: Vec<Json> = Vec::with_capacity(outcomes.len());
    for o in outcomes {
        let m = o.metrics()?;
        let mean_ber = m.mean_ber();
        let mut fields = vec![
            ("design", Json::from(o.job.design.name().as_str())),
            ("n", Json::from(o.job.n() as u64)),
        ];
        if let Some(t) = o.job.design.split_point() {
            fields.push(("t", Json::from(t as u64)));
        }
        if let Some(fix) = o.job.design.fix_mode() {
            fields.push(("fix", Json::from(fix)));
        }
        fields.extend([
            ("workload", Json::from(workload_name(&o.job.spec))),
            ("samples", Json::from(m.samples)),
            ("er", Json::from(m.er)),
            ("med_abs", Json::from(m.med_abs)),
            ("mae", Json::from(m.mae)),
            ("nmed", Json::from(m.nmed)),
            ("mred", Json::from(m.mred)),
            // Analytic answers carry no per-bit BER accumulator: null.
            ("mean_ber", if mean_ber.is_nan() { Json::Null } else { Json::from(mean_ber) }),
        ]);
        if !info.deterministic {
            fields.push(("wall_s", Json::from(o.wall().as_secs_f64())));
        }
        fields.push(("cached", Json::from(o.cached)));
        fields.push(("source", Json::from(o.source())));
        results.push(obj(fields));
    }
    let mut doc = vec![
        ("bench", Json::from("sweep")),
        ("backend", Json::from(info.backend.as_str())),
        ("configs", Json::from(outcomes.len() as u64)),
        ("cache_hits", Json::from(info.cache_hits)),
        ("analytic_answers", Json::from(info.analytic_answers)),
        ("pairs_evaluated", Json::from(pairs)),
    ];
    if info.deterministic {
        doc.push(("deterministic", Json::from(true)));
    } else {
        let dispatch: std::collections::BTreeMap<String, Json> = info
            .kernel_dispatch
            .iter()
            .map(|(design, class)| (design.clone(), Json::from(class.as_str())))
            .collect();
        doc.extend([
            ("kernel_dispatch", Json::Obj(dispatch)),
            ("workers", Json::from(info.workers as u64)),
            ("jobs_evaluated", Json::from(info.jobs_evaluated)),
            ("store_hits", Json::from(info.store_hits)),
            ("wall_s", Json::from(wall)),
            ("eval_busy_s", Json::from(busy)),
            (
                "metrics",
                obj(vec![(
                    "sweep_mpairs_per_s",
                    Json::from(pairs as f64 / wall.max(1e-9) / 1e6),
                )]),
            ),
        ]);
    }
    doc.push(("results", Json::Arr(results)));
    Ok(obj(doc))
}

/// Write `sweep.csv` and `BENCH_sweep.json` into `results_dir`; returns
/// the two paths.
pub fn write_sweep_reports(
    results_dir: &Path,
    outcomes: &[SweepOutcome],
    info: &SweepRunInfo,
) -> Result<(PathBuf, PathBuf)> {
    let csv_path = results_dir.join("sweep.csv");
    sweep_table(outcomes, info.deterministic)?.write(&csv_path)?;
    let json_path = results_dir.join("BENCH_sweep.json");
    crate::util::fsio::write_atomic(&json_path, sweep_json(outcomes, info)?.to_string_pretty().as_bytes())?;
    Ok((csv_path, json_path))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{CpuBackend, EvalBackend, EvalJob, SweepGrid, SweepRunner};

    fn outcomes() -> (Vec<SweepOutcome>, SweepRunInfo) {
        let grid = SweepGrid {
            bitwidths: vec![4],
            designs: crate::multiplier::DesignSet::Paper,
            exhaustive_max_n: 6,
            force_mc: false,
            mc_samples: 1000,
            seed: 1,
        };
        let mut runner =
            SweepRunner::new(|| Ok(Box::new(CpuBackend::new()) as Box<dyn EvalBackend>), 1)
                .unwrap();
        let outs = runner.run_grid(&grid, |_, _, _| {}).unwrap();
        let info = SweepRunInfo {
            workers: 1,
            cache_hits: runner.cache_hits,
            jobs_evaluated: runner.jobs_evaluated,
            analytic_answers: runner.analytic_answers,
            store_hits: runner.store_hits,
            wall: Duration::from_millis(10),
            backend: "cpu".into(),
            kernel_dispatch: runner
                .pool()
                .kernel_dispatch()
                .into_iter()
                .map(|(design, class)| (design, class.name().to_string()))
                .collect(),
            deterministic: false,
        };
        (outs, info)
    }

    #[test]
    fn table_has_one_row_per_config() {
        let (outs, _) = outcomes();
        let table = sweep_table(&outs, false).unwrap();
        assert_eq!(table.rows.len(), outs.len());
        assert_eq!(table.header.len(), table.rows[0].len());
        // Simulated rows carry the simulated source tag.
        assert!(table.rows.iter().all(|r| r.last().map(String::as_str) == Some("simulated")));
    }

    #[test]
    fn json_roundtrips_and_carries_totals() {
        let (outs, info) = outcomes();
        let j = sweep_json(&outs, &info).unwrap();
        let parsed = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(parsed.get("bench").unwrap().as_str(), Some("sweep"));
        assert_eq!(parsed.get("configs").unwrap().as_u64(), Some(outs.len() as u64));
        assert_eq!(parsed.get("cache_hits").unwrap().as_u64(), Some(info.cache_hits));
        assert!(parsed.get("metrics").unwrap().get("sweep_mpairs_per_s").is_some());
        // The dispatch audit ships with the summary: the paper grid runs
        // on batch kernels under the CPU backend.
        let dispatch = parsed.get("kernel_dispatch").unwrap();
        assert_eq!(
            dispatch.get("segmul(n=4,t=1,fix)").and_then(|c| c.as_str()),
            Some("batched")
        );
        let results = parsed.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), outs.len());
        assert_eq!(results[0].get("workload").unwrap().as_str(), Some("exhaustive"));
        // Cross-design identification: every row names its design.
        assert_eq!(
            results[0].get("design").unwrap().as_str(),
            Some(outs[0].job.design.name().as_str())
        );
    }

    #[test]
    fn reports_written_to_disk() {
        let (outs, info) = outcomes();
        let dir = std::env::temp_dir().join(format!("segmul_sweep_report_{}", std::process::id()));
        let (csv, json) = write_sweep_reports(&dir, &outs, &info).unwrap();
        let csv_text = std::fs::read_to_string(&csv).unwrap();
        assert!(csv_text.starts_with("design,n,t,fix,workload"));
        let parsed = Json::parse(&std::fs::read_to_string(&json).unwrap()).unwrap();
        assert_eq!(parsed.get("bench").unwrap().as_str(), Some("sweep"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cached_outcomes_excluded_from_throughput_totals() {
        let (mut outs, info) = outcomes();
        let pairs_fresh =
            outs.iter().map(|o| o.result().unwrap().stats.count).sum::<u64>();
        // Duplicate every outcome as a cache hit: totals must not change.
        let dupes: Vec<SweepOutcome> = outs
            .iter()
            .map(|o| SweepOutcome { cached: true, ..o.clone() })
            .collect();
        outs.extend(dupes);
        let j = sweep_json(&outs, &info).unwrap();
        assert_eq!(j.get("pairs_evaluated").unwrap().as_u64(), Some(pairs_fresh));
        assert_eq!(j.get("configs").unwrap().as_u64(), Some(outs.len() as u64));
    }

    #[test]
    fn analytic_rows_render_without_throughput_or_ber() {
        use crate::coordinator::AnalyticMode;
        let grid = SweepGrid {
            bitwidths: vec![8],
            designs: crate::multiplier::DesignSet::Baselines,
            exhaustive_max_n: 8,
            force_mc: false,
            mc_samples: 1000,
            seed: 1,
        };
        let mut runner =
            SweepRunner::new(|| Ok(Box::new(CpuBackend::new()) as Box<dyn EvalBackend>), 1)
                .unwrap();
        runner.set_analytic_mode(AnalyticMode::Auto);
        let outs = runner.run_grid(&grid, |_, _, _| {}).unwrap();
        let info = SweepRunInfo {
            workers: 1,
            cache_hits: runner.cache_hits,
            jobs_evaluated: runner.jobs_evaluated,
            analytic_answers: runner.analytic_answers,
            store_hits: runner.store_hits,
            wall: Duration::from_millis(10),
            backend: "cpu".into(),
            kernel_dispatch: vec![],
            deterministic: false,
        };
        assert!(info.analytic_answers > 0);
        let table = sweep_table(&outs, false).unwrap();
        let src = table.header.iter().position(|h| h == "source").unwrap();
        let tput = table.header.iter().position(|h| h == "mpairs_per_s").unwrap();
        let ber = table.header.iter().position(|h| h == "mean_ber").unwrap();
        let analytic_rows: Vec<_> =
            table.rows.iter().filter(|r| r[src] == "analytic").collect();
        assert_eq!(analytic_rows.len() as u64, info.analytic_answers);
        for row in &analytic_rows {
            assert_eq!(row[tput], "-");
            assert_eq!(row[ber], "-");
        }
        let j = sweep_json(&outs, &info).unwrap();
        assert_eq!(
            j.get("analytic_answers").unwrap().as_u64(),
            Some(info.analytic_answers)
        );
        let results = j.get("results").unwrap().as_arr().unwrap();
        let analytic_json: Vec<_> = results
            .iter()
            .filter(|r| r.get("source").and_then(|s| s.as_str()) == Some("analytic"))
            .collect();
        assert_eq!(analytic_json.len() as u64, info.analytic_answers);
        for r in analytic_json {
            assert!(matches!(r.get("mean_ber"), Some(Json::Null)));
            assert!(r.get("er").unwrap().as_f64().is_some());
        }
    }

    #[test]
    fn deterministic_reports_omit_volatile_fields() {
        let (outs, mut info) = outcomes();
        info.deterministic = true;
        // CSV: the throughput column is the only timing-derived cell.
        let table = sweep_table(&outs, true).unwrap();
        let tput = table.header.iter().position(|h| h == "mpairs_per_s").unwrap();
        assert!(table.rows.iter().all(|r| r[tput] == "-"));
        // JSON: everything timing- or provenance-dependent is gone...
        let j = sweep_json(&outs, &info).unwrap();
        for volatile in
            ["wall_s", "eval_busy_s", "jobs_evaluated", "store_hits", "kernel_dispatch", "workers", "metrics"]
        {
            assert!(j.get(volatile).is_none(), "{volatile} must be omitted");
        }
        assert_eq!(j.get("deterministic").and_then(Json::as_bool), Some(true));
        let results = j.get("results").unwrap().as_arr().unwrap();
        assert!(results.iter().all(|r| r.get("wall_s").is_none()));
        // ...while the statistics surface stays intact and stable.
        assert_eq!(j.get("configs").unwrap().as_u64(), Some(outs.len() as u64));
        assert!(j.get("cache_hits").is_some());
        assert!(j.get("pairs_evaluated").is_some());
        assert!(results.iter().all(|r| r.get("er").is_some() && r.get("cached").is_some()));
        // Byte determinism of the rendering itself: serialize twice.
        assert_eq!(j.to_string_pretty(), sweep_json(&outs, &info).unwrap().to_string_pretty());
    }

    #[test]
    fn workload_names() {
        assert_eq!(workload_name(&EvalJob::exhaustive(4, 1, false).spec), "exhaustive");
        assert_eq!(workload_name(&EvalJob::mc(8, 1, false, 10, 1).spec), "mc");
    }
}
