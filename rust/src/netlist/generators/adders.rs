//! Ripple-carry adder generators (the arithmetic building block).
//!
//! Full adder per bit: `sum = a ⊕ b ⊕ cin`, `cout = ((a ⊕ b) ∧ cin) ∨ (a ∧ b)`
//! — 2 XOR + 2 AND + 1 OR, the classic 5-gate cell. Carry-out nets are
//! returned so callers can tag them as a carry chain (dedicated fast logic
//! on the FPGA model; the latency-critical path on both technologies).

use crate::netlist::graph::{Net, NetlistBuilder};

/// One full adder; returns `(sum, cout, carry_internals)`. The internals
/// (`g`, `p∧cin`, `cout`, `sum`) live in the dedicated carry logic on the
/// FPGA target (CARRY4 muxes + XORCY); the propagate XOR `a⊕b` is the
/// per-bit LUT function.
pub fn full_adder(b: &mut NetlistBuilder, a: Net, bb: Net, cin: Net) -> (Net, Net, [Net; 4]) {
    let axb = b.xor2(a, bb);
    let sum = b.xor2(axb, cin);
    let g = b.and2(a, bb);
    let p_and_c = b.and2(axb, cin);
    let cout = b.or2(p_and_c, g);
    (sum, cout, [g, p_and_c, cout, sum])
}

/// Ripple-carry adder over equal-width buses; returns
/// `(sums, cout, carry_nets, members)`. `carry_nets` holds the per-bit
/// carry-outs (LSB first); `members` every gate inside the carry logic —
/// pass both to `tag_carry_chain_full`.
pub fn ripple_adder(
    b: &mut NetlistBuilder,
    a_bits: &[Net],
    b_bits: &[Net],
    cin: Net,
) -> (Vec<Net>, Net, Vec<Net>, Vec<Net>) {
    assert_eq!(a_bits.len(), b_bits.len());
    assert!(!a_bits.is_empty());
    let mut sums = Vec::with_capacity(a_bits.len());
    let mut carries = Vec::with_capacity(a_bits.len());
    let mut members = Vec::with_capacity(4 * a_bits.len());
    let mut c = cin;
    for (&ai, &bi) in a_bits.iter().zip(b_bits) {
        let (s, co, internals) = full_adder(b, ai, bi, c);
        sums.push(s);
        carries.push(co);
        members.extend_from_slice(&internals);
        c = co;
    }
    (sums, c, carries, members)
}

/// Standalone n-bit adder netlist (for unit tests and calibration).
pub fn rca_netlist(n: u32) -> crate::netlist::graph::Netlist {
    let mut b = NetlistBuilder::new(&format!("rca{n}"));
    let a = b.input_bus(n);
    let bb = b.input_bus(n);
    let zero = b.constant(false);
    let (sums, cout, chain, members) = ripple_adder(&mut b, &a, &bb, zero);
    b.tag_carry_chain_full("rca", &chain, &members);
    for (i, s) in sums.iter().enumerate() {
        b.output(&format!("s[{i}]"), *s);
    }
    b.output("cout", cout);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::sim::eval_comb;
    use crate::util::prop::Cases;

    fn add_via_netlist(n: u32, x: u64, y: u64) -> u64 {
        let nl = rca_netlist(n);
        let mut inputs = Vec::new();
        for i in 0..n {
            inputs.push(if (x >> i) & 1 == 1 { u64::MAX } else { 0 });
        }
        for i in 0..n {
            inputs.push(if (y >> i) & 1 == 1 { u64::MAX } else { 0 });
        }
        let vals = eval_comb(&nl, &inputs, &[]);
        let mut out = 0u64;
        for i in 0..n {
            let net = nl.find_output(&format!("s[{i}]")).unwrap();
            out |= (vals[net.0 as usize] & 1) << i;
        }
        let cout = nl.find_output("cout").unwrap();
        out |= (vals[cout.0 as usize] & 1) << n;
        out
    }

    #[test]
    fn adds_exhaustive_4bit() {
        for x in 0..16u64 {
            for y in 0..16u64 {
                assert_eq!(add_via_netlist(4, x, y), x + y);
            }
        }
    }

    #[test]
    fn prop_adds_random_wide() {
        Cases::new(0xADD, 60).run(|rng, _| {
            let n = 1 + rng.next_below(32) as u32;
            let x = rng.next_bits(n);
            let y = rng.next_bits(n);
            assert_eq!(add_via_netlist(n, x, y), x + y, "n={n}");
        });
    }

    #[test]
    fn gate_count_is_5n() {
        let nl = rca_netlist(16);
        assert_eq!(nl.gate_count(), 5 * 16);
        assert_eq!(nl.carry_chains.len(), 1);
        assert_eq!(nl.carry_chains[0].couts.len(), 16);
    }

    #[test]
    fn chain_is_the_deep_path() {
        use crate::netlist::timing::{analyze, UnitDelay};
        // Critical path of an n-bit RCA grows linearly with n.
        let t8 = analyze(&rca_netlist(8), &UnitDelay).critical_path_ps;
        let t16 = analyze(&rca_netlist(16), &UnitDelay).critical_path_ps;
        let t32 = analyze(&rca_netlist(32), &UnitDelay).critical_path_ps;
        assert!(t16 > t8 && t32 > t16);
        assert!((t32 - t16) > 0.9 * (t16 - t8) * 2.0 - 1e-9);
    }
}
