//! Structural generators for the paper's circuits.
//!
//! * [`adders`]     — ripple-carry full-adder chains (the building block;
//!   carry chains are tagged for the technology models).
//! * [`seq_mult`]   — the sequential multipliers of Fig. 1: accurate (1a)
//!   and approximate with segmented carry chain, D-FF carry deferral,
//!   fix-to-1 muxes and the decrement/zero-detect controller (1b).
//! * [`array_mult`] — the combinational array multiplier of §III (the
//!   n-1-adder baseline motivating the sequential approach).

pub mod adders;
pub mod array_mult;
pub mod seq_mult;

pub use seq_mult::{seq_mult, SeqMultCircuit};

/// Pack per-operand values into per-bit 64-way words: `out[i]` holds bit i
/// of up to 64 values (vector v in lane v).
pub fn pack_bits_u512(values: &[crate::multiplier::U512], nbits: u32) -> Vec<u64> {
    assert!(values.len() <= 64);
    let mut words = vec![0u64; nbits as usize];
    for (lane, v) in values.iter().enumerate() {
        for (i, w) in words.iter_mut().enumerate() {
            if v.bit(i as u32) {
                *w |= 1u64 << lane;
            }
        }
    }
    words
}

/// Unpack per-bit words back into values (lane-major).
pub fn unpack_bits_u512(words: &[u64], lanes: usize) -> Vec<crate::multiplier::U512> {
    assert!(lanes <= 64 && words.len() <= 512);
    let mut out = vec![crate::multiplier::U512::ZERO; lanes];
    for (i, w) in words.iter().enumerate() {
        for (lane, v) in out.iter_mut().enumerate() {
            if (w >> lane) & 1 == 1 {
                v.set_bit(i as u32);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multiplier::U512;

    #[test]
    fn pack_unpack_roundtrip() {
        let vals: Vec<U512> = (0..64u64).map(|i| U512::from_u64(i * 2654435761)).collect();
        let words = pack_bits_u512(&vals, 40);
        let back = unpack_bits_u512(&words, 64);
        for (orig, got) in vals.iter().zip(&back) {
            let masked = *orig & U512::mask_lo(40);
            assert_eq!(*got, masked);
        }
    }

    #[test]
    fn pack_partial_lanes() {
        let vals = vec![U512::from_u64(0b101), U512::from_u64(0b011)];
        let words = pack_bits_u512(&vals, 3);
        assert_eq!(words, vec![0b11, 0b10, 0b01]);
        let back = unpack_bits_u512(&words, 2);
        assert_eq!(back, vals);
    }
}
