//! Combinational array multiplier (§III, Table Ia).
//!
//! The n-1-adder grade-school architecture the paper contrasts against:
//! n² AND partial products accumulated row by row with ripple-carry
//! adders. Used for E8 (sequential-vs-combinational resource crossover).

use crate::netlist::graph::{Net, Netlist, NetlistBuilder};

use super::adders::ripple_adder;

/// Build the n×n combinational array multiplier (2n-bit product).
pub fn array_mult(n: u32) -> Netlist {
    assert!(n >= 2);
    let mut b = NetlistBuilder::new(&format!("arraymul_n{n}"));
    let a = b.input_bus(n);
    let bb = b.input_bus(n);
    let zero = b.constant(false);

    // Partial-product rows: pp[j][i] = a_i ∧ b_j.
    let rows: Vec<Vec<Net>> = (0..n as usize)
        .map(|j| a.iter().map(|&ai| b.and2(ai, bb[j])).collect())
        .collect();

    // Row-by-row accumulation. Invariant entering round j: `acc` holds the
    // partial sum of rows 0..j shifted so acc[0] has product weight
    // 2^{j-1}; product bit 2^{j-1} is finalized by retiring acc[0], and
    // the rest is added to row j.
    let mut product: Vec<Net> = Vec::with_capacity(2 * n as usize);
    let mut acc: Vec<Net> = rows[0].clone(); // rows 0 sum; acc[0] = p_0
    for (j, row) in rows.iter().enumerate().skip(1) {
        product.push(acc[0]); // finalize p_{j-1}
        // augend = acc >> 1, zero-padded to the row width.
        let mut augend: Vec<Net> = acc[1..].to_vec();
        while augend.len() < row.len() {
            augend.push(zero);
        }
        let (mut sums, cout, chain, members) = ripple_adder(&mut b, &augend, row, zero);
        b.tag_carry_chain_full(&format!("row{j}"), &chain, &members);
        sums.push(cout);
        acc = sums; // n+1 bits: weights 2^j .. 2^{j+n}
        // next round's row must be padded to acc[1..].len() = n — rows are
        // exactly n bits, and augend drops back to n via the shift: OK.
    }
    // After the last row (j = n-1): acc holds product bits n-1 .. 2n-1.
    product.extend(acc.iter().copied());
    assert_eq!(product.len(), 2 * n as usize);

    for (r, net) in product.iter().enumerate() {
        b.output(&format!("p[{r}]"), *net);
    }
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::sim::eval_comb;
    use crate::util::prop::Cases;

    fn mul_via_netlist(nl: &Netlist, n: u32, x: u64, y: u64) -> u64 {
        let mut inputs = Vec::new();
        for i in 0..n {
            inputs.push(if (x >> i) & 1 == 1 { u64::MAX } else { 0 });
        }
        for i in 0..n {
            inputs.push(if (y >> i) & 1 == 1 { u64::MAX } else { 0 });
        }
        let vals = eval_comb(nl, &inputs, &[]);
        let mut out = 0u64;
        for r in 0..2 * n {
            let net = nl.find_output(&format!("p[{r}]")).unwrap();
            out |= (vals[net.0 as usize] & 1) << r;
        }
        out
    }

    #[test]
    fn exhaustive_4bit() {
        let nl = array_mult(4);
        for x in 0..16u64 {
            for y in 0..16u64 {
                assert_eq!(mul_via_netlist(&nl, 4, x, y), x * y, "{x}*{y}");
            }
        }
    }

    #[test]
    fn prop_random_up_to_16() {
        Cases::new(0xA77, 40).run(|rng, _| {
            let n = 2 + rng.next_below(15) as u32;
            let nl = array_mult(n);
            let x = rng.next_bits(n);
            let y = rng.next_bits(n);
            assert_eq!(mul_via_netlist(&nl, n, x, y), x * y, "n={n} {x}*{y}");
        });
    }

    #[test]
    fn area_scales_quadratically() {
        // n² partial products dominate: gates(2n) / gates(n) ≈ 4.
        let g8 = array_mult(8).gate_count() as f64;
        let g16 = array_mult(16).gate_count() as f64;
        let ratio = g16 / g8;
        assert!(ratio > 3.0 && ratio < 5.0, "ratio {ratio}");
    }

    #[test]
    fn no_flip_flops() {
        assert_eq!(array_mult(8).ff_count(), 0);
    }
}
