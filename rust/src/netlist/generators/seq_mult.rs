//! The sequential multipliers of Fig. 1.
//!
//! Accurate (Fig. 1a): shift registers A (n bits + carry D-FF) and B
//! (n bits), one n-bit ripple adder. Per clock: the adder sums the
//! right-shifted previous accumulation `x = {C_FF, A[n-1:1]}` with the
//! partial product `a ∧ B[0]`; A latches the sum, C_FF the carry-out, and
//! B shifts right taking `A[0]` (the retiring product bit) from the left.
//!
//! Approximate (Fig. 1b): the adder's carry chain is segmented at bit `t`
//! — a t-bit LSP adder whose carry-out feeds a D flip-flop, and an
//! (n-t)-bit MSP adder whose carry-in is the FF's *previous-cycle* value.
//! A decrement unit (down counter + zero detect) raises `last` in the
//! final cycle; when the final LSP carry-out is 1 and fix-to-1 is enabled,
//! multiplexers force the n+t product LSBs to 1 (registers B and A[t:0]).
//!
//! The generated netlist is cycle-accurate against the word-level software
//! model for every n, t, fix (see `netlist_integration`).

use crate::multiplier::U512;
use crate::netlist::graph::{Net, Netlist, NetlistBuilder};
use crate::netlist::sim::SeqSim;

use super::adders::ripple_adder;
use super::{pack_bits_u512, unpack_bits_u512};

/// A generated sequential multiplier with its interface map.
pub struct SeqMultCircuit {
    /// The generated netlist.
    pub nl: Netlist,
    /// Operand bit-width.
    pub n: u32,
    /// Splitting point; 0 = accurate (no segmentation hardware).
    pub t: u32,
    /// Whether the fix-to-1 muxes were generated.
    pub has_fix: bool,
    /// Output nets of the product bits, LSB first (length 2n; read after
    /// a combinational settle following the final clock).
    product_nets: Vec<crate::netlist::graph::Net>,
}

/// Input ordering: `a[0..n)`, `b[0..n)`, `load`, `fix_mode`.
const fn input_count(n: u32) -> usize {
    (2 * n + 2) as usize
}

/// Generate the sequential multiplier. `t = 0` produces the accurate
/// design of Fig. 1a (no LSP FF, no muxes, but the same controller).
pub fn seq_mult(n: u32, t: u32, with_fix: bool) -> SeqMultCircuit {
    assert!(n >= 2, "need n >= 2");
    assert!(t < n, "t must be in [0, n)");
    assert!(!(with_fix && t == 0), "fix-to-1 requires a segmented chain (t >= 1)");
    let mut b = NetlistBuilder::new(&format!("seqmul_n{n}_t{t}{}", if with_fix { "_fix" } else { "" }));

    // ---- primary inputs ----------------------------------------------
    let a_in = b.input_bus(n);
    let b_in = b.input_bus(n);
    let load = b.input();
    let fix_mode = b.input();
    let zero = b.constant(false);
    let one = b.constant(true);

    // ---- state ---------------------------------------------------------
    let a_reg = b.ff_bus("A", n); // accumulated sum
    let c_ff = b.ff("Cout"); // adder carry-out delay FF
    let b_reg = b.ff_bus("B", n); // multiplicand / low product shift register
    let lsp_ff = if t >= 1 { Some(b.ff("ClspFF")) } else { None };

    // ---- decrement unit (down counter + zero detect -> `last`) ---------
    // Counts n-1 .. 0 across the n accumulation cycles; `last` is high in
    // the final cycle. The counter is log2ceil(n) bits.
    let cnt_w = 32 - (n - 1).leading_zeros().min(31);
    let cnt = b.ff_bus("cnt", cnt_w.max(1));
    // decrementer: cnt - 1 (ripple borrow: half subtractor per bit).
    // On the FPGA target this maps onto the dedicated carry chain, so the
    // borrow gates are tagged as chain members.
    let mut borrow = one; // subtracting 1 == borrow-in at bit 0
    let mut dec = Vec::with_capacity(cnt.len());
    let mut dec_couts = Vec::with_capacity(cnt.len());
    let mut dec_members = Vec::with_capacity(3 * cnt.len());
    for &bit in &cnt {
        let d = b.xor2(bit, borrow);
        let nb = b.not(bit);
        borrow = b.and2(nb, borrow);
        dec.push(d);
        dec_couts.push(borrow);
        dec_members.extend_from_slice(&[d, nb, borrow]);
    }
    b.tag_carry_chain_full("decrement", &dec_couts, &dec_members);
    // zero detect: balanced OR tree then NOT (packs into one LUT6 for
    // counters up to 6 bits): last = (cnt == 0)
    let mut layer: Vec<Net> = cnt.clone();
    while layer.len() > 1 {
        let mut next = Vec::with_capacity(layer.len().div_ceil(2));
        for pair in layer.chunks(2) {
            next.push(if pair.len() == 2 { b.or2(pair[0], pair[1]) } else { pair[0] });
        }
        layer = next;
    }
    let last = b.not(layer[0]);
    // counter next state: load ? n-1 : cnt-1
    for (i, (&q, &d)) in cnt.iter().zip(&dec).enumerate() {
        let init = if ((n - 1) >> i) & 1 == 1 { one } else { zero };
        let nxt = b.mux2(d, init, load);
        b.connect_ff(q, nxt);
    }

    // ---- datapath: shifted augend and partial product ------------------
    // x = {C_FF, A[n-1:1]}  (the "right-shifted once" adder input)
    let mut x = Vec::with_capacity(n as usize);
    for i in 0..n as usize {
        x.push(if i + 1 < n as usize { a_reg[i + 1] } else { c_ff });
    }
    // pp = a & B[0]
    let pp: Vec<Net> = a_in.iter().map(|&ai| b.and2(ai, b_reg[0])).collect();

    // ---- the (possibly segmented) accumulation adder --------------------
    let (sums, cout, clsp_comb) = if t == 0 {
        let (sums, cout, chain, members) = ripple_adder(&mut b, &x, &pp, zero);
        b.tag_carry_chain_full("acc", &chain, &members);
        (sums, cout, None)
    } else {
        let ti = t as usize;
        let (lsums, clsp, lchain, lmem) = ripple_adder(&mut b, &x[..ti], &pp[..ti], zero);
        b.tag_carry_chain_full("lsp", &lchain, &lmem);
        let ff = lsp_ff.unwrap();
        let (msums, cout, mchain, mmem) = ripple_adder(&mut b, &x[ti..], &pp[ti..], ff);
        b.tag_carry_chain_full("msp", &mchain, &mmem);
        let mut sums = lsums;
        sums.extend(msums);
        (sums, cout, Some(clsp))
    };

    // ---- fix-to-1 ------------------------------------------------------
    // The fix decision fires when the FINAL accumulation's LSP carry-out
    // is 1: fe = last ∧ fix_mode ∧ Ĉ_{t-1}^{n-1}. It is latched into a
    // dedicated D-FF at the final clock edge and applied on the READ-OUT
    // path (output-side multiplexing, Fig. 1b) — so the adder's shortened
    // carry chain, not the fix logic, sets the clock period.
    let fix_ff = match (with_fix, clsp_comb) {
        (true, Some(clsp)) => {
            let lf = b.and2(last, fix_mode);
            let fe = b.and2(lf, clsp);
            let q = b.ff("FixFF");
            let nl = b.not(load);
            let gated = b.and2(fe, nl); // cleared on load
            b.connect_ff(q, gated);
            Some(q)
        }
        _ => None,
    };

    // ---- register next-state logic --------------------------------------
    // A[i] <= load ? 0 : sum[i]
    for (i, &q) in a_reg.iter().enumerate() {
        let d = sums[i];
        let nl = b.not(load);
        let gated = b.and2(d, nl); // load clears A
        b.connect_ff(q, gated);
    }
    // C_FF <= load ? 0 : cout
    {
        let nl = b.not(load);
        let gated = b.and2(cout, nl);
        b.connect_ff(c_ff, gated);
    }
    // LSP FF <= load ? 0 : clsp (cleared on load so the first
    // accumulation sees a zero deferred carry)
    if let (Some(ff), Some(clsp)) = (lsp_ff, clsp_comb) {
        let nl = b.not(load);
        let gated = b.and2(clsp, nl);
        b.connect_ff(ff, gated);
    }
    // B[i] <= load ? b_in[i] : shift-right
    for (i, &q) in b_reg.iter().enumerate() {
        let shifted = if i + 1 < n as usize { b_reg[i + 1] } else { a_reg[0] };
        let with_load = b.mux2(shifted, b_in[i], load);
        b.connect_ff(q, with_load);
    }

    // ---- outputs ---------------------------------------------------------
    // Product: p[r] = B[r+1] for r < n-1; p[n-1+i] = A[i]; p[2n-1] = C_FF.
    // With fix-to-1, the n+t LSBs are OR-ed with the latched fix decision
    // (output-side multiplexing — one OR per affected product bit).
    let mut product_nets = Vec::with_capacity(2 * n as usize);
    for r in 0..(2 * n as usize) {
        let q = if r < n as usize - 1 {
            b_reg[r + 1]
        } else if r < 2 * n as usize - 1 {
            a_reg[r + 1 - n as usize]
        } else {
            c_ff
        };
        let out = match fix_ff {
            Some(ff) if (r as u32) < n + t => b.or2(q, ff),
            _ => q,
        };
        b.output(&format!("p[{r}]"), out);
        product_nets.push(out);
    }

    SeqMultCircuit { nl: b.build(), n, t, has_fix: with_fix, product_nets }
}

/// One batched run (≤ 64 operand pairs): load cycle + n accumulation
/// cycles, cycle-accurate. Returns the 2n-bit products.
pub fn run_batch(c: &SeqMultCircuit, sim: &mut SeqSim, a: &[U512], b: &[U512], fix: bool) -> Vec<U512> {
    assert!(a.len() == b.len() && a.len() <= 64);
    let n = c.n;
    let lanes = a.len();
    let a_words = pack_bits_u512(a, n);
    let b_words = pack_bits_u512(b, n);

    let mut inputs = vec![0u64; input_count(n)];
    inputs[..n as usize].copy_from_slice(&a_words);
    inputs[n as usize..2 * n as usize].copy_from_slice(&b_words);
    let fix_word = if fix && c.has_fix { u64::MAX } else { 0 };

    // load cycle
    inputs[2 * n as usize] = u64::MAX; // load
    inputs[2 * n as usize + 1] = fix_word;
    sim.step(&inputs);
    // n accumulation cycles (the counter supplies `last` internally)
    inputs[2 * n as usize] = 0;
    for _ in 0..n {
        sim.step(&inputs);
    }
    // settle the read-out logic (fix OR gates) and read the product nets
    sim.settle(&inputs);
    let words: Vec<u64> = c.product_nets.iter().map(|&net| sim.vals[net.0 as usize]).collect();
    unpack_bits_u512(&words, lanes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multiplier::wordlevel::{approx_seq_mul, approx_seq_mul_wide};
    use crate::util::prop::Cases;
    use crate::util::rng::Xoshiro256;

    fn check_against_word_model(n: u32, t: u32, fix: bool, trials: usize, seed: u64) {
        let c = seq_mult(n, t, t >= 1);
        let mut sim = SeqSim::new(&c.nl);
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let a: Vec<U512> = (0..trials).map(|_| U512::from_u64(rng.next_bits(n.min(63)))).collect();
        let b: Vec<U512> = (0..trials).map(|_| U512::from_u64(rng.next_bits(n.min(63)))).collect();
        let got = run_batch(&c, &mut sim, &a, &b, fix);
        for ((&ga, &gb), gp) in a.iter().zip(&b).zip(&got) {
            let want = approx_seq_mul_wide(&ga, &gb, n, t, fix);
            assert_eq!(*gp, want, "n={n} t={t} fix={fix} a={ga:?} b={gb:?}");
        }
    }

    #[test]
    fn accurate_matches_exact_products() {
        let c = seq_mult(8, 0, false);
        let mut sim = SeqSim::new(&c.nl);
        let a: Vec<U512> = (0..64u64).map(|i| U512::from_u64((i * 37) & 0xFF)).collect();
        let b: Vec<U512> = (0..64u64).map(|i| U512::from_u64((i * 91) & 0xFF)).collect();
        let got = run_batch(&c, &mut sim, &a, &b, false);
        for ((x, y), p) in a.iter().zip(&b).zip(&got) {
            assert_eq!(p.limb(0), x.limb(0) * y.limb(0));
        }
    }

    #[test]
    fn approx_matches_word_model_various_configs() {
        for (n, t) in [(4u32, 2u32), (6, 3), (8, 3), (8, 4), (12, 5)] {
            check_against_word_model(n, t, false, 64, n as u64 * 10 + t as u64);
            check_against_word_model(n, t, true, 64, n as u64 * 100 + t as u64);
        }
    }

    #[test]
    fn prop_random_configs() {
        Cases::new(0x5E9, 12).run(|rng, _| {
            let n = 3 + rng.next_below(14) as u32; // 3..=16
            let t = rng.next_below(n as u64) as u32;
            let fix = t >= 1 && rng.next_bits(1) == 1;
            check_against_word_model(n, t, fix, 32, rng.next_u64());
        });
    }

    #[test]
    fn wide_circuit_matches_wide_model() {
        // n = 40: beyond u64 products, exercises the U512 path end-to-end.
        let (n, t) = (40u32, 20u32);
        let c = seq_mult(n, t, true);
        let mut sim = SeqSim::new(&c.nl);
        let mut rng = Xoshiro256::seed_from_u64(77);
        let a: Vec<U512> = (0..16).map(|_| U512::from_u64(rng.next_bits(40))).collect();
        let b: Vec<U512> = (0..16).map(|_| U512::from_u64(rng.next_bits(40))).collect();
        for fix in [false, true] {
            let got = run_batch(&c, &mut sim, &a, &b, fix);
            for ((x, y), p) in a.iter().zip(&b).zip(&got) {
                assert_eq!(*p, approx_seq_mul_wide(x, y, n, t, fix));
            }
        }
    }

    #[test]
    fn fix_or_count_scales_with_t() {
        // Fix-to-1 instrumentation: n+t read-out OR gates (the paper's
        // "multiplexing of the least significant n+t bits") + the enable
        // ANDs + one FF — no multiplexers, nothing on the adder path.
        let plain = seq_mult(8, 4, false);
        let fixed = seq_mult(8, 4, true);
        let ph = plain.nl.gate_histogram();
        let fh = fixed.nl.gate_histogram();
        let extra_or = fh.get("OR2").unwrap_or(&0) - ph.get("OR2").unwrap_or(&0);
        assert_eq!(extra_or, (8 + 4) as usize);
        assert_eq!(
            fh.get("MUX2").unwrap_or(&0),
            ph.get("MUX2").unwrap_or(&0),
            "no extra muxes"
        );
        // both have the LSP FF (t >= 1); fix adds only the Fix FF
        assert_eq!(fixed.nl.ff_count(), plain.nl.ff_count() + 1);
    }

    #[test]
    fn segmented_shortens_critical_path() {
        use crate::netlist::timing::{analyze, UnitDelay};
        let acc = analyze(&seq_mult(16, 0, false).nl, &UnitDelay).critical_path_ps;
        let seg = analyze(&seq_mult(16, 8, true).nl, &UnitDelay).critical_path_ps;
        assert!(
            seg < acc,
            "segmentation must shorten the critical path (acc {acc}, seg {seg})"
        );
    }

    #[test]
    fn word_model_spot_check_consistency() {
        // The circuit-vs-word agreement implies circuit == paper equations,
        // but pin one literal value anyway (Table IIb).
        let c = seq_mult(4, 2, false);
        let mut sim = SeqSim::new(&c.nl);
        let got = run_batch(&c, &mut sim, &[U512::from_u64(0b1011)], &[U512::from_u64(0b0110)], false);
        assert_eq!(got[0].limb(0), 82);
        assert_eq!(approx_seq_mul(0b1011, 0b0110, 4, 2, false), 82);
    }
}
