//! 64-way bit-parallel netlist simulation.
//!
//! Every net carries a 64-bit word — one bit per concurrent test vector —
//! so functional verification and switching-activity extraction run 64
//! patterns per pass. The sequential simulator (`SeqSim`) is cycle-accurate
//! and counts per-net toggles, which the technology models turn into
//! vector-based dynamic-power estimates (the paper's Fig. 3 methodology:
//! "vector-based approach with a set of 2^16 uniform input patterns").

use super::graph::{Driver, GateKind, Net, Netlist};

#[inline]
fn eval_gate(kind: GateKind, ins: &[Net], vals: &[u64]) -> u64 {
    let v = |n: Net| vals[n.0 as usize];
    match kind {
        GateKind::Not => !v(ins[0]),
        GateKind::And => v(ins[0]) & v(ins[1]),
        GateKind::Or => v(ins[0]) | v(ins[1]),
        GateKind::Xor => v(ins[0]) ^ v(ins[1]),
        GateKind::Nand => !(v(ins[0]) & v(ins[1])),
        GateKind::Nor => !(v(ins[0]) | v(ins[1])),
        GateKind::Xnor => !(v(ins[0]) ^ v(ins[1])),
        GateKind::Mux => {
            let sel = v(ins[2]);
            (v(ins[0]) & !sel) | (v(ins[1]) & sel)
        }
    }
}

/// Evaluate the combinational fabric into a caller-provided buffer
/// (resized to the net count). Allocation-free when reused — the
/// activity-simulation hot path calls this once per clock cycle.
pub fn eval_comb_into(nl: &Netlist, inputs: &[u64], ff_state: &[u64], vals: &mut Vec<u64>) {
    assert_eq!(inputs.len(), nl.inputs.len(), "input width mismatch");
    assert_eq!(ff_state.len(), nl.ffs.len(), "FF state width mismatch");
    vals.clear();
    vals.resize(nl.drivers.len(), 0);
    for (i, d) in nl.drivers.iter().enumerate() {
        match d {
            Driver::Const(true) => vals[i] = u64::MAX,
            Driver::Const(false) => vals[i] = 0,
            Driver::Input(k) => vals[i] = inputs[*k as usize],
            Driver::Ff(k) => vals[i] = ff_state[*k as usize],
            Driver::Gate { .. } => {}
        }
    }
    for &net in &nl.topo {
        if let Driver::Gate { kind, ins } = &nl.drivers[net.0 as usize] {
            vals[net.0 as usize] = eval_gate(*kind, ins, vals);
        }
    }
}

/// Evaluate the combinational fabric given input words and FF state words.
/// Returns the full net-value table.
pub fn eval_comb(nl: &Netlist, inputs: &[u64], ff_state: &[u64]) -> Vec<u64> {
    let mut vals = Vec::new();
    eval_comb_into(nl, inputs, ff_state, &mut vals);
    vals
}

/// Cycle-accurate sequential simulator with toggle counting.
pub struct SeqSim<'a> {
    /// The netlist under simulation.
    pub nl: &'a Netlist,
    /// Current FF state (one word per FF; 64 vectors).
    pub state: Vec<u64>,
    /// Last combinational net values (after the most recent `step`).
    pub vals: Vec<u64>,
    /// Accumulated per-net toggle counts (bit-population of value changes),
    /// used for switching-activity power estimation.
    pub toggles: Vec<u64>,
    /// Clock cycles simulated.
    pub cycles: u64,
    /// Scratch buffer reused across settles (avoids per-cycle allocation).
    scratch: Vec<u64>,
}

impl<'a> SeqSim<'a> {
    /// A simulator with cleared state, values, and toggle counts.
    pub fn new(nl: &'a Netlist) -> Self {
        Self {
            nl,
            state: vec![0; nl.ffs.len()],
            vals: vec![0; nl.drivers.len()],
            toggles: vec![0; nl.drivers.len()],
            cycles: 0,
            scratch: Vec::with_capacity(nl.drivers.len()),
        }
    }

    /// Asynchronous clear: zero all FFs (the paper's D-FFs have async clear).
    pub fn reset(&mut self) {
        self.state.iter_mut().for_each(|s| *s = 0);
    }

    /// Load FF state directly (used for `parallel load` of shift registers).
    pub fn load_state(&mut self, ff_indices: &[usize], words: &[u64]) {
        for (&idx, &w) in ff_indices.iter().zip(words) {
            self.state[idx] = w;
        }
    }

    /// Evaluate combinational logic for the given inputs WITHOUT clocking.
    pub fn settle(&mut self, inputs: &[u64]) {
        eval_comb_into(self.nl, inputs, &self.state, &mut self.scratch);
        for (t, (old, new)) in self.toggles.iter_mut().zip(self.vals.iter().zip(&self.scratch)) {
            *t += (old ^ new).count_ones() as u64;
        }
        std::mem::swap(&mut self.vals, &mut self.scratch);
    }

    /// One clock edge: settle, then latch every FF's `d` into its state.
    pub fn step(&mut self, inputs: &[u64]) {
        self.settle(inputs);
        for (k, ff) in self.nl.ffs.iter().enumerate() {
            self.state[k] = self.vals[ff.d.0 as usize];
        }
        self.cycles += 1;
    }

    /// Value of an output net after the last settle/step.
    pub fn output(&self, name: &str) -> u64 {
        let net = self
            .nl
            .find_output(name)
            .unwrap_or_else(|| panic!("no output named {name}"));
        self.vals[net.0 as usize]
    }

    /// Total toggles across all nets (the switching-activity aggregate).
    pub fn total_toggles(&self) -> u64 {
        self.toggles.iter().sum()
    }

    /// Mean toggle rate per net per cycle per vector (activity factor α).
    pub fn activity_factor(&self) -> f64 {
        if self.cycles == 0 || self.nl.drivers.is_empty() {
            return 0.0;
        }
        self.total_toggles() as f64 / (self.nl.drivers.len() as f64 * self.cycles as f64 * 64.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::graph::NetlistBuilder;

    fn xor_netlist() -> Netlist {
        let mut b = NetlistBuilder::new("x");
        let p = b.input();
        let q = b.input();
        let o = b.xor2(p, q);
        b.output("o", o);
        b.build()
    }

    #[test]
    fn comb_eval_bitparallel() {
        let nl = xor_netlist();
        let vals = eval_comb(&nl, &[0b1100, 0b1010], &[]);
        let o = nl.find_output("o").unwrap();
        assert_eq!(vals[o.0 as usize], 0b0110);
    }

    #[test]
    fn mux_semantics() {
        let mut b = NetlistBuilder::new("m");
        let a = b.input();
        let c = b.input();
        let s = b.input();
        let o = b.mux2(a, c, s);
        b.output("o", o);
        let nl = b.build();
        let vals = eval_comb(&nl, &[0b0011, 0b0101, 0b1100], &[]);
        // sel=0 -> a, sel=1 -> b
        assert_eq!(vals[o.0 as usize], 0b0111);
    }

    #[test]
    fn toggle_ff_divides_clock() {
        let mut b = NetlistBuilder::new("t");
        let q = b.ff("q");
        let d = b.not(q);
        b.connect_ff(q, d);
        b.output("q", q);
        let nl = b.build();
        let mut sim = SeqSim::new(&nl);
        sim.reset();
        let mut seen = Vec::new();
        for _ in 0..4 {
            sim.step(&[]);
            seen.push(sim.state[0] & 1);
        }
        assert_eq!(seen, vec![1, 0, 1, 0]);
    }

    #[test]
    fn toggles_counted() {
        let nl = xor_netlist();
        let mut sim = SeqSim::new(&nl);
        sim.settle(&[u64::MAX, 0]); // every vector flips the input net a
        // first settle: from all-zero initial vals
        assert!(sim.total_toggles() >= 64);
    }

    #[test]
    fn const_nets() {
        let mut b = NetlistBuilder::new("c");
        let one = b.constant(true);
        let zero = b.constant(false);
        let o = b.or2(one, zero);
        b.output("o", o);
        let nl = b.build();
        let vals = eval_comb(&nl, &[], &[]);
        assert_eq!(vals[o.0 as usize], u64::MAX);
    }
}
