//! Gate-level netlist substrate.
//!
//! The paper evaluates Verilog/VHDL implementations through Vivado (FPGA)
//! and Genus/Innovus (45 nm ASIC). Neither toolchain is available, so this
//! module provides the substrate those flows would consume: a structural
//! netlist representation with
//!
//! * [`graph`]      — gates, D flip-flops, primary I/O, carry-chain tags,
//!   and a builder with topological levelization;
//! * [`sim`]        — 64-way bit-parallel functional simulation (combinational
//!   and cycle-accurate sequential) with per-net toggle counting for
//!   vector-based power estimation;
//! * [`timing`]     — static timing analysis parameterized by a per-gate
//!   delay model (supplied by [`crate::tech`]);
//! * [`generators`] — structural generators for the paper's circuits:
//!   ripple-carry and segmented adders, the accurate (Fig. 1a) and
//!   approximate (Fig. 1b) sequential multipliers, and the combinational
//!   array multiplier of §III.
//!
//! Every generated circuit is verified cycle-accurately against the
//! word-level software model (`netlist_integration` tests).

pub mod generators;
pub mod graph;
pub mod sim;
pub mod timing;

pub use graph::{GateKind, Net, Netlist, NetlistBuilder};
pub use sim::SeqSim;
