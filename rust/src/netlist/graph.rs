//! Netlist graph: gates, flip-flops, primary I/O, carry-chain tags.
//!
//! Nets are dense indices (`Net`), each driven by exactly one source
//! (constant, primary input, gate output, or D-FF output). The builder
//! checks single-driver and acyclicity invariants and produces a levelized
//! evaluation order for the simulator and the timing analyzer.

/// A net id (index into the netlist's driver table).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Net(pub u32);

/// Combinational gate kinds (2-input unless noted).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum GateKind {
    /// Inverter (1-input).
    Not,
    /// AND.
    And,
    /// OR.
    Or,
    /// XOR.
    Xor,
    /// NAND.
    Nand,
    /// NOR.
    Nor,
    /// XNOR.
    Xnor,
    /// 2:1 multiplexer: `sel ? b : a` (inputs ordered `[a, b, sel]`).
    Mux,
}

impl GateKind {
    /// Number of inputs the gate takes.
    pub fn fanin(&self) -> usize {
        match self {
            GateKind::Not => 1,
            GateKind::Mux => 3,
            _ => 2,
        }
    }
}

/// What drives a net.
#[derive(Clone, Debug, PartialEq)]
pub enum Driver {
    /// A constant 0/1 net.
    Const(bool),
    /// Primary input (index into the input list).
    Input(u32),
    /// Combinational gate over other nets.
    Gate { kind: GateKind, ins: Vec<Net> },
    /// D flip-flop output (index into the FF list); next-state net is
    /// registered separately in `Netlist::ffs`.
    Ff(u32),
}

/// A D flip-flop: output net `q`, data input net `d` (asynchronous clear
/// is modeled by the simulator's reset).
#[derive(Clone, Debug)]
pub struct FlipFlop {
    /// Output (Q) net.
    pub q: Net,
    /// Data (D) input net.
    pub d: Net,
    /// Instance name.
    pub name: String,
}

/// A tagged carry chain (sequence of carry-out nets, LSB first). Used by
/// the FPGA model to map onto dedicated carry logic and by both tech
/// models for critical-path reasoning.
#[derive(Clone, Debug)]
pub struct CarryChain {
    /// Chain name.
    pub name: String,
    /// Per-bit carry-out nets (chain length = couts.len()).
    pub couts: Vec<Net>,
    /// Every gate realized inside the dedicated carry logic (generate /
    /// propagate-AND, carry mux/OR, sum XORCY) — excluded from LUT packing
    /// and charged the fast carry delay by the FPGA model.
    pub members: Vec<Net>,
}

/// An immutable, levelized netlist.
#[derive(Clone, Debug)]
pub struct Netlist {
    /// Circuit name.
    pub name: String,
    /// Per-net driver, indexed by net id.
    pub drivers: Vec<Driver>,
    /// Primary-input nets, in declaration order.
    pub inputs: Vec<Net>,
    /// Named primary outputs.
    pub outputs: Vec<(String, Net)>,
    /// Flip-flops, in declaration order.
    pub ffs: Vec<FlipFlop>,
    /// Tagged carry chains.
    pub carry_chains: Vec<CarryChain>,
    /// Gate nets in topological (levelized) order.
    pub topo: Vec<Net>,
}

impl Netlist {
    /// Combinational gates in the netlist.
    pub fn gate_count(&self) -> usize {
        self.drivers
            .iter()
            .filter(|d| matches!(d, Driver::Gate { .. }))
            .count()
    }

    /// Flip-flops in the netlist.
    pub fn ff_count(&self) -> usize {
        self.ffs.len()
    }

    /// Gate count per kind (for area models).
    pub fn gate_histogram(&self) -> std::collections::BTreeMap<&'static str, usize> {
        let mut h = std::collections::BTreeMap::new();
        for d in &self.drivers {
            if let Driver::Gate { kind, .. } = d {
                let name = match kind {
                    GateKind::Not => "NOT",
                    GateKind::And => "AND2",
                    GateKind::Or => "OR2",
                    GateKind::Xor => "XOR2",
                    GateKind::Nand => "NAND2",
                    GateKind::Nor => "NOR2",
                    GateKind::Xnor => "XNOR2",
                    GateKind::Mux => "MUX2",
                };
                *h.entry(name).or_insert(0) += 1;
            }
        }
        h
    }

    /// Per-bit carry-out nets of all tagged chains.
    pub fn chain_nets(&self) -> std::collections::HashSet<Net> {
        self.carry_chains
            .iter()
            .flat_map(|c| c.couts.iter().copied())
            .collect()
    }

    /// Every gate realized inside dedicated carry logic.
    pub fn chain_member_nets(&self) -> std::collections::HashSet<Net> {
        self.carry_chains
            .iter()
            .flat_map(|c| c.members.iter().copied())
            .collect()
    }

    /// The net driving primary output `name`.
    pub fn find_output(&self, name: &str) -> Option<Net> {
        self.outputs
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, net)| *net)
    }
}

/// Builder with invariant checking.
pub struct NetlistBuilder {
    name: String,
    drivers: Vec<Driver>,
    inputs: Vec<Net>,
    outputs: Vec<(String, Net)>,
    ffs: Vec<FlipFlop>,
    ff_d_pending: Vec<Option<Net>>,
    carry_chains: Vec<CarryChain>,
}

impl NetlistBuilder {
    /// An empty builder for circuit `name`.
    pub fn new(name: &str) -> Self {
        Self {
            name: name.to_string(),
            drivers: Vec::new(),
            inputs: Vec::new(),
            outputs: Vec::new(),
            ffs: Vec::new(),
            ff_d_pending: Vec::new(),
            carry_chains: Vec::new(),
        }
    }

    fn push(&mut self, d: Driver) -> Net {
        let net = Net(self.drivers.len() as u32);
        self.drivers.push(d);
        net
    }

    /// A constant-`v` net.
    pub fn constant(&mut self, v: bool) -> Net {
        self.push(Driver::Const(v))
    }

    /// A fresh primary input.
    pub fn input(&mut self) -> Net {
        let idx = self.inputs.len() as u32;
        let net = self.push(Driver::Input(idx));
        self.inputs.push(net);
        net
    }

    /// A vector of fresh primary inputs, LSB first.
    pub fn input_bus(&mut self, width: u32) -> Vec<Net> {
        (0..width).map(|_| self.input()).collect()
    }

    /// A gate of `kind` over `ins` (fanin-checked).
    pub fn gate(&mut self, kind: GateKind, ins: &[Net]) -> Net {
        assert_eq!(ins.len(), kind.fanin(), "{kind:?} fanin mismatch");
        for n in ins {
            assert!((n.0 as usize) < self.drivers.len(), "undriven net {n:?}");
        }
        self.push(Driver::Gate { kind, ins: ins.to_vec() })
    }

    /// `!a`
    pub fn not(&mut self, a: Net) -> Net {
        self.gate(GateKind::Not, &[a])
    }
    /// `a & b`
    pub fn and2(&mut self, a: Net, b: Net) -> Net {
        self.gate(GateKind::And, &[a, b])
    }
    /// `a | b`
    pub fn or2(&mut self, a: Net, b: Net) -> Net {
        self.gate(GateKind::Or, &[a, b])
    }
    /// `a ^ b`
    pub fn xor2(&mut self, a: Net, b: Net) -> Net {
        self.gate(GateKind::Xor, &[a, b])
    }
    /// `sel ? b : a`
    pub fn mux2(&mut self, a: Net, b: Net, sel: Net) -> Net {
        self.gate(GateKind::Mux, &[a, b, sel])
    }

    /// Declare a flip-flop; its data input is connected later with
    /// [`Self::connect_ff`] (state nets are usually needed before the
    /// next-state logic exists).
    pub fn ff(&mut self, name: &str) -> Net {
        let idx = self.ffs.len() as u32;
        let q = self.push(Driver::Ff(idx));
        self.ffs.push(FlipFlop { q, d: Net(u32::MAX), name: name.to_string() });
        self.ff_d_pending.push(None);
        q
    }

    /// A vector of flip-flops named `name[i]`, LSB first.
    pub fn ff_bus(&mut self, name: &str, width: u32) -> Vec<Net> {
        (0..width).map(|i| self.ff(&format!("{name}[{i}]"))).collect()
    }

    /// Connect flip-flop output `q`'s data input to `d`.
    pub fn connect_ff(&mut self, q: Net, d: Net) {
        let idx = match self.drivers[q.0 as usize] {
            Driver::Ff(i) => i as usize,
            _ => panic!("{q:?} is not a flip-flop output"),
        };
        assert!(self.ff_d_pending[idx].is_none(), "FF {q:?} already connected");
        self.ff_d_pending[idx] = Some(d);
    }

    /// Declare `net` as primary output `name`.
    pub fn output(&mut self, name: &str, net: Net) {
        self.outputs.push((name.to_string(), net));
    }

    /// Peek the driver of a net (read-only; used by generators to map
    /// FF output nets back to FF indices).
    pub fn driver_of(&self, net: Net) -> Driver {
        self.drivers[net.0 as usize].clone()
    }

    /// Tag `couts` (LSB first) as carry chain `name` for the tech models.
    pub fn tag_carry_chain(&mut self, name: &str, couts: &[Net]) {
        self.carry_chains.push(CarryChain {
            name: name.to_string(),
            couts: couts.to_vec(),
            members: couts.to_vec(),
        });
    }

    /// Tag a chain with an explicit member set (couts ⊆ members).
    pub fn tag_carry_chain_full(&mut self, name: &str, couts: &[Net], members: &[Net]) {
        self.carry_chains.push(CarryChain {
            name: name.to_string(),
            couts: couts.to_vec(),
            members: members.to_vec(),
        });
    }

    /// Finalize: check invariants and levelize.
    pub fn build(mut self) -> Netlist {
        for (idx, d) in self.ff_d_pending.iter().enumerate() {
            let d = d.unwrap_or_else(|| panic!("FF {} left unconnected", self.ffs[idx].name));
            self.ffs[idx].d = d;
        }
        // Topological sort of combinational gates (FF outputs, inputs and
        // constants are level-0 sources). Cycles through gates are errors.
        let n = self.drivers.len();
        let mut order = Vec::with_capacity(n);
        let mut state = vec![0u8; n]; // 0 unvisited, 1 on stack, 2 done
        // iterative DFS
        for start in 0..n {
            if state[start] != 0 {
                continue;
            }
            let mut stack: Vec<(u32, usize)> = vec![(start as u32, 0)];
            while let Some(&mut (node, ref mut child)) = stack.last_mut() {
                let node_usize = node as usize;
                if state[node_usize] == 2 {
                    stack.pop();
                    continue;
                }
                state[node_usize] = 1;
                let ins: &[Net] = match &self.drivers[node_usize] {
                    Driver::Gate { ins, .. } => ins,
                    _ => &[],
                };
                if *child < ins.len() {
                    let next = ins[*child].0;
                    *child += 1;
                    match state[next as usize] {
                        0 => stack.push((next, 0)),
                        1 => panic!("combinational cycle through net {next}"),
                        _ => {}
                    }
                } else {
                    state[node_usize] = 2;
                    if matches!(self.drivers[node_usize], Driver::Gate { .. }) {
                        order.push(Net(node));
                    }
                    stack.pop();
                }
            }
        }
        Netlist {
            name: self.name,
            drivers: self.drivers,
            inputs: self.inputs,
            outputs: self.outputs,
            ffs: self.ffs,
            carry_chains: self.carry_chains,
            topo: order,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_simple_and() {
        let mut b = NetlistBuilder::new("and");
        let x = b.input();
        let y = b.input();
        let z = b.and2(x, y);
        b.output("z", z);
        let nl = b.build();
        assert_eq!(nl.gate_count(), 1);
        assert_eq!(nl.topo, vec![z]);
        assert_eq!(nl.find_output("z"), Some(z));
    }

    #[test]
    fn topo_respects_dependencies() {
        let mut b = NetlistBuilder::new("chain");
        let x = b.input();
        let g1 = b.not(x);
        let g2 = b.not(g1);
        let g3 = b.xor2(g1, g2);
        b.output("o", g3);
        let nl = b.build();
        let pos = |n: Net| nl.topo.iter().position(|&m| m == n).unwrap();
        assert!(pos(g1) < pos(g2));
        assert!(pos(g2) < pos(g3));
    }

    #[test]
    fn ff_breaks_cycles() {
        // q feeds its own d through an inverter — legal (sequential loop).
        let mut b = NetlistBuilder::new("toggle");
        let q = b.ff("q");
        let d = b.not(q);
        b.connect_ff(q, d);
        b.output("q", q);
        let nl = b.build();
        assert_eq!(nl.ff_count(), 1);
        assert_eq!(nl.ffs[0].d, d);
    }

    #[test]
    #[should_panic(expected = "combinational cycle")]
    fn combinational_cycle_detected() {
        let mut b = NetlistBuilder::new("bad");
        let x = b.input();
        // Manually create a cycle: gate reading a not-yet-created net is
        // prevented by the builder, so force it via two gates + swap.
        let g1 = b.gate(GateKind::And, &[x, x]);
        let g2 = b.gate(GateKind::And, &[g1, x]);
        // Rewire g1 to read g2 (test-only surgery).
        if let Driver::Gate { ins, .. } = &mut b.drivers[g1.0 as usize] {
            ins[1] = g2;
        }
        b.build();
    }

    #[test]
    #[should_panic(expected = "left unconnected")]
    fn unconnected_ff_panics() {
        let mut b = NetlistBuilder::new("bad_ff");
        b.ff("q");
        b.build();
    }

    #[test]
    fn histogram_counts_kinds() {
        let mut b = NetlistBuilder::new("h");
        let x = b.input();
        let y = b.input();
        let a = b.and2(x, y);
        let o = b.xor2(a, y);
        let _ = b.mux2(a, o, x);
        let h = b.build().gate_histogram();
        assert_eq!(h["AND2"], 1);
        assert_eq!(h["XOR2"], 1);
        assert_eq!(h["MUX2"], 1);
    }
}
