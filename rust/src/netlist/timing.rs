//! Static timing analysis over the levelized netlist.
//!
//! Delay is parameterized by a [`DelayModel`] supplied by the technology
//! layer: the ASIC model charges per-gate cell delays; the FPGA model
//! charges LUT hops for generic logic and fast dedicated-carry delays for
//! nets tagged as carry chains (the mechanism behind the paper's latency
//! savings — segmentation halves the longest chain).

use std::collections::HashSet;

use super::graph::{Driver, GateKind, Net, Netlist};

/// Per-gate delay model (picoseconds).
pub trait DelayModel {
    /// Delay through a gate of `kind`; `on_chain` is true when the gate's
    /// output net is part of a tagged carry chain (FPGA dedicated carry).
    fn gate_delay_ps(&self, kind: GateKind, on_chain: bool) -> f64;
    /// Clock-to-Q + setup allowance for flip-flops.
    fn ff_overhead_ps(&self) -> f64;
}

/// Result of a timing pass.
#[derive(Clone, Debug)]
pub struct TimingReport {
    /// Worst combinational arrival time (ps) over all FF inputs + outputs.
    pub critical_path_ps: f64,
    /// Arrival time per net (ps).
    pub arrival_ps: Vec<f64>,
    /// Net with the worst arrival.
    pub critical_net: Option<Net>,
}

impl TimingReport {
    /// Minimum clock period (ps) including FF overhead.
    pub fn min_period_ps(&self, model: &dyn DelayModel) -> f64 {
        self.critical_path_ps + model.ff_overhead_ps()
    }
}

/// Compute arrival times: sources (inputs, FF outputs, constants) start at
/// 0; every gate adds its delay on top of its worst input.
pub fn analyze(nl: &Netlist, model: &dyn DelayModel) -> TimingReport {
    let chain: HashSet<Net> = nl.chain_nets();
    let mut arrival = vec![0.0f64; nl.drivers.len()];
    let mut worst = 0.0f64;
    let mut worst_net = None;
    for &net in &nl.topo {
        if let Driver::Gate { kind, ins } = &nl.drivers[net.0 as usize] {
            let in_max = ins
                .iter()
                .map(|n| arrival[n.0 as usize])
                .fold(0.0f64, f64::max);
            let t = in_max + model.gate_delay_ps(*kind, chain.contains(&net));
            arrival[net.0 as usize] = t;
            if t > worst {
                worst = t;
                worst_net = Some(net);
            }
        }
    }
    TimingReport { critical_path_ps: worst, arrival_ps: arrival, critical_net: worst_net }
}

/// Logic depth (in gate levels) per net — technology-independent structure
/// metric used by tests and the LUT-depth estimator.
pub fn logic_depth(nl: &Netlist) -> Vec<u32> {
    let mut depth = vec![0u32; nl.drivers.len()];
    for &net in &nl.topo {
        if let Driver::Gate { ins, .. } = &nl.drivers[net.0 as usize] {
            depth[net.0 as usize] =
                1 + ins.iter().map(|n| depth[n.0 as usize]).max().unwrap_or(0);
        }
    }
    depth
}

/// A trivial unit-delay model (1000 ps per gate) for tests.
pub struct UnitDelay;

impl DelayModel for UnitDelay {
    fn gate_delay_ps(&self, _kind: GateKind, _on_chain: bool) -> f64 {
        1000.0
    }
    fn ff_overhead_ps(&self) -> f64 {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::graph::NetlistBuilder;

    #[test]
    fn unit_delay_equals_depth() {
        let mut b = NetlistBuilder::new("d");
        let x = b.input();
        let g1 = b.not(x);
        let g2 = b.and2(g1, x);
        let g3 = b.xor2(g2, g1);
        b.output("o", g3);
        let nl = b.build();
        let rep = analyze(&nl, &UnitDelay);
        assert_eq!(rep.critical_path_ps, 3000.0);
        assert_eq!(rep.critical_net, Some(g3));
        let depth = logic_depth(&nl);
        assert_eq!(depth[g3.0 as usize], 3);
    }

    #[test]
    fn chain_flag_reaches_model() {
        struct ChainCheck;
        impl DelayModel for ChainCheck {
            fn gate_delay_ps(&self, _k: GateKind, on_chain: bool) -> f64 {
                if on_chain {
                    10.0
                } else {
                    1000.0
                }
            }
            fn ff_overhead_ps(&self) -> f64 {
                0.0
            }
        }
        let mut b = NetlistBuilder::new("c");
        let x = b.input();
        let y = b.input();
        let c0 = b.and2(x, y);
        let c1 = b.and2(c0, y);
        let c2 = b.and2(c1, y);
        b.tag_carry_chain("cc", &[c0, c1, c2]);
        b.output("o", c2);
        let nl = b.build();
        let rep = analyze(&nl, &ChainCheck);
        assert_eq!(rep.critical_path_ps, 30.0);
    }

    #[test]
    fn parallel_paths_take_max() {
        let mut b = NetlistBuilder::new("p");
        let x = b.input();
        let shallow = b.not(x);
        let d1 = b.not(x);
        let d2 = b.not(d1);
        let deep = b.not(d2);
        let o = b.and2(shallow, deep);
        b.output("o", o);
        let nl = b.build();
        let rep = analyze(&nl, &UnitDelay);
        assert_eq!(rep.critical_path_ps, 4000.0);
    }
}
