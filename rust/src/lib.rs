//! # segmul — Accuracy-configurable Sequential Multipliers via Segmented Carry Chains
//!
//! A full reproduction of Echavarria et al., *"On the Approximation of
//! Accuracy-configurable Sequential Multipliers via Segmented Carry Chains"*
//! (2021), as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1/L2 (build-time python)** — a Pallas kernel + JAX graph computing
//!   batched approximate products and on-device error statistics, AOT-lowered
//!   to HLO text artifacts (`make artifacts`).
//! * **L3 (this crate)** — the evaluation platform: software models of the
//!   multiplier ([`multiplier`]), a gate-level netlist substrate with timing /
//!   area / power analysis ([`netlist`], [`tech`]), the paper's error metrics
//!   with exhaustive / Monte-Carlo / closed-form / probabilistic evaluation
//!   ([`error`]), and an asynchronous evaluation service that batches work
//!   onto the AOT-compiled PJRT executables ([`coordinator`], [`runtime`]).
//!
//! Library users start at the [`api`] facade: design-agnostic
//! [`api::MultiplierSpec`]s, builder-configured [`api::Session`]s over a
//! persistent worker pool, typed [`api::SegmulError`]s, and streaming
//! progress callbacks. The [`tune`] module layers the accuracy-budget
//! autotuner and Pareto explorer on top of a session.
//!
//! Python never runs on the request path: after `make artifacts` the Rust
//! binary is self-contained.
//!
//! See `README.md` for the crate map and quickstart, `DESIGN.md` for the
//! experiment index, and `EXPERIMENTS.md` for paper-vs-measured results.
#![warn(missing_docs)]

pub mod api;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod fault;
pub mod multiplier;
pub mod netlist;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod store;
pub mod tech;
pub mod tune;
pub mod util;
