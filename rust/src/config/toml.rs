//! Minimal TOML-subset parser (see module docs in `config`).

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// A TOML value (subset).
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    /// A quoted string.
    Str(String),
    /// An integer.
    Int(i64),
    /// A float.
    Float(f64),
    /// A boolean.
    Bool(bool),
    /// An array of values.
    Array(Vec<TomlValue>),
}

/// A parsed document: `section -> key -> value`. Keys outside any section
/// live under the empty-string section.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    /// Parsed sections (top-level keys live under `""`).
    pub sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    /// Parse the minimal TOML subset used by `segmul.toml`.
    pub fn parse(src: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow!("line {}: unterminated section", lineno + 1))?;
                section = name.trim().to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
            let v = parse_value(value.trim())
                .map_err(|e| anyhow!("line {}: {e}", lineno + 1))?;
            doc.sections
                .entry(section.clone())
                .or_default()
                .insert(key.trim().to_string(), v);
        }
        Ok(doc)
    }

    /// Raw value at `[section] key`.
    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section)?.get(key)
    }

    /// String value at `[section] key`.
    pub fn get_str(&self, section: &str, key: &str) -> Option<&str> {
        match self.get(section, key)? {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Integer value at `[section] key`.
    pub fn get_int(&self, section: &str, key: &str) -> Option<i64> {
        match self.get(section, key)? {
            TomlValue::Int(v) => Some(*v),
            _ => None,
        }
    }

    /// Float value at `[section] key`.
    pub fn get_float(&self, section: &str, key: &str) -> Option<f64> {
        match self.get(section, key)? {
            TomlValue::Float(v) => Some(*v),
            TomlValue::Int(v) => Some(*v as f64),
            _ => None,
        }
    }

    /// Boolean value at `[section] key`.
    pub fn get_bool(&self, section: &str, key: &str) -> Option<bool> {
        match self.get(section, key)? {
            TomlValue::Bool(v) => Some(*v),
            _ => None,
        }
    }

    /// Integer-array value at `[section] key`.
    pub fn get_int_array(&self, section: &str, key: &str) -> Option<Vec<i64>> {
        match self.get(section, key)? {
            TomlValue::Array(items) => items
                .iter()
                .map(|v| match v {
                    TomlValue::Int(i) => Some(*i),
                    _ => None,
                })
                .collect(),
            _ => None,
        }
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue> {
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or_else(|| anyhow!("unterminated string"))?;
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or_else(|| anyhow!("unterminated array"))?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(vec![]));
        }
        let items: Result<Vec<TomlValue>> =
            inner.split(',').map(|p| parse_value(p.trim())).collect();
        return Ok(TomlValue::Array(items?));
    }
    let clean = s.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("cannot parse value {s:?}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sections_and_types() {
        let doc = TomlDoc::parse(
            r#"
            top = 1
            [a]
            s = "hello"   # comment
            i = 42
            f = 2.5
            b = true
            arr = [1, 2, 3]
            [b]
            i = 1_000_000
            "#,
        )
        .unwrap();
        assert_eq!(doc.get_int("", "top"), Some(1));
        assert_eq!(doc.get_str("a", "s"), Some("hello"));
        assert_eq!(doc.get_int("a", "i"), Some(42));
        assert_eq!(doc.get_float("a", "f"), Some(2.5));
        assert_eq!(doc.get_bool("a", "b"), Some(true));
        assert_eq!(doc.get_int_array("a", "arr"), Some(vec![1, 2, 3]));
        assert_eq!(doc.get_int("b", "i"), Some(1_000_000));
    }

    #[test]
    fn hash_inside_string_kept() {
        let doc = TomlDoc::parse("s = \"a#b\"").unwrap();
        assert_eq!(doc.get_str("", "s"), Some("a#b"));
    }

    #[test]
    fn errors_reported_with_line() {
        let err = TomlDoc::parse("[bad").unwrap_err().to_string();
        assert!(err.contains("line 1"), "{err}");
        assert!(TomlDoc::parse("x ~ 3").is_err());
        assert!(TomlDoc::parse("x = ").is_err());
    }

    #[test]
    fn type_mismatch_is_none() {
        let doc = TomlDoc::parse("x = 3").unwrap();
        assert_eq!(doc.get_str("", "x"), None);
        assert_eq!(doc.get_int("", "x"), Some(3));
        assert_eq!(doc.get_float("", "x"), Some(3.0));
    }

    #[test]
    fn empty_array() {
        let doc = TomlDoc::parse("x = []").unwrap();
        assert_eq!(doc.get_int_array("", "x"), Some(vec![]));
    }
}
