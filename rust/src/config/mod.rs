//! Configuration system: a TOML-subset parser (the `toml` crate is
//! unavailable offline) plus the typed [`Config`] all binaries share.
//!
//! Supported TOML subset: `[section]` headers, `key = value` with string,
//! integer, float, boolean, and homogeneous-array values, `#` comments.
//! That covers every configuration this project needs; nested tables and
//! datetimes are intentionally out of scope.

pub mod toml;

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

pub use toml::TomlDoc;

/// Shared configuration for the CLI, examples, and benches.
#[derive(Clone, Debug)]
pub struct Config {
    /// Directory with the AOT artifacts (`manifest.json` + `*.hlo.txt`).
    pub artifacts_dir: PathBuf,
    /// Directory where figure CSVs/reports are written.
    pub results_dir: PathBuf,
    /// Monte-Carlo sample count for n > exhaustive_max.
    pub mc_samples: u64,
    /// Largest bit-width evaluated exhaustively.
    pub exhaustive_max_n: u32,
    /// Base RNG seed (every figure is reproducible from this).
    pub seed: u64,
    /// Vectors for hardware activity simulation (paper: 2^16).
    pub hw_vectors: u64,
    /// Worker threads (defaults to available parallelism). An invalid
    /// `SEGMUL_WORKERS` override falls back to 1 here; the CLI and the
    /// [`crate::api::SessionBuilder`] surface it as a typed
    /// `SegmulError::Config` before any work runs.
    pub workers: usize,
    /// Bit-widths for the error figures (Fig. 2).
    pub error_bitwidths: Vec<u32>,
    /// Bit-widths for the hardware figures (Fig. 3).
    pub hw_bitwidths: Vec<u32>,
    /// Bit-widths for the full design-space sweep (`segmul sweep`).
    pub sweep_bitwidths: Vec<u32>,
    /// Design set for the sweep (`paper`, `accurate`, `baselines`,
    /// `oracle`, `netlist`, `all`) — parsed by
    /// [`crate::multiplier::DesignSet::parse`] at sweep construction.
    pub sweep_designs: String,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            artifacts_dir: crate::runtime::artifact::default_dir(),
            results_dir: PathBuf::from("results"),
            mc_samples: 1 << 20,
            exhaustive_max_n: 12,
            seed: 0x5E6_0001,
            hw_vectors: 1 << 12,
            workers: crate::util::threadpool::default_workers().unwrap_or(1),
            error_bitwidths: vec![4, 8, 12, 16, 32],
            hw_bitwidths: vec![4, 8, 16, 32, 64, 128, 256],
            sweep_bitwidths: vec![4, 8, 16, 32],
            sweep_designs: "paper".to_string(),
        }
    }
}

impl Config {
    /// Load from a TOML file, falling back to defaults for missing keys.
    pub fn load(path: &Path) -> Result<Config> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
        let doc = TomlDoc::parse(&text).with_context(|| format!("parsing {path:?}"))?;
        Ok(Self::from_doc(&doc))
    }

    /// Load `segmul.toml` if present in the working directory.
    pub fn discover() -> Config {
        let p = Path::new("segmul.toml");
        if p.exists() {
            Self::load(p).unwrap_or_default()
        } else {
            Config::default()
        }
    }

    /// Build a config from a parsed TOML document (missing keys keep defaults).
    pub fn from_doc(doc: &TomlDoc) -> Config {
        let mut c = Config::default();
        if let Some(s) = doc.get_str("paths", "artifacts") {
            c.artifacts_dir = PathBuf::from(s);
        }
        if let Some(s) = doc.get_str("paths", "results") {
            c.results_dir = PathBuf::from(s);
        }
        if let Some(v) = doc.get_int("eval", "mc_samples") {
            c.mc_samples = v as u64;
        }
        if let Some(v) = doc.get_int("eval", "exhaustive_max_n") {
            c.exhaustive_max_n = v as u32;
        }
        if let Some(v) = doc.get_int("eval", "seed") {
            c.seed = v as u64;
        }
        if let Some(v) = doc.get_int("hw", "vectors") {
            c.hw_vectors = v as u64;
        }
        if let Some(v) = doc.get_int("eval", "workers") {
            c.workers = v as usize;
        }
        if let Some(v) = doc.get_int_array("eval", "error_bitwidths") {
            c.error_bitwidths = v.iter().map(|&x| x as u32).collect();
        }
        if let Some(v) = doc.get_int_array("hw", "bitwidths") {
            c.hw_bitwidths = v.iter().map(|&x| x as u32).collect();
        }
        if let Some(v) = doc.get_int_array("sweep", "bitwidths") {
            c.sweep_bitwidths = v.iter().map(|&x| x as u32).collect();
        }
        if let Some(s) = doc.get_str("sweep", "designs") {
            c.sweep_designs = s.to_string();
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = Config::default();
        assert!(c.mc_samples > 0);
        assert!(c.error_bitwidths.contains(&8));
    }

    #[test]
    fn from_doc_overrides() {
        let doc = TomlDoc::parse(
            r#"
            [paths]
            artifacts = "/tmp/a"
            [eval]
            mc_samples = 1024
            error_bitwidths = [4, 8]
            [hw]
            vectors = 256
            [sweep]
            bitwidths = [4, 8]
            designs = "all"
            "#,
        )
        .unwrap();
        let c = Config::from_doc(&doc);
        assert_eq!(c.artifacts_dir, PathBuf::from("/tmp/a"));
        assert_eq!(c.mc_samples, 1024);
        assert_eq!(c.error_bitwidths, vec![4, 8]);
        assert_eq!(c.hw_vectors, 256);
        assert_eq!(c.sweep_bitwidths, vec![4, 8]);
        assert_eq!(c.sweep_designs, "all");
        // untouched keys keep defaults
        assert_eq!(c.exhaustive_max_n, 12);
    }

    #[test]
    fn sweep_bitwidths_default_to_paper_grid() {
        assert_eq!(Config::default().sweep_bitwidths, vec![4, 8, 16, 32]);
        assert_eq!(Config::default().sweep_designs, "paper");
    }
}
