//! `bench-gate` — CI bench-regression gate.
//!
//! Compares machine-readable bench summaries (`BENCH_*.json`, written by
//! the benches via `segmul::bench::Summary`) against the committed
//! baseline (`ci/bench_baseline.json`) and exits nonzero when any gated
//! metric regresses past its tolerance (default 15%) or disappears.
//!
//!     bench-gate --baseline ci/bench_baseline.json [--tolerance 0.15] \
//!                target/bench-json/BENCH_batch_kernel.json [more.json ...]

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use anyhow::{anyhow, bail, Context, Result};

use segmul::bench::{gate_compare, GateCheck};
use segmul::report::csv::Table;
use segmul::util::json::Json;

fn load_json(path: &Path) -> Result<Json> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
    Json::parse(&text).map_err(|e| anyhow!("parsing {}: {e}", path.display()))
}

fn run() -> Result<bool> {
    let mut baseline: Option<PathBuf> = None;
    let mut tolerance = 0.15f64;
    let mut currents: Vec<PathBuf> = Vec::new();
    let mut it = std::env::args().skip(1);
    while let Some(arg) = it.next() {
        match arg.as_str() {
            "--baseline" => {
                baseline = Some(PathBuf::from(
                    it.next().ok_or_else(|| anyhow!("--baseline needs a path"))?,
                ));
            }
            "--tolerance" => {
                let v = it.next().ok_or_else(|| anyhow!("--tolerance needs a value"))?;
                tolerance = v.parse().map_err(|_| anyhow!("bad tolerance {v:?}"))?;
            }
            other if other.starts_with("--") => bail!("unknown option {other}"),
            other => currents.push(PathBuf::from(other)),
        }
    }
    let baseline = baseline.ok_or_else(|| anyhow!("missing --baseline <file>"))?;
    if currents.is_empty() {
        bail!("no current bench summaries given");
    }

    let base_doc = load_json(&baseline)?;
    let current_docs: Vec<Json> = currents.iter().map(|p| load_json(p)).collect::<Result<_>>()?;
    let checks = gate_compare(&base_doc, &current_docs, tolerance);
    if checks.is_empty() {
        bail!("baseline {} defines no metrics", baseline.display());
    }

    let mut table = Table::new(&["metric", "baseline", "floor", "current", "status"]);
    let fmt = |v: f64| format!("{v:.3}");
    for c in &checks {
        table.row(vec![
            c.metric.clone(),
            fmt(c.baseline),
            if c.gated { fmt(c.floor) } else { "-".into() },
            c.current.map(fmt).unwrap_or_else(|| "MISSING".into()),
            match (c.gated, c.pass) {
                (false, _) => "info".into(),
                (true, true) => "ok".into(),
                (true, false) => "FAIL".into(),
            },
        ]);
    }
    println!("{}", table.to_text());

    let failures: Vec<&GateCheck> = checks.iter().filter(|c| !c.pass).collect();
    for c in &failures {
        match c.current {
            Some(cur) => eprintln!(
                "bench-gate: {} regressed: {cur:.3} < floor {:.3} (baseline {:.3})",
                c.metric, c.floor, c.baseline
            ),
            None => eprintln!("bench-gate: {} missing from the current summaries", c.metric),
        }
    }
    Ok(failures.is_empty())
}

fn main() -> ExitCode {
    match run() {
        Ok(true) => {
            println!("bench-gate: all gated metrics within tolerance");
            ExitCode::SUCCESS
        }
        Ok(false) => ExitCode::FAILURE,
        Err(e) => {
            eprintln!("bench-gate: error: {e:#}");
            ExitCode::FAILURE
        }
    }
}
