//! `bench-gate` — CI bench-regression gate.
//!
//! Compares machine-readable bench summaries (`BENCH_*.json`, written by
//! the benches via `segmul::bench::Summary`) against the committed
//! baseline (`ci/bench_baseline.json`) and exits nonzero when any gated
//! metric regresses past its tolerance (default 15%) or disappears.
//!
//!     bench-gate --baseline ci/bench_baseline.json [--tolerance 0.15] \
//!                [--only PREFIX ...] \
//!                target/bench-json/BENCH_batch_kernel.json [more.json ...]
//!
//! `--only PREFIX` (repeatable) restricts the gate to baseline metrics
//! whose names start with a prefix — for jobs that run a subset of the
//! benches (e.g. the serve-smoke job gates only `serve_` metrics without
//! the other benches' summaries counting as MISSING failures).

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use anyhow::{anyhow, bail, Context, Result};

use segmul::bench::{gate_compare, GateCheck};
use segmul::report::csv::Table;
use segmul::util::json::Json;

fn load_json(path: &Path) -> Result<Json> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
    Json::parse(&text).map_err(|e| anyhow!("parsing {}: {e}", path.display()))
}

fn run() -> Result<bool> {
    let mut baseline: Option<PathBuf> = None;
    let mut tolerance = 0.15f64;
    let mut onlys: Vec<String> = Vec::new();
    let mut currents: Vec<PathBuf> = Vec::new();
    let mut it = std::env::args().skip(1);
    while let Some(arg) = it.next() {
        match arg.as_str() {
            "--baseline" => {
                baseline = Some(PathBuf::from(
                    it.next().ok_or_else(|| anyhow!("--baseline needs a path"))?,
                ));
            }
            "--tolerance" => {
                let v = it.next().ok_or_else(|| anyhow!("--tolerance needs a value"))?;
                tolerance = v.parse().map_err(|_| anyhow!("bad tolerance {v:?}"))?;
            }
            "--only" => {
                onlys.push(it.next().ok_or_else(|| anyhow!("--only needs a metric prefix"))?);
            }
            other if other.starts_with("--") => bail!("unknown option {other}"),
            other => currents.push(PathBuf::from(other)),
        }
    }
    let baseline = baseline.ok_or_else(|| anyhow!("missing --baseline <file>"))?;
    if currents.is_empty() {
        bail!("no current bench summaries given");
    }

    let base_doc = load_json(&baseline)?;
    let current_docs: Vec<Json> = currents.iter().map(|p| load_json(p)).collect::<Result<_>>()?;
    let mut checks = gate_compare(&base_doc, &current_docs, tolerance);
    if checks.is_empty() {
        bail!("baseline {} defines no metrics", baseline.display());
    }
    if !onlys.is_empty() {
        checks.retain(|c| onlys.iter().any(|p| c.metric.starts_with(p.as_str())));
        if checks.is_empty() {
            bail!(
                "--only {:?} matches no metric in baseline {}",
                onlys,
                baseline.display()
            );
        }
    }

    let fmt = |v: f64| format!("{v:.3}");
    let delta = |c: &GateCheck| match c.current {
        Some(cur) if c.baseline != 0.0 => format!("{:+.1}%", (cur - c.baseline) / c.baseline * 100.0),
        _ => "-".into(),
    };
    let mut table = Table::new(&["metric", "baseline", "floor", "current", "delta", "status"]);
    for c in &checks {
        table.row(vec![
            c.metric.clone(),
            fmt(c.baseline),
            if c.gated { fmt(c.floor) } else { "-".into() },
            c.current.map(fmt).unwrap_or_else(|| "MISSING".into()),
            delta(c),
            match (c.gated, c.pass) {
                (false, _) => "info".into(),
                (true, true) => "ok".into(),
                (true, false) => "FAIL".into(),
            },
        ]);
    }
    println!("{}", table.to_text());

    let failures: Vec<&GateCheck> = checks.iter().filter(|c| !c.pass).collect();
    if !failures.is_empty() {
        eprintln!(
            "bench-gate: {} of {} gated metrics failed against baseline {}:",
            failures.len(),
            checks.iter().filter(|c| c.gated).count(),
            baseline.display()
        );
        let mut failed = Table::new(&["metric", "current", "baseline", "delta", "floor"]);
        for c in &failures {
            failed.row(vec![
                c.metric.clone(),
                c.current.map(fmt).unwrap_or_else(|| "MISSING".into()),
                fmt(c.baseline),
                delta(*c),
                fmt(c.floor),
            ]);
        }
        eprint!("{}", failed.to_text());
        eprintln!(
            "bench-gate: a metric fails when current < floor = baseline * (1 - tolerance) or is missing; \
             refresh {} deliberately if the regression is intended",
            baseline.display()
        );
    }
    Ok(failures.is_empty())
}

fn main() -> ExitCode {
    match run() {
        Ok(true) => {
            println!("bench-gate: all gated metrics within tolerance");
            ExitCode::SUCCESS
        }
        Ok(false) => ExitCode::FAILURE,
        Err(e) => {
            eprintln!("bench-gate: error: {e:#}");
            ExitCode::FAILURE
        }
    }
}
