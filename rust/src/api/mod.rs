//! # The `segmul` public API facade
//!
//! The single entry point for library users, the CLI, and benches.
//! Everything evaluable is described by a design-agnostic
//! [`MultiplierSpec`] — the paper's segmented sequential multiplier, the
//! accurate reference, each related-work baseline, the bit-level oracle,
//! and the gate-level netlist simulator — and runs through one pipeline:
//!
//! ```text
//!  MultiplierSpec ──┐
//!                   ├─ JobBuilder ──> EvalJob ──┐
//!  WorkSpec ────────┘      (typed validation)   │
//!                                               ▼
//!  SessionBuilder ──> Session ──────────> persistent WorkerPool
//!   workers / backend │  ├─ JobKey cache   (long-lived workers, one
//!   cache / seed      │  ├─ telemetry       backend each, built once)
//!   progress callback │  └─ ProgressEvents        │
//!                     ▼                           ▼
//!               SweepGrid × DesignSet ──> bit-identical ErrorStats
//! ```
//!
//! * **Specs, not structs**: [`MultiplierSpec`] is plain hashable data;
//!   [`MultiplierSpec::canonical`] collapses provably-equal product
//!   functions so caches and sweeps dedup across designs.
//! * **Sessions, not per-job plumbing**: [`Session`] owns worker threads
//!   that hold a backend **across jobs** — artifact-heavy backends are
//!   constructed once per worker per session, never per job.
//! * **Typed errors**: the facade reports [`SegmulError`] (config /
//!   spec / workload / backend / eval / io) instead of stringly errors.
//! * **Streaming progress**: register a callback with
//!   [`SessionBuilder::on_progress`] and observe every in-order chunk
//!   merge without polling.
//! * **Determinism**: results are bit-identical — order-sensitive f64
//!   fields included — across worker counts and scheduling, inherited
//!   from the coordinator's ordered merge.
//! * **Persistence**: attach a content-addressed on-disk
//!   [`ResultStore`] with [`SessionBuilder::store`] — committed results
//!   answer later sessions, running jobs checkpoint per chunk so a
//!   killed sweep resumes bit-identically, and per-key leases let N
//!   processes shard one grid ([`Shard`]) with zero duplicate
//!   evaluations.
//!
//! Machinery re-exports ([`EvalJob`], [`SweepGrid`], [`EvalService`],
//! ...) come from [`crate::coordinator`]; reach into that module only
//! when building custom backends or drivers.

mod job;
mod session;

pub use crate::error::SegmulError;
pub use job::JobBuilder;
pub use session::{
    BackendChoice, BackendFactory, ProgressEvent, Session, SessionBuilder, SessionTelemetry,
};

pub use crate::coordinator::{
    AnalyticMode, Answer, ChunkEvent, EvalBackend, EvalJob, EvalService, JobKey, JobResult,
    Shard, SweepGrid, SweepOutcome, WorkSpec, WorkerPool,
};
pub use crate::error::analytic::{analytic_stats, AnalyticStats};
pub use crate::multiplier::{DesignSet, DispatchClass, MultiplierSpec};
pub use crate::store::{ResultStore, StoreKey, StoredResult, STORE_SCHEMA};
