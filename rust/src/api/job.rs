//! Fluent construction of validated [`EvalJob`]s.

use crate::coordinator::{EvalJob, WorkSpec};
use crate::multiplier::MultiplierSpec;

use crate::error::SegmulError;

/// Builder for one evaluation job: a design plus a workload. Obtain one
/// from [`JobBuilder::new`] or — pre-seeded with the session's RNG seed
/// policy — from [`super::Session::job`].
///
/// ```no_run
/// use segmul::api::{JobBuilder, MultiplierSpec};
///
/// let job = JobBuilder::new(MultiplierSpec::Segmented { n: 16, t: 7, fix: true })
///     .monte_carlo(1 << 20)
///     .seed(42)
///     .build()?;
/// # Ok::<(), segmul::api::SegmulError>(())
/// ```
#[derive(Clone, Debug)]
pub struct JobBuilder {
    design: MultiplierSpec,
    workload: Option<Workload>,
    seed: u64,
}

#[derive(Clone, Debug)]
enum Workload {
    Exhaustive,
    MonteCarlo { samples: u64 },
    Adaptive { max_samples: u64, target_rel_stderr: f64 },
}

impl JobBuilder {
    /// A builder for `design` with no workload chosen yet.
    pub fn new(design: MultiplierSpec) -> Self {
        JobBuilder { design, workload: None, seed: 0 }
    }

    /// RNG seed for Monte-Carlo workloads (ignored by exhaustive ones).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Evaluate all `2^(2n)` operand pairs (requires `n <= 16`).
    pub fn exhaustive(mut self) -> Self {
        self.workload = Some(Workload::Exhaustive);
        self
    }

    /// Fixed-budget Monte-Carlo with uniform operands.
    pub fn monte_carlo(mut self, samples: u64) -> Self {
        self.workload = Some(Workload::MonteCarlo { samples });
        self
    }

    /// Adaptive Monte-Carlo: stop when the relative CI target on the
    /// error rate is met, or `max_samples` is exhausted.
    pub fn adaptive(mut self, max_samples: u64, target_rel_stderr: f64) -> Self {
        self.workload = Some(Workload::Adaptive { max_samples, target_rel_stderr });
        self
    }

    /// Validate and produce the job.
    pub fn build(self) -> Result<EvalJob, SegmulError> {
        self.design.validate()?;
        let spec = match self.workload {
            None => {
                return Err(SegmulError::workload(
                    "no workload specified — call exhaustive(), monte_carlo(samples) \
                     or adaptive(max_samples, target)",
                ))
            }
            Some(Workload::Exhaustive) => WorkSpec::Exhaustive,
            Some(Workload::MonteCarlo { samples }) => {
                WorkSpec::MonteCarlo { samples, seed: self.seed }
            }
            Some(Workload::Adaptive { max_samples, target_rel_stderr }) => WorkSpec::Adaptive {
                max_samples,
                seed: self.seed,
                target_rel_stderr,
            },
        };
        let job = EvalJob::new(self.design, spec);
        job.validate()?;
        Ok(job)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_validated_jobs() {
        let job = JobBuilder::new(MultiplierSpec::Segmented { n: 8, t: 3, fix: true })
            .monte_carlo(1000)
            .seed(7)
            .build()
            .unwrap();
        assert_eq!(job.n(), 8);
        assert!(matches!(job.spec, WorkSpec::MonteCarlo { samples: 1000, seed: 7 }));
    }

    #[test]
    fn typed_errors_on_the_builder_surface() {
        // Missing workload.
        let e = JobBuilder::new(MultiplierSpec::Accurate { n: 8 }).build().unwrap_err();
        assert_eq!(e.kind(), "workload");
        // Invalid design.
        let e = JobBuilder::new(MultiplierSpec::Segmented { n: 8, t: 9, fix: false })
            .monte_carlo(10)
            .build()
            .unwrap_err();
        assert_eq!(e.kind(), "spec");
        // Invalid workload parameters.
        let e = JobBuilder::new(MultiplierSpec::Accurate { n: 8 })
            .monte_carlo(0)
            .build()
            .unwrap_err();
        assert_eq!(e.kind(), "workload");
        // Exhaustive out of range.
        let e = JobBuilder::new(MultiplierSpec::Accurate { n: 20 })
            .exhaustive()
            .build()
            .unwrap_err();
        assert_eq!(e.kind(), "workload");
    }

    #[test]
    fn every_registry_design_round_trips_through_job_key() {
        for spec in MultiplierSpec::registry_examples(8) {
            let j1 = JobBuilder::new(spec).monte_carlo(100).seed(1).build().unwrap();
            let j2 = JobBuilder::new(spec).monte_carlo(100).seed(1).build().unwrap();
            assert_eq!(j1.key(), j2.key(), "{}", spec.name());
            assert_eq!(j1.key().design, spec.canonical(), "{}", spec.name());
        }
    }
}
