//! The session: a builder-configured handle owning the persistent worker
//! pool, the result cache, and the telemetry of one evaluation campaign.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::{
    AnalyticMode, Answer, ChunkEvent, ChunkPlan, CpuBackend, EvalBackend, EvalJob, JobResult,
    PjrtBackend, SweepGrid, SweepOutcome, SweepRunner,
};
use crate::fault::FaultInjector;
use crate::multiplier::{DispatchClass, MultiplierSpec};
use crate::store::ResultStore;
use crate::util::threadpool::default_workers;

use crate::error::SegmulError;
use super::job::JobBuilder;

/// Backend selection for a session.
#[derive(Clone, Debug)]
pub enum BackendChoice {
    /// The pure-Rust word-level backend (evaluates every design).
    Cpu,
    /// The PJRT backend over AOT artifacts in the given directory.
    Pjrt(PathBuf),
    /// PJRT when `manifest.json` exists in the directory, CPU otherwise
    /// (the decision is made at session build time).
    Auto(PathBuf),
}

impl BackendChoice {
    /// The backend factory this choice denotes (the `Auto` manifest probe
    /// runs now, once). The factory runs in each worker's thread — once
    /// per worker for a session/pool, once total for a direct build.
    pub fn into_factory(self) -> BackendFactory {
        match self {
            BackendChoice::Cpu => {
                Box::new(|| Ok(Box::new(CpuBackend::new()) as Box<dyn EvalBackend>))
            }
            BackendChoice::Pjrt(dir) => Box::new(move || {
                Ok(Box::new(PjrtBackend::load(&dir)?) as Box<dyn EvalBackend>)
            }),
            BackendChoice::Auto(dir) => {
                if dir.join("manifest.json").exists() {
                    BackendChoice::Pjrt(dir).into_factory()
                } else {
                    BackendChoice::Cpu.into_factory()
                }
            }
        }
    }
}

/// Streaming progress events, delivered synchronously on the submitting
/// thread — callers observe chunk completion without polling.
#[derive(Clone, Debug)]
pub enum ProgressEvent {
    /// A job was submitted (a cache hit finishes without chunk events).
    JobStarted {
        design: String,
        /// Planned chunk count (adaptive jobs may stop earlier).
        chunks: u64,
    },
    /// One chunk folded into the job's in-order prefix.
    ChunkMerged { merged: u64, chunks: u64, samples: u64 },
    /// A job completed (evaluated or served from the cache).
    JobFinished { design: String, cached: bool, samples: u64, wall: Duration },
}

/// Aggregate session counters.
#[derive(Clone, Debug, Default)]
pub struct SessionTelemetry {
    /// Jobs completed (any answer source).
    pub jobs_completed: u64,
    /// Jobs answered from the in-memory result cache.
    pub cache_hits: u64,
    /// Jobs actually evaluated on the pool.
    pub jobs_evaluated: u64,
    /// Jobs answered from the analytic model registry — no pool
    /// dispatch, counted separately from `cache_hits`.
    pub analytic_answers: u64,
    /// Jobs answered from a committed blob of the persistent result
    /// store — no evaluation, counted separately from `cache_hits`.
    pub store_hits: u64,
    /// Store degradations recovered from: resumed or discarded chunk
    /// journals and corrupt blobs demoted to re-evaluation.
    pub store_recoveries: u64,
    /// Transient failures recovered by a retry — the pool's per-chunk
    /// self-healing loop plus the store's lease-wait episodes.
    pub retries: u64,
    /// Retry episodes that exhausted their budget and surfaced the error
    /// (or degraded to evaluating without lease exclusion).
    pub gave_up: u64,
    /// Faults deliberately injected by the active [`FaultInjector`] plan
    /// (always 0 with injection disabled — the production state).
    pub faults_injected: u64,
    /// Operand pairs evaluated.
    pub pairs_evaluated: u64,
    /// Backend constructions since startup — stays at `workers` for the
    /// session's lifetime (the persistent-pool contract).
    pub backend_builds: u64,
    /// Worker threads in the pool.
    pub workers: usize,
    /// Kernel tier per evaluated design (union over the pool's workers,
    /// name-sorted): [`DispatchClass::Batched`] for a true batch kernel,
    /// [`DispatchClass::Pjrt`] for a lowered accelerator module, and
    /// [`DispatchClass::Scalar`] for a per-pair fallback. Every registry
    /// design runs batched on the CPU backend and lowered on the PJRT
    /// backend (after `segmul lower`); a `Scalar` entry here means a
    /// sweep silently regressed to per-pair dispatch, and a non-`Pjrt`
    /// entry on an accelerator sweep means a design fell back to the CPU
    /// tier (`segmul sweep --require-pjrt` gates on both).
    pub kernel_dispatch: Vec<(String, DispatchClass)>,
}

impl SessionTelemetry {
    /// Designs that ran on a per-pair scalar fallback (empty on a healthy
    /// sweep).
    pub fn scalar_fallbacks(&self) -> Vec<&str> {
        self.kernel_dispatch
            .iter()
            .filter(|(_, c)| *c == DispatchClass::Scalar)
            .map(|(n, _)| n.as_str())
            .collect()
    }

    /// Designs dispatched through a lowered PJRT module.
    pub fn pjrt_dispatches(&self) -> Vec<&str> {
        self.kernel_dispatch
            .iter()
            .filter(|(_, c)| *c == DispatchClass::Pjrt)
            .map(|(n, _)| n.as_str())
            .collect()
    }

    /// Designs that did **not** dispatch through a lowered PJRT module —
    /// the offenders a `--require-pjrt` sweep names (empty when the whole
    /// sweep ran on lowered modules).
    pub fn non_pjrt_dispatches(&self) -> Vec<&str> {
        self.kernel_dispatch
            .iter()
            .filter(|(_, c)| *c != DispatchClass::Pjrt)
            .map(|(n, _)| n.as_str())
            .collect()
    }
}

type ProgressCallback = Box<dyn Fn(ProgressEvent) + Send + Sync>;

/// A backend constructor, invoked once per worker thread.
pub type BackendFactory = Box<dyn Fn() -> anyhow::Result<Box<dyn EvalBackend>> + Send + Sync>;

/// Builder for [`Session`].
///
/// # Example
///
/// A single-worker session with the analytic fast path: the paper-grid
/// point below is answered in closed form, so the pool is never
/// dispatched.
///
/// ```
/// use segmul::api::{AnalyticMode, MultiplierSpec, Session};
///
/// let mut session = Session::builder()
///     .workers(1)
///     .analytic(AnalyticMode::Require)
///     .build()?;
/// let job = session
///     .job(MultiplierSpec::Segmented { n: 8, t: 4, fix: true })
///     .exhaustive()
///     .build()?;
/// let metrics = session.run_outcome(&job)?.metrics()?;
/// assert!(metrics.mred > 0.0);
/// assert_eq!(session.jobs_evaluated(), 0); // closed form, zero dispatches
/// # Ok::<(), segmul::error::SegmulError>(())
/// ```
pub struct SessionBuilder {
    workers: Option<usize>,
    backend: BackendChoice,
    factory: Option<BackendFactory>,
    cache: bool,
    analytic: AnalyticMode,
    store: Option<PathBuf>,
    store_wait: Option<Duration>,
    seed: u64,
    progress: Option<ProgressCallback>,
    faults: Option<Arc<FaultInjector>>,
}

impl SessionBuilder {
    fn new() -> Self {
        SessionBuilder {
            workers: None,
            backend: BackendChoice::Cpu,
            factory: None,
            cache: true,
            analytic: AnalyticMode::Off,
            store: None,
            store_wait: None,
            seed: 0,
            progress: None,
            faults: None,
        }
    }

    /// Worker-thread count. Unset: `SEGMUL_WORKERS` when present (a
    /// typed [`SegmulError::Config`] if it is `0` or unparsable), else
    /// the machine's available parallelism. Explicit `0` is rejected at
    /// [`Self::build`].
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = Some(workers);
        self
    }

    /// Select a built-in backend (default: [`BackendChoice::Cpu`]).
    pub fn backend(mut self, choice: BackendChoice) -> Self {
        self.backend = choice;
        self
    }

    /// Provide a custom backend factory (overrides [`Self::backend`]).
    /// It runs once in each worker's thread at session build time.
    pub fn backend_factory<F>(mut self, factory: F) -> Self
    where
        F: Fn() -> anyhow::Result<Box<dyn EvalBackend>> + Send + Sync + 'static,
    {
        self.factory = Some(Box::new(factory));
        self
    }

    /// Enable or disable the result cache (default: enabled).
    pub fn cache(mut self, enabled: bool) -> Self {
        self.cache = enabled;
        self
    }

    /// Answer-source policy (default [`AnalyticMode::Off`]): `Auto`
    /// serves exactly-modeled designs from closed forms without touching
    /// the pool; `Require` serves every modeled design — the
    /// zero-dispatch mode for design-space queries. Analytic answers
    /// surface through [`Session::run_outcome`] / [`Session::run_grid`]
    /// and are counted in [`SessionTelemetry::analytic_answers`].
    pub fn analytic(mut self, mode: AnalyticMode) -> Self {
        self.analytic = mode;
        self
    }

    /// Attach a persistent on-disk result store rooted at `dir`
    /// ([`crate::store::ResultStore`], opened at [`Self::build`]):
    /// committed results answer future sessions without re-evaluation,
    /// running jobs checkpoint per chunk so a killed sweep resumes
    /// bit-identically (`segmul sweep --resume`), and per-key leases
    /// keep cooperating processes (`--shard i/n`) from evaluating a key
    /// twice.
    pub fn store(mut self, dir: impl Into<PathBuf>) -> Self {
        self.store = Some(dir.into());
        self
    }

    /// Bound the wait on another live process's store lease (default
    /// 600 s); past it this session evaluates without exclusion.
    pub fn store_wait(mut self, wait: Duration) -> Self {
        self.store_wait = Some(wait);
        self
    }

    /// Default RNG seed applied to jobs built through [`Session::job`].
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Register a streaming progress callback (chunk merges, job
    /// completion). Called synchronously on the submitting thread.
    pub fn on_progress<F>(mut self, callback: F) -> Self
    where
        F: Fn(ProgressEvent) + Send + Sync + 'static,
    {
        self.progress = Some(Box::new(callback));
        self
    }

    /// Use an explicit fault-injection plan instead of the environment's
    /// (`SEGMUL_FAULTS`). The same injector is threaded through the pool
    /// workers and the store seams, so
    /// [`SessionTelemetry::faults_injected`] is one process-wide account.
    pub fn faults(mut self, faults: Arc<FaultInjector>) -> Self {
        self.faults = Some(faults);
        self
    }

    /// Spawn the persistent pool and produce the session. Each worker
    /// thread constructs its backend exactly once, here; every job the
    /// session ever runs reuses them.
    pub fn build(self) -> Result<Session, SegmulError> {
        let workers = match self.workers {
            Some(0) => {
                return Err(SegmulError::config(
                    "workers = 0: a session needs at least one worker",
                ))
            }
            Some(w) => w,
            None => default_workers()?,
        };
        let factory: BackendFactory = match self.factory {
            Some(f) => f,
            None => self.backend.into_factory(),
        };
        let faults = match self.faults {
            Some(f) => f,
            None => FaultInjector::from_env()?,
        };
        let mut runner = SweepRunner::new_with_faults(factory, workers, faults.clone())
            .map_err(|e| SegmulError::Backend(e.to_string()))?;
        runner.set_cache_enabled(self.cache);
        runner.set_analytic_mode(self.analytic);
        if let Some(dir) = self.store {
            runner.set_store(ResultStore::open_with_faults(dir, faults.clone())?);
        }
        if let Some(wait) = self.store_wait {
            runner.set_store_wait(wait);
        }
        Ok(Session {
            runner,
            faults,
            seed: self.seed,
            progress: self.progress,
            jobs_completed: 0,
            pairs_evaluated: 0,
        })
    }
}

/// The single entry point for evaluating designs: owns long-lived worker
/// threads that hold a backend **across jobs** (replacing per-job backend
/// construction), a canonical-keyed result cache, and the session
/// telemetry. Construct with [`Session::builder`].
///
/// ```no_run
/// use segmul::api::{BackendChoice, MultiplierSpec, Session};
///
/// let mut session = Session::builder()
///     .workers(4)
///     .backend(BackendChoice::Cpu)
///     .seed(42)
///     .build()?;
/// let job = session
///     .job(MultiplierSpec::Segmented { n: 16, t: 7, fix: true })
///     .monte_carlo(1 << 20)
///     .build()?;
/// let result = session.run(&job)?;
/// println!("ER = {}", result.metrics()?.er);
/// # Ok::<(), segmul::api::SegmulError>(())
/// ```
pub struct Session {
    runner: SweepRunner,
    faults: Arc<FaultInjector>,
    seed: u64,
    progress: Option<ProgressCallback>,
    jobs_completed: u64,
    pairs_evaluated: u64,
}

impl Session {
    /// A [`SessionBuilder`] with defaults.
    pub fn builder() -> SessionBuilder {
        SessionBuilder::new()
    }

    /// A [`JobBuilder`] pre-seeded with the session's RNG seed policy.
    pub fn job(&self, design: MultiplierSpec) -> JobBuilder {
        JobBuilder::new(design).seed(self.seed)
    }

    /// Worker threads in the persistent pool.
    pub fn workers(&self) -> usize {
        self.runner.workers()
    }

    /// Backend constructions since startup (one per worker, ever).
    pub fn backend_builds(&self) -> u64 {
        self.runner.pool().backend_builds()
    }

    /// Name of the backend the pool workers hold.
    pub fn backend_name(&self) -> &'static str {
        self.runner.pool().backend_name()
    }

    /// Chunk batch size of the pool's backend (fixes the MC
    /// chunk-to-stream layout, and with it the store-key identity).
    pub fn batch(&self) -> usize {
        self.runner.pool().batch()
    }

    /// Jobs answered from the in-memory result cache.
    pub fn cache_hits(&self) -> u64 {
        self.runner.cache_hits
    }

    /// Jobs actually evaluated on the pool.
    pub fn jobs_evaluated(&self) -> u64 {
        self.runner.jobs_evaluated
    }

    /// Jobs answered from the analytic model registry.
    pub fn analytic_answers(&self) -> u64 {
        self.runner.analytic_answers
    }

    /// Jobs answered from a committed blob of the persistent store.
    pub fn store_hits(&self) -> u64 {
        self.runner.store_hits
    }

    /// Store degradations recovered from (resumed / discarded journals,
    /// corrupt blobs demoted to re-evaluation).
    pub fn store_recoveries(&self) -> u64 {
        self.runner.store_recoveries
    }

    /// The attached persistent store, if the builder configured one.
    pub fn store(&self) -> Option<&ResultStore> {
        self.runner.store()
    }

    /// The session's fault-injection plan (disabled in production).
    pub fn faults(&self) -> &Arc<FaultInjector> {
        &self.faults
    }

    /// Transient failures recovered by a retry, across the pool's
    /// per-chunk loop and the store's lease waits.
    pub fn retries(&self) -> u64 {
        self.runner.pool().retry_counters().retries() + self.runner.lease_retry_counters().retries()
    }

    /// Retry episodes that exhausted their budget.
    pub fn gave_up(&self) -> u64 {
        self.runner.pool().retry_counters().gave_up() + self.runner.lease_retry_counters().gave_up()
    }

    /// The configured answer-source policy.
    pub fn analytic_mode(&self) -> AnalyticMode {
        self.runner.analytic_mode()
    }

    /// Kernel tier per evaluated design, unioned over the pool's workers
    /// (see [`SessionTelemetry::kernel_dispatch`]).
    pub fn kernel_dispatch(&self) -> Vec<(String, DispatchClass)> {
        self.runner.pool().kernel_dispatch()
    }

    /// Aggregate telemetry snapshot.
    pub fn telemetry(&self) -> SessionTelemetry {
        SessionTelemetry {
            jobs_completed: self.jobs_completed,
            cache_hits: self.runner.cache_hits,
            jobs_evaluated: self.runner.jobs_evaluated,
            analytic_answers: self.runner.analytic_answers,
            store_hits: self.runner.store_hits,
            store_recoveries: self.runner.store_recoveries,
            retries: self.retries(),
            gave_up: self.gave_up(),
            faults_injected: self.faults.total_injected(),
            pairs_evaluated: self.pairs_evaluated,
            backend_builds: self.backend_builds(),
            workers: self.workers(),
            kernel_dispatch: self.kernel_dispatch(),
        }
    }

    /// Evaluate one job through the cache and the persistent pool,
    /// streaming progress to the registered callback. Requires a
    /// *simulated* answer: if the session's [`AnalyticMode`] elects to
    /// answer analytically, this reports a typed config error — consume
    /// analytic answers through [`Self::run_outcome`].
    pub fn run(&mut self, job: &EvalJob) -> Result<JobResult, SegmulError> {
        let outcome = self.run_outcome(job)?;
        match outcome.answer {
            Answer::Simulated(r) => Ok(r),
            Answer::Analytic { .. } => Err(SegmulError::config(format!(
                "job {} was answered analytically (mode {}); use run_outcome() for analytic answers",
                job.design.name(),
                self.runner.analytic_mode().name()
            ))),
        }
    }

    /// [`Self::run`], additionally reporting the answer source and
    /// whether the cache served it.
    pub fn run_outcome(&mut self, job: &EvalJob) -> Result<SweepOutcome, SegmulError> {
        // Validate and capability-check here, before anything is wrapped
        // in `anyhow`, so the caller sees the precise Spec / Workload /
        // Backend class (the vendored anyhow shim flattens messages and
        // cannot downcast).
        job.validate()?;
        let analytic = self.runner.will_answer_analytically(job);
        if !analytic {
            // Points the analytic layer answers never reach the pool, so
            // backend capability (e.g. a missing lowered module) is
            // irrelevant for them.
            self.runner.pool().preflight(job)?;
        }
        let progress = self.progress.as_deref();
        if let Some(cb) = progress {
            let chunks = if analytic {
                0
            } else {
                ChunkPlan::new(job, self.runner.pool().batch()).n_chunks()
            };
            cb(ProgressEvent::JobStarted { design: job.design.name(), chunks });
        }
        let outcome = self
            .runner
            .run_observed(job, &mut |e: ChunkEvent| {
                if let Some(cb) = progress {
                    cb(ProgressEvent::ChunkMerged {
                        merged: e.merged,
                        chunks: e.n_chunks,
                        samples: e.samples,
                    });
                }
            })
            .map_err(SegmulError::from)?;
        self.jobs_completed += 1;
        if let Some(r) = outcome.result() {
            if !outcome.cached {
                self.pairs_evaluated += r.stats.count;
            }
        }
        if let Some(cb) = progress {
            cb(ProgressEvent::JobFinished {
                design: job.design.name(),
                cached: outcome.cached,
                samples: outcome.result().map_or(0, |r| r.stats.count),
                wall: outcome.wall(),
            });
        }
        Ok(outcome)
    }

    /// Run an explicit job list in order through the shared cache /
    /// store / pool path, calling `progress` once per completed point —
    /// the sharded path: each cooperating process runs its
    /// [`crate::coordinator::Shard`] slice of the grid against the
    /// shared store.
    pub fn run_jobs(
        &mut self,
        jobs: &[EvalJob],
        mut progress: impl FnMut(usize, usize, &SweepOutcome),
    ) -> Result<Vec<SweepOutcome>, SegmulError> {
        let total = jobs.len();
        let mut out = Vec::with_capacity(total);
        for (i, job) in jobs.iter().enumerate() {
            let outcome = self.run_outcome(job)?;
            progress(i, total, &outcome);
            out.push(outcome);
        }
        Ok(out)
    }

    /// Run a whole sweep grid in order ([`Self::run_jobs`] over
    /// [`SweepGrid::jobs`]).
    pub fn run_grid(
        &mut self,
        grid: &SweepGrid,
        progress: impl FnMut(usize, usize, &SweepOutcome),
    ) -> Result<Vec<SweepOutcome>, SegmulError> {
        self.run_jobs(&grid.jobs(), progress)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::run_job;

    #[test]
    fn builder_rejects_zero_workers_with_typed_error() {
        let e = Session::builder().workers(0).build().unwrap_err();
        assert_eq!(e.kind(), "config");
    }

    #[test]
    fn session_runs_jobs_and_counts() {
        let mut s = Session::builder().workers(2).seed(9).build().unwrap();
        let job = s
            .job(MultiplierSpec::Segmented { n: 8, t: 4, fix: true })
            .monte_carlo(50_000)
            .build()
            .unwrap();
        let r1 = s.run(&job).unwrap();
        assert_eq!(r1.stats.count, 50_000);
        // Session-seeded: the builder picked up seed 9.
        match job.spec {
            crate::coordinator::WorkSpec::MonteCarlo { seed, .. } => assert_eq!(seed, 9),
            _ => panic!("expected MC"),
        }
        let r2 = s.run(&job).unwrap();
        assert_eq!(r1.stats, r2.stats);
        assert_eq!(s.cache_hits(), 1);
        assert_eq!(s.jobs_evaluated(), 1);
        assert_eq!(s.telemetry().jobs_completed, 2);
        // Results equal the sequential driver bit-for-bit.
        let mut be = CpuBackend::new();
        let want = run_job(&mut be, &job).unwrap();
        assert_eq!(r1.stats, want.stats);
    }

    #[test]
    fn telemetry_reports_kernel_dispatch_per_design() {
        let mut s = Session::builder().workers(2).seed(4).build().unwrap();
        for design in [
            MultiplierSpec::Segmented { n: 8, t: 3, fix: false },
            MultiplierSpec::Truncated { n: 8, k: 2 },
            MultiplierSpec::Kulkarni { n: 8 },
        ] {
            let job = s.job(design).monte_carlo(100_000).build().unwrap();
            s.run(&job).unwrap();
        }
        let t = s.telemetry();
        assert_eq!(t.kernel_dispatch.len(), 3);
        assert!(
            t.scalar_fallbacks().is_empty(),
            "no registry design may run per-pair: {:?}",
            t.kernel_dispatch
        );
        assert!(t.kernel_dispatch.iter().all(|(_, c)| *c == DispatchClass::Batched));
    }

    #[test]
    fn analytic_auto_serves_exact_designs_without_dispatch() {
        let mut s = Session::builder()
            .workers(1)
            .analytic(AnalyticMode::Auto)
            .build()
            .unwrap();
        let job = s.job(MultiplierSpec::Truncated { n: 8, k: 4 }).exhaustive().build().unwrap();
        let outcome = s.run_outcome(&job).unwrap();
        assert_eq!(outcome.source(), "analytic");
        assert_eq!(outcome.metrics().unwrap().er, 0.8125);
        let t = s.telemetry();
        assert_eq!(t.analytic_answers, 1);
        assert_eq!(t.jobs_evaluated, 0);
        assert_eq!(t.pairs_evaluated, 0, "analytic answers evaluate nothing");
        // run() demands a simulated answer — typed error instead.
        let e = s.run(&job).unwrap_err();
        assert_eq!(e.kind(), "config");
        assert!(e.to_string().contains("run_outcome"), "{e}");
    }

    #[test]
    fn analytic_off_by_default() {
        let mut s = Session::builder().workers(1).build().unwrap();
        assert_eq!(s.analytic_mode(), AnalyticMode::Off);
        let job = s.job(MultiplierSpec::Truncated { n: 6, k: 2 }).exhaustive().build().unwrap();
        let outcome = s.run_outcome(&job).unwrap();
        assert_eq!(outcome.source(), "simulated");
        assert_eq!(s.analytic_answers(), 0);
    }

    #[test]
    fn store_round_trips_results_across_sessions() {
        let dir = std::env::temp_dir()
            .join(format!("segmul-session-store-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut first = Session::builder().workers(2).seed(3).store(&dir).build().unwrap();
        let job = first
            .job(MultiplierSpec::Segmented { n: 8, t: 3, fix: true })
            .monte_carlo(120_000)
            .build()
            .unwrap();
        let a = first.run(&job).unwrap();
        assert_eq!(first.jobs_evaluated(), 1);
        assert_eq!(first.store_hits(), 0);
        // A separate session (fresh pool, cold cache) over the same store
        // answers from the committed blob, bit for bit, with zero
        // evaluation.
        let mut second = Session::builder().workers(1).seed(3).store(&dir).build().unwrap();
        let b = second.run(&job).unwrap();
        assert_eq!(second.jobs_evaluated(), 0);
        assert_eq!(second.telemetry().store_hits, 1);
        assert_eq!(a.stats, b.stats);
        assert_eq!(a.stats.sum_red.to_bits(), b.stats.sum_red.to_bits());
        assert_eq!(a.batches, b.batches);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn progress_events_stream_chunk_merges() {
        use std::sync::{Arc, Mutex};
        let events: Arc<Mutex<Vec<ProgressEvent>>> = Arc::new(Mutex::new(Vec::new()));
        let sink = events.clone();
        let mut s = Session::builder()
            .workers(2)
            .on_progress(move |e| sink.lock().unwrap().push(e))
            .build()
            .unwrap();
        let job = s
            .job(MultiplierSpec::Segmented { n: 8, t: 2, fix: false })
            .monte_carlo(200_000)
            .build()
            .unwrap();
        let r = s.run(&job).unwrap();
        let before = {
            let log = events.lock().unwrap();
            let merges = log
                .iter()
                .filter(|e| matches!(e, ProgressEvent::ChunkMerged { .. }))
                .count() as u64;
            assert_eq!(merges, r.batches, "one event per in-order chunk merge");
            assert!(matches!(log.first(), Some(ProgressEvent::JobStarted { .. })));
            match log.last() {
                Some(ProgressEvent::JobFinished { cached, samples, .. }) => {
                    assert!(!cached);
                    assert_eq!(*samples, 200_000);
                }
                other => panic!("expected JobFinished, got {other:?}"),
            }
            log.len()
        };
        // Cache hit: no chunk merges, still a started + finished pair.
        let _ = s.run(&job).unwrap();
        let log = events.lock().unwrap();
        assert_eq!(log.len(), before + 2);
        match log.last() {
            Some(ProgressEvent::JobFinished { cached, .. }) => assert!(cached),
            other => panic!("expected JobFinished, got {other:?}"),
        }
    }
}
