//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! Each `rust/benches/*.rs` is a `harness = false` binary built on this:
//! adaptive iteration count (targets ~0.6 s of measurement per benchmark),
//! warmup, median-of-batches timing, and criterion-style one-line output
//! with optional throughput reporting. `SEGMUL_BENCH_FAST=1` shrinks the
//! measurement budget for CI smoke runs.

use std::time::{Duration, Instant};

/// Measurement budget per benchmark.
fn budget() -> Duration {
    if std::env::var_os("SEGMUL_BENCH_FAST").is_some() {
        Duration::from_millis(120)
    } else {
        Duration::from_millis(600)
    }
}

/// One benchmark result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub ns_per_iter: f64,
    /// Optional items processed per iteration (for throughput lines).
    pub items_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn report(&self) {
        let thr = match self.items_per_iter {
            Some(items) => {
                let per_sec = items / (self.ns_per_iter * 1e-9);
                if per_sec >= 1e6 {
                    format!("   thrpt: {:>10.3} Melem/s", per_sec / 1e6)
                } else {
                    format!("   thrpt: {:>10.1} elem/s", per_sec)
                }
            }
            None => String::new(),
        };
        println!(
            "{:<44} time: {:>12}/iter ({} iters){}",
            self.name,
            fmt_ns(self.ns_per_iter),
            self.iters,
            thr
        );
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Run one benchmark: calls `f(iters)` which must perform `iters`
/// repetitions and return a value to keep the optimizer honest.
pub fn bench<T, F: FnMut(u64) -> T>(name: &str, items_per_iter: Option<f64>, mut f: F) -> BenchResult {
    // calibration: find an iteration count that takes >= ~10ms
    let mut iters = 1u64;
    let cal = loop {
        let started = Instant::now();
        std::hint::black_box(f(iters));
        let dt = started.elapsed();
        if dt >= Duration::from_millis(10) || iters >= 1 << 24 {
            break dt;
        }
        iters *= 4;
    };
    // measurement: scale to the budget, run 5 batches, take the median
    let per_iter = cal.as_secs_f64() / iters as f64;
    let target_iters = ((budget().as_secs_f64() / 5.0) / per_iter.max(1e-12)) as u64;
    let iters = target_iters.clamp(1, 1 << 26).max(1);
    let mut samples: Vec<f64> = (0..5)
        .map(|_| {
            let started = Instant::now();
            std::hint::black_box(f(iters));
            started.elapsed().as_secs_f64() / iters as f64
        })
        .collect();
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let result = BenchResult {
        name: name.to_string(),
        iters,
        ns_per_iter: median * 1e9,
        items_per_iter,
    };
    result.report();
    result
}

/// Print a section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Throughput ratio of two results over the same workload (> 1 means
/// `new` is faster). Accounts for differing `items_per_iter`, so a
/// batched run and a scalar run of the same sweep compare directly.
pub fn speedup(new: &BenchResult, old: &BenchResult) -> f64 {
    let per_item_new = new.ns_per_iter / new.items_per_iter.unwrap_or(1.0);
    let per_item_old = old.ns_per_iter / old.items_per_iter.unwrap_or(1.0);
    per_item_old / per_item_new
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        std::env::set_var("SEGMUL_BENCH_FAST", "1");
        let r = bench("noop-sum", Some(1000.0), |iters| {
            let mut acc = 0u64;
            for i in 0..iters * 1000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert!(r.ns_per_iter > 0.0);
        assert!(r.iters >= 1);
    }

    #[test]
    fn speedup_accounts_for_items() {
        let mk = |ns: f64, items: Option<f64>| BenchResult {
            name: "x".into(),
            iters: 1,
            ns_per_iter: ns,
            items_per_iter: items,
        };
        // 100 ns for 10 items vs 100 ns for 1 item: 10x.
        assert!((speedup(&mk(100.0, Some(10.0)), &mk(100.0, Some(1.0))) - 10.0).abs() < 1e-12);
        // Same workload, half the time: 2x.
        assert!((speedup(&mk(50.0, None), &mk(100.0, None)) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn ns_formatting() {
        assert!(fmt_ns(5.0).contains("ns"));
        assert!(fmt_ns(5e4).contains("µs"));
        assert!(fmt_ns(5e7).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
