//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! Each `rust/benches/*.rs` is a `harness = false` binary built on this:
//! adaptive iteration count (targets ~0.6 s of measurement per benchmark),
//! warmup, median-of-batches timing, and criterion-style one-line output
//! with optional throughput reporting. `SEGMUL_BENCH_FAST=1` shrinks the
//! measurement budget for CI smoke runs.
//!
//! Benches additionally publish a machine-readable [`Summary`]
//! (`BENCH_<name>.json`) that the CI bench-regression gate
//! (`bench-gate`, see [`gate_compare`]) checks against the committed
//! `ci/bench_baseline.json`.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use crate::util::json::{obj, Json};

/// Measurement budget per benchmark.
fn budget() -> Duration {
    if std::env::var_os("SEGMUL_BENCH_FAST").is_some() {
        Duration::from_millis(120)
    } else {
        Duration::from_millis(600)
    }
}

/// One benchmark result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Benchmark label.
    pub name: String,
    /// Iterations executed inside the timed window.
    pub iters: u64,
    /// Mean wall-clock nanoseconds per iteration.
    pub ns_per_iter: f64,
    /// Optional items processed per iteration (for throughput lines).
    pub items_per_iter: Option<f64>,
}

impl BenchResult {
    /// Print the human-readable result line (time/iter + optional throughput).
    pub fn report(&self) {
        let thr = match self.items_per_iter {
            Some(items) => {
                let per_sec = items / (self.ns_per_iter * 1e-9);
                if per_sec >= 1e6 {
                    format!("   thrpt: {:>10.3} Melem/s", per_sec / 1e6)
                } else {
                    format!("   thrpt: {:>10.1} elem/s", per_sec)
                }
            }
            None => String::new(),
        };
        println!(
            "{:<44} time: {:>12}/iter ({} iters){}",
            self.name,
            fmt_ns(self.ns_per_iter),
            self.iters,
            thr
        );
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Run one benchmark: calls `f(iters)` which must perform `iters`
/// repetitions and return a value to keep the optimizer honest.
pub fn bench<T, F: FnMut(u64) -> T>(name: &str, items_per_iter: Option<f64>, mut f: F) -> BenchResult {
    // calibration: find an iteration count that takes >= ~10ms
    let mut iters = 1u64;
    let cal = loop {
        let started = Instant::now();
        std::hint::black_box(f(iters));
        let dt = started.elapsed();
        if dt >= Duration::from_millis(10) || iters >= 1 << 24 {
            break dt;
        }
        iters *= 4;
    };
    // measurement: scale to the budget, run 5 batches, take the median
    let per_iter = cal.as_secs_f64() / iters as f64;
    let target_iters = ((budget().as_secs_f64() / 5.0) / per_iter.max(1e-12)) as u64;
    let iters = target_iters.clamp(1, 1 << 26).max(1);
    let mut samples: Vec<f64> = (0..5)
        .map(|_| {
            let started = Instant::now();
            std::hint::black_box(f(iters));
            started.elapsed().as_secs_f64() / iters as f64
        })
        .collect();
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let result = BenchResult {
        name: name.to_string(),
        iters,
        ns_per_iter: median * 1e9,
        items_per_iter,
    };
    result.report();
    result
}

/// Print a section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Throughput ratio of two results over the same workload (> 1 means
/// `new` is faster). Accounts for differing `items_per_iter`, so a
/// batched run and a scalar run of the same sweep compare directly.
pub fn speedup(new: &BenchResult, old: &BenchResult) -> f64 {
    let per_item_new = new.ns_per_iter / new.items_per_iter.unwrap_or(1.0);
    let per_item_old = old.ns_per_iter / old.items_per_iter.unwrap_or(1.0);
    per_item_old / per_item_new
}

/// Items processed per second (`None` without an item count).
pub fn throughput(r: &BenchResult) -> Option<f64> {
    r.items_per_iter.map(|items| items / (r.ns_per_iter * 1e-9))
}

/// Machine-readable bench summary: named scalar metrics (speedups,
/// Melem/s, ...) written to `BENCH_<bench>.json` for the CI gate.
pub struct Summary {
    bench: String,
    metrics: Vec<(String, f64)>,
}

impl Summary {
    /// Start an empty summary for bench `bench`.
    pub fn new(bench: &str) -> Self {
        Summary { bench: bench.to_string(), metrics: Vec::new() }
    }

    /// Record one named metric (higher is better by gate convention).
    pub fn metric(&mut self, name: &str, value: f64) -> &mut Self {
        self.metrics.push((name.to_string(), value));
        self
    }

    /// The summary as its `BENCH_*.json` object (`bench` name + `metrics` map).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("bench", Json::from(self.bench.as_str())),
            (
                "metrics",
                Json::Obj(self.metrics.iter().map(|(k, v)| (k.clone(), Json::from(*v))).collect()),
            ),
        ])
    }

    /// Write `BENCH_<bench>.json` into `$SEGMUL_BENCH_DIR` (default:
    /// `target/bench-json`), returning the path.
    pub fn write(&self) -> std::io::Result<PathBuf> {
        let dir = std::env::var_os("SEGMUL_BENCH_DIR")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("target/bench-json"));
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("BENCH_{}.json", self.bench));
        std::fs::write(&path, self.to_json().to_string_pretty())?;
        println!("bench summary -> {}", path.display());
        Ok(path)
    }
}

/// One bench-gate verdict.
#[derive(Clone, Debug)]
pub struct GateCheck {
    /// Metric name from the committed baseline.
    pub metric: String,
    /// Baseline value (the committed reference).
    pub baseline: f64,
    /// Measured value (`None`: metric missing from every current file).
    pub current: Option<f64>,
    /// Lowest acceptable value, `baseline * (1 - tolerance)`.
    pub floor: f64,
    /// Whether this metric fails the workflow (informational otherwise).
    pub gated: bool,
    /// `true` when the measured value is at or above the floor.
    pub pass: bool,
}

/// Compare bench summaries against the committed baseline.
///
/// Baseline format (`ci/bench_baseline.json`):
/// `{"tolerance": 0.15, "metrics": {"<name>": {"value": v, "gate": true,
/// "note": "..."}}}` — metrics are higher-is-better; `gate: false` marks
/// a metric as informational (reported, never failing); a per-metric
/// `"tolerance"` overrides the document default. Every **gated**
/// baseline metric must appear in some current summary — a silently
/// dropped benchmark is itself a failure.
pub fn gate_compare(baseline: &Json, currents: &[Json], default_tolerance: f64) -> Vec<GateCheck> {
    let tol_doc = baseline.get("tolerance").and_then(|t| t.as_f64()).unwrap_or(default_tolerance);
    let mut lookup = std::collections::BTreeMap::new();
    for cur in currents {
        if let Some(Json::Obj(m)) = cur.get("metrics") {
            for (k, v) in m {
                if let Some(x) = v.as_f64() {
                    lookup.insert(k.clone(), x);
                }
            }
        }
    }
    let mut out = Vec::new();
    if let Some(Json::Obj(metrics)) = baseline.get("metrics") {
        for (name, spec) in metrics {
            let Some(value) = spec.get("value").and_then(|v| v.as_f64()) else {
                continue;
            };
            let gated = spec.get("gate").and_then(|g| g.as_bool()).unwrap_or(true);
            let tol = spec.get("tolerance").and_then(|t| t.as_f64()).unwrap_or(tol_doc);
            let floor = value * (1.0 - tol);
            let current = lookup.get(name).copied();
            let pass = !gated || current.map(|c| c >= floor).unwrap_or(false);
            out.push(GateCheck { metric: name.clone(), baseline: value, current, floor, gated, pass });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        std::env::set_var("SEGMUL_BENCH_FAST", "1");
        let r = bench("noop-sum", Some(1000.0), |iters| {
            let mut acc = 0u64;
            for i in 0..iters * 1000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert!(r.ns_per_iter > 0.0);
        assert!(r.iters >= 1);
    }

    #[test]
    fn speedup_accounts_for_items() {
        let mk = |ns: f64, items: Option<f64>| BenchResult {
            name: "x".into(),
            iters: 1,
            ns_per_iter: ns,
            items_per_iter: items,
        };
        // 100 ns for 10 items vs 100 ns for 1 item: 10x.
        assert!((speedup(&mk(100.0, Some(10.0)), &mk(100.0, Some(1.0))) - 10.0).abs() < 1e-12);
        // Same workload, half the time: 2x.
        assert!((speedup(&mk(50.0, None), &mk(100.0, None)) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn ns_formatting() {
        assert!(fmt_ns(5.0).contains("ns"));
        assert!(fmt_ns(5e4).contains("µs"));
        assert!(fmt_ns(5e7).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }

    #[test]
    fn throughput_from_result() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            ns_per_iter: 1e9, // one second per iter
            items_per_iter: Some(500.0),
        };
        assert!((throughput(&r).unwrap() - 500.0).abs() < 1e-9);
        assert!(throughput(&BenchResult { items_per_iter: None, ..r }).is_none());
    }

    #[test]
    fn summary_serializes_metrics() {
        let mut s = Summary::new("demo");
        s.metric("speedup", 3.5).metric("melem_per_s", 120.0);
        let j = s.to_json();
        assert_eq!(j.get("bench").unwrap().as_str(), Some("demo"));
        assert_eq!(j.get("metrics").unwrap().get("speedup").unwrap().as_f64(), Some(3.5));
    }

    fn baseline_doc() -> Json {
        Json::parse(
            r#"{
              "tolerance": 0.15,
              "metrics": {
                "speedup": {"value": 3.0},
                "absolute": {"value": 100.0, "gate": false},
                "tight": {"value": 10.0, "tolerance": 0.0}
              }
            }"#,
        )
        .unwrap()
    }

    fn current_doc(speedup: f64, tight: f64) -> Json {
        Json::parse(&format!(
            r#"{{"bench": "demo", "metrics": {{"speedup": {speedup}, "tight": {tight}}}}}"#
        ))
        .unwrap()
    }

    #[test]
    fn gate_passes_within_tolerance() {
        // speedup floor = 3.0 * 0.85 = 2.55; tight floor = 10.0 exactly.
        let checks = gate_compare(&baseline_doc(), &[current_doc(2.6, 10.0)], 0.15);
        assert_eq!(checks.len(), 3);
        assert!(checks.iter().all(|c| c.pass), "{checks:?}");
        // "absolute" is informational: missing from current, still passes.
        let abs = checks.iter().find(|c| c.metric == "absolute").unwrap();
        assert!(!abs.gated && abs.current.is_none() && abs.pass);
    }

    #[test]
    fn gate_fails_beyond_tolerance() {
        let checks = gate_compare(&baseline_doc(), &[current_doc(2.5, 10.0)], 0.15);
        let sp = checks.iter().find(|c| c.metric == "speedup").unwrap();
        assert!(!sp.pass, "2.5 < floor {}", sp.floor);
        // Per-metric zero tolerance gates exactly.
        let checks = gate_compare(&baseline_doc(), &[current_doc(3.0, 9.99)], 0.15);
        assert!(!checks.iter().find(|c| c.metric == "tight").unwrap().pass);
    }

    #[test]
    fn gate_fails_on_missing_gated_metric() {
        let current = Json::parse(r#"{"bench": "demo", "metrics": {"tight": 10.0}}"#).unwrap();
        let checks = gate_compare(&baseline_doc(), &[current], 0.15);
        let sp = checks.iter().find(|c| c.metric == "speedup").unwrap();
        assert!(sp.current.is_none() && !sp.pass, "dropped benchmarks must fail the gate");
    }

    #[test]
    fn gate_merges_multiple_current_files() {
        let a = Json::parse(r#"{"metrics": {"speedup": 3.2}}"#).unwrap();
        let b = Json::parse(r#"{"metrics": {"tight": 11.0}}"#).unwrap();
        let checks = gate_compare(&baseline_doc(), &[a, b], 0.15);
        assert!(checks.iter().all(|c| c.pass), "{checks:?}");
    }
}
