//! The accuracy-budget autotuner and Pareto explorer (`segmul tune`).
//!
//! The paper's contribution is accuracy *configurability*: the split
//! point `t` trades error for carry-chain latency. This module closes
//! the loop — instead of hand-picking `(design, n, t, fix)`, callers
//! state an accuracy budget ([`Budget`]: `mred <= x`, `nmed <= x`,
//! `wce <= x`, or a PSNR target mapped to MRED) and the tuner returns
//! the cheapest configuration meeting it plus the full accuracy ×
//! latency × area/power Pareto frontier.
//!
//! **Answer-source ladder** (the invariant: never evaluate the same
//! point twice, and never dispatch the pool when a model can answer):
//! every grid point's error metrics flow through
//! [`crate::api::Session::run_outcome`], so the session's configured
//! [`crate::coordinator::AnalyticMode`] decides the source —
//! closed-form registry models first (`require` answers the full paper
//! grid with **zero** pool dispatches), then the in-memory cache and
//! the persistent [`crate::store::ResultStore`] when attached, and only
//! then simulation on the worker pool. Hardware cost comes from the
//! [`crate::tech`] FPGA/ASIC models over the generated gate-level
//! netlist, with the paper's power-fairness convention: approximate
//! points are power-evaluated at the accurate design's pinned clock
//! while latency keeps each point's own achievable period.
//!
//! **Frontier definition**: a candidate is on the frontier iff no other
//! candidate is at least as good in *every* objective (budget-metric
//! error, latency, resource, total power) and strictly better in one —
//! computed by [`pareto_frontier`], which the property suite
//! cross-checks against brute force.
//!
//! ```
//! use segmul::api::{AnalyticMode, Session};
//! use segmul::tune::{tune, Budget, TuneQuery};
//!
//! // "Cheapest FPGA config with MRED at or below 1e-2, n = 8."
//! let query = TuneQuery::new(Budget::parse("mred<=1e-2")?)
//!     .bitwidths(vec![8])
//!     .hw_vectors(64);
//! let mut session = Session::builder()
//!     .workers(1)
//!     .analytic(AnalyticMode::Require) // closed forms: zero dispatches
//!     .build()?;
//! let result = tune(&mut session, &query)?;
//! let best = result.winner().expect("the accurate point is always feasible");
//! assert!(best.feasible);
//! assert_eq!(session.jobs_evaluated(), 0); // nothing simulated
//! # Ok::<(), segmul::api::SegmulError>(())
//! ```

use std::collections::HashMap;
use std::time::{Duration, Instant};

use crate::api::Session;
use crate::error::metrics::ErrorMetrics;
use crate::error::SegmulError;
use crate::multiplier::{DesignSet, MultiplierSpec};
use crate::netlist::generators::seq_mult::seq_mult;
use crate::report::csv::{f, Table};
use crate::tech::{measure_activity, AsicModel, FpgaModel, HwFigures};
use crate::util::json::{obj, Json};

/// Which error metric an accuracy budget bounds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BudgetMetric {
    /// Mean relative error distance (paper Eq. 8).
    Mred,
    /// Normalized mean error distance (paper Eq. 7).
    Nmed,
    /// Worst-case (maximum absolute) error.
    Wce,
}

impl BudgetMetric {
    /// Canonical lower-case name (`mred` / `nmed` / `wce`).
    pub fn name(&self) -> &'static str {
        match self {
            BudgetMetric::Mred => "mred",
            BudgetMetric::Nmed => "nmed",
            BudgetMetric::Wce => "wce",
        }
    }

    /// Extract this metric's value from a derived metric set.
    pub fn value_of(&self, m: &ErrorMetrics) -> f64 {
        match self {
            BudgetMetric::Mred => m.mred,
            BudgetMetric::Nmed => m.nmed,
            BudgetMetric::Wce => m.mae as f64,
        }
    }
}

/// A parsed accuracy budget: "`metric` must not exceed `max`".
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Budget {
    /// The bounded metric.
    pub metric: BudgetMetric,
    /// Inclusive upper bound on the metric.
    pub max: f64,
    /// When the budget was stated as a PSNR target (`psnr>=30`), the
    /// original dB figure — kept for display; `metric`/`max` carry the
    /// derived MRED bound.
    pub psnr_db: Option<f64>,
}

impl Budget {
    /// An MRED budget (`mred <= max`).
    pub fn mred(max: f64) -> Budget {
        Budget { metric: BudgetMetric::Mred, max, psnr_db: None }
    }

    /// An NMED budget (`nmed <= max`).
    pub fn nmed(max: f64) -> Budget {
        Budget { metric: BudgetMetric::Nmed, max, psnr_db: None }
    }

    /// A worst-case-error budget (`wce <= max`).
    pub fn wce(max: f64) -> Budget {
        Budget { metric: BudgetMetric::Wce, max, psnr_db: None }
    }

    /// Map a PSNR target (dB) to an MRED budget: treating MRED as the
    /// relative RMS error proxy of the multiplier output, a signal
    /// quality of `P` dB requires a relative error at or below
    /// `10^(-P/20)` (e.g. 60 dB → MRED ≤ 1e-3).
    pub fn from_psnr(db: f64) -> Budget {
        Budget {
            metric: BudgetMetric::Mred,
            max: 10f64.powf(-db / 20.0),
            psnr_db: Some(db),
        }
    }

    /// Parse a budget expression: `mred<=1e-3`, `nmed<=0.01`,
    /// `wce<=4096`, or `psnr>=30` (mapped through [`Budget::from_psnr`]).
    /// A bare `=` is accepted in place of `<=` / `>=`. Anything else is a
    /// typed [`SegmulError::Config`].
    pub fn parse(s: &str) -> Result<Budget, SegmulError> {
        let text: String = s.chars().filter(|c| !c.is_whitespace()).collect();
        let bad = || {
            SegmulError::config(format!(
                "unparsable budget {s:?} (expected mred<=X, nmed<=X, wce<=X, or psnr>=X)"
            ))
        };
        let (name, op, value) = ["<=", ">=", "="]
            .iter()
            .find_map(|op| text.split_once(op).map(|(a, b)| (a, *op, b)))
            .ok_or_else(bad)?;
        let value: f64 = value.parse().map_err(|_| bad())?;
        if !value.is_finite() || value < 0.0 {
            return Err(SegmulError::config(format!(
                "budget bound {value} must be finite and non-negative"
            )));
        }
        match (name, op) {
            ("mred", "<=") | ("mred", "=") => Ok(Budget::mred(value)),
            ("nmed", "<=") | ("nmed", "=") => Ok(Budget::nmed(value)),
            ("wce", "<=") | ("wce", "=") => Ok(Budget::wce(value)),
            ("psnr", ">=") | ("psnr", "=") => Ok(Budget::from_psnr(value)),
            _ => Err(bad()),
        }
    }

    /// Does a metric set satisfy this budget?
    pub fn admits(&self, m: &ErrorMetrics) -> bool {
        self.metric.value_of(m) <= self.max
    }

    /// Canonical display / coalesce form, e.g. `mred<=0.001` or
    /// `psnr>=30 (mred<=0.0316...)`.
    pub fn canonical(&self) -> String {
        match self.psnr_db {
            Some(db) => format!("psnr>={db} ({}<={})", self.metric.name(), self.max),
            None => format!("{}<={}", self.metric.name(), self.max),
        }
    }
}

/// The hardware technology a tune query optimizes for.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum TechTarget {
    /// The Xilinx-7-series-class FPGA model (LUTs as the resource).
    #[default]
    Fpga,
    /// The 45 nm-class ASIC model (µm² as the resource).
    Asic,
}

impl TechTarget {
    /// Canonical lower-case name.
    pub fn name(&self) -> &'static str {
        match self {
            TechTarget::Fpga => "fpga",
            TechTarget::Asic => "asic",
        }
    }

    /// Parse a CLI / wire name.
    pub fn parse(s: &str) -> Result<TechTarget, SegmulError> {
        match s.trim() {
            "fpga" => Ok(TechTarget::Fpga),
            "asic" => Ok(TechTarget::Asic),
            other => {
                Err(SegmulError::config(format!("unknown target {other:?} (fpga|asic)")))
            }
        }
    }
}

/// One autotuning request: an accuracy budget plus grid constraints.
///
/// Defaults match the paper's evaluation: the full segmented grid
/// ([`DesignSet::Paper`]) over `n ∈ {4, 8, 16, 32}`, both fix modes,
/// FPGA target. Constructed with [`TuneQuery::new`] and narrowed with
/// the builder-style setters.
///
/// ```
/// use segmul::tune::{Budget, TechTarget, TuneQuery};
///
/// let q = TuneQuery::new(Budget::parse("psnr>=40")?)
///     .target(TechTarget::Asic)
///     .bitwidths(vec![8, 16])
///     .fix(Some(true)); // only fix-to-1 configurations
/// assert_eq!(q.specs().len(), 8 + 16); // t in 0..n, one fix mode each
/// # Ok::<(), segmul::api::SegmulError>(())
/// ```
#[derive(Clone, Debug)]
pub struct TuneQuery {
    /// The accuracy budget candidate points must satisfy.
    pub budget: Budget,
    /// Hardware technology whose latency/area/power joins the frontier.
    pub target: TechTarget,
    /// Candidate operand bit-widths.
    pub bitwidths: Vec<u32>,
    /// Candidate design family set.
    pub designs: DesignSet,
    /// Restrict the segmented family to one fix mode (`None`: both).
    pub fix: Option<bool>,
    /// Largest `n` evaluated exhaustively when a point must simulate.
    pub exhaustive_max_n: u32,
    /// Monte-Carlo budget for simulated points above that.
    pub mc_samples: u64,
    /// Random-vector count for switching-activity (power) estimation.
    pub hw_vectors: u64,
    /// Seed for the activity vectors (error-metric seeds come from the
    /// session, keeping tune answers store-key-compatible with sweeps).
    pub hw_seed: u64,
}

impl TuneQuery {
    /// A query with the default paper grid (see the type docs).
    pub fn new(budget: Budget) -> TuneQuery {
        TuneQuery {
            budget,
            target: TechTarget::Fpga,
            bitwidths: vec![4, 8, 16, 32],
            designs: DesignSet::Paper,
            fix: None,
            exhaustive_max_n: 12,
            mc_samples: 1 << 20,
            hw_vectors: 1024,
            hw_seed: 0x5E6_0001,
        }
    }

    /// Set the hardware target.
    pub fn target(mut self, target: TechTarget) -> Self {
        self.target = target;
        self
    }

    /// Set the candidate bit-widths.
    pub fn bitwidths(mut self, bitwidths: Vec<u32>) -> Self {
        self.bitwidths = bitwidths;
        self
    }

    /// Set the candidate design family set.
    pub fn designs(mut self, designs: DesignSet) -> Self {
        self.designs = designs;
        self
    }

    /// Constrain the fix-to-1 mode (`None`: keep both).
    pub fn fix(mut self, fix: Option<bool>) -> Self {
        self.fix = fix;
        self
    }

    /// Set the simulated-point workload split (exhaustive cutoff, MC
    /// samples above it).
    pub fn workload(mut self, exhaustive_max_n: u32, mc_samples: u64) -> Self {
        self.exhaustive_max_n = exhaustive_max_n;
        self.mc_samples = mc_samples;
        self
    }

    /// Set the switching-activity vector count for power estimation.
    pub fn hw_vectors(mut self, vectors: u64) -> Self {
        self.hw_vectors = vectors;
        self
    }

    /// Set the activity-vector seed.
    pub fn hw_seed(mut self, seed: u64) -> Self {
        self.hw_seed = seed;
        self
    }

    /// The candidate grid, in deterministic order: the design set at
    /// each bit-width, filtered by the fix constraint.
    pub fn specs(&self) -> Vec<MultiplierSpec> {
        let mut out = Vec::new();
        for &n in &self.bitwidths {
            for spec in self.designs.specs(n) {
                if let Some(want) = self.fix {
                    if spec.fix_mode().is_some_and(|fx| fx != want) {
                        continue;
                    }
                }
                out.push(spec);
            }
        }
        out
    }

    /// Validate the grid constraints (typed errors, checked before any
    /// evaluation starts).
    pub fn validate(&self) -> Result<(), SegmulError> {
        if self.bitwidths.is_empty() {
            return Err(SegmulError::config("tune query has no bit-widths"));
        }
        if self.mc_samples == 0 {
            return Err(SegmulError::config("tune mc_samples must be positive"));
        }
        if self.hw_vectors == 0 {
            return Err(SegmulError::config("tune hw_vectors must be positive"));
        }
        for spec in self.specs() {
            spec.validate()?;
        }
        Ok(())
    }

    /// Canonical identity string: two queries with equal strings request
    /// identical work (the serve layer's coalesce key for `/v1/tune`).
    pub fn canonical(&self) -> String {
        let widths: Vec<String> = self.bitwidths.iter().map(|n| n.to_string()).collect();
        format!(
            "tune|{}|{}|{}|n={}|fix={}|exh={}|mc={}|hwv={}|hws={}",
            self.budget.canonical(),
            self.target.name(),
            self.designs.name(),
            widths.join(","),
            self.fix.map(|f| f.to_string()).unwrap_or_else(|| "both".into()),
            self.exhaustive_max_n,
            self.mc_samples,
            self.hw_vectors,
            self.hw_seed,
        )
    }
}

/// One explored configuration: error metrics, budget verdict, hardware
/// join, answer provenance, and frontier membership.
#[derive(Clone, Debug)]
pub struct ParetoPoint {
    /// The design configuration.
    pub spec: MultiplierSpec,
    /// Its error metric set (whichever source answered).
    pub metrics: ErrorMetrics,
    /// The budget metric's value for this point.
    pub budget_value: f64,
    /// Whether the point satisfies the query's budget.
    pub feasible: bool,
    /// Answer source: `"analytic"` or `"simulated"` (store and cache
    /// hits are simulated answers served without re-evaluation).
    pub source: &'static str,
    /// Served from the in-memory cache or the persistent store.
    pub cached: bool,
    /// Technology estimates for the designs with a gate-level mapping
    /// (the segmented family and the accurate reference); `None` for
    /// families without a netlist generator, which then compete on
    /// error alone and never enter the hardware frontier.
    pub hw: Option<HwFigures>,
    /// On the non-dominated (error × latency × resource × power) set.
    pub frontier: bool,
}

impl ParetoPoint {
    /// The point's objective vector (minimize every coordinate), when
    /// it has a hardware mapping.
    fn objectives(&self) -> Option<Vec<f64>> {
        self.hw.as_ref().map(|h| {
            vec![self.budget_value, h.latency_ns, h.resource, h.total_power_mw()]
        })
    }

    /// JSON image (wire / report form).
    pub fn to_json(&self, winner: bool) -> Json {
        let mut fields = vec![
            ("design", Json::from(self.spec.name().as_str())),
            ("family", Json::from(self.spec.family())),
            ("n", Json::from(self.spec.n() as u64)),
        ];
        if let Some(t) = self.spec.split_point() {
            fields.push(("t", Json::from(t as u64)));
        }
        if let Some(fix) = self.spec.fix_mode() {
            fields.push(("fix", Json::from(fix)));
        }
        fields.extend([
            ("er", Json::from(self.metrics.er)),
            ("nmed", Json::from(self.metrics.nmed)),
            ("mred", Json::from(self.metrics.mred)),
            ("wce", Json::from(self.metrics.mae)),
            ("budget_value", Json::from(self.budget_value)),
            ("feasible", Json::from(self.feasible)),
            ("source", Json::from(self.source)),
            ("cached", Json::from(self.cached)),
            ("frontier", Json::from(self.frontier)),
            ("winner", Json::from(winner)),
        ]);
        let hw = match &self.hw {
            Some(h) => obj(vec![
                ("latency_ns", Json::from(h.latency_ns)),
                ("period_ns", Json::from(h.period_ns)),
                ("resource", Json::from(h.resource)),
                ("ffs", Json::from(h.ffs as u64)),
                ("dyn_power_mw", Json::from(h.dyn_power_mw)),
                ("total_power_mw", Json::from(h.total_power_mw())),
            ]),
            None => Json::Null,
        };
        fields.push(("hw", hw));
        obj(fields)
    }
}

/// The autotuner's answer: every explored point (frontier flagged), the
/// winning configuration, and the answer-source accounting for this
/// call.
#[derive(Clone, Debug)]
pub struct TuneResult {
    /// The budget the query stated.
    pub budget: Budget,
    /// The hardware target the cost objectives came from.
    pub target: TechTarget,
    /// Every explored point, in deterministic grid order.
    pub points: Vec<ParetoPoint>,
    /// Index (into `points`) of the winning configuration, when any
    /// point is feasible.
    pub winner: Option<usize>,
    /// Wall time of the whole tune call.
    pub wall: Duration,
    /// Points answered from closed forms (this call).
    pub analytic_answers: u64,
    /// Points answered from the persistent store (this call).
    pub store_hits: u64,
    /// Points answered from the in-memory cache (this call).
    pub cache_hits: u64,
    /// Points that dispatched the worker pool (this call).
    pub jobs_evaluated: u64,
}

impl TuneResult {
    /// The winning point: the cheapest feasible configuration.
    pub fn winner(&self) -> Option<&ParetoPoint> {
        self.winner.map(|i| &self.points[i])
    }

    /// The non-dominated points, in grid order.
    pub fn frontier(&self) -> Vec<&ParetoPoint> {
        self.points.iter().filter(|p| p.frontier).collect()
    }

    /// Count of budget-satisfying points.
    pub fn feasible_count(&self) -> usize {
        self.points.iter().filter(|p| p.feasible).count()
    }

    fn point_row(&self, i: usize, p: &ParetoPoint) -> Vec<String> {
        let dash = || "-".to_string();
        let hw = p.hw.as_ref();
        vec![
            p.spec.name(),
            p.spec.n().to_string(),
            p.spec.split_point().map(|t| t.to_string()).unwrap_or_else(dash),
            p.spec.fix_mode().map(|fx| fx.to_string()).unwrap_or_else(dash),
            f(p.metrics.er),
            f(p.metrics.nmed),
            f(p.metrics.mred),
            p.metrics.mae.to_string(),
            f(p.budget_value),
            p.feasible.to_string(),
            hw.map(|h| f(h.latency_ns)).unwrap_or_else(dash),
            hw.map(|h| f(h.period_ns)).unwrap_or_else(dash),
            hw.map(|h| f(h.resource)).unwrap_or_else(dash),
            hw.map(|h| f(h.total_power_mw())).unwrap_or_else(dash),
            p.source.to_string(),
            (self.winner == Some(i)).to_string(),
        ]
    }

    fn table_header() -> &'static [&'static str] {
        &[
            "design", "n", "t", "fix", "er", "nmed", "mred", "wce", "budget_value", "feasible",
            "latency_ns", "period_ns", "resource", "total_power_mw", "source", "winner",
        ]
    }

    /// The non-dominated set as a table — the `results/pareto.csv`
    /// payload (every row is on the frontier; the winner is flagged).
    pub fn frontier_table(&self) -> Table {
        let mut t = Table::new(Self::table_header());
        for (i, p) in self.points.iter().enumerate() {
            if p.frontier {
                t.row(self.point_row(i, p));
            }
        }
        t
    }

    /// Every explored point as a table (the Pareto scatter: frontier
    /// membership in the `frontier` column).
    pub fn points_table(&self) -> Table {
        let mut t = Table::new(&[
            "design", "n", "t", "fix", "er", "nmed", "mred", "wce", "budget_value", "feasible",
            "latency_ns", "period_ns", "resource", "total_power_mw", "source", "winner",
            "frontier",
        ]);
        for (i, p) in self.points.iter().enumerate() {
            let mut row = self.point_row(i, p);
            row.push(p.frontier.to_string());
            t.row(row);
        }
        t
    }

    /// JSON image: budget echo, winner, frontier, and source accounting
    /// (the `/v1/tune` response body).
    pub fn to_json(&self) -> Json {
        let frontier: Vec<Json> = self
            .points
            .iter()
            .enumerate()
            .filter(|(_, p)| p.frontier)
            .map(|(i, p)| p.to_json(self.winner == Some(i)))
            .collect();
        obj(vec![
            ("budget", Json::from(self.budget.canonical().as_str())),
            ("budget_metric", Json::from(self.budget.metric.name())),
            ("budget_max", Json::from(self.budget.max)),
            ("target", Json::from(self.target.name())),
            ("points", Json::from(self.points.len() as u64)),
            ("feasible", Json::from(self.feasible_count() as u64)),
            (
                "winner",
                match self.winner {
                    Some(i) => self.points[i].to_json(true),
                    None => Json::Null,
                },
            ),
            ("frontier", Json::Arr(frontier)),
            ("analytic_answers", Json::from(self.analytic_answers)),
            ("store_hits", Json::from(self.store_hits)),
            ("cache_hits", Json::from(self.cache_hits)),
            ("jobs_evaluated", Json::from(self.jobs_evaluated)),
        ])
    }
}

/// `a` dominates `b`: at least as good (≤, minimizing) in every
/// objective, strictly better in one. Any NaN coordinate disqualifies
/// `a` from dominating.
fn dominates(a: &[f64], b: &[f64]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    let mut strictly = false;
    for (x, y) in a.iter().zip(b) {
        if x.is_nan() || x > y {
            return false;
        }
        if x < y {
            strictly = true;
        }
    }
    strictly
}

/// The non-dominated mask of a set of objective vectors (all the same
/// arity, every coordinate minimized): `out[i]` is `true` iff no other
/// vector dominates vector `i`. Duplicate vectors are all kept (none
/// strictly beats its twin). A vector containing NaN is never on the
/// frontier and never eliminates another. O(n²) pairwise — exact, and
/// the property suite cross-checks it against an independent
/// brute-force at small sizes.
pub fn pareto_frontier(objectives: &[Vec<f64>]) -> Vec<bool> {
    let mut mask = vec![true; objectives.len()];
    for (i, a) in objectives.iter().enumerate() {
        if a.iter().any(|v| v.is_nan()) {
            mask[i] = false;
            continue;
        }
        for (j, b) in objectives.iter().enumerate() {
            if i != j && dominates(b, a) {
                mask[i] = false;
                break;
            }
        }
    }
    mask
}

/// The gate-level mapping of a spec, for the technology join: the
/// segmented family (word-level, bit-level oracle, and netlist forms
/// all map to the same generated circuit) and the accurate reference
/// (`t = 0`). `None` for the related-work baselines — the repo carries
/// no netlist generators for them.
fn netlist_params(spec: &MultiplierSpec) -> Option<(u32, u32, bool)> {
    match *spec {
        MultiplierSpec::Segmented { n, t, fix }
        | MultiplierSpec::BitLevel { n, t, fix }
        | MultiplierSpec::Netlist { n, t, fix } => {
            // The zero-bit LSP adder cannot raise the compensated carry:
            // fix is meaningless at t = 0 and the generator rejects it.
            Some((n, t, fix && t >= 1))
        }
        MultiplierSpec::Accurate { n } => Some((n, 0, false)),
        _ => None,
    }
}

/// Per-call hardware estimator with the accurate-period pin cache (the
/// paper's power-fairness convention, shared with
/// [`crate::report::figures::hw_sweep`]).
struct HwEstimator {
    target: TechTarget,
    vectors: u64,
    seed: u64,
    base_period: HashMap<u32, f64>,
}

impl HwEstimator {
    fn new(query: &TuneQuery) -> HwEstimator {
        HwEstimator {
            target: query.target,
            vectors: query.hw_vectors,
            seed: query.hw_seed,
            base_period: HashMap::new(),
        }
    }

    fn evaluate(&self, n: u32, t: u32, fix: bool, pin: Option<f64>) -> HwFigures {
        let c = seq_mult(n, t, fix);
        let act = measure_activity(&c, self.vectors, self.seed ^ n as u64, fix);
        let cycles = n + 1;
        match self.target {
            TechTarget::Fpga => FpgaModel::default().evaluate(&c.nl, &act, cycles, pin).figures,
            TechTarget::Asic => AsicModel::default().evaluate(&c.nl, &act, cycles, pin).figures,
        }
    }

    /// The accurate design's minimum period at `n` (computed once per
    /// bit-width; every approximate point's power clock pins to it).
    fn accurate_period(&mut self, n: u32) -> f64 {
        if let Some(&p) = self.base_period.get(&n) {
            return p;
        }
        let p = self.evaluate(n, 0, false, None).period_ns;
        self.base_period.insert(n, p);
        p
    }

    fn estimate(&mut self, spec: &MultiplierSpec) -> Option<HwFigures> {
        let (n, t, fix) = netlist_params(spec)?;
        if n < 2 {
            return None; // the generator needs a two-bit datapath
        }
        if t == 0 {
            // The accurate baseline itself: its own minimum period.
            return Some(self.evaluate(n, 0, false, None));
        }
        let pin = self.accurate_period(n);
        let mut fig = self.evaluate(n, t, fix, Some(pin));
        // Power was billed at the pinned common clock; latency keeps the
        // point's own achievable period.
        fig.latency_ns = (n + 1) as f64 * fig.period_ns;
        Some(fig)
    }
}

/// Winner ordering among feasible points: hardware-mapped points beat
/// unmapped ones; within the mapped set, minimize latency, then
/// resource, then total power, then the budget metric. Without any
/// mapped candidate (error-only families), minimize the budget metric,
/// then ER. NaN orders last throughout.
fn better_winner(a: &ParetoPoint, b: &ParetoPoint) -> bool {
    fn lex(a: &[f64], b: &[f64]) -> std::cmp::Ordering {
        for (x, y) in a.iter().zip(b) {
            let ord = x
                .partial_cmp(y)
                .unwrap_or_else(|| x.is_nan().cmp(&y.is_nan()));
            if ord != std::cmp::Ordering::Equal {
                return ord;
            }
        }
        std::cmp::Ordering::Equal
    }
    match (&a.hw, &b.hw) {
        (Some(ha), Some(hb)) => {
            lex(
                &[ha.latency_ns, ha.resource, ha.total_power_mw(), a.budget_value],
                &[hb.latency_ns, hb.resource, hb.total_power_mw(), b.budget_value],
            ) == std::cmp::Ordering::Less
        }
        (Some(_), None) => true,
        (None, Some(_)) => false,
        (None, None) => {
            lex(&[a.budget_value, a.metrics.er], &[b.budget_value, b.metrics.er])
                == std::cmp::Ordering::Less
        }
    }
}

/// Run the autotuner: enumerate the query's grid, answer error metrics
/// through the session's answer-source ladder (analytic → cache/store →
/// simulate), join the technology estimates, mark the non-dominated
/// frontier, and pick the cheapest feasible configuration. See the
/// module docs for the guarantees; the session's
/// [`crate::coordinator::AnalyticMode`] decides how much (if anything)
/// is simulated.
pub fn tune(session: &mut Session, query: &TuneQuery) -> Result<TuneResult, SegmulError> {
    let start = Instant::now();
    query.validate()?;
    let (analytic0, store0, cache0, eval0) = (
        session.analytic_answers(),
        session.store_hits(),
        session.cache_hits(),
        session.jobs_evaluated(),
    );
    let mut hw = HwEstimator::new(query);
    let mut points: Vec<ParetoPoint> = Vec::new();
    for spec in query.specs() {
        let builder = session.job(spec);
        let job = if spec.n() <= query.exhaustive_max_n {
            builder.exhaustive().build()?
        } else {
            builder.monte_carlo(query.mc_samples).build()?
        };
        let outcome = session.run_outcome(&job)?;
        let metrics = outcome.metrics()?;
        let budget_value = query.budget.metric.value_of(&metrics);
        points.push(ParetoPoint {
            spec,
            budget_value,
            feasible: query.budget.admits(&metrics),
            source: outcome.source(),
            cached: outcome.cached,
            hw: hw.estimate(&spec),
            metrics,
            frontier: false,
        });
    }
    // Frontier over the hardware-mapped subset (mixed objective arity
    // has no domination order); unmapped points never enter it.
    let mapped: Vec<usize> =
        (0..points.len()).filter(|&i| points[i].hw.is_some()).collect();
    let objectives: Vec<Vec<f64>> = mapped
        .iter()
        .map(|&i| points[i].objectives().expect("mapped point has objectives"))
        .collect();
    for (k, on) in pareto_frontier(&objectives).into_iter().enumerate() {
        points[mapped[k]].frontier = on;
    }
    let winner = points
        .iter()
        .enumerate()
        .filter(|(_, p)| p.feasible)
        .fold(None::<usize>, |best, (i, p)| match best {
            Some(b) if !better_winner(p, &points[b]) => Some(b),
            _ => Some(i),
        });
    Ok(TuneResult {
        budget: query.budget,
        target: query.target,
        points,
        winner,
        wall: start.elapsed(),
        analytic_answers: session.analytic_answers() - analytic0,
        store_hits: session.store_hits() - store0,
        cache_hits: session.cache_hits() - cache0,
        jobs_evaluated: session.jobs_evaluated() - eval0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::AnalyticMode;

    fn fast_query(budget: Budget) -> TuneQuery {
        TuneQuery::new(budget).bitwidths(vec![8]).hw_vectors(64)
    }

    fn analytic_session() -> Session {
        Session::builder()
            .workers(1)
            .analytic(AnalyticMode::Require)
            .build()
            .unwrap()
    }

    #[test]
    fn budget_grammar() {
        let b = Budget::parse("mred<=1e-3").unwrap();
        assert_eq!(b.metric, BudgetMetric::Mred);
        assert_eq!(b.max, 1e-3);
        assert_eq!(Budget::parse(" nmed <= 0.01 ").unwrap().metric, BudgetMetric::Nmed);
        assert_eq!(Budget::parse("wce=4096").unwrap().max, 4096.0);
        let p = Budget::parse("psnr>=60").unwrap();
        assert_eq!(p.metric, BudgetMetric::Mred);
        assert!((p.max - 1e-3).abs() < 1e-12, "{}", p.max);
        assert_eq!(p.psnr_db, Some(60.0));
        for bad in ["mred>=1", "psnr<=30", "er<=0.5", "mred<=x", "mred<=-1", ""] {
            assert_eq!(Budget::parse(bad).unwrap_err().kind(), "config", "{bad}");
        }
    }

    #[test]
    fn frontier_keeps_non_dominated_and_drops_dominated() {
        let objs = vec![
            vec![1.0, 5.0], // frontier
            vec![5.0, 1.0], // frontier
            vec![2.0, 2.0], // frontier (incomparable with both)
            vec![5.0, 5.0], // dominated by all three
            vec![1.0, 5.0], // duplicate of 0: kept
            vec![f64::NAN, 0.0], // NaN: never on the frontier
        ];
        assert_eq!(pareto_frontier(&objs), vec![true, true, true, false, true, false]);
        assert!(pareto_frontier(&[]).is_empty());
    }

    #[test]
    fn tune_paper_grid_is_simulation_free_and_consistent() {
        let mut s = analytic_session();
        let r = tune(&mut s, &fast_query(Budget::mred(1e-3))).unwrap();
        assert_eq!(r.points.len(), 16, "n=8 paper grid: t in 0..8 x fix");
        assert_eq!(r.jobs_evaluated, 0, "require mode must not dispatch");
        assert_eq!(r.analytic_answers as usize + r.cache_hits as usize, r.points.len());
        // Every point got a hardware join; the frontier is non-empty and
        // mutually consistent with the flags.
        assert!(r.points.iter().all(|p| p.hw.is_some()));
        assert!(!r.frontier().is_empty());
        // The accurate point (t=0) is always feasible, so there is a winner.
        let w = r.winner().expect("winner");
        assert!(w.feasible);
        assert!(w.budget_value <= 1e-3);
        // Winner latency: no other feasible point is strictly faster.
        let wl = w.hw.as_ref().unwrap().latency_ns;
        for p in r.points.iter().filter(|p| p.feasible) {
            assert!(p.hw.as_ref().unwrap().latency_ns >= wl - 1e-9);
        }
    }

    #[test]
    fn looser_budget_never_raises_winner_latency() {
        let mut s = analytic_session();
        let tight = tune(&mut s, &fast_query(Budget::mred(1e-4))).unwrap();
        let loose = tune(&mut s, &fast_query(Budget::mred(1e-1))).unwrap();
        let lt = tight.winner().unwrap().hw.as_ref().unwrap().latency_ns;
        let ll = loose.winner().unwrap().hw.as_ref().unwrap().latency_ns;
        assert!(ll <= lt + 1e-9, "loose {ll} vs tight {lt}");
        assert!(loose.feasible_count() >= tight.feasible_count());
    }

    #[test]
    fn fix_constraint_filters_the_grid() {
        let q = fast_query(Budget::mred(1.0)).fix(Some(true));
        // t=0 has fix=false and fix=true variants; the filter keeps 8.
        assert_eq!(q.specs().len(), 8);
        assert!(q.specs().iter().all(|s| s.fix_mode() == Some(true)));
    }

    #[test]
    fn error_only_families_tune_without_hardware() {
        let mut s = analytic_session();
        let q = TuneQuery::new(Budget::nmed(0.5))
            .designs(DesignSet::Baselines)
            .bitwidths(vec![8]);
        let r = tune(&mut s, &q).unwrap();
        assert!(!r.points.is_empty());
        assert!(r.points.iter().all(|p| p.hw.is_none()));
        assert!(r.frontier().is_empty(), "no hardware mapping, no frontier");
        // Degenerate winner: minimal budget-metric value among feasible.
        let w = r.winner().expect("all baselines admit nmed<=0.5");
        for p in r.points.iter().filter(|p| p.feasible) {
            assert!(w.budget_value <= p.budget_value + 1e-12);
        }
    }

    #[test]
    fn infeasible_budget_yields_no_winner() {
        let mut s = analytic_session();
        // A bound below zero admits nothing (parse rejects it, so build
        // the Budget directly to reach the no-winner path).
        let q = fast_query(Budget {
            metric: BudgetMetric::Wce,
            max: -1.0,
            psnr_db: None,
        });
        let r = tune(&mut s, &q).unwrap();
        assert_eq!(r.feasible_count(), 0);
        assert!(r.winner().is_none());
        assert!(!r.frontier().is_empty(), "frontier is budget-independent");
    }

    #[test]
    fn result_tables_and_json_are_consistent() {
        let mut s = analytic_session();
        let r = tune(&mut s, &fast_query(Budget::mred(1e-2))).unwrap();
        let ft = r.frontier_table();
        assert_eq!(ft.rows.len(), r.frontier().len());
        let winner_col = ft.header.iter().position(|h| h == "winner").unwrap();
        let pt = r.points_table();
        assert_eq!(pt.rows.len(), r.points.len());
        let j = r.to_json();
        assert_eq!(j.get("points").unwrap().as_u64(), Some(r.points.len() as u64));
        assert_eq!(
            j.get("frontier").unwrap().as_arr().unwrap().len(),
            r.frontier().len()
        );
        assert_eq!(j.get("jobs_evaluated").unwrap().as_u64(), Some(0));
        // The winner appears in the JSON and (when on the frontier) in
        // the frontier table exactly once.
        assert!(j.get("winner").unwrap().get("design").is_some());
        let winners = ft.rows.iter().filter(|row| row[winner_col] == "true").count();
        assert!(winners <= 1);
    }

    #[test]
    fn query_canonical_is_stable_identity() {
        let a = fast_query(Budget::mred(1e-3));
        let b = fast_query(Budget::mred(1e-3));
        assert_eq!(a.canonical(), b.canonical());
        assert_ne!(
            a.canonical(),
            fast_query(Budget::mred(2e-3)).canonical()
        );
        assert_ne!(a.canonical(), a.clone().target(TechTarget::Asic).canonical());
    }

    #[test]
    fn invalid_queries_are_typed_errors() {
        let e = TuneQuery::new(Budget::mred(1.0)).bitwidths(vec![]).validate().unwrap_err();
        assert_eq!(e.kind(), "config");
        let e = TuneQuery::new(Budget::mred(1.0)).bitwidths(vec![40]).validate().unwrap_err();
        assert_eq!(e.kind(), "spec");
    }
}
