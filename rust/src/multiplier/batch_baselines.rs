//! Branch-free batched kernels for the related-work baseline multipliers
//! and the bit-level oracle.
//!
//! PR 1 gave the paper's segmented design a monomorphized, 4-wide-unrolled
//! batch kernel; this module extends the same contract to every other
//! design in the [`super::spec::MultiplierSpec`] registry, so a
//! cross-design sweep (`--designs all`) never pays one virtual call per
//! operand pair. The scalar models in [`super::baselines`] are written
//! with data-dependent control flow (skip-on-zero-bit loops, Mitchell's
//! two antilog cases, Kulkarni's recursion); each kernel here is an
//! algebraic restructuring of the same product function into a
//! branch-free, uniform-latency recurrence, bit-exact against its scalar
//! model (`tests/kernel_differential.rs` checks every registry design):
//!
//! * **Truncation** — partial products split at column `k`: rows `j >= k`
//!   collapse into one hardware multiply `a * (b >> k << k)`; rows
//!   `j < k` contribute `k` masked adds (`(a >> (k-j)) << k`, AND-masked
//!   by the sign-extended `b_j`).
//! * **Broken-array** — same split at `max(hbl, vbl)`, with the
//!   `hbl <= j < vbl` window as masked adds.
//! * **Mitchell** — the leading-one detect becomes `leading_zeros` (a
//!   single `lzcnt`-class instruction), the zero-operand early-out an
//!   AND mask, and the two piecewise-antilog cases a mask select on the
//!   mantissa-sum carry bit.
//! * **Kulkarni** — the 2×2-block recursion composes sub-products with
//!   exact additions, so the only approximation is the base block
//!   `3 × 3 = 7` (error `-2`). Summing over all digit pairs:
//!   `kul(a, b) = a*b - 2 * f(a) * f(b)` where
//!   `f(x) = Σ_i [digit_i(x) = 3] · 4^i`, and `f` is one SWAR expression
//!   (`x & (x >> 1) & 0x5555…`, the marker bit landing exactly at `4^i`).
//!   Two hardware multiplies replace the whole recursion.
//! * **Bit-level oracle** — [`BitSlicedBitLevel`] transposes 64 operand
//!   pairs into bit planes (word `i` = bit `i` of all 64 lanes) and runs
//!   the paper's `Ŝ/Ĉ` recurrences once with `u64` bitwise ops, i.e. 64
//!   pairs per pass instead of one — the same trick the gate-level
//!   netlist simulator uses.
//!
//! The word-level kernels are unrolled four pairs wide like
//! [`super::batch::approx_seq_mul_batch`]: the lanes carry no data
//! dependencies, so independent multiplications overlap in flight.

use super::baselines::{BrokenArrayMul, Kulkarni2x2, MitchellLog, TruncatedMul};
use super::batch::BatchMultiplier;
use super::Multiplier;

/// Apply a branch-free per-pair kernel over equal-length slices, unrolled
/// four pairs wide (monomorphized per call site via the closure type).
#[inline(always)]
fn batch_unrolled<F: Fn(u64, u64) -> u64>(a: &[u64], b: &[u64], out: &mut [u64], f: F) {
    assert_eq!(a.len(), b.len(), "operand slices must have equal length");
    assert_eq!(a.len(), out.len(), "output slice must match operand length");
    let mut ac = a.chunks_exact(4);
    let mut bc = b.chunks_exact(4);
    let mut oc = out.chunks_exact_mut(4);
    for ((ca, cb), co) in (&mut ac).zip(&mut bc).zip(&mut oc) {
        co[0] = f(ca[0], cb[0]);
        co[1] = f(ca[1], cb[1]);
        co[2] = f(ca[2], cb[2]);
        co[3] = f(ca[3], cb[3]);
    }
    for ((&ai, &bi), o) in ac.remainder().iter().zip(bc.remainder()).zip(oc.into_remainder()) {
        *o = f(ai, bi);
    }
}

/// One branch-free vertically-truncated multiply (columns `< k` dropped).
/// The loop trip count depends only on the configuration, never the data.
#[inline(always)]
fn trunc_mul_one(a: u64, b: u64, k: u32) -> u64 {
    // Rows j >= k keep all their columns: one hardware multiply.
    let mut p = a * ((b >> k) << k);
    // Rows j < k keep only the bits landing in columns >= k.
    let mut j = 0u32;
    while j < k {
        p += ((a >> (k - j)) << k) & ((b >> j) & 1).wrapping_neg();
        j += 1;
    }
    p
}

/// Batched [`TruncatedMul`] products, bit-exact with the scalar model.
/// Requirements: equal slice lengths, `1 <= n <= 32`, `k <= n`, operands
/// `< 2^n`.
pub fn trunc_mul_batch(a: &[u64], b: &[u64], out: &mut [u64], n: u32, k: u32) {
    assert!(n >= 1 && n <= 32, "trunc_mul_batch supports 1 <= n <= 32");
    assert!(k <= n, "truncation column k={k} must satisfy k <= n={n}");
    debug_assert!(a.iter().chain(b).all(|&x| x >> n == 0), "operands must be < 2^n");
    batch_unrolled(a, b, out, |x, y| trunc_mul_one(x, y, k));
}

/// One branch-free broken-array multiply (rows `< hbl`, columns `< vbl`
/// dropped).
#[inline(always)]
fn bam_mul_one(a: u64, b: u64, hbl: u32, vbl: u32) -> u64 {
    // Rows j >= max(hbl, vbl) keep all their columns.
    let cut = hbl.max(vbl);
    let mut p = a * ((b >> cut) << cut);
    // Surviving rows below the vertical break line.
    let mut j = hbl;
    while j < vbl {
        p += ((a >> (vbl - j)) << vbl) & ((b >> j) & 1).wrapping_neg();
        j += 1;
    }
    p
}

/// Batched [`BrokenArrayMul`] products, bit-exact with the scalar model.
/// Requirements: equal slice lengths, `1 <= n <= 32`, `hbl <= n`,
/// `vbl <= n`, operands `< 2^n`.
pub fn bam_mul_batch(a: &[u64], b: &[u64], out: &mut [u64], n: u32, hbl: u32, vbl: u32) {
    assert!(n >= 1 && n <= 32, "bam_mul_batch supports 1 <= n <= 32");
    assert!(hbl <= n && vbl <= n, "break lines (hbl={hbl}, vbl={vbl}) must not exceed n={n}");
    debug_assert!(a.iter().chain(b).all(|&x| x >> n == 0), "operands must be < 2^n");
    batch_unrolled(a, b, out, |x, y| bam_mul_one(x, y, hbl, vbl));
}

/// One branch-free Mitchell logarithmic multiply.
#[inline(always)]
fn mitchell_mul_one(a: u64, b: u64) -> u64 {
    // All-ones when both operands are nonzero, zero otherwise: the scalar
    // model's early-out, as a mask applied at the end.
    let nz = (((a != 0) & (b != 0)) as u64).wrapping_neg();
    let am = a & nz;
    let bm = b & nz;
    // Characteristic via leading_zeros (one lzcnt-class instruction); the
    // `| 1` only guards the zeroed case and never changes the MSB of a
    // nonzero word. The mantissa drops the MSB — as a bit-clear, so the
    // zeroed case (k = 0, bit 0 unset) yields 0 without underflow.
    let k1 = 63 - (am | 1).leading_zeros();
    let k2 = 63 - (bm | 1).leading_zeros();
    let x1 = am & !(1u64 << k1);
    let x2 = bm & !(1u64 << k2);
    let k = k1 + k2;
    // S = 2^K (f1 + f2) with f1, f2 < 1, so S < 2^(K+1): bit K of S is
    // exactly the `f1 + f2 >= 1` case split, selecting between the two
    // piecewise antilog forms without a data-dependent branch.
    let s = (x1 << k2) + (x2 << k1);
    let over = ((s >> k) & 1).wrapping_neg();
    ((((1u64 << k) + s) & !over) | ((s << 1) & over)) & nz
}

/// Batched [`MitchellLog`] products, bit-exact with the scalar model.
/// Requirements: equal slice lengths, `1 <= n <= 32`, operands `< 2^n`.
pub fn mitchell_mul_batch(a: &[u64], b: &[u64], out: &mut [u64], n: u32) {
    assert!(n >= 1 && n <= 32, "mitchell_mul_batch supports 1 <= n <= 32");
    debug_assert!(a.iter().chain(b).all(|&x| x >> n == 0), "operands must be < 2^n");
    batch_unrolled(a, b, out, mitchell_mul_one);
}

/// One branch-free Kulkarni 2×2-block multiply: `a*b - 2 f(a) f(b)`.
///
/// The recursion composes half-width sub-products with exact adds, so
/// errors from the `3 × 3 = 7` base blocks (−2 each, at bit `4^(i+j)` for
/// digit pair `(i, j)`) sum linearly:
/// `error = −2 Σ_{i,j} [a_i = 3][b_j = 3] 4^(i+j) = −2 f(a) f(b)` with
/// `f(x) = Σ_i [x_i = 3] 4^i = x & (x >> 1) & 0b…0101` (the AND of each
/// digit's two bits lands on bit `2i`, which *is* `4^i`).
#[inline(always)]
fn kulkarni_mul_one(a: u64, b: u64, m3: u64) -> u64 {
    let fa = a & (a >> 1) & m3;
    let fb = b & (b >> 1) & m3;
    // No underflow: a >= 3 f(a) and b >= 3 f(b), so a*b >= 9 f(a) f(b).
    a * b - 2 * fa * fb
}

/// Batched [`Kulkarni2x2`] products, bit-exact with the scalar recursion.
/// Requirements: equal slice lengths, `n` a power of two in `2..=32`,
/// operands `< 2^n`.
pub fn kulkarni_mul_batch(a: &[u64], b: &[u64], out: &mut [u64], n: u32) {
    assert!(
        n.is_power_of_two() && (2..=32).contains(&n),
        "kulkarni_mul_batch needs a power-of-two n in 2..=32"
    );
    debug_assert!(a.iter().chain(b).all(|&x| x >> n == 0), "operands must be < 2^n");
    let m3 = 0x5555_5555_5555_5555u64 & ((1u64 << n) - 1);
    batch_unrolled(a, b, out, |x, y| kulkarni_mul_one(x, y, m3));
}

impl BatchMultiplier for TruncatedMul {
    fn n(&self) -> u32 {
        self.n
    }

    fn name(&self) -> String {
        Multiplier::name(self)
    }

    fn mul_batch(&self, a: &[u64], b: &[u64], out: &mut [u64]) {
        trunc_mul_batch(a, b, out, self.n, self.k);
    }
}

impl BatchMultiplier for BrokenArrayMul {
    fn n(&self) -> u32 {
        self.n
    }

    fn name(&self) -> String {
        Multiplier::name(self)
    }

    fn mul_batch(&self, a: &[u64], b: &[u64], out: &mut [u64]) {
        bam_mul_batch(a, b, out, self.n, self.hbl, self.vbl);
    }
}

impl BatchMultiplier for MitchellLog {
    fn n(&self) -> u32 {
        self.n
    }

    fn name(&self) -> String {
        Multiplier::name(self)
    }

    fn mul_batch(&self, a: &[u64], b: &[u64], out: &mut [u64]) {
        mitchell_mul_batch(a, b, out, self.n);
    }
}

impl BatchMultiplier for Kulkarni2x2 {
    fn n(&self) -> u32 {
        self.n
    }

    fn name(&self) -> String {
        Multiplier::name(self)
    }

    fn mul_batch(&self, a: &[u64], b: &[u64], out: &mut [u64]) {
        kulkarni_mul_batch(a, b, out, self.n);
    }
}

/// Word-parallel (bit-sliced) evaluator of the paper's Boolean `Ŝ/Ĉ`
/// recurrences: 64 operand pairs per pass.
///
/// Layout: operands are transposed into *bit planes* — plane `i` is a
/// `u64` whose lane-`l` bit is bit `i` of pair `l`'s operand. The
/// recurrence of [`super::bitlevel::approx_seq_mul_bitlevel`] then runs
/// once per pass with every `u8` cell widened to a 64-lane `u64` plane
/// (AND/XOR/OR are lane-wise), and the 2n product planes are transposed
/// back. Partial groups pad with `(0, 0)` lanes, which evaluate to 0 and
/// are never written back.
///
/// This keeps the oracle a *literal* transcription of the paper's
/// equations — same recurrence order, same `i = t` D-FF case, same
/// fix-to-1 product patch — while making oracle cross-checks at n = 16
/// roughly the cost of the word-level models instead of ~n² bit
/// operations per pair.
#[derive(Clone, Copy, Debug)]
pub struct BitSlicedBitLevel {
    n: u32,
    t: u32,
    fix: bool,
}

impl BitSlicedBitLevel {
    /// A bit-sliced oracle for `(n, t, fix)` (asserts `n <= 32`, `t < n`).
    pub fn new(n: u32, t: u32, fix: bool) -> Self {
        assert!(n >= 1 && n <= 32, "BitSlicedBitLevel supports 1 <= n <= 32");
        assert!(t < n, "splitting point must satisfy 0 <= t < n");
        BitSlicedBitLevel { n, t, fix }
    }
}

/// One <= 64-lane bit-sliced pass, monomorphized over the fix-to-1 flag.
fn bitlevel_group<const FIX: bool>(a: &[u64], b: &[u64], out: &mut [u64], n: usize, t: usize) {
    // Transpose operands into bit planes (lanes beyond a.len() stay 0).
    let mut abit = [0u64; 32];
    let mut bbit = [0u64; 32];
    for (l, (&av, &bv)) in a.iter().zip(b).enumerate() {
        for i in 0..n {
            abit[i] |= ((av >> i) & 1) << l;
            bbit[i] |= ((bv >> i) & 1) << l;
        }
    }

    // Product planes p[r], r in 0..2n.
    let mut p = [0u64; 64];
    // S planes of the previous row; index n holds the carry-out C_{n-1}^j.
    let mut s_prev = [0u64; 33];
    let mut s_cur = [0u64; 33];
    // j = 0: S^0 = a & -b_0; no carries yet.
    for i in 0..n {
        s_prev[i] = abit[i] & bbit[0];
    }
    s_prev[n] = 0;
    if n >= 2 {
        // p_0 = S_0^0 (the r < n-1 product case, row 0).
        p[0] = s_prev[0];
    }

    // D-FF'd LSP carry-out plane from the previous row: Ĉ_{t-1}^{j-1}.
    let mut c_dff = 0u64;
    for j in 1..n {
        // This row's Ĉ_{t-1}^j plane (captured when the ripple passes
        // bit t-1; stays 0 for t = 0, where the D-FF path is dead).
        let mut c_tm1 = 0u64;
        // i = 0: S = Ŝ_1^{j-1} ^ pp, C = Ŝ_1^{j-1} & pp.
        let pp0 = abit[0] & bbit[j];
        s_cur[0] = s_prev[1] ^ pp0;
        let mut c_prev = s_prev[1] & pp0;
        if t == 1 {
            c_tm1 = c_prev;
        }
        for i in 1..n {
            let pp = abit[i] & bbit[j];
            // The segmentation: bit t consumes the previous-cycle LSP
            // carry-out; all other bits ripple in-cycle.
            let cin = if i == t { c_dff } else { c_prev };
            let sp = s_prev[i + 1];
            s_cur[i] = sp ^ cin ^ pp;
            c_prev = ((sp ^ pp) & cin) | (sp & pp);
            if i + 1 == t {
                c_tm1 = c_prev;
            }
        }
        // i = n: Ŝ_n^j = Ĉ_{n-1}^j.
        s_cur[n] = c_prev;
        if j < n - 1 {
            // p_r = S_0^r for r < n-1.
            p[j] = s_cur[0];
        }
        std::mem::swap(&mut s_prev, &mut s_cur);
        c_dff = c_tm1;
    }

    // p_r = Ŝ_{r+1-n}^{n-1} for r in n-1..2n (row n-1 now in s_prev;
    // for n = 1 that is row 0, matching the scalar transcription).
    for i in 0..=n {
        p[n - 1 + i] = s_prev[i];
    }

    // Fix-to-1: lanes with Ĉ_{t-1}^{n-1} = 1 force the n+t LSBs to 1.
    if FIX && t >= 1 && n >= 2 {
        for pr in p[..n + t].iter_mut() {
            *pr |= c_dff;
        }
    }

    // Transpose the product planes back into per-lane words.
    for (l, o) in out.iter_mut().enumerate() {
        let mut v = 0u64;
        for (r, &pr) in p[..2 * n].iter().enumerate() {
            v |= ((pr >> l) & 1) << r;
        }
        *o = v;
    }
}

impl BatchMultiplier for BitSlicedBitLevel {
    fn n(&self) -> u32 {
        self.n
    }

    fn name(&self) -> String {
        format!("bitlevel(n={},t={}{})", self.n, self.t, if self.fix { ",fix" } else { "" })
    }

    fn mul_batch(&self, a: &[u64], b: &[u64], out: &mut [u64]) {
        assert_eq!(a.len(), b.len(), "operand slices must have equal length");
        assert_eq!(a.len(), out.len(), "output slice must match operand length");
        let (n, t) = (self.n as usize, self.t as usize);
        for ((ca, cb), co) in a.chunks(64).zip(b.chunks(64)).zip(out.chunks_mut(64)) {
            if self.fix {
                bitlevel_group::<true>(ca, cb, co, n, t);
            } else {
                bitlevel_group::<false>(ca, cb, co, n, t);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multiplier::bitlevel::approx_seq_mul_bitlevel;
    use crate::util::prop::Cases;

    /// Every ragged tail length through the 4-wide unroll, for each
    /// word-level baseline kernel.
    #[test]
    fn batch_matches_scalar_all_tail_lengths() {
        let n = 8u32;
        for len in 0..=9usize {
            let a: Vec<u64> = (0..len as u64).map(|i| (i * 41) & 0xFF).collect();
            let b: Vec<u64> = (0..len as u64).map(|i| (i * 89 + 3) & 0xFF).collect();
            let mut out = vec![0u64; len];
            let models: Vec<(Box<dyn Multiplier>, Box<dyn BatchMultiplier>)> = vec![
                (
                    Box::new(TruncatedMul { n, k: 3 }),
                    Box::new(TruncatedMul { n, k: 3 }),
                ),
                (
                    Box::new(BrokenArrayMul { n, hbl: 2, vbl: 4 }),
                    Box::new(BrokenArrayMul { n, hbl: 2, vbl: 4 }),
                ),
                (Box::new(MitchellLog { n }), Box::new(MitchellLog { n })),
                (Box::new(Kulkarni2x2 { n }), Box::new(Kulkarni2x2 { n })),
            ];
            for (scalar, batch) in &models {
                batch.mul_batch(&a, &b, &mut out);
                for i in 0..len {
                    assert_eq!(
                        out[i],
                        scalar.mul(a[i], b[i]),
                        "{} len={len} i={i}",
                        BatchMultiplier::name(batch.as_ref())
                    );
                }
            }
        }
    }

    #[test]
    fn prop_trunc_matches_scalar_random() {
        Cases::new(0x7A11C, 200).run(|rng, _| {
            let n = 1 + rng.next_below(32) as u32;
            let k = rng.next_below(n as u64 + 1) as u32;
            let len = 1 + rng.next_below(70) as usize;
            let a: Vec<u64> = (0..len).map(|_| rng.next_bits(n)).collect();
            let b: Vec<u64> = (0..len).map(|_| rng.next_bits(n)).collect();
            let mut out = vec![0u64; len];
            trunc_mul_batch(&a, &b, &mut out, n, k);
            let m = TruncatedMul { n, k };
            for i in 0..len {
                assert_eq!(out[i], m.mul(a[i], b[i]), "n={n} k={k} i={i}");
            }
        });
    }

    #[test]
    fn prop_bam_matches_scalar_random() {
        Cases::new(0xBA40, 200).run(|rng, _| {
            let n = 1 + rng.next_below(32) as u32;
            let hbl = rng.next_below(n as u64 + 1) as u32;
            let vbl = rng.next_below(n as u64 + 1) as u32;
            let len = 1 + rng.next_below(70) as usize;
            let a: Vec<u64> = (0..len).map(|_| rng.next_bits(n)).collect();
            let b: Vec<u64> = (0..len).map(|_| rng.next_bits(n)).collect();
            let mut out = vec![0u64; len];
            bam_mul_batch(&a, &b, &mut out, n, hbl, vbl);
            let m = BrokenArrayMul { n, hbl, vbl };
            for i in 0..len {
                assert_eq!(out[i], m.mul(a[i], b[i]), "n={n} hbl={hbl} vbl={vbl} i={i}");
            }
        });
    }

    #[test]
    fn prop_mitchell_matches_scalar_random() {
        Cases::new(0x317C, 200).run(|rng, _| {
            let n = 1 + rng.next_below(32) as u32;
            let len = 1 + rng.next_below(70) as usize;
            // Bias some operands to 0 and powers of two (the scalar
            // model's special paths).
            let gen = |rng: &mut crate::util::rng::Xoshiro256| match rng.next_below(8) {
                0 => 0u64,
                1 => 1u64 << rng.next_below(n as u64),
                _ => rng.next_bits(n),
            };
            let a: Vec<u64> = (0..len).map(|_| gen(rng)).collect();
            let b: Vec<u64> = (0..len).map(|_| gen(rng)).collect();
            let mut out = vec![0u64; len];
            mitchell_mul_batch(&a, &b, &mut out, n);
            let m = MitchellLog { n };
            for i in 0..len {
                assert_eq!(out[i], m.mul(a[i], b[i]), "n={n} a={} b={} i={i}", a[i], b[i]);
            }
        });
    }

    #[test]
    fn kulkarni_closed_form_matches_recursion_exhaustive_n4() {
        let m = Kulkarni2x2 { n: 4 };
        let a: Vec<u64> = (0..256u64).map(|i| i & 0xF).collect();
        let b: Vec<u64> = (0..256u64).map(|i| i >> 4).collect();
        let mut out = vec![0u64; 256];
        kulkarni_mul_batch(&a, &b, &mut out, 4);
        for i in 0..256 {
            assert_eq!(out[i], m.mul(a[i], b[i]), "a={} b={}", a[i], b[i]);
        }
    }

    #[test]
    fn prop_kulkarni_matches_recursion_random() {
        Cases::new(0x2317, 200).run(|rng, _| {
            let n = 1u32 << (1 + rng.next_below(5)); // 2, 4, 8, 16, 32
            let len = 1 + rng.next_below(70) as usize;
            let a: Vec<u64> = (0..len).map(|_| rng.next_bits(n)).collect();
            let b: Vec<u64> = (0..len).map(|_| rng.next_bits(n)).collect();
            let mut out = vec![0u64; len];
            kulkarni_mul_batch(&a, &b, &mut out, n);
            let m = Kulkarni2x2 { n };
            for i in 0..len {
                assert_eq!(out[i], m.mul(a[i], b[i]), "n={n} a={} b={} i={i}", a[i], b[i]);
            }
        });
    }

    #[test]
    fn bitsliced_oracle_matches_scalar_transcription_exhaustive_small() {
        for n in [1u32, 2, 4, 5] {
            for t in 0..n {
                for fix in [false, true] {
                    let m = BitSlicedBitLevel::new(n, t, fix);
                    let space = 1u64 << (2 * n);
                    let mask = (1u64 << n) - 1;
                    let a: Vec<u64> = (0..space).map(|i| i & mask).collect();
                    let b: Vec<u64> = (0..space).map(|i| i >> n).collect();
                    let mut out = vec![0u64; a.len()];
                    m.mul_batch(&a, &b, &mut out);
                    for i in 0..a.len() {
                        assert_eq!(
                            out[i],
                            approx_seq_mul_bitlevel(a[i], b[i], n, t, fix),
                            "n={n} t={t} fix={fix} a={} b={}",
                            a[i],
                            b[i]
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn prop_bitsliced_oracle_matches_scalar_random() {
        Cases::new(0xB17B, 60).run(|rng, _| {
            let n = 1 + rng.next_below(32) as u32;
            let t = rng.next_below(n as u64) as u32;
            let fix = rng.next_bits(1) == 1;
            // Ragged lengths around the 64-lane group size.
            let len = 1 + rng.next_below(150) as usize;
            let a: Vec<u64> = (0..len).map(|_| rng.next_bits(n)).collect();
            let b: Vec<u64> = (0..len).map(|_| rng.next_bits(n)).collect();
            let m = BitSlicedBitLevel::new(n, t, fix);
            let mut out = vec![0u64; len];
            m.mul_batch(&a, &b, &mut out);
            for i in 0..len {
                assert_eq!(
                    out[i],
                    approx_seq_mul_bitlevel(a[i], b[i], n, t, fix),
                    "n={n} t={t} fix={fix} i={i} a={} b={}",
                    a[i],
                    b[i]
                );
            }
        });
    }

    #[test]
    fn batch_trait_names_match_scalar_names() {
        let t = TruncatedMul { n: 8, k: 2 };
        assert_eq!(BatchMultiplier::name(&t), Multiplier::name(&t));
        let bam = BrokenArrayMul { n: 8, hbl: 1, vbl: 3 };
        assert_eq!(BatchMultiplier::name(&bam), Multiplier::name(&bam));
        let mi = MitchellLog { n: 8 };
        assert_eq!(BatchMultiplier::name(&mi), Multiplier::name(&mi));
        let ku = Kulkarni2x2 { n: 8 };
        assert_eq!(BatchMultiplier::name(&ku), Multiplier::name(&ku));
        assert_eq!(BitSlicedBitLevel::new(8, 3, true).name(), "bitlevel(n=8,t=3,fix)");
        assert_eq!(BitSlicedBitLevel::new(8, 3, false).name(), "bitlevel(n=8,t=3)");
    }

    #[test]
    #[should_panic(expected = "equal length")]
    fn rejects_mismatched_lengths() {
        let mut out = [0u64; 2];
        trunc_mul_batch(&[1, 2, 3], &[1, 2], &mut out, 4, 1);
    }
}
