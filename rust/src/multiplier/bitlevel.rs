//! Literal transcription of the paper's Boolean recurrences (§IV-A).
//!
//! This module is deliberately written from the `Ŝ_i^j` / `Ĉ_i^j` equations
//! rather than from the word-level algorithm, so the two implementations can
//! catch a mis-reading of the paper in either direction. It is the
//! ground-truth oracle for every other model (word-level, Pallas kernel,
//! gate-level netlist).

/// Approximate sequential multiply per the paper's equations.
///
/// `n ≤ 32` (result fits u64), `0 ≤ t < n`. `t = 0` yields the fully
/// accurate multiplier (the LSP adder is empty; the paper's `i = t` D-FF
/// case never fires).
pub fn approx_seq_mul_bitlevel(a: u64, b: u64, n: u32, t: u32, fix_to_1: bool) -> u64 {
    let n = n as usize;
    let t = t as usize;
    assert!(n >= 1 && n <= 32);
    assert!(t < n);
    let abit: Vec<u8> = (0..n).map(|i| ((a >> i) & 1) as u8).collect();
    let bbit: Vec<u8> = (0..n).map(|j| ((b >> j) & 1) as u8).collect();

    // S[j][i], i in [0, n]; S[j][n] is the carry-out C_{n-1}^j.
    let mut s = vec![vec![0u8; n + 1]; n];
    // C[j][i], i in [0, n).
    let mut c = vec![vec![0u8; n]; n];

    // j = 0: S^0 = a & -b_0; C_i^0 = 0 (paper's first cases).
    for i in 0..n {
        s[0][i] = abit[i] & bbit[0];
    }
    s[0][n] = 0;

    for j in 1..n {
        // i = 0: S = Ŝ_1^{j-1} ⊕ (a_0 ∧ b_j), C = Ŝ_1^{j-1} ∧ (a_0 ∧ b_j).
        let pp0 = abit[0] & bbit[j];
        s[j][0] = s[j - 1][1] ^ pp0;
        c[j][0] = s[j - 1][1] & pp0;
        for i in 1..n {
            let pp = abit[i] & bbit[j];
            // The segmentation: bit t consumes the D-FF'd previous-cycle
            // LSP carry-out Ĉ_{t-1}^{j-1}; all other bits ripple in-cycle.
            let cin = if i == t { c[j - 1][t - 1] } else { c[j][i - 1] };
            s[j][i] = s[j - 1][i + 1] ^ cin ^ pp;
            c[j][i] = ((s[j - 1][i + 1] ^ pp) & cin) | (s[j - 1][i + 1] & pp);
        }
        // i = n: Ŝ_n^j = Ĉ_{n-1}^j.
        s[j][n] = c[j][n - 1];
    }

    // Product construction (the paper's p̂_r cases).
    let mut p: u64 = 0;
    for r in 0..n.saturating_sub(1) {
        p |= (s[r][0] as u64) << r;
    }
    for r in (n - 1)..(2 * n) {
        p |= (s[n - 1][r + 1 - n] as u64) << r;
    }

    // Fix-to-1: Ĉ_{t-1}^{n-1} = 1 forces the n+t LSBs to 1.
    if fix_to_1 && t >= 1 && n >= 2 && c[n - 1][t - 1] == 1 {
        p |= (1u64 << (n + t)) - 1;
    }
    p
}

/// The fully accurate recurrence (the paper's unsegmented `S_i^j`/`C_i^j`,
/// §III-A) — must equal `a * b` for all inputs; used to validate the
/// transcription machinery itself.
pub fn exact_seq_mul_bitlevel(a: u64, b: u64, n: u32) -> u64 {
    // t = 0 disables the D-FF path entirely.
    approx_seq_mul_bitlevel(a, b, n, 0, false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Cases;

    #[test]
    fn exact_recurrence_is_multiplication() {
        for n in 1..=8u32 {
            for a in 0..(1u64 << n.min(6)) {
                for b in 0..(1u64 << n.min(6)) {
                    assert_eq!(exact_seq_mul_bitlevel(a, b, n), a * b, "n={n} a={a} b={b}");
                }
            }
        }
    }

    #[test]
    fn golden_table2b() {
        assert_eq!(approx_seq_mul_bitlevel(0b1011, 0b0110, 4, 2, false), 82);
    }

    #[test]
    fn prop_exact_random_wide() {
        Cases::new(0xB17, 200).run(|rng, _| {
            let n = 1 + rng.next_below(32) as u32;
            let a = rng.next_bits(n);
            let b = rng.next_bits(n);
            assert_eq!(exact_seq_mul_bitlevel(a, b, n), a * b);
        });
    }

    #[test]
    fn approximation_only_differs_when_carry_crosses_t() {
        // If b has a single set bit there is only one nonzero partial
        // product, no carries are ever generated, and the result is exact.
        for n in [8u32, 16] {
            for t in 1..n / 2 {
                for j in 0..n {
                    let b = 1u64 << j;
                    let a = (1u64 << n) - 1;
                    assert_eq!(approx_seq_mul_bitlevel(a, b, n, t, false), a * b);
                }
            }
        }
    }
}
