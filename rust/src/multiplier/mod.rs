//! Software models of the paper's multipliers and the related-work baselines.
//!
//! * [`wordlevel`] — the fast word-level model of the segmented-carry
//!   sequential multiplier (the L3 hot path for exhaustive / Monte-Carlo
//!   evaluation), generic over the word type so the same code serves
//!   n ≤ 32 (`u64`), n ≤ 63 (`u128`) and n ≤ 255 ([`wide::U512`]).
//! * [`bitlevel`] — a literal transcription of the paper's `Ŝ_i^j`/`Ĉ_i^j`
//!   Boolean recurrences (§IV-A); the ground-truth oracle.
//! * [`wide`] — a small fixed-width U512 integer for the n ∈ {64,128,256}
//!   hardware sweeps (Fig. 3).
//! * [`baselines`] — re-implemented approximate multipliers from the
//!   related work plotted in Fig. 2 (truncation / broken-array, Mitchell's
//!   logarithmic multiplier, Kulkarni's 2x2-block multiplier).
//! * [`spec`] — the design-agnostic [`MultiplierSpec`] registry: every
//!   implemented design as plain hashable data, with canonicalization for
//!   cache dedup and [`spec::DesignSet`] naming the sweepable families.
//! * [`batch`] — the batched evaluation kernels: [`batch::BatchMultiplier`]
//!   evaluates operand *slices* with a monomorphized, branch-free,
//!   4-wide-unrolled inner loop (one virtual call per slice instead of one
//!   per pair). This is what the exhaustive / Monte-Carlo sweeps and the
//!   coordinator's CPU backend actually run; the scalar [`Multiplier`]
//!   trait remains for single multiplies and as the differential-test
//!   reference (adapted via [`batch::ScalarBatch`] /
//!   [`spec::OwnedScalarBatch`]).
//! * [`batch_baselines`] — branch-free batch kernels for the baseline
//!   family (truncation / broken-array collapse to one hardware multiply
//!   plus masked adds, Mitchell goes branch-free via `leading_zeros` and
//!   a mask select, Kulkarni to `a*b - 2 f(a) f(b)` with a SWAR digit
//!   marker) and the bit-sliced 64-lane oracle
//!   ([`batch_baselines::BitSlicedBitLevel`]) — so every design in the
//!   [`spec::MultiplierSpec`] registry evaluates through a true batch
//!   kernel ([`batch::DispatchClass::Batched`]).

pub mod baselines;
pub mod batch;
pub mod batch_baselines;
pub mod bitlevel;
pub mod spec;
pub mod wide;
pub mod wordlevel;

pub use batch::{approx_seq_mul_batch, exact_mul_batch, BatchMultiplier, DispatchClass, ScalarBatch};
pub use batch_baselines::{
    bam_mul_batch, kulkarni_mul_batch, mitchell_mul_batch, trunc_mul_batch, BitSlicedBitLevel,
};
pub use bitlevel::approx_seq_mul_bitlevel;
pub use spec::{DesignSet, MultiplierSpec, OwnedScalarBatch};
pub use wide::U512;
pub use wordlevel::{approx_seq_mul, approx_seq_mul_u128, approx_seq_mul_wide, exact_mul};

/// A (possibly approximate) n-bit unsigned multiplier producing 2n-bit
/// products. All Fig. 2 error evaluation is driven through this trait.
pub trait Multiplier: Sync {
    /// Operand bit-width n (operands are `< 2^n`); n ≤ 32 for this trait
    /// (products fit in u64).
    fn n(&self) -> u32;
    /// The (approximate) product of `a * b`.
    fn mul(&self, a: u64, b: u64) -> u64;
    /// Display name used in reports, e.g. `"segmul(n=8,t=4,fix)"`.
    fn name(&self) -> String;
}

/// The paper's design: accuracy-configurable sequential multiplier with a
/// carry chain segmented at bit `t` (t = 0 degenerates to accurate).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SegmentedSeqMul {
    /// Operand bit-width.
    pub n: u32,
    /// Splitting point (`0` = accurate).
    pub t: u32,
    /// Compensate by fixing the segmented carry to 1.
    pub fix_to_1: bool,
}

impl SegmentedSeqMul {
    /// A segmented multiplier (asserts `n <= 32`, `t < n`).
    pub fn new(n: u32, t: u32, fix_to_1: bool) -> Self {
        assert!(n >= 1 && n <= 32, "SegmentedSeqMul supports 1 <= n <= 32");
        assert!(t < n, "splitting point must satisfy 0 <= t < n");
        Self { n, t, fix_to_1 }
    }

    /// The paper's recommended configuration space is `t <= n/2`.
    pub fn paper_configs(n: u32, fix_to_1: bool) -> Vec<Self> {
        (2..=n / 2).map(|t| Self::new(n, t, fix_to_1)).collect()
    }
}

impl Multiplier for SegmentedSeqMul {
    fn n(&self) -> u32 {
        self.n
    }

    fn mul(&self, a: u64, b: u64) -> u64 {
        wordlevel::approx_seq_mul(a, b, self.n, self.t, self.fix_to_1)
    }

    fn name(&self) -> String {
        format!(
            "segmul(n={},t={}{})",
            self.n,
            self.t,
            if self.fix_to_1 { ",fix" } else { "" }
        )
    }
}

/// The accurate reference multiplier.
#[derive(Clone, Copy, Debug)]
pub struct AccurateMul {
    /// Operand bit-width.
    pub n: u32,
}

impl Multiplier for AccurateMul {
    fn n(&self) -> u32 {
        self.n
    }
    fn mul(&self, a: u64, b: u64) -> u64 {
        wordlevel::exact_mul(a, b, self.n)
    }
    fn name(&self) -> String {
        format!("accurate(n={})", self.n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_configs_range() {
        let cfgs = SegmentedSeqMul::paper_configs(8, true);
        assert_eq!(cfgs.iter().map(|c| c.t).collect::<Vec<_>>(), vec![2, 3, 4]);
    }

    #[test]
    #[should_panic]
    fn rejects_t_equal_n() {
        SegmentedSeqMul::new(8, 8, false);
    }

    #[test]
    fn trait_dispatch_matches_fn() {
        let m = SegmentedSeqMul::new(8, 3, true);
        assert_eq!(m.mul(200, 100), wordlevel::approx_seq_mul(200, 100, 8, 3, true));
        assert_eq!(Multiplier::name(&m), "segmul(n=8,t=3,fix)");
    }
}
