//! Batched word-level kernels — the L3 hot path.
//!
//! The scalar [`super::Multiplier`] trait costs one virtual call per
//! operand pair, which blocks inlining of a ~ten-cycle kernel and starves
//! the out-of-order core. [`BatchMultiplier`] is the batched counterpart:
//! one (possibly virtual) call per operand *slice*, with the inner loop
//! monomorphized over the fix-to-1 flag and manually unrolled four pairs
//! wide so independent multiplications overlap in flight. The kernel body
//! is the branch-free generic recurrence of [`super::wordlevel`] (no
//! data-dependent early exit — uniform latency is what lets the unrolled
//! lanes pipeline), and bit-exactness against the scalar fast path, the
//! bit-level `Ŝ/Ĉ` oracle, and the gate-level netlist is enforced by
//! `tests/kernel_differential.rs`.
//!
//! Layering: this module only computes products. The streaming statistics
//! side of the batched engine (exact products + [`crate::error::metrics::
//! ErrorStats`] accumulation) lives in `error::stream`, which drives these
//! kernels through scratch blocks sized for the L1 cache.

use super::wordlevel::MulWord;
use super::{AccurateMul, Multiplier, SegmentedSeqMul};

/// Which dispatch tier a [`BatchMultiplier`]'s `mul_batch` runs on.
/// Telemetry only — the class never affects results, but sweeps surface
/// it so a design silently regressing to per-pair dispatch is visible
/// (see `SessionTelemetry::kernel_dispatch`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum DispatchClass {
    /// A true batch kernel: monomorphized inner loop, branch-free /
    /// uniform latency per pair, no per-pair virtual calls.
    Batched,
    /// A lowered accelerator module: the design executed through the PJRT
    /// backend's artifact path (an AOT-compiled stats module or a
    /// `segmul lower` module) — one execution per operand batch, never a
    /// host-side per-pair loop. Only the PJRT backend reports this.
    Pjrt,
    /// A per-pair adapter: one `Multiplier::mul` virtual call per operand
    /// pair. Only the differential-test reference evaluators report this.
    Scalar,
}

impl DispatchClass {
    /// Report name of the tier.
    pub fn name(&self) -> &'static str {
        match self {
            DispatchClass::Batched => "batched",
            DispatchClass::Pjrt => "pjrt",
            DispatchClass::Scalar => "scalar",
        }
    }
}

/// A (possibly approximate) n-bit multiplier evaluated over operand
/// slices. `mul_batch` must satisfy `out[i] = mul(a[i], b[i])` for the
/// corresponding scalar model; implementations amortize dispatch and
/// expose instruction-level parallelism across pairs.
pub trait BatchMultiplier: Sync {
    /// Operand bit-width n (operands `< 2^n`, products fit in u64; n ≤ 32).
    fn n(&self) -> u32;
    /// Display name used in reports.
    fn name(&self) -> String;
    /// Batched products: `out[i] = mul(a[i], b[i])`. All three slices must
    /// have equal length.
    fn mul_batch(&self, a: &[u64], b: &[u64], out: &mut [u64]);
    /// The dispatch tier of [`Self::mul_batch`] — [`DispatchClass::Batched`]
    /// unless the implementation is a per-pair scalar adapter.
    fn dispatch_class(&self) -> DispatchClass {
        DispatchClass::Batched
    }
}

/// One branch-free segmented-carry multiply (the generic word-level
/// recurrence, u64-specialized, fix-to-1 monomorphized).
#[inline(always)]
fn seq_mul_one<const FIX: bool>(a: u64, b: u64, n: u32, t: u32, mt: u64) -> u64 {
    let mut s = a & (b & 1).wrapping_neg();
    let mut cff = 0u64;
    let mut low = 0u64;
    let mut j = 1u32;
    while j < n {
        low |= (s & 1) << (j - 1);
        let x = s >> 1;
        let pp = a & ((b >> j) & 1).wrapping_neg();
        let lsum = (x & mt) + (pp & mt);
        let clsp = (lsum >> t) & 1;
        let msum = (x >> t) + (pp >> t) + cff;
        s = (msum << t) | (lsum & mt);
        cff = clsp;
        j += 1;
    }
    let mut phat = (s << (n - 1)) | low;
    if FIX && cff == 1 {
        phat |= (1u64 << (n + t)) - 1;
    }
    phat
}

/// Monomorphized batch loop, unrolled 4 pairs wide. The four lanes carry
/// no data dependencies, so their recurrences interleave in the pipeline.
fn batch_kernel<const FIX: bool>(a: &[u64], b: &[u64], out: &mut [u64], n: u32, t: u32) {
    let mt = (1u64 << t) - 1;
    let mut ac = a.chunks_exact(4);
    let mut bc = b.chunks_exact(4);
    let mut oc = out.chunks_exact_mut(4);
    for ((ca, cb), co) in (&mut ac).zip(&mut bc).zip(&mut oc) {
        co[0] = seq_mul_one::<FIX>(ca[0], cb[0], n, t, mt);
        co[1] = seq_mul_one::<FIX>(ca[1], cb[1], n, t, mt);
        co[2] = seq_mul_one::<FIX>(ca[2], cb[2], n, t, mt);
        co[3] = seq_mul_one::<FIX>(ca[3], cb[3], n, t, mt);
    }
    for ((&ai, &bi), o) in ac.remainder().iter().zip(bc.remainder()).zip(oc.into_remainder()) {
        *o = seq_mul_one::<FIX>(ai, bi, n, t, mt);
    }
}

/// Batched approximate products of the paper's segmented-carry sequential
/// multiplier: `out[i] = approx_seq_mul(a[i], b[i], n, t, fix)`, bit-exact
/// with the scalar model. Requirements: equal slice lengths, `1 <= n <= 32`,
/// `t < n`, operands `< 2^n`.
pub fn approx_seq_mul_batch(a: &[u64], b: &[u64], out: &mut [u64], n: u32, t: u32, fix: bool) {
    assert_eq!(a.len(), b.len(), "operand slices must have equal length");
    assert_eq!(a.len(), out.len(), "output slice must match operand length");
    assert!(n >= 1 && n <= 32, "approx_seq_mul_batch supports 1 <= n <= 32");
    assert!(t < n, "splitting point must satisfy 0 <= t < n");
    debug_assert!(a.iter().chain(b).all(|&x| x >> n == 0), "operands must be < 2^n");
    if fix {
        batch_kernel::<true>(a, b, out, n, t);
    } else {
        batch_kernel::<false>(a, b, out, n, t);
    }
}

/// Batched exact 2n-bit products (n ≤ 32): `out[i] = a[i] * b[i]`.
/// The loop is multiplication-only, so it auto-vectorizes.
pub fn exact_mul_batch(a: &[u64], b: &[u64], out: &mut [u64]) {
    assert_eq!(a.len(), b.len(), "operand slices must have equal length");
    assert_eq!(a.len(), out.len(), "output slice must match operand length");
    for ((&x, &y), o) in a.iter().zip(b).zip(out.iter_mut()) {
        *o = x * y;
    }
}

impl BatchMultiplier for SegmentedSeqMul {
    fn n(&self) -> u32 {
        self.n
    }

    fn name(&self) -> String {
        Multiplier::name(self)
    }

    fn mul_batch(&self, a: &[u64], b: &[u64], out: &mut [u64]) {
        approx_seq_mul_batch(a, b, out, self.n, self.t, self.fix_to_1);
    }
}

impl BatchMultiplier for AccurateMul {
    fn n(&self) -> u32 {
        self.n
    }

    fn name(&self) -> String {
        Multiplier::name(self)
    }

    fn mul_batch(&self, a: &[u64], b: &[u64], out: &mut [u64]) {
        exact_mul_batch(a, b, out);
    }
}

/// Adapter running any scalar [`Multiplier`] under the batched interface
/// (one virtual call per pair). Since every registry design now has a
/// true batch kernel (`batch_baselines`), this survives only as the
/// differential-test reference and for ad-hoc user-defined scalar models;
/// no production sweep path dispatches through it.
pub struct ScalarBatch<'a, M: Multiplier + ?Sized>(pub &'a M);

impl<M: Multiplier + ?Sized> BatchMultiplier for ScalarBatch<'_, M> {
    fn n(&self) -> u32 {
        self.0.n()
    }

    fn name(&self) -> String {
        self.0.name()
    }

    fn dispatch_class(&self) -> DispatchClass {
        DispatchClass::Scalar
    }

    fn mul_batch(&self, a: &[u64], b: &[u64], out: &mut [u64]) {
        assert_eq!(a.len(), b.len(), "operand slices must have equal length");
        assert_eq!(a.len(), out.len(), "output slice must match operand length");
        for ((&x, &y), o) in a.iter().zip(b).zip(out.iter_mut()) {
            *o = self.0.mul(x, y);
        }
    }
}

/// Word-generic batched kernel for the wide models (u128 / U512): the same
/// branch-free recurrence over any [`MulWord`]. Slower than the u64 path
/// (no unroll) — used by software cross-checks, not the hot loop.
pub fn approx_seq_mul_batch_word<W: MulWord>(a: &[W], b: &[W], out: &mut [W], n: u32, t: u32, fix: bool) {
    assert_eq!(a.len(), b.len(), "operand slices must have equal length");
    assert_eq!(a.len(), out.len(), "output slice must match operand length");
    for ((&x, &y), o) in a.iter().zip(b).zip(out.iter_mut()) {
        *o = super::wordlevel::approx_seq_mul_word(x, y, n, t, fix);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multiplier::wordlevel::approx_seq_mul;
    use crate::util::prop::Cases;

    #[test]
    fn batch_matches_scalar_all_tail_lengths() {
        // Exercise both the unrolled body and every remainder length.
        let (n, t) = (8u32, 3u32);
        for fix in [false, true] {
            for len in 0..=9usize {
                let a: Vec<u64> = (0..len as u64).map(|i| (i * 37) & 0xFF).collect();
                let b: Vec<u64> = (0..len as u64).map(|i| (i * 91 + 5) & 0xFF).collect();
                let mut out = vec![0u64; len];
                approx_seq_mul_batch(&a, &b, &mut out, n, t, fix);
                for i in 0..len {
                    assert_eq!(out[i], approx_seq_mul(a[i], b[i], n, t, fix), "len={len} i={i}");
                }
            }
        }
    }

    #[test]
    fn prop_batch_matches_scalar_random() {
        Cases::new(0xBA7C, 200).run(|rng, _| {
            let n = 1 + rng.next_below(32) as u32;
            let t = rng.next_below(n as u64) as u32;
            let fix = rng.next_bits(1) == 1;
            let len = 1 + rng.next_below(64) as usize;
            let a: Vec<u64> = (0..len).map(|_| rng.next_bits(n)).collect();
            let b: Vec<u64> = (0..len).map(|_| rng.next_bits(n)).collect();
            let mut out = vec![0u64; len];
            approx_seq_mul_batch(&a, &b, &mut out, n, t, fix);
            for i in 0..len {
                assert_eq!(out[i], approx_seq_mul(a[i], b[i], n, t, fix), "n={n} t={t} fix={fix} i={i}");
            }
        });
    }

    #[test]
    fn trait_impls_agree_with_scalar_trait() {
        let m = SegmentedSeqMul::new(8, 4, true);
        let a = [200u64, 0, 255, 7];
        let b = [100u64, 0, 255, 9];
        let mut out = [0u64; 4];
        BatchMultiplier::mul_batch(&m, &a, &b, &mut out);
        for i in 0..4 {
            assert_eq!(out[i], Multiplier::mul(&m, a[i], b[i]));
        }
        assert_eq!(BatchMultiplier::name(&m), Multiplier::name(&m));
        assert_eq!(BatchMultiplier::n(&m), 8);

        let acc = AccurateMul { n: 8 };
        BatchMultiplier::mul_batch(&acc, &a, &b, &mut out);
        assert_eq!(out[0], 200 * 100);
    }

    #[test]
    fn scalar_batch_adapter_forwards() {
        let m = SegmentedSeqMul::new(6, 2, false);
        let dynm: &dyn Multiplier = &m;
        let wrap = ScalarBatch(dynm);
        assert_eq!(wrap.n(), 6);
        assert_eq!(wrap.name(), "segmul(n=6,t=2)");
        let a = [13u64, 63, 0];
        let b = [7u64, 63, 5];
        let mut got = [0u64; 3];
        let mut want = [0u64; 3];
        wrap.mul_batch(&a, &b, &mut got);
        approx_seq_mul_batch(&a, &b, &mut want, 6, 2, false);
        assert_eq!(got, want);
    }

    #[test]
    fn exact_batch_is_exact() {
        let a = [0u64, 1, 65535, 40000];
        let b = [9u64, 1, 65535, 3];
        let mut out = [0u64; 4];
        exact_mul_batch(&a, &b, &mut out);
        for i in 0..4 {
            assert_eq!(out[i], a[i] * b[i]);
        }
    }

    #[test]
    fn word_generic_batch_matches_u64_batch() {
        let (n, t) = (20u32, 9u32);
        let a: Vec<u64> = (0..17u64).map(|i| (i * 48271) & 0xF_FFFF).collect();
        let b: Vec<u64> = (0..17u64).map(|i| (i * 69621 + 11) & 0xF_FFFF).collect();
        let mut fast = vec![0u64; 17];
        let mut generic = vec![0u64; 17];
        approx_seq_mul_batch(&a, &b, &mut fast, n, t, true);
        approx_seq_mul_batch_word(&a, &b, &mut generic, n, t, true);
        assert_eq!(fast, generic);
    }

    #[test]
    #[should_panic(expected = "equal length")]
    fn rejects_mismatched_lengths() {
        let mut out = [0u64; 2];
        approx_seq_mul_batch(&[1, 2, 3], &[1, 2], &mut out, 4, 1, false);
    }

    #[test]
    fn dispatch_classes() {
        let m = SegmentedSeqMul::new(8, 3, false);
        assert_eq!(BatchMultiplier::dispatch_class(&m), DispatchClass::Batched);
        assert_eq!(ScalarBatch(&m).dispatch_class(), DispatchClass::Scalar);
        assert_eq!(DispatchClass::Batched.name(), "batched");
        assert_eq!(DispatchClass::Pjrt.name(), "pjrt");
        assert_eq!(DispatchClass::Scalar.name(), "scalar");
    }
}
