//! Re-implemented approximate multipliers from the related work.
//!
//! Fig. 2 of the paper compares the segmented-carry sequential multiplier
//! against combinatorial approximate multipliers from the literature. The
//! authors' exact RTL is not available, so we re-implement the three classic
//! families those works build on, each with tunable aggressiveness, to
//! populate the same accuracy axes:
//!
//! * [`TruncatedMul`] / [`BrokenArrayMul`] — partial-product truncation
//!   (vertical/horizontal break lines), the basis of fixed-width and
//!   broken-array multipliers.
//! * [`MitchellLog`] — Mitchell's logarithmic multiplier, the basis of the
//!   approximate logarithmic designs (Liu et al. [10]).
//! * [`Kulkarni2x2`] — the underdesigned 2×2-block multiplier
//!   (3×3 ≈ 7 building block), the basis of block-composed designs.

use super::Multiplier;

/// Vertical truncation: every partial-product bit in columns `< k` is
/// dropped (no compensation). `k = 0` is exact.
#[derive(Clone, Copy, Debug)]
pub struct TruncatedMul {
    /// Operand bit-width.
    pub n: u32,
    /// Truncated columns (partial-product bits in columns `< k` dropped).
    pub k: u32,
}

impl Multiplier for TruncatedMul {
    fn n(&self) -> u32 {
        self.n
    }

    fn mul(&self, a: u64, b: u64) -> u64 {
        let mut p = 0u64;
        for j in 0..self.n {
            if (b >> j) & 1 == 0 {
                continue;
            }
            let drop = self.k.saturating_sub(j).min(self.n);
            p += (a >> drop) << (j + drop);
        }
        p
    }

    fn name(&self) -> String {
        format!("trunc(n={},k={})", self.n, self.k)
    }
}

/// Broken-array multiplier: drops partial-product rows `j < hbl` and
/// columns `< vbl`. `(0, 0)` is exact; `(0, k)` equals [`TruncatedMul`].
#[derive(Clone, Copy, Debug)]
pub struct BrokenArrayMul {
    /// Operand bit-width.
    pub n: u32,
    /// Horizontal break level (rows dropped).
    pub hbl: u32,
    /// Vertical break level (columns dropped).
    pub vbl: u32,
}

impl Multiplier for BrokenArrayMul {
    fn n(&self) -> u32 {
        self.n
    }

    fn mul(&self, a: u64, b: u64) -> u64 {
        let mut p = 0u64;
        for j in self.hbl..self.n {
            if (b >> j) & 1 == 0 {
                continue;
            }
            let drop = self.vbl.saturating_sub(j).min(self.n);
            p += (a >> drop) << (j + drop);
        }
        p
    }

    fn name(&self) -> String {
        format!("bam(n={},hbl={},vbl={})", self.n, self.hbl, self.vbl)
    }
}

/// Mitchell's logarithmic multiplier: `p ≈ antilog2(log2 a + log2 b)` with
/// piecewise-linear log/antilog. Exact when both operands are powers of two.
#[derive(Clone, Copy, Debug)]
pub struct MitchellLog {
    /// Operand bit-width.
    pub n: u32,
}

impl Multiplier for MitchellLog {
    fn n(&self) -> u32 {
        self.n
    }

    fn mul(&self, a: u64, b: u64) -> u64 {
        if a == 0 || b == 0 {
            return 0;
        }
        let k1 = 63 - a.leading_zeros(); // characteristic of a
        let k2 = 63 - b.leading_zeros();
        let x1 = a - (1u64 << k1); // mantissa numerators (x / 2^k)
        let x2 = b - (1u64 << k2);
        let k = k1 + k2;
        // S = 2^K * (f1 + f2)
        let s = (x1 << k2) + (x2 << k1);
        if s < (1u64 << k) {
            (1u64 << k) + s // 2^K (1 + f1 + f2)
        } else {
            s << 1 // 2^{K+1} (f1 + f2)
        }
    }

    fn name(&self) -> String {
        format!("mitchell(n={})", self.n)
    }
}

/// Kulkarni's underdesigned multiplier: exact 2×2 blocks except
/// `3 × 3 = 7` (saves the MSB of the 2×2 product), composed recursively.
/// `n` must be a power of two.
#[derive(Clone, Copy, Debug)]
pub struct Kulkarni2x2 {
    /// Operand bit-width.
    pub n: u32,
}

impl Kulkarni2x2 {
    fn mul_rec(a: u64, b: u64, n: u32) -> u64 {
        if n == 2 {
            return if a == 3 && b == 3 { 7 } else { a * b };
        }
        let h = n / 2;
        let mask = (1u64 << h) - 1;
        let (al, ah) = (a & mask, a >> h);
        let (bl, bh) = (b & mask, b >> h);
        let ll = Self::mul_rec(al, bl, h);
        let lh = Self::mul_rec(al, bh, h);
        let hl = Self::mul_rec(ah, bl, h);
        let hh = Self::mul_rec(ah, bh, h);
        (hh << n) + ((lh + hl) << h) + ll
    }
}

impl Multiplier for Kulkarni2x2 {
    fn n(&self) -> u32 {
        self.n
    }

    fn mul(&self, a: u64, b: u64) -> u64 {
        assert!(self.n.is_power_of_two() && self.n >= 2);
        Self::mul_rec(a, b, self.n)
    }

    fn name(&self) -> String {
        format!("kulkarni(n={})", self.n)
    }
}

// The Fig. 2 baseline set itself is defined once, as specs, in
// `super::spec::DesignSet::Baselines` — the figure generator and the
// sweeps both enumerate it from there and evaluate through the batched
// kernels of `super::batch_baselines`.

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Cases;

    #[test]
    fn trunc_k0_exact() {
        Cases::new(10, 200).run(|rng, _| {
            let n = 2 + rng.next_below(31) as u32;
            let a = rng.next_bits(n);
            let b = rng.next_bits(n);
            assert_eq!(TruncatedMul { n, k: 0 }.mul(a, b), a * b);
        });
    }

    #[test]
    fn trunc_underestimates() {
        Cases::new(11, 200).run(|rng, _| {
            let n = 4 + rng.next_below(29) as u32;
            let k = rng.next_below(n as u64) as u32;
            let a = rng.next_bits(n);
            let b = rng.next_bits(n);
            let p = TruncatedMul { n, k }.mul(a, b);
            assert!(p <= a * b, "truncation must never overestimate");
            // dropped columns bound: sum of columns < k of full PP array
            let bound: u64 = (0..k).map(|c| (c.min(n - 1) as u64 + 1) << c).sum();
            assert!(a * b - p <= bound);
        });
    }

    #[test]
    fn bam_equals_trunc_when_hbl0() {
        Cases::new(12, 200).run(|rng, _| {
            let n = 4 + rng.next_below(13) as u32;
            let k = rng.next_below(n as u64) as u32;
            let a = rng.next_bits(n);
            let b = rng.next_bits(n);
            assert_eq!(
                BrokenArrayMul { n, hbl: 0, vbl: k }.mul(a, b),
                TruncatedMul { n, k }.mul(a, b)
            );
        });
    }

    #[test]
    fn mitchell_exact_on_powers_of_two() {
        for i in 0..8u32 {
            for j in 0..8u32 {
                let m = MitchellLog { n: 8 };
                assert_eq!(m.mul(1 << i, 1 << j), 1u64 << (i + j));
            }
        }
    }

    #[test]
    fn mitchell_known_error_bound() {
        // Mitchell's relative error is bounded by ~11.1% underestimation.
        let m = MitchellLog { n: 16 };
        Cases::new(13, 500).run(|rng, _| {
            let a = 1 + rng.next_below((1 << 16) - 1);
            let b = 1 + rng.next_below((1 << 16) - 1);
            let p = (a * b) as f64;
            let phat = m.mul(a, b) as f64;
            assert!(phat <= p + 1e-9, "Mitchell never overestimates");
            assert!((p - phat) / p <= 0.1140, "rel err {} too large", (p - phat) / p);
        });
    }

    #[test]
    fn kulkarni_base_case() {
        let m = Kulkarni2x2 { n: 2 };
        for a in 0..4u64 {
            for b in 0..4u64 {
                let expect = if a == 3 && b == 3 { 7 } else { a * b };
                assert_eq!(m.mul(a, b), expect);
            }
        }
    }

    #[test]
    fn kulkarni_exact_without_33_blocks() {
        // If every 2-bit digit pair avoids (3,3), the product is exact.
        let m = Kulkarni2x2 { n: 8 };
        assert_eq!(m.mul(0b10_01_10_01, 0b01_10_01_10), 0b10011001u64 * 0b01100110);
        // And the canonical error case: all digits 3.
        assert!(m.mul(0xFF, 0xFF) < 0xFFu64 * 0xFF);
    }

    #[test]
    fn baseline_design_set_nonempty_and_distinct_names() {
        let set = crate::multiplier::DesignSet::Baselines.specs(8);
        assert!(set.len() >= 4);
        let mut names: Vec<String> = set.iter().map(|s| s.name()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), set.len());
    }
}
