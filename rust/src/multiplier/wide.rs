//! U512 — fixed-width 512-bit unsigned integer.
//!
//! Supports the n ∈ {64, 128, 256} configurations of the paper's hardware
//! sweep (Fig. 3): operands up to 256 bits, products up to 512 bits. Only
//! the operations the multiplier models and evaluators need are implemented
//! (add/sub with wrap, shifts, bitwise ops, comparison, full multiply,
//! decimal/hex formatting).

use std::cmp::Ordering;
use std::fmt;

const LIMBS: usize = 8;

/// Little-endian 8×u64 fixed-width unsigned integer.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct U512 {
    limbs: [u64; LIMBS],
}

impl U512 {
    /// The zero value.
    pub const ZERO: U512 = U512 { limbs: [0; LIMBS] };
    /// The one value.
    pub const ONE: U512 = {
        let mut l = [0u64; LIMBS];
        l[0] = 1;
        U512 { limbs: l }
    };
    /// The all-ones value.
    pub const MAX: U512 = U512 { limbs: [u64::MAX; LIMBS] };

    #[inline]
    /// Widen a `u64`.
    pub fn from_u64(v: u64) -> Self {
        let mut l = [0u64; LIMBS];
        l[0] = v;
        Self { limbs: l }
    }

    #[inline]
    /// Widen a `u128`.
    pub fn from_u128(v: u128) -> Self {
        let mut l = [0u64; LIMBS];
        l[0] = v as u64;
        l[1] = (v >> 64) as u64;
        Self { limbs: l }
    }

    #[inline]
    /// Limb `i` (little-endian).
    pub fn limb(&self, i: usize) -> u64 {
        self.limbs[i]
    }

    #[inline]
    /// Whether every limb is zero.
    pub fn is_zero(&self) -> bool {
        self.limbs.iter().all(|&l| l == 0)
    }

    /// Bit `i` (0-based), false beyond 511.
    #[inline]
    pub fn bit(&self, i: u32) -> bool {
        if i >= 512 {
            return false;
        }
        (self.limbs[(i / 64) as usize] >> (i % 64)) & 1 == 1
    }

    /// Set bit `i` to 1.
    #[inline]
    pub fn set_bit(&mut self, i: u32) {
        assert!(i < 512);
        self.limbs[(i / 64) as usize] |= 1u64 << (i % 64);
    }

    /// Number of significant bits (position of highest set bit + 1).
    pub fn bits(&self) -> u32 {
        for i in (0..LIMBS).rev() {
            if self.limbs[i] != 0 {
                return (i as u32) * 64 + (64 - self.limbs[i].leading_zeros());
            }
        }
        0
    }

    /// All-ones mask of the low `nbits` bits (nbits ≤ 512).
    pub fn mask_lo(nbits: u32) -> Self {
        assert!(nbits <= 512);
        let mut l = [0u64; LIMBS];
        let full = (nbits / 64) as usize;
        for limb in l.iter_mut().take(full) {
            *limb = u64::MAX;
        }
        let rem = nbits % 64;
        if rem != 0 && full < LIMBS {
            l[full] = (1u64 << rem) - 1;
        }
        Self { limbs: l }
    }

    #[inline]
    /// Modular addition (wraps at 2^512).
    pub fn wrapping_add(&self, rhs: &Self) -> Self {
        let mut out = [0u64; LIMBS];
        let mut carry = 0u64;
        for i in 0..LIMBS {
            let (s1, c1) = self.limbs[i].overflowing_add(rhs.limbs[i]);
            let (s2, c2) = s1.overflowing_add(carry);
            out[i] = s2;
            carry = (c1 as u64) + (c2 as u64);
        }
        Self { limbs: out }
    }

    #[inline]
    /// Modular subtraction (wraps at 2^512).
    pub fn wrapping_sub(&self, rhs: &Self) -> Self {
        let mut out = [0u64; LIMBS];
        let mut borrow = 0u64;
        for i in 0..LIMBS {
            let (d1, b1) = self.limbs[i].overflowing_sub(rhs.limbs[i]);
            let (d2, b2) = d1.overflowing_sub(borrow);
            out[i] = d2;
            borrow = (b1 as u64) + (b2 as u64);
        }
        Self { limbs: out }
    }

    #[inline]
    /// Left shift by `sh` bits (zero-fill).
    pub fn shl(&self, sh: u32) -> Self {
        if sh >= 512 {
            return Self::ZERO;
        }
        let word = (sh / 64) as usize;
        let bit = sh % 64;
        let mut out = [0u64; LIMBS];
        for i in (0..LIMBS).rev() {
            if i < word {
                continue;
            }
            let mut v = self.limbs[i - word] << bit;
            if bit != 0 && i - word >= 1 {
                v |= self.limbs[i - word - 1] >> (64 - bit);
            }
            out[i] = v;
        }
        Self { limbs: out }
    }

    #[inline]
    /// Logical right shift by `sh` bits.
    pub fn shr(&self, sh: u32) -> Self {
        if sh >= 512 {
            return Self::ZERO;
        }
        let word = (sh / 64) as usize;
        let bit = sh % 64;
        let mut out = [0u64; LIMBS];
        for i in 0..LIMBS {
            if i + word >= LIMBS {
                break;
            }
            let mut v = self.limbs[i + word] >> bit;
            if bit != 0 && i + word + 1 < LIMBS {
                v |= self.limbs[i + word + 1] << (64 - bit);
            }
            out[i] = v;
        }
        Self { limbs: out }
    }

    /// Full 512-bit wrapping multiply (schoolbook over limbs).
    pub fn wrapping_mul(&self, rhs: &Self) -> Self {
        let mut out = [0u64; LIMBS];
        for i in 0..LIMBS {
            if self.limbs[i] == 0 {
                continue;
            }
            let mut carry = 0u128;
            for j in 0..(LIMBS - i) {
                let cur = out[i + j] as u128
                    + (self.limbs[i] as u128) * (rhs.limbs[j] as u128)
                    + carry;
                out[i + j] = cur as u64;
                carry = cur >> 64;
            }
        }
        Self { limbs: out }
    }

    /// Absolute difference and sign (`self - rhs`): (|diff|, diff >= 0).
    pub fn abs_diff(&self, rhs: &Self) -> (Self, bool) {
        if self >= rhs {
            (self.wrapping_sub(rhs), true)
        } else {
            (rhs.wrapping_sub(self), false)
        }
    }

    /// Approximate f64 value (for statistics; exact below 2^53).
    pub fn to_f64(&self) -> f64 {
        let mut acc = 0.0f64;
        for i in (0..LIMBS).rev() {
            acc = acc * 1.8446744073709552e19 + self.limbs[i] as f64;
        }
        acc
    }

    /// Hexadecimal rendering (debug/report use).
    pub fn to_hex(&self) -> String {
        let top = ((self.bits().max(1) + 63) / 64) as usize;
        let mut s = String::new();
        for i in (0..top).rev() {
            if i == top - 1 {
                s.push_str(&format!("{:x}", self.limbs[i]));
            } else {
                s.push_str(&format!("{:016x}", self.limbs[i]));
            }
        }
        s
    }
}

impl Ord for U512 {
    fn cmp(&self, other: &Self) -> Ordering {
        for i in (0..LIMBS).rev() {
            match self.limbs[i].cmp(&other.limbs[i]) {
                Ordering::Equal => continue,
                ord => return ord,
            }
        }
        Ordering::Equal
    }
}

impl PartialOrd for U512 {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl fmt::Debug for U512 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "U512(0x{})", self.to_hex())
    }
}

macro_rules! forward_bitop {
    ($trait_:ident, $fn_:ident, $op:tt) => {
        impl std::ops::$trait_ for U512 {
            type Output = U512;
            #[inline]
            fn $fn_(self, rhs: U512) -> U512 {
                let mut out = [0u64; LIMBS];
                for i in 0..LIMBS {
                    out[i] = self.limbs[i] $op rhs.limbs[i];
                }
                U512 { limbs: out }
            }
        }
    };
}

forward_bitop!(BitAnd, bitand, &);
forward_bitop!(BitOr, bitor, |);
forward_bitop!(BitXor, bitxor, ^);

impl std::ops::Add for U512 {
    type Output = U512;
    #[inline]
    fn add(self, rhs: U512) -> U512 {
        self.wrapping_add(&rhs)
    }
}

impl std::ops::Shl<u32> for U512 {
    type Output = U512;
    #[inline]
    fn shl(self, sh: u32) -> U512 {
        U512::shl(&self, sh)
    }
}

impl std::ops::Shr<u32> for U512 {
    type Output = U512;
    #[inline]
    fn shr(self, sh: u32) -> U512 {
        U512::shr(&self, sh)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Cases;

    #[test]
    fn add_sub_roundtrip() {
        Cases::new(1, 300).run(|rng, _| {
            let a = U512::from_u128(((rng.next_u64() as u128) << 64) | rng.next_u64() as u128);
            let b = U512::from_u128(((rng.next_u64() as u128) << 64) | rng.next_u64() as u128);
            assert_eq!(a.wrapping_add(&b).wrapping_sub(&b), a);
        });
    }

    #[test]
    fn mul_matches_u128() {
        Cases::new(2, 300).run(|rng, _| {
            let a = rng.next_u64();
            let b = rng.next_u64();
            let got = U512::from_u64(a).wrapping_mul(&U512::from_u64(b));
            assert_eq!(got, U512::from_u128(a as u128 * b as u128));
        });
    }

    #[test]
    fn shifts_match_u128() {
        Cases::new(3, 300).run(|rng, _| {
            let v = ((rng.next_u64() as u128) << 64) | rng.next_u64() as u128;
            let sh = rng.next_below(128) as u32;
            // U512 is wide enough that no bits fall off for sh < 128:
            assert_eq!(U512::from_u128(v).shl(sh).shr(sh), U512::from_u128(v));
            // truncated back to 128 bits it matches the u128 shift:
            let truncated = U512::from_u128(v).shl(sh) & U512::mask_lo(128);
            assert_eq!(truncated, U512::from_u128(v << sh));
            assert_eq!(U512::from_u128(v).shr(sh), U512::from_u128(v >> sh));
        });
    }

    #[test]
    fn shift_across_limbs() {
        let one = U512::ONE;
        let big = one.shl(200);
        assert!(big.bit(200));
        assert_eq!(big.bits(), 201);
        assert_eq!(big.shr(200), one);
        assert_eq!(one.shl(512), U512::ZERO);
    }

    #[test]
    fn mask_lo_correct() {
        assert_eq!(U512::mask_lo(0), U512::ZERO);
        assert_eq!(U512::mask_lo(1), U512::ONE);
        assert_eq!(U512::mask_lo(64), U512::from_u64(u64::MAX));
        assert_eq!(U512::mask_lo(65), U512::from_u128((1u128 << 65) - 1));
        assert_eq!(U512::mask_lo(512), U512::MAX);
        // (1 << t) - 1 identity used by the word-level multiplier
        for t in [0u32, 1, 63, 64, 100, 300] {
            let via_ops = (U512::ONE.shl(t)).wrapping_sub(&U512::ONE);
            assert_eq!(via_ops, U512::mask_lo(t), "t={t}");
        }
    }

    #[test]
    fn cmp_ordering() {
        let a = U512::from_u64(5);
        let b = U512::ONE.shl(300);
        assert!(a < b);
        assert!(b > a);
        assert_eq!(a.cmp(&a), Ordering::Equal);
    }

    #[test]
    fn abs_diff_signs() {
        let a = U512::from_u64(10);
        let b = U512::from_u64(4);
        assert_eq!(a.abs_diff(&b), (U512::from_u64(6), true));
        assert_eq!(b.abs_diff(&a), (U512::from_u64(6), false));
    }

    #[test]
    fn to_f64_exact_small() {
        assert_eq!(U512::from_u64(12345).to_f64(), 12345.0);
        let big = U512::ONE.shl(100);
        assert!((big.to_f64() - 2f64.powi(100)).abs() / 2f64.powi(100) < 1e-12);
    }

    #[test]
    fn wide_multiply_256bit_operands() {
        // (2^256 - 1)^2 = 2^512 - 2^257 + 1 (mod 2^512)
        let x = U512::mask_lo(256);
        let sq = x.wrapping_mul(&x);
        let expect = U512::ZERO
            .wrapping_sub(&U512::ONE.shl(257))
            .wrapping_add(&U512::ONE);
        assert_eq!(sq, expect);
    }

    #[test]
    fn hex_format() {
        assert_eq!(U512::from_u64(0xdeadbeef).to_hex(), "deadbeef");
        assert_eq!(U512::ONE.shl(64).to_hex(), "10000000000000000");
    }
}
