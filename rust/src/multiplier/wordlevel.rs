//! Word-level model of the segmented-carry sequential multiplier.
//!
//! This is the L3 hot path: exhaustive and Monte-Carlo error evaluation run
//! hundreds of millions of these per figure, so the inner loop is branch-free
//! (the partial product is selected with a mask, not an `if`) and fully
//! inlined. Bit-exactness to the paper's Boolean recurrences is enforced by
//! tests against [`super::bitlevel`].
//!
//! Per clock cycle `j = 1..n` (cycle 0 loads `a & -b_0`):
//! ```text
//! x    = s >> 1                       // previous sum, shifted right once
//! pp   = b_j ? a : 0                  // partial product
//! lsum = (x & M_t) + (pp & M_t)       // t-bit LSP adder, carry-in 0
//! msum = (x >> t) + (pp >> t) + cff   // MSP adder; carry-in = D-FF'd LSP
//!                                     //   carry-out of the PREVIOUS cycle
//! s'   = (msum << t) | (lsum & M_t)
//! cff' = (lsum >> t) & 1
//! ```
//! with the product bit `p_{j-1} = s & 1` shifted out each cycle; after the
//! last cycle `p̂[2n-1 .. n-1] = s`, and fix-to-1 forces the `n+t` LSBs to 1
//! when the final LSP carry-out is 1.

use super::wide::U512;

/// Minimal unsigned-word interface so one generic implementation serves
/// u64 (n ≤ 32), u128 (n ≤ 63), and U512 (n ≤ 255).
pub trait MulWord:
    Copy
    + PartialEq
    + std::ops::BitAnd<Output = Self>
    + std::ops::BitOr<Output = Self>
    + std::ops::Add<Output = Self>
    + std::ops::Shl<u32, Output = Self>
    + std::ops::Shr<u32, Output = Self>
{
    const ZERO: Self;
    const ONE: Self;
    /// Two's-complement negation (for mask selection: `0 - 1 = all-ones`).
    fn wrapping_neg_word(self) -> Self;
    /// All-ones mask of the low `bits` bits (bits < word width).
    fn mask_lo_word(bits: u32) -> Self;
    /// Lowest 64 bits (used for bit tests).
    fn low_u64(self) -> u64;
}

impl MulWord for u64 {
    const ZERO: Self = 0;
    const ONE: Self = 1;
    #[inline(always)]
    fn wrapping_neg_word(self) -> Self {
        self.wrapping_neg()
    }
    #[inline(always)]
    fn mask_lo_word(bits: u32) -> Self {
        debug_assert!(bits < 64);
        (1u64 << bits) - 1
    }
    #[inline(always)]
    fn low_u64(self) -> u64 {
        self
    }
}

impl MulWord for u128 {
    const ZERO: Self = 0;
    const ONE: Self = 1;
    #[inline(always)]
    fn wrapping_neg_word(self) -> Self {
        self.wrapping_neg()
    }
    #[inline(always)]
    fn mask_lo_word(bits: u32) -> Self {
        debug_assert!(bits < 128);
        (1u128 << bits) - 1
    }
    #[inline(always)]
    fn low_u64(self) -> u64 {
        self as u64
    }
}

impl MulWord for U512 {
    const ZERO: Self = U512::ZERO;
    const ONE: Self = U512::ONE;
    #[inline(always)]
    fn wrapping_neg_word(self) -> Self {
        U512::ZERO.wrapping_sub(&self)
    }
    #[inline(always)]
    fn mask_lo_word(bits: u32) -> Self {
        U512::mask_lo(bits)
    }
    #[inline(always)]
    fn low_u64(self) -> u64 {
        self.limb(0)
    }
}

/// Generic word-level segmented-carry sequential multiply.
///
/// Requirements: `n >= 1`, `0 <= t < n`, operands `< 2^n`, and the word type
/// must hold `2n` bits.
#[inline(always)]
pub fn approx_seq_mul_word<W: MulWord>(a: W, b: W, n: u32, t: u32, fix_to_1: bool) -> W {
    debug_assert!(t < n);
    let mt = W::mask_lo_word(t); // (1 << t) - 1
    // s = b_0 ? a : 0   — branch-free via mask = 0 - bit
    let bit0 = b & W::ONE;
    let mut s = a & bit0.wrapping_neg_word();
    let mut cff = W::ZERO;
    let mut low = W::ZERO;
    for j in 1..n {
        low = low | ((s & W::ONE) << (j - 1));
        let x = s >> 1;
        let bj = (b >> j) & W::ONE;
        let pp = a & bj.wrapping_neg_word();
        let lsum = (x & mt) + (pp & mt);
        let clsp = (lsum >> t) & W::ONE;
        let msum = (x >> t) + (pp >> t) + cff;
        s = (msum << t) | (lsum & mt);
        cff = clsp;
    }
    let mut phat = (s << (n - 1)) | low;
    if fix_to_1 && cff.low_u64() == 1 {
        phat = phat | W::mask_lo_word(n + t);
    }
    phat
}

/// u64 fast path with an exhausted-multiplier early exit: once every
/// remaining multiplicand bit is 0 AND the deferred carry has been
/// consumed, the remaining cycles are pure right-shifts whose effect has
/// the closed form `p̂ = (s << (j-1)) | low` — so the loop runs only
/// `highest_set_bit(b) + 2` iterations instead of n. (Bit-exactness vs.
/// the generic loop is property-tested below.)
#[inline(always)]
fn approx_seq_mul_u64_fast(a: u64, b: u64, n: u32, t: u32, fix_to_1: bool) -> u64 {
    let mt = (1u64 << t) - 1;
    let mut s = a & (b & 1).wrapping_neg();
    let mut cff = 0u64;
    let mut low = 0u64;
    let mut j = 1u32;
    while j < n {
        let pp_possible = (b >> j) != 0;
        if !pp_possible && cff == 0 {
            // remaining cycles only shift: p̂ = (s << (j-1)) | low.
            // The final LSP carry-out is 0 here, so fix-to-1 never fires.
            return (s << (j - 1)) | low;
        }
        low |= (s & 1) << (j - 1);
        let x = s >> 1;
        let pp = a & ((b >> j) & 1).wrapping_neg();
        let lsum = (x & mt) + (pp & mt);
        let clsp = (lsum >> t) & 1;
        let msum = (x >> t) + (pp >> t) + cff;
        s = (msum << t) | (lsum & mt);
        cff = clsp;
        j += 1;
    }
    let mut phat = (s << (n - 1)) | low;
    if fix_to_1 && cff == 1 {
        phat |= (1u64 << (n + t)) - 1;
    }
    phat
}

/// Approximate product for n ≤ 32 (product fits in u64). Hot path.
#[inline(always)]
pub fn approx_seq_mul(a: u64, b: u64, n: u32, t: u32, fix_to_1: bool) -> u64 {
    debug_assert!(n >= 1 && n <= 32);
    debug_assert!(a < (1u64 << n) && b < (1u64 << n));
    approx_seq_mul_u64_fast(a, b, n, t, fix_to_1)
}

/// Generic-loop variant kept for differential testing of the fast path.
#[inline(always)]
pub fn approx_seq_mul_generic(a: u64, b: u64, n: u32, t: u32, fix_to_1: bool) -> u64 {
    approx_seq_mul_word(a, b, n, t, fix_to_1)
}

/// Approximate product for n ≤ 63.
#[inline]
pub fn approx_seq_mul_u128(a: u128, b: u128, n: u32, t: u32, fix_to_1: bool) -> u128 {
    debug_assert!(n >= 1 && n <= 63);
    approx_seq_mul_word(a, b, n, t, fix_to_1)
}

/// Approximate product for n ≤ 255 (hardware sweeps up to n = 256 use the
/// netlist simulator directly; this covers the software cross-check).
#[inline]
pub fn approx_seq_mul_wide(a: &U512, b: &U512, n: u32, t: u32, fix_to_1: bool) -> U512 {
    debug_assert!(n >= 1 && n <= 255);
    approx_seq_mul_word(*a, *b, n, t, fix_to_1)
}

/// Exact 2n-bit product for n ≤ 32.
#[inline(always)]
pub fn exact_mul(a: u64, b: u64, n: u32) -> u64 {
    debug_assert!(n <= 32 && a < (1u64 << n) && b < (1u64 << n));
    a * b
}

/// Signed error distance `ED = dec(p) - dec(p̂)` (Eq. 4), exact for n ≤ 32.
#[inline(always)]
pub fn error_distance(p: u64, phat: u64) -> i64 {
    p.wrapping_sub(phat) as i64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multiplier::bitlevel::approx_seq_mul_bitlevel;
    use crate::util::prop::Cases;

    #[test]
    fn golden_paper_table2b() {
        // Table IIb: a=1011₂, b=0110₂, n=4, t=2; exact = 66. The delayed
        // LSP carry from cycle 2 lands one position high in cycle 3:
        // p̂ = 82, ED = -16 (overshoot 2^{t+j} with j = 2).
        assert_eq!(exact_mul(0b1011, 0b0110, 4), 66);
        assert_eq!(approx_seq_mul(0b1011, 0b0110, 4, 2, false), 82);
        assert_eq!(approx_seq_mul(0b1011, 0b0110, 4, 2, true), 82);
    }

    #[test]
    fn golden_paper_table1_accurate() {
        // Table Ib: accurate sequential multiplication (t = 0 degenerate).
        assert_eq!(approx_seq_mul(0b1011, 0b0110, 4, 0, false), 66);
    }

    #[test]
    fn exhaustive_equals_bitlevel_n_le_6() {
        for n in 1..=6u32 {
            for t in 0..n {
                for fix in [false, true] {
                    for a in 0..(1u64 << n) {
                        for b in 0..(1u64 << n) {
                            let w = approx_seq_mul(a, b, n, t, fix);
                            let bl = approx_seq_mul_bitlevel(a, b, n, t, fix);
                            assert_eq!(w, bl, "n={n} t={t} fix={fix} a={a} b={b}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn prop_equals_bitlevel_random_n_le_32() {
        Cases::new(0xBEEF, 400).run(|rng, _| {
            let n = 2 + (rng.next_below(31)) as u32; // 2..=32
            let t = rng.next_below(n as u64) as u32;
            let fix = rng.next_bits(1) == 1;
            let a = rng.next_bits(n);
            let b = rng.next_bits(n);
            assert_eq!(
                approx_seq_mul(a, b, n, t, fix),
                approx_seq_mul_bitlevel(a, b, n, t, fix),
                "n={n} t={t} fix={fix} a={a} b={b}"
            );
        });
    }

    #[test]
    fn prop_t_zero_is_accurate() {
        Cases::new(0xACC, 300).run(|rng, _| {
            let n = 1 + rng.next_below(32) as u32;
            let a = rng.next_bits(n);
            let b = rng.next_bits(n);
            assert_eq!(approx_seq_mul(a, b, n, 0, false), a * b);
            assert_eq!(approx_seq_mul(a, b, n, 0, true), a * b);
        });
    }

    #[test]
    fn prop_u128_matches_u64_on_overlap() {
        Cases::new(0x128, 300).run(|rng, _| {
            let n = 2 + rng.next_below(31) as u32;
            let t = rng.next_below(n as u64) as u32;
            let fix = rng.next_bits(1) == 1;
            let a = rng.next_bits(n);
            let b = rng.next_bits(n);
            assert_eq!(
                approx_seq_mul_u128(a as u128, b as u128, n, t, fix) as u64,
                approx_seq_mul(a, b, n, t, fix)
            );
        });
    }

    #[test]
    fn prop_wide_matches_u128() {
        Cases::new(0x512, 200).run(|rng, _| {
            let n = 2 + rng.next_below(62) as u32; // 2..=63
            let t = rng.next_below(n as u64) as u32;
            let fix = rng.next_bits(1) == 1;
            let a = rng.next_bits(n.min(63)) as u128;
            let b = rng.next_bits(n.min(63)) as u128;
            let w = approx_seq_mul_wide(&U512::from_u128(a), &U512::from_u128(b), n, t, fix);
            let r = approx_seq_mul_u128(a, b, n, t, fix);
            assert_eq!(w, U512::from_u128(r), "n={n} t={t}");
        });
    }

    #[test]
    fn u128_t_zero_accurate_large_n() {
        let a = (1u128 << 60) - 3;
        let b = (1u128 << 60) - 7;
        assert_eq!(approx_seq_mul_u128(a, b, 61, 0, false), a * b);
    }

    #[test]
    fn prop_fast_path_equals_generic() {
        Cases::new(0xFA57, 600).run(|rng, _| {
            let n = 1 + rng.next_below(32) as u32;
            let t = rng.next_below(n as u64) as u32;
            let fix = rng.next_bits(1) == 1;
            // bias towards small b so the early exit actually fires
            let bbits = 1 + rng.next_below(n as u64) as u32;
            let a = rng.next_bits(n);
            let b = rng.next_bits(bbits);
            assert_eq!(
                approx_seq_mul(a, b, n, t, fix),
                approx_seq_mul_generic(a, b, n, t, fix),
                "n={n} t={t} fix={fix} a={a} b={b}"
            );
        });
    }

    #[test]
    fn error_distance_sign() {
        // Dropped final carry => p̂ < p => ED > 0; overshoot => ED < 0.
        assert_eq!(error_distance(66, 82), -16);
        assert_eq!(error_distance(82, 66), 16);
    }

    #[test]
    fn fix_to_1_sets_low_bits() {
        // Find a case where the final LSP carry-out is 1 and check the
        // n+t LSBs are forced to 1.
        let (n, t) = (8u32, 4u32);
        let mut found = false;
        for a in 0..256u64 {
            for b in 0..256u64 {
                let nofix = approx_seq_mul(a, b, n, t, false);
                let fix = approx_seq_mul(a, b, n, t, true);
                if nofix != fix {
                    let mask = (1u64 << (n + t)) - 1;
                    assert_eq!(fix & mask, mask);
                    assert_eq!(fix >> (n + t), nofix >> (n + t));
                    found = true;
                }
            }
        }
        assert!(found, "no fix-to-1 trigger found at n=8,t=4");
    }
}
