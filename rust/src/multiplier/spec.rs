//! Design-agnostic multiplier specification: the registry the public API
//! sweeps, caches, and shards over.
//!
//! [`MultiplierSpec`] is a plain-data description of every multiplier the
//! crate implements — the paper's segmented sequential design, the
//! accurate reference, each related-work baseline ([`super::baselines`]),
//! the bit-level `Ŝ/Ĉ` oracle ([`super::bitlevel`]), and the gate-level
//! netlist simulator ([`crate::netlist::generators::seq_mult`]). It is
//! `Copy + Eq + Hash`, so any design is a cache / dedup key; evaluation
//! machinery turns it into a concrete [`BatchMultiplier`] with
//! [`MultiplierSpec::build_batch`].
//!
//! [`MultiplierSpec::canonical`] generalizes the coordinator's old
//! `t = 0` fix-mode dedup: configurations that provably compute the same
//! product function for every operand pair map to one representative, so
//! the sweep cache collapses them (`t = 0` segmented ≡ accurate, `k = 0`
//! truncation ≡ accurate, `hbl = 0` broken-array ≡ truncation, ...).
//!
//! [`DesignSet`] names the sweep families the CLI exposes
//! (`segmul sweep --designs all`): the paper grid, the accurate
//! reference, the Fig. 2 baselines, and bit-level / netlist spot checks.

use crate::error::SegmulError;
use crate::netlist::generators::seq_mult::{run_batch, seq_mult, SeqMultCircuit};
use crate::netlist::sim::SeqSim;
use crate::util::json::{obj, Json};

use super::baselines::{BrokenArrayMul, Kulkarni2x2, MitchellLog, TruncatedMul};
use super::batch::{BatchMultiplier, DispatchClass};
use super::batch_baselines::BitSlicedBitLevel;
use super::bitlevel::approx_seq_mul_bitlevel;
use super::wide::U512;
use super::{AccurateMul, Multiplier, SegmentedSeqMul};

/// Every implemented multiplier design, as plain hashable data.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MultiplierSpec {
    /// The paper's accuracy-configurable segmented-carry sequential
    /// multiplier (word-level fast path; PJRT-lowerable).
    Segmented { n: u32, t: u32, fix: bool },
    /// The exact reference multiplier.
    Accurate { n: u32 },
    /// Vertical partial-product truncation (columns `< k` dropped).
    Truncated { n: u32, k: u32 },
    /// Broken-array multiplier (rows `< hbl`, columns `< vbl` dropped).
    BrokenArray { n: u32, hbl: u32, vbl: u32 },
    /// Mitchell's logarithmic multiplier.
    Mitchell { n: u32 },
    /// Kulkarni's underdesigned 2×2-block multiplier (`n` a power of two).
    Kulkarni { n: u32 },
    /// The paper's Boolean `Ŝ/Ĉ` recurrences — the bit-level oracle.
    BitLevel { n: u32, t: u32, fix: bool },
    /// The generated gate-level netlist, simulated cycle-accurately
    /// (64 operand pairs per bit-parallel pass).
    Netlist { n: u32, t: u32, fix: bool },
}

impl MultiplierSpec {
    /// Operand bit-width.
    pub fn n(&self) -> u32 {
        match *self {
            MultiplierSpec::Segmented { n, .. }
            | MultiplierSpec::Accurate { n }
            | MultiplierSpec::Truncated { n, .. }
            | MultiplierSpec::BrokenArray { n, .. }
            | MultiplierSpec::Mitchell { n }
            | MultiplierSpec::Kulkarni { n }
            | MultiplierSpec::BitLevel { n, .. }
            | MultiplierSpec::Netlist { n, .. } => n,
        }
    }

    /// Carry-chain split point, for the designs that have one.
    pub fn split_point(&self) -> Option<u32> {
        match *self {
            MultiplierSpec::Segmented { t, .. }
            | MultiplierSpec::BitLevel { t, .. }
            | MultiplierSpec::Netlist { t, .. } => Some(t),
            _ => None,
        }
    }

    /// Fix-to-1 compensation mode, for the designs that have one.
    pub fn fix_mode(&self) -> Option<bool> {
        match *self {
            MultiplierSpec::Segmented { fix, .. }
            | MultiplierSpec::BitLevel { fix, .. }
            | MultiplierSpec::Netlist { fix, .. } => Some(fix),
            _ => None,
        }
    }

    /// Display name (matches the underlying model's `Multiplier::name`).
    pub fn name(&self) -> String {
        fn fx(fix: bool) -> &'static str {
            if fix {
                ",fix"
            } else {
                ""
            }
        }
        match *self {
            MultiplierSpec::Segmented { n, t, fix } => format!("segmul(n={n},t={t}{})", fx(fix)),
            MultiplierSpec::Accurate { n } => format!("accurate(n={n})"),
            MultiplierSpec::Truncated { n, k } => format!("trunc(n={n},k={k})"),
            MultiplierSpec::BrokenArray { n, hbl, vbl } => {
                format!("bam(n={n},hbl={hbl},vbl={vbl})")
            }
            MultiplierSpec::Mitchell { n } => format!("mitchell(n={n})"),
            MultiplierSpec::Kulkarni { n } => format!("kulkarni(n={n})"),
            MultiplierSpec::BitLevel { n, t, fix } => format!("bitlevel(n={n},t={t}{})", fx(fix)),
            MultiplierSpec::Netlist { n, t, fix } => format!("netlist(n={n},t={t}{})", fx(fix)),
        }
    }

    /// Validate the design parameters.
    pub fn validate(&self) -> Result<(), SegmulError> {
        let n = self.n();
        if !(1..=32).contains(&n) {
            return Err(SegmulError::spec(self.name(), format!("n={n} out of range 1..=32")));
        }
        match *self {
            MultiplierSpec::Segmented { t, .. }
            | MultiplierSpec::BitLevel { t, .. }
            | MultiplierSpec::Netlist { t, .. } => {
                if t >= n {
                    return Err(SegmulError::spec(
                        self.name(),
                        format!("split point t={t} must satisfy 0 <= t < n={n}"),
                    ));
                }
            }
            MultiplierSpec::Truncated { k, .. } => {
                if k > n {
                    return Err(SegmulError::spec(self.name(), format!("k={k} exceeds n={n}")));
                }
            }
            MultiplierSpec::BrokenArray { hbl, vbl, .. } => {
                if hbl > n || vbl > n {
                    return Err(SegmulError::spec(
                        self.name(),
                        format!("break lines (hbl={hbl}, vbl={vbl}) exceed n={n}"),
                    ));
                }
            }
            MultiplierSpec::Kulkarni { .. } => {
                if !n.is_power_of_two() || n < 2 {
                    return Err(SegmulError::spec(
                        self.name(),
                        format!("n={n} must be a power of two >= 2"),
                    ));
                }
            }
            MultiplierSpec::Accurate { .. } | MultiplierSpec::Mitchell { .. } => {}
        }
        Ok(())
    }

    /// The canonical cache representative of this design: specs whose
    /// product function is provably identical for **every** operand pair
    /// map to one value, so [`crate::coordinator::JobKey`]s collapse and
    /// the sweep cache serves them from one entry.
    ///
    /// * `Segmented { t: 0 }` (either fix mode — the zero-bit LSP adder
    ///   can never raise the compensated carry) is the accurate design.
    /// * `Truncated { k: 0 }` drops nothing: accurate.
    /// * `BrokenArray { hbl: 0 }` is exactly `Truncated { k: vbl }`.
    /// * `BitLevel` / `Netlist` at `t = 0` canonicalize only their dead
    ///   `fix` flag: they stay distinct families on purpose, because
    ///   evaluating the oracle / the gate-level netlist *is* the point of
    ///   requesting them.
    pub fn canonical(&self) -> MultiplierSpec {
        match *self {
            MultiplierSpec::Segmented { n, t: 0, .. } => MultiplierSpec::Accurate { n },
            MultiplierSpec::Truncated { n, k: 0 } => MultiplierSpec::Accurate { n },
            MultiplierSpec::BrokenArray { n, hbl: 0, vbl } => {
                MultiplierSpec::Truncated { n, k: vbl }.canonical()
            }
            MultiplierSpec::BitLevel { n, t: 0, .. } => {
                MultiplierSpec::BitLevel { n, t: 0, fix: false }
            }
            MultiplierSpec::Netlist { n, t: 0, .. } => {
                MultiplierSpec::Netlist { n, t: 0, fix: false }
            }
            other => other,
        }
    }

    /// The design-tag family name used by the artifact manifest
    /// ([`Self::to_json`] / [`Self::from_json`]) and the per-design bench
    /// metrics (`pjrt_<family>_pairs_per_s`).
    pub fn family(&self) -> &'static str {
        match self {
            MultiplierSpec::Segmented { .. } => "segmented",
            MultiplierSpec::Accurate { .. } => "accurate",
            MultiplierSpec::Truncated { .. } => "truncated",
            MultiplierSpec::BrokenArray { .. } => "broken_array",
            MultiplierSpec::Mitchell { .. } => "mitchell",
            MultiplierSpec::Kulkarni { .. } => "kulkarni",
            MultiplierSpec::BitLevel { .. } => "bitlevel",
            MultiplierSpec::Netlist { .. } => "netlist",
        }
    }

    /// Serialize as the manifest's design tag: a JSON object carrying the
    /// family name plus every configuration axis. Round-trips exactly
    /// through [`Self::from_json`] for every registry design.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("family", Json::from(self.family())),
            ("n", Json::from(self.n() as u64)),
        ];
        match *self {
            MultiplierSpec::Segmented { t, fix, .. }
            | MultiplierSpec::BitLevel { t, fix, .. }
            | MultiplierSpec::Netlist { t, fix, .. } => {
                fields.push(("t", Json::from(t as u64)));
                fields.push(("fix", Json::from(fix)));
            }
            MultiplierSpec::Truncated { k, .. } => fields.push(("k", Json::from(k as u64))),
            MultiplierSpec::BrokenArray { hbl, vbl, .. } => {
                fields.push(("hbl", Json::from(hbl as u64)));
                fields.push(("vbl", Json::from(vbl as u64)));
            }
            MultiplierSpec::Accurate { .. }
            | MultiplierSpec::Mitchell { .. }
            | MultiplierSpec::Kulkarni { .. } => {}
        }
        obj(fields)
    }

    /// Parse a manifest design tag. The error is a plain reason string;
    /// the artifact loader wraps it into [`SegmulError::Artifact`] with
    /// the offending path.
    pub fn from_json(j: &Json) -> Result<MultiplierSpec, String> {
        let family = j
            .get("family")
            .and_then(Json::as_str)
            .ok_or_else(|| "design tag missing string 'family'".to_string())?;
        let num = |key: &str| -> Result<u32, String> {
            j.get(key)
                .and_then(Json::as_u64)
                .and_then(|v| u32::try_from(v).ok())
                .ok_or_else(|| format!("design tag ({family}) missing numeric '{key}'"))
        };
        let flag = |key: &str| -> Result<bool, String> {
            j.get(key)
                .and_then(Json::as_bool)
                .ok_or_else(|| format!("design tag ({family}) missing boolean '{key}'"))
        };
        let n = num("n")?;
        Ok(match family {
            "segmented" => MultiplierSpec::Segmented { n, t: num("t")?, fix: flag("fix")? },
            "accurate" => MultiplierSpec::Accurate { n },
            "truncated" => MultiplierSpec::Truncated { n, k: num("k")? },
            "broken_array" => MultiplierSpec::BrokenArray { n, hbl: num("hbl")?, vbl: num("vbl")? },
            "mitchell" => MultiplierSpec::Mitchell { n },
            "kulkarni" => MultiplierSpec::Kulkarni { n },
            "bitlevel" => MultiplierSpec::BitLevel { n, t: num("t")?, fix: flag("fix")? },
            "netlist" => MultiplierSpec::Netlist { n, t: num("t")?, fix: flag("fix")? },
            other => return Err(format!("unknown design family {other:?}")),
        })
    }

    /// Filesystem-safe stem for this design's lowered-module artifact,
    /// unique per spec (`segmented_n8_t3_fix`, `truncated_n8_k2`, ...).
    pub fn artifact_stem(&self) -> String {
        fn fx(fix: bool) -> &'static str {
            if fix {
                "_fix"
            } else {
                ""
            }
        }
        match *self {
            MultiplierSpec::Segmented { n, t, fix } => format!("segmented_n{n}_t{t}{}", fx(fix)),
            MultiplierSpec::Accurate { n } => format!("accurate_n{n}"),
            MultiplierSpec::Truncated { n, k } => format!("truncated_n{n}_k{k}"),
            MultiplierSpec::BrokenArray { n, hbl, vbl } => format!("broken_array_n{n}_h{hbl}_v{vbl}"),
            MultiplierSpec::Mitchell { n } => format!("mitchell_n{n}"),
            MultiplierSpec::Kulkarni { n } => format!("kulkarni_n{n}"),
            MultiplierSpec::BitLevel { n, t, fix } => format!("bitlevel_n{n}_t{t}{}", fx(fix)),
            MultiplierSpec::Netlist { n, t, fix } => format!("netlist_n{n}_t{t}{}", fx(fix)),
        }
    }

    /// Whether the paper's segmented fast path evaluates this design
    /// (everything else goes through the generic batched adapter).
    pub fn is_segmented(&self) -> bool {
        matches!(self, MultiplierSpec::Segmented { .. })
    }

    /// Whether this design is covered by the segmented kernel family that
    /// the **legacy** AOT stats modules lower (`Segmented`, plus
    /// `Accurate` — its `t = 0` point). Everything else needs either the
    /// CPU backend's generic design support or a design-lowered module
    /// from `segmul lower` (`crate::runtime::lower`).
    pub fn has_segmented_lowering(&self) -> bool {
        matches!(
            self,
            MultiplierSpec::Segmented { .. } | MultiplierSpec::Accurate { .. }
        )
    }

    /// Construct the batched evaluator for this design. The spec is
    /// validated first, so the error surface is typed; construction cost
    /// ranges from trivial (word-level models) to a full netlist build —
    /// backends cache the result per spec (see
    /// [`crate::coordinator::CpuBackend`]).
    ///
    /// Every design family resolves to a true batch kernel
    /// ([`DispatchClass::Batched`]): the segmented/accurate fast paths and
    /// the branch-free baseline kernels of
    /// [`super::batch_baselines`], the bit-sliced 64-lane oracle, and the
    /// bit-parallel netlist simulator. The per-pair scalar adapters exist
    /// only behind [`MultiplierSpec::build_scalar_reference`].
    pub fn build_batch(&self) -> Result<Box<dyn BatchMultiplier>, SegmulError> {
        self.validate()?;
        Ok(match *self {
            MultiplierSpec::Segmented { n, t, fix } => Box::new(SegmentedSeqMul::new(n, t, fix)),
            MultiplierSpec::Accurate { n } => Box::new(AccurateMul { n }),
            MultiplierSpec::Truncated { n, k } => Box::new(TruncatedMul { n, k }),
            MultiplierSpec::BrokenArray { n, hbl, vbl } => {
                Box::new(BrokenArrayMul { n, hbl, vbl })
            }
            MultiplierSpec::Mitchell { n } => Box::new(MitchellLog { n }),
            MultiplierSpec::Kulkarni { n } => Box::new(Kulkarni2x2 { n }),
            MultiplierSpec::BitLevel { n, t, fix } => Box::new(BitSlicedBitLevel::new(n, t, fix)),
            MultiplierSpec::Netlist { n, t, fix } => Box::new(NetlistMul::new(n, t, fix)),
        })
    }

    /// Construct the **per-pair scalar reference** for this design: the
    /// scalar model wrapped in [`OwnedScalarBatch`], one virtual call per
    /// operand pair. This is the differential-test baseline the batch
    /// kernels of [`Self::build_batch`] are checked bit-exact against
    /// (`tests/kernel_differential.rs`), and the slow side of the
    /// scalar-vs-batched comparison in `benches/batch_kernel.rs` — it is
    /// never dispatched on a production sweep path.
    ///
    /// The netlist design has no scalar software model; its reference is
    /// the scalar word-level fast path (`approx_seq_mul`), which computes
    /// the same product function (so the returned evaluator's *name*
    /// reports the word-level model, not the netlist).
    pub fn build_scalar_reference(&self) -> Result<Box<dyn BatchMultiplier>, SegmulError> {
        self.validate()?;
        Ok(match *self {
            MultiplierSpec::Segmented { n, t, fix } => {
                Box::new(OwnedScalarBatch(SegmentedSeqMul::new(n, t, fix)))
            }
            MultiplierSpec::Accurate { n } => Box::new(OwnedScalarBatch(AccurateMul { n })),
            MultiplierSpec::Truncated { n, k } => {
                Box::new(OwnedScalarBatch(TruncatedMul { n, k }))
            }
            MultiplierSpec::BrokenArray { n, hbl, vbl } => {
                Box::new(OwnedScalarBatch(BrokenArrayMul { n, hbl, vbl }))
            }
            MultiplierSpec::Mitchell { n } => Box::new(OwnedScalarBatch(MitchellLog { n })),
            MultiplierSpec::Kulkarni { n } => Box::new(OwnedScalarBatch(Kulkarni2x2 { n })),
            MultiplierSpec::BitLevel { n, t, fix } => {
                Box::new(OwnedScalarBatch(BitLevelMul { n, t, fix }))
            }
            MultiplierSpec::Netlist { n, t, fix } => {
                Box::new(OwnedScalarBatch(SegmentedSeqMul::new(n, t, fix)))
            }
        })
    }

    /// One spec of every design family (used by registry round-trip
    /// tests and documentation).
    pub fn registry_examples(n: u32) -> Vec<MultiplierSpec> {
        vec![
            MultiplierSpec::Segmented { n, t: n / 2, fix: true },
            MultiplierSpec::Accurate { n },
            MultiplierSpec::Truncated { n, k: n / 4 },
            MultiplierSpec::BrokenArray { n, hbl: n / 4, vbl: n / 2 },
            MultiplierSpec::Mitchell { n },
            MultiplierSpec::Kulkarni { n },
            MultiplierSpec::BitLevel { n, t: n / 2, fix: true },
            MultiplierSpec::Netlist { n, t: n / 2, fix: true },
        ]
    }
}

/// Scalar model of the paper's Boolean recurrences, adapted to the
/// [`Multiplier`] trait so the oracle can be swept like any design.
#[derive(Clone, Copy, Debug)]
struct BitLevelMul {
    n: u32,
    t: u32,
    fix: bool,
}

impl Multiplier for BitLevelMul {
    fn n(&self) -> u32 {
        self.n
    }

    fn mul(&self, a: u64, b: u64) -> u64 {
        approx_seq_mul_bitlevel(a, b, self.n, self.t, self.fix)
    }

    fn name(&self) -> String {
        format!("bitlevel(n={},t={}{})", self.n, self.t, if self.fix { ",fix" } else { "" })
    }
}

/// Owning counterpart of [`super::batch::ScalarBatch`]: runs a scalar
/// [`Multiplier`] under the batched interface (one call per pair).
///
/// Survives only as the differential-test reference
/// ([`MultiplierSpec::build_scalar_reference`]) — every registry design's
/// production evaluator is a true batch kernel, and `kernel_differential`
/// checks the two bit-exact against each other.
pub struct OwnedScalarBatch<M: Multiplier>(pub M);

impl<M: Multiplier> BatchMultiplier for OwnedScalarBatch<M> {
    fn n(&self) -> u32 {
        self.0.n()
    }

    fn name(&self) -> String {
        self.0.name()
    }

    fn dispatch_class(&self) -> DispatchClass {
        DispatchClass::Scalar
    }

    fn mul_batch(&self, a: &[u64], b: &[u64], out: &mut [u64]) {
        assert_eq!(a.len(), b.len(), "operand slices must have equal length");
        assert_eq!(a.len(), out.len(), "output slice must match operand length");
        for ((&x, &y), o) in a.iter().zip(b).zip(out.iter_mut()) {
            *o = self.0.mul(x, y);
        }
    }
}

/// Gate-level netlist-backed batch multiplier: simulates the generated
/// sequential circuit cycle-accurately, 64 operand pairs per bit-parallel
/// pass. The circuit is built once (in [`MultiplierSpec::build_batch`] —
/// backends cache it per spec); the simulator is re-created per call and
/// reset per 64-lane group, so products are state-independent.
pub struct NetlistMul {
    c: SeqMultCircuit,
    fix: bool,
}

impl NetlistMul {
    /// A cycle-accurate netlist evaluator for `(n, t, fix)`.
    pub fn new(n: u32, t: u32, fix: bool) -> Self {
        NetlistMul { c: seq_mult(n, t, fix && t >= 1), fix }
    }
}

impl BatchMultiplier for NetlistMul {
    fn n(&self) -> u32 {
        self.c.n
    }

    fn name(&self) -> String {
        MultiplierSpec::Netlist { n: self.c.n, t: self.c.t, fix: self.fix }.name()
    }

    fn mul_batch(&self, a: &[u64], b: &[u64], out: &mut [u64]) {
        assert_eq!(a.len(), b.len(), "operand slices must have equal length");
        assert_eq!(a.len(), out.len(), "output slice must match operand length");
        let mut sim = SeqSim::new(&self.c.nl);
        for ((ca, cb), co) in a.chunks(64).zip(b.chunks(64)).zip(out.chunks_mut(64)) {
            sim.reset();
            let aw: Vec<U512> = ca.iter().map(|&x| U512::from_u64(x)).collect();
            let bw: Vec<U512> = cb.iter().map(|&x| U512::from_u64(x)).collect();
            let prods = run_batch(&self.c, &mut sim, &aw, &bw, self.fix);
            for (o, p) in co.iter_mut().zip(&prods) {
                // n <= 32: the 2n-bit product fits the low limb.
                *o = p.limb(0);
            }
        }
    }
}

/// A named family of design points, swept per bit-width.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DesignSet {
    /// The paper grid: every split point `t ∈ 0..n`, both fix modes.
    Paper,
    /// The accurate reference only.
    Accurate,
    /// The Fig. 2 related-work baselines (truncation, broken-array,
    /// Mitchell, Kulkarni where `n` is a power of two).
    Baselines,
    /// Bit-level oracle spot check at `t = n/2` (n ≤ 16 — the per-pair
    /// transcription is orders of magnitude slower than the word model).
    Oracle,
    /// Gate-level netlist spot check at `t = n/2` (n ≤ 8 — cycle-accurate
    /// simulation; costs grow with gates × cycles).
    Netlist,
    /// The cross-design comparative sweep: paper grid ∪ accurate ∪
    /// baselines ∪ oracle ∪ netlist spots.
    All,
}

impl DesignSet {
    /// The CLI name (`--designs ...`).
    pub fn name(&self) -> &'static str {
        match self {
            DesignSet::Paper => "paper",
            DesignSet::Accurate => "accurate",
            DesignSet::Baselines => "baselines",
            DesignSet::Oracle => "oracle",
            DesignSet::Netlist => "netlist",
            DesignSet::All => "all",
        }
    }

    /// Parse a CLI / config name.
    pub fn parse(s: &str) -> Result<DesignSet, SegmulError> {
        match s.trim() {
            "paper" => Ok(DesignSet::Paper),
            "accurate" => Ok(DesignSet::Accurate),
            "baselines" => Ok(DesignSet::Baselines),
            "oracle" => Ok(DesignSet::Oracle),
            "netlist" => Ok(DesignSet::Netlist),
            "all" => Ok(DesignSet::All),
            other => Err(SegmulError::config(format!(
                "unknown design set {other:?} (paper|accurate|baselines|oracle|netlist|all)"
            ))),
        }
    }

    /// The design points of this family at bit-width `n`, in
    /// deterministic sweep order.
    pub fn specs(&self, n: u32) -> Vec<MultiplierSpec> {
        match self {
            DesignSet::Paper => {
                let mut out = Vec::new();
                for t in 0..n {
                    for fix in [false, true] {
                        out.push(MultiplierSpec::Segmented { n, t, fix });
                    }
                }
                out
            }
            DesignSet::Accurate => vec![MultiplierSpec::Accurate { n }],
            DesignSet::Baselines => {
                let mut out = vec![
                    MultiplierSpec::Truncated { n, k: n / 4 },
                    MultiplierSpec::Truncated { n, k: n / 2 },
                    MultiplierSpec::BrokenArray { n, hbl: n / 4, vbl: n / 2 },
                    MultiplierSpec::Mitchell { n },
                ];
                if n.is_power_of_two() && n >= 2 {
                    out.push(MultiplierSpec::Kulkarni { n });
                }
                out
            }
            DesignSet::Oracle => {
                if n <= 16 {
                    vec![MultiplierSpec::BitLevel { n, t: n / 2, fix: true }]
                } else {
                    Vec::new()
                }
            }
            DesignSet::Netlist => {
                if n <= 8 {
                    vec![MultiplierSpec::Netlist { n, t: n / 2, fix: true }]
                } else {
                    Vec::new()
                }
            }
            DesignSet::All => {
                let mut out = DesignSet::Paper.specs(n);
                out.extend(DesignSet::Accurate.specs(n));
                out.extend(DesignSet::Baselines.specs(n));
                out.extend(DesignSet::Oracle.specs(n));
                out.extend(DesignSet::Netlist.specs(n));
                out
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multiplier::wordlevel::approx_seq_mul;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn names_match_model_names() {
        assert_eq!(
            MultiplierSpec::Segmented { n: 8, t: 3, fix: true }.name(),
            Multiplier::name(&SegmentedSeqMul::new(8, 3, true))
        );
        assert_eq!(
            MultiplierSpec::Truncated { n: 8, k: 2 }.name(),
            Multiplier::name(&TruncatedMul { n: 8, k: 2 })
        );
        assert_eq!(
            MultiplierSpec::Accurate { n: 8 }.name(),
            Multiplier::name(&AccurateMul { n: 8 })
        );
    }

    #[test]
    fn validation_catches_bad_parameters() {
        assert!(MultiplierSpec::Segmented { n: 8, t: 8, fix: false }.validate().is_err());
        assert!(MultiplierSpec::Segmented { n: 40, t: 2, fix: false }.validate().is_err());
        assert!(MultiplierSpec::Kulkarni { n: 12 }.validate().is_err());
        assert!(MultiplierSpec::Truncated { n: 8, k: 9 }.validate().is_err());
        assert!(MultiplierSpec::BrokenArray { n: 8, hbl: 9, vbl: 0 }.validate().is_err());
        for spec in MultiplierSpec::registry_examples(8) {
            assert!(spec.validate().is_ok(), "{}", spec.name());
        }
    }

    #[test]
    fn canonicalization_merges_equal_product_functions() {
        // The generalized t=0 dedup: both fix modes AND the accurate
        // design share one representative.
        let a = MultiplierSpec::Segmented { n: 8, t: 0, fix: true }.canonical();
        let b = MultiplierSpec::Segmented { n: 8, t: 0, fix: false }.canonical();
        assert_eq!(a, b);
        assert_eq!(a, MultiplierSpec::Accurate { n: 8 });
        // Degenerate baselines collapse too.
        assert_eq!(
            MultiplierSpec::Truncated { n: 8, k: 0 }.canonical(),
            MultiplierSpec::Accurate { n: 8 }
        );
        assert_eq!(
            MultiplierSpec::BrokenArray { n: 8, hbl: 0, vbl: 3 }.canonical(),
            MultiplierSpec::Truncated { n: 8, k: 3 }
        );
        assert_eq!(
            MultiplierSpec::BrokenArray { n: 8, hbl: 0, vbl: 0 }.canonical(),
            MultiplierSpec::Accurate { n: 8 }
        );
        // t > 0 stays a real configuration axis.
        let c = MultiplierSpec::Segmented { n: 8, t: 4, fix: true };
        assert_eq!(c.canonical(), c);
        // Oracle / netlist families stay distinct (only the dead fix flag
        // canonicalizes at t = 0).
        assert_eq!(
            MultiplierSpec::BitLevel { n: 8, t: 0, fix: true }.canonical(),
            MultiplierSpec::BitLevel { n: 8, t: 0, fix: false }
        );
        assert_ne!(
            MultiplierSpec::BitLevel { n: 8, t: 0, fix: true }.canonical(),
            MultiplierSpec::Accurate { n: 8 }.canonical()
        );
    }

    #[test]
    fn canonical_is_idempotent() {
        let mut specs = MultiplierSpec::registry_examples(8);
        specs.push(MultiplierSpec::Segmented { n: 8, t: 0, fix: true });
        specs.push(MultiplierSpec::BrokenArray { n: 8, hbl: 0, vbl: 0 });
        for s in specs {
            assert_eq!(s.canonical(), s.canonical().canonical(), "{}", s.name());
        }
    }

    #[test]
    fn built_evaluators_match_reference_models() {
        let n = 8u32;
        let mut rng = Xoshiro256::seed_from_u64(0x5EC);
        let a: Vec<u64> = (0..200).map(|_| rng.next_bits(n)).collect();
        let b: Vec<u64> = (0..200).map(|_| rng.next_bits(n)).collect();
        for spec in MultiplierSpec::registry_examples(n) {
            let m = spec.build_batch().unwrap();
            assert_eq!(m.n(), n);
            assert_eq!(m.name(), spec.name());
            let mut out = vec![0u64; a.len()];
            m.mul_batch(&a, &b, &mut out);
            // Cross-check the segmented-family specs against the scalar
            // word-level model (the oracle tests cover the rest).
            if let (Some(t), Some(fix)) = (spec.split_point(), spec.fix_mode()) {
                for i in 0..a.len() {
                    assert_eq!(
                        out[i],
                        approx_seq_mul(a[i], b[i], n, t, fix),
                        "{} i={i}",
                        spec.name()
                    );
                }
            }
            if let MultiplierSpec::Accurate { .. } = spec {
                for i in 0..a.len() {
                    assert_eq!(out[i], a[i] * b[i]);
                }
            }
        }
    }

    #[test]
    fn every_registry_design_builds_a_true_batch_kernel() {
        // The acceptance contract of the batched-kernel layer: no
        // production evaluator is a per-pair scalar adapter, while every
        // scalar *reference* reports exactly that.
        for spec in MultiplierSpec::registry_examples(8) {
            let batch = spec.build_batch().unwrap();
            assert_eq!(
                batch.dispatch_class(),
                DispatchClass::Batched,
                "{} must not fall back to per-pair dispatch",
                spec.name()
            );
            let reference = spec.build_scalar_reference().unwrap();
            assert_eq!(reference.dispatch_class(), DispatchClass::Scalar, "{}", spec.name());
        }
    }

    #[test]
    fn batch_kernels_match_scalar_references() {
        let n = 8u32;
        let mut rng = Xoshiro256::seed_from_u64(0xD1F);
        let a: Vec<u64> = (0..300).map(|_| rng.next_bits(n)).collect();
        let b: Vec<u64> = (0..300).map(|_| rng.next_bits(n)).collect();
        for spec in MultiplierSpec::registry_examples(n) {
            let batch = spec.build_batch().unwrap();
            let reference = spec.build_scalar_reference().unwrap();
            let mut got = vec![0u64; a.len()];
            let mut want = vec![0u64; a.len()];
            batch.mul_batch(&a, &b, &mut got);
            reference.mul_batch(&a, &b, &mut want);
            assert_eq!(got, want, "{}", spec.name());
        }
    }

    #[test]
    fn netlist_batch_handles_ragged_groups() {
        // > 64 pairs exercises the 64-lane grouping; products must match
        // the word model regardless of group boundaries.
        let (n, t, fix) = (6u32, 3u32, true);
        let m = NetlistMul::new(n, t, fix);
        let mut rng = Xoshiro256::seed_from_u64(9);
        let a: Vec<u64> = (0..130).map(|_| rng.next_bits(n)).collect();
        let b: Vec<u64> = (0..130).map(|_| rng.next_bits(n)).collect();
        let mut out = vec![0u64; a.len()];
        m.mul_batch(&a, &b, &mut out);
        for i in 0..a.len() {
            assert_eq!(out[i], approx_seq_mul(a[i], b[i], n, t, fix), "i={i}");
        }
    }

    #[test]
    fn design_sets_enumerate_expected_points() {
        assert_eq!(DesignSet::Paper.specs(4).len(), 8); // t in 0..4 x 2 fix modes
        assert_eq!(DesignSet::Accurate.specs(4).len(), 1);
        // n=4 is a power of two: 4 fixed baselines + kulkarni.
        assert_eq!(DesignSet::Baselines.specs(4).len(), 5);
        assert_eq!(DesignSet::Baselines.specs(12).len(), 4);
        assert_eq!(DesignSet::Oracle.specs(8).len(), 1);
        assert_eq!(DesignSet::Oracle.specs(32).len(), 0);
        assert_eq!(DesignSet::Netlist.specs(8).len(), 1);
        assert_eq!(DesignSet::Netlist.specs(16).len(), 0);
        assert_eq!(
            DesignSet::All.specs(8).len(),
            DesignSet::Paper.specs(8).len() + 1 + 5 + 1 + 1
        );
        // Paper ordering is the legacy sweep order: t-major, fix-minor.
        let paper = DesignSet::Paper.specs(2);
        assert_eq!(
            paper,
            vec![
                MultiplierSpec::Segmented { n: 2, t: 0, fix: false },
                MultiplierSpec::Segmented { n: 2, t: 0, fix: true },
                MultiplierSpec::Segmented { n: 2, t: 1, fix: false },
                MultiplierSpec::Segmented { n: 2, t: 1, fix: true },
            ]
        );
    }

    #[test]
    fn design_tags_round_trip_for_every_registry_spec() {
        let mut specs = MultiplierSpec::registry_examples(8);
        specs.extend(MultiplierSpec::registry_examples(16));
        specs.push(MultiplierSpec::Segmented { n: 8, t: 0, fix: false });
        for spec in specs {
            let j = spec.to_json();
            // Serialized → reparsed → identical spec (through text too).
            let back = MultiplierSpec::from_json(&j).unwrap();
            assert_eq!(back, spec, "{}", spec.name());
            let reparsed = crate::util::json::Json::parse(&j.to_string_compact()).unwrap();
            assert_eq!(MultiplierSpec::from_json(&reparsed).unwrap(), spec);
            assert_eq!(j.get("family").unwrap().as_str(), Some(spec.family()));
        }
    }

    #[test]
    fn design_tag_parse_errors_are_reasons_not_panics() {
        let bad = crate::util::json::Json::parse(r#"{"family":"warp","n":8}"#).unwrap();
        assert!(MultiplierSpec::from_json(&bad).unwrap_err().contains("warp"));
        let missing = crate::util::json::Json::parse(r#"{"family":"segmented","n":8}"#).unwrap();
        assert!(MultiplierSpec::from_json(&missing).unwrap_err().contains("'t'"));
        let nofam = crate::util::json::Json::parse(r#"{"n":8}"#).unwrap();
        assert!(MultiplierSpec::from_json(&nofam).unwrap_err().contains("family"));
    }

    #[test]
    fn artifact_stems_are_unique_and_filesystem_safe() {
        let mut specs = MultiplierSpec::registry_examples(8);
        specs.extend(MultiplierSpec::registry_examples(16));
        specs.push(MultiplierSpec::Segmented { n: 8, t: 4, fix: false });
        let mut seen = std::collections::HashSet::new();
        for spec in &specs {
            let stem = spec.artifact_stem();
            assert!(seen.insert(stem.clone()), "duplicate stem {stem}");
            assert!(
                stem.chars().all(|c| c.is_ascii_alphanumeric() || c == '_'),
                "unsafe stem {stem}"
            );
        }
    }

    #[test]
    fn design_set_parsing() {
        assert_eq!(DesignSet::parse("all").unwrap(), DesignSet::All);
        assert_eq!(DesignSet::parse(" paper ").unwrap(), DesignSet::Paper);
        assert!(DesignSet::parse("everything").is_err());
        for set in [
            DesignSet::Paper,
            DesignSet::Accurate,
            DesignSet::Baselines,
            DesignSet::Oracle,
            DesignSet::Netlist,
            DesignSet::All,
        ] {
            assert_eq!(DesignSet::parse(set.name()).unwrap(), set);
        }
    }
}
