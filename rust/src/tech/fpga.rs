//! Xilinx-7-series-class FPGA model (stand-in for Vivado on the ZC706 —
//! DESIGN.md §2).
//!
//! * **LUT packing**: generic (non-carry) gates are greedily packed into
//!   LUT6s — a gate absorbs a fanin gate's cone when the merged cone still
//!   has ≤ 6 leaf inputs and the fanin has no other consumer. Adder bits on
//!   tagged carry chains map to 1 LUT (the propagate/generate function) +
//!   dedicated CARRY4 logic, like the 7-series slice.
//! * **Timing**: LUT hops cost `t_lut + t_net`; carry chain bits cost the
//!   fast dedicated-mux delay. This reproduces the paper's mechanism: the
//!   approximate design's shorter chain cuts the critical path while the
//!   LUT count barely moves.
//! * **Power**: toggle counts × per-resource switching energy at the
//!   operating frequency (vector-based, 2^16 uniform patterns by default).

use std::collections::HashSet;

use crate::netlist::graph::{Driver, GateKind, Net, Netlist};
use crate::netlist::timing::{analyze, DelayModel};

use super::activity::Activity;
use super::HwFigures;

/// FPGA timing/energy constants (7-series-class).
#[derive(Clone, Debug)]
pub struct FpgaModel {
    /// LUT6 propagation delay, ps.
    pub t_lut_ps: f64,
    /// Average net routing delay per LUT hop, ps.
    pub t_net_ps: f64,
    /// Delay per carry-logic gate, ps (two gates lie on the chain per
    /// adder bit, so the per-bit cost is 2x this — ~45 ps/bit like the
    /// 7-series CARRY4).
    pub t_carry_ps: f64,
    /// FF clock-to-Q + setup, ps.
    pub t_ff_ps: f64,
    /// Switching energy per LUT output toggle, fJ.
    pub e_lut_fj: f64,
    /// Switching energy per FF toggle (incl. local clock), fJ.
    pub e_ff_fj: f64,
    /// Switching energy per carry-logic gate toggle, fJ.
    pub e_carry_fj: f64,
}

impl Default for FpgaModel {
    fn default() -> Self {
        FpgaModel {
            t_lut_ps: 580.0,
            t_net_ps: 320.0,
            t_carry_ps: 22.0,
            t_ff_ps: 460.0,
            e_lut_fj: 12.0,
            e_ff_fj: 9.0,
            e_carry_fj: 3.0,
        }
    }
}

/// Result of LUT packing.
#[derive(Clone, Debug)]
pub struct Packing {
    /// Nets that are LUT roots (everything else was absorbed or is carry).
    pub roots: HashSet<Net>,
    /// Total LUT count (packed roots; carry logic uses CARRY4s, and the
    /// per-bit propagate XOR LUT is already a root).
    pub luts: usize,
    /// CARRY4 blocks (4 chain bits each, like the 7-series slice).
    pub carry4s: usize,
}

/// Greedy cone packing of the non-chain combinational gates into LUT6s.
pub fn pack_luts(nl: &Netlist) -> Packing {
    let chain = nl.chain_member_nets();
    // fanout of each net among gates
    let mut fanout = vec![0u32; nl.drivers.len()];
    for d in &nl.drivers {
        if let Driver::Gate { ins, .. } = d {
            for n in ins {
                fanout[n.0 as usize] += 1;
            }
        }
    }
    for (_, net) in &nl.outputs {
        fanout[net.0 as usize] += 1;
    }
    // Each gate's cone leaves (None = absorbed into a consumer).
    let mut leaves: Vec<Option<Vec<Net>>> = vec![None; nl.drivers.len()];
    let is_source = |d: &Driver| !matches!(d, Driver::Gate { .. });
    let mut roots: HashSet<Net> = HashSet::new();
    for &net in &nl.topo {
        if chain.contains(&net) {
            continue; // carry logic is not packed into LUTs
        }
        let Driver::Gate { ins, .. } = &nl.drivers[net.0 as usize] else { continue };
        let mut cone: Vec<Net> = Vec::new();
        for &input in ins {
            let d = &nl.drivers[input.0 as usize];
            let absorbable = !is_source(d)
                && !chain.contains(&input)
                && fanout[input.0 as usize] == 1
                && roots.contains(&input);
            if absorbable {
                // tentatively merge the fanin cone
                let sub = leaves[input.0 as usize].clone().unwrap_or_default();
                for l in sub {
                    if !cone.contains(&l) {
                        cone.push(l);
                    }
                }
            } else if !cone.contains(&input) {
                cone.push(input);
            }
        }
        if cone.len() <= 6 {
            // absorb eligible fanins
            for &input in ins {
                let d = &nl.drivers[input.0 as usize];
                if !is_source(d) && !chain.contains(&input) && fanout[input.0 as usize] == 1 {
                    roots.remove(&input);
                    leaves[input.0 as usize] = None;
                }
            }
            leaves[net.0 as usize] = Some(cone);
        } else {
            // keep fanins as their own LUTs; this gate reads them directly
            leaves[net.0 as usize] = Some(ins.clone());
        }
        roots.insert(net);
    }
    // Carry-logic gates map onto CARRY4 muxes/XORCY, not LUTs; the
    // propagate XOR per adder bit is an ordinary packed LUT (in `roots`).
    let carry4s = nl.carry_chains.iter().map(|c| c.couts.len().div_ceil(4)).sum();
    Packing { luts: roots.len(), roots, carry4s }
}

struct FpgaDelay<'a> {
    model: &'a FpgaModel,
    roots: &'a HashSet<Net>,
    current: std::cell::Cell<Net>,
}

impl DelayModel for FpgaDelay<'_> {
    fn gate_delay_ps(&self, _kind: GateKind, on_chain: bool) -> f64 {
        if on_chain {
            self.model.t_carry_ps
        } else if self.roots.contains(&self.current.get()) {
            self.model.t_lut_ps + self.model.t_net_ps
        } else {
            0.0 // absorbed into a LUT root
        }
    }
    fn ff_overhead_ps(&self) -> f64 {
        self.model.t_ff_ps
    }
}

/// FPGA evaluation report (Fig. 3a axes).
#[derive(Clone, Debug)]
pub struct FpgaReport {
    /// The common hardware figures.
    pub figures: HwFigures,
    /// LUTs used.
    pub luts: usize,
    /// CARRY4 blocks used.
    pub carry4s: usize,
    /// Critical combinational path, ps.
    pub crit_path_ps: f64,
}

impl FpgaModel {
    /// Evaluate a netlist. `cycles_per_op` as in the ASIC model; `period_ns`
    /// optionally pins the clock (power fairness).
    pub fn evaluate(
        &self,
        nl: &Netlist,
        act: &Activity,
        cycles_per_op: u32,
        period_ns: Option<f64>,
    ) -> FpgaReport {
        let packing = pack_luts(nl);
        // Timing: we cannot thread per-net identity through the DelayModel
        // trait, so run a custom arrival pass here.
        let chain = nl.chain_member_nets();
        let mut arrival = vec![0.0f64; nl.drivers.len()];
        let mut worst = 0.0f64;
        for &net in &nl.topo {
            if let Driver::Gate { ins, .. } = &nl.drivers[net.0 as usize] {
                let in_max = ins.iter().map(|n| arrival[n.0 as usize]).fold(0.0, f64::max);
                let d = if chain.contains(&net) {
                    self.t_carry_ps
                } else if packing.roots.contains(&net) {
                    self.t_lut_ps + self.t_net_ps
                } else {
                    0.0
                };
                arrival[net.0 as usize] = in_max + d;
                worst = worst.max(in_max + d);
            }
        }
        let min_period_ns = (worst + self.t_ff_ps) / 1000.0;
        let period = period_ns.unwrap_or(min_period_ns).max(min_period_ns);
        let f_ghz = 1.0 / period;
        // Energy: toggles on LUT roots + chain bits + FF outputs.
        let denom = (act.cycles * act.lanes) as f64;
        let mut e_cycle_fj = 0.0;
        for (i, d) in nl.drivers.iter().enumerate() {
            if let Driver::Gate { .. } = d {
                let net = Net(i as u32);
                if packing.roots.contains(&net) || chain.contains(&net) {
                    e_cycle_fj += act.toggles[i] as f64 / denom * self.e_lut_fj;
                }
            }
        }
        for ff in &nl.ffs {
            e_cycle_fj += act.toggles[ff.q.0 as usize] as f64 / denom * self.e_ff_fj;
            e_cycle_fj += 0.3 * self.e_ff_fj; // clock tree share
        }
        let dyn_mw = e_cycle_fj * f_ghz * 1e-3;
        FpgaReport {
            figures: HwFigures {
                resource: packing.luts as f64,
                ffs: nl.ff_count(),
                period_ns: min_period_ns,
                latency_ns: cycles_per_op as f64 * period,
                dyn_power_mw: dyn_mw,
                static_power_mw: 0.0,
            },
            luts: packing.luts,
            carry4s: packing.carry4s,
            crit_path_ps: worst,
        }
    }
}

// Silence the unused struct warning: FpgaDelay documents the intended trait
// shape; the inline pass above is the real implementation.
#[allow(dead_code)]
fn _delay_model_shape(m: &FpgaModel, roots: &HashSet<Net>) -> f64 {
    let d = FpgaDelay { model: m, roots, current: std::cell::Cell::new(Net(0)) };
    let _ = analyze;
    d.ff_overhead_ps()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::generators::adders::rca_netlist;
    use crate::netlist::generators::seq_mult::seq_mult;
    use crate::tech::measure_activity;

    #[test]
    fn rca_luts_scale_linearly() {
        let p8 = pack_luts(&rca_netlist(8));
        let p32 = pack_luts(&rca_netlist(32));
        assert!(p32.luts > p8.luts);
        let ratio = p32.luts as f64 / p8.luts as f64;
        assert!(ratio > 3.0 && ratio < 5.0, "ratio {ratio}");
        assert_eq!(p8.carry4s, 2);
        assert_eq!(p32.carry4s, 8);
    }

    #[test]
    fn packing_covers_all_gates() {
        // Every non-chain gate is either a root or absorbed (reachable
        // from some root) — sanity: root count <= gate count.
        let nl = seq_mult(8, 4, true).nl;
        let p = pack_luts(&nl);
        assert!(p.roots.len() <= nl.gate_count());
        assert!(p.luts >= p.roots.len());
    }

    #[test]
    fn segmentation_shortens_fpga_critical_path() {
        let model = FpgaModel::default();
        let acc = seq_mult(32, 0, false);
        let seg = seq_mult(32, 16, true);
        let a_act = measure_activity(&acc, 64, 1, false);
        let s_act = measure_activity(&seg, 64, 1, true);
        let ar = model.evaluate(&acc.nl, &a_act, 33, None);
        let sr = model.evaluate(&seg.nl, &s_act, 33, None);
        assert!(
            sr.figures.period_ns < ar.figures.period_ns,
            "seg {} vs acc {}",
            sr.figures.period_ns,
            ar.figures.period_ns
        );
        // LUT overhead should be modest (paper: slight area overhead).
        let overhead = sr.luts as f64 / ar.luts as f64 - 1.0;
        assert!(overhead < 0.40, "LUT overhead {overhead}");
    }

    #[test]
    fn power_positive() {
        let c = seq_mult(8, 4, true);
        let act = measure_activity(&c, 128, 5, true);
        let r = FpgaModel::default().evaluate(&c.nl, &act, 9, None);
        assert!(r.figures.dyn_power_mw > 0.0);
        assert_eq!(r.figures.static_power_mw, 0.0);
    }
}
