//! Technology models: map a netlist onto FPGA or ASIC resources and
//! estimate area, timing, and vector-based power.
//!
//! Substitution for the paper's EDA flows (DESIGN.md §2):
//!
//! * [`fpga`] — a Xilinx-7-series-class model (LUT6 packing + dedicated
//!   carry chains + FFs) standing in for Vivado on the ZC706;
//! * [`asic`] — a 45 nm-class standard-cell model standing in for
//!   Genus/Innovus on Nangate 45 nm OCL.
//!
//! Absolute numbers are model constants; the *shapes* the paper reports
//! (carry-chain-driven latency gap, small area/power overhead of the
//! approximate design, sequential-vs-combinational crossover) emerge from
//! structure: gate counts, chain lengths, logic depth, and simulated
//! switching activity.

pub mod activity;
pub mod asic;
pub mod fpga;

pub use activity::measure_activity;
pub use asic::{AsicModel, AsicReport};
pub use fpga::{FpgaModel, FpgaReport};

/// Common hardware evaluation output for one circuit.
#[derive(Clone, Debug)]
pub struct HwFigures {
    /// Resource metric: LUTs (FPGA) or µm² (ASIC).
    pub resource: f64,
    /// Registers used.
    pub ffs: usize,
    /// Minimum clock period, ns.
    pub period_ns: f64,
    /// End-to-end multiply latency, ns (cycles × period for sequential;
    /// = period for combinational).
    pub latency_ns: f64,
    /// Dynamic power at the operating frequency, mW.
    pub dyn_power_mw: f64,
    /// Static/leakage power, mW (ASIC only; 0 for the FPGA model).
    pub static_power_mw: f64,
}

impl HwFigures {
    /// Dynamic + static power, mW.
    pub fn total_power_mw(&self) -> f64 {
        self.dyn_power_mw + self.static_power_mw
    }
}
