//! Vector-based switching-activity measurement (the paper's Fig. 3 power
//! methodology: "a vector-based approach with a set of 2^16 uniform input
//! patterns").
//!
//! Runs the sequential multiplier netlist on uniform random operand pairs
//! (64 per simulator pass) and returns per-net toggle counts plus the cycle
//! count — the inputs to both technology power models.

use crate::multiplier::U512;
use crate::netlist::generators::seq_mult::{run_batch, SeqMultCircuit};
use crate::netlist::sim::SeqSim;
use crate::util::rng::Xoshiro256;

/// Toggle/activity measurement result.
#[derive(Clone, Debug)]
pub struct Activity {
    /// Per-net toggle counts over the whole run (64 vectors per lane-pass).
    pub toggles: Vec<u64>,
    /// Clock cycles simulated (load + n accumulation cycles per multiply,
    /// times the number of 64-lane groups).
    pub cycles: u64,
    /// Lanes per cycle (64): divide toggles by `cycles * 64` for per-net α.
    pub lanes: u64,
    /// Multiplies performed.
    pub multiplies: u64,
}

impl Activity {
    /// Mean toggles per net per (cycle·lane) — the activity factor α.
    pub fn alpha(&self, nets: usize) -> f64 {
        if self.cycles == 0 || nets == 0 {
            return 0.0;
        }
        self.toggles.iter().sum::<u64>() as f64
            / (nets as f64 * self.cycles as f64 * self.lanes as f64)
    }
}

/// Simulate `vectors` uniform random multiplies (rounded up to a multiple
/// of 64) and collect switching activity.
pub fn measure_activity(c: &SeqMultCircuit, vectors: u64, seed: u64, fix: bool) -> Activity {
    let groups = vectors.div_ceil(64).max(1);
    let mut sim = SeqSim::new(&c.nl);
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let n = c.n;
    for _ in 0..groups {
        let a: Vec<U512> = (0..64).map(|_| rand_u512(&mut rng, n)).collect();
        let b: Vec<U512> = (0..64).map(|_| rand_u512(&mut rng, n)).collect();
        let _ = run_batch(c, &mut sim, &a, &b, fix);
    }
    Activity {
        toggles: sim.toggles.clone(),
        cycles: sim.cycles,
        lanes: 64,
        multiplies: groups * 64,
    }
}

fn rand_u512(rng: &mut Xoshiro256, nbits: u32) -> U512 {
    let mut v = U512::ZERO;
    let mut remaining = nbits;
    let mut limb = 0;
    while remaining > 0 {
        let take = remaining.min(64);
        let word = rng.next_bits(take);
        // place at limb position
        let mut shifted = U512::from_u64(word);
        shifted = shifted.shl(limb * 64);
        v = v | shifted;
        remaining -= take;
        limb += 1;
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::generators::seq_mult::seq_mult;

    #[test]
    fn activity_nonzero_and_bounded() {
        let c = seq_mult(8, 4, true);
        let act = measure_activity(&c, 128, 1, true);
        assert_eq!(act.multiplies, 128);
        assert_eq!(act.cycles, 2 * (8 + 1)); // 2 groups x (load + n cycles)
        let alpha = act.alpha(c.nl.drivers.len());
        assert!(alpha > 0.0 && alpha < 1.0, "alpha {alpha}");
    }

    #[test]
    fn rand_u512_respects_width() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        for _ in 0..50 {
            let v = rand_u512(&mut rng, 100);
            assert!(v.bits() <= 100);
        }
        // wide values do appear
        let mut any_high = false;
        for _ in 0..50 {
            if rand_u512(&mut rng, 100).bits() > 64 {
                any_high = true;
            }
        }
        assert!(any_high);
    }

    #[test]
    fn deterministic_per_seed() {
        let c = seq_mult(6, 3, false);
        let a1 = measure_activity(&c, 64, 9, false);
        let a2 = measure_activity(&c, 64, 9, false);
        assert_eq!(a1.toggles, a2.toggles);
    }
}
