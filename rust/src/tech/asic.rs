//! 45 nm-class standard-cell model (stand-in for Nangate 45 nm OCL through
//! Genus/Innovus, which are unavailable — DESIGN.md §2).
//!
//! Per-cell constants are typical of public 45 nm open-cell data (order of
//! magnitude; the paper's claims are *relative*): area in µm², delay in ps,
//! switching energy in fJ per output toggle, leakage in nW. Every netlist
//! gate maps 1:1 onto a cell; timing runs the shared STA with these delays;
//! power combines simulated toggle counts with per-cell energies.

use crate::netlist::graph::{Driver, GateKind, Netlist};
use crate::netlist::timing::DelayModel;

use super::activity::Activity;
use super::HwFigures;

/// Per-cell characterization.
#[derive(Clone, Copy, Debug)]
pub struct Cell {
    /// Cell area, µm².
    pub area_um2: f64,
    /// Propagation delay, ps.
    pub delay_ps: f64,
    /// Switching energy per output toggle, fJ.
    pub energy_fj: f64,
    /// Leakage power, nW.
    pub leakage_nw: f64,
}

/// The cell library (45 nm-class constants).
#[derive(Clone, Debug)]
pub struct AsicModel {
    /// Inverter.
    pub inv: Cell,
    /// 2-input AND.
    pub and2: Cell,
    /// 2-input OR.
    pub or2: Cell,
    /// 2-input XOR.
    pub xor2: Cell,
    /// 2-input NAND.
    pub nand2: Cell,
    /// 2-input NOR.
    pub nor2: Cell,
    /// 2-input XNOR.
    pub xnor2: Cell,
    /// 2:1 multiplexer.
    pub mux2: Cell,
    /// D flip-flop.
    pub dff: Cell,
    /// Clock-to-Q + setup charged on every register-to-register path.
    pub ff_overhead_ps: f64,
    /// Per-stage delay of the synthesizer's carry-lookahead / prefix-adder
    /// substitution. Genus/Innovus do not keep long ripple chains: beyond
    /// the break-even width an n-bit carry resolves in ~(log2 n + 2)
    /// prefix stages. The timing pass charges each tagged chain
    /// min(ripple, CLA) — this reproduces the paper's ASIC trend (largest
    /// latency gain at n = 8, shrinking as n grows).
    pub cla_stage_ps: f64,
}

impl Default for AsicModel {
    fn default() -> Self {
        AsicModel {
            inv: Cell { area_um2: 0.532, delay_ps: 12.0, energy_fj: 0.6, leakage_nw: 10.0 },
            and2: Cell { area_um2: 1.064, delay_ps: 22.0, energy_fj: 1.2, leakage_nw: 22.0 },
            or2: Cell { area_um2: 1.064, delay_ps: 22.0, energy_fj: 1.2, leakage_nw: 22.0 },
            xor2: Cell { area_um2: 1.596, delay_ps: 36.0, energy_fj: 2.2, leakage_nw: 35.0 },
            nand2: Cell { area_um2: 0.798, delay_ps: 14.0, energy_fj: 0.9, leakage_nw: 16.0 },
            nor2: Cell { area_um2: 0.798, delay_ps: 16.0, energy_fj: 0.9, leakage_nw: 16.0 },
            xnor2: Cell { area_um2: 1.596, delay_ps: 36.0, energy_fj: 2.2, leakage_nw: 35.0 },
            mux2: Cell { area_um2: 1.862, delay_ps: 30.0, energy_fj: 1.8, leakage_nw: 30.0 },
            dff: Cell { area_um2: 4.522, delay_ps: 0.0, energy_fj: 4.5, leakage_nw: 60.0 },
            ff_overhead_ps: 130.0,
            cla_stage_ps: 60.0,
        }
    }
}

impl AsicModel {
    /// The characterized cell for `kind`.
    pub fn cell(&self, kind: GateKind) -> Cell {
        match kind {
            GateKind::Not => self.inv,
            GateKind::And => self.and2,
            GateKind::Or => self.or2,
            GateKind::Xor => self.xor2,
            GateKind::Nand => self.nand2,
            GateKind::Nor => self.nor2,
            GateKind::Xnor => self.xnor2,
            GateKind::Mux => self.mux2,
        }
    }

    /// Total cell area (gates + FFs), µm².
    pub fn area_um2(&self, nl: &Netlist) -> f64 {
        let gates: f64 = nl
            .drivers
            .iter()
            .filter_map(|d| match d {
                Driver::Gate { kind, .. } => Some(self.cell(*kind).area_um2),
                _ => None,
            })
            .sum();
        gates + nl.ff_count() as f64 * self.dff.area_um2
    }

    /// Total leakage, mW.
    pub fn leakage_mw(&self, nl: &Netlist) -> f64 {
        let gates: f64 = nl
            .drivers
            .iter()
            .filter_map(|d| match d {
                Driver::Gate { kind, .. } => Some(self.cell(*kind).leakage_nw),
                _ => None,
            })
            .sum();
        (gates + nl.ff_count() as f64 * self.dff.leakage_nw) * 1e-6
    }

    /// Dynamic energy per clock cycle (fJ) from measured activity:
    /// Σ_gates toggles_g / (cycles·lanes) · E_g, plus FF clock energy.
    pub fn energy_per_cycle_fj(&self, nl: &Netlist, act: &Activity) -> f64 {
        let denom = (act.cycles * act.lanes) as f64;
        let mut fj = 0.0;
        for (i, d) in nl.drivers.iter().enumerate() {
            if let Driver::Gate { kind, .. } = d {
                fj += act.toggles[i] as f64 / denom * self.cell(*kind).energy_fj;
            }
        }
        // FF output toggles + clock tree charge per FF per cycle (~30%).
        for ff in &nl.ffs {
            fj += act.toggles[ff.q.0 as usize] as f64 / denom * self.dff.energy_fj;
            fj += 0.3 * self.dff.energy_fj;
        }
        fj
    }

    /// Static timing with carry-lookahead substitution: every gate inside
    /// a tagged chain is charged `min(cell delay, CLA budget per gate)`,
    /// where the chain's CLA budget is `(log2 len + 2) * cla_stage_ps`.
    pub fn critical_path_ps(&self, nl: &Netlist) -> f64 {
        use crate::netlist::graph::Driver;
        use std::collections::HashMap;
        // per-gate delay cap for chain members
        let mut cap: HashMap<crate::netlist::graph::Net, f64> = HashMap::new();
        for chain in &nl.carry_chains {
            let len = chain.couts.len().max(1) as f64;
            let cla_total = ((len.log2().ceil()) + 2.0) * self.cla_stage_ps;
            // ~2 chain gates per bit lie on the carry path
            let per_gate = cla_total / (2.0 * len);
            for &m in &chain.members {
                cap.insert(m, per_gate);
            }
        }
        let mut arrival = vec![0.0f64; nl.drivers.len()];
        let mut worst = 0.0f64;
        for &net in &nl.topo {
            if let Driver::Gate { kind, ins } = &nl.drivers[net.0 as usize] {
                let in_max = ins.iter().map(|n| arrival[n.0 as usize]).fold(0.0, f64::max);
                let mut d = self.cell(*kind).delay_ps;
                if let Some(&c) = cap.get(&net) {
                    d = d.min(c);
                }
                arrival[net.0 as usize] = in_max + d;
                worst = worst.max(in_max + d);
            }
        }
        worst
    }

    /// Full evaluation. `cycles_per_op` is n+1 for the sequential designs
    /// (load + n accumulations), 1 for combinational. The clock is run at
    /// the circuit's own minimum period unless `period_ns` pins it (the
    /// paper pins accurate/approximate to the same clock for power
    /// fairness).
    pub fn evaluate(
        &self,
        nl: &Netlist,
        act: &Activity,
        cycles_per_op: u32,
        period_ns: Option<f64>,
    ) -> AsicReport {
        let crit = self.critical_path_ps(nl);
        let min_period_ns = (crit + self.ff_overhead_ps) / 1000.0;
        let period = period_ns.unwrap_or(min_period_ns).max(min_period_ns);
        let f_ghz = 1.0 / period;
        let e_cycle_fj = self.energy_per_cycle_fj(nl, act);
        // P[mW] = E[fJ]/cycle × f[GHz] × 1e-3
        let dyn_mw = e_cycle_fj * f_ghz * 1e-3;
        AsicReport {
            figures: HwFigures {
                resource: self.area_um2(nl),
                ffs: nl.ff_count(),
                period_ns: min_period_ns,
                latency_ns: cycles_per_op as f64 * period,
                dyn_power_mw: dyn_mw,
                static_power_mw: self.leakage_mw(nl),
            },
            cells: nl.gate_count(),
            crit_path_ps: crit,
        }
    }
}

impl DelayModel for AsicModel {
    fn gate_delay_ps(&self, kind: GateKind, _on_chain: bool) -> f64 {
        self.cell(kind).delay_ps
    }
    fn ff_overhead_ps(&self) -> f64 {
        self.ff_overhead_ps
    }
}

/// ASIC evaluation report (Fig. 3b axes).
#[derive(Clone, Debug)]
pub struct AsicReport {
    /// The common hardware figures.
    pub figures: HwFigures,
    /// Standard cells instantiated.
    pub cells: usize,
    /// Critical combinational path, ps.
    pub crit_path_ps: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::generators::seq_mult::seq_mult;
    use crate::tech::measure_activity;

    fn eval(n: u32, t: u32, fix: bool) -> AsicReport {
        let c = seq_mult(n, t, fix);
        let act = measure_activity(&c, 256, 42, fix);
        AsicModel::default().evaluate(&c.nl, &act, n + 1, None)
    }

    #[test]
    fn segmentation_reduces_period() {
        let acc = eval(16, 0, false);
        let seg = eval(16, 8, true);
        assert!(
            seg.figures.period_ns < acc.figures.period_ns,
            "approx {} vs accurate {}",
            seg.figures.period_ns,
            acc.figures.period_ns
        );
        assert!(seg.figures.latency_ns < acc.figures.latency_ns);
    }

    #[test]
    fn area_overhead_is_small() {
        // Paper: ASIC area overhead < 3% for larger bit-widths.
        let acc = eval(32, 0, false);
        let seg = eval(32, 16, true);
        let overhead = seg.figures.resource / acc.figures.resource - 1.0;
        assert!(overhead > 0.0, "approx design must cost extra muxes/FF");
        assert!(overhead < 0.25, "overhead {overhead} unexpectedly large");
    }

    #[test]
    fn power_positive_and_leakage_scales_with_area() {
        let small = eval(8, 4, true);
        let large = eval(16, 8, true);
        assert!(small.figures.dyn_power_mw > 0.0);
        assert!(large.figures.static_power_mw > small.figures.static_power_mw);
    }

    #[test]
    fn pinned_period_lowers_power_not_latency_floor() {
        let c = seq_mult(8, 4, true);
        let act = measure_activity(&c, 256, 1, true);
        let free = AsicModel::default().evaluate(&c.nl, &act, 9, None);
        let pinned = AsicModel::default().evaluate(&c.nl, &act, 9, Some(10.0));
        assert!(pinned.figures.latency_ns > free.figures.latency_ns);
        assert!(pinned.figures.dyn_power_mw < free.figures.dyn_power_mw);
        assert_eq!(pinned.figures.period_ns, free.figures.period_ns);
    }
}
