//! `segmul` — CLI for the segmented-carry sequential multiplier platform.
//!
//! Built on the [`segmul::api`] facade: a design-agnostic
//! [`MultiplierSpec`], builder-configured [`Session`]s over a persistent
//! worker pool (backends built once per worker, never per job), typed
//! errors, and streaming progress.
//!
//! Subcommands:
//!   eval     — evaluate one design configuration's error metrics
//!   sweep    — design-space sweep (paper grid and cross-design sets),
//!              writing sweep.csv + BENCH_sweep.json; `--require-pjrt`
//!              fails unless every design dispatched via lowered modules
//!   lower    — emit lowered PJRT modules for every registry design
//!              (schema-v2 manifest; enables full `--designs all` sweeps
//!              on the PJRT backend with zero CPU fallbacks)
//!   tune     — accuracy-budget autotuner: find the cheapest configuration
//!              meeting `--budget mred<=X|nmed<=X|wce<=X|psnr>=X` on the
//!              FPGA or ASIC model, writing the Pareto frontier to
//!              pareto.csv (closed-form answers by default: zero
//!              simulation on the paper grid)
//!   hw       — hardware figures (FPGA + ASIC models) for one config
//!   figures  — regenerate paper artifacts (fig2|mae|fig3a|fig3b|probprop|
//!              headline|seqcomb|pareto|all) into the results directory
//!   serve    — HTTP evaluation service (typed /v1/eval + /v1/sweep +
//!              /v1/tune, request coalescing, admission control, latency
//!              telemetry, graceful drain)
//!   fleet    — self-healing supervisor for store-backed sharded sweeps:
//!              spawns N `sweep --shard i/N` workers over one store,
//!              restarts crashes with backoff, reclaims dead leases,
//!              kills wedged shards, and merges when every shard drains
//!   estimate — probability-propagation ER/MED estimates (no simulation)
//!
//! Global options: --artifacts DIR, --results DIR, --config FILE,
//! --backend cpu|pjrt (default: pjrt when artifacts exist, else cpu).

use std::path::PathBuf;

use anyhow::{bail, Result};

use segmul::api::{
    analytic_stats, AnalyticMode, BackendChoice, DesignSet, EvalJob, JobResult, MultiplierSpec,
    Session, Shard, SweepGrid,
};
use segmul::config::Config;
use segmul::error::probprop;
use segmul::netlist::generators::seq_mult::seq_mult;
use segmul::report;
use segmul::runtime::{emit_artifacts, Manifest};
use segmul::tech::{measure_activity, AsicModel, FpgaModel};
use segmul::util::cli::Args;
use segmul::util::threadpool::default_workers;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn load_config(args: &Args) -> Result<Config> {
    let mut cfg = match args.opt("config") {
        Some(path) => Config::load(std::path::Path::new(path))?,
        None => Config::discover(),
    };
    if let Some(dir) = args.opt("artifacts") {
        cfg.artifacts_dir = PathBuf::from(dir);
    }
    if let Some(dir) = args.opt("results") {
        cfg.results_dir = PathBuf::from(dir);
    }
    if let Some(s) = args.opt_u64("samples")? {
        cfg.mc_samples = s;
    }
    if let Some(v) = args.opt_u64("hw-vectors")? {
        cfg.hw_vectors = v;
    }
    if let Some(s) = args.opt_u64("seed")? {
        cfg.seed = s;
    }
    Ok(cfg)
}

/// The single worker-count policy: `--workers` (0 is rejected), else
/// the config (which honors `SEGMUL_WORKERS`; an invalid env override
/// is a typed configuration error, not a silent clamp).
fn workers_from(args: &Args, cfg: &Config) -> Result<usize> {
    match args.opt_u64("workers")? {
        Some(0) => bail!("--workers 0: at least one worker is required"),
        Some(w) => Ok(w as usize),
        None => {
            // Surface an invalid SEGMUL_WORKERS before any work runs
            // (Config::default falls back silently to stay infallible).
            let _ = default_workers()?;
            Ok(cfg.workers)
        }
    }
}

/// The single backend-selection policy: `--backend cpu|pjrt`, else PJRT
/// exactly when artifacts exist.
fn backend_choice(args: &Args, cfg: &Config) -> Result<BackendChoice> {
    Ok(match args.opt("backend") {
        Some("cpu") => BackendChoice::Cpu,
        Some("pjrt") => BackendChoice::Pjrt(cfg.artifacts_dir.clone()),
        Some(other) => bail!("unknown backend {other:?} (cpu|pjrt)"),
        None => {
            if !cfg.artifacts_dir.join("manifest.json").exists() {
                eprintln!("note: no artifacts found, using cpu backend");
                BackendChoice::Cpu
            } else {
                BackendChoice::Auto(cfg.artifacts_dir.clone())
            }
        }
    })
}

/// Build the session every evaluating subcommand runs on: persistent
/// worker pool, the given backend, session-wide seed policy, and the
/// analytic answer-source mode (off everywhere except `sweep --analytic`).
fn make_session(
    choice: BackendChoice,
    cfg: &Config,
    workers: usize,
    analytic: AnalyticMode,
    store: Option<PathBuf>,
) -> Result<Session> {
    let mut builder = Session::builder()
        .workers(workers)
        .backend(choice)
        .seed(cfg.seed)
        .analytic(analytic);
    if let Some(dir) = store {
        builder = builder.store(dir);
    }
    Ok(builder.build()?)
}

fn job_from_args(args: &Args, cfg: &Config, session: &Session, n: u32, t: u32) -> Result<EvalJob> {
    let fix = args.flag("fix");
    let builder = session.job(MultiplierSpec::Segmented { n, t, fix });
    let builder = if args.flag("exhaustive") || (n <= cfg.exhaustive_max_n && !args.flag("mc")) {
        builder.exhaustive()
    } else if let Some(target) = args.opt_f64("target-stderr")? {
        builder.adaptive(cfg.mc_samples, target)
    } else {
        builder.monte_carlo(cfg.mc_samples)
    };
    Ok(builder.build()?)
}

fn print_metrics(job: &EvalJob, result: &JobResult) -> Result<()> {
    let m = result.metrics()?;
    println!(
        "{} backend={} samples={} ({} batches, {:.2} Mpairs/s)",
        job.design.name(),
        result.backend,
        m.samples,
        result.batches,
        result.throughput() / 1e6
    );
    println!(
        "  ER={:.6}  MED|ED|={:.4}  MED(signed)={:.4}  MAE={}  NMED={:.3e}  MRED={:.3e}  meanBER={:.5}",
        m.er,
        m.med_abs,
        m.med_signed,
        m.mae,
        m.nmed,
        m.mred,
        m.mean_ber()
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let n = args.req_u32("n")?;
    let t = args.opt_u32("t")?.unwrap_or(n / 2);
    let workers = workers_from(args, &cfg)?;
    let mut session =
        make_session(backend_choice(args, &cfg)?, &cfg, workers, AnalyticMode::Off, None)?;
    let job = job_from_args(args, &cfg, &session, n, t)?;
    let result = session.run(&job)?;
    print_metrics(&job, &result)?;
    Ok(())
}

/// Run the design-space sweep: the paper grid by default, a cross-design
/// comparative grid with `--designs all` (paper × accurate × baselines ×
/// oracle/netlist spot checks), or a single bit-width slice with `--n`.
/// Chunks of every config are sharded across the session's persistent
/// workers with a deterministic merge, so results are bit-identical for
/// any worker count; repeated and provably-equivalent configs are served
/// from the canonical result cache.
fn cmd_sweep(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let workers = workers_from(args, &cfg)?;
    let mut grid = match args.opt_u32("n")? {
        Some(n) => SweepGrid::single(n, &cfg)?,
        None => SweepGrid::from_config(&cfg)?,
    };
    if let Some(designs) = args.opt("designs") {
        grid.designs = DesignSet::parse(designs)?;
    }
    if args.flag("mc") {
        grid.force_mc = true;
    }
    let analytic = match args.opt("analytic") {
        Some(s) => AnalyticMode::parse(s)?,
        None => AnalyticMode::Off,
    };
    let store_dir = args.opt("store").map(PathBuf::from);
    let resume = args.flag("resume");
    let shard = match args.opt("shard") {
        Some(s) => Some(Shard::parse(s)?),
        None => None,
    };
    let deterministic = args.flag("deterministic-report");
    if resume {
        let Some(dir) = &store_dir else {
            bail!("--resume requires --store DIR (the store holds the checkpoints to resume from)");
        };
        if !dir.is_dir() {
            bail!("--resume: store {dir:?} does not exist — nothing to resume (drop --resume for a fresh run)");
        }
    }
    if shard.is_some() && store_dir.is_none() {
        bail!("--shard requires --store DIR (shards coordinate through the shared store)");
    }
    // Mirror of the runner's answer-source policy, usable before the
    // session exists: will this grid point be served analytically?
    let analytic_serves = |job: &EvalJob| match analytic {
        AnalyticMode::Off => false,
        AnalyticMode::Auto => analytic_stats(&job.design).is_some_and(|s| s.exact),
        AnalyticMode::Require => analytic_stats(&job.design).is_some(),
    };
    // PJRT coverage preflight: the manifest must dispatch every grid
    // design (a lowered module from `segmul lower`, or a legacy stats
    // module for the segmented family). Grid points served analytically
    // never reach the pool, so they don't need a lowering. Fall back
    // loudly to the CPU backend under Auto selection; reject an explicit
    // --backend pjrt up front with the uncovered designs named, rather
    // than failing mid-sweep.
    let mut choice = backend_choice(args, &cfg)?;
    let explicit_pjrt = matches!(choice, BackendChoice::Pjrt(_));
    let all_analytic = grid.jobs().iter().all(|j| analytic_serves(j));
    let pjrt_dir = match &choice {
        BackendChoice::Pjrt(dir) | BackendChoice::Auto(dir) if !all_analytic => Some(dir.clone()),
        _ => None,
    };
    if let Some(dir) = pjrt_dir {
        let uncovered: Vec<String> = match Manifest::load(&dir) {
            Ok(manifest) => {
                let mut missing: Vec<String> = grid
                    .jobs()
                    .iter()
                    .filter(|j| !analytic_serves(j) && !manifest.covers_design(&j.design))
                    .map(|j| j.design.name())
                    .collect();
                missing.dedup();
                missing
            }
            Err(e) if explicit_pjrt => return Err(e.into()),
            Err(e) => vec![format!("(manifest unreadable: {e})")],
        };
        if !uncovered.is_empty() {
            let shown = uncovered.iter().take(4).cloned().collect::<Vec<_>>().join(", ");
            let hint = format!("run `segmul lower --designs {}` to lower them", grid.designs.name());
            if explicit_pjrt {
                bail!(
                    "--backend pjrt cannot dispatch {} of {} grid designs ({shown}, ...); {hint}",
                    uncovered.len(),
                    grid.jobs().len()
                );
            }
            eprintln!(
                "note: {} of {} grid designs have no PJRT lowering ({shown}, ...); \
                 using cpu backend — {hint}",
                uncovered.len(),
                grid.jobs().len()
            );
            choice = BackendChoice::Cpu;
        }
    }
    let mut session = make_session(choice, &cfg, workers, analytic, store_dir.clone())?;
    let all_jobs = grid.jobs();
    let jobs = match shard {
        Some(s) => s.select(&all_jobs),
        None => all_jobs.clone(),
    };
    let total = jobs.len();
    println!(
        "sweep: {} configs over n ∈ {:?}, designs={} ({} workers, seed {}, analytic {})",
        all_jobs.len(),
        grid.bitwidths,
        grid.designs.name(),
        session.workers(),
        grid.seed,
        analytic.name()
    );
    if let Some(s) = shard {
        println!(
            "shard {}/{}: this process owns {} of {} grid configs (disjoint by canonical job key)",
            s.index,
            s.count,
            total,
            all_jobs.len()
        );
    }
    if let Some(dir) = &store_dir {
        println!(
            "store: {dir:?} ({})",
            if resume { "resuming from committed results and chunk checkpoints" } else { "persisting results" }
        );
    }
    let started = std::time::Instant::now();
    let outcomes = session.run_jobs(&jobs, |i, total, o| {
        let Ok(m) = o.metrics() else { return };
        println!(
            "  [{:>3}/{total}] {:<24} {:>10} samples  ER={:.6}  MED={:<12.4} {}",
            i + 1,
            o.job.design.name(),
            m.samples,
            m.er,
            m.med_abs,
            match o.result() {
                None => "(analytic)".to_string(),
                Some(_) if o.cached => "(cached)".to_string(),
                Some(r) => format!("({:.1} Mpairs/s)", r.throughput() / 1e6),
            }
        );
    })?;
    let wall = started.elapsed();
    println!("\n{}", report::sweep::sweep_table(&outcomes, deterministic)?.to_text());
    let telemetry = session.telemetry();
    let info = report::sweep::SweepRunInfo {
        workers: session.workers(),
        cache_hits: session.cache_hits(),
        jobs_evaluated: session.jobs_evaluated(),
        analytic_answers: session.analytic_answers(),
        store_hits: session.store_hits(),
        deterministic,
        wall,
        backend: session.backend_name().to_string(),
        kernel_dispatch: telemetry
            .kernel_dispatch
            .iter()
            .map(|(design, class)| (design.clone(), class.name().to_string()))
            .collect(),
    };
    let (csv_path, json_path) = report::sweep::write_sweep_reports(&cfg.results_dir, &outcomes, &info)?;
    println!(
        "{} configs in {:.2} s ({} evaluated, {} store hits, {} cache hits, {} analytic, {} workers, {} backend builds)",
        total,
        wall.as_secs_f64(),
        session.jobs_evaluated(),
        session.store_hits(),
        session.cache_hits(),
        session.analytic_answers(),
        session.workers(),
        session.backend_builds()
    );
    // Chaos-run accounting: when a fault plan is armed (SEGMUL_FAULTS)
    // or any retry fired, print greppable one-line summaries so the
    // chaos gauntlet can assert faults actually flowed through the run.
    if telemetry.faults_injected > 0 {
        let by_site: Vec<String> = session
            .faults()
            .counters()
            .iter()
            .map(|(site, n)| format!("{site}={n}"))
            .collect();
        println!("faults_injected: {} ({})", telemetry.faults_injected, by_site.join(", "));
    }
    if telemetry.retries > 0 || telemetry.gave_up > 0 {
        println!("retries: {} recovered, {} gave up", telemetry.retries, telemetry.gave_up);
    }
    if session.analytic_answers() > 0 {
        println!(
            "analytic: {} of {} configs answered in closed form (O(1), no simulation){}",
            session.analytic_answers(),
            total,
            if session.jobs_evaluated() == 0 && session.cache_hits() == 0 {
                " — zero pool dispatches"
            } else {
                ""
            }
        );
    }
    // Kernel-dispatch audit: every design must have run on a true batch
    // kernel or a lowered PJRT module — a scalar fallback means the sweep
    // silently regressed to per-pair dispatch, so name the offenders
    // loudly.
    let scalar = telemetry.scalar_fallbacks();
    let total = telemetry.kernel_dispatch.len();
    if scalar.is_empty() {
        if total > 0 {
            println!(
                "kernel dispatch: all {} evaluated designs ran on batch kernels ({} via lowered pjrt modules)",
                total,
                telemetry.pjrt_dispatches().len()
            );
        }
    } else {
        eprintln!(
            "warning: {} of {} designs fell back to per-pair scalar dispatch: {}",
            scalar.len(),
            total,
            scalar.join(", ")
        );
    }
    // --require-pjrt: the CI contract for accelerator sweeps — fail
    // unless the whole grid dispatched through lowered PJRT modules (no
    // scalar fallbacks, no CPU-tier fallback for any registry design).
    if args.flag("require-pjrt") {
        if total == 0 && session.analytic_answers() > 0 {
            // `--analytic` answered the whole grid in closed form:
            // nothing dispatched, so there is nothing for PJRT to prove
            // (whatever backend tier the idle pool holds).
            println!(
                "--require-pjrt: all {} configs answered analytically; no pjrt dispatches to audit",
                session.analytic_answers()
            );
            println!("wrote {csv_path:?} and {json_path:?}");
            return Ok(());
        }
        if session.backend_name() != "pjrt" {
            bail!(
                "--require-pjrt: sweep ran on the '{}' backend, not pjrt \
                 (run `segmul lower --designs {}` and retry with --backend pjrt)",
                session.backend_name(),
                grid.designs.name()
            );
        }
        if total == 0 {
            bail!("--require-pjrt: no designs were evaluated");
        }
        let offenders = telemetry.non_pjrt_dispatches();
        if !offenders.is_empty() {
            bail!(
                "--require-pjrt: {} of {total} evaluated designs fell back from the lowered pjrt path: {}",
                offenders.len(),
                offenders.join(", ")
            );
        }
        println!("--require-pjrt: all {total} evaluated designs dispatched via lowered pjrt modules");
    }
    println!("wrote {csv_path:?} and {json_path:?}");
    Ok(())
}

/// Lower every design point of the requested set × bit-widths into the
/// artifacts directory: one branch-free `.segir` module per design plus a
/// schema-v2 `manifest.json` — after which `segmul sweep --designs <set>
/// --backend pjrt` dispatches every design through a lowered module
/// (zero CPU/scalar fallbacks; prove it with `--require-pjrt`).
fn cmd_lower(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let designs = match args.opt("designs") {
        Some(s) => DesignSet::parse(s)?,
        None => DesignSet::All,
    };
    let bitwidths = match args.opt_u32("n")? {
        Some(n) => vec![n],
        None => cfg.sweep_bitwidths.clone(),
    };
    let batch = args.opt_u64("batch")?.unwrap_or(8192) as usize;
    let mut specs: Vec<MultiplierSpec> = Vec::new();
    for &n in &bitwidths {
        specs.extend(designs.specs(n));
    }
    if specs.is_empty() {
        bail!("design set '{}' is empty over n ∈ {:?}", designs.name(), bitwidths);
    }
    let started = std::time::Instant::now();
    let manifest = emit_artifacts(&cfg.artifacts_dir, &specs, batch)?;
    println!(
        "lowered {} modules (designs={}, n ∈ {:?}, batch {}) into {:?} in {:.2} s",
        manifest.lowered.len(),
        designs.name(),
        bitwidths,
        batch,
        cfg.artifacts_dir,
        started.elapsed().as_secs_f64()
    );
    println!(
        "manifest schema v{}: `segmul sweep --designs {} --backend pjrt` now dispatches every design via lowered modules",
        manifest.schema,
        designs.name()
    );
    Ok(())
}

/// Autotune: answer "what is the cheapest configuration within this
/// accuracy budget?" over a candidate grid. Error metrics flow through
/// the session's answer-source ladder (closed forms by default —
/// `--analytic require` — so the paper grid tunes with zero pool
/// dispatches; `--store` adds the persistent result store as a source),
/// hardware cost comes from the FPGA/ASIC models, and the full
/// non-dominated frontier lands in `results/pareto.csv`.
fn cmd_tune(args: &Args) -> Result<()> {
    use segmul::tune::{tune, Budget, TechTarget, TuneQuery};
    let cfg = load_config(args)?;
    let Some(budget) = args.opt("budget") else {
        bail!("tune requires --budget EXPR (mred<=X | nmed<=X | wce<=X | psnr>=X)");
    };
    let budget = Budget::parse(budget)?;
    let target = match args.opt("target") {
        Some(s) => TechTarget::parse(s)?,
        None => TechTarget::Fpga,
    };
    let bitwidths = match args.opt_u32("n")? {
        Some(n) => vec![n],
        None => cfg.sweep_bitwidths.clone(),
    };
    let designs = match args.opt("designs") {
        Some(s) => DesignSet::parse(s)?,
        None => DesignSet::Paper,
    };
    let fix = if args.flag("fix") {
        Some(true)
    } else {
        match args.opt("fix") {
            Some("true") => Some(true),
            Some("false") => Some(false),
            Some("both") | None => None,
            Some(other) => bail!("--fix expects true|false|both, got {other:?}"),
        }
    };
    let analytic = match args.opt("analytic") {
        Some(s) => AnalyticMode::parse(s)?,
        None => AnalyticMode::Require,
    };
    let workers = workers_from(args, &cfg)?;
    let store_dir = args.opt("store").map(PathBuf::from);
    let mut session =
        make_session(backend_choice(args, &cfg)?, &cfg, workers, analytic, store_dir)?;
    let query = TuneQuery::new(budget)
        .target(target)
        .bitwidths(bitwidths)
        .designs(designs)
        .fix(fix)
        .workload(cfg.exhaustive_max_n, cfg.mc_samples)
        .hw_vectors(cfg.hw_vectors)
        .hw_seed(cfg.seed);
    println!(
        "tune: {} over {} candidates (designs={}, n ∈ {:?}, target {}, analytic {})",
        query.budget.canonical(),
        query.specs().len(),
        query.designs.name(),
        query.bitwidths,
        query.target.name(),
        analytic.name()
    );
    let result = tune(&mut session, &query)?;
    match result.winner() {
        Some(w) => {
            println!("\nwinner: {}", w.spec.name());
            println!(
                "  error: ER={:.6}  NMED={:.3e}  MRED={:.3e}  WCE={}  (satisfies {})",
                w.metrics.er,
                w.metrics.nmed,
                w.metrics.mred,
                w.metrics.mae,
                result.budget.canonical()
            );
            match &w.hw {
                Some(h) => println!(
                    "  {:<5}: latency {:.2} ns (period {:.3} ns), resource {:.1}, power {:.4} mW",
                    query.target.name(),
                    h.latency_ns,
                    h.period_ns,
                    h.resource,
                    h.total_power_mw()
                ),
                None => {
                    println!("  (family has no gate-level mapping: error-only winner)")
                }
            }
        }
        None => println!(
            "\nno feasible configuration: none of the {} candidates meets {}",
            result.points.len(),
            result.budget.canonical()
        ),
    }
    let frontier = result.frontier_table();
    println!(
        "\nPareto frontier ({} of {} points non-dominated):",
        frontier.rows.len(),
        result.points.len()
    );
    println!("{}", frontier.to_text());
    let pareto_path = cfg.results_dir.join("pareto.csv");
    frontier.write(&pareto_path)?;
    println!(
        "{} points in {:.2} s ({} analytic, {} store hits, {} cache hits, {} evaluated{})",
        result.points.len(),
        result.wall.as_secs_f64(),
        result.analytic_answers,
        result.store_hits,
        result.cache_hits,
        result.jobs_evaluated,
        if result.jobs_evaluated == 0 { " — zero pool dispatches" } else { "" }
    );
    println!("wrote {pareto_path:?}");
    Ok(())
}

fn cmd_hw(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let n = args.req_u32("n")?;
    let t = args.opt_u32("t")?.unwrap_or(n / 2);
    let fix = t >= 1;
    let c = seq_mult(n, t, fix);
    let act = measure_activity(&c, cfg.hw_vectors, cfg.seed, fix);
    let fpga = FpgaModel::default().evaluate(&c.nl, &act, n + 1, None);
    let asic = AsicModel::default().evaluate(&c.nl, &act, n + 1, None);
    println!("circuit {} — {} gates, {} FFs", c.nl.name, c.nl.gate_count(), c.nl.ff_count());
    println!(
        "FPGA : {} LUTs, {} CARRY4, period {:.3} ns, latency {:.2} ns, dyn {:.4} mW",
        fpga.luts,
        fpga.carry4s,
        fpga.figures.period_ns,
        fpga.figures.latency_ns,
        fpga.figures.dyn_power_mw
    );
    println!(
        "ASIC : {:.1} um2, {} cells, period {:.3} ns, latency {:.2} ns, dyn {:.4} mW, leak {:.4} mW",
        asic.figures.resource,
        asic.cells,
        asic.figures.period_ns,
        asic.figures.latency_ns,
        asic.figures.dyn_power_mw,
        asic.figures.static_power_mw
    );
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    // The figure generators drive a backend directly (their tables mix
    // simulation with closed-form columns).
    let mut backend = backend_choice(args, &cfg)?.into_factory()()?;
    let run = |name: &str, which: &str| which == "all" || which == name;
    if run("fig2", which) {
        println!("== Fig. 2 (error metrics) ==");
        let t = report::fig2(&cfg, backend.as_mut())?;
        println!("{}", t.to_text());
    }
    if run("mae", which) {
        println!("== Eq. 11 closed-form MAE (E3) ==");
        let t = report::mae_table(&cfg)?;
        println!("{}", t.to_text());
    }
    if run("fig3a", which) {
        println!("== Fig. 3a (FPGA) ==");
        let t = report::fig3a(&cfg)?;
        println!("{}", t.to_text());
    }
    if run("fig3b", which) {
        println!("== Fig. 3b (ASIC) ==");
        let t = report::fig3b(&cfg)?;
        println!("{}", t.to_text());
    }
    if run("probprop", which) {
        println!("== §V-B estimator accuracy (E6) ==");
        let t = report::probprop_accuracy(&cfg)?;
        println!("{}", t.to_text());
    }
    if run("headline", which) {
        println!("== §V-D headline claims (E7) ==");
        let t = report::headline(&cfg)?;
        println!("{}", t.to_text());
    }
    if run("seqcomb", which) {
        println!("== §III seq-vs-comb crossover (E8) ==");
        let t = report::seqcomb(&cfg)?;
        println!("{}", t.to_text());
    }
    if run("pareto", which) {
        println!("== tune trade-off scatter (E10) ==");
        let t = report::pareto_fig(&cfg)?;
        println!("{}", t.to_text());
    }
    println!("CSV written to {:?}", cfg.results_dir);
    Ok(())
}

/// Run the HTTP evaluation service: typed `/v1/eval` + `/v1/sweep`
/// endpoints over the session layers (cache, analytic registry,
/// persistent store), with request coalescing, an in-flight admission
/// budget (typed 429/503), per-request deadlines, and a graceful drain
/// on SIGINT/SIGTERM or `POST /v1/shutdown`.
fn cmd_serve(args: &Args) -> Result<()> {
    use segmul::serve::{install_drain_signals, ServeConfig, Server};
    let cfg = load_config(args)?;
    let workers = workers_from(args, &cfg)?;
    let analytic = match args.opt("analytic") {
        Some(s) => AnalyticMode::parse(s)?,
        None => AnalyticMode::Off,
    };
    let max_inflight = args.opt_u64("max-inflight")?.unwrap_or(64) as usize;
    if max_inflight == 0 {
        bail!("--max-inflight 0: the server must admit at least one work item");
    }
    let serve_cfg = ServeConfig {
        addr: args.opt("addr").unwrap_or("127.0.0.1:8787").to_string(),
        workers: Some(workers),
        backend: backend_choice(args, &cfg)?,
        analytic,
        store: args.opt("store").map(PathBuf::from),
        seed: cfg.seed,
        mc_samples: cfg.mc_samples,
        exhaustive_max_n: cfg.exhaustive_max_n,
        max_inflight,
        default_deadline: std::time::Duration::from_millis(
            args.opt_u64("deadline-ms")?.unwrap_or(30_000).max(1),
        ),
        limits: Default::default(),
        faults: None,
    };
    install_drain_signals();
    let server = Server::start(serve_cfg)?;
    println!("listening on http://{}", server.addr());
    // Machine-readable backend identity (also served in /healthz,
    // /metrics, and every eval response) — scripts assert on this line
    // instead of scraping the stderr fallback note.
    println!("backend: {}", server.backend_name());
    println!(
        "endpoints: GET /healthz /v1/designs /metrics | POST /v1/eval /v1/sweep /v1/tune /v1/shutdown"
    );
    println!("drain: SIGINT/SIGTERM or POST /v1/shutdown");
    let summary = server.join();
    let t = &summary.telemetry;
    println!(
        "drained: {} requests, {} jobs ({} evaluated, {} cache hits, {} store hits, {} analytic) on the {} backend",
        summary.requests_total,
        t.jobs_completed,
        t.jobs_evaluated,
        t.cache_hits,
        t.store_hits,
        t.analytic_answers,
        summary.backend
    );
    Ok(())
}

/// Store-progress heartbeat for wedge detection: `(files, bytes)` over
/// the store's committed blobs and journals. Any shard that is actually
/// working appends journal checkpoints or commits blobs, so a fleet
/// whose heartbeat is frozen while children run is wedged, not slow.
fn store_progress(root: &std::path::Path) -> (u64, u64) {
    let mut files = 0u64;
    let mut bytes = 0u64;
    for sub in ["blobs", "journal"] {
        let Ok(entries) = std::fs::read_dir(root.join(sub)) else { continue };
        for entry in entries.flatten() {
            if let Ok(meta) = entry.metadata() {
                files += 1;
                bytes += meta.len();
            }
        }
    }
    (files, bytes)
}

/// One supervised sweep worker: the child process (when running), how
/// often it has been restarted, and the backoff gate for the respawn.
struct ShardSlot {
    child: Option<std::process::Child>,
    restarts: u32,
    backoff_until: Option<std::time::Instant>,
    done: bool,
}

/// Self-healing fleet supervisor for store-backed sharded sweeps.
///
/// Spawns `--shards N` child processes, each running
/// `segmul sweep --shard i/N --store DIR --resume --deterministic-report`
/// against one shared store, and supervises them until the grid drains:
///
/// - a shard that exits nonzero has its dead leases reclaimed and is
///   restarted with exponential backoff, up to `--max-restarts` times;
/// - a fleet whose store heartbeat (committed blobs + journal bytes)
///   freezes for `--wedge-secs` while children run is presumed wedged:
///   every live child is killed, leases are reclaimed, and the shards
///   restart from their checkpoints;
/// - when every shard drains, a merge-only pass re-runs the full grid
///   against the warm store (zero duplicate evaluations) and writes the
///   canonical deterministic report.
///
/// Restarts are safe because the store is the source of truth: committed
/// results are content-addressed, journals replay to the longest valid
/// prefix, and `--shard` ownership is disjoint by canonical job key —
/// so a heal never duplicates or reorders work and the merged report is
/// byte-identical to a crash-free run.
fn cmd_fleet(args: &Args) -> Result<()> {
    use std::process::{Command, Stdio};
    use std::time::{Duration, Instant};
    let cfg = load_config(args)?;
    let shards = args.req_u32("shards")? as usize;
    if shards == 0 {
        bail!("--shards 0: the fleet needs at least one worker process");
    }
    let Some(store_dir) = args.opt("store").map(PathBuf::from) else {
        bail!("fleet requires --store DIR (shards coordinate through the shared store)");
    };
    let max_restarts = args.opt_u64("max-restarts")?.unwrap_or(3) as u32;
    let wedge_secs = args.opt_u64("wedge-secs")?.unwrap_or(120).max(1);
    // Open (and thereby create) the store up front so every child can be
    // spawned with --resume from the first launch onward. The supervisor
    // itself never injects faults — a SEGMUL_FAULTS chaos plan is for
    // the worker processes (which inherit the environment), not for the
    // healing machinery.
    let store = segmul::store::ResultStore::open_with_faults(
        &store_dir,
        std::sync::Arc::new(segmul::fault::FaultInjector::disabled()),
    )?;
    let exe = std::env::current_exe()?;
    // Grid and backend options forwarded verbatim to every worker and to
    // the merge pass, so all of them see the same canonical job keys.
    let mut forwarded: Vec<String> = Vec::new();
    for opt in ["n", "designs", "samples", "seed", "workers", "backend", "analytic", "config", "artifacts"] {
        if let Some(v) = args.opt(opt) {
            forwarded.push(format!("--{opt}"));
            forwarded.push(v.to_string());
        }
    }
    if args.flag("mc") {
        forwarded.push("--mc".to_string());
    }
    let spawn_shard = |i: usize| -> std::io::Result<std::process::Child> {
        Command::new(&exe)
            .arg("sweep")
            .args(&forwarded)
            .arg("--store")
            .arg(&store_dir)
            .arg("--resume")
            .arg("--shard")
            .arg(format!("{i}/{shards}"))
            .arg("--deterministic-report")
            .arg("--results")
            .arg(cfg.results_dir.join(format!("shard-{i}")))
            .stdout(Stdio::null())
            .spawn()
    };
    println!("fleet: {shards} shards over store {store_dir:?} (max {max_restarts} restarts/shard, wedge after {wedge_secs} s)");
    let mut slots: Vec<ShardSlot> = Vec::with_capacity(shards);
    for i in 0..shards {
        let child = spawn_shard(i)?;
        // The pid line is machine-readable on purpose: the kill-and-heal
        // tests parse it to murder a live shard mid-sweep.
        println!("fleet: shard {i}/{shards} pid {} up (restart #0)", child.id());
        slots.push(ShardSlot { child: Some(child), restarts: 0, backoff_until: None, done: false });
    }
    let mut total_restarts = 0u32;
    let mut wedge_kills = 0u32;
    let mut leases_reclaimed = 0usize;
    let mut last_progress = store_progress(store.root());
    let mut progress_at = Instant::now();
    let mut fatal: Option<String> = None;
    loop {
        let mut all_done = true;
        for (i, slot) in slots.iter_mut().enumerate() {
            if slot.done {
                continue;
            }
            all_done = false;
            match &mut slot.child {
                Some(child) => match child.try_wait()? {
                    Some(status) if status.success() => {
                        slot.child = None;
                        slot.done = true;
                        println!("fleet: shard {i}/{shards} drained");
                    }
                    Some(status) => {
                        slot.child = None;
                        if slot.restarts >= max_restarts {
                            fatal = Some(format!(
                                "fleet: shard {i}/{shards} failed {} times (last: {status}); giving up",
                                slot.restarts + 1
                            ));
                            break;
                        }
                        slot.restarts += 1;
                        total_restarts += 1;
                        leases_reclaimed += store.reclaim_dead_leases();
                        let delay = Duration::from_millis(250u64 << slot.restarts.min(5));
                        slot.backoff_until = Some(Instant::now() + delay);
                        eprintln!(
                            "warning: fleet shard {i}/{shards} exited ({status}); restart #{} in {} ms",
                            slot.restarts,
                            delay.as_millis()
                        );
                    }
                    None => {}
                },
                None => {
                    if slot.backoff_until.is_none_or(|t| Instant::now() >= t) {
                        slot.backoff_until = None;
                        let child = spawn_shard(i)?;
                        println!("fleet: shard {i}/{shards} pid {} up (restart #{})", child.id(), slot.restarts);
                        slot.child = Some(child);
                    }
                }
            }
        }
        if let Some(msg) = fatal.take() {
            for slot in slots.iter_mut() {
                if let Some(child) = &mut slot.child {
                    let _ = child.kill();
                    let _ = child.wait();
                }
            }
            bail!(msg);
        }
        if all_done {
            break;
        }
        // Wedge detection: children are alive but the store heartbeat is
        // frozen past the deadline — kill the live shards and let the
        // restart path resume them from their checkpoints.
        let progress = store_progress(store.root());
        if progress != last_progress {
            last_progress = progress;
            progress_at = Instant::now();
        } else if progress_at.elapsed() >= Duration::from_secs(wedge_secs) {
            for (i, slot) in slots.iter_mut().enumerate() {
                if let Some(child) = &mut slot.child {
                    let _ = child.kill();
                    let _ = child.wait();
                    slot.child = None;
                    slot.backoff_until = Some(Instant::now() + Duration::from_millis(250));
                    wedge_kills += 1;
                    eprintln!("warning: fleet shard {i}/{shards} wedged (no store progress in {wedge_secs} s); killed");
                }
            }
            leases_reclaimed += store.reclaim_dead_leases();
            progress_at = Instant::now();
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    leases_reclaimed += store.reclaim_dead_leases();
    println!(
        "fleet: all {shards} shards drained ({total_restarts} restarts, {wedge_kills} wedge kills, \
         {leases_reclaimed} leases reclaimed); running merge pass"
    );
    let status = Command::new(&exe)
        .arg("sweep")
        .args(&forwarded)
        .arg("--store")
        .arg(&store_dir)
        .arg("--resume")
        .arg("--deterministic-report")
        .arg("--results")
        .arg(&cfg.results_dir)
        .status()?;
    if !status.success() {
        bail!("fleet: merge pass failed ({status})");
    }
    println!("fleet: merge complete; report written to {:?}", cfg.results_dir);
    Ok(())
}

fn cmd_estimate(args: &Args) -> Result<()> {
    let n = args.req_u32("n")?;
    let t = args.opt_u32("t")?.unwrap_or(n / 2);
    let lat = probprop::propagate(n, t);
    println!("probability-propagation estimates for n={n}, t={t} (no simulation):");
    println!("  ER  ≈ {:.6}", lat.er_estimate());
    println!("  MED ≈ {:.4} (signed, fix-to-1 off)", lat.med_estimate());
    println!("  P(fix-to-1 triggers) ≈ {:.6}", lat.fix_probability());
    Ok(())
}

fn usage() -> &'static str {
    "usage: segmul <eval|sweep|tune|lower|hw|figures|serve|fleet|estimate> [options]
  eval     --n N [--t T] [--fix] [--mc|--exhaustive] [--samples S] [--backend cpu|pjrt]
  sweep    [--n N] [--mc] [--designs paper|accurate|baselines|oracle|netlist|all]
           [--workers W] [--samples S] [--seed S] [--results DIR] [--require-pjrt]
           [--analytic off|auto|require] [--store DIR] [--resume] [--shard I/N]
           [--deterministic-report]
           (no --n: full configured grid; writes sweep.csv + BENCH_sweep.json;
            --require-pjrt fails unless every design ran via a lowered PJRT module;
            --analytic auto serves exact closed-form designs in O(1) without
            simulation, require answers the whole grid analytically or fails;
            --store persists results + per-chunk checkpoints so a killed sweep
            resumes bit-identically with --resume; --shard I/N claims a disjoint
            slice of the grid so N processes share one store with zero duplicate
            evaluations; --deterministic-report omits wall-clock fields so
            reports byte-compare across runs)
  tune     --budget 'mred<=X|nmed<=X|wce<=X|psnr>=X' [--target fpga|asic]
           [--n N] [--designs SET] [--fix true|false|both] [--workers W]
           [--analytic off|auto|require] [--store DIR] [--samples S]
           [--hw-vectors V] [--seed S] [--results DIR]
           (accuracy-budget autotuner: prints the cheapest configuration
            meeting the budget with its predicted error + latency/area/power,
            and writes the non-dominated error × latency × resource × power
            frontier to pareto.csv; --analytic defaults to require, so the
            paper grid is answered in closed form with zero simulation —
            quote the budget so the shell keeps the <= intact)
  lower    [--n N] [--designs SET] [--batch B] [--artifacts DIR]
           (emit lowered PJRT modules; default: the full sweep grid, batch 8192)
  hw       --n N [--t T] [--hw-vectors V]
  figures  [fig2|mae|fig3a|fig3b|probprop|headline|seqcomb|pareto|all]
           [--results DIR]
  serve    [--addr HOST:PORT] [--workers W] [--backend cpu|pjrt] [--store DIR]
           [--analytic off|auto|require] [--max-inflight K] [--deadline-ms D]
           (HTTP evaluation service, default 127.0.0.1:8787: POST /v1/eval,
            /v1/sweep (chunked ndjson stream), and /v1/tune (budget in, winner +
            Pareto frontier out), GET /healthz /v1/designs /metrics;
            identical concurrent requests coalesce into one pool evaluation,
            typed 429 past the in-flight budget, 503 while draining, 504 past a
            request deadline; graceful drain on SIGINT/SIGTERM or POST
            /v1/shutdown)
  fleet    --shards N --store DIR [--n N] [--mc] [--designs SET] [--samples S]
           [--seed S] [--workers W] [--results DIR] [--max-restarts R]
           [--wedge-secs T]
           (self-healing supervisor for sharded sweeps: spawns N
            `sweep --shard i/N` workers over one shared store, restarts
            crashed shards with exponential backoff after reclaiming their
            dead leases, kills shards wedged past T seconds of zero store
            progress, and runs a merge-only pass for the canonical report
            once every shard drains — byte-identical to a crash-free run)
  estimate --n N [--t T]"
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_deref() {
        Some("eval") => cmd_eval(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("tune") => cmd_tune(&args),
        Some("lower") => cmd_lower(&args),
        Some("hw") => cmd_hw(&args),
        Some("figures") => cmd_figures(&args),
        Some("serve") => cmd_serve(&args),
        Some("fleet") => cmd_fleet(&args),
        Some("estimate") => cmd_estimate(&args),
        Some("help") | None => {
            println!("{}", usage());
            Ok(())
        }
        Some(other) => bail!("unknown subcommand {other:?}\n{}", usage()),
    }
}
