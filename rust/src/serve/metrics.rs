//! Server-side counters and the `/metrics` text rendition.
//!
//! Everything is lock-light: counters are atomics bumped on the
//! connection threads; the latency reservoir is a small mutex-guarded
//! ring (the percentile math runs only when `/metrics` is scraped).
//! Rendition is plain `key value` lines — greppable from CI and the
//! loopback bench without a metrics client.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::api::SessionTelemetry;
use crate::report::percentile;

/// Queue-depth histogram bucket upper bounds (inclusive); the last
/// bucket is unbounded.
const QUEUE_BUCKETS: [usize; 6] = [0, 1, 2, 4, 8, 16];

/// Latency reservoir size: enough for stable p99 on smoke/bench runs
/// without unbounded growth on long-lived servers.
const LATENCY_RING: usize = 4096;

/// Aggregate server counters, shared by every connection thread.
#[derive(Default)]
pub struct ServerMetrics {
    /// Requests accepted for processing.
    pub requests_total: AtomicU64,
    /// 2xx responses sent.
    pub responses_2xx: AtomicU64,
    /// 4xx responses sent.
    pub responses_4xx: AtomicU64,
    /// 5xx responses sent.
    pub responses_5xx: AtomicU64,
    /// Admission rejections: in-flight budget exhausted.
    pub rejected_429: AtomicU64,
    /// Admission rejections: draining.
    pub rejected_503: AtomicU64,
    /// Requests whose deadline expired before the engine answered.
    pub deadline_timeouts: AtomicU64,
    /// Engine-thread panics caught by the supervisor (each one restarts
    /// the session; stranded requests got typed 500s).
    pub engine_restarts: AtomicU64,
    /// Answers served in closed form while the pool was degraded.
    pub degraded_answers: AtomicU64,
    /// Eval requests answered (the coalesce numerator).
    pub coalesce_requests: AtomicU64,
    /// Pool evaluations actually dispatched for them (the denominator):
    /// batch-deduped jobs that were neither cache, store, nor analytic
    /// answers.
    pub coalesce_dispatched: AtomicU64,
    /// Queue depth observed at each admission, histogrammed.
    queue_depth: [AtomicU64; QUEUE_BUCKETS.len() + 1],
    /// Request latencies (ms), overwriting ring.
    latencies_ms: Mutex<Vec<f64>>,
    latency_cursor: AtomicU64,
}

impl ServerMetrics {
    /// Classify a finished response by status family.
    pub fn observe_response(&self, status: u16) {
        self.requests_total.fetch_add(1, Ordering::Relaxed);
        let family = match status {
            200..=299 => &self.responses_2xx,
            400..=499 => &self.responses_4xx,
            _ => &self.responses_5xx,
        };
        family.fetch_add(1, Ordering::Relaxed);
        match status {
            429 => {
                self.rejected_429.fetch_add(1, Ordering::Relaxed);
            }
            503 => {
                self.rejected_503.fetch_add(1, Ordering::Relaxed);
            }
            _ => {}
        }
    }

    /// Record one request's wall latency.
    pub fn record_latency(&self, ms: f64) {
        let mut ring = super::lock_clean(&self.latencies_ms);
        if ring.len() < LATENCY_RING {
            ring.push(ms);
        } else {
            let at = self.latency_cursor.fetch_add(1, Ordering::Relaxed) as usize;
            ring[at % LATENCY_RING] = ms;
        }
    }

    /// Record the queue depth seen when a request was admitted.
    pub fn record_queue_depth(&self, depth: usize) {
        let bucket = QUEUE_BUCKETS
            .iter()
            .position(|&le| depth <= le)
            .unwrap_or(QUEUE_BUCKETS.len());
        self.queue_depth[bucket].fetch_add(1, Ordering::Relaxed);
    }

    /// (p50, p90, p99) of the recorded latencies, in ms (NaN when empty).
    pub fn latency_percentiles(&self) -> (f64, f64, f64) {
        let mut v = super::lock_clean(&self.latencies_ms).clone();
        v.sort_by(f64::total_cmp);
        (percentile(&v, 0.50), percentile(&v, 0.90), percentile(&v, 0.99))
    }

    /// Eval requests answered per pool evaluation dispatched (>= 1; the
    /// batch dedupe and the cache/store/analytic layers both contribute).
    /// Defined as the request count itself while nothing has dispatched.
    pub fn coalesce_ratio(&self) -> f64 {
        let requests = self.coalesce_requests.load(Ordering::Relaxed) as f64;
        let dispatched = self.coalesce_dispatched.load(Ordering::Relaxed) as f64;
        if dispatched == 0.0 {
            requests.max(1.0)
        } else {
            requests / dispatched
        }
    }

    /// Render the full `/metrics` document: server counters, latency
    /// percentiles, the queue-depth histogram, and the session telemetry
    /// (including the backend identity, so clients and CI can assert
    /// which backend actually served — not just a stderr note).
    pub fn render(
        &self,
        session: &SessionTelemetry,
        backend: &str,
        draining: bool,
        degraded: bool,
        queue_depth: usize,
    ) -> String {
        let (p50, p90, p99) = self.latency_percentiles();
        let load = |c: &AtomicU64| c.load(Ordering::Relaxed);
        let mut out = String::with_capacity(1024);
        let mut line = |k: &str, v: String| {
            out.push_str(k);
            out.push(' ');
            out.push_str(&v);
            out.push('\n');
        };
        let f3 = |v: f64| if v.is_nan() { "NaN".to_string() } else { format!("{v:.3}") };
        line("serve_backend", backend.to_string());
        line("serve_draining", u64::from(draining).to_string());
        line("serve_degraded", u64::from(degraded).to_string());
        line("serve_queue_depth", queue_depth.to_string());
        line("serve_requests_total", load(&self.requests_total).to_string());
        line("serve_responses_2xx", load(&self.responses_2xx).to_string());
        line("serve_responses_4xx", load(&self.responses_4xx).to_string());
        line("serve_responses_5xx", load(&self.responses_5xx).to_string());
        line("serve_rejected_429", load(&self.rejected_429).to_string());
        line("serve_rejected_503", load(&self.rejected_503).to_string());
        line("serve_deadline_timeouts", load(&self.deadline_timeouts).to_string());
        line("serve_engine_restarts", load(&self.engine_restarts).to_string());
        line("serve_degraded_answers", load(&self.degraded_answers).to_string());
        line("serve_coalesce_requests", load(&self.coalesce_requests).to_string());
        line("serve_coalesce_dispatched", load(&self.coalesce_dispatched).to_string());
        line("serve_coalesce_ratio", f3(self.coalesce_ratio()));
        line("serve_latency_p50_ms", f3(p50));
        line("serve_latency_p90_ms", f3(p90));
        line("serve_latency_p99_ms", f3(p99));
        for (i, le) in QUEUE_BUCKETS.iter().enumerate() {
            line(&format!("serve_queue_depth_le_{le}"), load(&self.queue_depth[i]).to_string());
        }
        line(
            "serve_queue_depth_le_inf",
            load(&self.queue_depth[QUEUE_BUCKETS.len()]).to_string(),
        );
        line("session_jobs_completed", session.jobs_completed.to_string());
        line("session_jobs_evaluated", session.jobs_evaluated.to_string());
        line("session_cache_hits", session.cache_hits.to_string());
        line("session_analytic_answers", session.analytic_answers.to_string());
        line("session_store_hits", session.store_hits.to_string());
        line("session_store_recoveries", session.store_recoveries.to_string());
        line("session_retries", session.retries.to_string());
        line("session_gave_up", session.gave_up.to_string());
        line("session_faults_injected", session.faults_injected.to_string());
        line("session_pairs_evaluated", session.pairs_evaluated.to_string());
        line("session_backend_builds", session.backend_builds.to_string());
        line("session_workers", session.workers.to_string());
        out
    }
}

/// Parse one `key value` line out of a rendered `/metrics` document —
/// shared by the loopback bench, the example, and the smoke tests.
pub fn metric_value(doc: &str, key: &str) -> Option<String> {
    doc.lines()
        .find_map(|l| l.strip_prefix(key).and_then(|rest| rest.strip_prefix(' ')))
        .map(|v| v.trim().to_string())
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;

    #[test]
    fn response_families_and_rejections() {
        let m = ServerMetrics::default();
        for s in [200, 200, 400, 429, 503, 500, 504] {
            m.observe_response(s);
        }
        assert_eq!(m.requests_total.load(Ordering::Relaxed), 7);
        assert_eq!(m.responses_2xx.load(Ordering::Relaxed), 2);
        assert_eq!(m.responses_4xx.load(Ordering::Relaxed), 2);
        assert_eq!(m.responses_5xx.load(Ordering::Relaxed), 3);
        assert_eq!(m.rejected_429.load(Ordering::Relaxed), 1);
        assert_eq!(m.rejected_503.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn latency_percentiles_use_nearest_rank() {
        let m = ServerMetrics::default();
        for i in 1..=10 {
            m.record_latency(i as f64);
        }
        let (p50, p90, p99) = m.latency_percentiles();
        assert_eq!((p50, p90, p99), (5.0, 9.0, 10.0));
    }

    #[test]
    fn queue_histogram_buckets() {
        let m = ServerMetrics::default();
        for depth in [0, 1, 2, 3, 5, 9, 17, 1000] {
            m.record_queue_depth(depth);
        }
        let counts: Vec<u64> =
            m.queue_depth.iter().map(|c| c.load(Ordering::Relaxed)).collect();
        assert_eq!(counts, vec![1, 1, 1, 1, 1, 1, 2]);
    }

    #[test]
    fn coalesce_ratio_floors_at_one() {
        let m = ServerMetrics::default();
        assert_eq!(m.coalesce_ratio(), 1.0);
        m.coalesce_requests.store(12, Ordering::Relaxed);
        m.coalesce_dispatched.store(3, Ordering::Relaxed);
        assert_eq!(m.coalesce_ratio(), 4.0);
    }

    #[test]
    fn render_emits_greppable_lines() {
        let m = ServerMetrics::default();
        m.observe_response(200);
        m.record_latency(3.0);
        m.record_queue_depth(2);
        let doc = m.render(&SessionTelemetry::default(), "cpu", false, true, 0);
        assert_eq!(metric_value(&doc, "serve_backend").as_deref(), Some("cpu"));
        assert_eq!(metric_value(&doc, "serve_requests_total").as_deref(), Some("1"));
        assert_eq!(metric_value(&doc, "serve_latency_p99_ms").as_deref(), Some("3.000"));
        assert_eq!(metric_value(&doc, "serve_queue_depth_le_2").as_deref(), Some("1"));
        assert_eq!(metric_value(&doc, "session_workers").as_deref(), Some("0"));
        assert_eq!(metric_value(&doc, "serve_degraded").as_deref(), Some("1"));
        assert_eq!(metric_value(&doc, "serve_engine_restarts").as_deref(), Some("0"));
        assert_eq!(metric_value(&doc, "serve_degraded_answers").as_deref(), Some("0"));
        assert_eq!(metric_value(&doc, "session_retries").as_deref(), Some("0"));
        assert_eq!(metric_value(&doc, "session_faults_injected").as_deref(), Some("0"));
        // Prefix keys must not shadow longer keys.
        assert_eq!(metric_value(&doc, "serve_queue_depth").as_deref(), Some("0"));
    }
}
