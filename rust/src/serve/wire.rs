//! Typed JSON wire formats: request extraction and response encoding.
//!
//! Request parsing is strict and total: every malformed body — invalid
//! JSON, wrong-typed fields, unknown design families, out-of-range
//! parameters — maps to a typed [`SegmulError`] (and from there, through
//! [`error_wire`], to a 4xx JSON error body). The design tag reuses the
//! artifact manifest's schema ([`MultiplierSpec::to_json`] /
//! [`MultiplierSpec::from_json`]), so a design is written identically in
//! `artifacts/manifest.json`, the result store, and on the wire.
//!
//! `u64` fields that can exceed 2^53 (seeds, sample budgets) are
//! accepted as JSON numbers *or* decimal strings, mirroring the store's
//! key encoding.

use std::time::Duration;

use crate::coordinator::SweepOutcome;
use crate::error::{ErrorMetrics, SegmulError};
use crate::multiplier::{DesignSet, MultiplierSpec};
use crate::util::json::{obj, Json};

/// One `/v1/eval` request: a design + workload, with an optional
/// per-request deadline.
#[derive(Clone, Debug)]
pub struct EvalRequest {
    /// The design + workload to evaluate.
    pub job: crate::coordinator::EvalJob,
    /// Per-request deadline (`None`: server default).
    pub deadline: Option<Duration>,
}

/// One `/v1/sweep` request: a design-set grid streamed back as ndjson.
#[derive(Clone, Debug)]
pub struct SweepRequest {
    /// Design families to sweep.
    pub designs: DesignSet,
    /// Bit-widths to sweep.
    pub bitwidths: Vec<u32>,
    /// MC sample budget per point.
    pub mc_samples: u64,
    /// Force Monte-Carlo even at exhaustive-feasible widths.
    pub force_mc: bool,
    /// RNG seed (`None`: server default).
    pub seed: Option<u64>,
    /// Per-request deadline (`None`: server default).
    pub deadline: Option<Duration>,
}

/// One `/v1/tune` request: an accuracy budget plus grid constraints,
/// answered with the winner and the Pareto frontier.
#[derive(Clone, Debug)]
pub struct TuneRequest {
    /// The autotuner query (budget, target, grid constraints).
    pub query: crate::tune::TuneQuery,
    /// Per-request deadline (`None`: server default).
    pub deadline: Option<Duration>,
}

fn bad(reason: impl Into<String>) -> SegmulError {
    SegmulError::serve(400, reason)
}

fn parse_body(body: &[u8]) -> Result<Json, SegmulError> {
    let text = std::str::from_utf8(body).map_err(|_| bad("request body is not UTF-8"))?;
    Json::parse(text).map_err(|e| bad(format!("invalid json body: {e}")))
}

/// Accept `u64` as a JSON number or a decimal string (the codec's
/// numbers are f64 and would round seeds above 2^53).
fn num_u64(j: &Json, field: &str) -> Result<u64, SegmulError> {
    match j {
        Json::Num(_) => j
            .as_u64()
            .ok_or_else(|| bad(format!("field '{field}' must be a non-negative integer"))),
        Json::Str(s) => s
            .parse::<u64>()
            .map_err(|_| bad(format!("field '{field}' is not a decimal u64: {s:?}"))),
        _ => Err(bad(format!("field '{field}' must be an integer or decimal string"))),
    }
}

fn opt_u64(j: &Json, field: &str) -> Result<Option<u64>, SegmulError> {
    match j.get(field) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => num_u64(v, field).map(Some),
    }
}

fn deadline_of(j: &Json) -> Result<Option<Duration>, SegmulError> {
    Ok(opt_u64(j, "deadline_ms")?.map(Duration::from_millis))
}

/// A `bitwidths` array field, shared by `/v1/sweep` and `/v1/tune`.
fn bitwidths_of(j: &Json, default: Vec<u32>) -> Result<Vec<u32>, SegmulError> {
    match j.get("bitwidths") {
        None | Some(Json::Null) => Ok(default),
        Some(Json::Arr(a)) => {
            let mut out = Vec::with_capacity(a.len());
            for v in a {
                let n = v
                    .as_u64()
                    .and_then(|n| u32::try_from(n).ok())
                    .ok_or_else(|| bad("field 'bitwidths' must be an array of integers"))?;
                out.push(n);
            }
            if out.is_empty() {
                return Err(bad("field 'bitwidths' must not be empty"));
            }
            Ok(out)
        }
        Some(_) => Err(bad("field 'bitwidths' must be an array of integers")),
    }
}

/// Parse a `/v1/eval` body:
/// `{"design": {...}, "workload": {...}, "deadline_ms": 500}` where the
/// design tag is the manifest schema and the workload is one of
/// `{"kind":"exhaustive"}`, `{"kind":"mc","samples":N,"seed":S}`, or
/// `{"kind":"adaptive","max_samples":N,"seed":S,"target_rel_stderr":T}`.
pub fn parse_eval(body: &[u8], default_seed: u64) -> Result<EvalRequest, SegmulError> {
    let j = parse_body(body)?;
    if !matches!(j, Json::Obj(_)) {
        return Err(bad("request body must be a JSON object"));
    }
    let design_tag = j.get("design").ok_or_else(|| bad("missing object field 'design'"))?;
    if !matches!(design_tag, Json::Obj(_)) {
        return Err(bad("field 'design' must be a design-tag object"));
    }
    let design = MultiplierSpec::from_json(design_tag).map_err(bad)?;
    let workload = j.get("workload").ok_or_else(|| bad("missing object field 'workload'"))?;
    let kind = workload
        .get("kind")
        .and_then(Json::as_str)
        .ok_or_else(|| bad("workload missing string field 'kind'"))?;
    let seed = opt_u64(workload, "seed")?.unwrap_or(default_seed);
    let builder = crate::api::JobBuilder::new(design).seed(seed);
    let builder = match kind {
        "exhaustive" => builder.exhaustive(),
        "mc" => {
            let samples = opt_u64(workload, "samples")?
                .ok_or_else(|| bad("mc workload missing field 'samples'"))?;
            builder.monte_carlo(samples)
        }
        "adaptive" => {
            let max = opt_u64(workload, "max_samples")?
                .ok_or_else(|| bad("adaptive workload missing field 'max_samples'"))?;
            let target = workload
                .get("target_rel_stderr")
                .and_then(Json::as_f64)
                .ok_or_else(|| bad("adaptive workload missing numeric 'target_rel_stderr'"))?;
            builder.adaptive(max, target)
        }
        other => return Err(bad(format!("unknown workload kind {other:?} (exhaustive|mc|adaptive)"))),
    };
    // Spec/workload validation errors keep their own typed kinds (both
    // map to 400 on the wire, with kind "spec"/"workload" in the body).
    let job = builder.build()?;
    Ok(EvalRequest { job, deadline: deadline_of(&j)? })
}

/// Parse a `/v1/sweep` body:
/// `{"designs":"paper","bitwidths":[4,8],"samples":N,"mc":true,
///   "seed":S,"deadline_ms":D}` — all fields optional except none; the
/// defaults mirror `segmul sweep` (paper set over the configured grid).
pub fn parse_sweep(body: &[u8], default_samples: u64) -> Result<SweepRequest, SegmulError> {
    let j = if body.is_empty() { Json::Obj(Default::default()) } else { parse_body(body)? };
    if !matches!(j, Json::Obj(_)) {
        return Err(bad("request body must be a JSON object"));
    }
    let designs = match j.get("designs") {
        None | Some(Json::Null) => DesignSet::parse("paper")?,
        Some(Json::Str(s)) => DesignSet::parse(s)?,
        Some(_) => return Err(bad("field 'designs' must be a design-set name string")),
    };
    let bitwidths = bitwidths_of(&j, vec![4, 8])?;
    Ok(SweepRequest {
        designs,
        bitwidths,
        mc_samples: opt_u64(&j, "samples")?.unwrap_or(default_samples),
        force_mc: match j.get("mc") {
            None | Some(Json::Null) => false,
            Some(Json::Bool(b)) => *b,
            Some(_) => return Err(bad("field 'mc' must be a boolean")),
        },
        seed: opt_u64(&j, "seed")?,
        deadline: deadline_of(&j)?,
    })
}

/// Parse a `/v1/tune` body:
/// `{"budget":"mred<=1e-3","target":"fpga","designs":"paper",
///   "bitwidths":[4,8],"fix":true,"samples":N,"hw_vectors":V,"seed":S,
///   "deadline_ms":D}` — everything but `budget` is optional; the
/// defaults mirror `segmul tune` with the server's configured workload
/// split, over a small `[4, 8]` grid (state `bitwidths` for the full
/// paper grid). Budget grammar errors keep their typed `config` kind
/// (still 400 on the wire).
pub fn parse_tune(
    body: &[u8],
    default_samples: u64,
    exhaustive_max_n: u32,
    default_seed: u64,
) -> Result<TuneRequest, SegmulError> {
    use crate::tune::{Budget, TechTarget, TuneQuery};
    let j = parse_body(body)?;
    if !matches!(j, Json::Obj(_)) {
        return Err(bad("request body must be a JSON object"));
    }
    let budget = j.get("budget").and_then(Json::as_str).ok_or_else(|| {
        bad("missing string field 'budget' (mred<=X | nmed<=X | wce<=X | psnr>=X)")
    })?;
    let budget = Budget::parse(budget)?;
    let target = match j.get("target") {
        None | Some(Json::Null) => TechTarget::Fpga,
        Some(Json::Str(s)) => TechTarget::parse(s)?,
        Some(_) => return Err(bad("field 'target' must be \"fpga\" or \"asic\"")),
    };
    let designs = match j.get("designs") {
        None | Some(Json::Null) => DesignSet::Paper,
        Some(Json::Str(s)) => DesignSet::parse(s)?,
        Some(_) => return Err(bad("field 'designs' must be a design-set name string")),
    };
    let fix = match j.get("fix") {
        None | Some(Json::Null) => None,
        Some(Json::Bool(b)) => Some(*b),
        Some(Json::Str(s)) if s == "both" => None,
        Some(_) => return Err(bad("field 'fix' must be a boolean or \"both\"")),
    };
    let mut query = TuneQuery::new(budget)
        .target(target)
        .designs(designs)
        .bitwidths(bitwidths_of(&j, vec![4, 8])?)
        .fix(fix)
        .workload(exhaustive_max_n, opt_u64(&j, "samples")?.unwrap_or(default_samples))
        .hw_seed(opt_u64(&j, "seed")?.unwrap_or(default_seed));
    if let Some(v) = opt_u64(&j, "hw_vectors")? {
        query = query.hw_vectors(v);
    }
    query.validate()?;
    Ok(TuneRequest { query, deadline: deadline_of(&j)? })
}

/// A tune answer as a response body: the library result's JSON image
/// plus the backend identity and the degraded flag every answer-bearing
/// response carries.
pub fn tune_json(r: &crate::tune::TuneResult, backend: &str, degraded: bool) -> Json {
    match r.to_json() {
        Json::Obj(mut m) => {
            m.insert("backend".to_string(), Json::from(backend));
            m.insert("degraded".to_string(), Json::from(degraded));
            m.insert("wall_ms".to_string(), Json::from(r.wall.as_secs_f64() * 1e3));
            Json::Obj(m)
        }
        other => other,
    }
}

/// The total `SegmulError → HTTP status` mapping. Client-caused classes
/// are 4xx, capability problems 503, everything else 500; the serving
/// layer's own rejections carry their status explicitly.
pub fn status_of(e: &SegmulError) -> u16 {
    match e {
        SegmulError::Serve { status, .. } => *status,
        SegmulError::Config(_) | SegmulError::Spec { .. } | SegmulError::Workload(_) => 400,
        SegmulError::Backend(_) => 503,
        SegmulError::Artifact { .. }
        | SegmulError::Eval(_)
        | SegmulError::Stats(_)
        | SegmulError::Store { .. }
        | SegmulError::Io(_) => 500,
    }
}

/// The total `SegmulError → (status, error body)` wire mapping:
/// `{"error": {"kind": "...", "status": N, "detail": "..."}}`.
pub fn error_wire(e: &SegmulError) -> (u16, Json) {
    let status = status_of(e);
    let body = obj(vec![(
        "error",
        obj(vec![
            ("kind", Json::from(e.kind())),
            ("status", Json::from(status as u64)),
            ("detail", Json::from(e.to_string().as_str())),
        ]),
    )]);
    (status, body)
}

/// Metric fields shared by eval responses and sweep stream rows. The
/// encoding mirrors `report::sweep::sweep_json` so a served answer is
/// field-for-field comparable with the CLI sweep report.
pub fn metrics_json(m: &ErrorMetrics) -> Json {
    let mean_ber = m.mean_ber();
    obj(vec![
        ("n", Json::from(m.n as u64)),
        ("samples", Json::from(m.samples)),
        ("er", Json::from(m.er)),
        ("med_signed", Json::from(m.med_signed)),
        ("med_abs", Json::from(m.med_abs)),
        ("mae", Json::from(m.mae)),
        ("nmed", Json::from(m.nmed)),
        ("mred", Json::from(m.mred)),
        ("mean_ber", if mean_ber.is_nan() { Json::Null } else { Json::from(mean_ber) }),
    ])
}

/// One answered job as a response body / stream row. `degraded` marks a
/// closed-form answer served while the evaluation pool was unhealthy —
/// still exact (only `--analytic auto`-eligible designs are answered
/// that way), but flagged so clients can tell the service was limping.
pub fn outcome_json(o: &SweepOutcome, backend: &str, degraded: bool) -> Result<Json, SegmulError> {
    let m = o.metrics()?;
    Ok(obj(vec![
        ("design", o.job.design.to_json()),
        ("name", Json::from(o.job.design.name().as_str())),
        ("metrics", metrics_json(&m)),
        ("source", Json::from(o.source())),
        ("cached", Json::from(o.cached)),
        ("degraded", Json::from(degraded)),
        ("backend", Json::from(backend)),
        ("wall_ms", Json::from(o.wall().as_secs_f64() * 1e3)),
    ]))
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::coordinator::WorkSpec;

    fn eval_body(design: &str, workload: &str) -> String {
        format!(r#"{{"design": {design}, "workload": {workload}}}"#)
    }

    #[test]
    fn parses_a_full_eval_request() {
        let body = eval_body(
            r#"{"family":"segmented","n":8,"t":3,"fix":true}"#,
            r#"{"kind":"mc","samples":50000,"seed":"18446744073709551615"}"#,
        );
        let req = parse_eval(body.as_bytes(), 0).unwrap();
        assert_eq!(req.job.design, MultiplierSpec::Segmented { n: 8, t: 3, fix: true });
        match req.job.spec {
            WorkSpec::MonteCarlo { samples, seed } => {
                assert_eq!(samples, 50_000);
                assert_eq!(seed, u64::MAX, "string-encoded seeds survive above 2^53");
            }
            other => panic!("expected MC, got {other:?}"),
        }
        assert!(req.deadline.is_none());
    }

    #[test]
    fn session_seed_fills_in_when_absent() {
        let body = eval_body(r#"{"family":"accurate","n":8}"#, r#"{"kind":"mc","samples":10}"#);
        let req = parse_eval(body.as_bytes(), 77).unwrap();
        match req.job.spec {
            WorkSpec::MonteCarlo { seed, .. } => assert_eq!(seed, 77),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn deadline_is_extracted() {
        let body = r#"{"design": {"family":"accurate","n":4}, "workload": {"kind":"exhaustive"}, "deadline_ms": 250}"#;
        let req = parse_eval(body.as_bytes(), 0).unwrap();
        assert_eq!(req.deadline, Some(Duration::from_millis(250)));
    }

    #[test]
    fn typed_4xx_for_malformed_eval_bodies() {
        let kind_status = |body: &str| {
            let e = parse_eval(body.as_bytes(), 0).unwrap_err();
            (e.kind(), status_of(&e))
        };
        // Structural garbage: serve-kind 400s.
        assert_eq!(kind_status("not json"), ("serve", 400));
        assert_eq!(kind_status("[1,2]"), ("serve", 400));
        assert_eq!(kind_status("{}"), ("serve", 400));
        assert_eq!(kind_status(r#"{"design": 5, "workload": {"kind":"exhaustive"}}"#), ("serve", 400));
        assert_eq!(
            kind_status(&eval_body(r#"{"family":"warp","n":8}"#, r#"{"kind":"exhaustive"}"#)),
            ("serve", 400)
        );
        assert_eq!(
            kind_status(&eval_body(r#"{"family":"accurate","n":8}"#, r#"{"kind":"turbo"}"#)),
            ("serve", 400)
        );
        assert_eq!(
            kind_status(&eval_body(
                r#"{"family":"accurate","n":8}"#,
                r#"{"kind":"mc","samples":-3}"#
            )),
            ("serve", 400)
        );
        // Domain validation keeps its own typed kinds, still 400.
        assert_eq!(
            kind_status(&eval_body(
                r#"{"family":"segmented","n":8,"t":9,"fix":false}"#,
                r#"{"kind":"exhaustive"}"#
            )),
            ("spec", 400)
        );
        assert_eq!(
            kind_status(&eval_body(
                r#"{"family":"accurate","n":8}"#,
                r#"{"kind":"mc","samples":0}"#
            )),
            ("workload", 400)
        );
    }

    #[test]
    fn sweep_defaults_and_overrides() {
        let req = parse_sweep(b"", 1000).unwrap();
        assert_eq!(req.designs.name(), "paper");
        assert_eq!(req.bitwidths, vec![4, 8]);
        assert_eq!(req.mc_samples, 1000);
        assert!(!req.force_mc && req.seed.is_none() && req.deadline.is_none());
        let req = parse_sweep(
            br#"{"designs":"all","bitwidths":[8],"samples":500,"mc":true,"seed":9,"deadline_ms":100}"#,
            1000,
        )
        .unwrap();
        assert_eq!(req.designs.name(), "all");
        assert_eq!((req.mc_samples, req.seed), (500, Some(9)));
        assert!(req.force_mc);
        assert_eq!(req.deadline, Some(Duration::from_millis(100)));
        assert!(parse_sweep(br#"{"designs":"nope"}"#, 1).is_err());
        assert!(parse_sweep(br#"{"bitwidths":[]}"#, 1).is_err());
        assert!(parse_sweep(br#"{"bitwidths":"x"}"#, 1).is_err());
        assert!(parse_sweep(br#"{"mc":"yes"}"#, 1).is_err());
    }

    #[test]
    fn tune_defaults_and_overrides() {
        use crate::tune::{BudgetMetric, TechTarget};
        let req = parse_tune(br#"{"budget":"mred<=1e-3"}"#, 1000, 12, 7).unwrap();
        assert_eq!(req.query.budget.metric, BudgetMetric::Mred);
        assert_eq!(req.query.budget.max, 1e-3);
        assert_eq!(req.query.target, TechTarget::Fpga);
        assert_eq!(req.query.bitwidths, vec![4, 8]);
        assert_eq!(req.query.mc_samples, 1000);
        assert_eq!(req.query.hw_seed, 7);
        assert!(req.query.fix.is_none() && req.deadline.is_none());
        let req = parse_tune(
            br#"{"budget":"psnr>=40","target":"asic","designs":"paper",
                 "bitwidths":[8],"fix":true,"samples":500,"hw_vectors":64,
                 "seed":9,"deadline_ms":250}"#,
            1000,
            12,
            7,
        )
        .unwrap();
        assert_eq!(req.query.budget.psnr_db, Some(40.0));
        assert_eq!(req.query.target, TechTarget::Asic);
        assert_eq!(req.query.bitwidths, vec![8]);
        assert_eq!(req.query.fix, Some(true));
        assert_eq!((req.query.mc_samples, req.query.hw_vectors, req.query.hw_seed), (500, 64, 9));
        assert_eq!(req.deadline, Some(Duration::from_millis(250)));
    }

    #[test]
    fn tune_rejections_are_typed_400s() {
        let kind_status = |body: &[u8]| {
            let e = parse_tune(body, 1000, 12, 0).unwrap_err();
            (e.kind(), status_of(&e))
        };
        assert_eq!(kind_status(b"{}"), ("serve", 400));
        assert_eq!(kind_status(br#"{"budget":"er<=1"}"#), ("config", 400));
        assert_eq!(kind_status(br#"{"budget":"mred<=1e-3","target":"gpu"}"#), ("config", 400));
        assert_eq!(kind_status(br#"{"budget":"mred<=1e-3","fix":"maybe"}"#), ("serve", 400));
        assert_eq!(kind_status(br#"{"budget":"mred<=1e-3","bitwidths":[]}"#), ("serve", 400));
        assert_eq!(kind_status(br#"{"budget":"mred<=1e-3","bitwidths":[40]}"#), ("spec", 400));
    }

    #[test]
    fn error_mapping_is_total_and_typed() {
        let cases = [
            (SegmulError::serve(429, "budget"), 429, "serve"),
            (SegmulError::serve(503, "draining"), 503, "serve"),
            (SegmulError::serve(504, "deadline"), 504, "serve"),
            (SegmulError::config("x"), 400, "config"),
            (SegmulError::spec("d", "r"), 400, "spec"),
            (SegmulError::workload("w"), 400, "workload"),
            (SegmulError::backend("b"), 503, "backend"),
            (SegmulError::artifact("p", "r"), 500, "artifact"),
            (SegmulError::Eval("e".into()), 500, "eval"),
            (SegmulError::stats("s"), 500, "stats"),
            (SegmulError::store("p", "r"), 500, "store"),
            (SegmulError::Io("i".into()), 500, "io"),
        ];
        for (e, status, kind) in cases {
            let (s, body) = error_wire(&e);
            assert_eq!(s, status, "{e}");
            let err = body.get("error").unwrap();
            assert_eq!(err.get("kind").unwrap().as_str(), Some(kind));
            assert_eq!(err.get("status").unwrap().as_u64(), Some(status as u64));
            assert!(err.get("detail").unwrap().as_str().is_some());
        }
    }
}
