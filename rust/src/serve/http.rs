//! Hand-rolled HTTP/1.1 request parsing and response writing.
//!
//! The build is fully offline (no hyper/tokio), so the wire protocol is
//! implemented directly on [`std::net::TcpStream`] with strict limits:
//! every malformed input — truncated head or body, oversized payload,
//! bogus content-length, unsupported transfer encoding — becomes a typed
//! [`SegmulError::Serve`] carrying the 4xx status the router writes
//! back. Nothing in this module panics on attacker-controlled bytes.
//!
//! Responses always carry `Connection: close`: one request per
//! connection keeps the state machine trivially correct under pipelined
//! garbage (whatever follows the first request is never interpreted).

use std::io::{Read, Write};
use std::net::TcpStream;

use crate::error::SegmulError;
use crate::util::json::Json;

/// Hard parser limits. Defaults are generous for the JSON bodies this
/// API carries while keeping a hostile peer from ballooning memory.
#[derive(Clone, Debug)]
pub struct Limits {
    /// Maximum bytes of request line + headers.
    pub max_head: usize,
    /// Maximum header count.
    pub max_headers: usize,
    /// Maximum request-body bytes (413 beyond).
    pub max_body: usize,
}

impl Default for Limits {
    fn default() -> Self {
        Limits { max_head: 8 * 1024, max_headers: 64, max_body: 1 << 20 }
    }
}

/// One parsed request.
#[derive(Clone, Debug)]
pub struct Request {
    /// Request method (uppercase).
    pub method: String,
    /// Path only (any `?query` suffix is split off and ignored).
    pub path: String,
    /// Header name (lowercased) / value pairs, in arrival order.
    pub headers: Vec<(String, String)>,
    /// Raw body bytes.
    pub body: Vec<u8>,
}

impl Request {
    /// First header value by case-insensitive name.
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    }
}

fn bad(status: u16, reason: impl Into<String>) -> SegmulError {
    SegmulError::serve(status, reason)
}

fn io_reason(e: &std::io::Error) -> SegmulError {
    if matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut) {
        bad(408, "request read timed out")
    } else {
        bad(400, format!("connection error while reading request: {e}"))
    }
}

/// Read and parse exactly one request from the stream, enforcing
/// `limits`. The caller is expected to have set a read timeout on the
/// stream; a timeout surfaces as a typed 408, never a hung thread.
pub fn read_request(stream: &mut TcpStream, limits: &Limits) -> Result<Request, SegmulError> {
    // -- head: byte-wise until CRLFCRLF, bounded by max_head ------------
    let mut head: Vec<u8> = Vec::with_capacity(512);
    let mut byte = [0u8; 1];
    loop {
        match stream.read(&mut byte) {
            Ok(0) => {
                if head.is_empty() {
                    return Err(bad(400, "empty request (peer closed before any bytes)"));
                }
                return Err(bad(400, "truncated request head (peer closed mid-headers)"));
            }
            Ok(_) => head.push(byte[0]),
            Err(e) => return Err(io_reason(&e)),
        }
        if head.len() > limits.max_head {
            return Err(bad(431, format!("request head exceeds {} bytes", limits.max_head)));
        }
        if head.ends_with(b"\r\n\r\n") {
            break;
        }
    }
    let head = std::str::from_utf8(&head).map_err(|_| bad(400, "request head is not UTF-8"))?;
    let mut lines = head.trim_end_matches("\r\n").split("\r\n");

    // -- request line ---------------------------------------------------
    let request_line = lines.next().unwrap_or_default();
    let mut parts = request_line.split(' ');
    let (method, target, version) =
        match (parts.next(), parts.next(), parts.next(), parts.next()) {
            (Some(m), Some(t), Some(v), None) if !m.is_empty() && !t.is_empty() => (m, t, v),
            _ => return Err(bad(400, format!("malformed request line {request_line:?}"))),
        };
    if version != "HTTP/1.1" && version != "HTTP/1.0" {
        return Err(bad(400, format!("unsupported protocol version {version:?}")));
    }
    let path = target.split('?').next().unwrap_or(target).to_string();
    if !path.starts_with('/') {
        return Err(bad(400, format!("request target {target:?} is not an absolute path")));
    }

    // -- headers ----------------------------------------------------------
    let mut headers: Vec<(String, String)> = Vec::new();
    for line in lines {
        if headers.len() >= limits.max_headers {
            return Err(bad(431, format!("more than {} headers", limits.max_headers)));
        }
        let Some((name, value)) = line.split_once(':') else {
            return Err(bad(400, format!("malformed header line {line:?}")));
        };
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }

    // -- body -------------------------------------------------------------
    let mut req = Request { method: method.to_string(), path, headers, body: Vec::new() };
    if req.header("transfer-encoding").is_some() {
        return Err(bad(400, "transfer-encoding request bodies are not supported"));
    }
    let content_length = match req.header("content-length") {
        None => 0usize,
        Some(raw) => raw
            .parse::<u64>()
            .ok()
            .and_then(|v| usize::try_from(v).ok())
            .ok_or_else(|| bad(400, format!("bogus content-length {raw:?}")))?,
    };
    if content_length > limits.max_body {
        return Err(bad(
            413,
            format!("payload of {content_length} bytes exceeds the {}-byte limit", limits.max_body),
        ));
    }
    if content_length > 0 {
        let mut body = vec![0u8; content_length];
        let mut read = 0usize;
        while read < content_length {
            match stream.read(&mut body[read..]) {
                Ok(0) => {
                    return Err(bad(
                        400,
                        format!("truncated body: got {read} of {content_length} declared bytes"),
                    ))
                }
                Ok(k) => read += k,
                Err(e) => return Err(io_reason(&e)),
            }
        }
        req.body = body;
    }
    Ok(req)
}

/// Canonical reason phrase for the statuses this server emits.
pub fn status_text(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Response",
    }
}

/// Write one fixed-length response and flush. `Connection: close` always.
pub fn write_response(
    stream: &mut TcpStream,
    status: u16,
    content_type: &str,
    body: &[u8],
) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        status,
        status_text(status),
        content_type,
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()
}

/// Write a JSON response body.
pub fn write_json(stream: &mut TcpStream, status: u16, body: &Json) -> std::io::Result<()> {
    let mut text = body.to_string_compact();
    text.push('\n');
    write_response(stream, status, "application/json", text.as_bytes())
}

/// Chunked transfer-encoding writer for streamed responses
/// (`POST /v1/sweep` progress). One `chunk` per payload line; `finish`
/// writes the terminating zero-chunk.
pub struct ChunkedWriter<'a> {
    stream: &'a mut TcpStream,
}

impl<'a> ChunkedWriter<'a> {
    /// Write the response head and return the writer.
    pub fn start(
        stream: &'a mut TcpStream,
        status: u16,
        content_type: &str,
    ) -> std::io::Result<ChunkedWriter<'a>> {
        let head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nTransfer-Encoding: chunked\r\nConnection: close\r\n\r\n",
            status,
            status_text(status),
            content_type
        );
        stream.write_all(head.as_bytes())?;
        stream.flush()?;
        Ok(ChunkedWriter { stream })
    }

    /// Write one chunk (empty payloads are skipped: a zero-length chunk
    /// would terminate the stream).
    pub fn chunk(&mut self, payload: &[u8]) -> std::io::Result<()> {
        if payload.is_empty() {
            return Ok(());
        }
        write!(self.stream, "{:x}\r\n", payload.len())?;
        self.stream.write_all(payload)?;
        self.stream.write_all(b"\r\n")?;
        self.stream.flush()
    }

    /// One JSON value as one newline-terminated chunk (ndjson framing).
    pub fn json_line(&mut self, value: &Json) -> std::io::Result<()> {
        let mut text = value.to_string_compact();
        text.push('\n');
        self.chunk(text.as_bytes())
    }

    /// Terminate the chunked body.
    pub fn finish(self) -> std::io::Result<()> {
        self.stream.write_all(b"0\r\n\r\n")?;
        self.stream.flush()
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use std::net::{TcpListener, TcpStream};

    /// Feed raw bytes through a real socket pair into the parser.
    fn parse(raw: &[u8]) -> Result<Request, SegmulError> {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        client.write_all(raw).unwrap();
        client.flush().unwrap();
        // Half-close so reads past the payload see EOF, not a hang.
        client.shutdown(std::net::Shutdown::Write).unwrap();
        let (mut server_side, _) = listener.accept().unwrap();
        server_side
            .set_read_timeout(Some(std::time::Duration::from_secs(2)))
            .unwrap();
        read_request(&mut server_side, &Limits::default())
    }

    #[test]
    fn parses_a_post_with_body() {
        let req = parse(b"POST /v1/eval HTTP/1.1\r\nHost: x\r\nContent-Length: 4\r\n\r\nabcd").unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/eval");
        assert_eq!(req.body, b"abcd");
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.header("HOST"), Some("x"));
    }

    #[test]
    fn strips_query_and_tolerates_http10() {
        let req = parse(b"GET /metrics?x=1 HTTP/1.0\r\n\r\n").unwrap();
        assert_eq!(req.path, "/metrics");
        assert!(req.body.is_empty());
    }

    #[test]
    fn typed_errors_for_malformed_requests() {
        let status = |raw: &[u8]| match parse(raw).unwrap_err() {
            SegmulError::Serve { status, .. } => status,
            other => panic!("expected serve error, got {other:?}"),
        };
        // Truncated head / empty connection.
        assert_eq!(status(b""), 400);
        assert_eq!(status(b"GET /x HT"), 400);
        // Malformed request line and versions.
        assert_eq!(status(b"NONSENSE\r\n\r\n"), 400);
        assert_eq!(status(b"GET /x HTTP/3.0\r\n\r\n"), 400);
        assert_eq!(status(b"GET x HTTP/1.1\r\n\r\n"), 400);
        // Bogus content lengths.
        assert_eq!(status(b"POST / HTTP/1.1\r\nContent-Length: abc\r\n\r\n"), 400);
        assert_eq!(status(b"POST / HTTP/1.1\r\nContent-Length: -5\r\n\r\n"), 400);
        assert_eq!(
            status(b"POST / HTTP/1.1\r\nContent-Length: 99999999999999999999\r\n\r\n"),
            400
        );
        // Truncated body: fewer bytes than declared.
        assert_eq!(status(b"POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nab"), 400);
        // Oversized payload is refused from the declared length alone.
        assert_eq!(status(b"POST / HTTP/1.1\r\nContent-Length: 9999999\r\n\r\n"), 413);
        // Chunked request bodies are unsupported.
        assert_eq!(status(b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n"), 400);
        // Header bombs.
        let mut bomb = b"GET / HTTP/1.1\r\n".to_vec();
        bomb.extend(vec![b'a'; 9000]);
        assert_eq!(status(&bomb), 431);
    }
}
