//! Request dispatch: one connection = one request = one typed response.
//!
//! | Method | Path           | Body                | Response                          |
//! |--------|----------------|---------------------|-----------------------------------|
//! | GET    | `/healthz`     | —                   | `{"status","backend"}` (503 drain)|
//! | GET    | `/v1/designs`  | —                   | registry design tags              |
//! | GET    | `/metrics`     | —                   | text `key value` counters         |
//! | POST   | `/v1/eval`     | design + workload   | one answered job (JSON)           |
//! | POST   | `/v1/sweep`    | grid request        | chunked ndjson stream             |
//! | POST   | `/v1/tune`     | budget + grid       | winner + Pareto frontier (JSON)   |
//! | POST   | `/v1/shutdown` | —                   | `{"status":"draining"}`           |
//!
//! Every error path funnels through [`wire::error_wire`], so the full
//! [`SegmulError`] taxonomy maps onto HTTP statuses in exactly one
//! place.

use std::io::Read;
use std::net::{Shutdown, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, sync_channel, RecvTimeoutError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::SweepGrid;
use crate::error::SegmulError;
use crate::multiplier::MultiplierSpec;
use crate::util::json::{obj, Json};

use super::http::{self, ChunkedWriter, Request};
use super::{wire, EvalWork, Shared, SweepEvent, SweepWork, TuneWork, Work};

/// Serve one connection: parse, dispatch, record latency + status.
pub(crate) fn handle(shared: &Arc<Shared>, mut stream: TcpStream) {
    let start = Instant::now();
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_secs(10)));
    let status = serve_one(shared, &mut stream);
    // Lingering close: half-close the write side, then drain whatever
    // the peer already sent (e.g. pipelined bytes this server never
    // parses) so the final close cannot RST the response out of the
    // peer's receive buffer. Bounded by a short read timeout.
    let _ = stream.shutdown(Shutdown::Write);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(500)));
    let mut sink = [0u8; 512];
    while matches!(stream.read(&mut sink), Ok(n) if n > 0) {}
    shared.metrics.observe_response(status);
    shared.metrics.record_latency(start.elapsed().as_secs_f64() * 1e3);
}

fn serve_one(shared: &Arc<Shared>, stream: &mut TcpStream) -> u16 {
    let req = match http::read_request(stream, &shared.cfg.limits) {
        Ok(r) => r,
        Err(e) => return write_error(stream, &e),
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => healthz(shared, stream),
        ("GET", "/v1/designs") => designs(stream),
        ("GET", "/metrics") => metrics_doc(shared, stream),
        ("POST", "/v1/eval") => eval(shared, stream, &req),
        ("POST", "/v1/sweep") => sweep(shared, stream, &req),
        ("POST", "/v1/tune") => tune(shared, stream, &req),
        ("POST", "/v1/shutdown") => shutdown(shared, stream),
        (m, p @ ("/healthz" | "/v1/designs" | "/metrics" | "/v1/eval" | "/v1/sweep"
        | "/v1/tune" | "/v1/shutdown")) => {
            write_error(stream, &SegmulError::serve(405, format!("method {m} not allowed on {p}")))
        }
        (_, p) => write_error(stream, &SegmulError::serve(404, format!("no route {p:?}"))),
    }
}

fn write_error(stream: &mut TcpStream, e: &SegmulError) -> u16 {
    let (status, body) = wire::error_wire(e);
    let _ = http::write_json(stream, status, &body);
    status
}

fn healthz(shared: &Arc<Shared>, stream: &mut TcpStream) -> u16 {
    let draining = shared.draining.load(Ordering::SeqCst);
    let degraded = shared.degraded.load(Ordering::SeqCst);
    let status = if draining { 503 } else { 200 };
    let state = match (draining, degraded) {
        (true, _) => "draining",
        (false, true) => "degraded",
        (false, false) => "ok",
    };
    let body = obj(vec![
        ("status", Json::from(state)),
        ("degraded", Json::from(degraded)),
        ("backend", Json::from(shared.backend_name())),
    ]);
    let _ = http::write_json(stream, status, &body);
    status
}

fn designs(stream: &mut TcpStream) -> u16 {
    let rows: Vec<Json> = MultiplierSpec::registry_examples(8)
        .iter()
        .map(|s| {
            obj(vec![
                ("design", s.to_json()),
                ("name", Json::from(s.name().as_str())),
                ("family", Json::from(s.family())),
            ])
        })
        .collect();
    let _ = http::write_json(stream, 200, &obj(vec![("designs", Json::Arr(rows))]));
    200
}

fn metrics_doc(shared: &Arc<Shared>, stream: &mut TcpStream) -> u16 {
    let telemetry = super::lock_clean(&shared.telemetry).clone();
    let doc = shared.metrics.render(
        &telemetry,
        shared.backend_name(),
        shared.draining.load(Ordering::SeqCst),
        shared.degraded.load(Ordering::SeqCst),
        shared.queue_depth(),
    );
    let _ = http::write_response(stream, 200, "text/plain; charset=utf-8", doc.as_bytes());
    200
}

fn shutdown(shared: &Arc<Shared>, stream: &mut TcpStream) -> u16 {
    shared.draining.store(true, Ordering::SeqCst);
    shared.ready.notify_all();
    let _ = http::write_json(stream, 200, &obj(vec![("status", Json::from("draining"))]));
    200
}

fn eval(shared: &Arc<Shared>, stream: &mut TcpStream, req: &Request) -> u16 {
    let parsed = match wire::parse_eval(&req.body, shared.cfg.seed) {
        Ok(p) => p,
        Err(e) => return write_error(stream, &e),
    };
    let deadline = parsed.deadline.unwrap_or(shared.cfg.default_deadline);
    let (reply, answer) = sync_channel(1);
    let cancelled = Arc::new(AtomicBool::new(false));
    let work = EvalWork { job: parsed.job, reply, cancelled: cancelled.clone() };
    if let Err(e) = shared.admit(Work::Eval(work)) {
        return write_error(stream, &e);
    }
    match answer.recv_timeout(deadline) {
        Ok(Ok((outcome, degraded))) => match wire::outcome_json(&outcome, shared.backend_name(), degraded) {
            Ok(body) => {
                let _ = http::write_json(stream, 200, &body);
                200
            }
            Err(e) => write_error(stream, &e),
        },
        Ok(Err(e)) => write_error(stream, &e),
        Err(RecvTimeoutError::Timeout) => {
            cancelled.store(true, Ordering::SeqCst);
            shared.metrics.deadline_timeouts.fetch_add(1, Ordering::Relaxed);
            write_error(
                stream,
                &SegmulError::serve(
                    504,
                    format!("deadline of {} ms elapsed before the engine answered", deadline.as_millis()),
                ),
            )
        }
        Err(RecvTimeoutError::Disconnected) => {
            write_error(stream, &SegmulError::serve(500, "engine exited before answering"))
        }
    }
}

fn tune(shared: &Arc<Shared>, stream: &mut TcpStream, req: &Request) -> u16 {
    let parsed = match wire::parse_tune(
        &req.body,
        shared.cfg.mc_samples,
        shared.cfg.exhaustive_max_n,
        shared.cfg.seed,
    ) {
        Ok(p) => p,
        Err(e) => return write_error(stream, &e),
    };
    let deadline = parsed.deadline.unwrap_or(shared.cfg.default_deadline);
    let (reply, answer) = sync_channel(1);
    let cancelled = Arc::new(AtomicBool::new(false));
    let work = TuneWork { query: parsed.query, reply, cancelled: cancelled.clone() };
    if let Err(e) = shared.admit(Work::Tune(work)) {
        return write_error(stream, &e);
    }
    match answer.recv_timeout(deadline) {
        Ok(Ok((result, degraded))) => {
            let body = wire::tune_json(&result, shared.backend_name(), degraded);
            let _ = http::write_json(stream, 200, &body);
            200
        }
        Ok(Err(e)) => write_error(stream, &e),
        Err(RecvTimeoutError::Timeout) => {
            cancelled.store(true, Ordering::SeqCst);
            shared.metrics.deadline_timeouts.fetch_add(1, Ordering::Relaxed);
            write_error(
                stream,
                &SegmulError::serve(
                    504,
                    format!(
                        "deadline of {} ms elapsed before the tuner answered",
                        deadline.as_millis()
                    ),
                ),
            )
        }
        Err(RecvTimeoutError::Disconnected) => {
            write_error(stream, &SegmulError::serve(500, "engine exited before answering"))
        }
    }
}

fn sweep(shared: &Arc<Shared>, stream: &mut TcpStream, req: &Request) -> u16 {
    let parsed = match wire::parse_sweep(&req.body, shared.cfg.mc_samples) {
        Ok(p) => p,
        Err(e) => return write_error(stream, &e),
    };
    let grid = SweepGrid {
        bitwidths: parsed.bitwidths,
        designs: parsed.designs,
        exhaustive_max_n: shared.cfg.exhaustive_max_n,
        force_mc: parsed.force_mc,
        mc_samples: parsed.mc_samples,
        seed: parsed.seed.unwrap_or(shared.cfg.seed),
    };
    let jobs: std::collections::VecDeque<_> = grid.jobs().into();
    let total = jobs.len() as u64;
    let deadline = parsed.deadline.unwrap_or(shared.cfg.default_deadline);
    // Unbounded events channel: the engine never blocks on a slow
    // client; a vanished client is detected by the failed send instead.
    let (events, rows) = channel();
    let cancelled = Arc::new(AtomicBool::new(false));
    let work = SweepWork { jobs, events, cancelled: cancelled.clone() };
    if let Err(e) = shared.admit(Work::Sweep(work)) {
        return write_error(stream, &e);
    }
    let start = Instant::now();
    let Ok(mut writer) = ChunkedWriter::start(stream, 200, "application/x-ndjson") else {
        cancelled.store(true, Ordering::SeqCst);
        return 200; // head may be half-written; the socket is dead anyway
    };
    let mut done = 0u64;
    loop {
        let remaining = deadline.saturating_sub(start.elapsed());
        match rows.recv_timeout(remaining) {
            Ok(SweepEvent::Row(outcome, degraded)) => {
                done += 1;
                let line = match wire::outcome_json(&outcome, shared.backend_name(), degraded) {
                    Ok(row) => obj(vec![
                        ("row", row),
                        ("done", Json::from(done)),
                        ("total", Json::from(total)),
                    ]),
                    Err(e) => wire::error_wire(&e).1,
                };
                if writer.json_line(&line).is_err() {
                    cancelled.store(true, Ordering::SeqCst);
                    return 200;
                }
            }
            Ok(SweepEvent::Done) => {
                let _ = writer.json_line(&obj(vec![
                    ("status", Json::from("complete")),
                    ("done", Json::from(done)),
                    ("total", Json::from(total)),
                ]));
                let _ = writer.finish();
                return 200;
            }
            Ok(SweepEvent::Failed(e)) => {
                let _ = writer.json_line(&wire::error_wire(&e).1);
                let _ = writer.finish();
                return 200;
            }
            Err(RecvTimeoutError::Timeout) => {
                // The stream already committed a 200 head; the timeout is
                // delivered in-band as a typed error row, and the engine
                // drops the remaining grid via the cancellation flag.
                cancelled.store(true, Ordering::SeqCst);
                shared.metrics.deadline_timeouts.fetch_add(1, Ordering::Relaxed);
                let e = SegmulError::serve(
                    504,
                    format!(
                        "deadline of {} ms elapsed after {done}/{total} grid points",
                        deadline.as_millis()
                    ),
                );
                let _ = writer.json_line(&wire::error_wire(&e).1);
                let _ = writer.finish();
                return 200;
            }
            Err(RecvTimeoutError::Disconnected) => {
                let e = SegmulError::serve(500, "engine exited mid-sweep");
                let _ = writer.json_line(&wire::error_wire(&e).1);
                let _ = writer.finish();
                return 200;
            }
        }
    }
}
